"""Hillclimb helper: measure one (arch, shape) cell end to end.

Runs the full-depth compile + the unrolled depth variants, then prints the
three roofline terms, dominant bottleneck, HBM, and per-collective bytes.

  PYTHONPATH=src python scripts/measure_cell.py --arch kimi-k2-1t-a32b --shape train_4k
"""
import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)
import argparse
import json

from repro.configs import get_config
from repro.launch import dryrun as dr
from repro.launch.roofline import HBM_BW, LINK_BW, PEAK_FLOPS, model_flops_per_device


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="pod")
    ap.add_argument("--json", default=None, help="dump raw results here")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    reps_full = [s.repeats for s in cfg.stages]
    n_stages = len(reps_full)

    full = dr.run_cell(args.arch, args.shape, args.mesh)
    variants = {}
    if n_stages == 1:
        for r in ("1", "2"):
            variants[r] = dr.run_cell(args.arch, args.shape, args.mesh, stage_repeats=r)
        b = {
            k: variants["2"][k] - variants["1"][k]
            for k in ("flops", "bytes_accessed")
        }
        b["coll"] = variants["2"]["collectives"]["total"] - variants["1"]["collectives"]["total"]
        flops = variants["1"]["flops"] + (reps_full[0] - 1) * max(0, b["flops"])
        byts = variants["1"]["bytes_accessed"] + (reps_full[0] - 1) * max(0, b["bytes_accessed"])
        coll = variants["1"]["collectives"]["total"] + (reps_full[0] - 1) * max(0, b["coll"])
    else:
        for r in ("1,1", "2,1", "1,2"):
            variants[r] = dr.run_cell(args.arch, args.shape, args.mesh, stage_repeats=r)
        v = variants
        flops = v["1,1"]["flops"] + (reps_full[0] - 1) * max(0, v["2,1"]["flops"] - v["1,1"]["flops"]) \
            + (reps_full[1] - 1) * max(0, v["1,2"]["flops"] - v["1,1"]["flops"])
        byts = v["1,1"]["bytes_accessed"] \
            + (reps_full[0] - 1) * max(0, v["2,1"]["bytes_accessed"] - v["1,1"]["bytes_accessed"]) \
            + (reps_full[1] - 1) * max(0, v["1,2"]["bytes_accessed"] - v["1,1"]["bytes_accessed"])
        coll = v["1,1"]["collectives"]["total"] \
            + (reps_full[0] - 1) * max(0, v["2,1"]["collectives"]["total"] - v["1,1"]["collectives"]["total"]) \
            + (reps_full[1] - 1) * max(0, v["1,2"]["collectives"]["total"] - v["1,1"]["collectives"]["total"])

    t_c, t_m, t_x = flops / PEAK_FLOPS, byts / HBM_BW, coll / LINK_BW
    mem = full["memory"]
    hbm = (mem["argument_size_in_bytes"] + mem["temp_size_in_bytes"]) / 2**30
    mf = model_flops_per_device(args.arch, args.shape, full["n_devices"])
    print(f"\n=== {args.arch} x {args.shape} on {args.mesh} ===")
    print(f"compute    {t_c:.4e} s")
    print(f"memory     {t_m:.4e} s")
    print(f"collective {t_x:.4e} s")
    dom = max((t_c, 'compute'), (t_m, 'memory'), (t_x, 'collective'))
    print(f"dominant   {dom[1]}  (bound {dom[0]:.4e} s; roofline frac {t_c/dom[0]:.3f})")
    print(f"useful/HLO {min(1.0, mf/max(flops,1)):.3f}   HBM {hbm:.1f} GiB/dev")
    print(f"collectives (full-depth raw): "
          f"{json.dumps({k: round(v/2**30, 3) for k, v in full['collectives'].items()})} GiB")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"full": full, "variants": variants,
                       "corrected": {"flops": flops, "bytes": byts, "coll": coll}}, f, indent=1)


if __name__ == "__main__":
    main()
