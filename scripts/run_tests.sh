#!/usr/bin/env bash
# Tier-1 test wrapper.
#
#   scripts/run_tests.sh            fast tier (default: slow marker excluded)
#   scripts/run_tests.sh --all      everything, including @pytest.mark.slow
#                                   and the full @pytest.mark.dist mesh tier
#   scripts/run_tests.sh --dist     distributed tier only: every forced-host-
#                                   device-count mesh test (test_distributed,
#                                   test_sharded_artifacts), slow members
#                                   included — the tier that pins programmed
#                                   crossbar serving under shard_map EP/TP
#   scripts/run_tests.sh --lifecycle  chip-lifecycle tier only: aging /
#                                   health-monitor / compensation / hot-swap
#                                   tests (@pytest.mark.lifecycle), slow
#                                   members included
#   scripts/run_tests.sh --serving  serving traffic tier only: engine
#                                   request-lifecycle regression tests +
#                                   continuous-batching scheduler / block
#                                   KV cache / chip-farm tests
#                                   (@pytest.mark.serving, slow members
#                                   included), then the serving_traffic
#                                   bench gates (bit-exactness vs the
#                                   slot-loop engine, p99 tick ceiling,
#                                   tokens/tick floor, farm scaling)
#   scripts/run_tests.sh --lint     static-analysis tier only: the
#                                   repro.analysis test suite plus the
#                                   python -m repro.analysis --check CI gate
#                                   (nonzero exit on any error-level finding)
#   scripts/run_tests.sh --bench    fast kernel-benchmark tier; runs the
#                                   BENCH_kernels.json --check regression gate
#                                   by default: fails on a >20% regression of
#                                   any headline number (bit-exactness flags,
#                                   conversion counts, repair recovery) or on
#                                   a programmed/repaired steady-state speedup
#                                   below the 5x acceptance floor, then
#                                   refreshes the file
#   scripts/run_tests.sh <args...>  extra args forwarded to pytest
#
# Wall-clock budget: the default fast tier targets < ~5 min on a laptop-class
# CPU (interpret-mode Pallas).  Anything heavier belongs behind
# @pytest.mark.slow (or the dist/lifecycle tiers); re-triage with
#   python -m pytest -q --durations=25
# when the fast tier creeps past the budget.
#
# pytest exits 2 on collection errors, so a broken import fails the run.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" == "--all" ]]; then
  shift
  # later -m overrides the "not slow" default from pytest.ini addopts; the
  # empty expression selects everything, dist tier included — CI cannot
  # skip the mesh tier silently, and a marker typo that deselected it
  # would fail the collection count guard below
  python -m pytest -q -m "" "$@"
  # guard: the dist tier must actually have been collected (an accidental
  # testpaths/marker change that drops the mesh tier should fail loudly)
  python -m pytest -q -m dist --collect-only >/dev/null
  exit 0
fi
if [[ "${1:-}" == "--dist" ]]; then
  shift
  # -m dist overrides the "not slow" default: the whole mesh tier runs,
  # slow members included
  exec python -m pytest -q -m dist "$@"
fi
if [[ "${1:-}" == "--lifecycle" ]]; then
  shift
  # -m lifecycle overrides the "not slow" default: the whole lifecycle
  # tier runs, slow members included
  exec python -m pytest -q -m lifecycle "$@"
fi
if [[ "${1:-}" == "--serving" ]]; then
  shift
  # -m serving overrides the "not slow" default: the whole serving tier
  # runs, slow members included
  python -m pytest -q -m serving "$@"
  exec python -m benchmarks.run --only serving_traffic --check
fi
if [[ "${1:-}" == "--lint" ]]; then
  shift
  python -m pytest -q tests/test_analysis.py "$@"
  exec python -m repro.analysis --check
fi
if [[ "${1:-}" == "--bench" ]]; then
  shift
  exec python -m benchmarks.run --only kernel --check "$@"
fi
# default fast tier: the static-analysis CI gate rides along — a contract
# violation fails the run before (cheaply, from source alone) the tests do
python -m repro.analysis --check --quiet
exec python -m pytest -q "$@"
