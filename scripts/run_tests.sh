#!/usr/bin/env bash
# Tier-1 test wrapper.
#
#   scripts/run_tests.sh            fast tier (default: slow marker excluded)
#   scripts/run_tests.sh --all      everything, including @pytest.mark.slow
#   scripts/run_tests.sh --bench    fast kernel-benchmark tier; runs the
#                                   BENCH_kernels.json --check regression gate
#                                   by default: fails on a >20% regression of
#                                   any headline number (bit-exactness flags,
#                                   conversion counts, repair recovery) or on
#                                   a programmed/repaired steady-state speedup
#                                   below the 5x acceptance floor, then
#                                   refreshes the file
#   scripts/run_tests.sh <args...>  extra args forwarded to pytest
#
# pytest exits 2 on collection errors, so a broken import fails the run.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

if [[ "${1:-}" == "--all" ]]; then
  shift
  # later -m overrides the "not slow" default from pytest.ini addopts
  exec python -m pytest -q -m "" "$@"
fi
if [[ "${1:-}" == "--bench" ]]; then
  shift
  exec python -m benchmarks.run --only kernel --check "$@"
fi
exec python -m pytest -q "$@"
