"""Fault-aware mapping & spare-column repair, end to end.

Pins down: planner determinism and report consistency; column-separability
(pre-gathered repaired layouts == physical layout + output gather, bit for
bit, for every kernel); programmed-vs-per-call bit-identity with repair
active; the zero-fault no-op guarantee; mapper fault provisioning; and the
repo's model-level acceptance bar — spare-column repair recovers >= 70% of
the stuck-at logit-MSE degradation at a 1% fault rate on a tiny LM whose
every projection routes through the crossbar.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import crossbar as cb
from repro.device import (
    DeviceConfig,
    apply_repair,
    effective_cell_codes,
    plan_repair,
    program_layer,
    program_model,
    programmed_matmul,
    repair_report,
    spare_budget,
    wants_repair,
)
from repro.kernels import ops

SPEC = cb.layer_scaled_spec(cb.DEFAULT_SPEC, 256)
FAULTY = DeviceConfig(p_stuck_on=5e-3, p_stuck_off=5e-3, spare_cols=32, seed=0)


def _codes(rng, K, N):
    w = jnp.asarray(rng.integers(-(1 << 15), 1 << 15, size=(K, N)))
    return w.astype(jnp.int32) + SPEC.weight_bias


# ---------------------------------------------------------------------------
# Planner
# ---------------------------------------------------------------------------

def test_plan_is_deterministic_and_consistent():
    rng = np.random.default_rng(0)
    wb = _codes(rng, 256, 64)
    p1 = plan_repair(wb, SPEC, FAULTY)
    p2 = plan_repair(wb, SPEC, FAULTY)
    np.testing.assert_array_equal(np.asarray(p1.victim), np.asarray(p2.victim))
    np.testing.assert_array_equal(np.asarray(p1.out_gather), np.asarray(p2.out_gather))
    np.testing.assert_array_equal(np.asarray(p1.g_spare), np.asarray(p2.g_spare))

    victim = np.asarray(p1.victim)
    gather = np.asarray(p1.out_gather)
    K, N = wb.shape
    B = spare_budget(N, SPEC, FAULTY)
    S, R = SPEC.n_slices, -(-K // SPEC.rows)
    # per-physical-crossbar resolution: one victim/gather table per
    # (bit-slice, row group) array
    assert victim.shape == (S, R, B) and gather.shape == (S, R, N)
    for s in range(S):
        for r in range(R):
            v_u, g_u = victim[s, r], gather[s, r]
            # every redirected output points at a spare unit holding
            # exactly that column's targets for this array
            for j in range(N):
                if g_u[j] >= N:
                    assert v_u[g_u[j] - N] == j
            # ... and no orphaned spares: used victim slots are exactly the
            # redirected columns, each repaired once per array
            used = v_u[v_u >= 0]
            assert len(used) == len(set(used.tolist()))
            assert set(used.tolist()) == {int(j) for j in range(N) if g_u[j] >= N}
            # spares are group-local: a spare only serves columns of its
            # own 128-column crossbar group
            for b in range(B):
                if v_u[b] >= 0:
                    assert v_u[b] // SPEC.cols == b // FAULTY.spare_cols
    # repair never increases planner-model salience, and strictly helps here
    before = np.asarray(p1.salience_before)
    after = np.asarray(p1.salience_after)
    assert (after <= before + 1e-6).all()
    assert after.sum() < before.sum()

    rep = repair_report(p1)
    assert rep.budget == S * R * B
    assert rep.n_repaired == int((victim >= 0).sum())
    repaired = {int(j) for j in range(N) if (gather[:, :, j] >= N).any()}
    assert set(rep.repaired_cols) == repaired
    assert 0.0 < rep.recovered_frac <= 1.0


def test_no_repair_without_budget_or_faults():
    assert plan_repair(jnp.zeros((8, 4), jnp.int32), SPEC, DeviceConfig()) is None
    assert not wants_repair(DeviceConfig(p_stuck_on=0.01))  # no budget
    assert not wants_repair(DeviceConfig(spare_cols=8))  # no faults
    assert wants_repair(DeviceConfig(p_stuck_on=0.01, spare_cols=8))


def test_spare_budget_scales_with_column_groups():
    cfg = DeviceConfig(p_stuck_on=0.01, spare_cols=8)
    assert spare_budget(64, SPEC, cfg) == 8  # one column group
    assert spare_budget(SPEC.cols + 1, SPEC, cfg) == 16  # two groups


# ---------------------------------------------------------------------------
# Column separability: pre-gathered layout == physical layout + out gather
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_repaired_layout_equals_physical_gather_noisy_kernel():
    rng = np.random.default_rng(1)
    wb = _codes(rng, 256, 48)
    x = jnp.asarray(rng.integers(0, 1 << 16, size=(4, 256)))
    plan = plan_repair(wb, SPEC, FAULTY)
    g_primary = effective_cell_codes(wb, SPEC, FAULTY, repair=False)
    g_repaired = apply_repair(g_primary, plan)
    # the physical chip: primary columns ++ spare block per array, each
    # (slice, row group) crossbar muxing its own columns through its own
    # routing table *before* the digital shift-and-add / row-group merge.
    # Reconstruct that layout independently and pin apply_repair to it.
    g_phys = np.concatenate(
        [np.asarray(g_primary), np.asarray(plan.g_spare)], axis=2
    )
    gather = np.asarray(plan.out_gather)  # (S, R, N)
    S, K, N = np.asarray(g_primary).shape
    expected = np.empty((S, K, N), g_phys.dtype)
    for s in range(S):
        for r in range(gather.shape[1]):
            r0 = r * plan.rows
            r1 = min(r0 + plan.rows, K)
            expected[s, r0:r1, :] = g_phys[s, r0:r1, :][:, gather[s, r]]
    np.testing.assert_array_equal(np.asarray(g_repaired), expected)
    # analog column separability per array: the unit's bitline partial sums
    # commute with its column mux (gather before or after the MAC is
    # identical), so pre-gathering at programming time loses nothing
    for s in (0, S - 1):
        for r in range(gather.shape[1]):
            r0, r1 = r * plan.rows, min((r + 1) * plan.rows, K)
            xs = np.asarray(x)[:, r0:r1].astype(np.float64)
            partial_phys = xs @ g_phys[s, r0:r1, :].astype(np.float64)
            partial_pre = xs @ np.asarray(g_repaired)[s, r0:r1, :].astype(np.float64)
            np.testing.assert_array_equal(
                partial_phys[:, gather[s, r]], partial_pre
            )
    # and the kernel agrees with the functional oracle on the repaired chip
    y_pre = ops.noisy_vmm_op(x, g_repaired, SPEC, interpret=True)
    y_ref = cb.noisy_crossbar_vmm(x, g_repaired, SPEC)
    np.testing.assert_array_equal(np.asarray(y_pre), np.asarray(y_ref))


@pytest.mark.parametrize("fast", [False, True], ids=["paper", "fast"])
def test_column_separability_ideal_kernels(fast):
    """The ideal kernels are column-separable too: gathering weight columns
    commutes with the VMM — the property that lets repaired layouts be baked
    at programming time for every kernel path."""
    rng = np.random.default_rng(2)
    w = jnp.asarray(rng.integers(-(1 << 15), 1 << 15, size=(200, 24)))
    x = jnp.asarray(rng.integers(0, 1 << 16, size=(3, 200)))
    gather = jnp.asarray(rng.permutation(24).astype(np.int32))
    y_full = ops.crossbar_vmm_op(x, w, SPEC, fast=fast, interpret=True)
    y_gathered = ops.crossbar_vmm_op(x, w[:, gather], SPEC, fast=fast, interpret=True)
    np.testing.assert_array_equal(np.asarray(y_full[:, gather]), np.asarray(y_gathered))


# ---------------------------------------------------------------------------
# Programmed pipeline integration
# ---------------------------------------------------------------------------

def test_programmed_repair_bit_identical_to_per_call():
    rng = np.random.default_rng(3)
    x = jnp.asarray(np.abs(rng.normal(size=(4, 256))).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(256, 32)).astype(np.float32))
    dev = FAULTY.replace(sigma=0.05, write_verify_iters=2)
    y_percall = ops.crossbar_matmul(x, w, device=dev, interpret=True)
    art = program_layer(w, device=dev)
    y_prog = programmed_matmul(x, art, interpret=True)
    np.testing.assert_array_equal(np.asarray(y_percall), np.asarray(y_prog))
    # artifact records the hardware view: spare block + routing table
    assert art.g_spare is not None and art.out_gather is not None
    assert art.repair is not None and art.repair.n_repaired > 0
    B = spare_budget(32, art.spec, dev)
    assert art.g_spare.shape == (art.spec.n_slices, 256, B)


def test_zero_fault_budget_is_bit_exact_no_op():
    """Provisioned spares with faults disabled change nothing: the repaired
    programmed path stays bit-identical to the per-call noisy path."""
    rng = np.random.default_rng(4)
    x = jnp.asarray(np.abs(rng.normal(size=(4, 128))).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(128, 16)).astype(np.float32))
    dev = DeviceConfig(sigma=0.1, spare_cols=16, seed=5)
    assert not wants_repair(dev)
    wb = jnp.asarray(
        np.asarray(cb.quantize_weight(w, SPEC, jnp.max(jnp.abs(w)) / ((1 << 15) - 1)))
    ) + SPEC.weight_bias
    np.testing.assert_array_equal(
        np.asarray(effective_cell_codes(wb, SPEC, dev)),
        np.asarray(effective_cell_codes(wb, SPEC, dev.replace(spare_cols=0))),
    )
    art = program_layer(w, device=dev)
    assert art.g_spare is None and art.out_gather is None and art.repair is None
    y_prog = programmed_matmul(x, art, interpret=True)
    y_percall = ops.crossbar_matmul(x, w, device=dev.replace(spare_cols=0), interpret=True)
    np.testing.assert_array_equal(np.asarray(y_prog), np.asarray(y_percall))


def test_repair_reduces_vmm_error():
    rng = np.random.default_rng(5)
    wb = _codes(rng, 256, 64)
    x = jnp.asarray(rng.integers(0, 1 << 16, size=(8, 256)))
    y_ideal = np.asarray(
        cb.noisy_crossbar_vmm(x, effective_cell_codes(wb, SPEC, DeviceConfig()), SPEC),
        np.int64,
    )
    cfg = DeviceConfig(p_stuck_on=5e-3, p_stuck_off=5e-3, seed=0)
    errs = {}
    for spares in (0, 64):
        g = effective_cell_codes(wb, SPEC, cfg.replace(spare_cols=spares))
        y = np.asarray(cb.noisy_crossbar_vmm(x, g, SPEC), np.int64)
        errs[spares] = float(((y - y_ideal) ** 2).mean())
    # a budget of one spare per column recovers the large majority of MSE
    assert errs[64] < 0.3 * errs[0]


def test_program_model_records_repairs():
    rng = np.random.default_rng(6)
    params = {
        "stage0": {
            "b0": {"wq": jnp.asarray(rng.normal(size=(2, 64, 16)).astype(np.float32))}
        },
        "head": jnp.asarray(rng.normal(size=(64, 32)).astype(np.float32)),
    }
    prog = program_model(params, device=FAULTY)
    assert prog.n_compiled == 2
    reps = prog.repair_reports()
    assert len(reps) == 2
    stacked = [r for k, r in reps.items() if "wq" in k][0]
    assert isinstance(stacked, tuple) and len(stacked) == 2  # per-layer reports
    spec = prog.artifacts["stage0"]["b0"]["wq"].spec
    units = spec.n_slices * -(-64 // spec.rows)  # budget counts unit slots
    assert all(r.budget == spare_budget(16, spec, FAULTY) * units for r in stacked)


@pytest.mark.slow
def test_serving_engine_exposes_repair_budget():
    """The engine constructor's ``spare_cols`` knob overrides the device
    budget at deploy time, and ``repair_reports()`` surfaces the planner's
    work for every compiled projection."""
    from benchmarks.noise_sweep import tiny_lm_config
    from repro.models import model as M
    from repro.models.layers import CrossbarMode
    from repro.serving.engine import ServingEngine

    cfg = tiny_lm_config()
    params, _ = M.init_model(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    dev = DeviceConfig(p_stuck_on=5e-3, p_stuck_off=5e-3, seed=1)
    eng = ServingEngine(
        cfg, params, max_batch=1, max_seq=32,
        crossbar=CrossbarMode(enabled=True, device=dev), spare_cols=16,
    )
    assert eng.crossbar.device.spare_cols == 16
    assert eng.crossbar.programmed is not None
    # a budget that cannot repair anything is a misconfiguration, not a no-op
    with pytest.raises(ValueError):
        ServingEngine(
            cfg, params, max_batch=1, max_seq=32,
            crossbar=CrossbarMode(enabled=True, device=DeviceConfig(sigma=0.1)),
            spare_cols=16,
        )
    with pytest.raises(ValueError):
        ServingEngine(
            cfg, params, max_batch=1, max_seq=32,
            crossbar=CrossbarMode(enabled=True), spare_cols=16,
        )
    # ... but spare_cols=0 explicitly disables a budget baked into the device
    eng_off = ServingEngine(
        cfg, params, max_batch=1, max_seq=32,
        crossbar=CrossbarMode(enabled=True, device=dev.replace(spare_cols=16)),
        spare_cols=0,
    )
    assert eng_off.crossbar.device.spare_cols == 0
    assert eng_off.repair_reports() == {}
    # ... and 0 stays a no-op wherever repair could not happen anyway
    assert ServingEngine(cfg, params, max_batch=1, spare_cols=0).crossbar is None
    reps = eng.repair_reports()
    # every compiled projection (attention q/k/v/o, mlp wi/wo, head) repaired
    assert len(reps) == 7
    flat = [r for v in reps.values() for r in (v if isinstance(v, tuple) else (v,))]
    assert all(rep.n_repaired > 0 for rep in flat)


# ---------------------------------------------------------------------------
# Mapper provisioning
# ---------------------------------------------------------------------------

def test_mapper_fault_provisioning_inflates_allocation():
    from repro.core import arch, mapper
    from repro.core import workloads as wl

    net = wl.benchmark_suite()[0]
    for policy in ("newton", "isaac"):
        base = mapper.map_network(net, arch.NEWTON_CHIP, policy=policy)
        prov = mapper.map_network(net, arch.NEWTON_CHIP, policy=policy, fault_rate=1e-2)
        assert prov.spare_cols == mapper.provision_spare_cols(
            1e-2, arch.NEWTON_CHIP.conv_tile.ima.xbar_spec
        ) > 0
        # unified layout (device.repair model): spares append past each
        # group's data columns, so the group fan-out — hence the crossbar
        # count — is spare-independent, but every allocated crossbar grows
        # by rows x spare_cols physical cells
        assert prov.spare_cells_frac == pytest.approx(
            prov.spare_cols / (128 + prov.spare_cols)
        )
        assert sum(m.crossbars for m in prov.layers) == sum(
            m.crossbars for m in base.layers
        )
        assert prov.crossbar_underutilization > base.crossbar_underutilization
        # throughput provisioning is not affected by column sparing
        assert prov.throughput_samples_s == base.throughput_samples_s


def test_spare_placement_models_agree():
    """Cross-module pin of the unified spare-placement layout: the mapper
    and ``device.repair`` provision the same groups — ``ceil(N /
    spec.cols)`` column groups, each with its full ``spec.cols`` data
    columns plus ``spare_cols`` appended spares — so the mapper's
    allocated spare cells for a slab equal the cells the repair planner
    programs into its spare block (per bit-slice)."""
    from repro.core import arch, mapper
    from repro.core.workloads import Layer, Network

    spec = arch.NEWTON_CHIP.conv_tile.ima.xbar_spec
    s = 8
    dev = DeviceConfig(p_stuck_on=5e-3, p_stuck_off=5e-3, spare_cols=s, seed=0)
    N = 2 * spec.cols + 40  # 3 column groups, last partial
    groups = -(-N // spec.cols)
    assert spare_budget(N, spec, dev) == s * groups

    net = Network(
        "one-fc", [Layer(name="fc", kind="fc", rows=spec.rows, cols=N, pixels=1)]
    )
    rep = mapper.map_network(net, arch.NEWTON_CHIP, spare_cols=s)
    m = rep.layers[0]
    # same group fan-out: the mapper allocates exactly `groups` column
    # groups per replica (full `spec.cols` data width each, no carving)
    assert m.crossbars == groups * spec.n_slices * m.replication
    assert rep.spare_cols == s
    assert rep.spare_cells_frac == pytest.approx(s / (spec.cols + s))

    # the repair planner's programmed spare block covers exactly the cells
    # the mapper provisioned: rows x (s per group) x groups, per slice
    rng = np.random.default_rng(0)
    wb = jnp.asarray(rng.integers(0, 1 << spec.weight_bits, size=(spec.rows, N)))
    plan = plan_repair(wb, spec, dev)
    assert plan.g_spare.shape == (spec.n_slices, spec.rows, s * groups)
    mapper_spare_cells = groups * spec.rows * s
    assert plan.g_spare.shape[1] * plan.g_spare.shape[2] == mapper_spare_cells


def test_provision_spare_cols_monotone_and_capped():
    from repro.core.mapper import provision_spare_cols

    spec = cb.DEFAULT_SPEC
    rates = [0.0, 1e-4, 1e-3, 1e-2, 1e-1]
    vals = [provision_spare_cols(p, spec) for p in rates]
    assert vals[0] == 0
    assert all(b >= a for a, b in zip(vals, vals[1:]))
    assert vals[-1] <= 2 * spec.cols  # self-fault discount caps at a 2x pool
    # coverage scales the budget
    assert provision_spare_cols(1e-3, spec, coverage=0.5) <= provision_spare_cols(1e-3, spec)


# ---------------------------------------------------------------------------
# Acceptance: model-level recovery (ISSUE 3 criterion)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_model_logit_mse_recovery_at_1pct_faults():
    """At p_stuck_on + p_stuck_off = 0.01, spare-column repair recovers
    >= 70% of the stuck-at logit-MSE degradation on the tiny LM (every
    projection — attention, MLP, LM head — on the crossbar path)."""
    from benchmarks.noise_sweep import model_fault_recovery

    out = model_fault_recovery(fault_rate=1e-2, spare_cols=64, seed=0)
    assert out["logit_mse_norepair"] > 0.0
    assert out["recovered_frac"] >= 0.70, out
