"""Per-architecture smoke tests (deliverable f): every assigned architecture
instantiates a reduced config of the same family and runs forward, one train
step, prefill and decode on CPU — asserting shapes, finiteness, and
decode/teacher-forcing consistency."""
import dataclasses as dc

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import reduced
from repro.models import model as M
from repro.optim import constant, make_optimizer
from repro.train import make_train_step

ARCHS = configs.ALL_ARCHS


def _marked(archs, slow_set):
    """Tag the heaviest reduced configs slow so tier-1 stays fast."""
    return [
        pytest.param(a, marks=pytest.mark.slow) if a in slow_set else a for a in archs
    ]


_SLOW_FORWARD = {"jamba-v0.1-52b"}
_SLOW_TRAIN = {"xlstm-350m", "deepseek-v2-236b", "musicgen-large",
               "jamba-v0.1-52b", "gemma2-9b"}


def _inputs(cfg, key, B, S):
    if cfg.frontend == "embed":
        return jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
    return jax.random.randint(key, (B, S), 0, cfg.vocab_size)


@pytest.fixture(scope="module")
def smoke(request):
    return {}


@pytest.mark.parametrize("arch", _marked(ARCHS, _SLOW_FORWARD))
def test_forward_shapes_and_finite(arch):
    cfg = reduced(configs.get_config(arch))
    key = jax.random.PRNGKey(0)
    params, axes = M.init_model(key, cfg)
    B, S = 2, 32
    inp = _inputs(cfg, key, B, S)
    logits = M.forward(params, cfg, inp)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", _marked(ARCHS, _SLOW_TRAIN))
def test_one_train_step(arch):
    cfg = reduced(configs.get_config(arch))
    key = jax.random.PRNGKey(1)
    params, _ = M.init_model(key, cfg)
    opt = make_optimizer("adamw", constant(1e-3))
    step = make_train_step(cfg, opt)
    B, S = 2, 16
    batch = {
        "inputs": _inputs(cfg, key, B, S),
        "targets": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
    }
    p2, o2, s2, metrics = step(params, opt.init(params), jnp.int32(0), batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(metrics["skipped"]) == 0
    # params actually changed
    delta = jax.tree.leaves(jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), params, p2))
    assert max(delta) > 0


_SLOW_DECODE = {"kimi-k2-1t-a32b", "deepseek-v2-236b", "jamba-v0.1-52b"}


@pytest.mark.parametrize("arch", _marked(ARCHS, _SLOW_DECODE))
def test_decode_matches_teacher_forcing(arch):
    cfg = reduced(configs.get_config(arch))
    key = jax.random.PRNGKey(2)
    if cfg.moe_experts:
        # Routing is discrete: ulp-level float reorder between the two
        # compiled graphs can flip a near-tied top-k choice and amplify;
        # and capacity drops depend on the batch's token census, which
        # differs between the S and S+1 runs.  Zero routers (exact ties =>
        # deterministic index-order selection) + uncapped capacity compare
        # the cache paths faithfully.
        cfg = dc.replace(cfg, moe_capacity_factor=1000.0)
    params, _ = M.init_model(key, cfg)
    if cfg.moe_experts:
        params = jax.tree_util.tree_map_with_path(
            lambda p, a: jnp.zeros_like(a) if any(
                getattr(q, "key", None) == "router" for q in p) else a,
            params,
        )
    B, S = 2, 16
    full = _inputs(cfg, key, B, S + 1)
    full_logits = M.forward(params, cfg, full)
    cache = M.init_cache(cfg, B, S + 8, dtype=jnp.float32)
    last, cache = M.prefill(params, cfg, full[:, :S], cache)
    np.testing.assert_allclose(
        np.asarray(last), np.asarray(full_logits[:, S - 1]), rtol=2e-3, atol=2e-3
    )
    lg, _ = M.decode_step(params, cfg, full[:, S : S + 1], jnp.int32(S), cache)
    np.testing.assert_allclose(
        np.asarray(lg), np.asarray(full_logits[:, S]), rtol=2e-3, atol=2e-3
    )


def test_chunked_attention_equals_dense():
    from repro.models.attention import gqa_attention
    from repro.kernels.ref import chunked_attention_ref

    key = jax.random.PRNGKey(3)
    B, S, H, KV, dh = 2, 64, 4, 2, 16
    q = jax.random.normal(key, (B, S, H, dh))
    k = jax.random.normal(jax.random.PRNGKey(4), (B, S, KV, dh))
    v = jax.random.normal(jax.random.PRNGKey(5), (B, S, KV, dh))
    out = gqa_attention(q, k, v, scale=dh**-0.5, chunk=16)
    ref = chunked_attention_ref(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)
    ).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_sliding_window_masks_history():
    """A local-attention layer must ignore tokens beyond its window."""
    from repro.models.attention import gqa_attention

    key = jax.random.PRNGKey(6)
    B, S, H, dh, W = 1, 32, 2, 8, 8
    q = jax.random.normal(key, (B, S, H, dh))
    k = jax.random.normal(jax.random.PRNGKey(7), (B, S, H, dh))
    v = jax.random.normal(jax.random.PRNGKey(8), (B, S, H, dh))
    out = gqa_attention(q, k, v, scale=dh**-0.5, window=W)
    # perturb a key/value far outside the window of the last query
    k2 = k.at[:, 0].set(k[:, 0] + 100.0)
    v2 = v.at[:, 0].set(v[:, 0] - 50.0)
    out2 = gqa_attention(q, k2, v2, scale=dh**-0.5, window=W)
    np.testing.assert_allclose(
        np.asarray(out[:, -1]), np.asarray(out2[:, -1]), atol=1e-5
    )


def test_param_counts_match_published():
    expected = {
        "xlstm-350m": (0.30, 0.45),
        "smollm-360m": (0.3, 0.42),
        "gemma2-9b": (8.5, 10.0),
        "minitron-4b": (3.8, 4.6),
        "starcoder2-3b": (2.7, 3.3),
        "deepseek-v2-236b": (220, 250),
        "kimi-k2-1t-a32b": (950, 1100),
        "pixtral-12b": (10.5, 12.8),
        "jamba-v0.1-52b": (48, 56),
    }
    for name, (lo, hi) in expected.items():
        c = configs.get_config(name)
        b = c.param_count() / 1e9
        assert lo < b < hi, (name, b)
    ds = configs.get_config("deepseek-v2-236b")
    assert ds.active_param_count() / 1e9 < 30  # ~21B active


@pytest.mark.slow
def test_moe_capacity_drops_are_bounded():
    """With capacity factor 1.25 and balanced-ish routing, outputs stay
    close to the infinite-capacity reference."""
    import repro.models.moe as Mo
    from repro.models.layers import Init

    cfg = reduced(configs.get_config("deepseek-v2-236b"))
    ini = Init(key=jax.random.PRNGKey(0))
    Mo.init_moe(ini, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 32, cfg.d_model)) * 0.3
    y = Mo.moe_ffn(ini.params, x, cfg)
    y_inf = Mo.moe_ffn(ini.params, x, dc.replace(cfg, moe_capacity_factor=1000.0))
    denom = float(jnp.linalg.norm(y_inf)) + 1e-9
    assert float(jnp.linalg.norm(y - y_inf)) / denom < 0.35


def count_dots(closed) -> int:
    """Plain-XLA dot_generals in a traced computation, Pallas calls excluded
    (they ARE the crossbar datapath)."""

    def walk(jaxpr) -> int:
        n = 0
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "pallas_call":
                continue  # the crossbar datapath itself
            if eqn.primitive.name == "dot_general":
                n += 1
            for v in eqn.params.values():
                for sub in (v if isinstance(v, (list, tuple)) else (v,)):
                    inner = getattr(sub, "jaxpr", None)
                    if hasattr(inner, "eqns"):
                        n += walk(inner)
                    elif hasattr(sub, "eqns"):
                        n += walk(sub)
        return n

    return walk(closed.jaxpr)


def test_no_plain_xla_matmuls_on_crossbar_path(monkeypatch):
    """Under an enabled CrossbarMode every weight-bearing matmul — attention
    q/k/v/o, MLP wi/wo, and the LM head — routes through crossbar_linear
    into a Pallas kernel; the only dot_generals left in the traced forward
    are the activation-activation attention products (QK^T, probs @ V),
    which hold no weights and cannot live on a crossbar."""
    from benchmarks.noise_sweep import tiny_lm_config
    from repro.models import attention as A
    from repro.models import layers as L

    cfg = tiny_lm_config()
    params, _ = M.init_model(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    tokens = jnp.zeros((1, 4), jnp.int32)

    consumed = []
    real = L.crossbar_linear

    def spy(x, w, name=None, **kw):
        consumed.append(tuple(int(d) for d in w.shape))
        return real(x, w, name=name, **kw)

    monkeypatch.setattr(L, "crossbar_linear", spy)
    monkeypatch.setattr(A, "crossbar_linear", spy)

    def trace(mode):
        consumed.clear()
        with L.crossbar_mode(mode):
            return jax.make_jaxpr(lambda p, t: M.forward(p, cfg, t))(params, tokens)

    off = count_dots(trace(L.CrossbarMode(enabled=False)))
    jaxpr_on = trace(L.CrossbarMode(enabled=True, fast=True))
    on = count_dots(jaxpr_on)
    n_routed = len(consumed)
    # every projection class is served: 4 attention + 2 mlp + 1 head (the
    # layer scan traces each distinct block body once)
    assert n_routed == 7, consumed
    expected = {
        tuple(int(d) for d in a.shape[1:])
        for a in jax.tree_util.tree_leaves(params["stage0"])
        if a.ndim == 3
    } | {tuple(int(d) for d in params["head"].shape)}
    assert set(consumed) == expected
    # ... and each routed site removed exactly one plain-XLA dot_general;
    # what remains is the weightless attention pair
    assert on == off - n_routed == 2, (on, off)


def test_no_plain_xla_matmuls_on_moe_crossbar_path():
    """The MoE + tied-head coverage criterion (ISSUE 4): on a small MoE
    config with crossbar mode enabled, the only dot_generals left in the
    traced forward are the two weightless attention products — the router,
    the per-expert wi/wg/wo bank, and the *tied* LM head all route through
    crossbar_linear (the expert bank via the per-expert scan, the tied head
    via the transpose that name-keyed binding can serve)."""
    from benchmarks.noise_sweep import tiny_moe_lm_config
    from repro.models import layers as L

    cfg = tiny_moe_lm_config()
    params, _ = M.init_model(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    tokens = jnp.zeros((1, 4), jnp.int32)

    def trace(mode):
        with L.crossbar_mode(mode):
            return jax.make_jaxpr(lambda p, t: M.forward(p, cfg, t))(params, tokens)

    off = count_dots(trace(L.CrossbarMode(enabled=False)))
    on = count_dots(trace(L.CrossbarMode(enabled=True, fast=True)))
    # digital reference: 4 attention projections + router + 3 expert einsums
    # (wi/wg/wo) + tied head + the 2 weightless attention products = 11;
    # enabled, only the weightless attention pair remains
    assert off == 11, off
    assert on == 2, on


# Every architecture family whose projections live on crossbars: dense
# (tied and untied heads, local/global attention, softcaps) and MoE (GLU
# expert banks, shared experts, MLA attention).  ssm/xlstm/hybrid mixers
# hold recurrence parameters no crossbar call site serves, so full-model
# coverage is not defined for them.
_COVERAGE_ARCHS = [
    "smollm-360m",        # dense, tied head
    "starcoder2-3b",      # dense, tied head, GQA
    "minitron-4b",        # dense, untied head
    "gemma2-9b",          # dense, tied head, softcaps, local/global attn
    "deepseek-v2-236b",   # MoE + shared experts + MLA
    "kimi-k2-1t-a32b",    # MoE + shared experts + MLA, 1T-scale pattern
]


@pytest.mark.parametrize("arch", _COVERAGE_ARCHS)
def test_programmed_coverage_sweep_zero_misses(arch):
    """ISSUE 5 satellite: every dense/MoE/tied-head architecture family
    pins full crossbar coverage, not just the two hand-picked tiny configs.
    A fully programmed reduced config traces a forward under strict mode
    (any artifact miss raises at trace time) and must consume exactly the
    emitted artifact name set (``verify_consumed`` — the drift direction
    the miss counter cannot see)."""
    import repro.device.programmed as prog
    from repro.device import program_model
    from repro.models import layers as L

    cfg = reduced(configs.get_config(arch))
    params, _ = M.init_model(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    pm = program_model(
        params, tie_lm_head=(cfg.tie_embeddings and cfg.frontend == "token")
    )
    L.reset_crossbar_misses()
    prog.reset_consumed_artifact_names()
    tokens = jnp.zeros((1, 4), jnp.int32)
    with L.crossbar_mode(
        L.CrossbarMode(enabled=True, fast=True, programmed=pm, strict=True)
    ):
        # tracing suffices: misses and consumption are recorded at trace
        # time, so the sweep stays cheap enough for the fast tier
        jax.make_jaxpr(lambda p, t: M.forward(p, cfg, t))(params, tokens)
    assert L.crossbar_misses() == ()
    pm.verify_consumed()
    L.reset_crossbar_misses()
    prog.reset_consumed_artifact_names()


@pytest.mark.slow
def test_programmed_moe_forward_zero_misses_and_strict():
    """A fully programmed MoE model (tie_lm_head=True) serves every
    projection from an artifact: zero crossbar misses over a traced forward
    (strict mode would raise on the first one), and the programmed forward
    matches the per-call path to float-fusion tolerance."""
    from benchmarks.noise_sweep import tiny_moe_lm_config
    from repro.device import program_model
    from repro.models import layers as L

    cfg = tiny_moe_lm_config()
    params, _ = M.init_model(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    tokens = jnp.asarray(
        np.random.default_rng(0).integers(0, cfg.vocab_size, size=(1, 4))
    )
    with L.crossbar_mode(L.CrossbarMode(enabled=True, fast=True)):
        y_percall = M.forward(params, cfg, tokens)

    prog = program_model(params, tie_lm_head=True)
    # coverage: attention q/k/v/o + router + expert wi/wg/wo + tied head
    assert prog.n_compiled == 9, sorted(prog.by_name)
    assert "embed/tokens" in prog.by_name
    assert prog.by_name["stage0/b0/ffn/wi"].w_codes.ndim == 4  # (L, E, K, N)
    L.reset_crossbar_misses()
    with L.crossbar_mode(
        L.CrossbarMode(enabled=True, fast=True, programmed=prog, strict=True)
    ):
        y_prog = M.forward(params, cfg, tokens)
    assert L.crossbar_misses() == ()
    np.testing.assert_allclose(
        np.asarray(y_prog), np.asarray(y_percall), rtol=1e-4, atol=1e-4
    )
    # ... and without tie_lm_head the tied head IS a miss, loudly
    prog_no_tie = program_model(params, tie_lm_head=False)
    L.reset_crossbar_misses()
    with L.crossbar_mode(
        L.CrossbarMode(enabled=True, fast=True, programmed=prog_no_tie)
    ):
        M.forward(params, cfg, tokens)
    assert "embed/tokens" in L.crossbar_misses()
    with pytest.raises(LookupError):
        with L.crossbar_mode(
            L.CrossbarMode(enabled=True, fast=True, programmed=prog_no_tie, strict=True)
        ):
            M.forward(params, cfg, tokens)
    L.reset_crossbar_misses()


@pytest.mark.slow
def test_moe_engine_save_restore_serve_round_trip(tmp_path):
    """ISSUE 4 acceptance: save -> restore -> serve is bit-identical to the
    original programmed MoE engine with zero reprogramming calls — the
    restored chip carries the same effective cells, fault realizations,
    write-verify reports and repair tables."""
    from benchmarks.noise_sweep import tiny_moe_lm_config
    from repro.device import DeviceConfig
    from repro.models.layers import CrossbarMode
    from repro.serving.engine import ServingEngine
    import repro.device.programmed as P

    cfg = tiny_moe_lm_config()
    params, _ = M.init_model(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    dev = DeviceConfig(
        sigma=0.05, p_stuck_on=2e-3, p_stuck_off=2e-3, write_verify_iters=2,
        spare_cols=2, seed=3,
    )
    eng = ServingEngine(
        cfg, params, max_batch=1, max_seq=16,
        crossbar=CrossbarMode(enabled=True, device=dev),
    )
    eng.submit(np.array([1, 2, 3], np.int32), max_new_tokens=2)
    out1 = eng.run_until_done()[0].generated

    eng.save_artifacts(str(tmp_path))
    real_program_layer = P.program_layer
    calls = []

    def counting(*a, **k):
        calls.append(a)
        return real_program_layer(*a, **k)

    P.program_layer = counting
    try:
        eng2 = ServingEngine(
            cfg, params, max_batch=1, max_seq=16,
            crossbar=CrossbarMode(enabled=True, device=dev),
            restore_artifacts=str(tmp_path),
        )
    finally:
        P.program_layer = real_program_layer
    assert calls == []  # zero reprogramming on restore

    from repro.device.programmed import artifacts_equal

    a1, a2 = eng.crossbar.programmed.by_name, eng2.crossbar.programmed.by_name
    assert set(a1) == set(a2)
    for n in a1:
        assert artifacts_equal(a1[n], a2[n]), n
        assert a1[n].repair == a2[n].repair, n

    eng2.submit(np.array([1, 2, 3], np.int32), max_new_tokens=2)
    out2 = eng2.run_until_done()[0].generated
    assert out1 == out2 and len(out1) == 2
