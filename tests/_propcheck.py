"""Deterministic property-sweep helper — offline stand-in for `hypothesis`.

The container has no network, so `hypothesis` cannot be installed; the three
property suites instead use this tiny shim.  ``sweep(*strategies,
examples=N)`` decorates a test so it runs N deterministic cases: the first
two cases are the all-low / all-high strategy endpoints (edge coverage
hypothesis found by shrinking), the rest are drawn from a numpy Generator
seeded by the test name, so every run and every machine sees the same cases.
On failure the offending case is printed so it can be replayed by hand.
"""
from __future__ import annotations

import functools
import zlib
from typing import Any, Callable, Sequence, Tuple

import numpy as np


class Strategy:
    """A value source: deterministic endpoints plus seeded random draws."""

    def __init__(self, draw: Callable[[np.random.Generator], Any], lo: Any, hi: Any):
        self._draw = draw
        self.lo = lo
        self.hi = hi

    def draw(self, rng: np.random.Generator) -> Any:
        return self._draw(rng)


def integers(lo: int, hi: int) -> Strategy:
    """Inclusive integer range (same convention as hypothesis.st.integers)."""
    return Strategy(lambda rng: int(rng.integers(lo, hi + 1)), lo, hi)


def sampled_from(seq: Sequence[Any]) -> Strategy:
    items = list(seq)
    return Strategy(lambda rng: items[int(rng.integers(len(items)))], items[0], items[-1])


def sweep(*strategies: Strategy, examples: int = 20, seed: int = 0) -> Callable:
    """Run the test once per case: 2 endpoint cases + seeded random fills."""

    def deco(fn: Callable) -> Callable:
        @functools.wraps(fn)
        def wrapper():
            rng = np.random.default_rng(zlib.crc32(fn.__name__.encode()) ^ seed)
            cases: list[Tuple[Any, ...]] = []
            if examples >= 1:
                cases.append(tuple(s.lo for s in strategies))
            if examples >= 2:
                cases.append(tuple(s.hi for s in strategies))
            while len(cases) < examples:
                cases.append(tuple(s.draw(rng) for s in strategies))
            for case in cases:
                try:
                    fn(*case)
                except Exception:
                    print(f"propcheck failing case: {fn.__name__}{case!r}")
                    raise

        # pytest resolves fixtures through __wrapped__; without this it would
        # mistake the swept parameters for fixture requests.
        del wrapper.__wrapped__
        return wrapper

    return deco
