"""Launch-layer unit tests: HLO collective parser, depth-variant
extrapolation math, input specs, sharding resolution, mesh construction."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _propcheck import integers, sampled_from, sweep

from repro import configs
from repro.configs.base import SHAPES, reduced
from repro.launch.dryrun import _shape_bytes, collective_bytes, input_specs, with_stage_repeats
from repro.launch.roofline import model_flops_per_device
from repro.models.layers import dividing_entry, use_mesh, layout_overrides


def test_shape_bytes_parser():
    assert _shape_bytes("f32[4,8]") == 128
    assert _shape_bytes("bf16[2,3,4]") == 48
    assert _shape_bytes("(f32[2], u8[16])") == 24
    assert _shape_bytes("pred[]") == 1  # scalar predicate: one byte
    assert _shape_bytes("token[]") == 0  # unknown dtype ignored


def test_collective_parser_counts_and_skips_done():
    hlo = """
      %ar = f32[16,1024]{1,0} all-reduce(%x), replica_groups={}
      %ag-start = bf16[8,256]{1,0} all-gather-start(%y)
      %ag-done = bf16[8,256]{1,0} all-gather-done(%ag-start)
      %ata = (f32[4,4]{1,0}, f32[4,4]{1,0}) all-to-all(%a, %b)
      %cp = u8[128]{0} collective-permute(%z)
      %dot = f32[999,999]{1,0} dot(%p, %q)
    """
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 16 * 1024 * 4
    assert out["all-gather"] == 8 * 256 * 2  # -start counted once, -done skipped
    assert out["all-to-all"] == 2 * 16 * 4
    assert out["collective-permute"] == 128
    assert out["total"] == sum(
        out[k] for k in ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")
    )


def test_with_stage_repeats_unrolls():
    cfg = configs.get_config("deepseek-v2-236b")
    v = with_stage_repeats(cfg, [1, 2])
    assert v.n_layers == 3
    assert v.scan_layers is False
    assert [s.repeats for s in v.stages] == [1, 2]


@pytest.mark.parametrize("arch", ["smollm-360m", "musicgen-large"])
@pytest.mark.parametrize("shape", list(SHAPES))
def test_input_specs_shapes(arch, shape):
    cfg = configs.get_config(arch)
    sp = SHAPES[shape]
    specs = input_specs(cfg, sp)
    if sp.kind == "train":
        assert set(specs) == {"inputs", "targets"}
        assert specs["targets"].shape == (sp.global_batch, sp.seq_len)
    else:
        assert set(specs) == {"inputs"}
    lead = specs["inputs"].shape
    assert lead[0] == sp.global_batch
    if cfg.frontend == "embed":
        assert lead[-1] == cfg.d_model


def test_model_flops_convention():
    # train: 6*N*D; decode: 2*N_active*B
    f_train = model_flops_per_device("smollm-360m", "train_4k", 256)
    cfg = configs.get_config("smollm-360m")
    expect = 6 * cfg.param_count() * 4096 * 256 / 256
    assert f_train == pytest.approx(expect)
    f_dec = model_flops_per_device("deepseek-v2-236b", "decode_32k", 256)
    ds = configs.get_config("deepseek-v2-236b")
    assert f_dec == pytest.approx(2 * ds.active_param_count() * 128 / 256)


@sweep(integers(1, 4096), sampled_from([(2,), (2, 4), (2, 4, 8)]), examples=40)
def test_dividing_entry_prefix_property(dim, sizes):
    """dividing_entry returns the longest prefix whose product divides dim."""
    import os
    import jax

    class FakeMesh:
        def __init__(self, sizes):
            self.shape = {f"a{i}": s for i, s in enumerate(sizes)}
            self.axis_names = tuple(self.shape)

    mesh = FakeMesh(sizes)
    axes = tuple(mesh.axis_names)
    entry = dividing_entry(dim, axes, mesh)
    if entry is None:
        assert dim % sizes[0] != 0 or sizes[0] == 1
    else:
        prefix = entry if isinstance(entry, tuple) else (entry,)
        prod = int(np.prod([mesh.shape[a] for a in prefix]))
        assert dim % prod == 0 and prod > 1
        # maximality: the next-longer prefix must not divide
        if len(prefix) < len(axes):
            bigger = prod * mesh.shape[axes[len(prefix)]]
            assert dim % bigger != 0


def test_layout_overrides():
    xl = configs.get_config("xlstm-350m")
    ov = layout_overrides(xl)
    assert ov["batch"] == ("pod", "data", "model")
    ds = configs.get_config("deepseek-v2-236b")
    assert layout_overrides(ds) == {}  # train layout is plain TP
    import dataclasses as dc

    ds_dec = dc.replace(ds, layout="expert_tp")
    ov2 = layout_overrides(ds_dec)
    assert ov2["experts"] == "data" and ov2["moe_ff"] == "model"


def test_mapper_invariants_property():
    """Mapper invariants over the CNN suite: every layer's crossbars cover
    its weights; utilization in (0, 1]; conv replication >= 1."""
    from repro.core import arch as hw, mapper, workloads as wl

    for net in wl.benchmark_suite():
        m = mapper.map_network(net, hw.NEWTON_CHIP, policy="newton")
        for lm in m.layers:
            assert 0 < lm.used_cells_frac <= 1
            assert lm.replication >= 1
            # allocated crossbar capacity >= weights (slot model)
            cap = (lm.crossbars / hw.NEWTON_CHIP.conv_tile.ima.xbar_spec.n_slices) * 128 * 128
            assert cap * lm.replication >= lm.layer.weights or cap >= lm.layer.weights
        assert m.throughput_samples_s > 0
