"""Name-keyed artifact binding (ISSUE 4 tentpole).

The old id-keyed binding silently orphaned every artifact the moment the
params pytree was copied — ``jax.device_put``, buffer donation, an optimizer
step, a checkpoint restore — downgrading crossbar serving to plain XLA
matmul with no error.  These tests pin the fix: binding is by canonical
parameter *name*, so it survives pytree copies, fresh jit traces and
transposed views; misses are counted and (under strict mode) fatal; MoE
expert banks program as per-expert stacked artifacts bit-identical to
standalone programming; and the whole programmed chip round-trips through
the ``repro.checkpoint`` artifact store bit-for-bit.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import restore_programmed, save_programmed
from repro.device import (
    DeviceConfig,
    bind_artifacts,
    name_scope,
    program_layer,
    program_model,
    programmed_linear,
    scoped_name,
)
from repro.models.layers import (
    CrossbarMode,
    crossbar_linear,
    crossbar_misses,
    crossbar_mode,
    reset_crossbar_misses,
)

DEV = DeviceConfig(sigma=0.1, p_stuck_on=1e-3, p_stuck_off=1e-3, write_verify_iters=4)


@pytest.fixture(autouse=True)
def _clean_miss_counter():
    reset_crossbar_misses()
    yield
    reset_crossbar_misses()


def _params(seed=0, K=128, N=16):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(4, K)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(K, N)).astype(np.float32))
    return x, {"wq": w}


# ---------------------------------------------------------------------------
# Binding survives everything id-keying did not
# ---------------------------------------------------------------------------

def test_binding_survives_pytree_copies():
    """device_put and a tree_map copy produce fresh leaf objects; name-keyed
    lookup still serves the artifact, bit-identically — both broke the old
    id-keyed binding (silent digital fallback)."""
    x, params = _params()
    prog = program_model(params, device=DEV)
    mode = CrossbarMode(enabled=True, device=DEV, programmed=prog)
    with crossbar_mode(mode):
        y0 = crossbar_linear(x, params["wq"], name="wq")
    for copy in (jax.device_put(params), jax.tree.map(lambda a: a + 0, params)):
        with crossbar_mode(mode):
            y = crossbar_linear(x, copy["wq"], name="wq")
        np.testing.assert_array_equal(np.asarray(y0), np.asarray(y))
    assert crossbar_misses() == ()


def test_binding_survives_fresh_jit_trace():
    """Every retrace sees new tracers; the name key is trace-invariant, so
    both independently-jitted wrappers serve the programmed path with zero
    misses (misses are recorded at trace time)."""
    x, params = _params(1)
    prog = program_model(params, device=DEV)
    mode = CrossbarMode(enabled=True, device=DEV, programmed=prog, strict=True)

    @jax.jit
    def f1(p, xin):
        with crossbar_mode(mode):
            return crossbar_linear(xin, p["wq"], name="wq")

    @jax.jit
    def f2(p, xin):
        with crossbar_mode(mode):
            return crossbar_linear(xin, p["wq"], name="wq") * 1.0

    a = np.asarray(f1(params, x))
    b = np.asarray(f2(jax.device_put(params), x))  # copied params, new trace
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)
    assert crossbar_misses() == ()


def test_binding_survives_transpose_view():
    """A per-call transpose has no stable object identity — the tied-head
    case.  Programming the transpose once and looking it up by the source
    leaf's name serves it regardless of which transpose view is passed."""
    x, params = _params(2, K=64, N=48)
    table = params["wq"].T  # pretend (V, D) embedding; head weight is its .T
    prog = program_model({"tokens": table}, device=DEV, tie_lm_head=True)
    assert prog.n_compiled == 1 and "tokens" in prog.by_name
    with crossbar_mode(CrossbarMode(enabled=True, device=DEV)):
        y_percall = crossbar_linear(x, table.T)
    with crossbar_mode(
        CrossbarMode(enabled=True, device=DEV, programmed=prog, strict=True)
    ):
        y1 = crossbar_linear(x, table.T, name="tokens")
        y2 = crossbar_linear(x, jnp.asarray(np.asarray(table)).T, name="tokens")
    np.testing.assert_array_equal(np.asarray(y_percall), np.asarray(y1))
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    assert crossbar_misses() == ()


def test_scoped_names_and_shadowing():
    """Keys join the ambient name_scope stack; inner binds shadow outer ones
    (how per-expert slices override the stacked per-layer binding)."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(np.abs(rng.normal(size=(2, 32))).astype(np.float32))
    w1 = jnp.asarray(rng.normal(size=(32, 8)).astype(np.float32))
    w2 = jnp.asarray(rng.normal(size=(32, 8)).astype(np.float32))
    a1 = program_layer(w1, device=DEV)
    a2 = program_layer(w2, device=DEV)
    with name_scope("stage0"):
        assert scoped_name("wq") == "stage0/wq"
        with bind_artifacts({"wq": a1}):
            with crossbar_mode(CrossbarMode(enabled=True, device=DEV)):
                y_outer = crossbar_linear(x, w1, name="wq")
                with bind_artifacts({"wq": a2}):  # shadow
                    y_inner = crossbar_linear(x, w2, name="wq")
    np.testing.assert_array_equal(
        np.asarray(y_outer), np.asarray(programmed_linear(x, a1))
    )
    np.testing.assert_array_equal(
        np.asarray(y_inner), np.asarray(programmed_linear(x, a2))
    )


def test_miss_counter_and_strict_mode():
    """A programmed model that resolves no artifact for a call is a counted
    miss (the old behavior was a *silent* digital fallback); strict mode —
    per-call or via CrossbarMode — raises instead."""
    x, params = _params(4)
    prog = program_model(params, device=DEV)
    mode = CrossbarMode(enabled=True, device=DEV, programmed=prog)
    w_other = jnp.asarray(
        np.random.default_rng(5).normal(size=(128, 16)).astype(np.float32)
    )
    with crossbar_mode(mode):
        crossbar_linear(x, w_other, name="not_compiled")
        crossbar_linear(x, w_other)  # nameless call under a programmed model
    assert crossbar_misses() == ("not_compiled", "<unnamed (128, 16)>")
    with pytest.raises(LookupError):
        with crossbar_mode(mode):
            crossbar_linear(x, w_other, name="not_compiled", strict=True)
    with pytest.raises(LookupError):
        with crossbar_mode(
            CrossbarMode(enabled=True, device=DEV, programmed=prog, strict=True)
        ):
            crossbar_linear(x, w_other, name="not_compiled")
    # without a programmed model there is nothing to miss
    reset_crossbar_misses()
    with crossbar_mode(CrossbarMode(enabled=True, strict=True)):
        crossbar_linear(x, w_other, name="not_compiled")
    assert crossbar_misses() == ()


# ---------------------------------------------------------------------------
# Per-expert MoE artifacts
# ---------------------------------------------------------------------------

def test_expert_stacked_artifact_bit_identical_to_standalone():
    """A 4-D (L, E, K, N) expert bank compiles to per-expert artifacts that
    are bit-identical — cells, scales, reports — to programming each expert
    slab standalone, and each serves bit-identically."""
    rng = np.random.default_rng(6)
    ws = jnp.asarray(rng.normal(size=(2, 3, 64, 8)).astype(np.float32))
    x = jnp.asarray(np.abs(rng.normal(size=(4, 64))).astype(np.float32))
    bank = program_layer(ws, device=DEV, with_report=True)
    assert bank.stacked and bank.shape == (2, 3, 64, 8)
    assert bank.g_eff.shape[:2] == (2, 3)
    for l in range(2):
        for e in range(3):
            direct = program_layer(ws[l, e], device=DEV, with_report=True)
            sliced = bank.layer(l).layer(e)
            np.testing.assert_array_equal(
                np.asarray(sliced.g_eff), np.asarray(direct.g_eff)
            )
            np.testing.assert_array_equal(
                np.asarray(sliced.w_scale), np.asarray(direct.w_scale)
            )
            assert bank.report[l][e] == direct.report
            np.testing.assert_array_equal(
                np.asarray(programmed_linear(x, sliced)),
                np.asarray(programmed_linear(x, direct)),
            )


# ---------------------------------------------------------------------------
# Artifact serialization round-trip
# ---------------------------------------------------------------------------

def test_artifact_store_round_trip_bit_identical(tmp_path):
    """save_programmed -> restore_programmed restores the *same chip*:
    every array leaf bit-identical (g_eff fault realizations included),
    write-verify and repair reports equal, names and tree layout intact."""
    rng = np.random.default_rng(7)
    params = {
        "stage0": {
            "b0": {"wq": jnp.asarray(rng.normal(size=(2, 128, 16)).astype(np.float32))}
        },
        "head": jnp.asarray(rng.normal(size=(128, 32)).astype(np.float32)),
    }
    dev = DEV.replace(p_stuck_on=5e-3, p_stuck_off=5e-3, spare_cols=8)
    prog = program_model(params, device=dev, with_report=True)
    assert prog.n_compiled == 2
    save_programmed(str(tmp_path), prog)
    back = restore_programmed(str(tmp_path))
    assert set(back.by_name) == set(prog.by_name)
    from repro.device.programmed import ARTIFACT_ARRAY_FIELDS, artifacts_equal

    assert all(artifacts_equal(prog.by_name[n], back.by_name[n]) for n in prog.by_name)
    for name, art in prog.by_name.items():
        rart = back.by_name[name]
        for f in ARTIFACT_ARRAY_FIELDS:
            v, rv = getattr(art, f), getattr(rart, f)
            if v is None:
                assert rv is None, (name, f)
                continue
            assert v.dtype == rv.dtype, (name, f)
            np.testing.assert_array_equal(np.asarray(v), np.asarray(rv), err_msg=(name, f))
        assert art.spec == rart.spec and art.adc_cfg == rart.adc_cfg
        assert art.fast == rart.fast
        assert art.report == rart.report
        assert art.repair == rart.repair
    # tree layout supports the stage subtree path _run_stage scans
    assert back.subtree("stage0")["b0"]["wq"].stacked
    # restored chips serve bit-identically to freshly programmed ones
    x = jnp.asarray(np.abs(rng.normal(size=(2, 128))).astype(np.float32))
    np.testing.assert_array_equal(
        np.asarray(programmed_linear(x, prog.by_name["head"])),
        np.asarray(programmed_linear(x, back.by_name["head"])),
    )


def test_restore_programmed_missing_dir(tmp_path):
    with pytest.raises(FileNotFoundError):
        restore_programmed(str(tmp_path / "nope"))


def test_engine_restore_validates_store(tmp_path):
    """A stale or mismatched artifact store must fail engine construction
    loudly — silently resolving zero artifacts would degrade every
    projection to per-call reprogramming (review finding, ISSUE 4)."""
    from benchmarks.noise_sweep import tiny_lm_config
    from repro.models import model as M
    from repro.serving.engine import ServingEngine

    # a store programmed from a *different* model
    other = {"wq": jnp.asarray(np.random.default_rng(8).normal(size=(8, 4)).astype(np.float32))}
    save_programmed(str(tmp_path), program_model(other))

    cfg = tiny_lm_config()
    params, _ = M.init_model(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    with pytest.raises(ValueError, match="does not match this model"):
        ServingEngine(
            cfg, params, max_batch=1, max_seq=16,
            crossbar=CrossbarMode(enabled=True), restore_artifacts=str(tmp_path),
        )


def test_expected_artifact_names_mirrors_program_model():
    from repro.device.programmed import expected_artifact_names

    rng = np.random.default_rng(9)
    params = {
        "embed": {"tokens": jnp.asarray(rng.normal(size=(32, 16)).astype(np.float32))},
        "stage0": {"b0": {"wq": jnp.asarray(rng.normal(size=(2, 16, 8)).astype(np.float32)),
                          "norm1": jnp.asarray(rng.normal(size=(2, 16)).astype(np.float32))}},
    }
    for tie in (False, True):
        prog = program_model(params, tie_lm_head=tie)
        exp = expected_artifact_names(params, tie_lm_head=tie)
        assert set(exp) == set(prog.by_name)
        assert all(prog.by_name[n].shape == s for n, s in exp.items())
    assert expected_artifact_names(params, tie_lm_head=True)["embed/tokens"] == (16, 32)


def test_save_programmed_overwrite_preserves_store(tmp_path):
    """Overwriting a store swaps atomically: the previous store is never
    deleted before the new one is in place, and the result is readable."""
    x, params = _params(10, K=32, N=8)
    prog = program_model(params, device=DEV)
    save_programmed(str(tmp_path), prog)
    save_programmed(str(tmp_path), prog)  # overwrite in place
    back = restore_programmed(str(tmp_path))
    np.testing.assert_array_equal(
        np.asarray(back.by_name["wq"].g_eff), np.asarray(prog.by_name["wq"].g_eff)
    )


def test_note_crossbar_gap():
    """Mesh-sharded paths that cannot serve from artifacts (rank-local
    weight shards) must still be loud: note_crossbar_gap counts a miss
    under a ProgrammedModel and raises under strict mode."""
    from repro.models.layers import note_crossbar_gap

    x, params = _params(11)
    prog = program_model(params, device=DEV)
    with crossbar_mode(CrossbarMode(enabled=True)):
        note_crossbar_gap("wi")  # no programmed model: not a gap
    assert crossbar_misses() == ()
    with crossbar_mode(CrossbarMode(enabled=True, programmed=prog)):
        with name_scope("stage0"):
            note_crossbar_gap("wi")
    assert crossbar_misses() == ("stage0/wi",)
    with pytest.raises(LookupError):
        with crossbar_mode(CrossbarMode(enabled=True, programmed=prog, strict=True)):
            note_crossbar_gap("wi")


# ---------------------------------------------------------------------------
# Structural name-set check (ISSUE 5 satellite)
# ---------------------------------------------------------------------------

def test_program_model_emits_and_forward_consumes_exactly():
    """``program_model`` returns its emitted name set
    (``ProgrammedModel.emitted_names``); a traced forward consumes exactly
    that set (``verify_consumed`` passes, and the consumption record
    matches name for name)."""
    import jax.numpy as jnp

    from benchmarks.noise_sweep import tiny_lm_config
    from repro.device.programmed import (
        consumed_artifact_names,
        reset_consumed_artifact_names,
    )
    from repro.models import model as M

    cfg = tiny_lm_config()
    params, _ = M.init_model(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    prog = program_model(params)
    assert prog.emitted_names == frozenset(prog.by_name)
    reset_consumed_artifact_names()
    with crossbar_mode(CrossbarMode(enabled=True, fast=True, programmed=prog)):
        jax.make_jaxpr(lambda p, t: M.forward(p, cfg, t))(
            params, jnp.zeros((1, 4), jnp.int32)
        )
    assert frozenset(consumed_artifact_names()) == prog.emitted_names
    prog.verify_consumed()
    reset_consumed_artifact_names()


def test_renamed_layer_raises_before_miss_counter_catches_it():
    """Drift test: rename a layer between programming and serving.  The
    orphaned artifacts produce **zero misses** — nothing ever looks their
    names up — so the miss counter alone would report a fully-covered
    forward while half the chip silently serves nothing.  The structural
    check (``verify_consumed``) raises on exactly this."""
    import jax.numpy as jnp

    from benchmarks.noise_sweep import tiny_lm_config
    from repro.device.programmed import reset_consumed_artifact_names
    from repro.models import model as M

    cfg = tiny_lm_config()
    params, _ = M.init_model(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    # programming sees a tree whose stage was renamed (stage0 -> stage0_v2):
    # every block artifact is emitted under the renamed path
    renamed = dict(params)
    renamed["stage0_v2"] = renamed.pop("stage0")
    prog = program_model(renamed)
    assert any(n.startswith("stage0_v2/") for n in prog.emitted_names)

    reset_crossbar_misses()
    reset_consumed_artifact_names()
    with crossbar_mode(CrossbarMode(enabled=True, fast=True, programmed=prog)):
        jax.make_jaxpr(lambda p, t: M.forward(p, cfg, t))(
            params, jnp.zeros((1, 4), jnp.int32)
        )
    # the head artifact (unrenamed) was consumed; the renamed block
    # artifacts were not — and the *misses* only see the consuming side
    with pytest.raises(LookupError, match="name-set drift"):
        prog.verify_consumed()
    reset_consumed_artifact_names()


def test_engine_verify_coverage_fails_on_orphaned_artifact(tmp_path):
    """ServingEngine runs the structural check at construction: a restored
    store that *superset*-matches the model (every needed artifact present,
    plus an orphan nothing serves) passes the shape cross-check but fails
    ``verify_coverage`` — before the first request is ever admitted."""
    import jax.numpy as jnp

    from benchmarks.noise_sweep import tiny_lm_config
    from repro.models import model as M
    from repro.serving.engine import ServingEngine

    cfg = tiny_lm_config()
    params, _ = M.init_model(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    # a store with every model projection plus one orphaned leaf
    extra = dict(params)
    extra["dead_branch"] = {
        "wq": jnp.asarray(np.random.default_rng(0).normal(size=(8, 4)).astype(np.float32))
    }
    save_programmed(str(tmp_path), program_model(extra))
    with pytest.raises(LookupError, match="name-set drift"):
        ServingEngine(
            cfg, params, max_batch=1, max_seq=16,
            crossbar=CrossbarMode(enabled=True), restore_artifacts=str(tmp_path),
        )
    # the check is opt-out for exotic setups
    eng = ServingEngine(
        cfg, params, max_batch=1, max_seq=16,
        crossbar=CrossbarMode(enabled=True), restore_artifacts=str(tmp_path),
        verify_coverage=False,
    )
    assert eng.crossbar.programmed is not None


def test_restore_falls_back_to_interrupted_swap_states(tmp_path):
    """A crash inside save_programmed's two-rename swap leaves the store
    under 'programmed.tmp' (complete, not yet renamed) or 'programmed.old'
    (previous chip renamed aside); restore must use them instead of forcing
    a full reprogram."""
    import os

    x, params = _params(12, K=32, N=8)
    prog = program_model(params, device=DEV)
    save_programmed(str(tmp_path), prog)
    base = os.path.join(str(tmp_path), "programmed")
    for suffix in (".tmp", ".old"):
        os.rename(base, base + suffix)
        back = restore_programmed(str(tmp_path))
        np.testing.assert_array_equal(
            np.asarray(back.by_name["wq"].g_eff), np.asarray(prog.by_name["wq"].g_eff)
        )
        os.rename(base + suffix, base)
