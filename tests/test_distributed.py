"""Distributed correctness on forced multi-host-device CPU backends.

These run in subprocesses (the main test process must keep 1 device for the
smoke tests), each with ``--xla_force_host_platform_device_count=8``:

  * DP+TP sharded loss == single-device loss (same params/batch)
  * shard_map expert-parallel MoE == single-device MoE
  * int8 error-feedback compressed all-reduce: unbiased under error feedback
  * a miniature dry-run (4x2 mesh) exercising the full lower+compile path
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

pytestmark = pytest.mark.dist


def _run(code: str) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-4000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_dp_tp_loss_matches_single_device():
    res = _run("""
        import json, numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro import configs
        from repro.configs.base import reduced
        from repro.models import model as M
        from repro.models.layers import use_mesh
        from repro.launch import sharding as shlib

        cfg = reduced(configs.get_config("smollm-360m"))
        params, axes = M.init_model(jax.random.PRNGKey(0), cfg)
        key = jax.random.PRNGKey(1)
        batch = {
            "inputs": jax.random.randint(key, (4, 16), 0, cfg.vocab_size),
            "targets": jax.random.randint(key, (4, 16), 0, cfg.vocab_size),
        }
        loss_single = float(M.loss_fn(params, cfg, batch))

        mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
        with use_mesh(mesh), mesh:
            p_sh = shlib.param_shardings(
                jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params),
                axes, mesh)
            b_sh = shlib.batch_shardings(
                jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), batch), mesh)
            p = jax.tree.map(jax.device_put, params, p_sh)
            b = jax.tree.map(jax.device_put, batch, b_sh)
            loss_sharded = float(jax.jit(lambda p, b: M.loss_fn(p, cfg, b))(p, b))
        print(json.dumps({"single": loss_single, "sharded": loss_sharded}))
    """)
    assert abs(res["single"] - res["sharded"]) < 2e-3 * max(1.0, abs(res["single"]))


@pytest.mark.slow
def test_moe_ep_matches_single_device():
    """shard_map EP == single device, once the two *policy* differences are
    held fixed: capacity is per-shard in EP (GShard semantics — uncap it),
    and top-k ties can flip across compiled graphs (separate the logits)."""
    res = _run("""
        import json, dataclasses as dc, numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh
        from repro import configs
        from repro.configs.base import reduced
        from repro.models import moe as Mo
        from repro.models.layers import Init, use_mesh

        cfg = dc.replace(reduced(configs.get_config("deepseek-v2-236b")),
                         moe_capacity_factor=1000.0)
        ini = Init(key=jax.random.PRNGKey(0))
        Mo.init_moe(ini, cfg)
        params = dict(ini.params)
        params["router"] = params["router"] * 100.0  # well-separated logits
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model)) * 0.3
        y_single = Mo.moe_ffn(params, x, cfg)

        mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
        with use_mesh(mesh), mesh:
            y_ep = jax.jit(lambda p, x: Mo.moe_ffn(p, x, cfg))(params, x)
        diff = float(jnp.max(jnp.abs(y_single - y_ep)))
        rel = diff / (float(jnp.max(jnp.abs(y_single))) + 1e-9)
        print(json.dumps({"rel": rel}))
    """)
    assert res["rel"] < 1e-3


@pytest.mark.slow
def test_compressed_allreduce_error_feedback():
    res = _run("""
        import json, numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh, PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.train.compression import ef_int8_psum

        mesh = Mesh(np.array(jax.devices()).reshape(8), ("data",))
        g = jax.random.normal(jax.random.PRNGKey(0), (8, 1024))  # per-device rows

        def step(x, err):
            return ef_int8_psum(x, err, "data")

        f = shard_map(step, mesh=mesh, in_specs=(P("data"), P("data")),
                      out_specs=(P("data"), P("data")), check_rep=False)
        err = jnp.zeros_like(g)
        true_mean = jnp.mean(g, axis=0, keepdims=True)
        # accumulated compressed means over T steps converge to T * true mean
        acc = jnp.zeros((1, 1024))
        T = 20
        for _ in range(T):
            out, err = f(g, err)
            acc = acc + out[:1]
        drift = float(jnp.max(jnp.abs(acc / T - true_mean)))
        scale = float(jnp.max(jnp.abs(true_mean))) + 1e-9
        one, _ = f(g, jnp.zeros_like(g))
        one_err = float(jnp.max(jnp.abs(one[:1] - true_mean)))
        print(json.dumps({"drift_rel": drift / scale, "one_err_rel": one_err / scale}))
    """)
    # single compressed step has visible quantization error; error feedback
    # makes the *average* far more accurate
    assert res["drift_rel"] < res["one_err_rel"]
    assert res["drift_rel"] < 0.02


@pytest.mark.slow
def test_mini_dryrun_both_meshes():
    res = _run("""
        import json, numpy as np, jax, jax.numpy as jnp, dataclasses as dc
        from jax.sharding import Mesh
        from repro import configs
        from repro.configs.base import reduced, SHAPES, ShapeSpec
        from repro.models import model as M
        from repro.models.layers import use_mesh
        from repro.launch import sharding as shlib
        from repro.optim import make_optimizer, constant
        from repro.train import make_train_step

        cfg = reduced(configs.get_config("gemma2-9b"))
        out = {}
        for name, shape_arr in [("pod", (4, 2)), ("multipod", (2, 2, 2))]:
            axes_names = ("data", "model") if len(shape_arr) == 2 else ("pod", "data", "model")
            mesh = Mesh(np.array(jax.devices()).reshape(shape_arr), axes_names)
            with use_mesh(mesh), mesh:
                p_shapes, axes = M.init_model(jax.random.PRNGKey(0), cfg, shape_only=True)
                p_sh = shlib.param_shardings(p_shapes, axes, mesh)
                opt = make_optimizer("adamw", constant(1e-3))
                step = make_train_step(cfg, opt)
                o_shapes = jax.eval_shape(opt.init, p_shapes)
                o_sh = shlib.opt_state_shardings("adamw", o_shapes, p_sh, mesh)
                batch = {
                    "inputs": jax.ShapeDtypeStruct((8, 32), jnp.int32),
                    "targets": jax.ShapeDtypeStruct((8, 32), jnp.int32),
                }
                b_sh = shlib.batch_shardings(batch, mesh)
                c = jax.jit(step, in_shardings=(p_sh, o_sh, None, b_sh)).lower(
                    p_shapes, o_shapes, jax.ShapeDtypeStruct((), jnp.int32), batch
                ).compile()
                out[name] = int(c.memory_analysis().temp_size_in_bytes)
        print(json.dumps(out))
    """)
    assert res["pod"] > 0 and res["multipod"] > 0
