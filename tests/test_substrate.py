"""Substrate tests: optimizers, schedules, data determinism, checkpointing
(incl. elastic restore), the fault-tolerant train loop, serving engine."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.checkpoint import CheckpointManager, latest_step, restore_checkpoint, save_checkpoint
from repro.configs.base import reduced
from repro.data import SyntheticLMDataset, MemmapLMDataset, prefetch
from repro.models import model as M
from repro.optim import (
    adafactor,
    adamw,
    constant,
    cosine_with_warmup,
    global_norm,
    make_optimizer,
    sgd,
)
from repro.serving import ServingEngine
from repro.train import TrainLoop, make_train_step
from repro.train.loop import StragglerMonitor


# --- optimizers -------------------------------------------------------------

@pytest.mark.parametrize("name", ["sgd", "adamw", "adafactor"])
def test_optimizer_converges_quadratic(name):
    """Each optimizer minimizes a simple quadratic (sum-scaled so SGD's raw
    gradients are O(w - target), not O(1/numel))."""
    target = jnp.asarray(np.random.default_rng(0).normal(size=(4, 130)).astype(np.float32))
    params = {"w": jnp.zeros((4, 130))}
    lr = {"sgd": 0.02, "adamw": 0.05, "adafactor": 0.3}[name]
    opt = make_optimizer(name, constant(lr))
    state = opt.init(params)

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    step = jnp.int32(0)
    for i in range(300):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params, step + i)
    assert float(jnp.mean((params["w"] - target) ** 2)) < 0.05


def test_adafactor_state_is_factored():
    params = {"big": jnp.zeros((512, 256)), "small": jnp.zeros((8,))}
    opt = adafactor(constant(1e-2))
    st = opt.init(params)
    assert "vr" in st["acc"]["big"] and st["acc"]["big"]["vr"].shape == (512,)
    assert st["acc"]["big"]["vc"].shape == (256,)
    assert "v" in st["acc"]["small"]
    # factored state is ~(r+c)/(r*c) of adam's
    adam_bytes = 2 * 512 * 256
    fact_bytes = 512 + 256
    assert fact_bytes < adam_bytes / 100


def test_layerwise_update_matches_direct():
    """The lax.map layer-chunked update must equal the unchunked math."""
    from repro.optim import optimizers as O

    rng = np.random.default_rng(1)
    p = jnp.asarray(rng.normal(size=(6, 256, 300)).astype(np.float32))  # stacked
    g = jnp.asarray(rng.normal(size=(6, 256, 300)).astype(np.float32))
    opt = adamw(constant(1e-2))
    st = opt.init({"w": p})
    p1, st1 = opt.update({"w": g}, st, {"w": p}, jnp.int32(0))
    # force the non-layerwise path by lowering the size threshold
    old = O.LAYERWISE_MIN_DIM
    O.LAYERWISE_MIN_DIM = 99  # disables layerwise
    try:
        p2, st2 = opt.update({"w": g}, opt.init({"w": p}), {"w": p}, jnp.int32(0))
    finally:
        O.LAYERWISE_MIN_DIM = old
    np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p2["w"]), rtol=1e-6)


def test_cosine_schedule_shape():
    f = cosine_with_warmup(1.0, 10, 100)
    assert float(f(jnp.int32(0))) == 0.0
    assert float(f(jnp.int32(10))) == pytest.approx(1.0, rel=1e-3)
    assert float(f(jnp.int32(100))) == pytest.approx(0.1, rel=1e-2)


# --- data -------------------------------------------------------------------

def test_synthetic_data_deterministic_and_restart_safe():
    ds1 = SyntheticLMDataset(1000, 32, 4, seed=7)
    ds2 = SyntheticLMDataset(1000, 32, 4, seed=7)
    b5a, b5b = ds1.batch_at(5), ds2.batch_at(5)
    np.testing.assert_array_equal(b5a["inputs"], b5b["inputs"])
    assert not np.array_equal(ds1.batch_at(6)["inputs"], b5a["inputs"])
    # host sharding partitions the global batch
    h0 = SyntheticLMDataset(1000, 32, 4, seed=7, process_index=0, process_count=2)
    h1 = SyntheticLMDataset(1000, 32, 4, seed=7, process_index=1, process_count=2)
    assert h0.local_batch == 2
    assert not np.array_equal(h0.batch_at(0)["inputs"], h1.batch_at(0)["inputs"])


def test_memmap_dataset(tmp_path):
    path = tmp_path / "tokens.bin"
    np.arange(10000, dtype=np.int32).tofile(path)
    ds = MemmapLMDataset(str(path), seq_len=16, global_batch=2, process_index=0, process_count=1)
    b = ds.batch_at(0)
    assert b["inputs"].shape == (2, 16)
    np.testing.assert_array_equal(b["targets"][:, :-1], b["inputs"][:, 1:])
    np.testing.assert_array_equal(ds.batch_at(0)["inputs"], ds.batch_at(0)["inputs"])


def test_prefetch_preserves_order():
    out = list(prefetch(iter(range(10)), size=3))
    assert out == list(range(10))


# --- checkpointing ----------------------------------------------------------

def test_checkpoint_roundtrip_and_elastic(tmp_path):
    tree = {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4), "b": {"c": jnp.ones(5)}}
    save_checkpoint(str(tmp_path), 7, tree, {"note": "x"})
    assert latest_step(str(tmp_path)) == 7
    restored, step, meta = restore_checkpoint(str(tmp_path), None, tree)
    assert step == 7 and meta["note"] == "x"
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))
    # elastic: restore onto an explicit different sharding (single device)
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_local_mesh

    mesh = make_local_mesh(model=1)
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
    restored2, _, _ = restore_checkpoint(str(tmp_path), None, tree, shardings=sh)
    assert restored2["b"]["c"].sharding == sh["b"]["c"]


def test_checkpoint_manager_async_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"w": jnp.zeros(3)}
    for s in (1, 2, 3, 4):
        mgr.save_async(s, tree)
    mgr.wait()
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert steps == [3, 4]


# --- train loop fault tolerance ----------------------------------------------

def test_nan_step_is_skipped():
    cfg = reduced(configs.get_config("smollm-360m"))
    params, _ = M.init_model(jax.random.PRNGKey(0), cfg)
    opt = make_optimizer("adamw", constant(1e-3))

    def poisoned_loss(p, b):
        return M.loss_fn(p, cfg, b) * jnp.where(b["targets"][0, 0] == 0, jnp.nan, 1.0)

    step = make_train_step(cfg, opt, loss_fn=poisoned_loss)
    batch = {
        "inputs": jnp.zeros((2, 8), jnp.int32),
        "targets": jnp.zeros((2, 8), jnp.int32),  # triggers the NaN
    }
    p2, o2, _, metrics = step(params, opt.init(params), jnp.int32(0), batch)
    assert int(metrics["skipped"]) == 1
    deltas = jax.tree.leaves(jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), params, p2))
    assert max(deltas) == 0.0  # params untouched


@pytest.mark.slow
def test_microbatched_grad_accum_matches_full():
    cfg = reduced(configs.get_config("smollm-360m"))
    params, _ = M.init_model(jax.random.PRNGKey(0), cfg)
    opt = make_optimizer("sgd", constant(1e-2))
    full = make_train_step(cfg, opt, microbatches=1)
    micro = make_train_step(cfg, opt, microbatches=2)
    key = jax.random.PRNGKey(3)
    batch = {
        "inputs": jax.random.randint(key, (4, 8), 0, cfg.vocab_size),
        "targets": jax.random.randint(key, (4, 8), 0, cfg.vocab_size),
    }
    p1, _, _, m1 = full(params, opt.init(params), jnp.int32(0), batch)
    p2, _, _, m2 = micro(params, opt.init(params), jnp.int32(0), batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)
    d = max(jax.tree.leaves(jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), p1, p2)))
    assert d < 1e-5


def test_train_resume_is_deterministic(tmp_path):
    cfg = reduced(configs.get_config("smollm-360m"))
    opt = make_optimizer("adamw", constant(1e-3))
    step_fn = jax.jit(make_train_step(cfg, opt))
    ds = SyntheticLMDataset(cfg.vocab_size, 16, 2, seed=0)

    def fresh():
        p, _ = M.init_model(jax.random.PRNGKey(0), cfg)
        return p, opt.init(p)

    # uninterrupted run: 6 steps
    p, o = fresh()
    loop = TrainLoop(cfg, step_fn, ds, ckpt_dir=None, log_every=100)
    p_ref, _ = loop.run(p, o, 6)

    # interrupted run: 3 steps + checkpoint, then resume for 3 more
    p, o = fresh()
    loop1 = TrainLoop(cfg, step_fn, ds, ckpt_dir=str(tmp_path), ckpt_every=3, log_every=100)
    loop1.run(p, o, 3)
    p2, o2 = fresh()
    loop2 = TrainLoop(cfg, step_fn, ds, ckpt_dir=str(tmp_path), ckpt_every=100, log_every=100)
    p2, o2, start = loop2.maybe_resume(p2, o2)
    assert start == 3
    p_resumed, _ = loop2.run(p2, o2, 6, start_step=start)
    d = max(jax.tree.leaves(jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), p_ref, p_resumed)))
    assert d < 1e-6


def test_straggler_monitor_flags_outliers():
    m = StragglerMonitor(threshold=3.0)
    for _ in range(10):
        assert not m.observe(0.1)
    assert m.observe(1.0)  # 10x the EMA
    assert m.flagged == 1


# --- serving ------------------------------------------------------------------

@pytest.mark.slow
def test_serving_engine_matches_teacher_forcing():
    cfg = reduced(configs.get_config("smollm-360m"))
    params, _ = M.init_model(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params, max_batch=2, max_seq=128)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=n) for n in (7, 13, 22)]
    for p in prompts:
        eng.submit(p, max_new_tokens=5)
    reqs = eng.run_until_done()
    assert len(reqs) == 3
    for req, prompt in zip(reqs, prompts):
        full = list(prompt)
        ref = []
        for _ in range(5):
            logits = M.forward(params, cfg, jnp.asarray([full]))
            nxt = int(jnp.argmax(logits[0, -1]))
            ref.append(nxt)
            full.append(nxt)
        assert req.generated[:5] == ref


@pytest.mark.slow
def test_serving_engine_recurrent_prefix():
    """Recurrent archs: small float reorders may flip late near-tie argmaxes
    on random weights; assert the prefix matches."""
    cfg = reduced(configs.get_config("jamba-v0.1-52b"))
    params, _ = M.init_model(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params, max_batch=2, max_seq=128)
    prompt = np.random.default_rng(0).integers(0, cfg.vocab_size, size=9)
    eng.submit(prompt, max_new_tokens=4)
    (req,) = eng.run_until_done()
    full = list(prompt)
    ref = []
    for _ in range(4):
        logits = M.forward(params, cfg, jnp.asarray([full]))
        nxt = int(jnp.argmax(logits[0, -1]))
        ref.append(nxt)
        full.append(nxt)
    assert req.generated[:2] == ref[:2]
