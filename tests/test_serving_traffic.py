"""Serving traffic tier: request-lifecycle regression tests + the
continuous-batching scheduler / block KV cache / chip farm.

The first three test groups pin the ISSUE 10 engine bugfixes — each fails
on the pre-fix engine:

  * ``run_until_done`` used to lose a request that was admitted and
    finished within one ``step()`` (its slot was freed before the loop's
    ``seen`` snapshot ever saw it);
  * ``_admit`` used to silently truncate a prompt longer than ``max_seq``
    while pointing ``pos``/``last_tok`` past the prefilled region
    (incoherent state, garbage generation);
  * ``hot_swap`` used to skip the ``analysis.verify_store`` fail-fast
    verification that construction-time ``restore_artifacts=`` runs, so a
    corrupt store hit mid-flight serving instead of being refused.

The rest covers the tentpole: scheduler determinism and bit-identity to
the slot-loop engine, deadlines/streaming/preemption, block accounting,
exact page-out/page-in, and farm routing/drain/refresh.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.noise_sweep import tiny_lm_config
from repro.device import DeviceConfig
from repro.models import model as M
from repro.models.layers import CrossbarMode
from repro.serving import (
    BlockCacheConfig,
    BlockKVCache,
    ChipFarm,
    ContinuousBatchingScheduler,
    ModelRunner,
    ServingEngine,
)

pytestmark = pytest.mark.serving


@pytest.fixture(scope="module")
def tiny_lm():
    cfg = tiny_lm_config()
    params, _ = M.init_model(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    return cfg, params


def _prompt(n, lo=1):
    return (np.arange(lo, lo + n) % 60 + 1).astype(np.int32)


# ---------------------------------------------------------------------------
# Bugfix 1: a request admitted and finished inside one step() must not
# vanish from run_until_done()
# ---------------------------------------------------------------------------


def test_one_token_request_round_trip(tiny_lm):
    cfg, params = tiny_lm
    eng = ServingEngine(cfg, params, max_batch=2, max_seq=32)
    rid = eng.submit(_prompt(5), max_new_tokens=1)
    res = eng.run_until_done()
    assert [r.rid for r in res] == [rid]
    assert res[0].done and len(res[0].generated) == 1


def test_one_token_request_not_lost_among_longer(tiny_lm):
    cfg, params = tiny_lm
    eng = ServingEngine(cfg, params, max_batch=2, max_seq=32)
    r0 = eng.submit(_prompt(5), max_new_tokens=1)
    r1 = eng.submit(_prompt(7), max_new_tokens=6)
    r2 = eng.submit(_prompt(4), max_new_tokens=1)
    res = eng.run_until_done()
    assert [r.rid for r in res] == [r0, r1, r2]
    assert all(r.done for r in res)
    assert [len(r.generated) for r in res] == [1, 6, 1]


def test_step_records_completion_ledger(tiny_lm):
    cfg, params = tiny_lm
    eng = ServingEngine(cfg, params, max_batch=1, max_seq=32)
    rid = eng.submit(_prompt(5), max_new_tokens=1)
    assert eng.step() == 1
    # the slot was freed the same step, but the request is in the ledger
    assert eng.slots == [None]
    assert rid in eng._completed and eng._completed[rid].done


# ---------------------------------------------------------------------------
# Bugfix 2: over-length prompts — loud rejection, coherent truncation
# ---------------------------------------------------------------------------


def test_overlength_prompt_rejected_at_submit(tiny_lm):
    cfg, params = tiny_lm
    eng = ServingEngine(cfg, params, max_batch=1, max_seq=16)
    with pytest.raises(ValueError, match="exceeds max_seq"):
        eng.submit(_prompt(24))
    # nothing was queued: the engine stays clean after the refusal
    assert eng.pending == [] and eng.run_until_done() == []


def test_overlength_prompt_truncates_coherently(tiny_lm):
    cfg, params = tiny_lm
    long = _prompt(24)
    eng = ServingEngine(cfg, params, max_batch=1, max_seq=16)
    eng.submit(long, max_new_tokens=3, truncate=True)
    res = eng.run_until_done()
    # truncate=True must behave exactly like submitting prompt[:max_seq]:
    # pos and last_tok come from the truncated length, not the original
    ref = ServingEngine(cfg, params, max_batch=1, max_seq=16)
    ref.submit(long[:16], max_new_tokens=3)
    ref_res = ref.run_until_done()
    assert res[0].generated == ref_res[0].generated
    assert res[0].done


def test_max_length_prompt_still_admits(tiny_lm):
    # the boundary case: S == max_seq is legal without truncate
    cfg, params = tiny_lm
    eng = ServingEngine(cfg, params, max_batch=1, max_seq=16)
    eng.submit(_prompt(16), max_new_tokens=2)
    res = eng.run_until_done()
    assert res[0].done and len(res[0].generated) >= 1


# ---------------------------------------------------------------------------
# Bugfix 3: hot_swap must verify the store before rebinding
# ---------------------------------------------------------------------------


@pytest.fixture()
def programmed_engine(tiny_lm):
    cfg, params = tiny_lm
    dev = DeviceConfig(sigma=0.02, seed=3)
    return ServingEngine(
        cfg, params, max_batch=1, max_seq=16,
        crossbar=CrossbarMode(enabled=True, device=dev),
    )


def test_hot_swap_refuses_corrupted_store(programmed_engine, tmp_path):
    eng = programmed_engine
    d = str(tmp_path / "store")
    eng.save_artifacts(d)
    before = eng.crossbar.programmed
    # tamper: append a bogus array member to one artifact's npz.
    # restore_programmed ignores unknown members (it loads by key), so the
    # pre-fix hot_swap bound this store silently; verify_store's manifest/
    # npz-header cross-check flags it
    store = os.path.join(d, "programmed")
    with open(os.path.join(store, "manifest.json")) as f:
        man = json.load(f)
    rec = next(iter(man["artifacts"].values()))
    fname = os.path.join(store, rec["file"])
    arrs = dict(np.load(fname, allow_pickle=False))
    arrs["bogus_extra"] = np.zeros(3, np.float32)
    np.savez(fname, **arrs)
    with pytest.raises(ValueError, match="verify_store"):
        eng.hot_swap(d)
    # the refusal is fail-fast: the old chip is still bound and serving
    assert eng.crossbar.programmed is before
    eng.submit(_prompt(4), max_new_tokens=1)
    assert len(eng.run_until_done()) == 1


@pytest.mark.slow
def test_hot_swap_clean_store_still_works(programmed_engine, tmp_path):
    eng = programmed_engine
    d = str(tmp_path / "store")
    eng.save_artifacts(d)
    eng.hot_swap(d)  # same chip round-tripped: swap must succeed
    eng.submit(_prompt(4), max_new_tokens=2)
    res = eng.run_until_done()
    assert res[0].done


def test_hot_swap_and_restore_share_verification(programmed_engine, tiny_lm, tmp_path):
    # the fix routes hot_swap through the same _verify_store helper that
    # construction-time restore uses: a store both accept is identical,
    # and a store construction refuses hot_swap must refuse too
    cfg, params = tiny_lm
    eng = programmed_engine
    d = str(tmp_path / "store")
    eng.save_artifacts(d)
    store = os.path.join(d, "programmed")
    man_path = os.path.join(store, "manifest.json")
    with open(man_path) as f:
        man = json.load(f)
    # drop one artifact from the manifest: a missing-leaf store
    man["artifacts"].pop(sorted(man["artifacts"])[0])
    with open(man_path, "w") as f:
        json.dump(man, f)
    with pytest.raises(ValueError):
        ServingEngine(
            cfg, params, max_batch=1, max_seq=16,
            crossbar=CrossbarMode(enabled=True, device=DeviceConfig(sigma=0.02, seed=3)),
            restore_artifacts=d,
        )
    with pytest.raises(ValueError):
        eng.hot_swap(d)


# ---------------------------------------------------------------------------
# Tentpole: scheduler determinism + bit-identity to the engine
# ---------------------------------------------------------------------------


def _mixed_workload():
    return [
        (_prompt(5), 3),
        (_prompt(9, lo=4), 6),
        (_prompt(3, lo=9), 1),
        (_prompt(12, lo=2), 4),
        (_prompt(6, lo=7), 5),
        (_prompt(4, lo=11), 2),
    ]


def test_scheduler_token_identical_to_engine(tiny_lm):
    cfg, params = tiny_lm
    eng = ServingEngine(cfg, params, max_batch=2, max_seq=32, seed=0)
    for p, n in _mixed_workload():
        eng.submit(p, max_new_tokens=n)
    eng_out = {r.rid: r.generated for r in eng.run_until_done()}

    sched = ContinuousBatchingScheduler(
        ModelRunner(cfg, params, max_seq=32, seed=0), max_batch=2
    )
    for p, n in _mixed_workload():
        sched.submit(p, max_new_tokens=n)
    sched_out = {r.rid: r.generated for r in sched.run()}
    assert sched_out == eng_out


def test_scheduler_deterministic_replay(tiny_lm):
    cfg, params = tiny_lm

    def run():
        sched = ContinuousBatchingScheduler(
            ModelRunner(cfg, params, max_seq=32, seed=0), max_batch=2
        )
        for p, n in _mixed_workload():
            sched.submit(p, max_new_tokens=n)
        return [(r.rid, tuple(r.generated), r.finish) for r in sched.run()]

    assert run() == run()


def test_scheduler_admits_mid_flight(tiny_lm):
    # continuous batching: a request submitted while others decode is
    # admitted at the next tick, not after the batch drains
    cfg, params = tiny_lm
    sched = ContinuousBatchingScheduler(
        ModelRunner(cfg, params, max_seq=32, seed=0), max_batch=2
    )
    sched.submit(_prompt(5), max_new_tokens=8)
    sched.step()
    sched.submit(_prompt(4, lo=3), max_new_tokens=2)
    sched.step()
    assert sched.n_active == 2  # joined the in-flight batch immediately
    res = sched.run()
    assert [len(r.generated) for r in res] == [8, 2]


def test_scheduler_deadline_eviction(tiny_lm):
    cfg, params = tiny_lm
    sched = ContinuousBatchingScheduler(
        ModelRunner(cfg, params, max_seq=48, seed=0), max_batch=1
    )
    r0 = sched.submit(_prompt(4), max_new_tokens=30, deadline=3)
    r1 = sched.submit(_prompt(4, lo=2), max_new_tokens=2)
    res = {r.rid: r for r in sched.run()}
    assert res[r0].expired and res[r0].done
    assert len(res[r0].generated) <= 3  # got at most its deadline's ticks
    # the evicted slot freed capacity: the second request completed fully
    assert not res[r1].expired and len(res[r1].generated) == 2


def test_scheduler_edf_admission_order(tiny_lm):
    # a tight-deadline latecomer must be admitted before an earlier
    # deadline-free request when one slot frees up
    cfg, params = tiny_lm
    sched = ContinuousBatchingScheduler(
        ModelRunner(cfg, params, max_seq=48, seed=0), max_batch=1
    )
    sched.submit(_prompt(4), max_new_tokens=2)  # occupies the only slot
    r_late = sched.submit(_prompt(4, lo=5), max_new_tokens=2, deadline=8)
    r_free = sched.submit(_prompt(4, lo=3), max_new_tokens=2)
    res = {r.rid: r for r in sched.run()}
    assert not res[r_late].expired
    # EDF: the deadlined request finished before the deadline-free one
    assert res[r_late].finish < res[r_free].finish


def test_scheduler_streaming_callbacks(tiny_lm):
    cfg, params = tiny_lm
    seen = []
    sched = ContinuousBatchingScheduler(
        ModelRunner(cfg, params, max_seq=32, seed=0),
        max_batch=2,
        stream=lambda req, tok: seen.append((req.rid, tok)),
    )
    r0 = sched.submit(_prompt(5), max_new_tokens=3)
    per_req = []
    r1 = sched.submit(
        _prompt(4, lo=2), max_new_tokens=2,
        on_token=lambda req, tok: per_req.append(tok),
    )
    res = {r.rid: r for r in sched.run()}
    # the scheduler-wide stream saw r0's tokens as they were sampled...
    assert [t for rid, t in seen if rid == r0] == res[r0].generated
    # ...and the per-request callback overrode it for r1
    assert per_req == res[r1].generated
    assert all(rid != r1 for rid, _ in seen)


def test_scheduler_preemption_is_exact(tiny_lm):
    # a pool too small for both requests forces swap-out/swap-in; the
    # token streams must be bit-identical to the unconstrained engine
    cfg, params = tiny_lm
    sched = ContinuousBatchingScheduler(
        ModelRunner(cfg, params, max_seq=48, seed=0),
        max_batch=2,
        block=BlockCacheConfig(block_size=4, n_blocks=4),
    )
    sched.submit(_prompt(6), max_new_tokens=8)
    sched.submit(_prompt(8, lo=2), max_new_tokens=8)
    out = {r.rid: r.generated for r in sched.run()}

    eng = ServingEngine(cfg, params, max_batch=2, max_seq=48, seed=0)
    eng.submit(_prompt(6), max_new_tokens=8)
    eng.submit(_prompt(8, lo=2), max_new_tokens=8)
    ref = {r.rid: r.generated for r in eng.run_until_done()}
    assert out == ref


def test_scheduler_refuses_impossible_request(tiny_lm):
    # admission control: a request whose worst-case block footprint
    # exceeds the whole pool would thrash forever — refused at submit
    cfg, params = tiny_lm
    sched = ContinuousBatchingScheduler(
        ModelRunner(cfg, params, max_seq=48, seed=0),
        max_batch=2,
        block=BlockCacheConfig(block_size=4, n_blocks=4),
    )
    with pytest.raises(ValueError, match="never run to completion"):
        sched.submit(_prompt(20), max_new_tokens=20)


# ---------------------------------------------------------------------------
# Block KV cache: accounting + exact paging
# ---------------------------------------------------------------------------


def test_block_accounting(tiny_lm):
    cfg, _ = tiny_lm
    kv = BlockKVCache(cfg, max_batch=2, max_seq=32,
                      block=BlockCacheConfig(block_size=8, n_blocks=6))
    assert kv.blocks_for(1) == 1 and kv.blocks_for(8) == 1
    assert kv.blocks_for(9) == 2 and kv.blocks_for(32) == 4
    kv.allocate(0, 9)
    assert kv.table(0) == (0, 1) and kv.free_blocks == 4
    assert kv.ensure(0, 16)  # still 2 blocks
    assert kv.table(0) == (0, 1)
    assert kv.ensure(0, 17)  # crosses into a third block
    assert kv.table(0) == (0, 1, 2) and kv.free_blocks == 3
    kv.allocate(1, 24)
    assert kv.free_blocks == 0
    assert not kv.ensure(0, 25)  # pool dry
    kv.release(1)
    assert kv.free_blocks == 3 and kv.ensure(0, 25)
    kv.release(0)
    assert kv.free_blocks == 6


def test_block_pool_default_matches_dense_capacity(tiny_lm):
    cfg, _ = tiny_lm
    kv = BlockKVCache(cfg, max_batch=4, max_seq=48)
    # default sizing: the pool can hold max_batch full-length requests
    assert kv.n_blocks == 4 * kv.blocks_for(48)
    for rid in range(4):
        kv.allocate(rid, 48)
    assert kv.free_blocks == 0


def test_page_out_in_round_trip_exact(tiny_lm):
    cfg, params = tiny_lm
    runner = ModelRunner(cfg, params, max_seq=32, seed=0)
    kv = BlockKVCache(cfg, max_batch=2, max_seq=32,
                      block=BlockCacheConfig(block_size=4))
    from repro.serving.engine import Request

    req = Request(0, _prompt(6), max_new_tokens=4)
    kv.allocate(0, 6)
    kv.cache, pos, last, _ = runner.admit_slot(kv.cache, 0, req)
    want = jax.tree.map(lambda l: np.asarray(l[:, 0]), kv.cache)
    # page out, trash the slot, page back into a *different* slot index,
    # then move it home: the prefix must round-trip bit-exactly
    kv.page_out(0, 0, pos, last)
    kv.cache = jax.tree.map(lambda l: l.at[:, 0].set(-1.0), kv.cache)
    pos2, last2 = kv.page_in(0, 1)
    assert (pos2, last2) == (pos, last)
    got = jax.tree.map(lambda l: np.asarray(l[:, 1]), kv.cache)
    for w, g in zip(jax.tree.leaves(want), jax.tree.leaves(got)):
        # positions < pos are the request's prefix: must match exactly
        np.testing.assert_array_equal(w[:, :pos], g[:, :pos])


def test_page_out_frees_blocks(tiny_lm):
    cfg, params = tiny_lm
    runner = ModelRunner(cfg, params, max_seq=32, seed=0)
    kv = BlockKVCache(cfg, max_batch=1, max_seq=32,
                      block=BlockCacheConfig(block_size=4, n_blocks=4))
    from repro.serving.engine import Request

    kv.allocate(7, 6)
    kv.cache, pos, last, _ = runner.admit_slot(
        kv.cache, 0, Request(7, _prompt(6), max_new_tokens=2)
    )
    held = kv.free_blocks
    kv.page_out(7, 0, pos, last)
    assert kv.is_paged(7) and kv.paged_pos(7) == pos
    assert kv.free_blocks > held  # swap-out relieves pool pressure
    kv.page_in(7, 0)
    assert not kv.is_paged(7) and kv.free_blocks == held


# ---------------------------------------------------------------------------
# Chip farm: routing, scaling, drain/refresh
# ---------------------------------------------------------------------------


def test_farm_round_robin_routing(tiny_lm):
    cfg, params = tiny_lm
    farm = ChipFarm(cfg, params, n_replicas=3, policy="round_robin",
                    max_batch=1, max_seq=32)
    rids = [farm.submit(_prompt(4, lo=k), max_new_tokens=1) for k in range(6)]
    assert [farm.replica_of(r) for r in rids] == [0, 1, 2, 0, 1, 2]
    res = farm.run_until_done()
    assert sorted(r.rid for r in res) == sorted(rids)
    assert all(r.done for r in res)


def test_farm_least_loaded_routing(tiny_lm):
    cfg, params = tiny_lm
    farm = ChipFarm(cfg, params, n_replicas=2, policy="least_loaded",
                    max_batch=1, max_seq=32)
    a = farm.submit(_prompt(4), max_new_tokens=4)
    b = farm.submit(_prompt(4, lo=2), max_new_tokens=4)
    # both replicas loaded 1 each; the third goes to the lowest index
    c = farm.submit(_prompt(4, lo=3), max_new_tokens=1)
    assert {farm.replica_of(a), farm.replica_of(b)} == {0, 1}
    assert farm.replica_of(c) == 0
    assert len(farm.run_until_done()) == 3


def test_farm_rids_disjoint_and_results_merge(tiny_lm):
    cfg, params = tiny_lm
    farm = ChipFarm(cfg, params, n_replicas=2, max_batch=2, max_seq=32)
    rids = [farm.submit(_prompt(5, lo=k), max_new_tokens=2) for k in range(4)]
    assert len(set(rids)) == 4
    res = farm.run_until_done()
    assert [r.rid for r in res] == sorted(rids)


def test_farm_single_replica_matches_engine(tiny_lm):
    cfg, params = tiny_lm
    farm = ChipFarm(cfg, params, n_replicas=1, max_batch=2, max_seq=32, seed=0)
    for p, n in _mixed_workload():
        farm.submit(p, max_new_tokens=n)
    farm_out = [r.generated for r in farm.run_until_done()]
    eng = ServingEngine(cfg, params, max_batch=2, max_seq=32, seed=0)
    for p, n in _mixed_workload():
        eng.submit(p, max_new_tokens=n)
    eng_out = [r.generated for r in eng.run_until_done()]
    assert farm_out == eng_out


def test_farm_drain_stops_admission_not_service(tiny_lm):
    cfg, params = tiny_lm
    farm = ChipFarm(cfg, params, n_replicas=2, max_batch=1, max_seq=32)
    r0 = farm.submit(_prompt(4), max_new_tokens=4)  # lands on replica 0
    farm.drain(0)
    # new traffic avoids the draining replica...
    rids = [farm.submit(_prompt(4, lo=k), max_new_tokens=1) for k in range(3)]
    assert all(farm.replica_of(r) == 1 for r in rids)
    # ...but its in-flight request still runs to completion
    res = {r.rid: r for r in farm.run_until_done()}
    assert res[r0].done and len(res[r0].generated) == 4
    with pytest.raises(ValueError, match="draining"):
        farm.drain(1)
        farm.submit(_prompt(4))
    farm.undrain(0)
    farm.submit(_prompt(4), max_new_tokens=1)
    assert len(farm.run_until_done()) == 5


@pytest.mark.slow
def test_farm_drain_refresh_undrain_cycle(tiny_lm, tmp_path):
    # the lifecycle story: an aged replica is drained, refreshed from a
    # store commit, and undrained — without dropping the other replica's
    # traffic, and serving bit-identically afterwards
    cfg, params = tiny_lm
    dev = DeviceConfig(sigma=0.02, drift_nu=0.05, seed=3)
    d = str(tmp_path / "store")
    seedling = ServingEngine(
        cfg, params, max_batch=1, max_seq=16,
        crossbar=CrossbarMode(enabled=True, device=dev),
    )
    seedling.save_artifacts(d)
    farm = ChipFarm(
        cfg, params, n_replicas=2, max_batch=1, max_seq=16,
        crossbar=CrossbarMode(enabled=True, device=dev), restore_artifacts=d,
    )
    farm.replicas[0].age(3600.0)
    assert farm.uptimes()[0] > 0.0 and farm.uptimes()[1] == 0.0
    farm.drain(0)
    keep = farm.submit(_prompt(4), max_new_tokens=2)  # routed to replica 1
    assert farm.replica_of(keep) == 1
    assert farm.is_idle(0)
    farm.refresh(0, d)  # reprogram into the inactive slot + hot swap
    farm.undrain(0)
    assert farm.uptimes()[0] == 0.0
    back = farm.submit(_prompt(4, lo=2), max_new_tokens=2)
    res = {r.rid: r for r in farm.run_until_done()}
    assert res[keep].done and res[back].done
    # the refreshed replica serves exactly what a fresh restore serves
    ref = ServingEngine(
        cfg, params, max_batch=1, max_seq=16,
        crossbar=CrossbarMode(enabled=True, device=dev), restore_artifacts=d,
    )
    ref.submit(_prompt(4, lo=2), max_new_tokens=2)
    assert ref.run_until_done()[0].generated == res[back].generated


def test_farm_rejects_bad_config(tiny_lm):
    cfg, params = tiny_lm
    with pytest.raises(ValueError, match="n_replicas"):
        ChipFarm(cfg, params, n_replicas=0)
    with pytest.raises(ValueError, match="policy"):
        ChipFarm(cfg, params, n_replicas=1, policy="random")
