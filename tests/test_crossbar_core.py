"""Bit-exactness tests for the crossbar datapath, adaptive ADC, Karatsuba,
and Strassen (paper §III) against an int64 numpy oracle."""
import numpy as np
import jax.numpy as jnp
import pytest
from _propcheck import integers, sweep

from repro.core import adc
from repro.core import crossbar as cb
from repro.core import karatsuba as ka
from repro.core import strassen as stn


SPEC_S = cb.DEFAULT_SPEC
SPEC_U = cb.DEFAULT_SPEC.replace(signed_weights=False)


def _rand(rng, B, K, N, signed):
    x = rng.integers(0, 1 << 16, size=(B, K))
    if signed:
        w = rng.integers(-(1 << 15), 1 << 15, size=(K, N))
    else:
        w = rng.integers(0, 1 << 16, size=(K, N))
    return x, w


@pytest.mark.parametrize("shape", [(3, 128, 16), (2, 300, 8), (5, 17, 5), (1, 1024, 32)])
@pytest.mark.parametrize("signed", [True, False])
def test_crossbar_vmm_matches_oracle(shape, signed):
    rng = np.random.default_rng(sum(shape) + signed)
    B, K, N = shape
    x, w = _rand(rng, B, K, N, signed)
    spec = SPEC_S if signed else SPEC_U
    y = np.asarray(cb.crossbar_vmm(jnp.asarray(x), jnp.asarray(w), spec))
    ref = cb.exact_vmm_reference(x, w, spec)
    np.testing.assert_array_equal(y, ref)


def test_crossbar_width_constants_match_paper():
    """§III: 9-bit column ADC, 39-bit accumulator for 16bx16b over 128 rows."""
    assert SPEC_S.adc_bits == 9
    assert SPEC_S.acc_bits == 39
    assert SPEC_S.n_slices == 8
    assert SPEC_S.n_iters == 16


@pytest.mark.slow
@sweep(
    integers(1, 4),
    integers(1, 200),
    integers(1, 6),
    integers(0, 2**32 - 1),
    examples=25,
)
def test_crossbar_vmm_property(B, K, N, seed):
    rng = np.random.default_rng(seed)
    x, w = _rand(rng, B, K, N, True)
    y = np.asarray(cb.crossbar_vmm(jnp.asarray(x), jnp.asarray(w), SPEC_S))
    ref = cb.exact_vmm_reference(x, w, SPEC_S)
    np.testing.assert_array_equal(y, ref)


# --- adaptive ADC (T2): the paper's "zero impact on accuracy" claim -------

def test_adaptive_exact_guard_is_bit_exact_unsigned():
    rng = np.random.default_rng(7)
    for (B, K, N) in [(4, 128, 16), (2, 384, 8)]:
        x, w = _rand(rng, B, K, N, False)
        tr = adc.make_partial_transform(SPEC_U, adc.EXACT_ADAPTIVE)
        y = np.asarray(cb.crossbar_vmm(jnp.asarray(x), jnp.asarray(w), SPEC_U, partial_transform=tr))
        np.testing.assert_array_equal(y, cb.exact_vmm_reference(x, w, SPEC_U))


@sweep(integers(0, 2**32 - 1), examples=20)
def test_adaptive_safe_guard_within_bound(seed):
    rng = np.random.default_rng(seed)
    x, w = _rand(rng, 4, 128, 16, False)
    cfg = adc.SAFE_ADAPTIVE
    tr = adc.make_partial_transform(SPEC_U, cfg)
    y = np.asarray(cb.crossbar_vmm(jnp.asarray(x), jnp.asarray(w), SPEC_U, partial_transform=tr)).astype(np.int64)
    ref = cb.exact_vmm_reference(x, w, SPEC_U)
    bound = adc.lsb_error_bound(SPEC_U, cfg, 128)
    assert bound < 1.0  # guard=4 keeps worst case under one output ULP
    assert np.abs(y - ref).max() <= 1


def test_adaptive_signed_lsb_rounding_is_exact_in_practice():
    rng = np.random.default_rng(11)
    x, w = _rand(rng, 8, 128, 32, True)
    tr = adc.make_partial_transform(SPEC_S, adc.SAFE_ADAPTIVE)
    y = np.asarray(cb.crossbar_vmm(jnp.asarray(x), jnp.asarray(w), SPEC_S, partial_transform=tr))
    np.testing.assert_array_equal(y, cb.exact_vmm_reference(x, w, SPEC_S))


def test_fig5_schedule_shape():
    """Fig 5: relevant bits per (column-slice, iteration) fall off at both
    ends; full mode resolves all 9 bits everywhere."""
    full = adc.adaptive_schedule(SPEC_U, adc.FULL_ADC)
    assert (full == 9).all()
    sched = adc.adaptive_schedule(SPEC_U, adc.ADCConfig())
    assert sched.mean() < 7.0  # substantial SAR-work reduction
    assert sched[0, 0] <= 1  # lowest partial: below the output window
    assert sched[-1, -1] <= 1  # highest partial: clamp-detect only
    assert sched.max() == 9


# --- Karatsuba (T3) --------------------------------------------------------

@pytest.mark.parametrize("levels", [1, 2])
@pytest.mark.parametrize("shape", [(3, 128, 16), (2, 300, 8)])
def test_karatsuba_bit_exact(levels, shape):
    rng = np.random.default_rng(levels * 100 + sum(shape))
    B, K, N = shape
    x, w = _rand(rng, B, K, N, True)
    y = np.asarray(ka.karatsuba_vmm(jnp.asarray(x), jnp.asarray(w), SPEC_S, levels=levels))
    np.testing.assert_array_equal(y, cb.exact_vmm_reference(x, w, SPEC_S))


def test_karatsuba_cost_matches_paper():
    c0, c1, c2 = ka.karatsuba_cost(0), ka.karatsuba_cost(1), ka.karatsuba_cost(2)
    assert c0.adc_slots == 128 and c0.iterations == 16
    # §III.A.1: A,B on 4 slices x 8 iters in parallel; C on 5 x 9 => -15%
    assert c1.adc_slots == 109 and c1.iterations == 17
    assert abs(c1.adc_reduction_vs_baseline - 0.148) < 0.01
    # §III.C: two levels -> 28% fewer ADC slots, 14 iterations
    assert c2.adc_slots == 92 and c2.iterations == 14
    assert abs(c2.adc_reduction_vs_baseline - 0.281) < 0.01


def test_karatsuba_cost_asymmetric_spec():
    """A (hi x hi) and B (lo x lo) are distinct products and must be costed
    separately: for a 16b x 8b spec the split is h = 4, so A is 12b x 4b
    (2 slices x 12 iters), B is 4b x 4b (2 x 4) and C is 13b x 5b (3 x 13)
    => 24 + 8 + 39 = 71 slots, max(12, 4) + 13 = 25 iterations."""
    spec = cb.DEFAULT_SPEC.replace(input_bits=16, weight_bits=8)
    c1 = ka.karatsuba_cost(1, spec)
    assert c1.adc_slots == 71
    assert c1.iterations == 25


# --- Strassen (T4) ---------------------------------------------------------

@pytest.mark.parametrize("levels", [1, 2])
@pytest.mark.parametrize("shape", [(6, 128, 10), (5, 130, 9), (7, 63, 3)])
def test_strassen_bit_exact(levels, shape):
    rng = np.random.default_rng(levels * 10 + sum(shape))
    M, K, N = shape
    x = rng.integers(0, 1 << 16, size=(M, K))
    w = rng.integers(-(1 << 15), 1 << 15, size=(K, N))
    y = np.asarray(stn.strassen_matmul(jnp.asarray(x), jnp.asarray(w), SPEC_S, levels=levels))
    np.testing.assert_array_equal(y, cb.exact_vmm_reference(x, w, SPEC_S))


def test_strassen_cost_both_accountings():
    paper = stn.strassen_cost(256, 256, 256, levels=1, widening="paper")
    exact = stn.strassen_cost(256, 256, 256, levels=1, widening="exact")
    base = stn.strassen_cost(256, 256, 256, levels=0)
    assert paper.adc_conversions / base.adc_conversions == pytest.approx(7 / 8)
    # honest accounting: operand widening makes Strassen a net conversion loss
    assert exact.adc_conversions > base.adc_conversions
    assert paper.imas_used == 7  # frees 1 IMA in 8 (Fig 8)


def test_strassen_stats_iterations_follow_widening():
    """The iteration charge must match the conversion accounting: "paper"
    mode reuses the 16-bit datapath (no extra iteration), only "exact"
    widening pays +1 iteration per level for its extra slice."""
    base_iters = cb.DEFAULT_SPEC.n_iters
    for levels in (1, 2):
        paper = stn.strassen_stats(64, 256, 64, levels=levels)
        exact = stn.strassen_stats(64, 256, 64, levels=levels, widening="exact")
        assert paper.iterations == base_iters
        assert exact.iterations == base_iters + levels
        cost = stn.strassen_cost(64, 256, 64, levels=levels, widening="exact")
        assert exact.conversions == cost.adc_conversions


# --- fixed point helpers ----------------------------------------------------

@sweep(integers(0, 2**16 - 1), examples=50)
def test_bitplane_roundtrip(v):
    from repro.core import fixedpoint as fxp

    arr = jnp.asarray([v])
    assert int(fxp.from_bit_planes(fxp.bit_planes(arr, 16))[0]) == v
    assert int(fxp.from_cell_slices(fxp.cell_slices(arr, 16, 2), 2)[0]) == v
    lo, hi = fxp.split_halves(arr, 16)
    assert int(lo[0]) + (int(hi[0]) << 8) == v
