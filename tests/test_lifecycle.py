"""Drift-aware chip lifecycle (ISSUE 7): aging artifacts, health
monitoring, free digital compensation, and zero-downtime hot-swap.

The contract under test:
  * aging is a pure view of the same chip — a drift-free config ages to a
    bit-identical chip (only the service clock moves), a drifting one shows
    strictly growing error vs its immortal digital reference, and time
    only moves forward (rejuvenation = reprogramming);
  * the health monitor reads drift error without perturbing the chip, and
    flags exactly the layers over budget;
  * refitting the digital ``comp_scale`` recovers >= 50% of the aged error
    with zero reprogramming (in practice near-total: retention drift is
    almost pure common-mode conductance scale);
  * the double-buffered store (slot A/B + atomic ACTIVE pointer) and
    ``ServingEngine.hot_swap`` refresh a serving engine *between decode
    steps*: a mid-run swap onto a reprogrammed chip generates the same
    tokens as an uninterrupted run, and the store round-trips
    ``t_service_s`` and the programming ``DeviceConfig``.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.device import (
    DeviceConfig,
    age_artifact,
    artifact_at_time,
    drift_time_factor,
    effective_drift_nu,
    fit_compensation,
    health_check,
    layer_health,
    program_layer,
    program_model,
    programmed_linear,
    programmed_matmul,
)
from repro.device.health import compensate_model, digital_twin

pytestmark = pytest.mark.lifecycle

DRIFT_DEV = DeviceConfig(sigma=0.02, drift_nu=0.05, seed=7)


def _data(rng, B, K, N):
    x = jnp.asarray(np.abs(rng.normal(size=(B, K))).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(K, N)).astype(np.float32) * 0.1)
    return x, w


# ---------------------------------------------------------------------------
# aging semantics
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_zero_drift_aging_is_bit_identical_noop():
    """A drift-free chip ages to the same arrays — only the clock moves."""
    rng = np.random.default_rng(0)
    x, w = _data(rng, 4, 128, 16)
    for dev in (None, DeviceConfig(sigma=0.05, seed=1)):
        art = program_layer(w, device=dev)
        aged = art.age(1e7)
        assert aged.t_service_s == 1e7
        assert art.t_service_s == 0.0  # aging never mutates the original
        np.testing.assert_array_equal(np.asarray(art.w_codes), np.asarray(aged.w_codes))
        if art.g_eff is not None:
            np.testing.assert_array_equal(np.asarray(art.g_eff), np.asarray(aged.g_eff))
        np.testing.assert_array_equal(
            np.asarray(programmed_linear(x, art)),
            np.asarray(programmed_linear(x, aged)),
        )


def test_aged_chip_error_grows_monotonically():
    """Acceptance: drift_nu>0, t_service_s>0 shows monotone MSE growth vs
    the frozen digital reference — the same chip, no reprogramming."""
    rng = np.random.default_rng(1)
    x, w = _data(rng, 4, 128, 16)
    art = program_layer(w, device=DRIFT_DEV)
    y_ref = programmed_matmul(x, digital_twin(art), interpret=True)

    def mse(a):
        return float(jnp.mean((programmed_matmul(x, a, interpret=True) - y_ref) ** 2))

    errs = [mse(art.at_time(t)) for t in (1e2, 1e4, 1e6, 1e8)]
    assert all(a < b for a, b in zip(errs, errs[1:])), errs


def test_time_only_moves_forward():
    rng = np.random.default_rng(2)
    _, w = _data(rng, 1, 64, 8)
    art = program_layer(w, device=DRIFT_DEV).age(100.0)
    with pytest.raises(ValueError):
        art.at_time(50.0)
    with pytest.raises(ValueError):
        age_artifact(art, -1.0)


def test_incremental_aging_matches_absolute():
    """age(a).age(b) lands at the same service time as at_time(a+b), and
    the cells agree to one write-grid re-quantization step."""
    rng = np.random.default_rng(3)
    _, w = _data(rng, 1, 64, 8)
    art = program_layer(w, device=DRIFT_DEV)
    two = art.age(1e3).age(9e3)
    one = artifact_at_time(art, 1e4)
    assert two.t_service_s == one.t_service_s == 1e4
    from repro.device import GEFF_FRAC_BITS

    step = 2.0 ** -GEFF_FRAC_BITS
    assert float(jnp.max(jnp.abs(two.g_eff - one.g_eff))) <= step + 1e-7


def test_aged_stacked_artifact_slices_like_fresh():
    """Aging commutes with stacking: at_time on the stacked artifact equals
    at_time per slice (the elementwise decay has no cross-slice terms)."""
    rng = np.random.default_rng(4)
    ws = jnp.asarray(rng.normal(size=(3, 64, 8)).astype(np.float32))
    stacked = program_layer(ws, device=DRIFT_DEV).at_time(1e6)
    for i in range(3):
        direct = program_layer(ws[i], device=DRIFT_DEV).at_time(1e6)
        np.testing.assert_array_equal(
            np.asarray(stacked.layer(i).g_eff), np.asarray(direct.g_eff)
        )


# ---------------------------------------------------------------------------
# health monitor
# ---------------------------------------------------------------------------

def test_health_monitor_flags_only_over_budget_layers():
    rng = np.random.default_rng(5)
    _, w = _data(rng, 1, 128, 16)
    params = {"wq": w}
    prog = program_model(params, device=DRIFT_DEV)

    fresh = health_check(prog, budget=1e9)  # absurd budget: nothing flags
    assert fresh.healthy and fresh.flagged == ()

    aged = health_check(prog.at_time(1e8), budget=1e-6)  # everything flags
    assert not aged.healthy and aged.flagged == ("wq",)
    assert aged.worst > fresh.worst


def test_health_probe_does_not_perturb_the_chip():
    rng = np.random.default_rng(6)
    _, w = _data(rng, 1, 64, 8)
    art = program_layer(w, device=DRIFT_DEV)
    before = np.asarray(art.g_eff).copy()
    layer_health("wq", art)
    np.testing.assert_array_equal(before, np.asarray(art.g_eff))


def test_ideal_chip_probes_error_free():
    rng = np.random.default_rng(7)
    _, w = _data(rng, 1, 64, 8)
    h = layer_health("wq", program_layer(w))
    assert h.rel_err == 0.0 and h.mse == 0.0


# ---------------------------------------------------------------------------
# free digital compensation
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_compensation_recovers_at_least_half_the_aged_mse():
    """Acceptance: digital scale compensation recovers >= 50% of the aged
    MSE with zero reprogramming (the cells are untouched)."""
    rng = np.random.default_rng(8)
    x, w = _data(rng, 8, 128, 16)
    art = program_layer(w, device=DRIFT_DEV)
    aged = art.at_time(1e7)
    comp = fit_compensation(aged)

    np.testing.assert_array_equal(np.asarray(aged.g_eff), np.asarray(comp.g_eff))
    y_ref = programmed_matmul(x, digital_twin(art), interpret=True)

    def mse(a):
        return float(jnp.mean((programmed_matmul(x, a, interpret=True) - y_ref) ** 2))

    m_aged, m_comp = mse(aged), mse(comp)
    assert m_comp <= 0.5 * m_aged, (m_aged, m_comp)


def test_unit_comp_scale_is_bit_exact_noop():
    """comp_scale of exactly 1.0 multiplies out bit-identically, so fresh
    chips (comp_scale=None) and explicitly-unit-compensated chips serve the
    same outputs."""
    rng = np.random.default_rng(9)
    x, w = _data(rng, 4, 64, 8)
    art = program_layer(w, device=DRIFT_DEV)
    unit = dataclasses.replace(art, comp_scale=jnp.ones(8, jnp.float32))
    np.testing.assert_array_equal(
        np.asarray(programmed_linear(x, art)),
        np.asarray(programmed_linear(x, unit)),
    )


# ---------------------------------------------------------------------------
# temperature knob (Arrhenius drift scaling)
# ---------------------------------------------------------------------------

def test_reference_temperature_and_zero_ea_are_exact_noops():
    base = DeviceConfig(drift_nu=0.05)
    assert effective_drift_nu(base) == 0.05
    assert effective_drift_nu(base.replace(temp_k=300.0, drift_ea_ev=0.4)) == 0.05
    assert effective_drift_nu(base.replace(temp_k=360.0, drift_ea_ev=0.0)) == 0.05


def test_hotter_chips_drift_faster():
    base = DeviceConfig(drift_nu=0.05, drift_ea_ev=0.3)
    hot, cold = base.replace(temp_k=360.0), base.replace(temp_k=250.0)
    assert effective_drift_nu(hot) > 0.05 > effective_drift_nu(cold)
    # more decay (smaller factor) at higher T over the same interval
    assert drift_time_factor(hot, 0.0, 1e6) < drift_time_factor(base, 0.0, 1e6)
    assert drift_time_factor(cold, 0.0, 1e6) > drift_time_factor(base, 0.0, 1e6)


def test_temperature_scales_aged_error():
    rng = np.random.default_rng(10)
    x, w = _data(rng, 4, 64, 8)
    y_ref = programmed_matmul(x, program_layer(w), interpret=True)

    def mse_at(T):
        dev = DRIFT_DEV.replace(temp_k=T, drift_ea_ev=0.3)
        aged = program_layer(w, device=dev).at_time(1e6)
        return float(jnp.mean((programmed_matmul(x, aged, interpret=True) - y_ref) ** 2))

    assert mse_at(300.0) < mse_at(350.0)


# ---------------------------------------------------------------------------
# chip-to-chip spread
# ---------------------------------------------------------------------------

def test_chip_zero_is_bit_compatible():
    """chip=0 (the default) folds nothing into the stage keys: spread-off
    programming is bit-identical to pre-lifecycle artifacts."""
    rng = np.random.default_rng(11)
    ws = jnp.asarray(rng.normal(size=(2, 64, 8)).astype(np.float32))
    plain = program_layer(ws, device=DRIFT_DEV)
    spread0 = program_layer(ws, device=DRIFT_DEV, chips=(0, 0))
    np.testing.assert_array_equal(np.asarray(plain.g_eff), np.asarray(spread0.g_eff))


def test_chip_spread_decorrelates_identical_slabs():
    """The same weight slab on two chip identities draws different device
    perturbations — the fleet-realism knob for EP meshes."""
    rng = np.random.default_rng(12)
    w = rng.normal(size=(64, 8)).astype(np.float32)
    ws = jnp.asarray(np.stack([w, w]))  # identical slabs
    same = program_layer(ws, device=DRIFT_DEV)
    spread = program_layer(ws, device=DRIFT_DEV, chips=(1, 2))
    # without spread, identical slabs program to identical cells
    np.testing.assert_array_equal(
        np.asarray(same.g_eff[0]), np.asarray(same.g_eff[1])
    )
    assert not np.array_equal(np.asarray(spread.g_eff[0]), np.asarray(spread.g_eff[1]))
    # per-slice equivalence: slice i == direct programming on chip i
    for i, c in enumerate((1, 2)):
        direct = program_layer(
            jnp.asarray(w), device=DRIFT_DEV.replace(chip=c)
        )
        np.testing.assert_array_equal(
            np.asarray(spread.g_eff[i]), np.asarray(direct.g_eff)
        )
    # stacked aux is normalized to the base device (stackable treedef)
    assert spread.device == DRIFT_DEV


def test_chips_length_mismatch_raises():
    rng = np.random.default_rng(13)
    ws = jnp.asarray(rng.normal(size=(3, 32, 8)).astype(np.float32))
    with pytest.raises(ValueError):
        program_layer(ws, device=DRIFT_DEV, chips=(1, 2))
    with pytest.raises(ValueError):
        program_layer(ws, device=None, chips=(1, 2, 3))


def test_expert_chips_spread_moe_banks():
    """program_model(expert_chips=) varies chip identity along the expert
    axis of 4-D banks and leaves 2-D/3-D leaves on the base chip."""
    rng = np.random.default_rng(14)
    w_e = rng.normal(size=(32, 8)).astype(np.float32)
    params = {
        "stage0": {
            "b0": {
                "ffn": {"wi": jnp.asarray(np.stack([np.stack([w_e, w_e])]))},  # (1, 2, K, N)
                "mixer": {"wq": jnp.asarray(rng.normal(size=(32, 32)).astype(np.float32))},
            }
        }
    }
    plain = program_model(params, device=DRIFT_DEV)
    spread = program_model(params, device=DRIFT_DEV, expert_chips=(1, 2))
    wi_p = plain.by_name["stage0/b0/ffn/wi"]
    wi_s = spread.by_name["stage0/b0/ffn/wi"]
    np.testing.assert_array_equal(np.asarray(wi_p.g_eff[0, 0]), np.asarray(wi_p.g_eff[0, 1]))
    assert not np.array_equal(np.asarray(wi_s.g_eff[0, 0]), np.asarray(wi_s.g_eff[0, 1]))
    # 2-D leaves are untouched by the expert spread
    np.testing.assert_array_equal(
        np.asarray(plain.by_name["stage0/b0/mixer/wq"].g_eff),
        np.asarray(spread.by_name["stage0/b0/mixer/wq"].g_eff),
    )


# ---------------------------------------------------------------------------
# double-buffered store + service-time round trip
# ---------------------------------------------------------------------------

def test_store_round_trips_service_time_and_device(tmp_path):
    """Acceptance: restore_programmed of an aged-then-saved chip round-trips
    t_service_s (and the programming DeviceConfig) — the restored chip is
    the aged chip, artifacts_equal including lifecycle state."""
    from repro.checkpoint import restore_programmed, save_programmed
    from repro.device.programmed import ProgrammedModel, artifacts_equal

    rng = np.random.default_rng(15)
    _, w = _data(rng, 1, 64, 8)
    aged = program_layer(w, device=DRIFT_DEV).at_time(12345.5)
    comp = fit_compensation(aged)
    save_programmed(str(tmp_path), ProgrammedModel({"wq": comp}))
    back = restore_programmed(str(tmp_path)).by_name["wq"]
    assert back.t_service_s == 12345.5
    assert back.device == DRIFT_DEV
    assert back.comp_scale is not None
    assert artifacts_equal(back, comp)


def test_slot_swap_is_atomic_and_restore_follows_active(tmp_path):
    from repro.checkpoint import (
        active_slot,
        restore_programmed,
        save_programmed,
        swap_active,
    )
    from repro.device.programmed import ProgrammedModel, artifacts_equal

    rng = np.random.default_rng(16)
    _, w = _data(rng, 1, 64, 8)
    a = program_layer(w, device=DRIFT_DEV)
    b = a.at_time(1e6)
    d = str(tmp_path)

    # swapping to an empty slot refuses — the pointer can never dangle
    with pytest.raises(FileNotFoundError):
        swap_active(d, "B")
    assert active_slot(d) is None

    save_programmed(d, ProgrammedModel({"wq": a}), slot="A")
    swap_active(d, "A")
    assert active_slot(d) == "A"
    assert artifacts_equal(restore_programmed(d).by_name["wq"], a)

    # writing the inactive slot does not disturb the active chip
    save_programmed(d, ProgrammedModel({"wq": b}), slot="B")
    assert artifacts_equal(restore_programmed(d).by_name["wq"], a)
    swap_active(d, "B")
    assert artifacts_equal(restore_programmed(d).by_name["wq"], b)
    # a forced slot read overrides the pointer (rollback inspection)
    assert artifacts_equal(restore_programmed(d, slot="A").by_name["wq"], a)
    with pytest.raises(ValueError):
        swap_active(d, "C")


# ---------------------------------------------------------------------------
# serving-engine lifecycle (tiny LM, end to end)
# ---------------------------------------------------------------------------

def _tiny_engine(params, cfg, dev, **kw):
    from repro.models.layers import CrossbarMode
    from repro.serving.engine import ServingEngine

    return ServingEngine(
        cfg, params, max_batch=1, max_seq=16,
        crossbar=CrossbarMode(enabled=True, device=dev), **kw,
    )


@pytest.fixture(scope="module")
def tiny_lm():
    from benchmarks.noise_sweep import tiny_lm_config
    from repro.models import model as M

    cfg = tiny_lm_config()
    params, _ = M.init_model(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    return cfg, params


@pytest.mark.slow
def test_engine_lifecycle_monitor_compensate_refresh(tiny_lm, tmp_path):
    """The full state machine on a serving engine: age degrades health,
    compensate recovers it (no reprogramming), refresh through the
    double-buffered store returns to a bit-identical fresh chip."""
    from repro.device.programmed import artifacts_equal

    cfg, params = tiny_lm
    dev = DeviceConfig(sigma=0.02, drift_nu=0.05, seed=3)
    eng = _tiny_engine(params, cfg, dev)
    assert eng.uptime_s == 0.0

    eng.age(1e7)
    assert eng.uptime_s == 1e7
    aged_health = eng.health_check()
    assert aged_health.worst > 0

    eng.compensate()
    assert eng.health_check().worst < aged_health.worst
    assert eng.uptime_s == 1e7  # compensation is not a refresh

    slot = eng.refresh(str(tmp_path))
    assert slot == "A" and eng.uptime_s == 0.0
    fresh = _tiny_engine(params, cfg, dev)
    a1, a2 = eng.crossbar.programmed.by_name, fresh.crossbar.programmed.by_name
    assert set(a1) == set(a2)
    for n in a1:
        assert artifacts_equal(a1[n], a2[n]), n
    # the next refresh lands in the other slot
    assert eng.refresh(str(tmp_path)) == "B"


@pytest.mark.slow
def test_engine_hot_swap_mid_run_yields_uninterrupted_tokens(tiny_lm, tmp_path):
    """Acceptance: hot_swap mid-run_until_done yields the same tokens as an
    uninterrupted fresh-chip run — the swap rebinds between decode steps
    without touching KV caches or slot state, and the refreshed chip is
    bit-identical to the one that started the run."""
    cfg, params = tiny_lm
    dev = DeviceConfig(sigma=0.02, drift_nu=0.05, seed=3)
    prompt = np.array([1, 2, 3], np.int32)

    ref = _tiny_engine(params, cfg, dev)
    ref.submit(prompt, max_new_tokens=5)
    out_ref = ref.run_until_done()[0].generated

    eng = _tiny_engine(params, cfg, dev)
    eng.submit(prompt, max_new_tokens=5)
    eng.step()  # admit + first decode
    eng.step()
    eng.refresh(str(tmp_path))  # reprogram -> inactive slot -> swap -> rebind
    out = eng.run_until_done()[0].generated
    assert out == out_ref and len(out) == 5


def test_engine_hot_swap_validates_the_store(tiny_lm, tmp_path):
    """A store from a different model fails hot_swap loudly — silent
    degradation to per-call programming is the failure mode the name-keyed
    binding layer exists to prevent."""
    from repro.checkpoint import save_programmed
    from repro.device.programmed import ProgrammedModel

    cfg, params = tiny_lm
    dev = DeviceConfig(sigma=0.02, drift_nu=0.05, seed=3)
    eng = _tiny_engine(params, cfg, dev)
    rng = np.random.default_rng(17)
    stranger = program_layer(jnp.asarray(rng.normal(size=(8, 8)).astype(np.float32)))
    save_programmed(str(tmp_path), ProgrammedModel({"nope": stranger}))
    with pytest.raises(ValueError, match="does not match"):
        eng.hot_swap(str(tmp_path))


def test_engine_restart_restores_aged_chip(tiny_lm, tmp_path):
    """save_artifacts of an aged engine + restore_artifacts restart resumes
    at the same service time with the same cells (t_service_s round-trips
    through the store, engine-level)."""
    from repro.device.programmed import artifacts_equal

    cfg, params = tiny_lm
    dev = DeviceConfig(sigma=0.02, drift_nu=0.05, seed=3)
    eng = _tiny_engine(params, cfg, dev)
    eng.age(5e5)
    eng.save_artifacts(str(tmp_path))

    eng2 = _tiny_engine(params, cfg, dev, restore_artifacts=str(tmp_path))
    assert eng2.uptime_s == 5e5
    a1, a2 = eng.crossbar.programmed.by_name, eng2.crossbar.programmed.by_name
    for n in a1:
        assert artifacts_equal(a1[n], a2[n]), n
