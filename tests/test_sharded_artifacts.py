"""Per-rank artifact sharding: programmed crossbar serving under shard_map
EP/TP (ISSUE 5 tentpole).

The ``shard_map`` expert-/tensor-parallel paths were the last place the
model fell back to plain XLA matmul under a ProgrammedModel (the old
``note_crossbar_gap`` fallbacks).  These tests pin the fix:

* artifacts shard with the weights they shadow — ``artifact_shard_specs``
  derives every array leaf's PartitionSpec from the weight's, and
  ``local_artifact`` materializes one rank's slice (repair tables
  re-indexed to local columns);
* a shard_map expert-parallel MoE forward on an 8-device host mesh serves
  programmed with **zero** recorded crossbar gaps, **bit-identical** to the
  single-device programmed path (the acceptance criterion — on the seed
  state the gap fallbacks fire and this fails);
* the sharded chip survives a save -> restore -> serve round trip, with
  the deployment sharding recorded in the store and re-applied on restore;
* the TP-sharded paths (alltoall dispatch, expert_tp layout) serve from
  rank-local rows of the global chip as partial sums accumulated by the
  existing collectives — the paper's inter-tile digital reduction at
  cluster scale.

Mesh tests run in subprocesses with ``--xla_force_host_platform_device_count
=8`` (same pattern as tests/test_distributed.py): the main test process
must keep 1 device for the rest of the suite.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

pytestmark = pytest.mark.dist


def _run(code: str) -> dict:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.pathsep.join([os.path.join(REPO, "src"), REPO])
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert out.returncode == 0, out.stderr[-4000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


# The shared preamble for the subprocess tests: a tiny MoE LM (8 experts,
# top-1 routing so gate weights are exactly 1.0, well-separated router
# logits so per-rank quantization cannot flip a routing decision, a shared
# expert, tied LM head) fully programmed — the whole-model chip.
_SETUP = """
    import dataclasses as dc, json
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import Mesh
    from benchmarks.noise_sweep import tiny_moe_lm_config
    from repro.models import model as M
    from repro.models import layers as L
    from repro.models.layers import use_mesh, layout_overrides
    from repro.device import DeviceConfig, program_model
    import repro.device.programmed as prog

    def make(layout="ep_only", dispatch="allreduce"):
        cfg = dc.replace(
            tiny_moe_lm_config(), moe_experts=8, moe_top_k=1,
            moe_capacity_factor=1000.0, moe_shared_experts=1,
            layout=layout, moe_dispatch=dispatch,
        )
        params, axes = M.init_model(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
        ffn = params["stage0"]["b0"]["ffn"]
        ffn["router"] = ffn["router"] * 100.0  # well-separated logits
        tokens = jnp.asarray(
            np.random.default_rng(0).integers(0, cfg.vocab_size, size=(2, 8)))
        return cfg, params, axes, tokens

    def forward_fn(cfg, pm, mode):
        def fwd(p, t):
            with L.crossbar_mode(mode), pm.bind():
                return M.forward(p, cfg, t)
        return fwd
"""


@pytest.mark.slow
def test_ep_moe_programmed_bit_identical_and_zero_gaps():
    """Acceptance: a shard_map EP MoE forward on an 8-device host mesh
    serves programmed — zero crossbar misses/gaps under strict, the full
    emitted artifact name set consumed — bit-identical to the single-device
    programmed path, with a *noisy* chip (fixed fault/variation draw) and
    spare-column repair active.  On the seed state the EP body falls back
    to digital einsums (note_crossbar_gap) and this fails both ways:
    misses are recorded (strict raises) and the logits differ grossly."""
    res = _run(_SETUP + """
    cfg, params, axes, tokens = make(layout="ep_only")
    dev = DeviceConfig(sigma=0.05, p_stuck_on=2e-3, p_stuck_off=2e-3,
                       write_verify_iters=2, spare_cols=2, seed=3)
    pm = program_model(params, device=dev, tie_lm_head=True)
    mode = L.CrossbarMode(enabled=True, fast=True, device=dev, programmed=pm,
                          strict=True)

    L.reset_crossbar_misses(); prog.reset_consumed_artifact_names()
    y0 = np.asarray(jax.jit(forward_fn(cfg, pm, mode))(params, tokens))
    pm.verify_consumed()
    single_misses = L.crossbar_misses()

    mesh = Mesh(np.array(jax.devices()).reshape(1, 8), ("data", "model"))
    L.reset_crossbar_misses(); prog.reset_consumed_artifact_names()
    with use_mesh(mesh, layout_overrides(cfg)), mesh:
        y1 = np.asarray(jax.jit(forward_fn(cfg, pm, mode))(params, tokens))
    pm.verify_consumed()
    mesh_misses = L.crossbar_misses()

    print(json.dumps({
        "single_misses": list(single_misses),
        "mesh_misses": list(mesh_misses),
        "bit_identical": bool(np.array_equal(y0, y1)),
        "max_abs_diff": float(np.max(np.abs(y0 - y1))),
        "n_compiled": pm.n_compiled,
    }))
    """)
    assert res["single_misses"] == []
    assert res["mesh_misses"] == []
    assert res["n_compiled"] == 12  # 4 attn + router + 3 expert banks + 3 shared + tied head
    assert res["bit_identical"], res["max_abs_diff"]


@pytest.mark.slow
def test_ep_sharded_store_round_trip_serves_bit_identical(tmp_path):
    """save -> restore(mesh) -> serve: the sharded chip round-trips through
    the artifact store — recorded PartitionSpecs re-place every shard, the
    restored arrays are bit-equal, and the restored mesh forward matches
    the original bit-for-bit."""
    res = _run(_SETUP + f"""
    from repro.checkpoint import restore_programmed, save_programmed
    from repro.device.programmed import artifacts_equal, shard_artifacts

    cfg, params, axes, tokens = make(layout="ep_only")
    dev = DeviceConfig(sigma=0.05, p_stuck_on=2e-3, p_stuck_off=2e-3,
                       write_verify_iters=2, spare_cols=2, seed=3)
    pm = program_model(params, device=dev, tie_lm_head=True)
    mode = L.CrossbarMode(enabled=True, fast=True, device=dev, programmed=pm,
                          strict=True)
    mesh = Mesh(np.array(jax.devices()).reshape(1, 8), ("data", "model"))
    e_axes = jax.tree_util.tree_flatten_with_path(
        axes, is_leaf=lambda x: isinstance(x, tuple))[0]
    from repro.device.programmed import join_path
    from repro.models.layers import pspec
    with use_mesh(mesh, layout_overrides(cfg)):
        specs = {{join_path(p): pspec(a, mesh) for p, a in e_axes}}
    pm_sh = shard_artifacts(pm, mesh, specs)
    wi = pm_sh.by_name["stage0/b0/ffn/wi"]
    sharded_before = str(wi.g_eff.sharding.spec)

    with use_mesh(mesh, layout_overrides(cfg)), mesh:
        y0 = np.asarray(jax.jit(forward_fn(cfg, pm_sh, mode))(params, tokens))

    save_programmed({str(tmp_path)!r}, pm_sh)
    back = restore_programmed({str(tmp_path)!r}, mesh=mesh)
    equal = set(back.by_name) == set(pm_sh.by_name) and all(
        artifacts_equal(pm_sh.by_name[n], back.by_name[n]) for n in pm_sh.by_name)
    restored_spec = str(back.by_name["stage0/b0/ffn/wi"].g_eff.sharding.spec)

    L.reset_crossbar_misses()
    with use_mesh(mesh, layout_overrides(cfg)), mesh:
        y1 = np.asarray(jax.jit(forward_fn(cfg, back, mode))(params, tokens))
    print(json.dumps({{
        "store_equal": bool(equal),
        "sharded_before": sharded_before,
        "restored_spec": restored_spec,
        "bit_identical": bool(np.array_equal(y0, y1)),
        "misses": list(L.crossbar_misses()),
    }}))
    """)
    assert res["store_equal"]
    assert "model" in res["sharded_before"]
    assert res["restored_spec"] == res["sharded_before"]
    assert res["misses"] == []
    assert res["bit_identical"]


@pytest.mark.slow
def test_alltoall_ep_programmed_zero_gaps():
    """GShard-style alltoall EP serves programmed: zero misses, the full
    name set consumed, outputs at per-rank-quantization tolerance of the
    single-device programmed path (each rank quantizes its own sequence
    shard, so bit-identity is not expected — the EP test above pins that)."""
    res = _run(_SETUP + """
    cfg, params, axes, tokens = make(layout="ep_only", dispatch="alltoall")
    pm = program_model(params, tie_lm_head=True)  # ideal chip
    mode = L.CrossbarMode(enabled=True, fast=True, programmed=pm, strict=True)

    y0 = np.asarray(jax.jit(forward_fn(cfg, pm, mode))(params, tokens))
    mesh = Mesh(np.array(jax.devices()).reshape(1, 8), ("data", "model"))
    L.reset_crossbar_misses(); prog.reset_consumed_artifact_names()
    with use_mesh(mesh, layout_overrides(cfg)), mesh:
        y1 = np.asarray(jax.jit(forward_fn(cfg, pm, mode))(params, tokens))
    pm.verify_consumed()
    rel = float(np.max(np.abs(y0 - y1)) / (np.max(np.abs(y0)) + 1e-9))
    print(json.dumps({"misses": list(L.crossbar_misses()), "rel": rel}))
    """)
    assert res["misses"] == []
    assert res["rel"] < 5e-3


@pytest.mark.slow
def test_expert_tp_programmed_partial_sums_zero_gaps():
    """expert_tp (weights-stationary serving): every projection contracts
    over a mesh-sharded dim, so ranks hold rows of the global chip and
    serve *partial sums* that the existing psum/psum_scatter collectives
    accumulate digitally.  Zero misses, full consumption, outputs at
    per-rank-quantization tolerance of the single-device programmed path."""
    res = _run(_SETUP + """
    cfg, params, axes, tokens = make(layout="expert_tp")
    pm = program_model(params, tie_lm_head=True)  # ideal chip
    mode = L.CrossbarMode(enabled=True, fast=True, programmed=pm, strict=True)

    y0 = np.asarray(jax.jit(forward_fn(cfg, pm, mode))(params, tokens))
    mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
    L.reset_crossbar_misses(); prog.reset_consumed_artifact_names()
    with use_mesh(mesh, layout_overrides(cfg)), mesh:
        y1 = np.asarray(jax.jit(forward_fn(cfg, pm, mode))(params, tokens))
    consumed = prog.consumed_artifact_names()
    rel = float(np.max(np.abs(y0 - y1)) / (np.max(np.abs(y0)) + 1e-9))
    print(json.dumps({
        "misses": list(L.crossbar_misses()),
        "rel": rel,
        "tp_consumed": sorted(
            n for n in consumed if n.startswith("stage0/b0/ffn/")),
    }))
    """)
    assert res["misses"] == []
    # the TP body consumed the router and all expert banks by name
    for n in ("router", "wi", "wg", "wo"):
        assert f"stage0/b0/ffn/{n}" in res["tp_consumed"]
    assert res["rel"] < 5e-3


@pytest.mark.slow
def test_engine_mesh_serving_matches_single_device(tmp_path):
    """ServingEngine(mesh=, param_axes=): generates the same tokens as the
    meshless engine from the same noisy chip, artifacts are placed on the
    mesh with the weights' specs, and a save -> restore(mesh) -> serve
    round trip preserves both the chip and its placement."""
    res = _run(_SETUP + f"""
    from repro.models.layers import CrossbarMode
    from repro.serving.engine import ServingEngine
    from repro.device.programmed import artifacts_equal

    cfg, params, axes, tokens = make(layout="ep_only")
    dev = DeviceConfig(sigma=0.05, p_stuck_on=1e-3, p_stuck_off=1e-3,
                       write_verify_iters=2)
    prompt = np.array([1, 2, 3], np.int32)

    eng = ServingEngine(cfg, params, max_batch=1, max_seq=16,
                        crossbar=CrossbarMode(enabled=True, device=dev))
    eng.submit(prompt, max_new_tokens=3)
    out0 = eng.run_until_done()[0].generated

    mesh = Mesh(np.array(jax.devices()).reshape(1, 8), ("data", "model"))
    eng2 = ServingEngine(cfg, params, max_batch=1, max_seq=16,
                         crossbar=CrossbarMode(enabled=True, device=dev),
                         mesh=mesh, param_axes=axes)
    wi = eng2.crossbar.programmed.by_name["stage0/b0/ffn/wi"]
    placed = str(wi.g_eff.sharding.spec)
    eng2.submit(prompt, max_new_tokens=3)
    out1 = eng2.run_until_done()[0].generated

    eng2.save_artifacts({str(tmp_path)!r})
    eng3 = ServingEngine(cfg, params, max_batch=1, max_seq=16,
                         crossbar=CrossbarMode(enabled=True, device=dev),
                         restore_artifacts={str(tmp_path)!r},
                         mesh=mesh, param_axes=axes)
    a, b = eng2.crossbar.programmed.by_name, eng3.crossbar.programmed.by_name
    equal = set(a) == set(b) and all(artifacts_equal(a[n], b[n]) for n in a)
    eng3.submit(prompt, max_new_tokens=3)
    out2 = eng3.run_until_done()[0].generated
    print(json.dumps({{
        "out0": out0, "out1": out1, "out2": out2,
        "placed": placed, "store_equal": bool(equal),
    }}))
    """)
    assert res["out0"] == res["out1"] == res["out2"]
    assert len(res["out0"]) == 3
    assert "model" in res["placed"]
    assert res["store_equal"]


# ---------------------------------------------------------------------------
# Chip lifecycle under the 8-rank mesh (ISSUE 7)
# ---------------------------------------------------------------------------

@pytest.mark.lifecycle
@pytest.mark.slow
def test_ep_chip_spread_serves_bit_identical_sharded():
    """Per-rank chip variation: expert_chips= programs each expert bank
    slice on its own chip identity (distinct device perturbation draws),
    and the spread chip still serves the shard_map EP path with zero
    misses, bit-identical to the single-device programmed path.  Non-expert
    leaves stay on the base chip, so spread-off programming (the default)
    remains bit-compatible with pre-lifecycle chips — the existing EP
    bit-identity test above pins that arm."""
    res = _run(_SETUP + """
    cfg, params, axes, tokens = make(layout="ep_only")
    dev = DeviceConfig(sigma=0.05, p_stuck_on=1e-3, p_stuck_off=1e-3,
                       write_verify_iters=2, seed=3)
    pm0 = program_model(params, device=dev, tie_lm_head=True)
    pm = program_model(params, device=dev, tie_lm_head=True,
                       expert_chips=tuple(range(1, 9)))
    mode = L.CrossbarMode(enabled=True, fast=True, device=dev, programmed=pm,
                          strict=True)

    wi0 = np.asarray(pm0.by_name["stage0/b0/ffn/wi"].g_eff)
    wis = np.asarray(pm.by_name["stage0/b0/ffn/wi"].g_eff)
    wq0 = np.asarray(pm0.by_name["stage0/b0/mixer/wq"].g_eff)
    wqs = np.asarray(pm.by_name["stage0/b0/mixer/wq"].g_eff)

    L.reset_crossbar_misses(); prog.reset_consumed_artifact_names()
    y0 = np.asarray(jax.jit(forward_fn(cfg, pm, mode))(params, tokens))
    pm.verify_consumed()

    mesh = Mesh(np.array(jax.devices()).reshape(1, 8), ("data", "model"))
    L.reset_crossbar_misses(); prog.reset_consumed_artifact_names()
    with use_mesh(mesh, layout_overrides(cfg)), mesh:
        y1 = np.asarray(jax.jit(forward_fn(cfg, pm, mode))(params, tokens))
    pm.verify_consumed()

    print(json.dumps({
        "spread_changed_experts": bool(not np.array_equal(wi0, wis)),
        "attn_on_base_chip": bool(np.array_equal(wq0, wqs)),
        "misses": list(L.crossbar_misses()),
        "bit_identical": bool(np.array_equal(y0, y1)),
    }))
    """)
    assert res["spread_changed_experts"]
    assert res["attn_on_base_chip"]
    assert res["misses"] == []
    assert res["bit_identical"]


@pytest.mark.slow
@pytest.mark.lifecycle
def test_engine_mesh_hot_swap_mid_run_bit_identical(tmp_path):
    """Acceptance (ISSUE 7): hot_swap after re-programming is bit-identical
    to a fresh chip *under the 8-rank sharded path* — a mesh ServingEngine
    ages its chip, refreshes through the double-buffered store mid-run, and
    finishes the generation with exactly the tokens of an uninterrupted
    fresh-chip run; the swapped-in artifacts equal the fresh engine's and
    keep their mesh placement."""
    res = _run(_SETUP + f"""
    from repro.models.layers import CrossbarMode
    from repro.serving.engine import ServingEngine
    from repro.device.programmed import artifacts_equal

    cfg, params, axes, tokens = make(layout="ep_only")
    dev = DeviceConfig(sigma=0.05, p_stuck_on=1e-3, p_stuck_off=1e-3,
                       write_verify_iters=2, drift_nu=0.05)
    prompt = np.array([1, 2, 3], np.int32)
    mesh = Mesh(np.array(jax.devices()).reshape(1, 8), ("data", "model"))

    ref = ServingEngine(cfg, params, max_batch=1, max_seq=16,
                        crossbar=CrossbarMode(enabled=True, device=dev),
                        mesh=mesh, param_axes=axes)
    ref.submit(prompt, max_new_tokens=5)
    out_ref = ref.run_until_done()[0].generated

    eng = ServingEngine(cfg, params, max_batch=1, max_seq=16,
                        crossbar=CrossbarMode(enabled=True, device=dev),
                        mesh=mesh, param_axes=axes)
    eng.submit(prompt, max_new_tokens=5)
    eng.step()  # admit + first decode on the original chip
    eng.step()
    slot = eng.refresh({str(tmp_path)!r})  # reprogram -> slot -> swap -> rebind
    out = eng.run_until_done()[0].generated

    a, b = eng.crossbar.programmed.by_name, ref.crossbar.programmed.by_name
    equal = set(a) == set(b) and all(artifacts_equal(a[n], b[n]) for n in a)
    wi = eng.crossbar.programmed.by_name["stage0/b0/ffn/wi"]

    # aging works on the mesh-placed chip too: elementwise decay respects
    # the recorded sharding and health sees the drift
    eng.age(1e6)
    worst_aged = eng.health_check().worst
    eng.compensate()
    worst_comp = eng.health_check().worst
    print(json.dumps({{
        "out_ref": out_ref, "out": out, "slot": slot,
        "swap_equal_fresh": bool(equal),
        "placed": str(wi.g_eff.sharding.spec),
        "worst_aged": worst_aged, "worst_comp": worst_comp,
    }}))
    """)
    assert res["out"] == res["out_ref"]
    assert len(res["out"]) == 5
    assert res["slot"] == "A"
    assert "model" in res["placed"]
    assert res["worst_comp"] < res["worst_aged"]
    assert res["worst_aged"] > 0


# ---------------------------------------------------------------------------
# Single-process unit tests: spec derivation and rank-local slicing
# ---------------------------------------------------------------------------

def _art(K=64, N=32, device=None, stacked=None):
    import jax.numpy as jnp

    from repro.device import program_layer

    rng = np.random.default_rng(0)
    shape = ((stacked,) if stacked else ()) + (K, N)
    w = jnp.asarray(rng.normal(size=shape).astype(np.float32))
    return program_layer(w, device=device)


def test_artifact_shard_specs_follow_weight_axes():
    from jax.sharding import PartitionSpec as P

    from repro.device import DeviceConfig
    from repro.device.programmed import artifact_shard_specs

    dev = DeviceConfig(sigma=0.05, p_stuck_on=5e-3, p_stuck_off=5e-3,
                       write_verify_iters=2, spare_cols=4)
    art = _art(device=dev, stacked=4)  # (E, K, N), repaired
    specs = artifact_shard_specs(art, P("model", None, None))
    assert specs["w_codes"] == P("model", None, None)
    assert specs["g_eff"] == P("model", None, None, None)  # bit-plane axis kept whole
    assert specs["w_colsum"] == P("model", None)
    assert specs["w_scale"] == P("model")
    assert specs["g_spare"] == P("model", None, None, None)
    # (E, S, R, N): slice / row-group axes are physical-array coordinates
    assert specs["out_gather"] == P("model", None, None, None)
    # K-sharded: cells slice along rows; the full-K colsum cannot shard
    specs_k = artifact_shard_specs(art, P(None, "model", None))
    assert specs_k["w_codes"] == P(None, "model", None)
    assert specs_k["g_eff"] == P(None, None, "model", None)
    assert specs_k["w_colsum"] == P(None, None)
    # spec longer than the weight rank is a hard error
    with pytest.raises(ValueError):
        artifact_shard_specs(_art(), P(None, None, "model"))


def test_with_arrays_round_trips_artifact_arrays():
    from repro.device import DeviceConfig
    from repro.device.programmed import (
        artifact_arrays,
        artifacts_equal,
        with_arrays,
    )

    dev = DeviceConfig(sigma=0.05, p_stuck_on=1e-3, p_stuck_off=1e-3,
                       write_verify_iters=2)
    art = _art(device=dev)
    back = with_arrays(art, artifact_arrays(art))
    assert artifacts_equal(art, back)
    assert back.report is None and back.repair is None  # global-chip metadata dropped


def test_local_artifact_slices_rows_and_stacked_axes():
    from jax.sharding import PartitionSpec as P

    from repro.device import DeviceConfig
    from repro.device.programmed import local_artifact

    dev = DeviceConfig(sigma=0.05, p_stuck_on=1e-3, p_stuck_off=1e-3,
                       write_verify_iters=2)
    art = _art(K=64, N=32, device=dev, stacked=4)
    # expert axis: rank r holds experts [r*2, r*2+2)
    loc = local_artifact(art, P("model", None, None), {"model": 2}, {"model": 1})
    np.testing.assert_array_equal(np.asarray(loc.w_codes), np.asarray(art.w_codes[2:]))
    np.testing.assert_array_equal(np.asarray(loc.g_eff), np.asarray(art.g_eff[2:]))
    np.testing.assert_array_equal(np.asarray(loc.w_scale), np.asarray(art.w_scale[2:]))
    # contraction axis: rank-local rows of the global chip
    loc_k = local_artifact(art, P(None, "model", None), {"model": 4}, {"model": 3})
    np.testing.assert_array_equal(
        np.asarray(loc_k.w_codes), np.asarray(art.w_codes[:, 48:64])
    )
    np.testing.assert_array_equal(
        np.asarray(loc_k.g_eff), np.asarray(art.g_eff[:, :, 48:64])
    )


def test_local_artifact_reindexes_repair_tables_to_local_columns():
    """N-sharded slicing of a repaired artifact: out_gather re-indexes to
    local column coordinates, the local spare block is compacted to the
    spares local columns actually use, and the (already repaired) g_eff
    slice is consistent with the re-indexed record: every repaired local
    column's effective cells equal the local spare column it points to."""
    from jax.sharding import PartitionSpec as P

    from repro.device import DeviceConfig
    from repro.device.programmed import local_artifact

    dev = DeviceConfig(sigma=0.05, p_stuck_on=2e-2, p_stuck_off=2e-2,
                       write_verify_iters=2, spare_cols=8, seed=7)
    art = _art(K=64, N=32, device=dev)
    assert art.repair is not None and art.repair.n_repaired > 0
    n_loc = 16
    rows = int(art.spec.rows)
    seen_spare = 0
    for rank in (0, 1):
        loc = local_artifact(art, P(None, "model"), {"model": 2}, {"model": rank})
        g = np.asarray(loc.out_gather)  # (S, R, n_loc)
        S, R = g.shape[:2]
        assert g.shape == (S, R, n_loc)
        glob = np.asarray(art.out_gather)[:, :, rank * n_loc:(rank + 1) * n_loc]
        for s in range(S):
            for r in range(R):
                r0 = r * rows
                r1 = min(r0 + rows, np.asarray(art.g_eff).shape[1])
                for j in range(n_loc):
                    if glob[s, r, j] < 32:  # unrepaired: local identity
                        assert g[s, r, j] == j
                    else:  # repaired: points into the compacted local spares
                        b = g[s, r, j] - n_loc
                        assert 0 <= b < loc.g_spare.shape[-1]
                        np.testing.assert_array_equal(
                            np.asarray(loc.g_eff)[s, r0:r1, j],
                            np.asarray(loc.g_spare)[s, r0:r1, b],
                        )
                        seen_spare += 1
        np.testing.assert_array_equal(
            np.asarray(loc.g_eff), np.asarray(art.g_eff)[:, :, rank * n_loc:(rank + 1) * n_loc]
        )
    assert seen_spare == art.repair.n_repaired


def test_rank_local_serving_bit_identical_to_global_bank():
    """Expert-sharded rank-local artifacts serve bit-identically to the
    global bank: each rank's slice of an (E, K, N) bank produces exactly
    the outputs the global chip produces for those experts (the invariant
    the kernel_sharded_programmed bench gates)."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from repro.device import DeviceConfig, programmed_linear
    from repro.device.programmed import local_artifact

    rng = np.random.default_rng(1)
    E, K, N, ranks = 4, 64, 16, 2
    dev = DeviceConfig(sigma=0.05, p_stuck_on=1e-3, p_stuck_off=1e-3,
                       write_verify_iters=2)
    art = _art(K=K, N=N, device=dev, stacked=E)
    x = jnp.asarray(rng.normal(size=(4, K)).astype(np.float32))
    y_global = [np.asarray(programmed_linear(x, art.layer(e))) for e in range(E)]
    for r in range(ranks):
        loc = local_artifact(art, P("model", None, None), {"model": ranks}, {"model": r})
        for i in range(E // ranks):
            np.testing.assert_array_equal(
                np.asarray(programmed_linear(x, loc.layer(i))),
                y_global[r * (E // ranks) + i],
            )
