"""Program-once crossbar compilation: the programmed-artifact path must be
bit-identical to the program-every-call path (ideal and noisy, Pallas
interpret and jnp reference), zero-plane skipping must be bit-identical to
the dense loop, and the activity/latency accounting must follow its
documented semantics."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import adc
from repro.core import crossbar as cb
from repro.core.crossbar import ConversionStats, DEFAULT_SPEC
from repro.device import (
    DeviceConfig,
    program_layer,
    program_model,
    programmed_linear,
    programmed_matmul,
)
from repro.kernels import ops, ref
from repro.models.layers import CrossbarMode, crossbar_mode, crossbar_linear

DEV = DeviceConfig(sigma=0.1, p_stuck_on=1e-3, p_stuck_off=1e-3, write_verify_iters=4)


def _float_data(rng, B, K, N, nonneg=True):
    x = rng.normal(size=(B, K)).astype(np.float32)
    if nonneg:
        x = np.abs(x)
    w = rng.normal(size=(K, N)).astype(np.float32)
    return jnp.asarray(x), jnp.asarray(w)


# ---------------------------------------------------------------------------
# programmed artifact == program-every-call, bit for bit
# ---------------------------------------------------------------------------

def test_programmed_noisy_bit_identical_pallas():
    rng = np.random.default_rng(0)
    x, w = _float_data(rng, 4, 256, 32)
    y_percall = ops.crossbar_matmul(x, w, device=DEV, interpret=True)
    art = program_layer(w, device=DEV)
    y_prog = programmed_matmul(x, art, interpret=True)
    np.testing.assert_array_equal(np.asarray(y_percall), np.asarray(y_prog))


def test_programmed_noisy_bit_identical_jnp_reference():
    """The same split through the pure-jnp functional model: quantizing with
    the artifact's frozen scales and running ``noisy_crossbar_vmm`` on its
    frozen ``g_eff`` reproduces ``crossbar_matmul_f32(device=...)``."""
    rng = np.random.default_rng(1)
    x, w = _float_data(rng, 3, 200, 24)
    y_percall = cb.crossbar_matmul_f32(x, w, device=DEV)
    art = program_layer(w, device=DEV, adc_cfg=None)
    spec = art.spec
    x_scale = jnp.maximum(jnp.max(x), 1e-9) / ((1 << spec.input_bits) - 1)
    xq = cb.quantize_input(x, spec, x_scale)
    yq = cb.noisy_crossbar_vmm(xq, art.g_eff, spec)
    y_prog = yq.astype(jnp.float32) * (x_scale * art.w_scale * (2.0 ** spec.drop_lsb))
    np.testing.assert_array_equal(np.asarray(y_percall), np.asarray(y_prog))


@pytest.mark.parametrize("fast", [True, False])
def test_programmed_ideal_bit_identical(fast):
    rng = np.random.default_rng(2)
    x, w = _float_data(rng, 4, 256, 32)
    y_percall = ops.crossbar_matmul(x, w, fast=fast, interpret=True)
    art = program_layer(w, fast=fast)
    y_prog = programmed_matmul(x, art, interpret=True)
    np.testing.assert_array_equal(np.asarray(y_percall), np.asarray(y_prog))


def test_programming_is_deterministic():
    """One DeviceConfig seed -> one chip: reprogramming draws the identical
    faults, pulses and read path (the program-every-call path relied on
    exactly this, so the cache is sound)."""
    rng = np.random.default_rng(3)
    _, w = _float_data(rng, 1, 128, 16)
    a1 = program_layer(w, device=DEV)
    a2 = program_layer(w, device=DEV, with_report=True)
    np.testing.assert_array_equal(np.asarray(a1.g_eff), np.asarray(a2.g_eff))
    np.testing.assert_array_equal(np.asarray(a1.w_codes), np.asarray(a2.w_codes))
    assert a2.report is not None and a2.report.iterations >= 1


def test_stacked_artifact_matches_per_layer():
    rng = np.random.default_rng(4)
    ws = jnp.asarray(rng.normal(size=(3, 128, 16)).astype(np.float32))
    stacked = program_layer(ws, device=DEV)
    assert stacked.stacked and stacked.w_codes.shape == (3, 128, 16)
    for i in range(3):
        direct = program_layer(ws[i], device=DEV)
        sliced = stacked.layer(i)
        np.testing.assert_array_equal(np.asarray(sliced.g_eff), np.asarray(direct.g_eff))
        np.testing.assert_array_equal(
            np.asarray(sliced.w_scale), np.asarray(direct.w_scale)
        )


# ---------------------------------------------------------------------------
# crossbar_linear / CrossbarMode integration
# ---------------------------------------------------------------------------

def test_crossbar_linear_programmed_bit_identical():
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(4, 256)).astype(np.float32))  # signed
    w = jnp.asarray(rng.normal(size=(256, 32)).astype(np.float32))
    params = {"wq": w}
    prog = program_model(params, device=DEV)
    assert prog.n_compiled == 1
    with crossbar_mode(CrossbarMode(enabled=True, device=DEV)):
        y_percall = crossbar_linear(x, w)
    with crossbar_mode(CrossbarMode(enabled=True, device=DEV, programmed=prog)):
        y_prog = crossbar_linear(x, params["wq"], name="wq")
    np.testing.assert_array_equal(np.asarray(y_percall), np.asarray(y_prog))


def test_crossbar_linear_programmed_bit_identical_bf16():
    """Offset encoding must happen in x.dtype on both paths — bf16 is the
    default param dtype, and pre-casting activations to f32 on only one
    side silently breaks the bit-identity guarantee."""
    rng = np.random.default_rng(8)
    x = jnp.asarray(rng.normal(size=(4, 128)).astype(np.float32)).astype(jnp.bfloat16)
    w = jnp.asarray(rng.normal(size=(128, 16)).astype(np.float32)).astype(jnp.bfloat16)
    params = {"wq": w}
    prog = program_model(params, device=DEV)
    with crossbar_mode(CrossbarMode(enabled=True, device=DEV)):
        y_percall = crossbar_linear(x, w)
    with crossbar_mode(CrossbarMode(enabled=True, device=DEV, programmed=prog)):
        y_prog = crossbar_linear(x, params["wq"], name="wq")
    assert y_prog.dtype == x.dtype
    np.testing.assert_array_equal(
        np.asarray(y_percall, np.float32), np.asarray(y_prog, np.float32)
    )


@pytest.mark.slow
def test_programmed_bind_under_jit():
    """Artifact lookup resolves through tracers inside jit; the result
    matches the jitted per-call path to float fusion tolerance (XLA fuses
    the two traces differently, so exact bit equality is an eager-only
    guarantee)."""
    rng = np.random.default_rng(6)
    x = jnp.asarray(rng.normal(size=(2, 128)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(128, 16)).astype(np.float32))
    params = {"wq": w}
    prog = program_model(params, device=DEV)

    @jax.jit
    def fwd_prog(p, xin):
        with prog.bind(), crossbar_mode(CrossbarMode(enabled=True, device=DEV)):
            return crossbar_linear(xin, p["wq"], name="wq")

    @jax.jit
    def fwd_percall(p, xin):
        with crossbar_mode(CrossbarMode(enabled=True, device=DEV)):
            return crossbar_linear(xin, p["wq"])

    a = np.asarray(fwd_prog(params, x))
    b = np.asarray(fwd_percall(params, x))
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_programmed_model_default_filter():
    """Stacked projections compile; embeddings and norm scales do not."""
    rng = np.random.default_rng(7)
    params = {
        "embed": {"tokens": jnp.asarray(rng.normal(size=(64, 32)).astype(np.float32))},
        "stage0": {
            "b0": {
                "wq": jnp.asarray(rng.normal(size=(2, 32, 16)).astype(np.float32)),
                "norm1": jnp.asarray(rng.normal(size=(2, 32)).astype(np.float32)),
            }
        },
    }
    prog = program_model(params)  # ideal: cheap
    assert prog.n_compiled == 1
    assert prog.artifacts["stage0"]["b0"]["wq"].stacked
    assert prog.artifacts["stage0"]["b0"]["norm1"] is None
    assert prog.artifacts["embed"]["tokens"] is None


@pytest.mark.slow
def test_serving_engine_programmed_crossbars():
    """End-to-end: the engine programs the model once and decodes on the
    steady-state path; generation is deterministic for a fixed seed."""
    from repro import configs
    from repro.configs.base import reduced
    from repro.models import model as M
    from repro.serving.engine import ServingEngine

    cfg = reduced(configs.get_config("smollm-360m"))
    params, _ = M.init_model(jax.random.PRNGKey(0), cfg)
    dev = DeviceConfig(sigma=0.02, write_verify_iters=2)
    outs = []
    for _ in range(2):
        eng = ServingEngine(
            cfg, params, max_batch=2, max_seq=64,
            crossbar=CrossbarMode(enabled=True, device=dev),
        )
        assert eng.crossbar.programmed is not None
        assert eng.crossbar.programmed.n_compiled >= 4
        eng.submit(np.array([1, 2, 3], np.int32), max_new_tokens=2)
        outs.append(eng.run_until_done()[0].generated)
    assert outs[0] == outs[1] and len(outs[0]) == 2


# ---------------------------------------------------------------------------
# zero-plane skipping: bit-identity + conversion accounting
# ---------------------------------------------------------------------------

def _int_data(rng, B, K, N, sparse=False):
    if sparse:  # post-ReLU style: mostly zero, small codes
        x = rng.integers(0, 1 << 9, size=(B, K)) * (rng.random((B, K)) < 0.25)
    else:
        x = rng.integers(0, 1 << 16, size=(B, K))
    w = rng.integers(-(1 << 15), 1 << 15, size=(K, N))
    return jnp.asarray(x), jnp.asarray(w)


# The kernel x skip_zero_planes x jit x sparsity bit-identity grid lives in
# tests/test_kernels.py (test_kernel_bit_identity_matrix); here we keep only
# the adaptive-ADC + skip interaction that grid does not span.
@pytest.mark.parametrize("sparse", [False, True], ids=["dense", "sparse"])
def test_zero_plane_skip_bit_identical_adaptive_adc(sparse):
    rng = np.random.default_rng(10 + sparse)
    x, w = _int_data(rng, 4, 300, 24, sparse=sparse)
    y_skip = ops.crossbar_vmm_op(
        x, w, DEFAULT_SPEC, adc_cfg=adc.SAFE_ADAPTIVE, interpret=True,
        skip_zero_planes=True,
    )
    y_dense = ops.crossbar_vmm_op(
        x, w, DEFAULT_SPEC, adc_cfg=adc.SAFE_ADAPTIVE, interpret=True,
        skip_zero_planes=False,
    )
    y_ref = ref.crossbar_vmm_ref(x, w, DEFAULT_SPEC, adc_cfg=adc.SAFE_ADAPTIVE)
    np.testing.assert_array_equal(np.asarray(y_skip), np.asarray(y_dense))
    np.testing.assert_array_equal(np.asarray(y_skip), np.asarray(y_ref))


def test_activity_conversion_stats():
    rng = np.random.default_rng(16)
    B, K, N = 4, 300, 24
    x_dense, _ = _int_data(rng, B, K, N)
    x_sparse, _ = _int_data(rng, B, K, N, sparse=True)
    dense = cb.conversion_stats(B, K, N, DEFAULT_SPEC, x_codes=x_dense)
    sparse = cb.conversion_stats(B, K, N, DEFAULT_SPEC, x_codes=x_sparse)
    nominal = cb.conversion_stats(B, K, N, DEFAULT_SPEC)
    # dense 16-bit codes light every plane; sparse inputs skip many
    assert dense.conversions == nominal.conversions and dense.skipped_conversions == 0
    assert 0 < sparse.conversions < nominal.conversions
    assert sparse.conversions + sparse.skipped_conversions == nominal.conversions
    # all-zero input: everything skipped
    zero = cb.conversion_stats(
        B, K, N, DEFAULT_SPEC, x_codes=jnp.zeros((B, K), jnp.int32)
    )
    assert zero.conversions == 0
    assert zero.skipped_conversions == nominal.conversions


def test_energy_activity_term():
    from repro.core import energy as E
    from repro.core.arch import ISAAC_CHIP
    from repro.core.workloads import alexnet

    net = alexnet()
    r_dense = E.evaluate(net, ISAAC_CHIP)
    r_sparse = E.evaluate(net, ISAAC_CHIP, activity=0.5)
    # ADC/crossbar/DAC energy scale with activity; provisioned power doesn't
    assert r_sparse.breakdown["adc"] == pytest.approx(0.5 * r_dense.breakdown["adc"])
    assert r_sparse.breakdown["crossbar"] == pytest.approx(
        0.5 * r_dense.breakdown["crossbar"]
    )
    assert r_sparse.energy_per_sample_j < r_dense.energy_per_sample_j
    assert r_sparse.peak_power_w == r_dense.peak_power_w


# ---------------------------------------------------------------------------
# ConversionStats semantics
# ---------------------------------------------------------------------------

def test_conversion_stats_add_is_sequential_sum():
    """``+`` composes sequential VMMs: every field adds, including
    ``iterations`` (total cycles).  Pinned because an earlier revision
    documented a max-latency proxy while summing."""
    a = ConversionStats(conversions=10, bit_decisions=90, iterations=16,
                        skipped_conversions=2)
    b = ConversionStats(conversions=5, bit_decisions=45, iterations=16,
                        skipped_conversions=1)
    c = a + b
    assert c == ConversionStats(
        conversions=15, bit_decisions=135, iterations=32, skipped_conversions=3
    )
    # identity element + associativity of the sum semantic
    z = ConversionStats()
    assert a + z == a and (a + b) + c == a + (b + c)
