"""Device non-ideality subsystem: seeded determinism, zero-noise bit-exact
reduction to the ideal datapath, noisy-kernel interpret-mode equivalence to
the dense perturbed reference, and write-verify convergence."""
import numpy as np
import jax.numpy as jnp
import pytest
from _propcheck import integers, sampled_from, sweep

from repro.core import adc
from repro.core import crossbar as cb
from repro.device import (
    DeviceConfig,
    IDEAL_DEVICE,
    effective_cell_codes,
    fault_masks,
    write_verify,
)
from repro.device.models import (
    GEFF_FRAC_BITS,
    apply_drift,
    ir_drop_conductance,
    read_effective_codes,
    target_cell_codes,
)
from repro.kernels import ops, ref

SPEC = cb.DEFAULT_SPEC


def _codes(rng, B, K, N):
    x = jnp.asarray(rng.integers(0, 1 << 16, size=(B, K)))
    w = jnp.asarray(rng.integers(-(1 << 15), 1 << 15, size=(K, N)))
    return x, w


def _biased(w):
    return w.astype(jnp.int32) + SPEC.weight_bias


NOISY = DeviceConfig(sigma=0.05, p_stuck_on=2e-3, p_stuck_off=2e-3, r_line_ohm=1.0, seed=7)


# --- zero-noise identity ----------------------------------------------------

def test_zero_noise_reduces_to_ideal_bit_exact():
    rng = np.random.default_rng(0)
    x, w = _codes(rng, 4, 300, 24)
    y_ideal = cb.crossbar_vmm(x, w, SPEC)
    y_dev = cb.crossbar_vmm(x, w, SPEC, device=IDEAL_DEVICE)
    np.testing.assert_array_equal(np.asarray(y_dev), np.asarray(y_ideal))
    # and through the explicit g_eff + Pallas path
    g0 = effective_cell_codes(_biased(w), SPEC, IDEAL_DEVICE)
    np.testing.assert_array_equal(
        np.asarray(target_cell_codes(_biased(w), SPEC)), np.asarray(g0)
    )
    y_k = ops.noisy_vmm_op(x, g0, SPEC, interpret=True)
    np.testing.assert_array_equal(np.asarray(y_k), np.asarray(y_ideal))


def test_explicitly_zeroed_config_is_ideal():
    cfg = DeviceConfig(sigma=0.0, drift_nu=0.5, t_drift_s=0.0)  # nu without t: ideal
    assert cfg.is_ideal
    assert not NOISY.is_ideal


# --- seeded determinism -----------------------------------------------------

@sweep(
    integers(1, 8),  # slices
    integers(32, 384),  # rows
    integers(8, 48),  # cols
    sampled_from([(0.0, 0.0), (0.01, 0.02), (0.05, 0.0), (0.05, 0.05)]),
    integers(0, 2**31 - 1),  # seed
    examples=8,
)
def test_fault_masks_property(S, K, N, rates, seed):
    """fault_masks is a bit-reproducible pure function of (cfg, shape, tag):
    masks are disjoint, empirical rates match p_stuck_* to binomial
    tolerance, repeated calls and jit-compiled calls agree bit-for-bit, and
    tag / stage select independent fields."""
    import functools
    import jax

    p_on, p_off = rates
    cfg = DeviceConfig(p_stuck_on=p_on, p_stuck_off=p_off, seed=seed)
    shape = (S, K, N)
    on1, off1 = fault_masks(cfg, shape)
    on2, off2 = fault_masks(cfg, shape)
    # disjoint + bit-reproducible across calls
    assert not bool(jnp.any(on1 & off1))
    np.testing.assert_array_equal(np.asarray(on1), np.asarray(on2))
    np.testing.assert_array_equal(np.asarray(off1), np.asarray(off2))
    # and under jit (shape/cfg static, tag traced)
    jon, joff = jax.jit(functools.partial(fault_masks, cfg, shape))(
        tag=jnp.uint32(7)
    )
    eon, eoff = fault_masks(cfg, shape, tag=jnp.uint32(7))
    np.testing.assert_array_equal(np.asarray(jon), np.asarray(eon))
    np.testing.assert_array_equal(np.asarray(joff), np.asarray(eoff))
    # empirical rates within a 6-sigma binomial band (plus one-cell slack)
    ncells = S * K * N
    for mask, p in ((on1, p_on), (off1, p_off)):
        se = (p * (1.0 - p) / ncells) ** 0.5
        assert abs(float(jnp.mean(mask)) - p) <= 6.0 * se + 1.0 / ncells
    if p_on + p_off > 0.0 and ncells >= 4096:
        # tag and stage decorrelate: same cfg/shape, different field
        t1, _ = fault_masks(cfg, shape, tag=jnp.uint32(1))
        t2, _ = fault_masks(cfg, shape, tag=jnp.uint32(2))
        assert bool(jnp.any(t1 != t2))
        s1 = fault_masks(cfg, shape, stage="spare_faults")
        assert bool(jnp.any(s1[0] != on1)) or bool(jnp.any(s1[1] != off1))


def test_fault_maps_deterministic_and_disjoint():
    cfg = DeviceConfig(p_stuck_on=0.01, p_stuck_off=0.02, seed=5)
    on1, off1 = fault_masks(cfg, (8, 128, 16))
    on2, off2 = fault_masks(cfg, (8, 128, 16))
    np.testing.assert_array_equal(np.asarray(on1), np.asarray(on2))
    np.testing.assert_array_equal(np.asarray(off1), np.asarray(off2))
    assert not bool(jnp.any(on1 & off1))
    # rates in the right ballpark over 16k cells
    assert abs(float(jnp.mean(on1)) - 0.01) < 0.005
    assert abs(float(jnp.mean(off1)) - 0.02) < 0.007
    on3, _ = fault_masks(cfg.replace(seed=6), (8, 128, 16))
    assert bool(jnp.any(on1 != on3))


def test_effective_codes_deterministic_and_on_grid():
    rng = np.random.default_rng(1)
    _, w = _codes(rng, 1, 200, 16)
    g1 = effective_cell_codes(_biased(w), SPEC, NOISY)
    g2 = effective_cell_codes(_biased(w), SPEC, NOISY)
    np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2))
    scaled = np.asarray(g1) * (1 << GEFF_FRAC_BITS)
    np.testing.assert_array_equal(scaled, np.round(scaled))  # on the grid
    assert float(jnp.min(g1)) >= 0.0
    assert float(jnp.max(g1)) <= (1 << SPEC.cell_bits) - 1


# --- kernel vs dense perturbed reference ------------------------------------

@pytest.mark.parametrize("adc_cfg", [None, adc.SAFE_ADAPTIVE], ids=["full", "adaptive"])
def test_noisy_kernel_matches_dense_reference(adc_cfg):
    rng = np.random.default_rng(2)
    x, w = _codes(rng, 3, 300, 40)
    g = effective_cell_codes(_biased(w), SPEC, NOISY)
    y_k = ops.noisy_vmm_op(x, g, SPEC, adc_cfg=adc_cfg, interpret=True)
    y_r = ref.noisy_vmm_ref(x, g, SPEC, adc_cfg=adc_cfg)
    np.testing.assert_array_equal(np.asarray(y_k), np.asarray(y_r))


@pytest.mark.slow
@sweep(integers(1, 6), integers(1, 260), integers(1, 32), integers(0, 2**32 - 1), examples=6)
def test_noisy_kernel_property(B, K, N, seed):
    rng = np.random.default_rng(seed)
    x, w = _codes(rng, B, K, N)
    cfg = DeviceConfig(sigma=0.1, p_stuck_on=5e-3, seed=seed % 97)
    g = effective_cell_codes(_biased(w), SPEC, cfg)
    y_k = ops.noisy_vmm_op(x, g, SPEC, interpret=True)
    y_r = ref.noisy_vmm_ref(x, g, SPEC)
    np.testing.assert_array_equal(np.asarray(y_k), np.asarray(y_r))


def test_noisy_kernel_unsigned_msb_clamp_path():
    spec_u = SPEC.replace(signed_weights=False)
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.integers(0, 1 << 16, size=(4, 384)))
    w = jnp.asarray(rng.integers(0, 1 << 16, size=(384, 32)))
    g = effective_cell_codes(w.astype(jnp.int32), spec_u, DeviceConfig(sigma=0.05, seed=3))
    y_k = ops.noisy_vmm_op(x, g, spec_u, adc_cfg=adc.SAFE_ADAPTIVE, interpret=True)
    y_r = ref.noisy_vmm_ref(x, g, spec_u, adc_cfg=adc.SAFE_ADAPTIVE)
    np.testing.assert_array_equal(np.asarray(y_k), np.asarray(y_r))


# --- write-verify calibration -----------------------------------------------

def test_write_verify_converges():
    rng = np.random.default_rng(4)
    _, w = _codes(rng, 1, 256, 32)
    cfg = DeviceConfig(sigma=0.2, write_verify_iters=8, seed=11)
    g, rep = write_verify(_biased(w), SPEC, cfg)
    # error shrinks monotonically and beats the open-loop write
    errs = rep.per_iter_mean_error
    assert all(b <= a for a, b in zip(errs, errs[1:]))
    assert errs[-1] < errs[0]
    assert rep.converged_frac > 0.95
    # the programmed slab matches what the inference path programs
    from repro.device.models import programmed_conductance

    g_inf = programmed_conductance(_biased(w), SPEC, cfg)
    np.testing.assert_array_equal(np.asarray(g), np.asarray(g_inf))


def test_write_verify_stuck_cells_never_converge():
    rng = np.random.default_rng(5)
    _, w = _codes(rng, 1, 128, 16)
    cfg = DeviceConfig(sigma=0.1, p_stuck_on=0.05, write_verify_iters=6, seed=2)
    _, rep = write_verify(_biased(w), SPEC, cfg)
    assert rep.stuck_frac > 0.0
    # converged fraction is capped roughly by the non-stuck share whose
    # target isn't already at the stuck rail
    assert rep.converged_frac < 1.0
    assert rep.max_abs_error >= 1.0  # a stuck-on cell on a low target


def test_write_verify_reduces_output_error():
    rng = np.random.default_rng(6)
    x, w = _codes(rng, 4, 256, 16)
    spec = cb.layer_scaled_spec(SPEC, 256)
    y_ideal = np.asarray(cb.crossbar_vmm(x, w, spec), dtype=np.int64)
    errs = {}
    for iters in (1, 8):
        cfg = DeviceConfig(sigma=0.3, write_verify_iters=iters, seed=13)
        y = np.asarray(cb.crossbar_vmm(x, w, spec, device=cfg), dtype=np.int64)
        errs[iters] = np.abs(y - y_ideal).mean()
    assert errs[8] < errs[1]


# --- read-time physics ------------------------------------------------------

def test_drift_and_ir_drop_monotone():
    g = jnp.full((SPEC.n_slices, 128, 8), 200e-6, jnp.float32)
    cfg_d = DeviceConfig(drift_nu=0.1, t_drift_s=1e4)
    assert float(jnp.max(apply_drift(g, cfg_d))) < 200e-6
    cfg_r1 = DeviceConfig(r_line_ohm=1.0)
    cfg_r2 = DeviceConfig(r_line_ohm=2.0)
    g1 = ir_drop_conductance(g, SPEC, cfg_r1)
    g2 = ir_drop_conductance(g, SPEC, cfg_r2)
    assert bool(jnp.all(g1 <= g))
    assert bool(jnp.all(g2 <= g1))
    # far column attenuates more than near column
    assert float(g1[0, 0, -1]) < float(g1[0, 0, 0])


def test_read_effective_codes_clips_to_rails():
    cfg = DeviceConfig(sigma=1.5, seed=9)  # absurd sigma: must still clip
    rng = np.random.default_rng(7)
    _, w = _codes(rng, 1, 128, 8)
    g = effective_cell_codes(_biased(w), SPEC, cfg)
    assert float(jnp.min(g)) >= 0.0
    assert float(jnp.max(g)) <= (1 << SPEC.cell_bits) - 1
    # read path alone also respects the grid
    from repro.device.models import programmed_conductance

    codes = read_effective_codes(programmed_conductance(_biased(w), SPEC, cfg), SPEC, cfg)
    scaled = np.asarray(codes) * (1 << GEFF_FRAC_BITS)
    np.testing.assert_array_equal(scaled, np.round(scaled))
