"""Chip-plan compiler: selection behavior, serialization, and executed
bit-exactness.

The planner's contract (ISSUE 8): per layer it picks the datapath / ADC
schedule / spare budget / replication that minimizes predicted ADC energy
under ``core.energy``'s accounting, the result is deterministic and
serializable, and a chip programmed under the plan produces the *same bits*
as the homogeneous direct compile (exact limb arithmetic) while strictly
reducing predicted conversions/energy.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core.adc import FULL_ADC, SAFE_ADAPTIVE
from repro.core.crossbar import DEFAULT_SPEC, layer_scaled_spec
from repro.core.planner import (
    ADC_MODES,
    ChipPlan,
    LayerPlan,
    adc_config_for,
    datapath_crossbar_factor,
    homogeneous_network,
    plan_layer,
    plan_model,
    plan_network,
)
from repro.core.workloads import alexnet, lm_workload
from repro.device import DeviceConfig, program_layer, programmed_matmul
from repro.device.programmed import ProgrammedModel, program_model


def _lm_net():
    return lm_workload(get_config("smollm-360m"))


# ---------------------------------------------------------------------------
# selection behavior
# ---------------------------------------------------------------------------

def test_lm_plan_beats_homogeneous_and_is_deterministic():
    net = _lm_net()
    planned = plan_network(net)
    homo = homogeneous_network(net)
    # strictly cheaper in both currencies — the kernel_planned gate's claim
    assert planned.total_conversions < homo.total_conversions
    assert planned.total_energy_pj < homo.total_energy_pj
    # unconstrained + paper widening: Karatsuba level 2 wins every fc layer
    # (92 of 128 conversion slots), with the empirically-exact adaptive ADC
    hist = planned.datapath_histogram()
    assert hist == {"karatsuba2": len(net.layers)}
    assert all(p.adc_mode == "safe_adaptive" for p in planned.layers.values())
    # pure function of its inputs: replanning is the identical plan
    assert plan_network(net) == planned


def test_area_constraint_admits_strassen_only_under_paper_widening():
    """At ``max_crossbar_factor=1.0`` (no slack arrays) Karatsuba is
    inadmissible (1.625x / 2.5x crossbars) and Strassen — which *frees*
    arrays at 7/8 — is the only conversion-cutting datapath.  Under the
    exact widening accounting Strassen costs more conversions than direct,
    so the planner must refuse it."""
    net = alexnet()
    tight = plan_network(net, max_crossbar_factor=1.0)
    hist = tight.datapath_histogram()
    assert hist.get("strassen", 0) > 0
    assert "karatsuba1" not in hist and "karatsuba2" not in hist
    exact = plan_network(net, widening="exact", max_crossbar_factor=1.0)
    assert exact.datapath_histogram() == {"direct": len(net.layers)}


def test_provable_exactness_restricts_adc_modes():
    """``provable`` admits only schedules whose analytic LSB error bound is
    exactly zero — safe_adaptive's loose worst-case bound excludes it."""
    net = _lm_net()
    provable = plan_network(net, exactness="provable")
    assert all(
        p.adc_mode in ("full", "exact_adaptive") for p in provable.layers.values()
    )
    # it still beats the full-ADC homogeneous compile on conversions
    homo = homogeneous_network(net)
    assert provable.total_conversions < homo.total_conversions


def test_exact_adaptive_is_layer_scaled():
    """The exact_adaptive guard must track the *layer's* drop_lsb, not the
    default spec's — the module constant would under-guard a deep layer."""
    deep = layer_scaled_spec(DEFAULT_SPEC, 4096)
    assert deep.drop_lsb > DEFAULT_SPEC.drop_lsb
    assert adc_config_for("exact_adaptive", deep).guard_bits == deep.drop_lsb
    assert adc_config_for("full", deep).mode == "full"
    assert adc_config_for("safe_adaptive", deep) == SAFE_ADAPTIVE


def test_spare_budget_follows_fault_rate_and_salience():
    kw = dict(rows=512, cols=512, spec=DEFAULT_SPEC)
    assert plan_layer("a", **kw).spare_cols == 0  # no faults, no spares
    lo = plan_layer("a", **kw, fault_rate=1e-2, salience=0.5)
    hi = plan_layer("a", **kw, fault_rate=1e-2, salience=2.0)
    assert 0 < lo.spare_cols <= hi.spare_cols


def test_conv_replication_follows_pixel_ratio():
    p = plan_layer("c", 363, 96, pixels=3025, kind="conv", pixels_ref=169)
    assert p.replication == -(-3025 // 169)
    assert plan_layer("f", 4096, 1000).replication == 1


def test_crossbar_factors():
    s = DEFAULT_SPEC
    assert datapath_crossbar_factor("direct", s) == 1.0
    assert datapath_crossbar_factor("karatsuba1", s) == pytest.approx(13 / 8)
    assert datapath_crossbar_factor("karatsuba2", s) == pytest.approx(20 / 8)
    assert datapath_crossbar_factor("strassen", s, "paper") == pytest.approx(7 / 8)
    assert datapath_crossbar_factor("strassen", s, "exact") > 7 / 8


# ---------------------------------------------------------------------------
# serialization
# ---------------------------------------------------------------------------

def test_chip_plan_json_round_trip():
    plan = plan_network(alexnet(), fault_rate=1e-3, max_crossbar_factor=1.0)
    back = ChipPlan.from_json(plan.to_json())
    assert back == plan
    assert list(back.layers) == list(plan.layers)  # order preserved


def test_layer_plan_validates():
    with pytest.raises(ValueError, match="datapath"):
        LayerPlan(name="x", datapath="fft")
    with pytest.raises(ValueError, match="ADC mode"):
        LayerPlan(name="x", adc_mode="lazy")
    assert LayerPlan(name="x", datapath="karatsuba2").karatsuba_levels == 2
    assert LayerPlan(name="x", datapath="strassen").karatsuba_levels == 0


# ---------------------------------------------------------------------------
# executed bit-exactness: plan choices must not change the bits
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("datapath", ["karatsuba1", "karatsuba2", "strassen"])
def test_planned_ideal_datapath_bit_identical(datapath):
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(256, 64)).astype(np.float32) * 0.1)
    x = jnp.asarray(np.abs(rng.normal(size=(4, 256))).astype(np.float32))
    base = program_layer(w)
    art = program_layer(
        w, plan=LayerPlan(name="w", datapath=datapath, adc_mode="safe_adaptive")
    )
    assert art.plan is not None and art.plan.datapath == datapath
    np.testing.assert_array_equal(
        np.asarray(programmed_matmul(x, art, interpret=True)),
        np.asarray(programmed_matmul(x, base, interpret=True)),
    )


def test_planned_noisy_chip_keeps_device_kernel():
    """Noisy chips serve the analog read path regardless of the plan's
    datapath (D&C re-tiles arrays it cannot re-read); the plan still picks
    the ADC schedule and the spare budget the chip is programmed with."""
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.normal(size=(256, 32)).astype(np.float32))
    x = jnp.asarray(np.abs(rng.normal(size=(4, 256))).astype(np.float32))
    dev = DeviceConfig(sigma=0.05, p_stuck_on=1e-3, p_stuck_off=1e-3,
                       write_verify_iters=4)
    base = program_layer(w, device=dev)  # default adc_cfg is SAFE_ADAPTIVE
    art = program_layer(
        w, device=dev,
        plan=LayerPlan(name="w", datapath="karatsuba2", adc_mode="safe_adaptive"),
    )
    np.testing.assert_array_equal(
        np.asarray(programmed_matmul(x, art, interpret=True)),
        np.asarray(programmed_matmul(x, base, interpret=True)),
    )
    # a planned spare budget reaches the repair planner
    spared = program_layer(
        w, device=dev,
        plan=LayerPlan(name="w", adc_mode="safe_adaptive", spare_cols=8),
    )
    assert spared.g_spare is not None and spared.device.spare_cols == 8


# ---------------------------------------------------------------------------
# model-level threading + persistence
# ---------------------------------------------------------------------------

def _tiny_params(rng):
    return {
        "wq": jnp.asarray(rng.normal(size=(128, 32)).astype(np.float32)),
        "wk": jnp.asarray(rng.normal(size=(128, 32)).astype(np.float32) * 4.0),
    }


def test_plan_model_names_and_salience():
    rng = np.random.default_rng(2)
    params = _tiny_params(rng)
    dev = DeviceConfig(p_stuck_on=5e-3, p_stuck_off=5e-3)
    plan = plan_model(params, device=dev)
    assert set(plan.layers) == {"wq", "wk"}
    assert plan.fault_rate == pytest.approx(1e-2)
    # wk's 4x magnitude means higher fault salience -> >= spare budget
    assert plan.layers["wk"].spare_cols >= plan.layers["wq"].spare_cols > 0


def test_program_model_attaches_plans_by_name():
    rng = np.random.default_rng(3)
    params = _tiny_params(rng)
    plan = plan_model(params)
    prog = program_model(params, plan=plan)
    for name in ("wq", "wk"):
        assert prog.by_name[name].plan == plan.layer_for(name)


def test_plan_round_trips_through_artifact_store(tmp_path):
    from repro.checkpoint import restore_programmed, save_programmed
    from repro.device.programmed import artifacts_equal

    rng = np.random.default_rng(4)
    params = _tiny_params(rng)
    prog = program_model(params, plan=plan_model(params))
    save_programmed(str(tmp_path), prog)
    back = restore_programmed(str(tmp_path))
    for name, art in prog.by_name.items():
        assert back.by_name[name].plan == art.plan
        assert artifacts_equal(back.by_name[name], art)
    # pre-planner stores (no plan) still restore
    plain = program_model(params)
    save_programmed(str(tmp_path / "plain"), plain)
    assert restore_programmed(str(tmp_path / "plain")).by_name["wq"].plan is None


def test_engine_rejects_plan_with_restored_chip(tmp_path):
    import jax

    from benchmarks.noise_sweep import tiny_lm_config
    from repro.checkpoint import save_programmed
    from repro.models import model as M
    from repro.models.layers import CrossbarMode
    from repro.serving.engine import ServingEngine

    cfg = tiny_lm_config()
    params, _ = M.init_model(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    save_programmed(str(tmp_path), ProgrammedModel({}))
    with pytest.raises(ValueError, match="replan a restored chip"):
        ServingEngine(
            cfg, params, max_batch=1, max_seq=16,
            crossbar=CrossbarMode(enabled=True),
            restore_artifacts=str(tmp_path),
            plan=plan_model(params),
        )
