"""Static contract checker (ISSUE 9): lint rules + offline store verifier.

Each lint rule class is pinned by a fixture true positive (a crafted
snippet that must produce exactly the expected finding) and the whole repo
is pinned clean: ``run_lint()`` over the live tree yields zero error-level
findings, so the CI gate (``python -m repro.analysis --check``) is green by
construction and any regression is a visible diff in these tests.

``verify_store`` is exercised against real ``save_programmed`` stores: a
freshly programmed (planned, device-noised) chip verifies OK from manifest
and npz headers alone, and the three corruption classes the issue names —
bad name-set, dangling ACTIVE pointer, over-budget plan — are each
rejected with the right rule.  Tolerant decode is regression-pinned:
manifests predating the planner/lifecycle (no ``plan`` / ``device`` /
``t_service_s``) still restore and still verify.
"""
import json
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.analysis import ALL_RULES, ERROR, INFO, lint_source, run_lint, verify_store
from repro.analysis.rules_determinism import rule_barrier, rule_rng
from repro.analysis.rules_device import rule_shadowing, rule_stage_keys
from repro.analysis.rules_matmul import rule_digital_fallback
from repro.analysis.rules_pallas import rule_pallas
from repro.checkpoint import restore_programmed, save_programmed, swap_active
from repro.core.planner import plan_model
from repro.device import DeviceConfig, program_model
from repro.device.programmed import expected_artifact_names

DEV = DeviceConfig(sigma=0.1, p_stuck_on=1e-3, p_stuck_off=1e-3, write_verify_iters=4)


def _params(seed=0, K=32, N=8):
    rng = np.random.default_rng(seed)
    return {"wq": jnp.asarray(rng.normal(size=(K, N)).astype(np.float32))}


def _saved_store(tmp_path, params, *, planned=True, slot=None):
    plan = plan_model(params, device=DEV) if planned else None
    prog = program_model(params, device=DEV, plan=plan)
    save_programmed(str(tmp_path), prog, slot=slot)
    return prog


def _manifest_path(tmp_path, slot=None):
    sub = f"programmed.slot{slot}" if slot else "programmed"
    return os.path.join(str(tmp_path), sub, "manifest.json")


def _edit_manifest(tmp_path, fn, slot=None):
    path = _manifest_path(tmp_path, slot)
    with open(path) as f:
        manifest = json.load(f)
    fn(manifest)
    with open(path, "w") as f:
        json.dump(manifest, f)


# ---------------------------------------------------------------------------
# lint rules: one fixture true positive per rule class
# ---------------------------------------------------------------------------

def test_rule_registry_has_all_six_classes():
    names = {r.__name__ for r in ALL_RULES}
    assert names == {
        "rule_digital_fallback", "rule_rng", "rule_barrier",
        "rule_stage_keys", "rule_shadowing", "rule_pallas",
    }


def test_digital_fallback_flags_unclassified_matmul():
    src = "def f(x, params):\n    return x @ params['w_new']\n"
    fs = lint_source("src/repro/models/newmodel.py", src,
                     rules=[rule_digital_fallback])
    assert len(fs) == 1
    assert fs[0].rule == "digital-fallback" and fs[0].level == ERROR
    assert "unclassified matmul" in fs[0].message
    # out of scope: the same site elsewhere is not this rule's business
    assert lint_source("src/repro/serving/x.py", src,
                       rules=[rule_digital_fallback]) == []


def test_digital_fallback_audit_statuses(monkeypatch):
    import repro.analysis.rules_matmul as rm
    monkeypatch.setitem(rm.AUDIT, "src/repro/models/fake.py", {
        "x @ w": ("known", "not lifted yet"),
        "q @ w": ("allow", "weightless"),
        "gone @ w": ("allow", "site was deleted"),
    })
    fs = lint_source("src/repro/models/fake.py", "a = x @ w\nb = q @ w\n",
                     rules=[rm.rule_digital_fallback])
    # known -> info (visible, non-fatal); allow -> silent; stale -> error
    levels = sorted((f.level, f.message.split(":")[0]) for f in fs)
    assert levels == [
        (ERROR, "stale AUDIT entry (site no longer in file)"),
        (INFO, "known-digital projection"),
    ]


def test_rng_rule_flags_unseeded_and_wall_clock():
    src = (
        "import time, jax\nimport numpy as np\n"
        "k = jax.random.PRNGKey(epoch)\n"          # seed from a step counter
        "g = np.random.default_rng()\n"            # argless generator
        "v = np.random.normal(0.0, 1.0)\n"         # hidden global state
        "t = time.time()\n"                        # wall clock in src/
    )
    fs = lint_source("src/repro/serving/fake.py", src, rules=[rule_rng])
    assert len(fs) == 4 and all(f.rule == "determinism-rng" for f in fs)
    clean = (
        "import jax\nimport numpy as np\n"
        "k = jax.random.PRNGKey(0)\n"
        "k2 = jax.random.PRNGKey(cfg.seed + 1)\n"
        "g = np.random.default_rng(seed)\n"
    )
    assert lint_source("src/repro/serving/fake.py", clean, rules=[rule_rng]) == []
    # wall clock outside src/ (benchmark timing loops) is not a finding
    assert lint_source("benchmarks/fake.py", "import time\nt = time.time()\n",
                       rules=[rule_rng]) == []


def test_barrier_rule_flags_unpinned_two_scale_product():
    bad = "def f(x, x_scale, w_scale):\n    return x * (x_scale * w_scale)\n"
    fs = lint_source("src/repro/device/fake.py", bad, rules=[rule_barrier])
    assert len(fs) == 1 and fs[0].rule == "determinism-barrier"
    assert "optimization_barrier" in fs[0].message
    pinned = (
        "def f(x, x_scale, w_scale):\n"
        "    return x * jax.lax.optimization_barrier(x_scale * w_scale)\n"
    )
    assert lint_source("src/repro/device/fake.py", pinned, rules=[rule_barrier]) == []
    # same-scale grid snap (round(c*scale)/scale) is not the hazard
    snap = "def q(c, scale):\n    return jnp.round(c * scale) / scale\n"
    assert lint_source("src/repro/device/fake.py", snap, rules=[rule_barrier]) == []
    # the device family is the scope; models/ scale math is out of scope
    assert lint_source("src/repro/models/fake.py", bad, rules=[rule_barrier]) == []


def test_stage_rule_flags_registry_index_collision():
    src = (
        "STAGE_A = 'faults'\nSTAGE_B = 'program'\n"
        "_STAGES = {STAGE_A: 0, STAGE_B: 0}\n"
    )
    fs = lint_source("src/repro/device/models.py", src, rules=[rule_stage_keys])
    assert any("index collision" in f.message for f in fs)
    ok = (
        "STAGE_A = 'faults'\nSTAGE_B = 'program'\n"
        "_STAGES = {STAGE_A: 0, STAGE_B: 1}\n"
    )
    assert lint_source("src/repro/device/models.py", ok, rules=[rule_stage_keys]) == []


def test_stage_rule_flags_ad_hoc_literals_and_duplicate_fold_in():
    src = (
        "def f(cfg, shape, tag, key):\n"
        "    m = fault_masks(cfg, shape, tag, stage='faults')\n"
        "    k = _stage_key(cfg, 'program', tag)\n"
        "    k1 = jax.random.fold_in(key, 3)\n"
        "    k2 = jax.random.fold_in(key, 3)\n"
    )
    fs = lint_source("src/repro/device/fake.py", src, rules=[rule_stage_keys])
    msgs = "\n".join(f.message for f in fs)
    assert len(fs) == 3
    assert "stage='faults'" in msgs and "'program'" in msgs
    assert "fold_in index literal 3" in msgs


def test_real_stage_registry_is_collision_free():
    import repro.device.models as dm
    assert len(set(dm._STAGES.values())) == len(dm._STAGES)
    assert set(dm._STAGES) == {
        dm.STAGE_FAULTS, dm.STAGE_PROGRAM,
        dm.STAGE_SPARE_FAULTS, dm.STAGE_SPARE_PROGRAM,
    }


def test_shadowing_rule_flags_aux_slot_rebind():
    # the PR 7 bug, verbatim shape: a RepairPlan local named `plan`
    src = (
        "def fix_layer(g_eff, spare):\n"
        "    plan = plan_repair(g_eff, spare)\n"
        "    return apply_repair(g_eff, plan)\n"
    )
    fs = lint_source("src/repro/device/repair.py", src, rules=[rule_shadowing])
    assert len(fs) == 1 and fs[0].rule == "aux-slot-shadowing"
    assert "PR 7" in fs[0].message
    # the audited allowlist admits the canonical sites
    allowed = (
        "def repaired_effective_cells(g, cfg):\n"
        "    report = build_report(g)\n"
        "    return g, report\n"
    )
    assert lint_source("src/repro/device/repair.py", allowed,
                       rules=[rule_shadowing]) == []
    # non-slot names are never flagged
    renamed = src.replace("plan", "rplan")
    assert lint_source("src/repro/device/repair.py", renamed,
                       rules=[rule_shadowing]) == []


def test_pallas_rule_flags_side_effects_and_trace_time_branch():
    src = (
        "def k(x_ref, o_ref):\n"
        "    print('step')\n"
        "    if pl.program_id(0) == 0:\n"
        "        o_ref[...] = x_ref[...]\n"
    )
    fs = lint_source("src/repro/kernels/fake.py", src, rules=[rule_pallas])
    msgs = "\n".join(f.message for f in fs)
    assert len(fs) == 2
    assert "side effect" in msgs and "@pl.when" in msgs


def test_pallas_rule_flags_blockspec_grid_arity_mismatch():
    src = (
        "def launch(x):\n"
        "    return pl.pallas_call(\n"
        "        k, grid=(4, 4),\n"
        "        in_specs=[pl.BlockSpec((8, 8), lambda i: (i, 0))],\n"
        "    )(x)\n"
    )
    fs = lint_source("src/repro/kernels/fake.py", src, rules=[rule_pallas])
    assert len(fs) == 1
    assert "1 arg(s)" in fs[0].message and "2 dimension(s)" in fs[0].message
    ok = src.replace("lambda i:", "lambda i, j:")
    assert lint_source("src/repro/kernels/fake.py", ok, rules=[rule_pallas]) == []


def test_repo_is_lint_clean():
    """The CI gate's invariant: the live tree carries zero error-level
    findings, and the known-digital map (info findings) is non-empty — the
    not-yet-lifted projections stay visible instead of becoming folklore."""
    findings = run_lint()
    errors = [f for f in findings if f.level == ERROR]
    assert errors == [], "\n".join(f.format() for f in errors)
    assert any(f.level == INFO and f.rule == "digital-fallback" for f in findings)


# ---------------------------------------------------------------------------
# offline store verification
# ---------------------------------------------------------------------------

def test_verify_store_accepts_fresh_planned_store(tmp_path):
    params = _params()
    _saved_store(tmp_path, params)
    rep = verify_store(str(tmp_path), expected=expected_artifact_names(params))
    assert rep.ok, rep.summary()
    assert rep.n_artifacts == 1
    assert "OK" in rep.summary()


def test_verify_store_follows_active_slot(tmp_path):
    params = _params()
    _saved_store(tmp_path, params, slot="A")
    swap_active(str(tmp_path), "A")
    rep = verify_store(str(tmp_path), expected=expected_artifact_names(params))
    assert rep.ok, rep.summary()
    assert rep.slot == "A"


def test_verify_store_rejects_wrong_model_name_set(tmp_path):
    _saved_store(tmp_path, _params(), planned=False)
    rep = verify_store(str(tmp_path), expected={"wk": (32, 8)})
    assert not rep.ok
    assert {f.rule for f in rep.findings} == {"name-set"}
    msgs = "\n".join(f.format() for f in rep.findings)
    # both directions: the missing expected name and the orphaned store leaf
    assert "[wk]" in msgs and "silently fall back" in msgs
    assert "[wq]" in msgs and "orphaned leaf" in msgs


def test_verify_store_rejects_dangling_active_pointer(tmp_path):
    (tmp_path / "programmed.ACTIVE").write_text("A")
    rep = verify_store(str(tmp_path))
    assert not rep.ok
    assert rep.findings[0].rule == "active-pointer"
    assert "dangling ACTIVE pointer" in rep.findings[0].message


def test_verify_store_rejects_corrupt_active_pointer(tmp_path):
    (tmp_path / "programmed.ACTIVE").write_text("Z")
    rep = verify_store(str(tmp_path))
    assert not rep.ok
    assert rep.findings[0].rule == "active-pointer"
    assert "corrupt" in rep.findings[0].message


def test_verify_store_rejects_over_budget_plan(tmp_path):
    params = _params()
    _saved_store(tmp_path, params)
    # sanity: the plan is admissible without a budget...
    assert verify_store(str(tmp_path)).ok
    # ...and over budget under an impossible one (every datapath needs
    # crossbar area; 0.1x admits nothing)
    rep = verify_store(str(tmp_path), max_crossbar_factor=0.1)
    assert not rep.ok
    assert any(f.rule == "plan" and "over budget" in f.message
               for f in rep.findings)


def test_verify_store_rejects_undecodable_plan(tmp_path):
    _saved_store(tmp_path, _params())

    def corrupt(manifest):
        manifest["artifacts"]["wq"]["plan"]["datapath"] = "quantum"

    _edit_manifest(tmp_path, corrupt)
    rep = verify_store(str(tmp_path))
    assert not rep.ok
    assert any(f.rule == "plan" and "inadmissible plan" in f.message
               for f in rep.findings)


def test_verify_store_rejects_missing_npz_and_unknown_schema(tmp_path):
    _saved_store(tmp_path, _params(), planned=False)

    def corrupt(manifest):
        manifest["schema"] = 99
        manifest["artifacts"]["wq"]["file"] = "nope.npz"

    _edit_manifest(tmp_path, corrupt)
    rep = verify_store(str(tmp_path))
    rules = {f.rule for f in rep.findings}
    assert "manifest" in rules and "arrays" in rules


def test_verify_store_tolerates_pre_planner_manifests(tmp_path):
    """Regression: stores written before the planner / lifecycle PRs carry
    no ``plan`` / ``device`` / ``t_service_s`` / ``sharding`` keys.  Both
    ``restore_programmed`` and ``verify_store`` must accept them."""
    params = _params()
    prog = _saved_store(tmp_path, params)

    def strip(manifest):
        for info in manifest["artifacts"].values():
            for key in ("plan", "device", "t_service_s", "sharding"):
                info.pop(key, None)

    _edit_manifest(tmp_path, strip)
    rep = verify_store(str(tmp_path), expected=expected_artifact_names(params))
    assert rep.ok, rep.summary()
    back = restore_programmed(str(tmp_path))
    art = back.by_name["wq"]
    assert art.plan is None and art.device is None and art.t_service_s == 0.0
    np.testing.assert_array_equal(
        np.asarray(art.g_eff), np.asarray(prog.by_name["wq"].g_eff)
    )


def test_engine_refuses_store_failing_static_verification(tmp_path):
    """ServingEngine(restore_artifacts=) runs verify_store fail-fast: an
    internally corrupt store is refused at construction with an error
    naming the checker, before any restore work happens."""
    from benchmarks.noise_sweep import tiny_lm_config
    from repro.models import model as M
    from repro.models.layers import CrossbarMode
    from repro.serving.engine import ServingEngine

    _saved_store(tmp_path, _params(), planned=False)

    def corrupt(manifest):
        manifest["artifacts"]["wq"]["spec"] = {"bogus_field": 1}

    _edit_manifest(tmp_path, corrupt)
    cfg = tiny_lm_config()
    params, _ = M.init_model(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    with pytest.raises(ValueError, match="static verification"):
        ServingEngine(
            cfg, params, max_batch=1, max_seq=16,
            crossbar=CrossbarMode(enabled=True),
            restore_artifacts=str(tmp_path),
        )
