"""Validation of the analytic hardware model against the paper's claims.

The paper's evaluation is analytic (CACTI/Orion/ADC-survey constants, Table
I); we rebuild it bottom-up from mechanisms.  Absolute anchors are asserted
with wide bands (their exact spreadsheet constants are unpublished); the
*relative* technique deltas — the paper's actual claims — are asserted
tightly, with deviations documented in EXPERIMENTS.md §Repro-validation.
"""
import numpy as np
import pytest

from repro.core import arch, energy as en, mapper, workloads as wl


@pytest.fixture(scope="module")
def suite_results():
    return en.evaluate_suite(wl.benchmark_suite())


def test_table2_network_stats():
    # paper: MSRA Prelu-net has 330M params, 5.5x Alexnet's (~60M)
    msra_c = wl.msra("c")
    assert 300e6 < msra_c.total_weights < 360e6
    alex = wl.alexnet()
    assert 55e6 < alex.total_weights < 70e6
    assert 4.5 < msra_c.total_weights / alex.total_weights < 6.5
    # resnet-34: ~3.6 GMACs, ~21M params
    rn = wl.resnet34()
    assert 15e6 < rn.total_weights < 30e6
    assert 3e9 < rn.total_macs < 5e9


def test_isaac_chip_anchors():
    isaac = arch.ISAAC_CHIP
    # 168 tiles x 12 IMAs x 8 crossbars of 128x128 @ 16 cycles => 41.3 TOPS
    assert isaac.peak_gops() == pytest.approx(41300, rel=0.02)
    # ADC dominates: Newton §V says ~49% of ISAAC chip power
    pa = isaac.conv_tile.power_area()
    share = pa["ima_adc"].power_w * isaac.tiles / isaac.total_power_w()
    assert 0.42 < share < 0.58


def test_isaac_pj_per_op_anchor(suite_results):
    pj = np.mean([r["isaac"].pj_per_op for r in suite_results.values()])
    # paper: 1.8 pJ/op (our Table-I Kull ADC at 3.1 mW lands higher; ISAAC's
    # own table used ~2 mW for the same part — band covers both)
    assert 1.4 < pj < 2.6


def test_newton_vs_isaac_headline(suite_results):
    h = en.headline(suite_results)
    # paper: 77% power decrease, 51% energy decrease, 2.2x throughput/area.
    # Mechanism-derived model bands (deviations documented):
    assert 0.50 < h["power_decrease"] < 0.85
    assert 0.35 < h["energy_decrease"] < 0.60
    assert 1.8 < h["throughput_per_area_x"] < 2.5
    # ordering of the claims must hold: power drops more than energy
    assert h["power_decrease"] > h["energy_decrease"]


def test_newton_pj_per_op_improvement(suite_results):
    pj_i = np.mean([r["isaac"].pj_per_op for r in suite_results.values()])
    pj_n = np.mean([r["newton (+strassen)"].pj_per_op for r in suite_results.values()])
    # paper: 1.8 -> 0.85 pJ/op (ratio 0.47); we land within [0.4, 0.65]
    assert 0.40 < pj_n / pj_i < 0.65
    assert pj_n > arch.IDEAL_NEURON_PJ  # can't beat the ideal neuron


def test_technique_stack_directions(suite_results):
    """Each technique moves the metric the paper says it moves."""
    labels = [l for l, _, _, _ in en.technique_stack()]

    def mean(metric, lab):
        return np.mean([getattr(suite_results[n][lab], metric) for n in suite_results])

    # T1 compact HTree: large area-efficiency gain, power drops (Fig 11)
    assert mean("ce", "+compact-htree") > 1.25 * mean("ce", "isaac")
    assert mean("peak_power_w", "+compact-htree") < 0.95 * mean("peak_power_w", "isaac")
    # T2 adaptive ADC: power drops ~15% (Fig 12)
    r = mean("peak_power_w", "+adaptive-adc") / mean("peak_power_w", "+compact-htree")
    assert 0.78 < r < 0.92
    # T3 Karatsuba: energy down, area efficiency slightly down (Fig 13/14)
    assert mean("energy_per_sample_j", "+karatsuba") < mean("energy_per_sample_j", "+adaptive-adc")
    assert mean("ce", "+karatsuba") < mean("ce", "+adaptive-adc")
    # buffers: area efficiency up ~6.5% (Fig 16)
    r = mean("ce", "+small-buffers") / mean("ce", "+karatsuba")
    assert 1.02 < r < 1.10
    # FC tiles: big power reduction (Fig 17), area efficiency up (Fig 18)
    r = mean("peak_power_w", "+fc-tiles") / mean("peak_power_w", "+small-buffers")
    assert r < 0.80
    assert mean("ce", "+fc-tiles") > mean("ce", "+small-buffers")
    # Strassen: energy efficiency gain, modest (Fig 19)
    r = mean("energy_per_sample_j", labels[-1]) / mean("energy_per_sample_j", "+fc-tiles")
    assert 0.75 < r < 0.99


def test_htree_credit_requires_compact_links():
    """Adaptive ADC narrows the *shared compact* HTree links to 16 bits; a
    non-compact chip has no shared links, so flipping its ADC adaptive must
    not change HTree energy (the override is gated on ``compact_htree``)."""
    net = wl.alexnet()
    e_plain = en.evaluate(
        net,
        arch.newton_chip(compact=False, adaptive=False, karatsuba=0,
                         small_buffers=False, fc_tiles=False),
        policy="newton",
    ).breakdown["htree"]
    e_adaptive = en.evaluate(
        net,
        arch.newton_chip(compact=False, adaptive=True, karatsuba=0,
                         small_buffers=False, fc_tiles=False),
        policy="newton",
    ).breakdown["htree"]
    assert e_adaptive == pytest.approx(e_plain)
    # with compact links the adaptive trim does apply (23+16 -> 16+16 bits)
    e_c = en.evaluate(
        net,
        arch.newton_chip(compact=True, adaptive=False, karatsuba=0,
                         small_buffers=False, fc_tiles=False),
        policy="newton",
    ).breakdown["htree"]
    e_ca = en.evaluate(
        net,
        arch.newton_chip(compact=True, adaptive=True, karatsuba=0,
                         small_buffers=False, fc_tiles=False),
        policy="newton",
    ).breakdown["htree"]
    assert e_ca == pytest.approx(e_c * 32 / 39)


def test_technique_stack_orderings_pinned():
    """The shipped cumulative stack always pairs adaptive ADC with the
    compact HTree (so the gated 16-bit link credit still applies to every
    shipped entry), and each technique is introduced exactly once, in the
    paper's order."""
    stack = en.technique_stack()
    labels = [lab for lab, _, _, _ in stack]
    assert labels == [
        "isaac", "+compact-htree", "+adaptive-adc", "+karatsuba",
        "+small-buffers", "+fc-tiles", "newton (+strassen)",
    ]
    for lab, chip, policy, strassen in stack[1:]:
        ima = chip.conv_tile.ima
        if ima.adc_cfg.mode == "adaptive":
            assert ima.compact_htree, lab
    assert [s for _, _, _, s in stack] == [False] * 6 + [True]


def test_resnet_gains_least(suite_results):
    """Paper §V: Resnet does not gain much from heterogeneous FC tiles."""
    last, base = "newton (+strassen)", "isaac"
    ratios = {
        n: suite_results[n][last].peak_power_w / suite_results[n][base].peak_power_w
        for n in suite_results
    }
    assert ratios["resnet-34"] == max(ratios.values())


def test_fig10_underutilization_trend():
    sizes = [(128, 128), (128, 256), (512, 256), (2048, 1024), (8192, 1024)]
    uu = mapper.underutilization_sweep(wl.benchmark_suite(), sizes, arch.NEWTON_CHIP)
    vals = list(uu.values())
    # monotone-ish growth with IMA size; chosen point (128x256) is small
    assert vals == sorted(vals)
    assert uu["128x256"] < 0.12  # paper: ~9%
    assert uu["8192x1024"] > 0.45  # paper: "quite significant"


def test_buffer_requirement_band():
    """Fig 15: Newton's spreading brings per-tile buffers well under 64 KB
    (16 KB chosen for 256x256 images; 224x224 suite lands below that)."""
    for net in wl.benchmark_suite():
        m = mapper.map_network(net, arch.NEWTON_CHIP, policy="newton")
        assert m.mean_tile_buffer_bytes < 32 * 1024
    worst = max(
        mapper.map_network(n, arch.ISAAC_CHIP, policy="isaac").worst_tile_buffer_bytes
        for n in wl.benchmark_suite()
    )
    assert worst > 32 * 1024  # ISAAC's worst case motivates its 64 KB


def test_fc_replication_keeps_throughput():
    """T5: slowing FC ADCs must not lower pipeline throughput (paper Fig 17)."""
    for net in (wl.resnet34(), wl.vgg("a")):
        fast = mapper.map_network(net, arch.newton_chip(fc_tiles=False), policy="newton")
        slow = mapper.map_network(net, arch.newton_chip(fc_tiles=True), policy="newton")
        assert slow.throughput_samples_s == pytest.approx(fast.throughput_samples_s)


def test_tpu_comparison_direction():
    """Fig 24: the 8-bit Newton beats the TPU-1 model on throughput for the
    large networks (the paper notes Alexnet/Resnet gain least because small
    networks batch well on the TPU)."""
    tpu = en.TPUModel()
    chip8 = arch.newton_chip_8bit()
    wins = {}
    for net in (wl.msra("a"), wl.msra("c"), wl.vgg("d"), wl.alexnet()):
        b = tpu.best_batch(net)
        tpu_thpt = tpu.throughput(net, b)
        newton = en.evaluate(net, chip8, policy="newton", strassen=True)
        newton_thpt = newton.throughput_samples_s * (tpu.area_mm2 / newton.area_mm2)
        wins[net.name] = newton_thpt / tpu_thpt
    assert wins["msra-a"] > 1.0 and wins["msra-c"] > 1.0 and wins["vgg-d"] > 1.0
    # weight-heavy nets (batch-1 on TPU) gain the most — paper's MSRA story
    assert wins["msra-c"] > wins["alexnet"]
