"""Pallas kernel validation: interpret-mode execution vs the pure-jnp oracle
across shape/dtype/ADC-config sweeps (bit-identical, not just allclose)."""
import functools
import zlib

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from _propcheck import integers, sweep

from repro.core import adc
from repro.core.crossbar import CrossbarSpec, DEFAULT_SPEC
from repro.device import DeviceConfig, effective_cell_codes
from repro.kernels import ops, ref

SPEC_S = DEFAULT_SPEC
SPEC_U = DEFAULT_SPEC.replace(signed_weights=False)


def _data(rng, B, K, N, signed=True):
    x = rng.integers(0, 1 << 16, size=(B, K))
    lim = (1 << 15) if signed else (1 << 16)
    lo = -(1 << 15) if signed else 0
    w = rng.integers(lo, lim, size=(K, N))
    return jnp.asarray(x), jnp.asarray(w)


@pytest.mark.parametrize(
    "shape",
    [
        (1, 128, 8),
        (4, 128, 16),
        (3, 300, 40),
        pytest.param((130, 257, 129), marks=pytest.mark.slow),
        (2, 64, 256),
        pytest.param((16, 1024, 64), marks=pytest.mark.slow),
    ],
)
def test_kernel_matches_ref_shapes(shape):
    rng = np.random.default_rng(sum(shape))
    x, w = _data(rng, *shape)
    y_k = ops.crossbar_vmm_op(x, w, SPEC_S, interpret=True)
    y_r = ref.crossbar_vmm_ref(x, w, SPEC_S)
    np.testing.assert_array_equal(np.asarray(y_k), np.asarray(y_r))


@pytest.mark.parametrize("shape", [(4, 128, 16), (3, 300, 40)])
def test_fast_kernel_matches_ref(shape):
    rng = np.random.default_rng(sum(shape) + 1)
    x, w = _data(rng, *shape)
    y_k = ops.crossbar_vmm_op(x, w, SPEC_S, fast=True, interpret=True)
    y_r = ref.crossbar_vmm_ref(x, w, SPEC_S)
    np.testing.assert_array_equal(np.asarray(y_k), np.asarray(y_r))


@pytest.mark.parametrize("cfg", [adc.SAFE_ADAPTIVE, adc.EXACT_ADAPTIVE])
@pytest.mark.parametrize("signed", [True, False])
def test_kernel_adaptive_adc(cfg, signed):
    rng = np.random.default_rng(13 + signed)
    spec = SPEC_S if signed else SPEC_U
    x, w = _data(rng, 8, 384, 32, signed=signed)
    y_k = ops.crossbar_vmm_op(x, w, spec, adc_cfg=cfg, interpret=True)
    y_r = ref.crossbar_vmm_ref(x, w, spec, adc_cfg=cfg)
    np.testing.assert_array_equal(np.asarray(y_k), np.asarray(y_r))


@pytest.mark.parametrize(
    "spec",
    [
        CrossbarSpec(weight_bits=8, input_bits=8, out_bits=8, drop_lsb=7),
        CrossbarSpec(cell_bits=4, dac_bits=2),
        CrossbarSpec(rows=64),
    ],
    ids=["w8a8", "cell4dac2", "rows64"],
)
def test_kernel_spec_variants(spec):
    rng = np.random.default_rng(spec.rows + spec.cell_bits)
    x = jnp.asarray(rng.integers(0, 1 << spec.input_bits, size=(4, 200)))
    w = jnp.asarray(
        rng.integers(-(1 << (spec.weight_bits - 1)), 1 << (spec.weight_bits - 1), size=(200, 24))
    )
    y_k = ops.crossbar_vmm_op(x, w, spec, interpret=True)
    y_r = ref.crossbar_vmm_ref(x, w, spec)
    np.testing.assert_array_equal(np.asarray(y_k), np.asarray(y_r))


@pytest.mark.slow
@sweep(
    integers(1, 8),
    integers(1, 300),
    integers(1, 40),
    integers(0, 2**32 - 1),
    examples=10,
)
def test_kernel_property(B, K, N, seed):
    rng = np.random.default_rng(seed)
    x, w = _data(rng, B, K, N)
    y_k = ops.crossbar_vmm_op(x, w, SPEC_S, interpret=True)
    y_r = ref.crossbar_vmm_ref(x, w, SPEC_S)
    np.testing.assert_array_equal(np.asarray(y_k), np.asarray(y_r))


# ---------------------------------------------------------------------------
# Bit-identity matrix: every Pallas kernel x skip_zero_planes x jit x input
# sparsity vs the dense jnp reference — one grid instead of ad-hoc per-kernel
# coverage (the zero-plane early-out, outer-jit tracing and repaired g_eff
# layouts all ride these same entry points).
# ---------------------------------------------------------------------------

_MB, _MK, _MN = 2, 160, 16  # K=160 pads to two 128-row groups
_MDEV = DeviceConfig(sigma=0.1, p_stuck_on=2e-3, p_stuck_off=2e-3, seed=11)


def _matrix_inputs(case_id: str, sparse: bool):
    rng = np.random.default_rng(zlib.crc32(case_id.encode()))
    if sparse:  # post-ReLU style: mostly zero, codes confined to low planes
        x = rng.integers(0, 1 << 9, size=(_MB, _MK)) * (rng.random((_MB, _MK)) < 0.3)
    else:
        x = rng.integers(0, 1 << 16, size=(_MB, _MK))
    w = rng.integers(-(1 << 15), 1 << 15, size=(_MK, _MN))
    return jnp.asarray(x), jnp.asarray(w)


@pytest.mark.parametrize("sparse", [False, True], ids=["dense_x", "sparse_x"])
@pytest.mark.parametrize("use_jit", [False, True], ids=["eager", "jit"])
@pytest.mark.parametrize("skip", [True, False], ids=["skip", "dense_loop"])
@pytest.mark.parametrize("kernel", ["paper", "fast", "noisy"])
def test_kernel_bit_identity_matrix(kernel, skip, use_jit, sparse):
    x, w = _matrix_inputs(f"{kernel}-{sparse}", sparse)
    if kernel == "noisy":
        g = effective_cell_codes(w.astype(jnp.int32) + SPEC_S.weight_bias, SPEC_S, _MDEV)
        fn = functools.partial(
            ops.noisy_vmm_op, spec=SPEC_S, interpret=True, skip_zero_planes=skip
        )
        args = (x, g)
        y_ref = ref.noisy_vmm_ref(x, g, SPEC_S)
    else:
        fn = functools.partial(
            ops.crossbar_vmm_op,
            spec=SPEC_S,
            fast=(kernel == "fast"),
            interpret=True,
            skip_zero_planes=skip,
        )
        args = (x, w)
        y_ref = ref.crossbar_vmm_ref(x, w, SPEC_S)
    if use_jit:
        fn = jax.jit(fn)
    y = fn(*args)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y_ref))


def test_float_crossbar_matmul_fidelity():
    """The float wrapper approximates x @ w to W16A16 quantization error."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(np.abs(rng.normal(size=(16, 256))).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(256, 64)).astype(np.float32))
    y = ops.crossbar_matmul(x, w, interpret=True)
    exact = x @ w
    rel = np.linalg.norm(np.asarray(y - exact)) / np.linalg.norm(np.asarray(exact))
    # 16-bit fixed point with worst-case (static) per-layer output scaling
    assert rel < 5e-3


def test_slstm_scan_kernel_matches_jnp():
    """Fused sLSTM recurrence kernel == the pure-jnp scan (bitwise-close),
    including the carried final state."""
    import jax
    from repro import configs
    from repro.configs.base import reduced
    from repro.models import xlstm as X
    from repro.models.layers import Init
    from repro.kernels.slstm_scan import slstm_scan_pallas

    cfg = reduced(configs.get_config("xlstm-350m"))
    ini = Init(key=jax.random.PRNGKey(0))
    X.init_slstm(ini, cfg)
    params = ini.params
    B, S = 2, 24
    din, H = X.d_inner_of(cfg), cfg.n_heads
    dh = din // H
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model)) * 0.5
    y_ref, _ = X.slstm_block(params, x, cfg, None, decode=False)
    pre = (x @ params["w_in"]).reshape(B, S, 4, H, dh)
    z = jnp.zeros((B, H, dh), jnp.float32)
    h_all, c1, n1, h1 = slstm_scan_pallas(
        pre, params["r_z"], params["r_i"], params["r_f"], params["r_o"],
        z, jnp.ones_like(z), z, interpret=True,
    )
    y_k = h_all.reshape(B, S, din) @ params["out_proj"]
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref), atol=1e-5)
    # final state consistent with step-by-step decode
    cache = X.init_xlstm_cache(cfg, "slstm", B)
    for t in range(S):
        _, cache = X.slstm_block(params, x[:, t : t + 1], cfg, cache, decode=True)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(cache["c"]), atol=1e-5)


def test_batched_leading_dims():
    rng = np.random.default_rng(9)
    x = jnp.asarray(rng.integers(0, 1 << 16, size=(2, 3, 128)))
    w = jnp.asarray(rng.integers(-(1 << 15), 1 << 15, size=(128, 16)))
    y = ops.crossbar_vmm_op(x, w, SPEC_S, interpret=True)
    assert y.shape == (2, 3, 16)
    y_r = ref.crossbar_vmm_ref(x, w, SPEC_S)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y_r))
