"""Learning-rate schedules (pure functions of the int32 step)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    def f(step):
        return jnp.asarray(lr, jnp.float32)

    return f


def linear_warmup(lr: float, warmup_steps: int):
    def f(step):
        frac = jnp.minimum(step / jnp.maximum(warmup_steps, 1), 1.0)
        return jnp.asarray(lr, jnp.float32) * frac

    return f


def cosine_with_warmup(lr: float, warmup_steps: int, total_steps: int, min_ratio: float = 0.1):
    def f(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
        warm = jnp.minimum(step / jnp.maximum(warmup_steps, 1), 1.0)
        prog = jnp.clip(
            (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0
        )
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.asarray(lr, jnp.float32) * warm * cos

    return f
