from repro.optim.optimizers import (  # noqa: F401
    Optimizer,
    adamw,
    adafactor,
    sgd,
    make_optimizer,
    global_norm,
    clip_by_global_norm,
)
from repro.optim.schedules import (  # noqa: F401
    constant,
    cosine_with_warmup,
    linear_warmup,
)
