"""Optimizers in pure JAX (no optax): SGD-momentum, AdamW, and Adafactor.

Adafactor (Shazeer & Stern) keeps *factored* second moments for >=2-D
parameters — row and column accumulators instead of a full tensor — which is
what makes optimizer state for the 1T-parameter kimi-k2 MoE fit on a 256-chip
pod (EXPERIMENTS.md §Dry-run records the bytes).  Optimizer state mirrors the
parameter PartitionSpecs, so states shard exactly like their parameters
(ZeRO-style for the factored vectors).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, jnp.ndarray], Tuple[Any, Any]]
    # update(grads, state, params, step) -> (new_params, new_state)


LAYERWISE_MIN_DIM = 3  # leaves stacked over layers get chunked updates


def _maybe_layerwise(fn, *args):
    """Apply an elementwise update per layer-slice for stacked leaves.

    Optimizer math materializes several f32 copies of each leaf; for
    layer-stacked MoE tensors (e.g. kimi-k2 wi: (60, 384, 7168, 4096)) that
    is tens of GB of transients.  Scanning over the leading (layers) axis
    bounds the f32 working set to one layer's slice.
    """
    p = args[0]
    if p.ndim >= LAYERWISE_MIN_DIM and p.shape[0] <= 128 and p.size > (1 << 24):
        return jax.lax.map(lambda xs: fn(*xs), args)
    return fn(*args)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree), norm


def sgd(lr_fn, momentum: float = 0.9, clip_norm: float = 1.0) -> Optimizer:
    def init(params):
        return {"mu": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)}

    def update(grads, state, params, step):
        grads, _ = clip_by_global_norm(grads, clip_norm)
        lr = lr_fn(step)
        mu = jax.tree.map(
            lambda m, g: momentum * m + g.astype(jnp.float32), state["mu"], grads
        )
        new_params = jax.tree.map(lambda p, m: (p - lr * m).astype(p.dtype), params, mu)
        return new_params, {"mu": mu}

    return Optimizer(init, update)


def adamw(
    lr_fn,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    clip_norm: float = 1.0,
) -> Optimizer:
    def init(params):
        z = lambda p: jnp.zeros_like(p, jnp.float32)
        return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params)}

    def update(grads, state, params, step):
        grads, _ = clip_by_global_norm(grads, clip_norm)
        lr = lr_fn(step)
        t = step.astype(jnp.float32) + 1.0
        c1 = 1.0 - b1**t
        c2 = 1.0 - b2**t

        def upd_inner(p, g, m, v):
            gf = g.astype(jnp.float32)
            m1 = b1 * m + (1 - b1) * gf
            v1 = b2 * v + (1 - b2) * gf * gf
            u = (m1 / c1) / (jnp.sqrt(v1 / c2) + eps)
            p1 = p.astype(jnp.float32) - lr * (u + weight_decay * p.astype(jnp.float32))
            return p1.astype(p.dtype), m1, v1

        def upd(p, g, m, v):
            return _maybe_layerwise(upd_inner, p, g, m, v)

        out = jax.tree.map(upd, params, grads, state["m"], state["v"])
        new_params = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"m": m, "v": v}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# Adafactor
# ---------------------------------------------------------------------------

def _is_factored(shape, min_size: int) -> bool:
    """Factor over the last two dims (handles (E, D, F) MoE stacks per-expert)."""
    return len(shape) >= 2 and shape[-1] >= min_size and shape[-2] >= min_size


def adafactor(
    lr_fn,
    decay: float = 0.8,
    eps: float = 1e-30,
    clip_threshold: float = 1.0,
    weight_decay: float = 0.0,
    min_dim_size_to_factor: int = 128,
) -> Optimizer:
    def init(params):
        def one(p):
            if _is_factored(p.shape, min_dim_size_to_factor):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),  # mean over cols
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return {"acc": jax.tree.map(one, params)}

    def update(grads, state, params, step):
        lr = lr_fn(step)
        t = step.astype(jnp.float32) + 1.0
        beta = 1.0 - t ** (-decay)

        def one_inner(p, g, vr_or_v, vc=None):
            gf = g.astype(jnp.float32)
            g2 = gf * gf + eps
            if vc is not None:
                vr = beta * vr_or_v + (1 - beta) * jnp.mean(g2, axis=-1)
                vc1 = beta * vc + (1 - beta) * jnp.mean(g2, axis=-2)
                # v_hat = outer(vr, vc) / mean(vr) (Shazeer & Stern eq. 4)
                vr_n = vr / jnp.mean(vr, axis=-1, keepdims=True).clip(1e-30)
                v_hat = vr_n[..., :, None] * vc1[..., None, :]
                u = gf * jax.lax.rsqrt(v_hat.clip(eps))
                new_acc = {"vr": vr, "vc": vc1}
            else:
                v = beta * vr_or_v + (1 - beta) * g2
                u = gf * jax.lax.rsqrt(v.clip(eps))
                new_acc = {"v": v}
            # update clipping by RMS
            rms_u = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-30)
            u = u / jnp.maximum(1.0, rms_u / clip_threshold)
            p1 = p.astype(jnp.float32) - lr * u
            if weight_decay:
                p1 = p1 - lr * weight_decay * p.astype(jnp.float32)
            return p1.astype(p.dtype), new_acc

        def one(p, g, acc):
            if "vr" in acc:
                return _maybe_layerwise(one_inner, p, g, acc["vr"], acc["vc"])
            return _maybe_layerwise(one_inner, p, g, acc["v"])

        flat_p, tree = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        flat_a = tree.flatten_up_to(state["acc"])
        outs = [one(p, g, a) for p, g, a in zip(flat_p, flat_g, flat_a)]
        new_params = tree.unflatten([o[0] for o in outs])
        new_acc = tree.unflatten([o[1] for o in outs])
        return new_params, {"acc": new_acc}

    return Optimizer(init, update)


def make_optimizer(name: str, lr_fn, **kw) -> Optimizer:
    if name == "adamw":
        return adamw(lr_fn, **kw)
    if name == "adafactor":
        return adafactor(lr_fn, **kw)
    if name == "sgd":
        return sgd(lr_fn, **kw)
    raise ValueError(name)
