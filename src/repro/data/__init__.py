from repro.data.pipeline import (  # noqa: F401
    SyntheticLMDataset,
    MemmapLMDataset,
    EmbeddingStubDataset,
    make_dataset,
    prefetch,
)
