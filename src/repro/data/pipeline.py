"""Deterministic, restart-safe data pipeline.

Every batch is a pure function of (seed, step, host slice), so a restarted
job resumes mid-epoch by just setting the step counter — no iterator state
to checkpoint (the fault-tolerance story in DESIGN.md §4).  Hosts read only
their slice of the global batch; ``prefetch`` overlaps host-side batch
assembly with device compute via a background thread.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import numpy as np


class SyntheticLMDataset:
    """Deterministic synthetic token stream (counter-based RNG per batch)."""

    def __init__(
        self,
        vocab_size: int,
        seq_len: int,
        global_batch: int,
        seed: int = 0,
        process_index: Optional[int] = None,
        process_count: Optional[int] = None,
    ):
        self.vocab = vocab_size
        self.seq = seq_len
        self.global_batch = global_batch
        self.seed = seed
        self.pi = jax.process_index() if process_index is None else process_index
        self.pc = jax.process_count() if process_count is None else process_count
        assert global_batch % self.pc == 0
        self.local_batch = global_batch // self.pc

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.Philox(key=self.seed + step * 1000003 + self.pi)
        gen = np.random.Generator(rng)
        toks = gen.integers(
            0, self.vocab, size=(self.local_batch, self.seq + 1), dtype=np.int32
        )
        return {"inputs": toks[:, :-1], "targets": toks[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class MemmapLMDataset:
    """Token file (np.memmap, int32) chunked into fixed windows.

    Window assignment is a pure function of (step, host, index) so restarts
    are deterministic; wraps around at the end of the file.
    """

    def __init__(
        self,
        path: str,
        seq_len: int,
        global_batch: int,
        seed: int = 0,
        process_index: Optional[int] = None,
        process_count: Optional[int] = None,
    ):
        self.tokens = np.memmap(path, dtype=np.int32, mode="r")
        self.seq = seq_len
        self.global_batch = global_batch
        self.pi = jax.process_index() if process_index is None else process_index
        self.pc = jax.process_count() if process_count is None else process_count
        self.local_batch = global_batch // self.pc
        self.n_windows = max(1, (len(self.tokens) - 1) // seq_len)
        self.seed = seed

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        base = step * self.global_batch + self.pi * self.local_batch
        idx = (base + np.arange(self.local_batch)) % self.n_windows
        # deterministic shuffle of window order
        rng = np.random.Generator(np.random.Philox(key=self.seed))
        perm = rng.permutation(self.n_windows)
        starts = perm[idx] * self.seq
        rows = np.stack([self.tokens[s : s + self.seq + 1] for s in starts])
        return {"inputs": rows[:, :-1].astype(np.int32), "targets": rows[:, 1:].astype(np.int32)}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class EmbeddingStubDataset:
    """Modality-frontend stub for [audio]/[vlm] archs: precomputed frame/patch
    embeddings (as the assignment specifies) + token targets."""

    def __init__(self, d_model: int, vocab_size: int, seq_len: int, global_batch: int,
                 seed: int = 0, process_index: Optional[int] = None, process_count: Optional[int] = None):
        self.d = d_model
        self.vocab = vocab_size
        self.seq = seq_len
        self.pi = jax.process_index() if process_index is None else process_index
        self.pc = jax.process_count() if process_count is None else process_count
        self.local_batch = global_batch // self.pc
        self.seed = seed

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        gen = np.random.Generator(np.random.Philox(key=self.seed + step * 7919 + self.pi))
        emb = gen.standard_normal((self.local_batch, self.seq, self.d)).astype(np.float32)
        tgt = gen.integers(0, self.vocab, size=(self.local_batch, self.seq), dtype=np.int32)
        return {"inputs": emb, "targets": tgt}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def make_dataset(cfg, seq_len: int, global_batch: int, seed: int = 0, path: Optional[str] = None):
    if cfg.frontend == "embed":
        return EmbeddingStubDataset(cfg.d_model, cfg.vocab_size, seq_len, global_batch, seed)
    if path:
        return MemmapLMDataset(path, seq_len, global_batch, seed)
    return SyntheticLMDataset(cfg.vocab_size, seq_len, global_batch, seed)


def prefetch(it: Iterator, size: int = 2) -> Iterator:
    """Background-thread prefetch: overlaps batch assembly with compute."""
    q: "queue.Queue" = queue.Queue(maxsize=size)
    stop = object()

    def worker():
        try:
            for item in it:
                q.put(item)
        finally:
            q.put(stop)

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    while True:
        item = q.get()
        if item is stop:
            return
        yield item
