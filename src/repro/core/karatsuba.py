"""Karatsuba bit-level divide & conquer on the crossbar datapath (§III.A.1).

The 16b x 16b product is decomposed into three narrower products that run on
separate crossbars (Fig 3 / Fig 9):

    W = 2^h W1 + W0,  X = 2^h X1 + X0        (h = 8)
    WX = 2^2h W1X1 + 2^h [(W1+W0)(X1+X0) - W1X1 - W0X0] + W0X0

* ``A = W1 X1`` and ``B = W0 X0`` are 8b x 8b products: 4 slices x 8
  iterations each, run **in parallel** on the left crossbars of the IMA's 8
  mats (paper Fig 9) — 8 ADCs busy for 8 iterations.
* ``C = (W1+W0)(X1+X0)`` is a 9b x 9b product: 5 slices x 9 iterations on the
  right crossbars of 5 mats — 5 ADCs busy for 9 iterations.

ADC work drops from 8x16 = 128 conversion slots to 8x8 + 5x9 = 109 (-15%),
at +1 iteration of latency (17 vs 16) — exactly the paper's numbers, which
``karatsuba_stats`` reproduces and the benchmarks assert.

The recombination is exact integer arithmetic (two-limb), so the result is
bit-identical to the direct datapath — asserted by the property tests.
Recursion (``levels=2``) splits A, B, C again; the paper finds one level is
nearly as good as two and much simpler (Fig 13).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import fixedpoint as fxp
from repro.core.crossbar import (
    ConversionStats,
    CrossbarSpec,
    DEFAULT_SPEC,
    crossbar_accumulate,
    limb_add,
    limb_from_int_shifted,
    limb_normalize,
    limb_sub,
    requantize_exact_limbs,
)


def _sub_spec(spec: CrossbarSpec, in_bits: int, w_bits: int) -> CrossbarSpec:
    return spec.replace(
        input_bits=in_bits, weight_bits=w_bits, signed_weights=False
    )


def _accumulate_unsigned(x, w, spec: CrossbarSpec, in_bits: int, w_bits: int, levels: int):
    """Exact limb accumulator of unsigned x @ w, with `levels` of Karatsuba."""
    if levels == 0 or in_bits <= 2 or w_bits <= 2:
        acc, _ = crossbar_accumulate(x, w, _sub_spec(spec, in_bits, w_bits))
        return acc
    hx = in_bits // 2
    hw = w_bits // 2
    # Symmetric split keeps the algebra simple; the paper splits both at n/2.
    h = min(hx, hw)
    x0, x1 = x & ((1 << h) - 1), x >> h
    w0, w1 = w & ((1 << h) - 1), w >> h
    in_hi_bits, w_hi_bits = in_bits - h, w_bits - h
    A = _accumulate_unsigned(x1, w1, spec, in_hi_bits, w_hi_bits, levels - 1)
    B = _accumulate_unsigned(x0, w0, spec, h, h, levels - 1)
    C = _accumulate_unsigned(
        x0 + x1, w0 + w1, spec, max(h, in_hi_bits) + 1, max(h, w_hi_bits) + 1, levels - 1
    )
    # WX = 2^2h A + 2^h (C - A - B) + B
    mid = limb_sub(limb_sub(C, A), B)
    total = limb_add(_limb_shift(A, 2 * h), limb_add(_limb_shift(mid, h), B))
    return total


def _limb_shift(acc, shift: int):
    """Shift a normalized limb pair left by ``shift`` bits, exactly.

    value = hi * 2^20 + lo; shifted = hi * 2^(20+shift) + lo * 2^shift.
    Both pieces are re-decomposed through ``limb_from_int_shifted``; ``hi``
    must satisfy |hi| < 2^30 / 2^shift after shifting into the hi limb, which
    holds for all uses here (sub-products <= 2^26 before shifting).
    """
    if shift == 0:
        return limb_normalize(*acc)
    hi, lo = limb_normalize(*acc)
    h1, l1 = limb_from_int_shifted(lo, shift)
    # hi * 2^(20+shift): lands entirely in the hi limb
    return limb_normalize(h1 + (hi << shift), l1)


def karatsuba_vmm(
    x_codes: jnp.ndarray,
    w_codes: jnp.ndarray,
    spec: CrossbarSpec = DEFAULT_SPEC,
    levels: int = 1,
) -> jnp.ndarray:
    """Karatsuba crossbar VMM — bit-identical to ``crossbar.crossbar_vmm``.

    x_codes: (..., K) unsigned input codes; w_codes: (K, N) signed codes if
    ``spec.signed_weights``.  The biased weight code is split (the halves of a
    biased code are themselves unsigned), and the bias is removed digitally at
    the end exactly as in the direct datapath.
    """
    batch_shape = x_codes.shape[:-1]
    K = x_codes.shape[-1]
    x = x_codes.reshape(-1, K).astype(jnp.int32)
    w = w_codes.astype(jnp.int32) + spec.weight_bias  # biased unsigned
    acc = _accumulate_unsigned(x, w, spec, spec.input_bits, spec.weight_bits, levels)
    if spec.signed_weights:
        x_sum = jnp.sum(x, axis=-1)[:, None]
        b = limb_from_int_shifted(x_sum, spec.weight_bits - 1)
        acc = limb_sub(acc, (jnp.broadcast_to(b[0], acc[0].shape), jnp.broadcast_to(b[1], acc[1].shape)))
    y = requantize_exact_limbs(acc, spec, signed_out=spec.signed_weights)
    return y.reshape(batch_shape + (w_codes.shape[-1],))


# ---------------------------------------------------------------------------
# ADC-work accounting (paper Fig 9 mapping / Fig 13 comparison)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class KaratsubaCost:
    """Conversion-slot accounting for one 128-wide column group.

    ``adc_slots``: (#ADC conversions) summed over the schedule — the paper's
    "ADC use".  ``iterations``: pipeline latency in 100 ns crossbar cycles.
    ``crossbars``: crossbars occupied per 128x128 weight tile.
    """

    adc_slots: int
    iterations: int
    crossbars: int

    @property
    def adc_reduction_vs_baseline(self) -> float:
        base = DEFAULT_SPEC.n_iters * DEFAULT_SPEC.n_slices
        return 1.0 - self.adc_slots / base


def karatsuba_cost(levels: int, spec: CrossbarSpec = DEFAULT_SPEC) -> KaratsubaCost:
    """Analytic ADC-slot cost of `levels` of divide & conquer (paper numbers).

    level 0: 8 slices x 16 iters = 128 slots, 16 iters, 8 crossbars.
    level 1: A,B (4 slices x 8 iters each, parallel) + C (5 x 9)
             = 64 + 45 = 109 slots (-15%), 17 iters, 13 crossbars (8 mats x 2,
               3 unused right crossbars — Fig 9).
    level 2: paper: 8 ADCs busy 4 iters + 6 ADCs busy 10 iters = 92 slots
             (-28%), 14 iters, 20 crossbars.
    """
    if levels == 0:
        return KaratsubaCost(spec.n_iters * spec.n_slices, spec.n_iters, spec.n_slices)
    if levels == 1:
        # Split mirrors _accumulate_unsigned: h = min(in//2, w//2), so
        # A = W1X1 is an (in-h)b x (w-h)b product and B = W0X0 an h x h one
        # (identical only for the symmetric 16x16 default); C widens both
        # operand halves by one carry bit.
        h = min(spec.input_bits // 2, spec.weight_bits // 2)
        in_hi, w_hi = spec.input_bits - h, spec.weight_bits - h
        a = _cost_unsigned(in_hi, w_hi, spec)
        b = _cost_unsigned(h, h, spec)
        c = _cost_unsigned(max(h, in_hi) + 1, max(h, w_hi) + 1, spec)
        slots = a[0] + b[0] + c[0]
        iters = max(a[1], b[1]) + c[1]  # A,B parallel then C
        return KaratsubaCost(slots, iters, 13)
    if levels == 2:
        # Paper §III.C: "8 ADCs busy in the first 4 iterations, 6 ADCs in the
        # next 10 iterations" => 8*4 + 6*10 = 92 slots, 14 iterations,
        # 20 crossbars per IMA.
        return KaratsubaCost(92, 14, 20)
    raise ValueError("levels must be 0, 1, or 2")


def _cost_unsigned(
    in_bits: int, w_bits: int, spec: CrossbarSpec = DEFAULT_SPEC
) -> Tuple[int, int]:
    slices = -(-w_bits // spec.cell_bits)
    iters = -(-in_bits // spec.dac_bits)
    return slices * iters, iters


def karatsuba_stats(
    batch: int, k: int, n: int, spec: CrossbarSpec = DEFAULT_SPEC, levels: int = 1
) -> ConversionStats:
    """ADC work for one (batch, k) x (k, n) VMM under Karatsuba."""
    cost = karatsuba_cost(levels, spec)
    groups = -(-k // spec.rows)
    convs = batch * n * groups * cost.adc_slots
    return ConversionStats(
        conversions=convs,
        bit_decisions=convs * spec.adc_bits,
        iterations=cost.iterations,
    )
