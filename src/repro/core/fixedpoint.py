"""Fixed-point formats and bit decompositions for the crossbar datapath.

Newton/ISAAC represent a 16-bit weight as eight 2-bit cells ("slices") spread
across eight crossbars, and stream a 16-bit input one bit per cycle through a
1-bit DAC ("planes").  Everything here is pure jnp and bit-exact: recomposition
round-trips are the identity, which the property tests assert.

Conventions
-----------
* Inputs (activations) are unsigned ``Q(in_bits)`` integers (ISAAC assumes
  post-ReLU activations; signed activations are offset-encoded by the caller).
* Weights are signed and stored **biased**: ``w_biased = w + 2**(w_bits-1)``,
  so every cell is a non-negative conductance.  The bias is removed digitally
  after accumulation (see ``crossbar.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class QFormat:
    """Unsigned fixed-point format with ``bits`` total bits, ``frac`` fractional."""

    bits: int = 16
    frac: int = 0

    @property
    def max_int(self) -> int:
        return (1 << self.bits) - 1

    def quantize(self, x: jnp.ndarray) -> jnp.ndarray:
        """Real -> integer code (round-to-nearest, saturating)."""
        scaled = jnp.round(x * (1 << self.frac))
        return jnp.clip(scaled, 0, self.max_int).astype(jnp.int32)

    def dequantize(self, q: jnp.ndarray) -> jnp.ndarray:
        return q.astype(jnp.float32) / (1 << self.frac)


@dataclasses.dataclass(frozen=True)
class SignedQFormat:
    """Signed two's-complement fixed point, stored biased for crossbar cells."""

    bits: int = 16
    frac: int = 0

    @property
    def bias(self) -> int:
        return 1 << (self.bits - 1)

    @property
    def min_int(self) -> int:
        return -(1 << (self.bits - 1))

    @property
    def max_int(self) -> int:
        return (1 << (self.bits - 1)) - 1

    def quantize(self, x: jnp.ndarray) -> jnp.ndarray:
        scaled = jnp.round(x * (1 << self.frac))
        return jnp.clip(scaled, self.min_int, self.max_int).astype(jnp.int32)

    def to_biased(self, q: jnp.ndarray) -> jnp.ndarray:
        """Signed integer code -> biased unsigned cell code in [0, 2**bits)."""
        return (q + self.bias).astype(jnp.int32)

    def from_biased(self, b: jnp.ndarray) -> jnp.ndarray:
        return (b - self.bias).astype(jnp.int32)

    def dequantize(self, q: jnp.ndarray) -> jnp.ndarray:
        return q.astype(jnp.float32) / (1 << self.frac)


def bit_planes(x: jnp.ndarray, n_bits: int) -> jnp.ndarray:
    """Decompose unsigned integers into ``n_bits`` bit planes (LSB first).

    Returns shape ``(n_bits,) + x.shape`` with plane ``t`` holding bit ``t``,
    each entry in {0, 1}.
    """
    x = x.astype(jnp.int32)
    shifts = jnp.arange(n_bits, dtype=jnp.int32).reshape((n_bits,) + (1,) * x.ndim)
    return (x[None] >> shifts) & 1


def from_bit_planes(planes: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`bit_planes`."""
    n_bits = planes.shape[0]
    weights = (1 << jnp.arange(n_bits, dtype=jnp.int32)).reshape(
        (n_bits,) + (1,) * (planes.ndim - 1)
    )
    return jnp.sum(planes.astype(jnp.int32) * weights, axis=0)


def cell_slices(w: jnp.ndarray, n_bits: int, cell_bits: int) -> jnp.ndarray:
    """Decompose unsigned integers into ``ceil(n_bits/cell_bits)`` slices.

    Slice ``s`` holds bits ``[s*cell_bits, (s+1)*cell_bits)`` (LSB first); each
    entry lies in ``[0, 2**cell_bits)``.  Returns ``(n_slices,) + w.shape``.
    """
    n_slices = -(-n_bits // cell_bits)
    w = w.astype(jnp.int32)
    shifts = (cell_bits * jnp.arange(n_slices, dtype=jnp.int32)).reshape(
        (n_slices,) + (1,) * w.ndim
    )
    mask = (1 << cell_bits) - 1
    return (w[None] >> shifts) & mask


def from_cell_slices(slices: jnp.ndarray, cell_bits: int) -> jnp.ndarray:
    """Inverse of :func:`cell_slices`."""
    n_slices = slices.shape[0]
    weights = (1 << (cell_bits * jnp.arange(n_slices, dtype=jnp.int32))).reshape(
        (n_slices,) + (1,) * (slices.ndim - 1)
    )
    return jnp.sum(slices.astype(jnp.int32) * weights, axis=0)


def split_halves(v: jnp.ndarray, n_bits: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Split unsigned ``n_bits`` integers into (low, high) halves.

    Used by Karatsuba: ``v = hi * 2**(n_bits//2) + lo``.
    """
    half = n_bits // 2
    mask = (1 << half) - 1
    v = v.astype(jnp.int32)
    return v & mask, v >> half


def round_shift_right(v: jnp.ndarray, shift: int) -> jnp.ndarray:
    """Arithmetic right shift with round-half-up.

    This is the "rounding mode to generate carries" the paper adopts from
    Gupta et al. [11] when dropping LSBs.  Works on signed int32/int64.
    """
    if shift <= 0:
        return v
    half = jnp.asarray(1, v.dtype) << (shift - 1)
    return (v + half) >> shift
