"""Chip-plan compiler: per-layer heterogeneous datapath selection (§III).

The paper's techniques — Karatsuba bit-level divide & conquer (§III.A.1),
Strassen matrix blocking (§III.A.2), the adaptive SAR ADC schedule
(§III.A.3), and fault-aware spare-column provisioning — are all *per-layer*
choices: an fc projection with one output pixel cannot use Strassen, a
shallow layer gains nothing from two Karatsuba levels' extra crossbars, and
the spare budget a layer deserves scales with how salient its weights are
to the network output.  The modules implementing each technique price their
own choice (``karatsuba_cost``, ``strassen_cost``, ``adc.adaptive_schedule``
+ ``SARModel``, ``mapper.provision_spare_cols``); this pass composes them:
enumerate the candidate datapaths per layer, price each under the same
accounting ``core.energy.evaluate`` uses (conversions x per-conversion SAR
energy from the schedule histogram), and pick the minimum — emitting a
serializable ``LayerPlan`` per layer and a ``ChipPlan`` for the model.

Execution is wired through the programming pipeline: ``program_layer`` /
``program_model(plan=...)`` attach each layer's ``LayerPlan`` to the
compiled ``ProgrammedLinear`` (static aux — part of the jit cache key) and
materialize its choices (ADC config, spare-column budget);
``programmed_matmul`` then routes ideal-device artifacts through
``karatsuba_vmm`` / ``strassen_matmul``, which are bit-identical to the
direct datapath by exact limb arithmetic — a planned chip must produce the
same bits as the homogeneous compile (BENCH ``kernel_planned`` gates 1.0).
Noisy chips keep the device kernel for the analog stage (the effective-cell
read models physical arrays, which divide-and-conquer re-tiles rather than
re-reads); their plan still selects the ADC schedule the kernel applies and
the spare budget the repair planner programs.

Two accounting modes mirror ``strassen_cost``: ``widening="paper"``
reproduces the paper's 7/8-per-level Strassen claim (combined operands
reuse the 16-bit datapath); ``"exact"`` charges the extra slice + iteration
the bit-exact implementation actually pays — under which Strassen is a net
conversion *loss* and the planner correctly refuses it.
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Any, Dict, Iterable, List, Mapping, Optional, Tuple

from repro.core import adc as adc_mod
from repro.core.adc import ADCConfig, DEFAULT_SAR, SARModel
from repro.core.crossbar import CrossbarSpec, DEFAULT_SPEC, layer_scaled_spec
from repro.core.karatsuba import karatsuba_cost
from repro.core.mapper import provision_spare_cols
from repro.core.strassen import strassen_cost
from repro.core.workloads import Network

DATAPATHS = ("direct", "karatsuba1", "karatsuba2", "strassen")
ADC_MODES = ("full", "safe_adaptive", "exact_adaptive")


def adc_config_for(mode: str, spec: CrossbarSpec) -> ADCConfig:
    """Materialize a plan's ADC-mode name against a (layer-scaled) spec.

    ``exact_adaptive`` keeps every guard bit below the layer's own
    ``drop_lsb`` (provably lossless for *this* layer's scaling), so it must
    be resolved per layer — the module-level ``EXACT_ADAPTIVE`` constant is
    pinned to the default spec and would under-guard a deep layer.
    """
    if mode == "full":
        return ADCConfig(mode="full")
    if mode == "safe_adaptive":
        return ADCConfig(mode="adaptive", guard_bits=4)
    if mode == "exact_adaptive":
        return ADCConfig(mode="adaptive", guard_bits=spec.drop_lsb)
    raise ValueError(f"unknown ADC mode {mode!r} (one of {ADC_MODES})")


@dataclasses.dataclass(frozen=True)
class LayerPlan:
    """One layer's compiled datapath choice — hashable, serializable.

    Rides a ``ProgrammedLinear``'s static aux (part of the jit cache key),
    so every field is a primitive.  ``predicted_conversions`` /
    ``predicted_energy_pj`` are per-sample ADC figures under the planner's
    accounting — recorded so a served chip carries the numbers it was
    admitted on (the ``kernel_planned`` gate re-derives and compares).
    """

    name: str
    datapath: str = "direct"  # one of DATAPATHS
    adc_mode: str = "full"  # one of ADC_MODES
    spare_cols: int = 0  # per-crossbar repair budget (provision_spare_cols)
    replication: int = 1  # pipeline-balance copies (mapper's rule)
    predicted_conversions: float = 0.0
    predicted_energy_pj: float = 0.0

    def __post_init__(self):
        if self.datapath not in DATAPATHS:
            raise ValueError(f"unknown datapath {self.datapath!r}")
        if self.adc_mode not in ADC_MODES:
            raise ValueError(f"unknown ADC mode {self.adc_mode!r}")

    @property
    def karatsuba_levels(self) -> int:
        return {"karatsuba1": 1, "karatsuba2": 2}.get(self.datapath, 0)

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "LayerPlan":
        return cls(**dict(d))


@dataclasses.dataclass
class ChipPlan:
    """Every layer's ``LayerPlan``, keyed by the layer/artifact name."""

    network: str
    layers: Dict[str, LayerPlan]
    fault_rate: float = 0.0
    widening: str = "paper"
    exactness: str = "empirical"

    def layer_for(self, name: str) -> Optional[LayerPlan]:
        return self.layers.get(name)

    @property
    def total_conversions(self) -> float:
        return sum(p.predicted_conversions for p in self.layers.values())

    @property
    def total_energy_pj(self) -> float:
        return sum(p.predicted_energy_pj for p in self.layers.values())

    def datapath_histogram(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for p in self.layers.values():
            out[p.datapath] = out.get(p.datapath, 0) + 1
        return out

    def to_json(self) -> str:
        return json.dumps(
            {
                "schema": 1,
                "network": self.network,
                "fault_rate": self.fault_rate,
                "widening": self.widening,
                "exactness": self.exactness,
                # insertion order is the plan order — keep it
                "layers": {n: p.to_dict() for n, p in self.layers.items()},
            }
        )

    @classmethod
    def from_json(cls, s: str) -> "ChipPlan":
        d = json.loads(s)
        return cls(
            network=d["network"],
            layers={n: LayerPlan.from_dict(p) for n, p in d["layers"].items()},
            fault_rate=float(d.get("fault_rate", 0.0)),
            widening=d.get("widening", "paper"),
            exactness=d.get("exactness", "empirical"),
        )


# ---------------------------------------------------------------------------
# Candidate pricing (the same currency as core.energy.evaluate)
# ---------------------------------------------------------------------------


def predicted_conversions(
    rows: int,
    cols: int,
    pixels: int,
    datapath: str,
    spec: CrossbarSpec,
    widening: str = "paper",
) -> float:
    """Per-sample ADC conversions of one layer under one datapath.

    Direct / Karatsuba follow ``energy.evaluate``'s formula — pixels x cols
    x row-groups x conversion slots per column group; Strassen prices the
    whole (pixels, rows) x (rows, cols) matmul through ``strassen_cost``
    under the requested ``widening`` accounting.
    """
    groups = -(-rows // spec.rows)
    if datapath == "strassen":
        return float(
            strassen_cost(pixels, rows, cols, spec, levels=1, widening=widening)
            .adc_conversions
        )
    levels = {"direct": 0, "karatsuba1": 1, "karatsuba2": 2}[datapath]
    slots = karatsuba_cost(levels, spec).adc_slots
    return float(pixels * cols * groups * slots)


def _energy_per_conversion_pj(spec: CrossbarSpec, mode: str, sar: SARModel) -> float:
    """Mean SAR energy of one conversion under the mode's schedule histogram
    (``energy.evaluate``'s ``bits_frac`` without the normalization detour)."""
    sched = adc_mod.adaptive_schedule(
        spec.replace(signed_weights=False), adc_config_for(mode, spec)
    )
    return sar.mean_energy_pj(sched)


def _admissible_adc_modes(spec: CrossbarSpec, rows: int, exactness: str) -> List[str]:
    """ADC modes the layer may use, per the requested exactness contract.

    ``empirical``: every mode — ``safe_adaptive``'s 4 guard bits are the
    property-tested empirically-bit-exact regime (its *analytic* worst-case
    bound is loose: simultaneous worst-case carries in every truncated
    conversion never materialize).  ``provable``: only schedules whose
    analytic LSB error bound is exactly zero (``full`` /
    ``exact_adaptive``).
    """
    if exactness != "provable":
        return list(ADC_MODES)
    return [
        mode
        for mode in ADC_MODES
        if adc_mod.lsb_error_bound(spec, adc_config_for(mode, spec), rows) == 0.0
    ]


def datapath_crossbar_factor(datapath: str, spec: CrossbarSpec, widening: str = "paper") -> float:
    """Crossbars per 128x128 weight tile, relative to the direct datapath.

    The area price of each conversion saving: Karatsuba re-tiles one column
    group across 13 (level 1) or 20 (level 2) crossbars where direct uses
    ``n_slices``; Strassen *frees* arrays (7 products replace 8) but its
    precombined weight operands widen by one slice per level under the
    ``exact`` accounting.
    """
    if datapath == "strassen":
        c = strassen_cost(2, 2 * spec.rows, 2, spec, levels=1, widening=widening)
        return (c.imas_used / 8.0) * (
            (spec.n_slices + c.extra_weight_slices) / spec.n_slices
        )
    levels = {"direct": 0, "karatsuba1": 1, "karatsuba2": 2}[datapath]
    return karatsuba_cost(levels, spec).crossbars / float(spec.n_slices)


def plan_layer(
    name: str,
    rows: int,
    cols: int,
    *,
    pixels: int = 1,
    kind: str = "fc",
    spec: CrossbarSpec = DEFAULT_SPEC,
    sar: SARModel = DEFAULT_SAR,
    fault_rate: float = 0.0,
    salience: float = 1.0,
    pixels_ref: int = 1,
    widening: str = "paper",
    exactness: str = "empirical",
    datapaths: Optional[Iterable[str]] = None,
    max_crossbar_factor: Optional[float] = None,
) -> LayerPlan:
    """Compile one layer's plan by minimizing predicted ADC energy.

    Candidates: every datapath in ``datapaths`` (default: direct, both
    Karatsuba levels, and Strassen for conv-shaped layers with >= 2 output
    pixels) x every admissible ADC mode; the objective is (energy,
    conversions, iterations) lexicographic — energy decides, conversion
    count breaks ties, pipeline latency breaks those.

    ``max_crossbar_factor`` is the area constraint the paper's mapping
    lives under: candidates whose ``datapath_crossbar_factor`` exceeds it
    are inadmissible.  Unconstrained, Karatsuba level 2 wins everywhere (92
    of 128 conversion slots, at 2.5x the crossbars); at a factor of 1.0 —
    a chip with no slack arrays, e.g. a heavily replicated early conv
    layer — Strassen is the only datapath that still cuts conversions,
    because it *frees* arrays instead of consuming them.  Spare budget and
    replication are constraints, not choices: the budget comes from
    ``provision_spare_cols`` scaled by this layer's fault ``salience``, and
    replication from the mapper's pipeline-balance rule
    (``ceil(pixels / pixels_ref)`` for conv, 1 for fc).
    """
    spec_l = layer_scaled_spec(spec, max(2, rows))
    cands = list(datapaths) if datapaths is not None else [
        "direct", "karatsuba1", "karatsuba2",
    ]
    if datapaths is None and kind == "conv" and pixels >= 2:
        cands.append("strassen")
    modes = _admissible_adc_modes(spec_l, rows, exactness)
    if not modes:
        modes = ["full"]

    best: Optional[Tuple[Tuple[float, float, int], str, str, float, float]] = None
    for dp in cands:
        if (
            max_crossbar_factor is not None
            and dp != "direct"
            and datapath_crossbar_factor(dp, spec_l, widening) > max_crossbar_factor
        ):
            continue
        convs = predicted_conversions(rows, cols, pixels, dp, spec_l, widening)
        if dp == "strassen":
            iters = spec_l.n_iters + (1 if widening == "exact" else 0)
        else:
            iters = karatsuba_cost(
                {"direct": 0, "karatsuba1": 1, "karatsuba2": 2}[dp], spec_l
            ).iterations
        for mode in modes:
            e_pj = convs * _energy_per_conversion_pj(spec_l, mode, sar)
            key = (e_pj, convs, iters)
            if best is None or key < best[0]:
                best = (key, dp, mode, convs, e_pj)
    assert best is not None
    _, datapath, adc_mode, convs, e_pj = best

    spare = provision_spare_cols(fault_rate, spec_l, coverage=salience)
    repl = max(1, -(-pixels // max(1, pixels_ref))) if kind == "conv" else 1
    return LayerPlan(
        name=name,
        datapath=datapath,
        adc_mode=adc_mode,
        spare_cols=spare,
        replication=repl,
        predicted_conversions=convs,
        predicted_energy_pj=e_pj,
    )


# ---------------------------------------------------------------------------
# Whole-model planning
# ---------------------------------------------------------------------------


def plan_network(
    net: Network,
    spec: CrossbarSpec = DEFAULT_SPEC,
    sar: SARModel = DEFAULT_SAR,
    *,
    fault_rate: float = 0.0,
    salience: Optional[Mapping[str, float]] = None,
    widening: str = "paper",
    exactness: str = "empirical",
    datapaths: Optional[Iterable[str]] = None,
    max_crossbar_factor: Optional[float] = None,
) -> ChipPlan:
    """Plan every layer of a ``workloads.Network`` (Table II CNNs, or a
    ``configs/`` model through ``workloads.lm_workload``)."""
    conv_pixels = [l.pixels for l in net.conv_layers()]
    pixels_ref = min(conv_pixels, default=1)
    layers: Dict[str, LayerPlan] = {}
    for layer in net.layers:
        layers[layer.name] = plan_layer(
            layer.name,
            layer.rows,
            layer.cols,
            pixels=layer.pixels,
            kind=layer.kind,
            spec=spec,
            sar=sar,
            fault_rate=fault_rate,
            salience=(salience or {}).get(layer.name, 1.0),
            pixels_ref=pixels_ref,
            widening=widening,
            exactness=exactness,
            datapaths=datapaths,
            max_crossbar_factor=max_crossbar_factor,
        )
    return ChipPlan(
        network=net.name,
        layers=layers,
        fault_rate=fault_rate,
        widening=widening,
        exactness=exactness,
    )


def homogeneous_network(
    net: Network,
    spec: CrossbarSpec = DEFAULT_SPEC,
    sar: SARModel = DEFAULT_SAR,
    *,
    fault_rate: float = 0.0,
) -> ChipPlan:
    """The homogeneous compile the planner is judged against: every layer on
    the direct datapath with a full-resolution ADC — exactly what
    ``program_layer``'s default ``fast=True`` kernel executes."""
    plan = plan_network(
        net, spec, sar, fault_rate=fault_rate, datapaths=("direct",)
    )
    # full-mode conversion energy is scaling-independent (every conversion
    # resolves all adc_bits), so one per-conversion figure prices every layer
    e_full = _energy_per_conversion_pj(spec, "full", sar)
    forced = {
        n: dataclasses.replace(
            p,
            adc_mode="full",
            predicted_energy_pj=p.predicted_conversions * e_full,
        )
        for n, p in plan.layers.items()
    }
    return dataclasses.replace(plan, layers=forced, exactness="provable")


def plan_model(
    params: Any,
    spec: CrossbarSpec = DEFAULT_SPEC,
    sar: SARModel = DEFAULT_SAR,
    *,
    device: Optional[Any] = None,
    tie_lm_head: bool = False,
    leaf_filter: Optional[Any] = None,
    widening: str = "paper",
    exactness: str = "empirical",
    name: str = "model",
) -> ChipPlan:
    """Plan a parameter pytree, keyed by the **canonical artifact names**
    ``program_model`` will emit — the plan then threads straight through
    ``program_model(plan=...)`` / ``ServingEngine(plan=...)`` with exact
    name matches.

    Per-layer fault salience comes from the weights themselves: a layer
    whose mean |w| is above the model mean carries more output weight per
    stuck cell, so its spare budget scales up (clamped to [0.5, 2]x — the
    provisioning cap in ``provision_spare_cols`` still binds).
    ``device`` (a ``repro.device.DeviceConfig``) supplies the stuck-cell
    rate; without one the plan provisions no spares.
    """
    import jax
    import jax.numpy as jnp

    from repro.device.programmed import expected_artifact_names

    shapes = expected_artifact_names(
        params, tie_lm_head=tie_lm_head, leaf_filter=leaf_filter
    )
    fault_rate = 0.0
    if device is not None:
        fault_rate = float(
            getattr(device, "p_stuck_on", 0.0) + getattr(device, "p_stuck_off", 0.0)
        )

    # mean |w| per planned leaf, matched to artifact names by (K, N) shape
    # per path — the transpose the tied head compiles included
    from repro.device.programmed import _path_names, join_path

    mags: Dict[str, float] = {}
    flat, _ = jax.tree_util.tree_flatten_with_path(params)
    for path, leaf in flat:
        if not hasattr(leaf, "ndim"):
            continue
        key = join_path(path)
        if key in shapes or (
            tie_lm_head and _path_names(path) and _path_names(path)[-1] == "tokens"
        ):
            if key in shapes:
                mags[key] = float(jnp.mean(jnp.abs(leaf)))
    overall = sum(mags.values()) / max(1, len(mags))

    layers: Dict[str, LayerPlan] = {}
    for art_name, shape in shapes.items():
        rows, cols = int(shape[-2]), int(shape[-1])
        sal = 1.0
        if art_name in mags and overall > 0:
            sal = min(2.0, max(0.5, mags[art_name] / overall))
        layers[art_name] = plan_layer(
            art_name,
            rows,
            cols,
            spec=spec,
            sar=sar,
            fault_rate=fault_rate,
            salience=sal,
            widening=widening,
            exactness=exactness,
        )
    return ChipPlan(
        network=name,
        layers=layers,
        fault_rate=fault_rate,
        widening=widening,
        exactness=exactness,
    )
