"""Adaptive (heterogeneous-resolution) SAR ADC model — paper §III.A.3, Fig 5.

Key observation reproduced here: with 2-bit cells and a 1-bit DAC, the exact
accumulator of a 16b x 16b, 128-row column dot product is 39 bits wide, but
the scaling stage keeps only bits ``[drop_lsb, drop_lsb + out_bits)`` = [10,
26).  The partial produced at (iteration ``t``, slice ``s``) occupies
accumulator bits ``[base, base + 9)`` with ``base = t + 2 s``, so a SAR ADC
only needs to resolve the bits of each conversion that overlap the window:

* **MSB side** (exact): all contributions are non-negative, so if any partial
  has a set bit at/above the window top, the total exceeds the representable
  maximum and the output clamps.  A single SAR comparison starting at the
  ``LSB+1`` position detects this ("clamp" signal on the HTree); the bits
  above the window are never resolved individually.
* **LSB side** (rounded): bits below ``drop_lsb - guard_bits`` are not
  resolved; the conversion is rounded at that granularity (round-half-up,
  after Gupta et al. [11]).  With ``guard_bits >= drop_lsb`` this is lossless;
  the default guard makes the worst-case carry error < 1 output ULP and the
  property tests measure exactness empirically.

``adaptive_schedule`` returns the Fig-5 table: SAR bit-decisions per (t, s).
The SAR energy model (``sar_energy_pj``) follows Kull et al. [18] /
Murmann's survey [23]: per-conversion energy is split between CDAC, analog
(comparator) and digital logic; resolving fewer bits gates off the later
stages, scaling comparator+digital energy ~linearly in resolved bits.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.crossbar import CrossbarSpec, DEFAULT_SPEC


@dataclasses.dataclass(frozen=True)
class ADCConfig:
    mode: str = "adaptive"  # "full" | "adaptive"
    # LSBs kept below drop_lsb.  The paper's Fig-5 schedule resolves nothing
    # below the output window (guard 0, rounding "generates carries"); with
    # guard >= 4 the result is provably within 1 output ULP and empirically
    # bit-exact, and guard >= drop_lsb is exact by construction.  Energy
    # accounting defaults to the paper's schedule; numeric layers
    # (CrossbarLinear) use SAFE_ADAPTIVE.
    guard_bits: int = 0
    msb_clamp: bool = True  # resolve MSBs above window with 1 compare + clamp

    def replace(self, **kw) -> "ADCConfig":
        return dataclasses.replace(self, **kw)


FULL_ADC = ADCConfig(mode="full")
SAFE_ADAPTIVE = ADCConfig(mode="adaptive", guard_bits=4)  # < 1 ULP worst case
EXACT_ADAPTIVE = ADCConfig(mode="adaptive", guard_bits=DEFAULT_SPEC.drop_lsb)


def window(spec: CrossbarSpec, cfg: ADCConfig) -> Tuple[int, int]:
    """Absolute accumulator bit window [lo, hi) that the ADCs must resolve.

    For signed (biased) weights the clamp detection must cover the worst-case
    digital bias term, so the MSB side widens by one bit (two-sided clamp on
    the de-biased value); the LSB side is bias-agnostic.
    """
    lo = max(0, spec.drop_lsb - cfg.guard_bits)
    hi = spec.drop_lsb + spec.out_bits + (1 if spec.signed_weights else 0)
    return lo, hi


def adaptive_schedule(spec: CrossbarSpec = DEFAULT_SPEC, cfg: ADCConfig = ADCConfig()) -> np.ndarray:
    """Fig-5 table: SAR bit decisions for conversion (t, s) -> (T, S) int array.

    ``full`` mode: every conversion resolves ``adc_bits`` (9) bits.
    ``adaptive``: bits of [base, base+adc_bits) overlapping [lo, hi), plus one
    comparison when the partial extends above the window (overflow detect).
    """
    T, S = spec.n_iters, spec.n_slices
    table = np.zeros((T, S), dtype=np.int64)
    if cfg.mode == "full":
        table[:] = spec.adc_bits
        return table
    lo, hi = window(spec, cfg)
    for t in range(T):
        for s in range(S):
            base = spec.base_shift(t, s)
            top = base + spec.adc_bits
            kept = max(0, min(top, hi) - max(base, lo))
            extra = 1 if (cfg.msb_clamp and top > hi and kept > 0) else 0
            if top > hi and kept == 0:
                extra = 1 if cfg.msb_clamp else 0  # pure overflow detector
            table[t, s] = min(kept + extra, spec.adc_bits)
    return table


def mean_bits_per_conversion(spec: CrossbarSpec = DEFAULT_SPEC, cfg: ADCConfig = ADCConfig()) -> float:
    return float(adaptive_schedule(spec, cfg).mean())


def make_partial_transform(spec: CrossbarSpec, cfg: ADCConfig):
    """Build the ``partial_transform`` hook for ``crossbar.crossbar_accumulate``.

    Applies, per (t, s) conversion: LSB rounding at granularity
    ``2**(lo - base)`` and MSB overflow detection above ``hi``.  Returns
    (transformed partials, overflow flags) — flags force a clamp-to-max,
    which is exact by the non-negativity argument (unsigned datapath).
    """
    if cfg.mode == "full":
        return None
    lo, hi = window(spec, cfg)
    T, S = spec.n_iters, spec.n_slices
    base = np.array(
        [[spec.base_shift(t, s) for s in range(S)] for t in range(T)], dtype=np.int32
    )
    lsb_shift = np.clip(lo - base, 0, spec.adc_bits)  # (T, S)
    hi_rel = hi - base  # (T, S); if < adc_bits, top bits are clamp-detect only
    detect = (hi_rel < spec.adc_bits) & np.array(cfg.msb_clamp)
    lsb_shift_j = jnp.asarray(lsb_shift).reshape(T, S, 1, 1, 1)
    hi_rel_j = jnp.asarray(np.clip(hi_rel, 0, spec.adc_bits)).reshape(T, S, 1, 1, 1)
    detect_j = jnp.asarray(detect).reshape(T, S, 1, 1, 1)

    def transform(partials: jnp.ndarray, spec_: CrossbarSpec):
        # Round-half-up at the LSB granularity the SAR did not resolve.
        half = jnp.where(lsb_shift_j > 0, 1 << jnp.maximum(lsb_shift_j - 1, 0), 0)
        p = ((partials + half) >> lsb_shift_j) << lsb_shift_j
        # Overflow detection: any resolved-or-rounded bit at/above hi?
        over = jnp.where(detect_j, (p >> hi_rel_j) > 0, False)
        # Bits above the window are not individually resolved; for unflagged
        # outputs p < 2**hi_rel so masking is the identity — keep p as-is for
        # flagged ones too (the clamp overrides downstream).
        return p, over

    return transform if spec.signed_weights is False else _signed_wrapper(transform)


def _signed_wrapper(transform):
    """For the biased-signed datapath, MSB clamp detection on the *biased*
    accumulator is not sound (the bias shifts the window), so we disable the
    per-partial flags and keep only the LSB-side rounding; the energy model
    still charges the paper's schedule (the paper presents the mechanism on
    the unsigned example).  See DESIGN.md §2.2."""

    def wrapped(partials, spec_):
        p, _ = transform(partials, spec_)
        return p, None

    return wrapped


def lsb_error_bound(spec: CrossbarSpec, cfg: ADCConfig, k: int) -> float:
    """Worst-case |error| in output ULPs from LSB-side rounding.

    Each truncated conversion errs by at most half its granule; conversions
    with granule g contribute <= groups * g / 2 each.  ``k`` is the
    contraction length.
    """
    if cfg.mode == "full":
        return 0.0
    lo, _ = window(spec, cfg)
    groups = -(-k // spec.rows)
    err = 0.0
    for t in range(spec.n_iters):
        for s in range(spec.n_slices):
            base = spec.base_shift(t, s)
            g = max(0, lo - base)
            if g > 0:
                # round-half-up error per conversion <= 2**(g-1) partial units
                err += groups * (2 ** (g - 1)) * (2 ** base)
    return err / (2 ** spec.drop_lsb)


# ---------------------------------------------------------------------------
# SAR ADC energy/power model (Kull et al. [18]; Murmann survey [23])
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SARModel:
    """Power split of a SAR ADC at full resolution and rate (Table I).

    Paper §III.A.3: conventionally ~1/3 CDAC, ~1/3 digital, ~1/3 analog;
    recent designs shrink CDAC (they evaluate 10% and 27% CDAC variants).
    Energy scales ~linearly with resolved bits for the comparator/digital
    parts; the CDAC share is charged per sample (dominated by the MSB
    charge-up), and is also skipped when zero bits are resolved.
    """

    power_w: float = 3.1e-3  # 8-bit @ 1.28 GS/s (Kull) — Table I
    sample_rate: float = 1.28e9
    full_bits: int = 8
    # §III.A.3: conventional SARs split ~1/3 CDAC, ~1/3 digital, ~1/3 analog,
    # but "recent trends show CDAC power diminishing (tiny unit caps,
    # reference buffers)"; the paper's headline uses the modern split and
    # §V re-evaluates CDAC at 10%/27% (13%/12% improvements).
    cdac_frac: float = 0.10
    digital_frac: float = 0.45
    analog_frac: float = 0.45

    @property
    def energy_per_sample_j(self) -> float:
        return self.power_w / self.sample_rate

    def energy_pj(self, bits: float) -> float:
        """Energy (pJ) for one conversion resolving ``bits`` bits."""
        e_full = self.energy_per_sample_j * 1e12
        if bits <= 0:
            return 0.0
        frac = bits / self.full_bits
        return e_full * (self.cdac_frac + (self.digital_frac + self.analog_frac) * frac)

    def mean_energy_pj(self, schedule: np.ndarray) -> float:
        return float(np.mean([self.energy_pj(b) for b in schedule.ravel()]))


DEFAULT_SAR = SARModel()
