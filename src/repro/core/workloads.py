"""Workload definitions: the paper's Table II CNN suite, plus extraction of
VMM workloads from the framework's LM architectures (paper §VI notes the
techniques apply to RNN/LSTM-class models; our LM-serving estimates realize
that claim — see ``lm_workload``).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence


@dataclasses.dataclass(frozen=True)
class Layer:
    """One weight-bearing network layer as seen by the mapper."""

    name: str
    kind: str  # "conv" | "fc"
    rows: int  # weight-matrix rows  (= kx*ky*cin for conv)
    cols: int  # weight-matrix cols  (= cout)
    pixels: int  # output positions per input sample (1 for fc)
    in_hw: int = 0  # input feature-map height/width (conv)
    kx: int = 0
    ky: int = 0
    cin: int = 0
    stride: int = 1

    @property
    def weights(self) -> int:
        return self.rows * self.cols

    @property
    def macs_per_sample(self) -> int:
        return self.weights * self.pixels


@dataclasses.dataclass(frozen=True)
class Network:
    name: str
    layers: List[Layer]
    input_hw: int = 224

    @property
    def total_weights(self) -> int:
        return sum(l.weights for l in self.layers)

    @property
    def total_macs(self) -> int:
        return sum(l.macs_per_sample for l in self.layers)

    def conv_layers(self) -> List[Layer]:
        return [l for l in self.layers if l.kind == "conv"]

    def fc_layers(self) -> List[Layer]:
        return [l for l in self.layers if l.kind == "fc"]


class _Builder:
    """Sequential CNN builder tracking feature-map size (Table II format)."""

    def __init__(self, name: str, hw: int = 224, cin: int = 3):
        self.name, self.hw, self.cin = name, hw, cin
        self.layers: List[Layer] = []
        self._n = 0

    def conv(self, k: int, cout: int, stride: int = 1, repeat: int = 1, pad: Optional[int] = None):
        for _ in range(repeat):
            p = (k // 2) if pad is None else pad
            out_hw = (self.hw + 2 * p - k) // stride + 1
            self._n += 1
            self.layers.append(
                Layer(
                    name=f"conv{self._n}",
                    kind="conv",
                    rows=k * k * self.cin,
                    cols=cout,
                    pixels=out_hw * out_hw,
                    in_hw=self.hw,
                    kx=k,
                    ky=k,
                    cin=self.cin,
                    stride=stride,
                )
            )
            self.hw, self.cin, stride = out_hw, cout, 1
        return self

    def pool(self, k: int, stride: int = 2):
        self.hw = (self.hw - k) // stride + 1 if k > stride else self.hw // stride
        return self

    def spp(self, bins: Sequence[int] = (7, 3, 2, 1)):
        # spatial pyramid pooling: output features = sum(b^2) * cin
        self.hw = int(sum(b * b for b in bins)) ** 0  # flag: handled in fc()
        self._spp_feats = sum(b * b for b in bins) * self.cin
        return self

    def fc(self, cout: int, repeat: int = 1):
        for _ in range(repeat):
            rows = getattr(self, "_spp_feats", None) or self.hw * self.hw * self.cin
            self._spp_feats = None
            self._n += 1
            self.layers.append(
                Layer(name=f"fc{self._n}", kind="fc", rows=int(rows), cols=cout, pixels=1)
            )
            self.hw, self.cin = 1, cout
        return self

    def build(self) -> Network:
        return Network(self.name, self.layers)


def alexnet() -> Network:
    return (
        _Builder("alexnet")
        .conv(11, 96, stride=4, pad=2)
        .pool(3, 2)
        .conv(5, 256)
        .pool(3, 2)
        .conv(3, 384, repeat=2)
        .conv(3, 256)
        .pool(3, 2)
        .fc(4096, repeat=2)
        .fc(1000)
        .build()
    )


def vgg(cfg: str) -> Network:
    b = _Builder(f"vgg-{cfg.lower()}")
    plans = {
        # Simonyan & Zisserman configs A-D [28] (Table II columns)
        "a": [(64, 1)], "b": [(64, 2)], "c": [(64, 2)], "d": [(64, 2)],
    }
    n64 = {"a": 1, "b": 2, "c": 2, "d": 2}[cfg]
    n128 = {"a": 1, "b": 2, "c": 2, "d": 2}[cfg]
    b.conv(3, 64, repeat=n64).pool(2, 2)
    b.conv(3, 128, repeat=n128).pool(2, 2)
    b.conv(3, 256, repeat=2)
    if cfg == "c":
        b.conv(1, 256)
    elif cfg == "d":
        b.conv(3, 256)
    b.pool(2, 2)
    b.conv(3, 512, repeat=2)
    if cfg == "c":
        b.conv(1, 512)
    elif cfg == "d":
        b.conv(3, 512)
    b.pool(2, 2)
    b.conv(3, 512, repeat=2)
    if cfg == "c":
        b.conv(1, 512)
    elif cfg == "d":
        b.conv(3, 512)
    b.pool(2, 2)
    return b.fc(4096, repeat=2).fc(1000).build()


def msra(cfg: str) -> Network:
    """MSRA PReLU-nets A/B/C (He et al. [13]) per Table II."""
    b = _Builder(f"msra-{cfg.lower()}")
    b.conv(7, 96, stride=2, pad=3).pool(3, 2)
    if cfg == "a":
        b.conv(3, 256, repeat=5).pool(2, 2)
        b.conv(3, 512, repeat=5).pool(2, 2)
        b.conv(3, 512, repeat=5)
    elif cfg == "b":
        b.conv(3, 256, repeat=6).pool(2, 2)
        b.conv(3, 512, repeat=6).pool(2, 2)
        b.conv(3, 512, repeat=6)
    else:
        b.conv(3, 384, repeat=6).pool(2, 2)
        b.conv(3, 768, repeat=6).pool(2, 2)
        b.conv(3, 896, repeat=6)
    b.spp((7, 3, 2, 1))
    return b.fc(4096, repeat=2).fc(1000).build()


def resnet34() -> Network:
    b = _Builder("resnet-34")
    b.conv(7, 64, stride=2, pad=3).pool(3, 2)
    b.conv(3, 64, repeat=6)
    b.conv(3, 128, stride=2)
    b.conv(3, 128, repeat=7)
    b.conv(3, 256, stride=2)
    b.conv(3, 256, repeat=11)
    b.conv(3, 512, stride=2)
    b.conv(3, 512, repeat=5)
    b.pool(7, 7)  # global average pool
    return b.fc(1000).build()


def benchmark_suite() -> List[Network]:
    """The paper's Table II suite in presentation order."""
    return [
        alexnet(),
        vgg("a"),
        vgg("b"),
        vgg("c"),
        vgg("d"),
        msra("a"),
        msra("b"),
        msra("c"),
        resnet34(),
    ]


def by_name(name: str) -> Network:
    for n in benchmark_suite():
        if n.name == name:
            return n
    raise KeyError(name)


# ---------------------------------------------------------------------------
# LM architectures as crossbar workloads (framework integration)
# ---------------------------------------------------------------------------

def lm_workload(cfg, seq_len: int = 1) -> Network:
    """Extract the per-token VMM workload of an LM architecture config.

    Every projection of the model becomes an ``fc`` layer (decode-style: one
    token => pure VMM, the crossbar's natural shape).  MoE layers contribute
    only their activated experts (top-k + shared) — the in-situ array stores
    all experts but only activated columns draw ADC conversions.

    ``cfg`` is a ``repro.configs.base.ModelConfig``.
    """
    layers: List[Layer] = []

    def fc(name, rows, cols, count=1):
        if rows and cols and count:
            layers.append(Layer(name=name, kind="fc", rows=int(rows), cols=int(cols), pixels=int(count)))

    d = cfg.d_model
    for i, blk in enumerate(cfg.block_pattern_summary()):
        p = f"L{i}.{blk}"
        if blk in ("attn", "attn_local", "attn_global"):
            h = cfg.head_dim * cfg.n_heads
            kvh = cfg.head_dim * cfg.n_kv_heads
            if cfg.kv_lora_rank:  # MLA
                fc(p + ".q", d, h)
                fc(p + ".kv_down", d, cfg.kv_lora_rank + cfg.qk_rope_dim)
                fc(p + ".kv_up", cfg.kv_lora_rank, 2 * h)
                fc(p + ".o", h, d)
            else:
                fc(p + ".q", d, h)
                fc(p + ".k", d, kvh)
                fc(p + ".v", d, kvh)
                fc(p + ".o", h, d)
        elif blk == "mamba":
            d_in = cfg.mamba_d_inner or 2 * d
            fc(p + ".in", d, 2 * d_in)
            fc(p + ".x", d_in, cfg.mamba_dt_rank + 2 * cfg.mamba_d_state)
            fc(p + ".out", d_in, d)
        elif blk in ("mlstm", "slstm"):
            d_in = cfg.xlstm_d_inner or 2 * d
            fc(p + ".qkv", d, 3 * d_in)
            fc(p + ".gates", d, 2 * d_in)
            fc(p + ".out", d_in, d)
        if blk.startswith("attn") or blk in ("mlstm", "slstm", "mamba"):
            if cfg.moe_experts and cfg.moe_layer(i):
                active = cfg.moe_top_k + cfg.moe_shared_experts
                fc(p + ".router", d, cfg.moe_experts)
                fc(p + ".ffn_in", d, 2 * cfg.moe_d_ff, count=active)
                fc(p + ".ffn_out", cfg.moe_d_ff, d, count=active)
            elif cfg.d_ff:
                fc(p + ".ffn_in", d, 2 * cfg.d_ff)
                fc(p + ".ffn_out", cfg.d_ff, d)
    fc("lm_head", d, cfg.vocab_size)
    net = Network(f"lm-{cfg.name}", layers, input_hw=0)
    return net
