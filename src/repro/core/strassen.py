"""Strassen's divide & conquer for crossbar matrix-matrix multiply (§III.A.2).

When a conv layer performs a large matrix-matrix product (im2col'd patches x
kernels), a 2x2 blocking lets 7 sub-products replace 8 (Fig 4); Newton maps
the seven products P0..P6 onto 7 of a tile's 8 IMAs (Fig 8), freeing the 8th.

Operand-side notes faithful to the hardware:

* **Weight-side combinations** (e.g. W11 + W22) are precomputed when the
  crossbars are programmed — free at inference time, but they widen the cell
  codes by one bit (17-bit signed), i.e. one extra slice.
* **Input-side combinations** (e.g. X11 + X21, X11 - X12 in the dual form)
  are computed digitally on the fly by adders on the input HTree.  Negative
  sums are handled by offset encoding with digital correction
  (``crossbar.signed_vmm_limbs``) — the input-side analogue of ISAAC's
  weight bias.

We use the Winograd variant below (the classic 7-product scheme) with
X = input matrix (rows = im2col'd vectors) and W = weight matrix:

    P1 = (X11 + X22)(W11 + W22)   P5 = (X11 + X12) W22
    P2 = (X21 + X22) W11          P6 = (X21 - X11)(W11 + W12)
    P3 = X11 (W12 - W22)          P7 = (X12 - X22)(W21 + W22)
    P4 = X22 (W21 - W11)
    Y11 = P1 + P4 - P5 + P7       Y12 = P3 + P5
    Y21 = P2 + P4                 Y22 = P1 - P2 + P3 + P6

Recombination is exact limb arithmetic, so ``strassen_matmul`` is
bit-identical to the direct datapath (property-tested).  ``strassen_cost``
reproduces the paper's accounting: 7/8 of the ADC conversions per recursion
level, at the price of one extra weight slice for the combined operands.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp

from repro.core.crossbar import (
    ConversionStats,
    CrossbarSpec,
    DEFAULT_SPEC,
    limb_add,
    limb_normalize,
    limb_sub,
    requantize_exact_limbs,
    signed_vmm_limbs,
)


def _pad_even(a: jnp.ndarray) -> jnp.ndarray:
    pr = a.shape[0] % 2
    pc = a.shape[1] % 2
    if pr or pc:
        a = jnp.pad(a, ((0, pr), (0, pc)))
    return a


def _blocks(a: jnp.ndarray):
    m, n = a.shape
    return (
        a[: m // 2, : n // 2],
        a[: m // 2, n // 2 :],
        a[m // 2 :, : n // 2],
        a[m // 2 :, n // 2 :],
    )


def strassen_matmul(
    x_codes: jnp.ndarray,
    w_codes: jnp.ndarray,
    spec: CrossbarSpec = DEFAULT_SPEC,
    levels: int = 1,
) -> jnp.ndarray:
    """Strassen crossbar matmul — bit-identical to the direct datapath.

    x_codes: (M, K) unsigned input codes; w_codes: (K, N) signed weight codes.
    Returns (M, N) int32 output codes with the standard scaling stage applied.
    """
    M, N = x_codes.shape[0], w_codes.shape[1]
    acc = _strassen_acc(
        x_codes.astype(jnp.int32),
        w_codes.astype(jnp.int32),
        spec,
        levels,
        in_bits=spec.input_bits,
        in_signed=False,
        w_bits=spec.weight_bits,
    )
    y = requantize_exact_limbs(acc, spec, signed_out=spec.signed_weights)
    return y[:M, :N]


def _strassen_acc(
    x: jnp.ndarray,
    w: jnp.ndarray,
    spec: CrossbarSpec,
    levels: int,
    in_bits: int,
    in_signed: bool,
    w_bits: int,
):
    """Exact limb accumulator of x @ w with `levels` of Strassen recursion."""
    if levels == 0 or min(x.shape + w.shape) < 2:
        sub = spec.replace(input_bits=in_bits, weight_bits=w_bits, signed_weights=True)
        acc, _ = signed_vmm_limbs(x, w, sub, signed_inputs=in_signed)
        return acc

    m_orig, n_orig = x.shape[0], w.shape[1]
    x = _pad_even(x)
    w = _pad_even(w)
    if x.shape[1] != w.shape[0]:  # K padded on one side only
        k = max(x.shape[1], w.shape[0])
        x = jnp.pad(x, ((0, 0), (0, k - x.shape[1])))
        w = jnp.pad(w, ((0, k - w.shape[0]), (0, 0)))
    X11, X12, X21, X22 = _blocks(x)
    W11, W12, W21, W22 = _blocks(w)

    ib, wb = in_bits + 1, w_bits + 1  # combined operands are one bit wider

    def rec(xs, ws, xs_signed):
        return _strassen_acc(xs, ws, spec, levels - 1, ib, xs_signed, wb)

    P1 = rec(X11 + X22, W11 + W22, in_signed)
    P2 = rec(X21 + X22, W11, in_signed)
    P3 = rec(X11, W12 - W22, in_signed)
    P4 = rec(X22, W21 - W11, in_signed)
    P5 = rec(X11 + X12, W22, in_signed)
    P6 = rec(X21 - X11, W11 + W12, True)
    P7 = rec(X12 - X22, W21 + W22, True)

    Y11 = limb_add(limb_sub(limb_add(P1, P4), P5), P7)
    Y12 = limb_add(P3, P5)
    Y21 = limb_add(P2, P4)
    Y22 = limb_add(limb_sub(limb_add(P1, P3), P2), P6)

    hi = jnp.block([[Y11[0], Y12[0]], [Y21[0], Y22[0]]])
    lo = jnp.block([[Y11[1], Y12[1]], [Y21[1], Y22[1]]])
    # Slice away padding so recursive callers reassemble clean blocks.
    return limb_normalize(hi[:m_orig, :n_orig], lo[:m_orig, :n_orig])


# ---------------------------------------------------------------------------
# ADC-work accounting (Fig 8 / Fig 19)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StrassenCost:
    adc_conversions: int  # per output tile, summed over the 7 products
    imas_used: int  # of 8 in a tile (paper: frees 1 in 8)
    extra_weight_slices: int  # widened combined operands


def strassen_cost(
    m: int,
    k: int,
    n: int,
    spec: CrossbarSpec = DEFAULT_SPEC,
    levels: int = 1,
    widening: str = "paper",
) -> StrassenCost:
    """ADC conversions for an (m,k) x (k,n) matmul under Strassen.

    Direct: m * n * ceil(k/rows) * T * S conversions.  One Strassen level
    replaces 8 half-size products with 7.

    ``widening`` selects the accounting:
      * ``"paper"`` — sub-products run at the original 16b x 16b width (the
        paper's implicit accounting behind its 4.5% energy gain: combined
        operands reuse the 16-bit datapath, relying on headroom/saturation).
        Conversion ratio = 7/8 per level.
      * ``"exact"`` — combined operands widen by one bit per level (one extra
        slice and one extra iteration), which our bit-exact implementation
        actually requires.  This accounting shows Strassen is a net *loss*
        in conversions (~ +5% for one level) unless width is held constant —
        an analysis we surface in EXPERIMENTS.md.
    """
    T, S = spec.n_iters, spec.n_slices
    if levels == 0:
        groups = -(-k // spec.rows)
        return StrassenCost(m * n * groups * T * S, 8, 0)
    mh, kh, nh = -(-m // 2), -(-k // 2), -(-n // 2)
    groups = -(-kh // spec.rows)
    if widening == "paper":
        per_product = mh * nh * groups * T * S
        extra = 0
    else:
        per_product = mh * nh * groups * (T + levels) * (S + levels)
        extra = levels
    return StrassenCost(7 * per_product, 7, extra)


def strassen_stats(
    m: int,
    k: int,
    n: int,
    spec: CrossbarSpec = DEFAULT_SPEC,
    levels: int = 1,
    widening: str = "paper",
) -> ConversionStats:
    """Conversion stats under the same ``widening`` accounting as
    ``strassen_cost``: the "paper" mode reuses the original datapath width,
    so it costs no extra iterations; only the "exact" mode (one bit wider
    per level) pays the +1 iteration per level its extra slice implies."""
    cost = strassen_cost(m, k, n, spec, levels, widening=widening)
    return ConversionStats(
        conversions=cost.adc_conversions,
        bit_decisions=cost.adc_conversions * spec.adc_bits,
        iterations=spec.n_iters + (levels if widening == "exact" else 0),
    )
