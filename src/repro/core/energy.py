"""Analytic power / energy / area evaluation (paper §IV-V).

Combines the mapper's allocation with per-component unit costs to produce,
per benchmark: peak power, energy per sample, area, CE (GOPS/s/mm^2), PE
(GOPS/W) — for ISAAC and every increment of the Newton technique stack.

Calibration
-----------
One explicit scalar reconciles Table I's Kull ADC instance (3.1 mW) with the
published ISAAC aggregates Newton validates against (1.8 pJ/op average; ADC
~49% of chip power, §V): ``CAL.adc_power_scale = 0.65`` (the effective 2.0 mW
ISAAC's table uses for the same ADC).  Everything else is computed
bottom-up; the tests assert the paper's *relative* claims — which do not
depend on this scalar — plus the absolute anchors within tolerance.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

import numpy as np

from repro.core import adc as adc_mod
from repro.core.arch import (
    ADC_8B,
    CROSSBAR_128,
    ChipConfig,
    DAC_ARRAY_128,
    HYPER_TRANSPORT,
    ISAAC_CHIP,
    TileConfig,
    newton_chip,
)
from repro.core.crossbar import CrossbarSpec, DEFAULT_SPEC
from repro.core.karatsuba import karatsuba_cost
from repro.core.mapper import MappingReport, map_network
from repro.core.workloads import Network

BYTES_PER_VAL = 2


@dataclasses.dataclass(frozen=True)
class Calibration:
    adc_power_scale: float = 1.0  # Table I Kull ADC used as-is
    edram_pj_per_byte: float = 0.65  # 20.7 mW / (32 GB/s read stream), CACTI 6.5-ish
    htree_pj_per_byte: float = 0.47  # short on-tile wires at 16-bit links, 32 nm
    router_pj_per_byte: float = 1.3  # Orion 2.0, 32-flit 8-port at 1 GHz
    ht_pj_per_byte: float = 1625.0  # 10.4 W / 6.4 GB/s HyperTransport
    digital_pj_per_mac: float = 0.05  # shift-and-add + misc per 16b MAC
    # Fraction of provisioned (peak) power drawn regardless of activity —
    # eDRAM refresh, clock trees, ADC bias, repeater leakage.  Idle ADCs are
    # clock-gated (peak power still provisions them; energy does not), so
    # the static share is small.  This is how provisioning reductions
    # (compact HTree, FC tiles) show up in *energy*, not just peak power.
    static_frac: float = 0.05


CAL = Calibration()


@dataclasses.dataclass
class EvalResult:
    network: str
    chip: str
    mapping: MappingReport
    area_mm2: float
    peak_power_w: float
    energy_per_sample_j: float
    throughput_samples_s: float
    ops_per_sample: float
    breakdown: Dict[str, float]  # energy by component (J per sample)

    @property
    def pj_per_op(self) -> float:
        return self.energy_per_sample_j * 1e12 / self.ops_per_sample

    @property
    def ce(self) -> float:  # GOPS / (s mm^2) on the allocated hardware
        return self.ops_per_sample * self.throughput_samples_s / 1e9 / self.area_mm2

    @property
    def pe(self) -> float:  # GOPS / W
        return self.ops_per_sample * self.throughput_samples_s / 1e9 / self.peak_power_w


def _adc_energy_per_conversion_j(tile: TileConfig, cal: Calibration) -> float:
    """Energy of one full-resolution conversion on this tile's ADC."""
    ima = tile.ima
    base = ADC_8B.power_w * cal.adc_power_scale / ima.adc_rate
    # FC tiles run the ADC slower; SAR conversion energy is ~rate-independent
    # (same capacitor charges, longer idle), so energy per conversion is flat,
    # but leakage share rises slightly — ignored (conservative).
    return base


def evaluate(
    net: Network,
    chip: ChipConfig,
    policy: str = "newton",
    strassen: bool = False,
    cal: Calibration = CAL,
    activity: float = 1.0,
) -> EvalResult:
    """Evaluate one network on one chip configuration.

    ``activity``: row-weighted fraction of non-zero input bit-planes (see
    ``core.crossbar.plane_activity``; 1.0 = dense worst case).  An all-zero
    plane draws no bitline current, so a zero-plane-aware datapath (the
    kernels' ``skip_zero_planes``, after Ibrayev et al.'s
    pruning-for-ADC-efficiency observation) gates the ADC sample and the
    DAC/crossbar drive for that cycle — scaling the ADC, crossbar and DAC
    *energy* terms (peak power still provisions them).  Post-ReLU CNN/LM
    activations typically measure 0.3-0.6.
    """
    m = map_network(net, chip, policy=policy)
    ima = chip.conv_tile.ima
    spec = ima.xbar_spec

    # --- ADC schedule / divide & conquer (Fig-5 unsigned schedule) ---
    # Per-conversion energy from the schedule *histogram*: a conversion that
    # resolves zero bits is fully gated (no CDAC charge either).
    sched = adc_mod.adaptive_schedule(spec.replace(signed_weights=False), ima.adc_cfg)
    sar = ima.sar
    e_full = sar.energy_pj(spec.adc_bits)
    bits_frac = float(np.mean([sar.energy_pj(b) for b in sched.ravel()])) / e_full
    bits_frac *= e_full / (sar.energy_per_sample_j * 1e12)  # vs 8-bit Kull sample
    conv_slots_frac = 1.0
    if ima.karatsuba_levels:
        c = karatsuba_cost(ima.karatsuba_levels, spec)
        conv_slots_frac = c.adc_slots / (spec.n_iters * spec.n_slices)
    if strassen:
        conv_slots_frac *= 7.0 / 8.0  # paper-mode accounting (see strassen.py)

    e_conv = _adc_energy_per_conversion_j(chip.conv_tile, cal)

    # --- per-sample energies ---
    # HTree repeaters are sized for the provisioned link width: energy per
    # moved byte scales with it (ISAAC 39-bit private links vs Newton's
    # 16-bit shared links after embedded shift-and-add / adaptive ADC).
    out_bits = 23 if ima.compact_htree else spec.acc_bits
    if ima.compact_htree and ima.adc_cfg.mode == "adaptive":
        # Adaptive ADC trims the *shared* compact links to 16 bits; without
        # the compact HTree there are no shared links to trim, so a
        # non-compact chip must not be credited with Newton's narrow links.
        out_bits = 16
    htree_width_scale = (out_bits + (16 if ima.compact_htree else 32)) / 32.0

    e_adc = e_dac = e_xbar = e_edram = e_htree = e_router = e_digital = 0.0
    total_macs = 0
    for lm in m.layers:
        layer = lm.layer
        groups = -(-layer.rows // spec.rows)
        col_convs = layer.cols  # one ADC conversion per output column
        d_and_c = conv_slots_frac
        if strassen and layer.kind == "conv":
            d_and_c *= 7.0 / 8.0  # Strassen applies to conv matmuls only
        conversions = (
            layer.pixels * col_convs * groups * spec.n_iters * spec.n_slices
        ) * d_and_c * activity
        e_adc += conversions * e_conv * bits_frac
        # crossbar + DAC active energy: arrays light up for the VMM duration;
        # zero input planes gate the drive for their cycles (activity term)
        xbar_vmms = layer.pixels * groups * -(-layer.cols // spec.cols) * spec.n_slices
        if strassen and layer.kind == "conv":
            xbar_vmms *= 7.0 / 8.0
        e_xbar += xbar_vmms * CROSSBAR_128.power_w * ima.vmm_time_s * activity
        e_dac += xbar_vmms * (DAC_ARRAY_128.power_w / 128 * spec.rows) * ima.vmm_time_s * activity
        # buffers: read rows once per pixel; write cols once per pixel
        bytes_moved = layer.pixels * (layer.rows + layer.cols) * BYTES_PER_VAL
        e_edram += bytes_moved * cal.edram_pj_per_byte * 1e-12
        e_htree += bytes_moved * cal.htree_pj_per_byte * htree_width_scale * 1e-12
        total_macs += layer.macs_per_sample

    e_router = m.inter_tile_bytes_per_sample * cal.router_pj_per_byte * 1e-12
    e_ht = (
        m.inter_tile_bytes_per_sample * cal.ht_pj_per_byte * 1e-12 * max(0, m.chips - 1)
        / max(1, m.chips)
        * 0.1  # only layer-boundary traffic crossing chips (statically routed)
    )
    e_digital = total_macs * cal.digital_pj_per_mac * 1e-12

    # --- peak power and area: provisioned tiles ---
    conv_p = chip.conv_tile.total_power_w()
    conv_a = chip.conv_tile.total_area_mm2()
    fc_cfg = chip.fc_tile or chip.conv_tile
    fc_p = fc_cfg.total_power_w()
    fc_a = fc_cfg.total_area_mm2()
    power = m.conv_tiles * conv_p + m.fc_tiles * fc_p + m.chips * HYPER_TRANSPORT.power_w
    area = m.conv_tiles * conv_a + m.fc_tiles * fc_a + m.chips * HYPER_TRANSPORT.area_mm2

    # Static share of provisioned power drawn for the whole sample period
    # (refresh, clocks, bias; see Calibration.static_frac).
    e_static = cal.static_frac * power / m.throughput_samples_s

    breakdown = {
        "adc": e_adc,
        "crossbar": e_xbar,
        "dac": e_dac,
        "edram": e_edram,
        "htree": e_htree,
        "router": e_router,
        "ht": e_ht,
        "digital": e_digital,
        "static": e_static,
    }
    energy = sum(breakdown.values())

    return EvalResult(
        network=net.name,
        chip=chip.name,
        mapping=m,
        area_mm2=area,
        peak_power_w=power,
        energy_per_sample_j=energy,
        throughput_samples_s=m.throughput_samples_s,
        ops_per_sample=2.0 * total_macs,
        breakdown=breakdown,
    )


# ---------------------------------------------------------------------------
# The incremental technique stack (Figs 11, 12, 14, 16, 17/18, 19, 20-23)
# ---------------------------------------------------------------------------

def technique_stack() -> List[tuple]:
    """(label, chip, policy, strassen) in the paper's cumulative order."""
    return [
        ("isaac", ISAAC_CHIP, "isaac", False),
        (
            "+compact-htree",
            newton_chip(compact=True, adaptive=False, karatsuba=0, small_buffers=False, fc_tiles=False),
            "newton",
            False,
        ),
        (
            "+adaptive-adc",
            newton_chip(compact=True, adaptive=True, karatsuba=0, small_buffers=False, fc_tiles=False),
            "newton",
            False,
        ),
        (
            "+karatsuba",
            newton_chip(compact=True, adaptive=True, karatsuba=1, small_buffers=False, fc_tiles=False),
            "newton",
            False,
        ),
        (
            "+small-buffers",
            newton_chip(compact=True, adaptive=True, karatsuba=1, small_buffers=True, fc_tiles=False),
            "newton",
            False,
        ),
        (
            "+fc-tiles",
            newton_chip(compact=True, adaptive=True, karatsuba=1, small_buffers=True, fc_tiles=True),
            "newton",
            False,
        ),
        (
            "newton (+strassen)",
            newton_chip(compact=True, adaptive=True, karatsuba=1, small_buffers=True, fc_tiles=True),
            "newton",
            True,
        ),
    ]


def evaluate_suite(nets: List[Network]) -> Dict[str, Dict[str, EvalResult]]:
    """All benchmarks x all technique increments."""
    out: Dict[str, Dict[str, EvalResult]] = {}
    for net in nets:
        row = {}
        for label, chip, policy, strassen in technique_stack():
            row[label] = evaluate(net, chip, policy=policy, strassen=strassen)
        out[net.name] = row
    return out


def headline(results: Dict[str, Dict[str, EvalResult]]) -> Dict[str, float]:
    """Suite-average Newton-vs-ISAAC deltas (the 77% / 51% / 2.2x claims)."""
    power_ratio, energy_ratio, ce_ratio = [], [], []
    for net, row in results.items():
        base = row["isaac"]
        new = row["newton (+strassen)"]
        power_ratio.append(new.peak_power_w / base.peak_power_w)
        energy_ratio.append(new.energy_per_sample_j / base.energy_per_sample_j)
        ce_ratio.append(new.ce / base.ce)
    return {
        "power_decrease": 1.0 - float(np.mean(power_ratio)),
        "energy_decrease": 1.0 - float(np.mean(energy_ratio)),
        "throughput_per_area_x": float(np.mean(ce_ratio)),
    }


# ---------------------------------------------------------------------------
# Reference designs for Fig 20 / Fig 24 (digital baselines + TPU-1)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DigitalRef:
    name: str
    pj_per_op: float
    ce_gops_mm2: float
    pe_gops_w: float


# Peak CE/PE from the respective papers as cited by Newton Fig 20.
DADIANNAO_REF = DigitalRef("dadiannao", 3.5, 63.0, 286.0)
ISAAC_REF = DigitalRef("isaac", 1.8, 479.0, 644.0)
IDEAL_NEURON = DigitalRef("ideal", 0.33, float("nan"), float("nan"))


@dataclasses.dataclass(frozen=True)
class TPUModel:
    """TPU-1-like analytic model for the Fig 24 iso-area comparison.

    65536 8-bit MACs at 700 MHz, 92 TOPS peak, 34 GB/s GDDR5 (the paper
    models GDDR5 to lift the memory bound), 331 mm^2, 40 W TDP, 7 ms latency
    target limiting batch size.
    """

    peak_tops: float = 92.0
    mem_bw_gbs: float = 34.0
    area_mm2: float = 331.0
    power_w: float = 40.0
    latency_target_s: float = 7e-3
    # Measured CNN utilization of TPU-1 (Jouppi et al., ISCA'17: CNNs ran at
    # ~14-22 TOPS of the 92 TOPS peak due to systolic fill/drain and
    # activation traffic); the paper's "idle processing units".
    cnn_utilization: float = 0.20

    def _sample_time(self, net: Network, batch: int) -> float:
        macs = net.total_macs
        weight_bytes = net.total_weights  # int8 weights
        t_compute = 2 * macs * batch / (self.peak_tops * 1e12 * self.cnn_utilization)
        t_mem = weight_bytes / (self.mem_bw_gbs * 1e9)  # weights fetched once/batch
        return max(t_compute, t_mem)

    def throughput(self, net: Network, batch: int) -> float:
        """Samples/s under the roofline of compute vs weight refetch."""
        return batch / self._sample_time(net, batch)

    def best_batch(self, net: Network, max_batch: int = 256) -> int:
        best, arg = 0.0, 1
        for b in (1, 2, 4, 8, 16, 32, 64, 128, 256):
            if b > max_batch:
                break
            if self._sample_time(net, b) <= self.latency_target_s and self.throughput(net, b) > best:
                best, arg = self.throughput(net, b), b
        return arg
