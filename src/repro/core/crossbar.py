"""Bit-exact functional model of the ISAAC/Newton analog crossbar datapath.

The modeled pipeline (paper §II.C / §III):

* a ``rows x cols`` memristor crossbar holds one ``cell_bits``-bit slice of
  each weight; a 16-bit weight spans ``n_slices`` crossbars,
* a 16-bit input is streamed ``dac_bits`` (=1) bit per 100 ns iteration,
* per (iteration ``t``, slice ``s``, row-group ``g``) each bitline produces a
  <= 9-bit partial dot product which an ADC digitizes,
* shift-and-add over slices and iterations builds the exact 39-bit (for one
  128-row group) accumulator; groups are summed digitally,
* the scaling stage drops ``drop_lsb`` LSBs (round-half-up, after Gupta et
  al. [11]) and clamps to ``out_bits`` — the paper's "10 LSBs dropped, 13 MSBs
  clamp" for the 16b x 16b, 128-row case.

Everything is implemented in int32 two-limb arithmetic (radix 2**20) so the
model is bit-exact under JAX's default 32-bit integers and maps directly onto
the Pallas kernel's accumulation strategy.

Signed weights are stored **biased** (cell codes ``w + 2**15``), and the bias
``2**15 * sum(x)`` is removed digitally after accumulation — this is how
ISAAC/Newton handle signedness with non-negative conductances.

The adaptive-ADC machinery (paper §III.A.3, Fig 5) lives in ``adc.py``; this
module exposes the hooks it needs (per-(t, s) partial quantization + overflow
flags) and the conversion statistics that drive the energy model.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fixedpoint as fxp

RADIX_BITS = 20
RADIX = 1 << RADIX_BITS
RADIX_MASK = RADIX - 1


@dataclasses.dataclass(frozen=True)
class CrossbarSpec:
    """Static description of one crossbar datapath (paper Table I defaults)."""

    rows: int = 128  # wordlines simultaneously active
    cols: int = 128  # bitlines per crossbar
    cell_bits: int = 2
    dac_bits: int = 1
    weight_bits: int = 16
    input_bits: int = 16
    out_bits: int = 16
    drop_lsb: int = 10  # LSBs dropped by the output scaling stage
    signed_weights: bool = True

    @property
    def n_slices(self) -> int:
        return -(-self.weight_bits // self.cell_bits)

    @property
    def n_iters(self) -> int:
        return -(-self.input_bits // self.dac_bits)

    @property
    def partial_max(self) -> int:
        """Max value of one column partial: rows * (2^cell-1) * (2^dac-1)."""
        return self.rows * ((1 << self.cell_bits) - 1) * ((1 << self.dac_bits) - 1)

    @property
    def adc_bits(self) -> int:
        """Bits needed to represent one lossless column conversion (9 for default)."""
        return max(1, math.ceil(math.log2(self.partial_max + 1)))

    @property
    def acc_bits(self) -> int:
        """Exact accumulator width for a single row-group (39 for default)."""
        total_max = self.partial_max * sum(
            1 << self.base_shift(t, s)
            for t in range(self.n_iters)
            for s in range(self.n_slices)
        )
        return max(1, math.ceil(math.log2(total_max + 1)))

    @property
    def weight_bias(self) -> int:
        return (1 << (self.weight_bits - 1)) if self.signed_weights else 0

    def base_shift(self, t: int, s: int) -> int:
        """Accumulator bit position of partial (iteration t, slice s)."""
        return t * self.dac_bits + s * self.cell_bits

    def replace(self, **kw) -> "CrossbarSpec":
        return dataclasses.replace(self, **kw)


DEFAULT_SPEC = CrossbarSpec()


@dataclasses.dataclass
class ConversionStats:
    """ADC work accounting — the paper's currency for energy.

    ``conversions``: number of ADC samples actually taken (one per column x
    group x t x s x input-vector, minus any skipped).  ``bit_decisions``:
    total SAR bit tests performed, which is what the adaptive scheme
    reduces.  ``skipped_conversions``: samples a zero-plane-aware ADC never
    takes because the input bit-plane for the whole row block is zero
    (kernel ``skip_zero_planes`` / Ibrayev et al. activity skipping);
    ``conversions + skipped_conversions`` is the dense count.
    ``iterations``: 100 ns crossbar cycles consumed.  All python ints.

    ``a + b`` models *sequential* composition — two VMMs issued back-to-back
    on the same datapath — so every field adds, including ``iterations``
    (total cycles, hence a latency count, not a max).  Stats for VMMs that
    run on disjoint crossbars in parallel should instead combine energy
    fields with ``+`` and take ``max`` of ``iterations`` by hand.  (An
    earlier revision documented ``iterations`` as a "max latency proxy"
    while ``__add__`` summed ``max(x, 0)`` terms — i.e. it silently summed;
    the sum semantic is now the documented one and is pinned by tests.)
    """

    conversions: int = 0
    bit_decisions: int = 0
    iterations: int = 0  # total 100ns crossbar cycles (sequential latency)
    skipped_conversions: int = 0

    def __add__(self, other: "ConversionStats") -> "ConversionStats":
        return ConversionStats(
            conversions=self.conversions + other.conversions,
            bit_decisions=self.bit_decisions + other.bit_decisions,
            iterations=self.iterations + other.iterations,
            skipped_conversions=self.skipped_conversions + other.skipped_conversions,
        )


# ---------------------------------------------------------------------------
# Two-limb (radix 2**20) accumulator helpers — jit-safe 39+ bit integers.
# ---------------------------------------------------------------------------

def limb_normalize(hi: jnp.ndarray, lo: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Bring ``lo`` into [0, RADIX); works for signed (hi, lo) pairs."""
    carry = lo >> RADIX_BITS  # arithmetic shift == floor division by RADIX
    return hi + carry, lo - (carry << RADIX_BITS)


def limb_add(a, b):
    return limb_normalize(a[0] + b[0], a[1] + b[1])


def limb_sub(a, b):
    return limb_normalize(a[0] - b[0], a[1] - b[1])


def limb_from_int(v: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """int32 value -> normalized limb pair."""
    return limb_normalize(jnp.zeros_like(v), v)


def limb_from_int_shifted(v: jnp.ndarray, shift: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Limb pair holding ``v * 2**shift`` for int32 ``v`` (|v| < 2**30).

    Used by Karatsuba/Strassen recombination where sub-products fit in int32
    but their shifted positions do not.  Exact for signed ``v`` (two's
    complement identity ``v = (v >> k) * 2**k + (v & (2**k - 1))``).
    """
    v = v.astype(jnp.int32)
    if shift >= RADIX_BITS:
        return limb_normalize(v << (shift - RADIX_BITS), jnp.zeros_like(v))
    k = RADIX_BITS - shift
    hi = v >> k  # arithmetic shift: floor(v / 2**k)
    lo = (v & ((1 << k) - 1)) << shift  # < RADIX, non-negative
    return hi, lo


# ---------------------------------------------------------------------------
# Core datapath
# ---------------------------------------------------------------------------

def _grouped_planes(x_codes: jnp.ndarray, spec: CrossbarSpec):
    """DAC view of a padded (B, Kp) input block: (T, B, G, R) planes.

    regroup DAC bits: dac_bits=1 -> T = input_bits planes of 1 bit each;
    otherwise dac_bits consecutive planes combine into one multi-bit level.
    """
    B, Kp = x_codes.shape
    G = Kp // spec.rows
    planes = fxp.bit_planes(x_codes, spec.input_bits)  # (T', B, Kp) with T'=input_bits
    if spec.dac_bits != 1:
        T = spec.n_iters
        pw = (1 << jnp.arange(spec.dac_bits, dtype=jnp.int32)).reshape(1, -1, 1, 1)
        planes = jnp.pad(planes, ((0, T * spec.dac_bits - planes.shape[0]), (0, 0), (0, 0)))
        planes = planes.reshape(T, spec.dac_bits, B, Kp)
        planes = jnp.sum(planes * pw, axis=1)
    return planes.reshape(planes.shape[0], B, G, spec.rows)


def _grouped(x_codes: jnp.ndarray, w_codes: jnp.ndarray, spec: CrossbarSpec):
    """Pad the contraction dim to a multiple of ``spec.rows`` and reshape.

    x_codes: (B, K) unsigned input codes; w_codes: (K, N) *biased* cell codes.
    Returns planes (T, B, G, R), slices (S, G, R, N), n_groups.
    """
    B, K = x_codes.shape
    Kp = -(-K // spec.rows) * spec.rows
    if Kp != K:
        x_codes = jnp.pad(x_codes, ((0, 0), (0, Kp - K)))
        w_codes = jnp.pad(w_codes, ((0, Kp - K), (0, 0)))
    G = Kp // spec.rows
    planes = _grouped_planes(x_codes, spec)
    slices = fxp.cell_slices(w_codes, spec.weight_bits, spec.cell_bits)
    slices = slices.reshape(slices.shape[0], G, spec.rows, w_codes.shape[1])
    return planes, slices, G


def _column_partials(planes: jnp.ndarray, slices: jnp.ndarray) -> jnp.ndarray:
    """All ADC column conversions: (T, S, B, G, N) int32, each <= partial_max."""
    return jnp.einsum(
        "tbgr,sgrn->tsbgn",
        planes.astype(jnp.float32),
        slices.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ).astype(jnp.int32)


def accumulate_partials(
    partials: jnp.ndarray, spec: CrossbarSpec
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Shift-add (T, S, B, G, N) int32 partials into a (B, N) limb pair.

    Shared by the ideal and device-perturbed datapaths: once the column
    conversions exist as integers, the digital shift-and-add tree is the
    same exact two-limb arithmetic either way.
    """
    T, S = partials.shape[0], partials.shape[1]
    t_idx = jnp.arange(T, dtype=jnp.int32) * spec.dac_bits
    s_idx = jnp.arange(S, dtype=jnp.int32) * spec.cell_bits
    base = (t_idx[:, None] + s_idx[None, :]).reshape(T, S, 1, 1, 1)  # (T,S,1,1,1)

    # Split each shifted partial into limbs without overflowing int32:
    # if base < RADIX_BITS: p << base fits in base+adc_bits <= 19+9=28 bits.
    # if base >= RADIX_BITS: contribution is entirely in the hi limb.
    base_lo = jnp.minimum(base, RADIX_BITS - 1)
    shifted = partials << base_lo  # safe
    c_lo = jnp.where(base < RADIX_BITS, shifted & RADIX_MASK, 0)
    c_hi = jnp.where(
        base < RADIX_BITS,
        shifted >> RADIX_BITS,
        partials << jnp.maximum(base - RADIX_BITS, 0),
    )
    # Sum over (t, s) first: <= T*S*2^20 < 2^28 for the lo limb — safe.
    lo_ts = jnp.sum(c_lo, axis=(0, 1))  # (B, G, N)
    hi_ts = jnp.sum(c_hi, axis=(0, 1))
    # Normalize per group, then reduce over groups.
    hi_g, lo_g = limb_normalize(hi_ts, lo_ts)
    hi = jnp.sum(hi_g, axis=1)
    lo = jnp.sum(lo_g, axis=1)  # <= G * 2^20; G <= 2^10 keeps this < 2^31
    return limb_normalize(hi, lo)


def _apply_partial_transform(partials, spec, partial_transform):
    flags = None
    if partial_transform is not None:
        partials, flags = partial_transform(partials, spec)
        if flags is not None:
            flags = jnp.any(flags, axis=(0, 1, 3))  # (B, N)
    return partials, flags


def crossbar_accumulate(
    x_codes: jnp.ndarray,
    w_codes_biased: jnp.ndarray,
    spec: CrossbarSpec,
    partial_transform=None,
) -> Tuple[Tuple[jnp.ndarray, jnp.ndarray], Optional[jnp.ndarray]]:
    """Run the full analog pipeline, returning the exact accumulator.

    Args:
      x_codes: (B, K) unsigned input codes in [0, 2**input_bits).
      w_codes_biased: (K, N) unsigned cell codes in [0, 2**weight_bits).
      partial_transform: optional ``fn(partials, spec) -> (partials, flags)``
        hook used by the adaptive-ADC model to round/mask each (t, s)
        conversion; ``flags`` (B, N) bool marks columns whose above-window
        MSBs fired (=> clamp), or None.

    Returns:
      ((hi, lo), flags): normalized limb pair of shape (B, N) holding the
      exact (or ADC-transformed) accumulator value; flags as above.
    """
    planes, slices, G = _grouped(x_codes, w_codes_biased, spec)
    partials = _column_partials(planes, slices)  # (T,S,B,G,N)
    partials, flags = _apply_partial_transform(partials, spec, partial_transform)
    return accumulate_partials(partials, spec), flags


def noisy_crossbar_accumulate(
    x_codes: jnp.ndarray,
    g_eff: jnp.ndarray,
    spec: CrossbarSpec,
    partial_transform=None,
) -> Tuple[Tuple[jnp.ndarray, jnp.ndarray], Optional[jnp.ndarray]]:
    """Analog pipeline against *perturbed* per-slice cell values.

    ``g_eff``: (S, K, N) float32 effective cell codes from
    ``repro.device.models.effective_cell_codes`` — grid-quantized so the f32
    column dot products below are exact (any summation order).  Each column
    conversion is what a real ADC does to the analog bitline current: round
    to the nearest integer code, saturating at ``partial_max``.  From there
    the digital shift-add tree is identical to the ideal path, so a zero-
    noise ``g_eff`` reproduces ``crossbar_accumulate`` bit-for-bit.
    """
    B, K = x_codes.shape
    Kp = -(-K // spec.rows) * spec.rows
    if Kp != K:
        x_codes = jnp.pad(x_codes, ((0, 0), (0, Kp - K)))
        g_eff = jnp.pad(g_eff, ((0, 0), (0, Kp - K), (0, 0)))
    G = Kp // spec.rows
    planes = _grouped_planes(x_codes, spec)
    slices = g_eff.astype(jnp.float32).reshape(g_eff.shape[0], G, spec.rows, g_eff.shape[2])
    raw = jnp.einsum(
        "tbgr,sgrn->tsbgn",
        planes.astype(jnp.float32),
        slices,
        preferred_element_type=jnp.float32,
    )
    # ADC sampling of the analog column current: round-half-up, saturating.
    partials = jnp.floor(raw + 0.5).astype(jnp.int32)
    partials = jnp.clip(partials, 0, spec.partial_max)
    partials, flags = _apply_partial_transform(partials, spec, partial_transform)
    return accumulate_partials(partials, spec), flags


def requantize_limbs(
    acc: Tuple[jnp.ndarray, jnp.ndarray],
    spec: CrossbarSpec,
    x_sum: Optional[jnp.ndarray] = None,
    clamp_flags: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Scaling stage: drop ``drop_lsb`` LSBs (round-half-up), clamp to out_bits.

    For signed weights the digital bias correction ``2**(wb-1) * sum(x)`` is
    applied first (``x_sum``: (B,) int32 sum of input codes).
    """
    hi, lo = acc
    if spec.signed_weights:
        assert x_sum is not None
        # bias = x_sum << (weight_bits - 1); decompose into limbs.
        wb = spec.weight_bits - 1
        if wb >= RADIX_BITS:
            b_hi = x_sum << (wb - RADIX_BITS)
            b_lo = jnp.zeros_like(x_sum)
        else:
            b_hi = x_sum >> (RADIX_BITS - wb)
            b_lo = (x_sum << wb) & RADIX_MASK
        hi, lo = limb_normalize(hi - b_hi[:, None], lo - b_lo[:, None])
        out_max = (1 << (spec.out_bits - 1)) - 1
        out_min = -(1 << (spec.out_bits - 1))
    else:
        out_max = (1 << spec.out_bits) - 1
        out_min = 0

    y = _scale_round_clip(hi, lo, spec.drop_lsb, out_min, out_max)
    if clamp_flags is not None:
        y = jnp.where(clamp_flags, out_max, y)
    return y.astype(jnp.int32)


def _scale_round_clip(hi, lo, d: int, out_min: int, out_max: int):
    """Exact round-half-up shift of a normalized limb pair, then clip.

    For d < 20 the value is reassembled with a saturation pre-check; for
    d >= 20: floor((hi*2^20 + lo + 2^(d-1)) / 2^d) = (hi + ((lo+half)>>20))
    >> (d-20), exact because the discarded cross term is < 2^d.
    """
    assert 0 < d
    if d < RADIX_BITS:
        hi_cap = (1 << max((out_max.bit_length() + d) - RADIX_BITS + 1, 1)) + 1
        hi_c = jnp.clip(hi, -hi_cap, hi_cap)
        y = (hi_c << (RADIX_BITS - d)) + ((lo + (1 << (d - 1))) >> d)
        y = jnp.where(hi > hi_cap, out_max, jnp.where(hi < -hi_cap, out_min, y))
    else:
        half = 1 << (d - 1)
        if d - 1 >= 31:
            # half exceeds int32; fold it into the hi limb exactly
            tmp = lo
            hi = hi + (1 << (d - 1 - RADIX_BITS))
        else:
            tmp = lo + half
        H = hi + (tmp >> RADIX_BITS)
        y = H >> (d - RADIX_BITS)
    return jnp.clip(y, out_min, out_max)


def requantize_exact_limbs(
    acc: Tuple[jnp.ndarray, jnp.ndarray], spec: CrossbarSpec, signed_out: bool = True
) -> jnp.ndarray:
    """Scale+clamp a limb accumulator that already holds the exact ``x @ w``
    (bias corrections applied by the caller, e.g. ``signed_vmm_limbs``)."""
    hi, lo = limb_normalize(*acc)
    if signed_out:
        out_max = (1 << (spec.out_bits - 1)) - 1
        out_min = -(1 << (spec.out_bits - 1))
    else:
        out_max = (1 << spec.out_bits) - 1
        out_min = 0
    return _scale_round_clip(hi, lo, spec.drop_lsb, out_min, out_max).astype(jnp.int32)


def layer_scaled_spec(spec: CrossbarSpec, k: int) -> CrossbarSpec:
    """Per-layer output scaling (the paper's "scaling factor" stage).

    The fixed-point format of a layer's output is chosen offline so the
    worst-case accumulator of a K-row dot product fits the ``out_bits``
    window after the shift: drop >= in + w - 1 + ceil(log2 K) - (out - 1).
    """
    need = (
        spec.input_bits
        + spec.weight_bits
        - 1
        + max(0, math.ceil(math.log2(max(2, k))))
        - (spec.out_bits - 1)
    )
    return spec.replace(drop_lsb=max(spec.drop_lsb, need))


def crossbar_vmm(
    x_codes: jnp.ndarray,
    w_codes: jnp.ndarray,
    spec: CrossbarSpec = DEFAULT_SPEC,
    partial_transform=None,
    device=None,
) -> jnp.ndarray:
    """End-to-end crossbar VMM on integer codes.

    x_codes: (..., K) unsigned input codes.  w_codes: (K, N) **signed** codes
    if ``spec.signed_weights`` else unsigned.  Returns (..., N) int32 output
    codes (``out_bits`` wide, signed per spec).

    ``device``: optional ``repro.device.models.DeviceConfig``; when set, the
    weight slab is programmed through the device non-ideality pipeline and
    the VMM runs against the perturbed cells (the ideal config is a no-op).
    A config provisioning ``spare_cols`` additionally routes the slab
    through the fault-aware spare-column repair planner (``device.repair``)
    before the VMM — faulty columns serve from programmed spares.
    """
    batch_shape = x_codes.shape[:-1]
    K = x_codes.shape[-1]
    xb = x_codes.reshape(-1, K).astype(jnp.int32)
    if spec.signed_weights:
        wb = (w_codes.astype(jnp.int32) + spec.weight_bias)
        x_sum = jnp.sum(xb, axis=-1)
    else:
        wb = w_codes.astype(jnp.int32)
        x_sum = None
    if device is not None and not device.is_ideal:
        from repro.device import models as dev_models  # deferred: device imports core

        g_eff = dev_models.effective_cell_codes(wb, spec, device)
        acc, flags = noisy_crossbar_accumulate(xb, g_eff, spec, partial_transform)
    else:
        acc, flags = crossbar_accumulate(xb, wb, spec, partial_transform)
    y = requantize_limbs(acc, spec, x_sum=x_sum, clamp_flags=flags)
    return y.reshape(batch_shape + (w_codes.shape[-1],))


def noisy_crossbar_vmm(
    x_codes: jnp.ndarray,
    g_eff: jnp.ndarray,
    spec: CrossbarSpec = DEFAULT_SPEC,
    partial_transform=None,
) -> jnp.ndarray:
    """Crossbar VMM against precomputed effective cell codes.

    Same contract as ``crossbar_vmm`` but the weights are already programmed:
    ``g_eff`` is the (S, K, N) float32 effective-cell-code array (biased
    representation) — possibly a *repaired* layout with spare-column cells
    already scattered into victim positions (``device.repair``; the datapath
    is column-separable, so nothing downstream can tell).  This is the
    functional oracle for the batched Pallas kernel ``kernels.noisy_vmm``.
    """
    batch_shape = x_codes.shape[:-1]
    K = x_codes.shape[-1]
    xb = x_codes.reshape(-1, K).astype(jnp.int32)
    x_sum = jnp.sum(xb, axis=-1) if spec.signed_weights else None
    acc, flags = noisy_crossbar_accumulate(xb, g_eff, spec, partial_transform)
    y = requantize_limbs(acc, spec, x_sum=x_sum, clamp_flags=flags)
    return y.reshape(batch_shape + (g_eff.shape[-1],))


def signed_vmm_limbs(
    x: jnp.ndarray,
    w: jnp.ndarray,
    spec: CrossbarSpec,
    signed_inputs: bool = False,
    partial_transform=None,
) -> Tuple[Tuple[jnp.ndarray, jnp.ndarray], Optional[jnp.ndarray]]:
    """Exact limb accumulator of ``x @ w`` through the analog pipeline.

    Generalizes the datapath to signed inputs *and* signed weights via offset
    encoding with digital correction (the input-side analogue of ISAAC's
    weight bias): with offsets ``ox = 2**(in_bits-1)``, ``ow = 2**(w_bits-1)``

        sum (x+ox)(w+ow) = sum x w + ox * sum_col(w) + ow * sum(x) + K*ox*ow

    The three correction terms are exact digital computations (the column
    sums of installed weights are precomputed at write time on real hardware).
    Used by Karatsuba/Strassen, which need exact sub-products.

    x: (B, K) int codes; w: (K, N) int codes.  Returns ((hi, lo), flags).
    """
    B, K = x.shape
    ox = (1 << (spec.input_bits - 1)) if signed_inputs else 0
    ow = spec.weight_bias
    xu = (x.astype(jnp.int32) + ox)
    wu = (w.astype(jnp.int32) + ow)
    acc, flags = crossbar_accumulate(xu, wu, spec, partial_transform)
    hi, lo = acc
    # acc = sum_k (x_k + ox)(w_k + ow); peel the offsets digitally:
    # x@w = acc - ox * colsum(w_u) - ow * rowsum(x_u) + K * ox * ow
    N = w.shape[1]
    corr = (jnp.zeros((B, N), jnp.int32), jnp.zeros((B, N), jnp.int32))
    if ox:
        col_wu = jnp.sum(wu, axis=0)  # (N,), <= K * 2**w_bits
        h, l = limb_from_int_shifted(col_wu, spec.input_bits - 1)
        corr = limb_add(corr, (jnp.broadcast_to(h, (B, N)), jnp.broadcast_to(l, (B, N))))
    if ow:
        row_xu = jnp.sum(xu, axis=-1)[:, None]  # (B, 1)
        h, l = limb_from_int_shifted(row_xu, spec.weight_bits - 1)
        corr = limb_add(corr, (jnp.broadcast_to(h, (B, N)), jnp.broadcast_to(l, (B, N))))
    hi, lo = limb_sub((hi, lo), corr)
    if ox and ow:
        kxw = K * ox * ow  # python int, exact
        add_hi = kxw >> RADIX_BITS
        add_lo = kxw & RADIX_MASK
        hi, lo = limb_normalize(hi + add_hi, lo + add_lo)
    return (hi, lo), flags


def plane_activity(
    x_codes: jnp.ndarray, spec: CrossbarSpec, block_m: int = 128
) -> Tuple[int, int]:
    """Row-weighted (active, total) input bit-plane counts for a VMM input.

    Mirrors the Pallas kernels' ``skip_zero_planes`` granularity: the kernel
    skips all S slice-dots of iteration ``t`` for a ``(bm, rows)`` input
    block whose bit-plane is entirely zero, so every row in the block shares
    the skip decision.  One "row-plane" here is (input row, iteration t, row
    group g); each active row-plane costs ``n_cols * n_slices`` ADC
    conversions.  Returns python ints with ``active <= total``;
    ``total * n * n_slices`` is the dense conversion count.
    """
    x2 = x_codes.reshape(-1, x_codes.shape[-1]).astype(jnp.int32)
    B, K = x2.shape
    Kp = -(-K // spec.rows) * spec.rows
    if Kp != K:
        x2 = jnp.pad(x2, ((0, 0), (0, Kp - K)))
    planes = _grouped_planes(x2, spec)  # (T, B, G, R)
    nz = np.asarray(jnp.any(planes != 0, axis=3))  # (T, B, G)
    T, _, G = nz.shape
    bm = min(block_m, max(8, B))  # the kernel wrappers' block choice
    active = 0
    for start in range(0, B, bm):
        rows = min(start + bm, B) - start
        blk = nz[:, start : start + bm, :].any(axis=1)  # (T, G)
        active += int(blk.sum()) * rows
    return active, T * G * B


def conversion_stats(
    batch: int,
    k: int,
    n: int,
    spec: CrossbarSpec,
    bits_per_conversion: Optional[float] = None,
    x_codes: Optional[jnp.ndarray] = None,
    block_m: int = 128,
) -> ConversionStats:
    """ADC work for one VMM of shape (batch, k) x (k, n).

    With ``x_codes`` (the actual unsigned input codes) the count becomes
    activity-aware: conversions belonging to all-zero input bit-planes — the
    ones ``skip_zero_planes`` kernels never issue and a zero-plane-aware ADC
    never samples — move to ``skipped_conversions``.
    """
    groups = -(-k // spec.rows)
    convs = batch * n * groups * spec.n_iters * spec.n_slices
    skipped = 0
    if x_codes is not None:
        active, total = plane_activity(x_codes, spec, block_m=block_m)
        if total != batch * spec.n_iters * groups:
            raise ValueError(
                f"x_codes {x_codes.shape} inconsistent with batch={batch}, k={k}"
            )
        active_convs = active * n * spec.n_slices
        skipped = convs - active_convs
        convs = active_convs
    bits = bits_per_conversion if bits_per_conversion is not None else spec.adc_bits
    return ConversionStats(
        conversions=convs,
        bit_decisions=int(round(convs * bits)),
        iterations=spec.n_iters,
        skipped_conversions=skipped,
    )


# ---------------------------------------------------------------------------
# Float-level convenience API (used by models.CrossbarLinear and examples)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class QuantParams:
    """Symmetric-ish affine quantization of a float matmul onto the datapath."""

    x_scale: float  # x_code = round(x / x_scale), unsigned
    w_scale: float  # w_code = round(w / w_scale), signed
    out_frac_shift: int = 0  # extra output shift folded into drop_lsb


def quantize_input(x: jnp.ndarray, spec: CrossbarSpec, x_scale: float) -> jnp.ndarray:
    q = jnp.round(x / x_scale)
    return jnp.clip(q, 0, (1 << spec.input_bits) - 1).astype(jnp.int32)


def quantize_weight(w: jnp.ndarray, spec: CrossbarSpec, w_scale: float) -> jnp.ndarray:
    q = jnp.round(w / w_scale)
    lim = 1 << (spec.weight_bits - 1)
    return jnp.clip(q, -lim, lim - 1).astype(jnp.int32)


def crossbar_matmul_f32(
    x: jnp.ndarray,
    w: jnp.ndarray,
    spec: CrossbarSpec = DEFAULT_SPEC,
    qp: Optional[QuantParams] = None,
    partial_transform=None,
    device=None,
) -> jnp.ndarray:
    """Quantize float operands, run the crossbar pipeline, dequantize.

    A float reference for a CrossbarLinear layer: ``y ~ x @ w`` with ISAAC
    fixed-point semantics.  ``x`` must be non-negative (post-ReLU/softmax
    style) unless callers offset-encode.
    """
    spec = layer_scaled_spec(spec, x.shape[-1])
    if qp is None:
        x_scale = jnp.maximum(jnp.max(x), 1e-9) / ((1 << spec.input_bits) - 1)
        w_scale = jnp.maximum(jnp.max(jnp.abs(w)), 1e-9) / ((1 << (spec.weight_bits - 1)) - 1)
    else:
        x_scale, w_scale = qp.x_scale, qp.w_scale
    xq = quantize_input(x, spec, x_scale)
    wq = quantize_weight(w, spec, w_scale)
    yq = crossbar_vmm(xq, wq, spec, partial_transform=partial_transform, device=device)
    return yq.astype(jnp.float32) * (x_scale * w_scale * (2.0 ** spec.drop_lsb))


def exact_vmm_reference(x_codes: np.ndarray, w_codes: np.ndarray, spec: CrossbarSpec) -> np.ndarray:
    """Numpy int64 oracle for the full datapath (used by tests only)."""
    x = x_codes.astype(np.int64)
    w = w_codes.astype(np.int64)
    total = x @ w  # exact in int64
    d = spec.drop_lsb
    y = (total + (1 << (d - 1))) >> d
    if spec.signed_weights:
        out_max, out_min = (1 << (spec.out_bits - 1)) - 1, -(1 << (spec.out_bits - 1))
    else:
        out_max, out_min = (1 << spec.out_bits) - 1, 0
    return np.clip(y, out_min, out_max)
