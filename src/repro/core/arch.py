"""Machine description for ISAAC/Newton accelerators (paper Table I + §IV).

The hierarchy is chip -> tile -> IMA -> crossbar.  Component unit costs come
from Newton's Table I; components Newton does not re-list (eDRAM, buses,
registers, shift-and-add, sigmoid/pool) use the ISAAC ISCA'16 table at the
same 32 nm node, which Newton's methodology section says it shares.

Anchors used for validation (see tests/test_energy_model.py):
  * ISAAC peak computational efficiency ~ 479 GOPS/(s mm^2), power
    efficiency ~ 644 GOPS/W (ISAAC paper, reproduced in Newton Fig 20).
  * ADC ~ 49% of ISAAC chip power (Newton §V).
  * Average ISAAC op ~ 1.8 pJ; Newton op ~ 0.85 pJ; ideal neuron 0.33 pJ.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

from repro.core.adc import ADCConfig, SARModel, adaptive_schedule, DEFAULT_SAR
from repro.core.crossbar import CrossbarSpec, DEFAULT_SPEC


@dataclasses.dataclass(frozen=True)
class Component:
    """A leaf hardware component with peak power and area."""

    name: str
    power_w: float
    area_mm2: float


# --- Table I (Newton) ------------------------------------------------------
ROUTER = Component("router", 168e-3, 0.604)  # 32 flits, 8 ports; shared by 4 tiles
ADC_8B = Component("adc", 3.1e-3, 0.0015)  # 8-bit @ 1.28 GS/s (Kull [18])
HYPER_TRANSPORT = Component("hyper_transport", 10.4, 22.88)  # 4 links, 6.4 GB/s
DAC_ARRAY_128 = Component("dac_array", 0.5e-3, 0.00002)  # 128 x 1-bit
CROSSBAR_128 = Component("crossbar", 0.3e-3, 0.0001)  # 128x128 memristor array

# --- ISAAC ISCA'16 tile components (same 32 nm CACTI/Orion methodology) ----
EDRAM_64KB = Component("edram_64k", 20.7e-3, 0.083)
EDRAM_BUS = Component("edram_bus", 7e-3, 0.090)
SIGMOID = Component("sigmoid", 0.52e-3, 0.0006)
SHIFT_ADD_TILE = Component("s+a_tile", 0.05e-3, 0.00006)
MAXPOOL = Component("maxpool", 0.4e-3, 0.00024)
TILE_OR = Component("tile_or", 1.68e-3, 0.0032)
IMA_IR = Component("ima_ir", 1.24e-3, 0.0021)  # 2 KB input register
IMA_OR = Component("ima_or", 0.23e-3, 0.00077)
IMA_SA = Component("ima_s+a", 0.2e-3, 0.00024)
SAMPLE_HOLD = Component("s+h", 0.01e-3, 0.00004)


def edram_component(kb: float) -> Component:
    """eDRAM buffer scaled from the 64 KB CACTI point.

    Small buffers keep a fixed periphery overhead; we use a 15% floor plus
    linear banking, which reproduces ISAAC's 64 KB point exactly and gives
    16 KB ~ 0.33x power/area (consistent with Newton Fig 16's ~6.5% area
    efficiency gain at chip level).
    """
    f = kb / 64.0
    scale = 0.15 + 0.85 * f
    return Component(f"edram_{kb:g}k", EDRAM_64KB.power_w * scale, EDRAM_64KB.area_mm2 * scale)


def htree_component(n_leaves: int, out_width_bits: int, shared_inputs: bool) -> Component:
    """Input/output HTree of an IMA.

    Parametric wire model: area/power scale with (leaf count) x (link width).
    The paper's central T1 observation is that ISAAC's HTree is provisioned
    for the *worst case* — every crossbar may serve a different layer, so
    input wiring cannot be shared along the tree (2x input links), and every
    output link carries full 39-bit partials privately to the IMA output
    register.  Newton constrains an IMA to one layer / <=128 shared inputs
    and embeds shift-and-add units at HTree junctions, so input links are
    shared and output links carry reduced partials (~23 bits mean; 16 bits
    once the adaptive ADC clamps the window).

    Unit costs are calibrated once against the paper's own T1 measurement
    (+37% area efficiency, +18% power/energy efficiency — Fig 11) and held
    fixed for every other configuration; see tests/test_energy_model.py.
    """
    unit_area = 2.65e-5  # mm^2 per leaf-bit (calibrated, see above)
    unit_power = 9.0e-6  # W per leaf-bit
    in_bits = 16 * (2.0 if not shared_inputs else 1.0)  # input stream links
    leaf_bits = n_leaves * (out_width_bits + in_bits)
    return Component("htree", unit_power * leaf_bits, unit_area * leaf_bits)


@dataclasses.dataclass(frozen=True)
class IMAConfig:
    """An In-situ Multiply-Accumulate unit."""

    name: str
    crossbars: int = 8  # physical 128x128 arrays
    rows: int = 128  # inputs processed per VMM
    out_cols: int = 128  # output neurons per VMM
    adcs: int = 8
    adc_rate: float = 1.28e9  # samples/s
    adc_cfg: ADCConfig = ADCConfig(mode="full")
    xbar_spec: CrossbarSpec = DEFAULT_SPEC
    compact_htree: bool = False  # Newton T1
    karatsuba_levels: int = 0  # Newton T3 (crossbar count grows per Fig 9)
    sar: SARModel = DEFAULT_SAR

    @property
    def weights_per_ima(self) -> int:
        return self.rows * self.out_cols

    @property
    def n_slices(self) -> int:
        return self.xbar_spec.n_slices

    @property
    def iters_per_vmm(self) -> int:
        if self.karatsuba_levels == 0:
            return self.xbar_spec.n_iters
        from repro.core.karatsuba import karatsuba_cost

        return karatsuba_cost(self.karatsuba_levels, self.xbar_spec).iterations

    @property
    def vmm_time_s(self) -> float:
        return self.iters_per_vmm * 100e-9

    @property
    def macs_per_vmm(self) -> int:
        return self.rows * self.out_cols

    def adc_mean_power_w(self) -> float:
        """Mean ADC power across a VMM under the configured schedule.

        The energy schedule follows the paper's Fig-5 (unsigned) example;
        see adc.window for the signed-datapath discussion.
        """
        sched = adaptive_schedule(
            self.xbar_spec.replace(signed_weights=False), self.adc_cfg
        )
        mean_bits = float(sched.mean())
        full = ADC_8B.power_w * (self.adc_rate / 1.28e9)
        # SAR energy ~ cdac_frac + rest * bits/full_bits (adc.SARModel)
        s = self.sar
        frac = s.cdac_frac + (s.digital_frac + s.analog_frac) * (
            mean_bits / s.full_bits
        )
        if self.karatsuba_levels > 0:
            from repro.core.karatsuba import karatsuba_cost

            c = karatsuba_cost(self.karatsuba_levels, self.xbar_spec)
            base = self.xbar_spec.n_iters * self.xbar_spec.n_slices
            frac *= (c.adc_slots / base) * (self.xbar_spec.n_iters / c.iterations)
        return full * frac

    def power_area(self) -> Dict[str, Component]:
        comps: Dict[str, Component] = {}
        # Karatsuba adds crossbars per mat, but DAC/ADC/HTree ports are
        # *shared within a mat* (Fig 9: "each mat now has two crossbars that
        # share the DAC and ADC"), so only the array count grows.
        n_mats = self.crossbars
        n_xbar = self.crossbars
        if self.karatsuba_levels == 1:
            n_xbar = max(n_xbar, 13)  # Fig 9: 8 mats x 2 xbars, 3 unused
        elif self.karatsuba_levels == 2:
            n_xbar = max(n_xbar, 20)
        col_groups = self.out_cols // self.xbar_spec.cols
        n_xbar = n_xbar * col_groups
        n_mats = n_mats * col_groups
        comps["crossbar"] = Component(
            "crossbar", CROSSBAR_128.power_w * n_xbar, CROSSBAR_128.area_mm2 * n_xbar
        )
        comps["dac"] = Component(
            "dac", DAC_ARRAY_128.power_w * n_mats, DAC_ARRAY_128.area_mm2 * n_mats
        )
        n_adc = self.adcs * col_groups
        comps["adc"] = Component(
            "adc", self.adc_mean_power_w() * n_adc, ADC_8B.area_mm2 * n_adc
        )
        comps["s+h"] = Component(
            "s+h", SAMPLE_HOLD.power_w * n_mats, SAMPLE_HOLD.area_mm2 * n_mats
        )
        # Input/output registers: ISAAC provisions a 2 KB IR (worst-case
        # multi-layer inputs) and a 39-bit-wide OR; Newton's constraint
        # (single layer, <=128 inputs) shrinks the IR 4x, and the embedded
        # shift-and-add (+ adaptive ADC) narrows the OR to 16 bits.
        if self.compact_htree:
            comps["ir"] = Component("ir", IMA_IR.power_w / 4, IMA_IR.area_mm2 / 4)
        else:
            comps["ir"] = IMA_IR
        out_bits = 23 if self.compact_htree else self.xbar_spec.acc_bits
        if self.adc_cfg.mode == "adaptive":
            out_bits = 16
        or_scale = out_bits / self.xbar_spec.acc_bits
        comps["or"] = Component(
            "or", IMA_OR.power_w * or_scale, IMA_OR.area_mm2 * or_scale
        )
        comps["s+a"] = IMA_SA
        comps["htree"] = htree_component(
            n_leaves=n_mats + col_groups,
            out_width_bits=out_bits,
            shared_inputs=self.compact_htree,
        )
        return comps

    def total_power_w(self) -> float:
        return sum(c.power_w for c in self.power_area().values())

    def total_area_mm2(self) -> float:
        return sum(c.area_mm2 for c in self.power_area().values())


@dataclasses.dataclass(frozen=True)
class TileConfig:
    name: str
    ima: IMAConfig
    imas: int = 12
    edram_kb: float = 64.0
    kind: str = "conv"  # "conv" | "fc"
    adc_slowdown: float = 1.0  # FC tiles run ADCs N x slower (T5)
    xbars_per_adc: int = 1  # FC tiles share one ADC across 4 crossbars (T5)

    def power_area(self) -> Dict[str, Component]:
        comps: Dict[str, Component] = {}
        ima_pa = self.ima.power_area()
        for k, c in ima_pa.items():
            p, a = c.power_w, c.area_mm2
            if k == "adc":
                p = p / self.adc_slowdown / self.xbars_per_adc
                a = a / self.xbars_per_adc
            elif k in ("crossbar", "dac", "s+h"):
                # FC tiles fire a crossbar read every ADC window, so the
                # whole analog read path slows with the ADC (T5).
                p = p / self.adc_slowdown
            comps[f"ima_{k}"] = Component(k, p * self.imas, a * self.imas)
        comps["edram"] = edram_component(self.edram_kb)
        comps["edram_bus"] = EDRAM_BUS
        comps["router"] = Component("router", ROUTER.power_w / 4, ROUTER.area_mm2 / 4)
        comps["sigmoid"] = SIGMOID
        comps["s+a"] = SHIFT_ADD_TILE
        comps["maxpool"] = MAXPOOL
        comps["or"] = TILE_OR
        return comps

    def total_power_w(self) -> float:
        return sum(c.power_w for c in self.power_area().values())

    def total_area_mm2(self) -> float:
        return sum(c.area_mm2 for c in self.power_area().values())

    @property
    def weights_per_tile(self) -> int:
        return self.imas * self.ima.weights_per_ima

    def peak_gops(self) -> float:
        """Peak 16-bit fixed point GOPS (MAC = 2 ops), iso with the paper."""
        ops = 2 * self.imas * self.ima.macs_per_vmm / self.ima.vmm_time_s
        return ops / self.adc_slowdown / 1e9


@dataclasses.dataclass(frozen=True)
class ChipConfig:
    name: str
    conv_tile: TileConfig
    fc_tile: Optional[TileConfig] = None
    tiles: int = 168
    fc_tile_frac: float = 0.0  # fraction of tiles that are FC tiles

    def tile_counts(self):
        n_fc = int(round(self.tiles * self.fc_tile_frac))
        return self.tiles - n_fc, n_fc

    def total_power_w(self) -> float:
        n_conv, n_fc = self.tile_counts()
        p = n_conv * self.conv_tile.total_power_w()
        if n_fc and self.fc_tile:
            p += n_fc * self.fc_tile.total_power_w()
        return p + HYPER_TRANSPORT.power_w

    def total_area_mm2(self) -> float:
        n_conv, n_fc = self.tile_counts()
        a = n_conv * self.conv_tile.total_area_mm2()
        if n_fc and self.fc_tile:
            a += n_fc * self.fc_tile.total_area_mm2()
        return a + HYPER_TRANSPORT.area_mm2

    def peak_gops(self) -> float:
        n_conv, n_fc = self.tile_counts()
        g = n_conv * self.conv_tile.peak_gops()
        if n_fc and self.fc_tile:
            g += n_fc * self.fc_tile.peak_gops()
        return g

    def ce(self) -> float:
        """Computational efficiency GOPS/(s mm^2)."""
        return self.peak_gops() / self.total_area_mm2()

    def pe(self) -> float:
        """Power efficiency GOPS/W."""
        return self.peak_gops() / self.total_power_w()


# ---------------------------------------------------------------------------
# Presets: ISAAC baseline and the Newton technique stack (for Figs 11-23)
# ---------------------------------------------------------------------------

ISAAC_IMA = IMAConfig(name="isaac_ima", crossbars=8, rows=128, out_cols=128, adcs=8)
ISAAC_TILE = TileConfig(name="isaac_tile", ima=ISAAC_IMA, imas=12, edram_kb=64)
ISAAC_CHIP = ChipConfig(name="isaac", conv_tile=ISAAC_TILE, tiles=168)


def newton_ima(
    compact: bool = True,
    adaptive: bool = True,
    karatsuba: int = 0,
) -> IMAConfig:
    return IMAConfig(
        name="newton_ima",
        crossbars=8,
        rows=128,
        out_cols=256,  # Newton's chosen IMA: 128 inputs x 256 neurons (§IV)
        adcs=8,
        adc_cfg=ADCConfig(mode="adaptive") if adaptive else ADCConfig(mode="full"),
        compact_htree=compact,
        karatsuba_levels=karatsuba,
    )


def newton_conv_tile(ima: IMAConfig, edram_kb: float = 16.0) -> TileConfig:
    return TileConfig(name="newton_conv", ima=ima, imas=16, edram_kb=edram_kb)


def newton_fc_tile(ima: IMAConfig, slowdown: float = 128.0) -> TileConfig:
    return TileConfig(
        name="newton_fc",
        ima=ima,
        imas=16,
        edram_kb=4.0,
        kind="fc",
        adc_slowdown=slowdown,
        xbars_per_adc=4,
    )


def newton_chip(
    compact: bool = True,
    adaptive: bool = True,
    karatsuba: int = 1,
    small_buffers: bool = True,
    fc_tiles: bool = True,
    tiles: int = 168,
) -> ChipConfig:
    ima = newton_ima(compact=compact, adaptive=adaptive, karatsuba=karatsuba)
    conv = newton_conv_tile(ima, edram_kb=16.0 if small_buffers else 64.0)
    fc = newton_fc_tile(ima) if fc_tiles else None
    return ChipConfig(
        name="newton",
        conv_tile=conv,
        fc_tile=fc,
        tiles=tiles,
        fc_tile_frac=0.5 if fc_tiles else 0.0,  # §III.B.2: 1:1 fits most workloads
    )


NEWTON_CHIP = newton_chip()


def newton_chip_8bit(**kw) -> ChipConfig:
    """8-bit Newton used for the TPU-1 comparison (Fig 24): 8-bit weights
    (4 slices) and inputs (8 iterations) double the pipeline rate and halve
    the crossbars per weight."""
    spec8 = CrossbarSpec(weight_bits=8, input_bits=8, out_bits=8, drop_lsb=7)
    chip = newton_chip(**kw)
    ima8 = dataclasses.replace(chip.conv_tile.ima, xbar_spec=spec8)
    conv8 = dataclasses.replace(chip.conv_tile, ima=ima8)
    fc8 = dataclasses.replace(chip.fc_tile, ima=ima8) if chip.fc_tile else None
    return dataclasses.replace(chip, name="newton-8b", conv_tile=conv8, fc_tile=fc8)

# Reference per-op energies from the paper's introduction (validation anchors)
IDEAL_NEURON_PJ = 0.33
DADIANNAO_PJ = 3.5
EYERISS_PJ = 1.67
ISAAC_PJ = 1.8
NEWTON_PJ = 0.85
