"""Workload -> tile/IMA/crossbar mapping (paper §III.B, Figs 6, 7, 10, 15).

Two policies:

* ``"isaac"`` — the baseline: no constraints; IMAs may be shared by layers
  (dense packing, high crossbar utilization) but the HTree and eDRAM are
  provisioned for the worst case (64 KB buffers, wide private links).
* ``"newton"`` — constrained mapping: an IMA serves exactly one layer with at
  most 128 inputs; replicas are co-located so input buffers are shared
  (Fig 6d); every layer is finely spread across many tiles so each tile
  inherits the buffering efficiency of early layers (Fig 7b).

Replication (both policies, ISAAC §"pipeline balancing"): early conv layers
produce more pixels than later ones; layer ``l`` is replicated
``ceil(pixels_l / pixels_min)`` times so the inter-tile pipeline is balanced
and throughput is set by the least-replicated layer.

Fault-aware provisioning: both policies accept a per-crossbar spare-column
budget (``spare_cols``, or derived from a stuck-cell ``fault_rate`` via
``provision_spare_cols``).  The spare-placement model is **shared with
``device.repair``**: every 128-column group keeps its full data width and
a block of ``spare_cols`` redundant columns is appended past it (the
classic memory-redundancy layout — extra physical bitlines beyond the
addressable array, reachable only through the column mux).  Spares are
allocated-but-unmappable cells: layer columns never land in them, so the
group fan-out is spare-independent, but every allocated crossbar grows by
``rows x spare_cols`` cells per slice — deflating ``used_cells_frac`` /
the Fig-10 underutilization accounting, which is exactly the provisioning
cost the repair capability is bought with.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

from repro.core.arch import ChipConfig, IMAConfig, TileConfig
from repro.core.crossbar import CrossbarSpec
from repro.core.workloads import Layer, Network

BYTES_PER_VAL = 2  # 16-bit fixed point


def provision_spare_cols(
    fault_rate: float, spec: CrossbarSpec, coverage: float = 1.0
) -> int:
    """Spare columns per crossbar for a stuck-cell rate (provisioning rule).

    Repair operates per physical column unit (one bit-slice x row-group
    crossbar column of ``spec.rows`` cells — ``device.repair``); the
    expected fraction of afflicted units is ``frac = 1 - (1 - p)**rows``.
    Spares draw faults at the same rate, so only ``1 - frac`` of the pool
    is clean: the budget that covers the victims is ``cols * frac``
    *discounted by the usable-spare fraction*, ``cols * frac / (1 - frac)``
    — at p = 1e-2 that self-fault correction is the difference between a
    pool that structurally cannot reach the >= 97% recovery bar and one
    that does (BENCH kernel_repaired).  ``coverage`` scales the budget
    (< 1 repairs only the worst offenders, > 1 over-provisions further).
    Capped at twice the crossbar width (the widest per-group output mux we
    model).

    The budget is provisioned per column group in the same layout
    ``device.repair.spare_budget`` consumes: ``spare_cols`` redundant
    columns appended past each group's ``spec.cols`` data columns, so a
    slab spanning ``ceil(N / spec.cols)`` groups owns exactly the spares
    the repair planner will assign (pinned cross-module in
    tests/test_repair.py).
    """
    if fault_rate <= 0.0 or coverage <= 0.0:
        return 0
    frac = 1.0 - (1.0 - fault_rate) ** spec.rows
    usable = max(1.0 - frac, 1.0 / (2.0 * spec.cols))  # cap binds anyway
    return min(2 * spec.cols, math.ceil(spec.cols * frac / usable * coverage))


@dataclasses.dataclass
class LayerMapping:
    layer: Layer
    replication: int
    row_groups: int  # ceil(rows / ima.rows)
    col_groups: int  # ceil(cols / ima.out_cols)
    imas: int  # total IMA instances allocated (grid x replication)
    crossbars: int  # physical crossbars allocated
    used_cells_frac: float  # crossbar utilization (Fig 10)
    tiles: int  # distinct tiles this layer spans
    buffer_bytes_per_tile: float  # input-buffer share per tile (Fig 15)

    @property
    def wasted_frac(self) -> float:
        return 1.0 - self.used_cells_frac


@dataclasses.dataclass
class MappingReport:
    network: str
    policy: str
    layers: List[LayerMapping]
    conv_tiles: int
    fc_tiles: int
    chips: int
    throughput_samples_s: float
    worst_tile_buffer_bytes: float
    mean_tile_buffer_bytes: float
    crossbar_underutilization: float  # weighted average (Fig 10)
    inter_tile_bytes_per_sample: float
    spare_cols: int = 0  # repair columns provisioned per crossbar
    spare_cells_frac: float = 0.0  # fraction of allocated cells held spare

    @property
    def total_tiles(self) -> int:
        return self.conv_tiles + self.fc_tiles


def _layer_grid(layer: Layer, ima: IMAConfig, policy: str):
    rg = -(-layer.rows // ima.rows)
    cg = -(-layer.cols // ima.out_cols)
    return rg, cg


def map_network(
    net: Network,
    chip: ChipConfig,
    policy: str = "newton",
    pixels_ref: Optional[int] = None,
    max_replication: int = 1 << 30,
    spare_cols: int = 0,
    fault_rate: Optional[float] = None,
) -> MappingReport:
    """Map ``net`` onto ``chip`` under the given policy.

    ``spare_cols`` appends repair columns past every crossbar column group
    (the ``device.repair`` layout: data width stays ``spec.cols``, spares
    are extra unmappable cells); alternatively pass a stuck-cell
    ``fault_rate`` and the budget is derived via ``provision_spare_cols``.
    Spares grow every allocated crossbar by ``rows x spare_cols`` cells per
    slice and count as allocated-but-unused in ``used_cells_frac`` — the
    Fig-10 accounting then shows the fault-tolerance provisioning cost
    directly, while group fan-out (hence ``crossbars`` and IMA counts)
    matches the unprovisioned mapping and the repair planner's
    ``spare_budget`` group arithmetic.
    """
    ima = chip.conv_tile.ima
    if fault_rate is not None and spare_cols == 0:
        spare_cols = provision_spare_cols(fault_rate, ima.xbar_spec)
    # physical column-group width: cols data + spare_cols appended repair
    # columns (shared layout with device.repair.spare_budget — deliberately
    # uncapped here so an explicit budget is accounted exactly as the
    # repair planner will program it; provision_spare_cols caps its own
    # derived budgets at the crossbar width)
    group_width = ima.xbar_spec.cols + spare_cols
    conv = net.conv_layers()
    fc = net.fc_layers()

    # --- replication for pipeline balance (throughput set by pixels_ref) ---
    if pixels_ref is None:
        pixels_ref = min((l.pixels for l in conv), default=1)
    # FC tiles run their ADCs `slowdown` x slower (T5); to keep the FC layer
    # off the critical path (paper: "none of these configurations lower the
    # throughput"), FC IMAs are replicated when one slowed VMM would exceed
    # the image period.
    fc_cfg_tile = chip.fc_tile or chip.conv_tile
    fc_repl = max(1, -(-int(fc_cfg_tile.adc_slowdown) // max(1, pixels_ref)))
    mapped: List[LayerMapping] = []
    for layer in net.layers:
        rg, cg = _layer_grid(layer, ima, policy)
        if layer.kind == "conv":
            repl = min(max_replication, max(1, -(-layer.pixels // pixels_ref)))
        else:
            repl = fc_repl
        grid_imas = rg * cg
        imas = grid_imas * repl

        if policy == "isaac":
            # Unconstrained: partial row/col groups of different layers can
            # share an IMA; utilization ~ full but account fragmentation at
            # crossbar granularity.  Layer columns map into each group's
            # full ``cols`` data width; the appended spare block is bought
            # physical cells that are never mappable.
            used = layer.rows * layer.cols
            alloc_xbars = (
                math.ceil(used / (ima.rows * ima.xbar_spec.cols))
                * ima.xbar_spec.n_slices
            )
            alloc_cells = alloc_xbars / ima.xbar_spec.n_slices * ima.rows * group_width
            util = used / alloc_cells
            crossbars = alloc_xbars * repl
            tiles_span = max(1, math.ceil(imas / chip.conv_tile.imas))
        else:
            # Constrained: an IMA belongs to one layer, but the embedded
            # HTree shift-and-add lets multiple *row groups of the same
            # layer* occupy its column slots (partials reduced in-tree), so
            # allocation granularity is a 128x128 crossbar-column slot —
            # each slot's physical array is ``group_width`` wide when repair
            # spares are provisioned (data columns + appended spare block).
            slots_per_ima = max(1, ima.out_cols // ima.xbar_spec.cols)
            slots = rg * -(-layer.cols // ima.xbar_spec.cols) * repl
            imas = -(-slots // slots_per_ima)
            grid_imas = -(-slots // (repl * slots_per_ima))
            used = layer.rows * layer.cols
            alloc_cells = (slots // repl) * ima.rows * group_width
            util = min(1.0, used / alloc_cells)
            crossbars = slots * ima.xbar_spec.n_slices
            tiles_span = max(1, math.ceil(imas / chip.conv_tile.imas))

        # --- input buffering (Figs 6, 7) ---
        if layer.kind == "conv":
            # steady-state sliding window: ky rows of the input feature map
            row_bytes = layer.ky * layer.in_hw * layer.cin * BYTES_PER_VAL
            if policy == "newton":
                # replicas co-located => buffer NOT multiplied by replication;
                # layer spread across its distinct tiles shares the buffer.
                distinct = max(1, math.ceil(grid_imas / chip.conv_tile.imas))
                # replication spreads ADDITIONAL tiles but shares inputs
                span = max(distinct, math.ceil(imas / chip.conv_tile.imas))
                buf_per_tile = row_bytes / span
            else:
                # ISAAC: replicas may land on different tiles with private
                # buffers; per-tile need is the full window of its layer.
                buf_per_tile = row_bytes / max(1, math.ceil(grid_imas / chip.conv_tile.imas))
        else:
            buf_per_tile = layer.rows * BYTES_PER_VAL / max(
                1, math.ceil(imas / chip.conv_tile.imas)
            )
        mapped.append(
            LayerMapping(
                layer=layer,
                replication=repl,
                row_groups=rg,
                col_groups=cg,
                imas=imas,
                crossbars=crossbars,
                used_cells_frac=util,
                tiles=tiles_span,
                buffer_bytes_per_tile=buf_per_tile,
            )
        )

    conv_imas = sum(m.imas for m in mapped if m.layer.kind == "conv")
    fc_imas = sum(m.imas for m in mapped if m.layer.kind == "fc")
    conv_tiles = max(1, math.ceil(conv_imas / chip.conv_tile.imas))
    fc_tile_cfg = chip.fc_tile or chip.conv_tile
    fc_tiles = max(0, math.ceil(fc_imas / fc_tile_cfg.imas)) if fc_imas else 0

    n_conv_cap, n_fc_cap = chip.tile_counts()
    if n_fc_cap == 0:
        chips = math.ceil((conv_tiles + fc_tiles) / max(1, chip.tiles))
    else:
        chips = max(
            math.ceil(conv_tiles / max(1, n_conv_cap)),
            math.ceil(fc_tiles / max(1, n_fc_cap)),
        )

    # --- throughput (deterministic pipeline, §IV) ---
    # FC replication above keeps the slowed FC VMMs off the critical path.
    vmm_t = ima.vmm_time_s
    throughput = 1.0 / (pixels_ref * vmm_t)

    # --- buffers ---
    per_layer_buf = [m.buffer_bytes_per_tile for m in mapped if m.layer.kind == "conv"]
    if policy == "newton":
        # Fig 7b: layers are striped across tiles; each tile hosts slices of
        # adjacent layers, so the requirement approaches the mean.
        total_buf = sum(
            m.buffer_bytes_per_tile * m.tiles for m in mapped if m.layer.kind == "conv"
        )
        mean_buf = total_buf / max(1, conv_tiles)
        worst_buf = max(per_layer_buf, default=0.0)
        worst_buf = min(worst_buf, 2 * mean_buf) if per_layer_buf else 0.0
    else:
        mean_buf = sum(per_layer_buf) / max(1, len(per_layer_buf))
        worst_buf = max(per_layer_buf, default=0.0)

    # --- inter-tile traffic: every layer's outputs travel to the next ---
    traffic = sum(l.pixels * l.cols * BYTES_PER_VAL for l in net.layers)
    under = 1.0 - (
        sum(m.used_cells_frac * m.crossbars for m in mapped)
        / max(1, sum(m.crossbars for m in mapped))
    )

    return MappingReport(
        network=net.name,
        policy=policy,
        layers=mapped,
        conv_tiles=conv_tiles,
        fc_tiles=fc_tiles,
        chips=chips,
        throughput_samples_s=throughput,
        worst_tile_buffer_bytes=worst_buf,
        mean_tile_buffer_bytes=mean_buf,
        crossbar_underutilization=under,
        inter_tile_bytes_per_sample=traffic,
        spare_cols=spare_cols,
        spare_cells_frac=spare_cols / group_width,
    )


def fault_provision_sweep(
    nets: List[Network], chip: ChipConfig, fault_rates: List[float], policy: str = "newton"
):
    """Fig-10 accounting extended with repair provisioning: average crossbar
    under-utilization vs stuck-cell fault rate (spares via
    ``provision_spare_cols``)."""
    out: Dict[str, float] = {}
    for p in fault_rates:
        vals = [
            map_network(n, chip, policy=policy, fault_rate=p).crossbar_underutilization
            for n in nets
        ]
        out[f"{p:g}"] = sum(vals) / len(vals)
    return out


def underutilization_sweep(nets: List[Network], ima_sizes: List[tuple], chip: ChipConfig):
    """Fig 10: average crossbar under-utilization vs IMA (rows x out_cols)."""
    import dataclasses as dc

    out: Dict[str, float] = {}
    for rows, cols in ima_sizes:
        ima = dc.replace(chip.conv_tile.ima, rows=rows, out_cols=cols)
        tile = dc.replace(chip.conv_tile, ima=ima)
        c = dc.replace(chip, conv_tile=tile)
        vals = [map_network(n, c, policy="newton").crossbar_underutilization for n in nets]
        out[f"{rows}x{cols}"] = sum(vals) / len(vals)
    return out
