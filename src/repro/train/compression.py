"""Gradient compression: int8 error-feedback all-reduce.

For cross-pod data parallelism the gradient all-reduce crosses the slow
inter-pod links; compressing to int8 cuts that traffic 4x (bf16) at the cost
of quantization noise, which error feedback (Seide et al.; Karimireddy et
al.) removes asymptotically: the residual of each step's quantization is
added back before the next step's compression, so the *accumulated* update
is unbiased.

``ef_int8_psum`` is the primitive (used inside ``shard_map`` over the DP
axes); convergence-preservation is property-tested in
tests/test_compression.py.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def make_compression_state(grads):
    """Error-feedback residual buffers (same structure/dtype-f32 as grads)."""
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def _quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def ef_int8_psum(x: jnp.ndarray, err: jnp.ndarray, axis_names) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Error-feedback int8 all-reduce-mean over ``axis_names``.

    Must be called inside ``shard_map``.  Returns (mean_x, new_err) where
    mean_x approximates ``lax.pmean(x, axis_names)`` and new_err carries this
    step's local quantization residual.
    """
    xf = x.astype(jnp.float32) + err
    q, scale = _quantize_int8(xf)
    deq = q.astype(jnp.float32) * scale
    new_err = xf - deq
    # int8 codes summed as int32 (the wire format the 4x saving refers to);
    # scales are tiny scalars all-reduced in f32.
    total = jax.lax.psum(q.astype(jnp.int32).astype(jnp.float32) * scale, axis_names)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_names)
    return (total / n).astype(x.dtype), new_err
