"""Training step + fault-tolerant loop.

``make_train_step`` builds the jit-able step: (optionally microbatched)
value_and_grad -> NaN/Inf guard (bad steps are *skipped*, not applied — a
fleet-scale necessity: one bad host must not poison the weights) ->
optimizer update.

``TrainLoop`` adds the operational layer: deterministic resume (data is a
pure function of step), async checkpoints, heartbeat + straggler monitor
(step-time EMA; outliers logged — on real multi-host deployments this feeds
the scheduler's replace-node decision), and metric logging.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.base import ModelConfig
from repro.models import model as model_lib
from repro.optim import Optimizer, global_norm


def _tree_where(pred, a, b):
    return jax.tree.map(lambda x, y: jnp.where(pred, x, y), a, b)


def make_train_step(
    cfg: ModelConfig,
    opt: Optimizer,
    microbatches: int = 1,
    loss_fn: Optional[Callable] = None,
):
    loss_fn = loss_fn or (lambda p, b: model_lib.loss_fn(p, cfg, b))

    def train_step(params, opt_state, step, batch):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            # split batch leading dim into microbatches and scan (grad accum
            # overlaps per-microbatch compute with the weight-grad reduction)
            def split(x):
                return x.reshape((microbatches, x.shape[0] // microbatches) + x.shape[1:])

            mbatch = jax.tree.map(split, batch)

            def body(acc, mb):
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                acc_l, acc_g = acc
                return (acc_l + l, jax.tree.map(jnp.add, acc_g, g)), None

            zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32), zero_g), mbatch)
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)

        gnorm = global_norm(grads)
        ok = jnp.isfinite(loss) & jnp.isfinite(gnorm)
        safe_grads = jax.tree.map(lambda g: jnp.where(ok, g, jnp.zeros_like(g)), grads)
        new_params, new_opt = opt.update(safe_grads, opt_state, params, step)
        new_params = _tree_where(ok, new_params, params)
        new_opt = _tree_where(ok, new_opt, opt_state)
        metrics = {"loss": loss, "grad_norm": gnorm, "skipped": (~ok).astype(jnp.int32)}
        return new_params, new_opt, step + 1, metrics

    return train_step


@dataclasses.dataclass
class StragglerMonitor:
    """Step-time tracker: EMA + outlier flagging (straggler mitigation hook).

    On a real fleet the flag feeds preemption/replacement; here it logs and
    counts, and the count is surfaced in metrics so tests can poke it.
    """

    ema: float = 0.0
    beta: float = 0.9
    threshold: float = 3.0
    flagged: int = 0

    def observe(self, dt: float) -> bool:
        if self.ema == 0.0:
            self.ema = dt
            return False
        is_straggler = dt > self.threshold * self.ema
        self.ema = self.beta * self.ema + (1 - self.beta) * dt
        if is_straggler:
            self.flagged += 1
        return is_straggler


class TrainLoop:
    def __init__(
        self,
        cfg: ModelConfig,
        train_step,
        dataset,
        ckpt_dir: Optional[str] = None,
        ckpt_every: int = 100,
        log_every: int = 10,
        heartbeat_path: Optional[str] = None,
    ):
        self.cfg = cfg
        self.train_step = train_step
        self.dataset = dataset
        self.ckpt = CheckpointManager(ckpt_dir) if ckpt_dir else None
        self.ckpt_every = ckpt_every
        self.log_every = log_every
        self.heartbeat_path = heartbeat_path
        self.monitor = StragglerMonitor()
        self.history = []

    def maybe_resume(self, params, opt_state):
        step = 0
        if self.ckpt is not None:
            try:
                state = {"params": params, "opt": opt_state}
                state, step, _ = self.ckpt.restore_latest(state)
                params, opt_state = state["params"], state["opt"]
                print(f"[train] resumed from step {step}")
            except FileNotFoundError:
                pass
        return params, opt_state, step

    def run(self, params, opt_state, num_steps: int, start_step: int = 0):
        step = jnp.asarray(start_step, jnp.int32)
        for i in range(start_step, num_steps):
            batch = jax.tree.map(jnp.asarray, self.dataset.batch_at(i))
            t0 = time.perf_counter()
            params, opt_state, step, metrics = self.train_step(params, opt_state, step, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            straggler = self.monitor.observe(dt)
            if self.heartbeat_path:
                with open(self.heartbeat_path, "w") as f:
                    json.dump({"step": i, "time": time.time(), "dt": dt}, f)
            if i % self.log_every == 0 or straggler:
                rec = {
                    "step": i,
                    "loss": float(metrics["loss"]),
                    "grad_norm": float(metrics["grad_norm"]),
                    "skipped": int(metrics["skipped"]),
                    "dt_s": dt,
                    "straggler": straggler,
                }
                self.history.append(rec)
                print(f"[train] {rec}")
            if self.ckpt is not None and (i + 1) % self.ckpt_every == 0:
                self.ckpt.save_async(i + 1, {"params": params, "opt": opt_state})
        if self.ckpt is not None:
            self.ckpt.save_async(num_steps, {"params": params, "opt": opt_state})
            self.ckpt.wait()
        return params, opt_state
