from repro.train.loop import TrainLoop, make_train_step  # noqa: F401
from repro.train.compression import ef_int8_psum, make_compression_state  # noqa: F401
