"""Block-allocated KV cache for the continuous-batching traffic tier.

The slot-pool engine pins ``max_seq`` worth of cache per slot for every
request, however short.  This module adds block-granular accounting and
exact swap on top of the same dense compute view (JAX needs static shapes
inside the jitted decode step, so the *compute* cache stays a dense
``(max_batch, max_seq)`` slot pool — what gets block-managed is the
*budget* and the *paged-out copies*):

  * a pool of ``n_blocks`` fixed-size blocks (``block_size`` tokens each)
    with a deterministic free-list allocator;
  * per-request block tables: a request holds exactly
    ``ceil(tokens / block_size)`` blocks and extends one block at a time
    as decode crosses a block boundary — long-running requests stop
    pinning bucket-max memory in the accounting the scheduler admits
    against, and short requests stop paying for ``max_seq``;
  * recurrent/SSM state leaves (no sequence axis: mamba ``h``/``conv``,
    xLSTM ``C``/``n``/``m``/``conv``) are single-block caches — their
    size does not grow with generated tokens, so one block covers the
    whole request regardless of length;
  * ``page_out``/``page_in``: exact preemption and resume.  Page-out
    copies the victim's cache prefix into block-size host chunks, frees
    its pool blocks (swap-out — the whole point of preemption is that the
    pool pressure drops), and surrenders the slot; page-in re-allocates
    blocks and scatters the chunks back into any free slot.  Attention
    masks by position, so stale slot content beyond ``pos`` is
    bit-irrelevant — a resumed request is bit-identical to one that was
    never preempted, which the tests pin.

Block shapes are derived from ``models.model.cache_axes`` (the logical
axes tree; ``"cache_seq"`` names the sequence axis), not hard-coded per
family, so every config the model zoo serves is pageable.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as model_lib


@dataclasses.dataclass(frozen=True)
class BlockCacheConfig:
    """Sizing of the block pool.

    ``n_blocks=None`` sizes the pool to the dense slot-pool capacity
    (``max_batch * ceil(max_seq / block_size)``) — same total budget as
    the engine's cache, but fungible across requests of different
    lengths.  Smaller pools oversubscribe: admission then depends on the
    *actual* token footprint, and the scheduler preempts when the pool
    runs dry.
    """

    block_size: int = 16
    n_blocks: Optional[int] = None

    def resolve_n_blocks(self, max_batch: int, max_seq: int) -> int:
        if self.n_blocks is not None:
            return self.n_blocks
        return max_batch * -(-max_seq // self.block_size)


def _join(path) -> str:
    return "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)


class BlockKVCache:
    """Dense compute view + block-granular accounting and exact swap.

    ``cache`` is the jitted-decode-facing dense slot pool (identical to
    ``ModelRunner.init_cache``); schedulers read and reassign it around
    ``runner.decode``/``runner.admit_slot`` calls.  Everything else here
    manages the block pool: allocation (``allocate``/``ensure``/
    ``release``), capacity queries (``can_admit``/``free_blocks``), and
    exact page-out/page-in of a slot's cache prefix.
    """

    def __init__(
        self,
        cfg: ModelConfig,
        max_batch: int,
        max_seq: int,
        block: Optional[BlockCacheConfig] = None,
        dtype=jnp.float32,
    ):
        self.cfg = cfg
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.block = block or BlockCacheConfig()
        self.block_size = self.block.block_size
        self.n_blocks = self.block.resolve_n_blocks(max_batch, max_seq)
        self.cache = model_lib.init_cache(cfg, max_batch, max_seq, dtype=dtype)
        axes = model_lib.cache_axes(cfg)
        leaves = jax.tree_util.tree_flatten_with_path(self.cache)[0]
        ax_leaves = jax.tree_util.tree_flatten_with_path(
            axes, is_leaf=lambda x: isinstance(x, tuple)
        )[0]
        ax_by_name = {_join(p): a for p, a in ax_leaves}
        # per-leaf layout: index of the sequence axis, None for state
        # leaves (recurrent state — O(1) in tokens, single-block)
        self._seq_axis: Dict[str, Optional[int]] = {}
        for path, leaf in leaves:
            name = _join(path)
            ax = ax_by_name[name]
            if len(ax) != len(leaf.shape):
                raise ValueError(
                    f"cache leaf {name!r}: axes {ax} rank-mismatch shape {leaf.shape}"
                )
            if ax[1] != "cache_batch":
                # the paging index math below slices axis 1 as the slot
                # axis; every family's init_cache puts cache_batch there
                raise NotImplementedError(
                    f"cache leaf {name!r}: expected cache_batch at axis 1, got {ax}"
                )
            seq = ax.index("cache_seq") if "cache_seq" in ax else None
            if seq is not None and seq != 2:
                raise NotImplementedError(
                    f"cache leaf {name!r}: expected cache_seq at axis 2, got {ax}"
                )
            self._seq_axis[name] = seq
        self.has_seq = any(s is not None for s in self._seq_axis.values())
        # deterministic allocator: lowest-numbered free block first
        self._free: List[int] = list(range(self.n_blocks))
        self._tables: Dict[int, List[int]] = {}
        # swap space for paged-out requests: rid -> (pos, last_tok,
        # {leaf name -> list of block-size host chunks (state leaves: one
        # whole-state chunk)}).  Swapped requests hold no pool blocks.
        self._swap: Dict[int, Tuple[int, int, Dict[str, List[np.ndarray]]]] = {}

    # -- accounting ----------------------------------------------------
    def blocks_for(self, n_tokens: int) -> int:
        """Blocks needed to hold ``n_tokens`` of cache for one request.

        Pure-recurrent configs (no sequence axis anywhere) cost one block
        regardless of length — their state is O(1) in tokens.
        """
        if not self.has_seq:
            return 1
        return max(1, -(-int(n_tokens) // self.block_size))

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    def can_admit(self, n_tokens: int) -> bool:
        return self.blocks_for(n_tokens) <= self.free_blocks

    def table(self, rid: int) -> Tuple[int, ...]:
        return tuple(self._tables.get(rid, ()))

    def allocate(self, rid: int, n_tokens: int) -> None:
        if rid in self._tables:
            raise ValueError(f"rid {rid} already holds blocks {self._tables[rid]}")
        need = self.blocks_for(n_tokens)
        if need > self.free_blocks:
            raise ValueError(
                f"block pool exhausted: rid {rid} needs {need} blocks, "
                f"{self.free_blocks}/{self.n_blocks} free"
            )
        self._tables[rid] = [self._free.pop(0) for _ in range(need)]

    def ensure(self, rid: int, n_tokens: int) -> bool:
        """Grow ``rid``'s table to cover ``n_tokens``; False if the pool is
        dry (caller preempts a victim and retries)."""
        tab = self._tables[rid]
        need = self.blocks_for(n_tokens)
        while len(tab) < need:
            if not self._free:
                return False
            tab.append(self._free.pop(0))
        return True

    def release(self, rid: int) -> None:
        """Return all of ``rid``'s blocks to the pool (request finished or
        expired).  Freed blocks re-enter in sorted order so the allocator
        stays deterministic regardless of completion order."""
        tab = self._tables.pop(rid, [])
        self._swap.pop(rid, None)
        self._free = sorted(self._free + tab)

    # -- paging --------------------------------------------------------
    def is_paged(self, rid: int) -> bool:
        return rid in self._swap

    def paged_pos(self, rid: int) -> int:
        return self._swap[rid][0]

    def page_out(self, rid: int, slot: int, pos: int, last_tok: int) -> None:
        """Swap slot ``slot``'s cache prefix (positions < ``pos`` for seq
        leaves; whole state for state leaves) out to block-size host
        chunks, free the request's pool blocks, and record the resume
        point.  The slot is the caller's to reuse and the freed blocks
        relieve the pool pressure that forced the preemption."""
        n_tok = int(pos)
        chunks: Dict[str, List[np.ndarray]] = {}
        for (path, leaf) in jax.tree_util.tree_flatten_with_path(self.cache)[0]:
            name = _join(path)
            arr = np.asarray(leaf[:, slot])  # (L, S, ...) or (L, ...)
            if self._seq_axis[name] is None:
                chunks[name] = [arr.copy()]
            else:
                chunks[name] = [
                    arr[:, lo:min(lo + self.block_size, n_tok)].copy()
                    for lo in range(0, n_tok, self.block_size)
                ]
        self._swap[rid] = (n_tok, int(last_tok), chunks)
        tab = self._tables.pop(rid, [])
        self._free = sorted(self._free + tab)

    def page_in(self, rid: int, slot: int) -> Tuple[int, int]:
        """Re-allocate blocks for ``rid``, scatter its swapped chunks back
        into slot ``slot`` of the dense cache, and return the recorded
        ``(pos, last_tok)`` resume point.  Positions >= pos keep whatever
        stale content the slot held — attention masks by position, so the
        resumed request is bit-identical to one never preempted."""
        pos, last_tok, chunks = self._swap.pop(rid)
        self.allocate(rid, pos)
        flat, treedef = jax.tree_util.tree_flatten_with_path(self.cache)
        new_leaves = []
        for (path, leaf) in flat:
            name = _join(path)
            if self._seq_axis[name] is None:
                leaf = leaf.at[:, slot].set(jnp.asarray(chunks[name][0], leaf.dtype))
            else:
                for bi, chunk in enumerate(chunks[name]):
                    lo = bi * self.block_size
                    leaf = leaf.at[:, slot, lo:lo + chunk.shape[1]].set(
                        jnp.asarray(chunk, leaf.dtype)
                    )
            new_leaves.append(leaf)
        self.cache = jax.tree_util.tree_unflatten(treedef, new_leaves)
        return pos, last_tok
