from repro.serving.engine import ModelRunner, Request, ServingEngine  # noqa: F401
from repro.serving.farm import ChipFarm  # noqa: F401
from repro.serving.kvcache import BlockCacheConfig, BlockKVCache  # noqa: F401
from repro.serving.scheduler import ContinuousBatchingScheduler  # noqa: F401
