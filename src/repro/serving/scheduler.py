"""Continuous-batching scheduler: the traffic tier over ``ModelRunner``.

Where ``ServingEngine`` runs a FIFO slot loop, this scheduler treats every
decode tick as a scheduling decision:

  * **admit/evict at every tick** — waiting requests are admitted
    earliest-deadline-first (FIFO by rid among equals, so a deadline-free
    workload schedules exactly like the engine) into any free slot whose
    block budget fits; expired requests are evicted mid-flight;
  * **per-request deadlines** — ``submit(deadline=K)`` gives a request K
    ticks; a request still unfinished when the clock passes its absolute
    deadline is evicted with ``expired=True`` and its blocks returned;
  * **block-granular memory** — admission and per-tick growth are charged
    against ``serving.kvcache.BlockKVCache``; when the pool runs dry the
    latest-deadline active request is preempted (swapped out exactly,
    its blocks freed, re-queued) rather than the whole tick stalling;
  * **streaming** — ``submit(on_token=cb)`` (or a scheduler-wide
    ``stream=`` default) fires per generated token, as the token is
    sampled, not when the request completes.

Time is the tick counter — one decode step per tick — so every latency
number the traffic bench reports is deterministic: no wall clock enters
the scheduler (the determinism lint forbids it in src/), and a fixed
(seed, arrival schedule) replays identically.

Bit-exactness: with ample blocks, no deadlines and the same admission
order, ``step()`` makes exactly the decisions ``ServingEngine.step()``
makes — admit-then-decode, same slot assignment, same sampling stream —
so generated tokens are bit-identical to the engine's
(``benchmarks/serving_traffic.py`` gates this, and preempted/resumed
requests are pinned token-identical to undisturbed runs).
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional

from repro.serving.engine import ModelRunner, Request
from repro.serving.kvcache import BlockCacheConfig, BlockKVCache

import numpy as np


def _deadline_key(req: Request):
    # EDF with FIFO tiebreak: no deadline sorts last (schedules like the
    # plain engine among themselves), earlier rid first among equals
    return (req.deadline if req.deadline is not None else float("inf"), req.rid)


class ContinuousBatchingScheduler:
    """Admit/evict-every-tick scheduler over one ``ModelRunner``."""

    def __init__(
        self,
        runner: ModelRunner,
        max_batch: int = 4,
        block: Optional[BlockCacheConfig] = None,
        stream: Optional[Callable[[Request, int], None]] = None,
        rid_start: int = 0,
    ):
        self.runner = runner
        self.max_batch = max_batch
        self.kv = BlockKVCache(runner.cfg, max_batch, runner.max_seq, block=block)
        self.stream = stream
        self.tick = 0
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.pos = np.zeros(max_batch, np.int32)
        self.last_tok = np.zeros(max_batch, np.int32)
        self.waiting: List[Request] = []
        self.completed: Dict[int, Request] = {}
        self.expired: Dict[int, Request] = {}
        import itertools

        self._rid = itertools.count(rid_start)

    # ------------------------------------------------------------------
    def submit(
        self,
        prompt,
        max_new_tokens: int = 16,
        eos_id: Optional[int] = None,
        deadline: Optional[int] = None,
        on_token: Optional[Callable[[Request, int], None]] = None,
        truncate: bool = False,
    ) -> int:
        """Queue a request.  ``deadline`` is in ticks from now; the request
        is evicted (``expired=True``) if still unfinished after that many
        decode ticks.  ``on_token`` streams tokens as they are sampled."""
        prompt = np.asarray(prompt)
        S = self.runner.check_prompt(prompt, truncate)
        # admission control against livelock: a request whose worst-case
        # footprint exceeds the whole pool would thrash forever (preempted
        # and resumed without ever reaching max_new_tokens) — refuse it up
        # front instead
        worst = self.kv.blocks_for(min(self.runner.max_seq, S + max_new_tokens))
        if worst > self.kv.n_blocks:
            raise ValueError(
                f"request needs up to {worst} blocks "
                f"({S} prompt + {max_new_tokens} new tokens, block_size="
                f"{self.kv.block_size}) but the pool only has "
                f"{self.kv.n_blocks}: it could never run to completion"
            )
        req = Request(
            next(self._rid), prompt, max_new_tokens, eos_id,
            truncate=truncate,
            deadline=None if deadline is None else self.tick + int(deadline),
            on_token=on_token if on_token is not None else self.stream,
            arrival=self.tick,
        )
        self.waiting.append(req)
        return req.rid

    # ------------------------------------------------------------------
    @property
    def n_active(self) -> int:
        return sum(1 for s in self.slots if s is not None)

    @property
    def load(self) -> int:
        return self.n_active + len(self.waiting)

    def _finish(self, req: Request, *, expired: bool) -> None:
        req.done = True
        req.expired = expired
        req.finish = self.tick + 1
        self.kv.release(req.rid)
        (self.expired if expired else self.completed)[req.rid] = req

    def _expire(self) -> None:
        """Evict anything whose deadline has passed — active or waiting."""
        for i, req in enumerate(self.slots):
            if req is not None and req.deadline is not None and self.tick >= req.deadline:
                self._finish(req, expired=True)
                self.slots[i] = None
        still = []
        for req in self.waiting:
            if req.deadline is not None and self.tick >= req.deadline:
                self._finish(req, expired=True)
            else:
                still.append(req)
        self.waiting = still

    def _stream_tok(self, req: Request, tok: int) -> None:
        req.generated.append(tok)
        if req.on_token is not None:
            req.on_token(req, tok)

    def _admit(self) -> None:
        """EDF admission into free slots, charged against the block pool.

        A candidate that does not fit the pool is skipped (no head-of-line
        blocking); a previously preempted request resumes from its paged
        blocks without re-prefilling.
        """
        if not self.waiting:
            return
        for slot in range(self.max_batch):
            if self.slots[slot] is not None or not self.waiting:
                continue
            order = sorted(self.waiting, key=_deadline_key)
            chosen = None
            for req in order:
                if self.kv.is_paged(req.rid):
                    need = self.kv.paged_pos(req.rid)
                else:
                    need = self.runner.check_prompt(req.prompt, req.truncate)
                if self.kv.can_admit(need):
                    chosen = req
                    break
            if chosen is None:
                return  # pool dry for every candidate; decode drains it
            self.waiting.remove(chosen)
            if self.kv.is_paged(chosen.rid):
                p, lt = self.kv.page_in(chosen.rid, slot)
                self.pos[slot] = p
                self.last_tok[slot] = lt
            else:
                S = self.runner.check_prompt(chosen.prompt, chosen.truncate)
                self.kv.allocate(chosen.rid, S)
                self.kv.cache, p, lt, first = self.runner.admit_slot(
                    self.kv.cache, slot, chosen
                )
                self.pos[slot] = p
                self.last_tok[slot] = lt
                if first is not None:
                    self._stream_tok(chosen, first)
            self.slots[slot] = chosen

    def _preempt(self, slot: int) -> None:
        """Swap a victim out exactly (freeing its blocks) and re-queue it."""
        req = self.slots[slot]
        self.kv.page_out(req.rid, slot, int(self.pos[slot]), int(self.last_tok[slot]))
        self.slots[slot] = None
        self.waiting.append(req)

    def _ensure_blocks(self) -> None:
        """Charge this tick's cache growth; preempt latest-deadline victims
        when the pool runs dry (they resume bit-identically later)."""
        for i in range(self.max_batch):
            req = self.slots[i]
            if req is None:
                continue
            # the decode below writes position pos[i]: the table must cover
            # pos[i] + 1 tokens
            while not self.kv.ensure(req.rid, int(self.pos[i]) + 1):
                victims = [
                    j for j in range(self.max_batch)
                    if self.slots[j] is not None and j != i
                ]
                if not victims:
                    # nothing left to steal from: preempt the request
                    # itself; it resumes when blocks free up
                    self._preempt(i)
                    break
                victim = max(victims, key=lambda j: _deadline_key(self.slots[j]))
                self._preempt(victim)

    # ------------------------------------------------------------------
    def step(self) -> int:
        """One scheduling tick: expire, admit, budget, decode, sample.

        Returns the number of slots advanced this tick."""
        self._expire()
        self._admit()
        self._ensure_blocks()
        active = [i for i in range(self.max_batch) if self.slots[i] is not None]
        if not active:
            self.tick += 1
            return 0
        logits, self.kv.cache = self.runner.decode(self.last_tok, self.pos, self.kv.cache)
        nxt = self.runner.sample(logits)
        for i in active:
            req = self.slots[i]
            self.pos[i] += 1
            tok = int(nxt[i])
            self._stream_tok(req, tok)
            self.last_tok[i] = tok
            if (
                len(req.generated) >= req.max_new_tokens
                or (req.eos_id is not None and tok == req.eos_id)
                or self.pos[i] >= self.runner.max_seq - 1
            ):
                self._finish(req, expired=False)
                self.slots[i] = None
        self.tick += 1
        return len(active)

    def run(self, max_ticks: int = 10_000) -> List[Request]:
        """Drain the queue; returns completed + expired sorted by rid."""
        for _ in range(max_ticks):
            if not self.waiting and self.n_active == 0:
                break
            self.step()
        out = dict(self.completed)
        out.update(self.expired)
        for s in self.slots:
            if s is not None:
                out[s.rid] = s
        return sorted(out.values(), key=lambda r: r.rid)
