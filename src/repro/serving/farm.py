"""Chip-farm router: N programmed replicas behind one submit().

The artifact store makes programmed replicas nearly free — restore is
~300x faster than reprogramming (``ROADMAP``), so a farm scales out in
seconds: every replica is a full ``ServingEngine`` restored from the
*same* store (``restore_artifacts=``), serving bit-identically to the
chip that was saved.  This module adds the routing layer:

  * **policies** — ``round_robin`` (rotating cursor over undrained
    replicas) and ``least_loaded`` (fewest active + queued requests,
    lowest index tiebreak); both deterministic;
  * **disjoint rid spaces** — replica ``i`` allocates rids from
    ``i * RID_STRIDE``, so farm-wide results merge without collisions and
    ``replica_of(rid)`` recovers the placement;
  * **lifecycle-aware draining** — ``drain(i)`` takes a replica out of
    admission while its in-flight requests finish (``step()`` keeps
    advancing it); combined with the PR 6 lifecycle verbs
    (``health(...)``, per-replica ``age``/``refresh``/``hot_swap``
    through ``farm.replicas[i]``) an aged replica is refreshed without
    dropping traffic: drain -> wait idle -> refresh -> undrain, while the
    other replicas keep admitting.

The farm is a pure fan-out: replicas share no state, so farm throughput
scales with replica count (the traffic bench gates the 1 -> 2 replica
speedup), and a single-replica farm serves token-identically to a bare
engine.
"""
from __future__ import annotations

from typing import Callable, List, Optional

from repro.configs.base import ModelConfig
from repro.models.layers import CrossbarMode
from repro.serving.engine import Request, ServingEngine

# rid space per replica; no request stream should plausibly exceed this
RID_STRIDE = 1_000_000

POLICIES = ("round_robin", "least_loaded")


class ChipFarm:
    """Route one request stream across N ``ServingEngine`` replicas."""

    def __init__(
        self,
        cfg: ModelConfig,
        params,
        n_replicas: int = 2,
        policy: str = "round_robin",
        max_batch: int = 4,
        max_seq: int = 512,
        temperature: float = 0.0,
        seed: int = 0,
        crossbar: Optional[CrossbarMode] = None,
        restore_artifacts: Optional[str] = None,
        verify_coverage: bool = True,
    ):
        if n_replicas < 1:
            raise ValueError(f"n_replicas must be >= 1, got {n_replicas}")
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}: pick one of {POLICIES}")
        self.policy = policy
        # every replica restores the *same* programmed chip from the one
        # store (or programs/serves digital when no store is given) —
        # replicas are bit-identical by construction, so routing does not
        # change what any request generates
        self.replicas: List[ServingEngine] = [
            ServingEngine(
                cfg,
                params,
                max_batch=max_batch,
                max_seq=max_seq,
                temperature=temperature,
                seed=seed,
                crossbar=crossbar,
                restore_artifacts=restore_artifacts,
                verify_coverage=verify_coverage,
                rid_start=i * RID_STRIDE,
            )
            for i in range(n_replicas)
        ]
        self._draining: set = set()
        self._rr = 0

    # -- routing -------------------------------------------------------
    @property
    def n_replicas(self) -> int:
        return len(self.replicas)

    def load(self, i: int) -> int:
        """Queued + in-flight request count of replica ``i``."""
        eng = self.replicas[i]
        return len(eng.pending) + sum(1 for s in eng.slots if s is not None)

    def _route(self) -> int:
        open_ = [i for i in range(self.n_replicas) if i not in self._draining]
        if not open_:
            raise ValueError(
                "every replica is draining: undrain one before submitting"
            )
        if self.policy == "least_loaded":
            return min(open_, key=lambda i: (self.load(i), i))
        # round_robin: next undrained replica at or after the cursor
        for k in range(self.n_replicas):
            i = (self._rr + k) % self.n_replicas
            if i in open_:
                self._rr = (i + 1) % self.n_replicas
                return i
        raise AssertionError("unreachable")  # open_ is non-empty

    def submit(
        self,
        prompt,
        max_new_tokens: int = 16,
        eos_id: Optional[int] = None,
        truncate: bool = False,
        on_token: Optional[Callable[[Request, int], None]] = None,
    ) -> int:
        """Route one request to a replica by the farm's policy; the rid
        encodes the placement (``replica_of``)."""
        i = self._route()
        return self.replicas[i].submit(
            prompt,
            max_new_tokens=max_new_tokens,
            eos_id=eos_id,
            truncate=truncate,
            on_token=on_token,
        )

    def replica_of(self, rid: int) -> int:
        return rid // RID_STRIDE

    # -- serving -------------------------------------------------------
    def step(self) -> int:
        """Advance every replica one decode tick (draining replicas keep
        finishing their in-flight work — drain never drops traffic).
        Returns total slots advanced across the farm."""
        return sum(eng.step() for eng in self.replicas)

    def is_idle(self, i: int) -> bool:
        eng = self.replicas[i]
        return not eng.pending and all(s is None for s in eng.slots)

    def run_until_done(self, max_ticks: int = 10_000) -> List[Request]:
        """Drain every replica; merged results sorted by rid."""
        for _ in range(max_ticks):
            if all(self.is_idle(i) for i in range(self.n_replicas)):
                break
            self.step()
        out: List[Request] = []
        for eng in self.replicas:
            out.extend(eng.run_until_done(max_ticks=0))
        return sorted(out, key=lambda r: r.rid)

    # -- lifecycle -----------------------------------------------------
    def drain(self, i: int) -> None:
        """Stop routing new requests to replica ``i``; in-flight requests
        keep serving to completion."""
        self.replicas[i]  # index check
        self._draining.add(i)

    def undrain(self, i: int) -> None:
        self._draining.discard(i)

    @property
    def draining(self) -> frozenset:
        return frozenset(self._draining)

    def refresh(self, i: int, directory: Optional[str] = None) -> Optional[str]:
        """Refresh replica ``i``'s chip (see ``ModelRunner.refresh``);
        typically called on a drained, idle replica, but hot-swap is safe
        mid-flight too."""
        return self.replicas[i].refresh(directory)

    def hot_swap(self, i: int, directory: str, slot: Optional[str] = None) -> None:
        self.replicas[i].hot_swap(directory, slot=slot)

    def uptimes(self) -> List[float]:
        return [eng.uptime_s for eng in self.replicas]

    def health(self, n_probes: Optional[int] = None, seed: int = 0,
               budget: Optional[float] = None) -> List[object]:
        """Per-replica ``HealthReport`` (see ``ModelRunner.health_check``)."""
        return [
            eng.health_check(n_probes=n_probes, seed=seed, budget=budget)
            for eng in self.replicas
        ]
