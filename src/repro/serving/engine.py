"""Batched serving engine with continuous batching.

Slot-based design (vLLM-lite, adapted to JAX static shapes):
  * a fixed pool of ``max_batch`` cache slots, each holding one request's
    KV/state cache at its own position;
  * admission: a pending request is prefilled with a batch-1 prefill
    (prompt padded to a bucket to bound recompilation) and its cache is
    scattered into the slot pool;
  * decode: one jitted ``decode_step`` advances *all* occupied slots each
    tick with per-slot positions; finished slots are freed and refilled
    without stalling the others.

Sampling is greedy or temperature-based with a per-engine PRNG; generation
is deterministic given (seed, admission order), which the tests assert.

Crossbar serving: pass ``crossbar=CrossbarMode(enabled=True, device=...)``
and the engine compiles every projection onto programmed crossbars **once**
at construction (``repro.device.programmed.program_model``) — the paper's
program-once premise as a serving feature.  Every prefill/decode then runs
the steady-state artifact path inside the jitted step functions: one fixed
noisy chip across the whole engine lifetime, no per-call reprogramming.
Artifacts are name-keyed, so MoE expert banks and tied LM heads serve from
the crossbar too (the tied head from a transpose programmed once at
construction).  ``spare_cols=`` exposes the fault-aware spare-column repair
budget (``device.repair``) at deploy time; ``repair_reports()`` summarizes
what the planner remapped.

Persistence: ``save_artifacts(dir)`` writes the programmed chip —
effective cells, frozen scales, write-verify reports, spare blocks and
gather tables — through ``repro.checkpoint``; a later
``ServingEngine(..., restore_artifacts=dir)`` restores the *same* chip
bit-for-bit and skips reprogramming entirely (restart latency is file I/O,
not write-verify).
"""
from __future__ import annotations

import contextlib
import dataclasses
import itertools
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as model_lib
from repro.models.layers import CrossbarMode, crossbar_mode


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32 tokens (or (S, D) embeddings)
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


def _bucket(n: int, buckets=(32, 64, 128, 256, 512, 1024, 2048)) -> int:
    for b in buckets:
        if n <= b:
            return b
    return -(-n // 2048) * 2048


class ServingEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        max_batch: int = 4,
        max_seq: int = 512,
        temperature: float = 0.0,
        seed: int = 0,
        crossbar: Optional[CrossbarMode] = None,
        spare_cols: Optional[int] = None,
        restore_artifacts: Optional[str] = None,
    ):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        self.crossbar = self._program_crossbars(crossbar, spare_cols, restore_artifacts)
        self.cache = model_lib.init_cache(cfg, max_batch, max_seq, dtype=jnp.float32)
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.pos = np.zeros(max_batch, np.int32)  # position of next write
        self.last_tok = np.zeros(max_batch, np.int32)
        self.pending: List[Request] = []
        self._rid = itertools.count()
        self._decode = jax.jit(
            lambda p, t, pos, c: self._with_crossbar(
                lambda: model_lib.decode_step(p, self.cfg, t, pos, c)
            )
        )
        self._prefills: Dict[int, object] = {}

    # ------------------------------------------------------------------
    def _program_crossbars(
        self,
        crossbar: Optional[CrossbarMode],
        spare_cols: Optional[int] = None,
        restore_artifacts: Optional[str] = None,
    ):
        """Program-once compilation of the model's weights (deploy time).

        When crossbar serving is requested without prebuilt artifacts, walk
        the params and compile every projection now — every subsequent
        prefill/decode is pure steady-state (and under a noisy
        ``DeviceConfig`` the whole engine serves from one fixed chip
        instead of redrawing noise per layer call).

        ``spare_cols`` (engine constructor arg) overrides the device's
        spare-column repair budget at deploy time: the fault-aware planner
        (``device.repair``) then remaps the worst stuck-cell columns of
        every projection into programmed spares before serving begins.

        ``restore_artifacts`` restores a previously ``save_artifacts``-ed
        programmed chip instead of reprogramming: the name-keyed artifact
        store is loaded bit-for-bit (fault fields, write-verify reports,
        repair tables included) and no ``program_layer`` call runs.
        """
        if restore_artifacts is not None:
            if crossbar is None or not crossbar.enabled:
                raise ValueError(
                    "restore_artifacts= needs crossbar serving enabled "
                    "(pass crossbar=CrossbarMode(enabled=True, ...))"
                )
            if crossbar.programmed is not None:
                raise ValueError(
                    "restore_artifacts= with prebuilt CrossbarMode.programmed "
                    "artifacts: pick one source of truth"
                )
            if spare_cols is not None:
                # 0 included: an explicit disable can no more be applied to
                # a baked chip than a new budget can — silently serving the
                # repaired artifacts would ignore the operator's override
                raise ValueError(
                    "spare_cols= cannot rebudget a restored chip (not even "
                    "to 0): the repair plan was baked in when the artifacts "
                    "were programmed — reprogram with the desired budget"
                )
            from repro.checkpoint import restore_programmed
            from repro.device.programmed import expected_artifact_names

            prog = restore_programmed(restore_artifacts)
            # a stale or mismatched store would resolve no artifacts and
            # silently degrade every projection to per-call reprogramming —
            # the exact silent fallback this engine exists to prevent, so
            # cross-check the store against what this model would program
            expected = expected_artifact_names(
                self.params,
                tie_lm_head=(self.cfg.tie_embeddings and self.cfg.frontend == "token"),
            )
            bad = sorted(
                name for name, shape in expected.items()
                if prog.lookup(name, shape) is None
            )
            if bad:
                raise ValueError(
                    f"restored artifact store at {restore_artifacts!r} does not "
                    f"match this model: {len(bad)}/{len(expected)} projections "
                    f"missing or shape-mismatched ({', '.join(bad[:5])}"
                    + (", ..." if len(bad) > 5 else "")
                    + ") — was it saved from a different model/config?"
                )
            return dataclasses.replace(crossbar, programmed=prog)
        # spare_cols=0 means "no repair" and is a no-op wherever repair could
        # not happen anyway; a *positive* budget that cannot take effect is a
        # misconfiguration — silently serving unrepaired while the operator
        # believes a repair budget is active would be worse than failing
        if crossbar is None or not crossbar.enabled or crossbar.programmed is not None:
            if spare_cols:
                raise ValueError(
                    "spare_cols= needs crossbar serving with a DeviceConfig "
                    "to repair and no prebuilt artifacts (set spare_cols on "
                    "the DeviceConfig passed to program_model instead)"
                )
            return crossbar
        device = crossbar.device
        if spare_cols is not None:
            if device is None:
                if spare_cols:
                    raise ValueError(
                        "spare_cols= without a CrossbarMode.device: there is "
                        "no fault model to repair against"
                    )
            else:
                device = device.replace(spare_cols=spare_cols)
                from repro.device import wants_repair

                if spare_cols > 0 and not wants_repair(device):
                    raise ValueError(
                        f"spare_cols={spare_cols} on a device with no "
                        "stuck-at faults (p_stuck_on == p_stuck_off == 0): "
                        "nothing to repair"
                    )
                crossbar = dataclasses.replace(crossbar, device=device)
        from repro.device.programmed import program_model

        prog = program_model(
            self.params,
            device=device,
            fast=crossbar.fast,
            # tied LM heads serve from a transpose programmed once, bound to
            # the embedding's name (name-keyed binding makes this possible)
            tie_lm_head=(self.cfg.tie_embeddings and self.cfg.frontend == "token"),
        )
        return dataclasses.replace(crossbar, programmed=prog)

    def save_artifacts(self, directory: str) -> str:
        """Persist the programmed chip so a restart can restore instead of
        reprogram (``ServingEngine(..., restore_artifacts=directory)``)."""
        if self.crossbar is None or self.crossbar.programmed is None:
            raise ValueError(
                "no programmed artifacts to save: construct the engine with "
                "crossbar=CrossbarMode(enabled=True, ...) first"
            )
        from repro.checkpoint import save_programmed

        return save_programmed(directory, self.crossbar.programmed)

    def repair_reports(self):
        """Path -> spare-column ``RepairReport`` for every repaired
        projection of the programmed model ({} when repair is off)."""
        if self.crossbar is None or self.crossbar.programmed is None:
            return {}
        return self.crossbar.programmed.repair_reports()

    def _with_crossbar(self, fn):
        """Run ``fn`` under the engine's crossbar mode, with the programmed
        model's name-keyed artifact table bound for the dynamic scope
        (works at jit trace time — lookups resolve by name, not by leaf
        identity, so any congruent params tree serves)."""
        if self.crossbar is None:
            return fn()
        bind = (
            self.crossbar.programmed.bind()
            if self.crossbar.programmed is not None
            else contextlib.nullcontext()
        )
        with crossbar_mode(self.crossbar), bind:
            return fn()

    # ------------------------------------------------------------------
    def submit(self, prompt, max_new_tokens: int = 16, eos_id: Optional[int] = None) -> int:
        req = Request(next(self._rid), np.asarray(prompt), max_new_tokens, eos_id)
        self.pending.append(req)
        return req.rid

    def _prefill_fn(self, bucket: int):
        if bucket not in self._prefills:
            def fn(params, tokens, cache):
                return self._with_crossbar(
                    lambda: model_lib.prefill(params, self.cfg, tokens, cache)
                )
            self._prefills[bucket] = jax.jit(fn)
        return self._prefills[bucket]

    def _admit(self):
        for slot in range(self.max_batch):
            if self.slots[slot] is not None or not self.pending:
                continue
            req = self.pending.pop(0)
            S = len(req.prompt)
            # Recurrent archs (ssm/hybrid) must not process padding tokens —
            # their state would absorb them — so they prefill exact lengths;
            # attention caches tolerate padding (masked by position), so they
            # use buckets + an idempotent catch-up re-issue of token S-1.
            recurrent = self.cfg.family in ("ssm", "hybrid")
            bucket = S if recurrent else min(_bucket(S), self.max_seq)
            prompt = np.zeros((1, bucket), np.int32)
            prompt[0, :S] = req.prompt[:bucket]
            small_cache = model_lib.init_cache(self.cfg, 1, self.max_seq, dtype=jnp.float32)
            logits, filled = self._prefill_fn(bucket)(self.params, jnp.asarray(prompt), small_cache)
            self.cache = jax.tree.map(
                lambda big, one: big.at[:, slot].set(one[:, 0]), self.cache, filled
            )
            if recurrent:
                tok = int(self._sample(np.asarray(logits, np.float32))[0])
                self.pos[slot] = S
                self.last_tok[slot] = tok
                req.generated.append(tok)
            else:
                self.pos[slot] = S - 1
                self.last_tok[slot] = int(req.prompt[S - 1])
            self.slots[slot] = req

    # ------------------------------------------------------------------
    def _sample(self, logits: np.ndarray) -> np.ndarray:
        if self.temperature <= 0.0:
            return np.argmax(logits, axis=-1).astype(np.int32)
        self.key, sub = jax.random.split(self.key)
        g = jax.random.gumbel(sub, logits.shape)
        return np.asarray(
            jnp.argmax(logits / self.temperature + g, axis=-1), np.int32
        )

    def step(self) -> int:
        """Admit pending requests and advance every occupied slot one token.

        Returns the number of active slots advanced."""
        self._admit()
        active = [i for i in range(self.max_batch) if self.slots[i] is not None]
        if not active:
            return 0
        toks = jnp.asarray(self.last_tok[:, None])
        pos = jnp.asarray(self.pos)
        logits, self.cache = self._decode(self.params, toks, pos, self.cache)
        nxt = self._sample(np.asarray(logits, np.float32))
        for i in active:
            req = self.slots[i]
            self.pos[i] += 1
            tok = int(nxt[i])
            req.generated.append(tok)
            self.last_tok[i] = tok
            if (
                len(req.generated) >= req.max_new_tokens
                or (req.eos_id is not None and tok == req.eos_id)
                or self.pos[i] >= self.max_seq - 1
            ):
                req.done = True
                self.slots[i] = None
        return len(active)

    def run_until_done(self, max_ticks: int = 10_000) -> List[Request]:
        finished: List[Request] = []
        seen: Dict[int, Request] = {}
        for _ in range(max_ticks):
            for s in self.slots:
                if s is not None:
                    seen[s.rid] = s
            if not self.pending and all(s is None for s in self.slots):
                break
            self.step()
        for s in self.slots:
            if s is not None:
                seen[s.rid] = s
        return sorted(seen.values(), key=lambda r: r.rid)
