"""Batched serving, split into a model runner and a slot scheduler.

Two layers (the scheduler/model-runner split):

``ModelRunner`` owns the *model* half of serving: the params, the
programmed crossbar chip and its whole lifecycle (program-once
compilation, artifact store save/restore, aging, health probes,
compensation, zero-downtime hot-swap/refresh), the jitted prefill/decode
step functions, and sampling.  It is stateless with respect to traffic —
it does not know about slots, requests or queues — so any number of
scheduling policies can drive one runner.

``ServingEngine`` is the synchronous slot scheduler on top (vLLM-lite,
adapted to JAX static shapes):
  * a fixed pool of ``max_batch`` cache slots, each holding one request's
    KV/state cache at its own position;
  * admission: a pending request is prefilled with a batch-1 prefill
    (prompt padded to a bucket to bound recompilation) and its cache is
    scattered into the slot pool;
  * decode: one jitted ``decode_step`` advances *all* occupied slots each
    tick with per-slot positions; finished slots are freed and refilled
    without stalling the others.

The continuous-batching traffic tier builds on the same runner:
``serving.scheduler.ContinuousBatchingScheduler`` adds per-request
deadlines, mid-flight eviction and a block-allocated KV cache
(``serving.kvcache``), and ``serving.farm.ChipFarm`` routes requests
across N programmed replicas restored from one artifact store.

Sampling is greedy or temperature-based with a per-runner PRNG; generation
is deterministic given (seed, admission order), which the tests assert.

Crossbar serving: pass ``crossbar=CrossbarMode(enabled=True, device=...)``
and the runner compiles every projection onto programmed crossbars **once**
at construction (``repro.device.programmed.program_model``) — the paper's
program-once premise as a serving feature.  Every prefill/decode then runs
the steady-state artifact path inside the jitted step functions: one fixed
noisy chip across the whole engine lifetime, no per-call reprogramming.
Artifacts are name-keyed, so MoE expert banks and tied LM heads serve from
the crossbar too (the tied head from a transpose programmed once at
construction).  ``spare_cols=`` exposes the fault-aware spare-column repair
budget (``device.repair``) at deploy time; ``repair_reports()`` summarizes
what the planner remapped.

Persistence: ``save_artifacts(dir)`` writes the programmed chip —
effective cells, frozen scales, write-verify reports, spare blocks and
gather tables — through ``repro.checkpoint``; a later
``ServingEngine(..., restore_artifacts=dir)`` restores the *same* chip
bit-for-bit and skips reprogramming entirely (restart latency is file I/O,
not write-verify).  Both construction-time restore *and* ``hot_swap()``
run the same ``analysis.verify_store`` fail-fast static verification
before binding, so a corrupt store is refused up front instead of hitting
mid-flight serving.

Mesh serving: pass ``mesh=`` (plus ``param_axes=`` from ``init_model``)
and every jitted step runs under the mesh with the config's layout
overrides, so the model's ``shard_map`` EP/TP paths engage; programmed
artifacts are sharded with the same PartitionSpecs as the weights they
shadow (``device.programmed.shard_artifacts``) and the bodies rebind
rank-local slices by name — expert-parallel serving is bit-identical to
the single-device chip (tests/test_sharded_artifacts.py).  Saved stores
record the deployment sharding; restore re-places shards on the mesh.
``verify_coverage`` (default on) runs the structural name-set check at
construction: one abstract trace asserts the forward consumes exactly the
emitted artifact name set, failing loudly on drift a miss counter cannot
see (an orphaned artifact misses nothing — nothing ever looks it up).
"""
from __future__ import annotations

import contextlib
import dataclasses
import itertools
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as model_lib
from repro.models.layers import CrossbarMode, crossbar_mode


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32 tokens (or (S, D) embeddings)
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    # allow silently truncating a prompt longer than max_seq to its last
    # max_seq tokens-worth prefix; without it an over-length prompt is
    # refused at submit() with a ValueError
    truncate: bool = False
    # traffic tier (serving.scheduler): absolute tick by which the request
    # must finish, else it is evicted with expired=True; None = no deadline
    deadline: Optional[int] = None
    # streaming: called as on_token(req, tok) for every generated token,
    # including the prefill-sampled first token of recurrent archs
    on_token: Optional[Callable[["Request", int], None]] = None
    arrival: int = 0  # scheduler tick at submit time
    finish: Optional[int] = None  # scheduler tick after the finishing step
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    expired: bool = False


def _bucket(n: int, buckets=(32, 64, 128, 256, 512, 1024, 2048)) -> int:
    for b in buckets:
        if n <= b:
            return b
    return -(-n // 2048) * 2048


class ModelRunner:
    """The model half of serving: chip + jitted steps + sampling.

    Owns everything about *how* one token batch is computed — programmed
    crossbar artifacts and their lifecycle, mesh placement, the jitted
    prefill/decode closures, the sampling PRNG — and nothing about *which*
    requests run when.  Schedulers (the slot loop in ``ServingEngine``,
    the continuous-batching tier in ``serving.scheduler``) hold the
    traffic state and call into one runner.
    """

    def __init__(
        self,
        cfg: ModelConfig,
        params,
        max_seq: int = 512,
        temperature: float = 0.0,
        seed: int = 0,
        crossbar: Optional[CrossbarMode] = None,
        spare_cols: Optional[int] = None,
        restore_artifacts: Optional[str] = None,
        mesh=None,
        param_axes=None,
        verify_coverage: bool = True,
        expert_chips=None,
        plan=None,
    ):
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        # mesh serving: every jitted step runs under ``use_mesh(mesh,
        # layout_overrides(cfg))`` so the model's shard_map EP/TP paths
        # engage; ``param_axes`` (the logical-axes tree from init_model)
        # lets the runner shard programmed artifacts with the same specs as
        # the weights they shadow (device.programmed.shard_artifacts)
        self.mesh = mesh
        self.param_axes = param_axes
        # fleet realism: one DeviceConfig.chip identity per expert, so the
        # slabs an EP mesh places on different ranks draw decorrelated
        # device perturbations (device.programmed.program_layer(chips=));
        # remembered so refresh() reprograms the same fleet
        self.expert_chips = tuple(expert_chips) if expert_chips is not None else None
        # chip-plan compiler (core.planner.ChipPlan): per-layer heterogeneous
        # datapath / ADC schedule / spare budget, threaded into program_model
        # at deploy time and again on refresh() — the reprogrammed fleet must
        # be the chip the plan admitted
        self.plan = plan
        self.crossbar = self._program_crossbars(crossbar, spare_cols, restore_artifacts)
        if verify_coverage:
            self.verify_crossbar_coverage()
        self._decode = jax.jit(
            lambda p, t, pos, c: self._with_crossbar(
                lambda: model_lib.decode_step(p, self.cfg, t, pos, c)
            )
        )
        self._prefills: Dict[int, object] = {}

    # ------------------------------------------------------------------
    @property
    def _tie_lm_head(self) -> bool:
        return self.cfg.tie_embeddings and self.cfg.frontend == "token"

    def _verify_store(self, directory: str, slot: Optional[str], what: str):
        """Fail-fast static store verification shared by construction-time
        restore and ``hot_swap`` — same rules, same orphaned-leaf carve-out.

        Verifies from manifests alone, before any array loads or binding: a
        corrupt slot pointer, undecodable spec/plan, inconsistent leaf
        shapes or a wrong name-set is refused with the failing rule named,
        instead of surfacing as a silent per-call reprogramming fallback
        mid-serving.  Returns the expected name -> shape map for the
        follow-up binding cross-check.
        """
        from repro.analysis.store import verify_store
        from repro.device.programmed import expected_artifact_names

        expected = expected_artifact_names(self.params, tie_lm_head=self._tie_lm_head)
        vreport = verify_store(directory, expected=expected, slot=slot)
        # orphaned leaves (store ⊃ model) are left to verify_coverage: a
        # superset store serves correctly, and that check has an explicit
        # opt-out (verify_coverage=False) for exotic setups
        fatal = [
            f for f in vreport.findings
            if not (f.rule == "name-set" and "orphaned leaf" in f.message)
        ]
        if fatal:
            vreport.findings[:] = fatal
            raise ValueError(
                f"{what} store failed static verification "
                "(repro.analysis.verify_store): it is internally "
                "inconsistent or does not match this model —\n"
                + vreport.summary()
            )
        return expected

    def _program_crossbars(
        self,
        crossbar: Optional[CrossbarMode],
        spare_cols: Optional[int] = None,
        restore_artifacts: Optional[str] = None,
    ):
        """Program-once compilation of the model's weights (deploy time).

        When crossbar serving is requested without prebuilt artifacts, walk
        the params and compile every projection now — every subsequent
        prefill/decode is pure steady-state (and under a noisy
        ``DeviceConfig`` the whole engine serves from one fixed chip
        instead of redrawing noise per layer call).

        ``spare_cols`` (constructor arg) overrides the device's
        spare-column repair budget at deploy time: the fault-aware planner
        (``device.repair``) then remaps the worst stuck-cell columns of
        every projection into programmed spares before serving begins.

        ``restore_artifacts`` restores a previously ``save_artifacts``-ed
        programmed chip instead of reprogramming: the name-keyed artifact
        store is loaded bit-for-bit (fault fields, write-verify reports,
        repair tables included) and no ``program_layer`` call runs.
        """
        if restore_artifacts is not None:
            if crossbar is None or not crossbar.enabled:
                raise ValueError(
                    "restore_artifacts= needs crossbar serving enabled "
                    "(pass crossbar=CrossbarMode(enabled=True, ...))"
                )
            if crossbar.programmed is not None:
                raise ValueError(
                    "restore_artifacts= with prebuilt CrossbarMode.programmed "
                    "artifacts: pick one source of truth"
                )
            if spare_cols is not None:
                # 0 included: an explicit disable can no more be applied to
                # a baked chip than a new budget can — silently serving the
                # repaired artifacts would ignore the operator's override
                raise ValueError(
                    "spare_cols= cannot rebudget a restored chip (not even "
                    "to 0): the repair plan was baked in when the artifacts "
                    "were programmed — reprogram with the desired budget"
                )
            if self.plan is not None:
                # same bakery rule: a restored chip was compiled under the
                # plan recorded in its artifacts (each carries its
                # LayerPlan); a different plan needs a reprogram
                raise ValueError(
                    "plan= cannot replan a restored chip: the datapath / ADC "
                    "/ spare choices were baked in when the artifacts were "
                    "programmed — reprogram with the desired plan"
                )
            from repro.checkpoint import restore_programmed

            expected = self._verify_store(restore_artifacts, None, "restore_artifacts=")
            # restore re-places shards on the engine's mesh from the specs
            # recorded at save time; _shard_artifacts below re-derives from
            # param_axes as well, so either source of truth suffices
            prog = restore_programmed(restore_artifacts, mesh=self.mesh)
            # a stale or mismatched store would resolve no artifacts and
            # silently degrade every projection to per-call reprogramming —
            # the exact silent fallback this engine exists to prevent, so
            # cross-check the store against what this model would program
            bad = sorted(
                name for name, shape in expected.items()
                if prog.lookup(name, shape) is None
            )
            if bad:
                raise ValueError(
                    f"restored artifact store at {restore_artifacts!r} does not "
                    f"match this model: {len(bad)}/{len(expected)} projections "
                    f"missing or shape-mismatched ({', '.join(bad[:5])}"
                    + (", ..." if len(bad) > 5 else "")
                    + ") — was it saved from a different model/config?"
                )
            return dataclasses.replace(crossbar, programmed=self._shard_artifacts(prog))
        # spare_cols=0 means "no repair" and is a no-op wherever repair could
        # not happen anyway; a *positive* budget that cannot take effect is a
        # misconfiguration — silently serving unrepaired while the operator
        # believes a repair budget is active would be worse than failing
        if crossbar is None or not crossbar.enabled or crossbar.programmed is not None:
            if spare_cols:
                raise ValueError(
                    "spare_cols= needs crossbar serving with a DeviceConfig "
                    "to repair and no prebuilt artifacts (set spare_cols on "
                    "the DeviceConfig passed to program_model instead)"
                )
            return crossbar
        device = crossbar.device
        if spare_cols is not None:
            if device is None:
                if spare_cols:
                    raise ValueError(
                        "spare_cols= without a CrossbarMode.device: there is "
                        "no fault model to repair against"
                    )
            else:
                device = device.replace(spare_cols=spare_cols)
                from repro.device import wants_repair

                if spare_cols > 0 and not wants_repair(device):
                    raise ValueError(
                        f"spare_cols={spare_cols} on a device with no "
                        "stuck-at faults (p_stuck_on == p_stuck_off == 0): "
                        "nothing to repair"
                    )
                crossbar = dataclasses.replace(crossbar, device=device)
        from repro.device.programmed import program_model

        prog = program_model(
            self.params,
            device=device,
            fast=crossbar.fast,
            # tied LM heads serve from a transpose programmed once, bound to
            # the embedding's name (name-keyed binding makes this possible)
            tie_lm_head=self._tie_lm_head,
            expert_chips=self.expert_chips,
            plan=self.plan,
        )
        return dataclasses.replace(crossbar, programmed=self._shard_artifacts(prog))

    def _shard_artifacts(self, prog):
        """Place every artifact on the runner's mesh with its weight's spec.

        No-op without a mesh or without ``param_axes`` (artifacts stay
        replicated — the shard_map bodies still slice them per rank on the
        fly, so correctness never depends on placement, only memory/traffic
        does: an unplaced 8-plane ``g_eff`` would otherwise be resident on
        every device).
        """
        if self.mesh is None or self.param_axes is None or prog is None:
            return prog
        from jax.sharding import PartitionSpec as P

        from repro.device.programmed import join_path, shard_artifacts
        from repro.models.layers import layout_overrides, pspec, use_mesh

        flat_axes = jax.tree_util.tree_flatten_with_path(
            self.param_axes, is_leaf=lambda x: isinstance(x, tuple)
        )[0]
        axes_by_name = {join_path(p): a for p, a in flat_axes}
        shapes_by_name = {
            join_path(p): tuple(leaf.shape)
            for p, leaf in jax.tree_util.tree_flatten_with_path(self.params)[0]
        }
        specs = {}
        with use_mesh(self.mesh, layout_overrides(self.cfg)):
            for name, art in prog.by_name.items():
                axes = axes_by_name.get(name)
                if axes is None:
                    continue
                spec = pspec(axes, self.mesh)
                wshape = shapes_by_name.get(name)
                if art.shape == wshape:
                    specs[name] = spec
                elif wshape is not None and art.shape == tuple(reversed(wshape)):
                    # the tied-head artifact is the embedding's transpose,
                    # programmed under the embedding's name: reverse the spec
                    specs[name] = P(*reversed(tuple(spec) + (None,) * (len(wshape) - len(tuple(spec)))))
        return shard_artifacts(prog, self.mesh, specs)

    def verify_crossbar_coverage(self) -> None:
        """Structural name-set check at construction (abstract trace only).

        Traces one forward with ``jax.eval_shape`` under the runner's
        crossbar mode and asserts the programmed model's emitted name set
        was consumed exactly — a renamed layer or an artifact no call site
        serves fails construction loudly, *before* the first request
        (and before the miss counter could ever catch the orphaned-artifact
        direction, which produces zero misses).  No kernels execute and
        nothing is allocated.
        """
        if self.crossbar is None or self.crossbar.programmed is None:
            return
        from repro.device import programmed as prog_mod
        from repro.models import layers as layers_mod

        if self.cfg.frontend == "token":
            inp = jax.ShapeDtypeStruct((1, 4), jnp.int32)
        else:
            inp = jax.ShapeDtypeStruct((1, 4, self.cfg.d_model), jnp.float32)
        # snapshot the ambient trace-time records: this internal trace must
        # neither clobber a caller's in-flight consumption record nor leave
        # its own misses behind for an operator to misread as serving-time
        before_consumed = prog_mod.consumed_artifact_names()
        before_misses = layers_mod.crossbar_miss_counts()
        prog_mod.reset_consumed_artifact_names()
        try:
            jax.eval_shape(
                lambda p, t: self._with_crossbar(
                    lambda: model_lib.forward(p, self.cfg, t)
                ),
                self.params,
                inp,
            )
            self.crossbar.programmed.verify_consumed()
        finally:
            prog_mod.reset_consumed_artifact_names()
            for n in before_consumed:
                prog_mod.record_artifact_consumed(n)
            layers_mod.restore_crossbar_misses(before_misses)

    def save_artifacts(self, directory: str, slot: Optional[str] = None) -> str:
        """Persist the programmed chip so a restart can restore instead of
        reprogram (``ServingEngine(..., restore_artifacts=directory)``).
        ``slot`` writes into the double-buffered A/B layout (see
        ``checkpoint.save_programmed``; commit with ``swap_active``)."""
        if self.crossbar is None or self.crossbar.programmed is None:
            raise ValueError(
                "no programmed artifacts to save: construct the engine with "
                "crossbar=CrossbarMode(enabled=True, ...) first"
            )
        from repro.checkpoint import save_programmed

        return save_programmed(directory, self.crossbar.programmed, slot=slot)

    def repair_reports(self):
        """Path -> spare-column ``RepairReport`` for every repaired
        projection of the programmed model ({} when repair is off)."""
        if self.crossbar is None or self.crossbar.programmed is None:
            return {}
        return self.crossbar.programmed.repair_reports()

    # ------------------------------------------------------------------
    # Chip lifecycle: monitor -> compensate -> refresh
    # ------------------------------------------------------------------

    @property
    def programmed(self):
        """The bound ``ProgrammedModel`` (None when not crossbar-serving)."""
        if self.crossbar is None:
            return None
        return self.crossbar.programmed

    @property
    def uptime_s(self) -> float:
        """Fleet service time of the bound chips, seconds since programming."""
        prog = self.programmed
        return prog.t_service_s if prog is not None else 0.0

    def _require_programmed(self, what: str):
        prog = self.programmed
        if prog is None:
            raise ValueError(
                f"{what} needs programmed crossbar serving: construct the "
                "engine with crossbar=CrossbarMode(enabled=True, ...)"
            )
        return prog

    def _rebind(self, prog) -> None:
        """Swap the served chip and rebuild every jitted step function.

        Artifacts are *trace-time constants* inside the jitted prefill and
        decode steps (the closures bind ``self.crossbar.programmed`` when
        they trace) — mutating the crossbar mode alone would keep serving
        the old chip out of the jit cache.  Dropping the wrappers forces a
        retrace against the new binding; KV caches, slot state and pending
        requests live in the scheduler layer and are untouched, so
        in-flight requests continue on the new chip at the next tick — the
        zero-downtime part of ``hot_swap``.
        """
        self.crossbar = dataclasses.replace(self.crossbar, programmed=prog)
        self._decode = jax.jit(
            lambda p, t, pos, c: self._with_crossbar(
                lambda: model_lib.decode_step(p, self.cfg, t, pos, c)
            )
        )
        self._prefills = {}

    def age(self, dt_s: float) -> None:
        """Advance every bound chip ``dt_s`` seconds of service.

        The lifecycle clock: cells decay through the device's retention
        power law (``device.programmed.age_artifact``) without
        reprogramming.  Drift-free configs only advance the clock
        (bit-identical serving).
        """
        prog = self._require_programmed("age()")
        self._rebind(prog.age(dt_s))

    def health_check(self, n_probes: Optional[int] = None, seed: int = 0,
                     budget: Optional[float] = None):
        """Probe every bound artifact against its frozen digital reference.

        Returns a ``device.health.HealthReport``; ``report.flagged`` names
        the layers whose drift error crossed the budget — the refresh
        candidates.  Purely digital, does not perturb the chips.
        """
        from repro.device import health as health_mod

        prog = self._require_programmed("health_check()")
        kw = {}
        if n_probes is not None:
            kw["n_probes"] = n_probes
        if budget is not None:
            kw["budget"] = budget
        return health_mod.health_check(prog, seed=seed, **kw)

    def compensate(self, n_probes: Optional[int] = None, seed: int = 0) -> None:
        """Refit the free digital drift compensation on every noisy chip.

        Updates each artifact's ``comp_scale`` (closed-form power-law
        rescale + probe-fit residual, ``device.health.fit_compensation``)
        and rebinds — zero reprogramming, recovers most of the drift-accrued
        logit error between refreshes.
        """
        from repro.device import health as health_mod

        prog = self._require_programmed("compensate()")
        kw = {"n_probes": n_probes} if n_probes is not None else {}
        self._rebind(health_mod.compensate_model(prog, seed=seed, **kw))

    def hot_swap(self, directory: str, slot: Optional[str] = None) -> None:
        """Rebind the chip from an artifact store without stopping serving.

        Runs the *same* ``analysis.verify_store`` fail-fast static
        verification as construction-time ``restore_artifacts=`` (same
        orphaned-leaf carve-out), restores ``directory`` (following the
        ``ACTIVE`` slot pointer unless ``slot`` is forced), cross-checks it
        against this model's expected projection set, re-places it on the
        runner's mesh, and swaps between decode steps — in-flight requests
        keep their caches and continue on the refreshed chip at the next
        tick.  A corrupt or mismatched store is refused up front and the
        old chip keeps serving.  A swap onto a just-reprogrammed store is
        bit-identical to an engine freshly constructed on that chip
        (programming is deterministic; the store round-trips exact dtypes).
        """
        self._require_programmed("hot_swap()")
        from repro.checkpoint import restore_programmed

        expected = self._verify_store(directory, slot, "hot_swap")
        prog = restore_programmed(directory, mesh=self.mesh, slot=slot)
        bad = sorted(
            name for name, shape in expected.items()
            if prog.lookup(name, shape) is None
        )
        if bad:
            raise ValueError(
                f"hot_swap store at {directory!r} does not match this model: "
                f"{len(bad)}/{len(expected)} projections missing or "
                f"shape-mismatched ({', '.join(bad[:5])}"
                + (", ..." if len(bad) > 5 else "") + ")"
            )
        self._rebind(self._shard_artifacts(prog))

    def refresh(self, directory: Optional[str] = None) -> Optional[str]:
        """Reprogram fresh chips and swap them in — the lifecycle reset.

        Reprograms every projection from the runner's params under the
        construction-time device config (deterministic: the same chip the
        engine started with, at service time zero).  With ``directory``,
        the fresh chips are written into the *inactive* store slot while
        the old ones keep serving, the ``ACTIVE`` pointer is atomically
        swapped, and the runner hot-swaps from the store (serving exactly
        what a restart would restore); returns the committed slot.  Without
        a directory the fresh chips are rebound directly.
        """
        self._require_programmed("refresh()")
        from repro.device.programmed import program_model

        prog = program_model(
            self.params,
            device=self.crossbar.device,
            fast=self.crossbar.fast,
            tie_lm_head=self._tie_lm_head,
            expert_chips=self.expert_chips,
            plan=self.plan,
        )
        if directory is None:
            self._rebind(self._shard_artifacts(prog))
            return None
        from repro.checkpoint import active_slot, save_programmed, swap_active

        target = "B" if active_slot(directory) == "A" else "A"
        save_programmed(directory, prog, slot=target)
        swap_active(directory, target)
        self.hot_swap(directory)
        return target

    def _with_crossbar(self, fn):
        """Run ``fn`` under the runner's mesh and crossbar mode, with the
        programmed model's name-keyed artifact table bound for the dynamic
        scope (works at jit trace time — lookups resolve by name, not by
        leaf identity, so any congruent params tree serves).  With a mesh,
        the model's shard_map EP/TP paths engage and their bodies rebind
        rank-local artifact slices."""
        with contextlib.ExitStack() as stack:
            if self.mesh is not None:
                from repro.models.layers import layout_overrides, use_mesh

                stack.enter_context(use_mesh(self.mesh, layout_overrides(self.cfg)))
                stack.enter_context(self.mesh)
            if self.crossbar is not None:
                stack.enter_context(crossbar_mode(self.crossbar))
                if self.crossbar.programmed is not None:
                    stack.enter_context(self.crossbar.programmed.bind())
            return fn()

    # ------------------------------------------------------------------
    # Scheduler-facing surface: cache init, prefill-admit, decode, sample
    # ------------------------------------------------------------------

    def init_cache(self, batch: int, dtype=jnp.float32):
        """A dense slot-pool cache sized to this runner's ``max_seq``."""
        return model_lib.init_cache(self.cfg, batch, self.max_seq, dtype=dtype)

    def _prefill_fn(self, bucket: int):
        if bucket not in self._prefills:
            def fn(params, tokens, cache):
                return self._with_crossbar(
                    lambda: model_lib.prefill(params, self.cfg, tokens, cache)
                )
            self._prefills[bucket] = jax.jit(fn)
        return self._prefills[bucket]

    def check_prompt(self, prompt, truncate: bool) -> int:
        """Validate a prompt against ``max_seq``; returns the effective
        (possibly truncated) prefill length.

        A prompt longer than ``max_seq`` cannot be coherently prefilled —
        the slot pool has no room for its tail — so it is refused with a
        clear error unless the caller explicitly opted into truncation
        (``truncate=True`` keeps the first ``max_seq`` tokens and admits
        with pos/last_tok derived from the truncated length).
        """
        S = len(prompt)
        if S > self.max_seq:
            if not truncate:
                raise ValueError(
                    f"prompt of length {S} exceeds max_seq={self.max_seq}: "
                    "it cannot be prefilled into the slot pool — raise "
                    "max_seq, shorten the prompt, or pass truncate=True to "
                    "serve the first max_seq tokens"
                )
            return self.max_seq
        return S

    def admit_slot(self, cache, slot: int, req: Request):
        """Prefill one request and scatter its cache into slot ``slot``.

        Returns ``(cache, pos, last_tok, first_tok)`` where ``first_tok``
        is the prefill-sampled first generated token for recurrent archs
        (None for attention, which re-issues the last prompt token on the
        first decode tick instead).
        """
        S = self.check_prompt(req.prompt, req.truncate)
        # Recurrent archs (ssm/hybrid) must not process padding tokens —
        # their state would absorb them — so they prefill exact lengths;
        # attention caches tolerate padding (masked by position), so they
        # use buckets + an idempotent catch-up re-issue of token S-1.
        recurrent = self.cfg.family in ("ssm", "hybrid")
        bucket = S if recurrent else min(_bucket(S), self.max_seq)
        prompt = np.zeros((1, bucket), np.int32)
        # S <= bucket always (check_prompt clamps S to max_seq >= bucket),
        # so the copy below never silently drops tokens the bookkeeping
        # would then point past
        prompt[0, :S] = req.prompt[:S]
        small_cache = self.init_cache(1)
        logits, filled = self._prefill_fn(bucket)(self.params, jnp.asarray(prompt), small_cache)
        cache = jax.tree.map(
            lambda big, one: big.at[:, slot].set(one[:, 0]), cache, filled
        )
        if recurrent:
            tok = int(self.sample(np.asarray(logits, np.float32))[0])
            return cache, S, tok, tok
        # pos/last_tok from the *effective* length: after truncation both
        # point at the last token that was actually prefilled
        return cache, S - 1, int(np.asarray(req.prompt)[S - 1]), None

    def decode(self, last_tok: np.ndarray, pos: np.ndarray, cache):
        """One jitted decode tick over the whole slot pool; returns
        ``(logits, cache)`` with logits as host float32."""
        toks = jnp.asarray(np.asarray(last_tok)[:, None])
        logits, cache = self._decode(self.params, toks, jnp.asarray(pos), cache)
        return np.asarray(logits, np.float32), cache

    def sample(self, logits: np.ndarray) -> np.ndarray:
        if self.temperature <= 0.0:
            return np.argmax(logits, axis=-1).astype(np.int32)
        self.key, sub = jax.random.split(self.key)
        g = jax.random.gumbel(sub, logits.shape)
        return np.asarray(
            jnp.argmax(logits / self.temperature + g, axis=-1), np.int32
        )


class ServingEngine:
    """Slot scheduler over a ``ModelRunner`` (the pre-traffic-tier loop).

    Composes a runner with a fixed slot pool and a FIFO pending queue;
    ``step()`` admits and advances, ``run_until_done()`` drains.  All
    model/chip concerns (programming, lifecycle, persistence, sampling)
    delegate to the runner — ``eng.crossbar``, ``eng.hot_swap(...)`` etc.
    keep working as before the scheduler/model-runner split.
    """

    def __init__(
        self,
        cfg: ModelConfig,
        params,
        max_batch: int = 4,
        max_seq: int = 512,
        temperature: float = 0.0,
        seed: int = 0,
        crossbar: Optional[CrossbarMode] = None,
        spare_cols: Optional[int] = None,
        restore_artifacts: Optional[str] = None,
        mesh=None,
        param_axes=None,
        verify_coverage: bool = True,
        expert_chips=None,
        plan=None,
        rid_start: int = 0,
    ):
        self.runner = ModelRunner(
            cfg,
            params,
            max_seq=max_seq,
            temperature=temperature,
            seed=seed,
            crossbar=crossbar,
            spare_cols=spare_cols,
            restore_artifacts=restore_artifacts,
            mesh=mesh,
            param_axes=param_axes,
            verify_coverage=verify_coverage,
            expert_chips=expert_chips,
            plan=plan,
        )
        self.max_batch = max_batch
        self.cache = self.runner.init_cache(max_batch)
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.pos = np.zeros(max_batch, np.int32)  # position of next write
        self.last_tok = np.zeros(max_batch, np.int32)
        self.pending: List[Request] = []
        # completion ledger: step() records every finished request here the
        # moment it frees the slot, so a request that is admitted and
        # finishes within one step() (max_new_tokens=1) cannot vanish from
        # run_until_done()'s returned list
        self._completed: Dict[int, Request] = {}
        # rid_start: disjoint rid ranges per replica when a ChipFarm fans
        # one request stream across several engines (serving.farm)
        self._rid = itertools.count(rid_start)

    # -- delegation: the model half lives on the runner -----------------
    @property
    def cfg(self) -> ModelConfig:
        return self.runner.cfg

    @property
    def params(self):
        return self.runner.params

    @property
    def max_seq(self) -> int:
        return self.runner.max_seq

    @property
    def temperature(self) -> float:
        return self.runner.temperature

    @property
    def mesh(self):
        return self.runner.mesh

    @property
    def param_axes(self):
        return self.runner.param_axes

    @property
    def plan(self):
        return self.runner.plan

    @property
    def expert_chips(self):
        return self.runner.expert_chips

    @property
    def crossbar(self) -> Optional[CrossbarMode]:
        return self.runner.crossbar

    @property
    def programmed(self):
        return self.runner.programmed

    @property
    def uptime_s(self) -> float:
        return self.runner.uptime_s

    def verify_crossbar_coverage(self) -> None:
        self.runner.verify_crossbar_coverage()

    def save_artifacts(self, directory: str, slot: Optional[str] = None) -> str:
        return self.runner.save_artifacts(directory, slot=slot)

    def repair_reports(self):
        return self.runner.repair_reports()

    def age(self, dt_s: float) -> None:
        self.runner.age(dt_s)

    def health_check(self, n_probes: Optional[int] = None, seed: int = 0,
                     budget: Optional[float] = None):
        return self.runner.health_check(n_probes=n_probes, seed=seed, budget=budget)

    def compensate(self, n_probes: Optional[int] = None, seed: int = 0) -> None:
        self.runner.compensate(n_probes=n_probes, seed=seed)

    def hot_swap(self, directory: str, slot: Optional[str] = None) -> None:
        self.runner.hot_swap(directory, slot=slot)

    def refresh(self, directory: Optional[str] = None) -> Optional[str]:
        return self.runner.refresh(directory)

    # ------------------------------------------------------------------
    def submit(
        self,
        prompt,
        max_new_tokens: int = 16,
        eos_id: Optional[int] = None,
        truncate: bool = False,
        on_token: Optional[Callable[[Request, int], None]] = None,
    ) -> int:
        prompt = np.asarray(prompt)
        # refuse over-length prompts at submit time (not deep in _admit
        # mid-serving) unless truncation was explicitly allowed
        self.runner.check_prompt(prompt, truncate)
        req = Request(
            next(self._rid), prompt, max_new_tokens, eos_id,
            truncate=truncate, on_token=on_token,
        )
        self.pending.append(req)
        return req.rid

    def _admit(self):
        for slot in range(self.max_batch):
            if self.slots[slot] is not None or not self.pending:
                continue
            req = self.pending.pop(0)
            self.cache, p, lt, first = self.runner.admit_slot(self.cache, slot, req)
            self.pos[slot] = p
            self.last_tok[slot] = lt
            if first is not None:
                req.generated.append(first)
                if req.on_token is not None:
                    req.on_token(req, first)
            self.slots[slot] = req

    # ------------------------------------------------------------------
    def step(self) -> int:
        """Admit pending requests and advance every occupied slot one token.

        Finished requests are recorded in the completion ledger as their
        slots free.  Returns the number of active slots advanced."""
        self._admit()
        active = [i for i in range(self.max_batch) if self.slots[i] is not None]
        if not active:
            return 0
        logits, self.cache = self.runner.decode(self.last_tok, self.pos, self.cache)
        nxt = self.runner.sample(logits)
        for i in active:
            req = self.slots[i]
            self.pos[i] += 1
            tok = int(nxt[i])
            req.generated.append(tok)
            self.last_tok[i] = tok
            if req.on_token is not None:
                req.on_token(req, tok)
            if (
                len(req.generated) >= req.max_new_tokens
                or (req.eos_id is not None and tok == req.eos_id)
                or self.pos[i] >= self.max_seq - 1
            ):
                req.done = True
                self._completed[req.rid] = req
                self.slots[i] = None
        return len(active)

    def run_until_done(self, max_ticks: int = 10_000) -> List[Request]:
        for _ in range(max_ticks):
            if not self.pending and all(s is None for s in self.slots):
                break
            self.step()
        # completion ledger + whatever is still in flight at the tick
        # budget: nothing is lost, even a request admitted and finished
        # inside a single step()
        out = dict(self._completed)
        for s in self.slots:
            if s is not None:
                out[s.rid] = s
        return sorted(out.values(), key=lambda r: r.rid)
