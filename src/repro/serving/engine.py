"""Batched serving engine with continuous batching.

Slot-based design (vLLM-lite, adapted to JAX static shapes):
  * a fixed pool of ``max_batch`` cache slots, each holding one request's
    KV/state cache at its own position;
  * admission: a pending request is prefilled with a batch-1 prefill
    (prompt padded to a bucket to bound recompilation) and its cache is
    scattered into the slot pool;
  * decode: one jitted ``decode_step`` advances *all* occupied slots each
    tick with per-slot positions; finished slots are freed and refilled
    without stalling the others.

Sampling is greedy or temperature-based with a per-engine PRNG; generation
is deterministic given (seed, admission order), which the tests assert.

Crossbar serving: pass ``crossbar=CrossbarMode(enabled=True, device=...)``
and the engine compiles every projection onto programmed crossbars **once**
at construction (``repro.device.programmed.program_model``) — the paper's
program-once premise as a serving feature.  Every prefill/decode then runs
the steady-state artifact path inside the jitted step functions: one fixed
noisy chip across the whole engine lifetime, no per-call reprogramming.
Artifacts are name-keyed, so MoE expert banks and tied LM heads serve from
the crossbar too (the tied head from a transpose programmed once at
construction).  ``spare_cols=`` exposes the fault-aware spare-column repair
budget (``device.repair``) at deploy time; ``repair_reports()`` summarizes
what the planner remapped.

Persistence: ``save_artifacts(dir)`` writes the programmed chip —
effective cells, frozen scales, write-verify reports, spare blocks and
gather tables — through ``repro.checkpoint``; a later
``ServingEngine(..., restore_artifacts=dir)`` restores the *same* chip
bit-for-bit and skips reprogramming entirely (restart latency is file I/O,
not write-verify).

Mesh serving: pass ``mesh=`` (plus ``param_axes=`` from ``init_model``)
and every jitted step runs under the mesh with the config's layout
overrides, so the model's ``shard_map`` EP/TP paths engage; programmed
artifacts are sharded with the same PartitionSpecs as the weights they
shadow (``device.programmed.shard_artifacts``) and the bodies rebind
rank-local slices by name — expert-parallel serving is bit-identical to
the single-device chip (tests/test_sharded_artifacts.py).  Saved stores
record the deployment sharding; restore re-places shards on the mesh.
``verify_coverage`` (default on) runs the structural name-set check at
construction: one abstract trace asserts the forward consumes exactly the
emitted artifact name set, failing loudly on drift a miss counter cannot
see (an orphaned artifact misses nothing — nothing ever looks it up).
"""
from __future__ import annotations

import contextlib
import dataclasses
import itertools
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as model_lib
from repro.models.layers import CrossbarMode, crossbar_mode


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32 tokens (or (S, D) embeddings)
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    generated: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


def _bucket(n: int, buckets=(32, 64, 128, 256, 512, 1024, 2048)) -> int:
    for b in buckets:
        if n <= b:
            return b
    return -(-n // 2048) * 2048


class ServingEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        max_batch: int = 4,
        max_seq: int = 512,
        temperature: float = 0.0,
        seed: int = 0,
        crossbar: Optional[CrossbarMode] = None,
        spare_cols: Optional[int] = None,
        restore_artifacts: Optional[str] = None,
        mesh=None,
        param_axes=None,
        verify_coverage: bool = True,
        expert_chips=None,
        plan=None,
    ):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        # mesh serving: every jitted step runs under ``use_mesh(mesh,
        # layout_overrides(cfg))`` so the model's shard_map EP/TP paths
        # engage; ``param_axes`` (the logical-axes tree from init_model)
        # lets the engine shard programmed artifacts with the same specs as
        # the weights they shadow (device.programmed.shard_artifacts)
        self.mesh = mesh
        self.param_axes = param_axes
        # fleet realism: one DeviceConfig.chip identity per expert, so the
        # slabs an EP mesh places on different ranks draw decorrelated
        # device perturbations (device.programmed.program_layer(chips=));
        # remembered so refresh() reprograms the same fleet
        self.expert_chips = tuple(expert_chips) if expert_chips is not None else None
        # chip-plan compiler (core.planner.ChipPlan): per-layer heterogeneous
        # datapath / ADC schedule / spare budget, threaded into program_model
        # at deploy time and again on refresh() — the reprogrammed fleet must
        # be the chip the plan admitted
        self.plan = plan
        self.crossbar = self._program_crossbars(crossbar, spare_cols, restore_artifacts)
        if verify_coverage:
            self.verify_crossbar_coverage()
        self.cache = model_lib.init_cache(cfg, max_batch, max_seq, dtype=jnp.float32)
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.pos = np.zeros(max_batch, np.int32)  # position of next write
        self.last_tok = np.zeros(max_batch, np.int32)
        self.pending: List[Request] = []
        self._rid = itertools.count()
        self._decode = jax.jit(
            lambda p, t, pos, c: self._with_crossbar(
                lambda: model_lib.decode_step(p, self.cfg, t, pos, c)
            )
        )
        self._prefills: Dict[int, object] = {}

    # ------------------------------------------------------------------
    def _program_crossbars(
        self,
        crossbar: Optional[CrossbarMode],
        spare_cols: Optional[int] = None,
        restore_artifacts: Optional[str] = None,
    ):
        """Program-once compilation of the model's weights (deploy time).

        When crossbar serving is requested without prebuilt artifacts, walk
        the params and compile every projection now — every subsequent
        prefill/decode is pure steady-state (and under a noisy
        ``DeviceConfig`` the whole engine serves from one fixed chip
        instead of redrawing noise per layer call).

        ``spare_cols`` (engine constructor arg) overrides the device's
        spare-column repair budget at deploy time: the fault-aware planner
        (``device.repair``) then remaps the worst stuck-cell columns of
        every projection into programmed spares before serving begins.

        ``restore_artifacts`` restores a previously ``save_artifacts``-ed
        programmed chip instead of reprogramming: the name-keyed artifact
        store is loaded bit-for-bit (fault fields, write-verify reports,
        repair tables included) and no ``program_layer`` call runs.
        """
        if restore_artifacts is not None:
            if crossbar is None or not crossbar.enabled:
                raise ValueError(
                    "restore_artifacts= needs crossbar serving enabled "
                    "(pass crossbar=CrossbarMode(enabled=True, ...))"
                )
            if crossbar.programmed is not None:
                raise ValueError(
                    "restore_artifacts= with prebuilt CrossbarMode.programmed "
                    "artifacts: pick one source of truth"
                )
            if spare_cols is not None:
                # 0 included: an explicit disable can no more be applied to
                # a baked chip than a new budget can — silently serving the
                # repaired artifacts would ignore the operator's override
                raise ValueError(
                    "spare_cols= cannot rebudget a restored chip (not even "
                    "to 0): the repair plan was baked in when the artifacts "
                    "were programmed — reprogram with the desired budget"
                )
            if self.plan is not None:
                # same bakery rule: a restored chip was compiled under the
                # plan recorded in its artifacts (each carries its
                # LayerPlan); a different plan needs a reprogram
                raise ValueError(
                    "plan= cannot replan a restored chip: the datapath / ADC "
                    "/ spare choices were baked in when the artifacts were "
                    "programmed — reprogram with the desired plan"
                )
            from repro.analysis.store import verify_store
            from repro.checkpoint import restore_programmed
            from repro.device.programmed import expected_artifact_names

            expected = expected_artifact_names(
                self.params,
                tie_lm_head=(self.cfg.tie_embeddings and self.cfg.frontend == "token"),
            )
            # fail-fast static verification from manifests alone, before any
            # array loads or binding: a corrupt slot pointer, undecodable
            # spec/plan, inconsistent leaf shapes or a wrong name-set is
            # refused with the failing rule named, instead of surfacing as a
            # silent per-call reprogramming fallback mid-serving
            vreport = verify_store(restore_artifacts, expected=expected)
            # orphaned leaves (store ⊃ model) are left to verify_coverage
            # below: a superset store serves correctly, and that check has
            # an explicit opt-out (verify_coverage=False) for exotic setups
            fatal = [
                f for f in vreport.findings
                if not (f.rule == "name-set" and "orphaned leaf" in f.message)
            ]
            if fatal:
                vreport.findings[:] = fatal
                raise ValueError(
                    "restore_artifacts= store failed static verification "
                    "(repro.analysis.verify_store): it is internally "
                    "inconsistent or does not match this model —\n"
                    + vreport.summary()
                )
            # restore re-places shards on the engine's mesh from the specs
            # recorded at save time; _shard_artifacts below re-derives from
            # param_axes as well, so either source of truth suffices
            prog = restore_programmed(restore_artifacts, mesh=self.mesh)
            # a stale or mismatched store would resolve no artifacts and
            # silently degrade every projection to per-call reprogramming —
            # the exact silent fallback this engine exists to prevent, so
            # cross-check the store against what this model would program
            bad = sorted(
                name for name, shape in expected.items()
                if prog.lookup(name, shape) is None
            )
            if bad:
                raise ValueError(
                    f"restored artifact store at {restore_artifacts!r} does not "
                    f"match this model: {len(bad)}/{len(expected)} projections "
                    f"missing or shape-mismatched ({', '.join(bad[:5])}"
                    + (", ..." if len(bad) > 5 else "")
                    + ") — was it saved from a different model/config?"
                )
            return dataclasses.replace(crossbar, programmed=self._shard_artifacts(prog))
        # spare_cols=0 means "no repair" and is a no-op wherever repair could
        # not happen anyway; a *positive* budget that cannot take effect is a
        # misconfiguration — silently serving unrepaired while the operator
        # believes a repair budget is active would be worse than failing
        if crossbar is None or not crossbar.enabled or crossbar.programmed is not None:
            if spare_cols:
                raise ValueError(
                    "spare_cols= needs crossbar serving with a DeviceConfig "
                    "to repair and no prebuilt artifacts (set spare_cols on "
                    "the DeviceConfig passed to program_model instead)"
                )
            return crossbar
        device = crossbar.device
        if spare_cols is not None:
            if device is None:
                if spare_cols:
                    raise ValueError(
                        "spare_cols= without a CrossbarMode.device: there is "
                        "no fault model to repair against"
                    )
            else:
                device = device.replace(spare_cols=spare_cols)
                from repro.device import wants_repair

                if spare_cols > 0 and not wants_repair(device):
                    raise ValueError(
                        f"spare_cols={spare_cols} on a device with no "
                        "stuck-at faults (p_stuck_on == p_stuck_off == 0): "
                        "nothing to repair"
                    )
                crossbar = dataclasses.replace(crossbar, device=device)
        from repro.device.programmed import program_model

        prog = program_model(
            self.params,
            device=device,
            fast=crossbar.fast,
            # tied LM heads serve from a transpose programmed once, bound to
            # the embedding's name (name-keyed binding makes this possible)
            tie_lm_head=(self.cfg.tie_embeddings and self.cfg.frontend == "token"),
            expert_chips=self.expert_chips,
            plan=self.plan,
        )
        return dataclasses.replace(crossbar, programmed=self._shard_artifacts(prog))

    def _shard_artifacts(self, prog):
        """Place every artifact on the engine's mesh with its weight's spec.

        No-op without a mesh or without ``param_axes`` (artifacts stay
        replicated — the shard_map bodies still slice them per rank on the
        fly, so correctness never depends on placement, only memory/traffic
        does: an unplaced 8-plane ``g_eff`` would otherwise be resident on
        every device).
        """
        if self.mesh is None or self.param_axes is None or prog is None:
            return prog
        from jax.sharding import PartitionSpec as P

        from repro.device.programmed import join_path, shard_artifacts
        from repro.models.layers import layout_overrides, pspec, use_mesh

        flat_axes = jax.tree_util.tree_flatten_with_path(
            self.param_axes, is_leaf=lambda x: isinstance(x, tuple)
        )[0]
        axes_by_name = {join_path(p): a for p, a in flat_axes}
        shapes_by_name = {
            join_path(p): tuple(leaf.shape)
            for p, leaf in jax.tree_util.tree_flatten_with_path(self.params)[0]
        }
        specs = {}
        with use_mesh(self.mesh, layout_overrides(self.cfg)):
            for name, art in prog.by_name.items():
                axes = axes_by_name.get(name)
                if axes is None:
                    continue
                spec = pspec(axes, self.mesh)
                wshape = shapes_by_name.get(name)
                if art.shape == wshape:
                    specs[name] = spec
                elif wshape is not None and art.shape == tuple(reversed(wshape)):
                    # the tied-head artifact is the embedding's transpose,
                    # programmed under the embedding's name: reverse the spec
                    specs[name] = P(*reversed(tuple(spec) + (None,) * (len(wshape) - len(tuple(spec)))))
        return shard_artifacts(prog, self.mesh, specs)

    def verify_crossbar_coverage(self) -> None:
        """Structural name-set check at construction (abstract trace only).

        Traces one forward with ``jax.eval_shape`` under the engine's
        crossbar mode and asserts the programmed model's emitted name set
        was consumed exactly — a renamed layer or an artifact no call site
        serves fails engine construction loudly, *before* the first request
        (and before the miss counter could ever catch the orphaned-artifact
        direction, which produces zero misses).  No kernels execute and
        nothing is allocated.
        """
        if self.crossbar is None or self.crossbar.programmed is None:
            return
        from repro.device import programmed as prog_mod
        from repro.models import layers as layers_mod
        from repro.models import model as model_lib

        if self.cfg.frontend == "token":
            inp = jax.ShapeDtypeStruct((1, 4), jnp.int32)
        else:
            inp = jax.ShapeDtypeStruct((1, 4, self.cfg.d_model), jnp.float32)
        # snapshot the ambient trace-time records: this internal trace must
        # neither clobber a caller's in-flight consumption record nor leave
        # its own misses behind for an operator to misread as serving-time
        before_consumed = prog_mod.consumed_artifact_names()
        before_misses = layers_mod.crossbar_miss_counts()
        prog_mod.reset_consumed_artifact_names()
        try:
            jax.eval_shape(
                lambda p, t: self._with_crossbar(
                    lambda: model_lib.forward(p, self.cfg, t)
                ),
                self.params,
                inp,
            )
            self.crossbar.programmed.verify_consumed()
        finally:
            prog_mod.reset_consumed_artifact_names()
            for n in before_consumed:
                prog_mod.record_artifact_consumed(n)
            layers_mod.restore_crossbar_misses(before_misses)

    def save_artifacts(self, directory: str, slot: Optional[str] = None) -> str:
        """Persist the programmed chip so a restart can restore instead of
        reprogram (``ServingEngine(..., restore_artifacts=directory)``).
        ``slot`` writes into the double-buffered A/B layout (see
        ``checkpoint.save_programmed``; commit with ``swap_active``)."""
        if self.crossbar is None or self.crossbar.programmed is None:
            raise ValueError(
                "no programmed artifacts to save: construct the engine with "
                "crossbar=CrossbarMode(enabled=True, ...) first"
            )
        from repro.checkpoint import save_programmed

        return save_programmed(directory, self.crossbar.programmed, slot=slot)

    def repair_reports(self):
        """Path -> spare-column ``RepairReport`` for every repaired
        projection of the programmed model ({} when repair is off)."""
        if self.crossbar is None or self.crossbar.programmed is None:
            return {}
        return self.crossbar.programmed.repair_reports()

    # ------------------------------------------------------------------
    # Chip lifecycle: monitor -> compensate -> refresh
    # ------------------------------------------------------------------

    @property
    def programmed(self):
        """The bound ``ProgrammedModel`` (None when not crossbar-serving)."""
        if self.crossbar is None:
            return None
        return self.crossbar.programmed

    @property
    def uptime_s(self) -> float:
        """Fleet service time of the bound chips, seconds since programming."""
        prog = self.programmed
        return prog.t_service_s if prog is not None else 0.0

    def _require_programmed(self, what: str):
        prog = self.programmed
        if prog is None:
            raise ValueError(
                f"{what} needs programmed crossbar serving: construct the "
                "engine with crossbar=CrossbarMode(enabled=True, ...)"
            )
        return prog

    def _rebind(self, prog) -> None:
        """Swap the served chip and rebuild every jitted step function.

        Artifacts are *trace-time constants* inside the jitted prefill and
        decode steps (the closures bind ``self.crossbar.programmed`` when
        they trace) — mutating the crossbar mode alone would keep serving
        the old chip out of the jit cache.  Dropping the wrappers forces a
        retrace against the new binding; KV caches, slot state and pending
        requests are untouched, so in-flight requests continue on the new
        chip at the next tick — the zero-downtime part of ``hot_swap``.
        """
        self.crossbar = dataclasses.replace(self.crossbar, programmed=prog)
        self._decode = jax.jit(
            lambda p, t, pos, c: self._with_crossbar(
                lambda: model_lib.decode_step(p, self.cfg, t, pos, c)
            )
        )
        self._prefills = {}

    def age(self, dt_s: float) -> None:
        """Advance every bound chip ``dt_s`` seconds of service.

        The lifecycle clock: cells decay through the device's retention
        power law (``device.programmed.age_artifact``) without
        reprogramming.  Drift-free configs only advance the clock
        (bit-identical serving).
        """
        prog = self._require_programmed("age()")
        self._rebind(prog.age(dt_s))

    def health_check(self, n_probes: Optional[int] = None, seed: int = 0,
                     budget: Optional[float] = None):
        """Probe every bound artifact against its frozen digital reference.

        Returns a ``device.health.HealthReport``; ``report.flagged`` names
        the layers whose drift error crossed the budget — the refresh
        candidates.  Purely digital, does not perturb the chips.
        """
        from repro.device import health as health_mod

        prog = self._require_programmed("health_check()")
        kw = {}
        if n_probes is not None:
            kw["n_probes"] = n_probes
        if budget is not None:
            kw["budget"] = budget
        return health_mod.health_check(prog, seed=seed, **kw)

    def compensate(self, n_probes: Optional[int] = None, seed: int = 0) -> None:
        """Refit the free digital drift compensation on every noisy chip.

        Updates each artifact's ``comp_scale`` (closed-form power-law
        rescale + probe-fit residual, ``device.health.fit_compensation``)
        and rebinds — zero reprogramming, recovers most of the drift-accrued
        logit error between refreshes.
        """
        from repro.device import health as health_mod

        prog = self._require_programmed("compensate()")
        kw = {"n_probes": n_probes} if n_probes is not None else {}
        self._rebind(health_mod.compensate_model(prog, seed=seed, **kw))

    def hot_swap(self, directory: str, slot: Optional[str] = None) -> None:
        """Rebind the chip from an artifact store without stopping serving.

        Restores ``directory`` (following the ``ACTIVE`` slot pointer
        unless ``slot`` is forced), validates it against this model's
        expected projection set exactly like construction-time restore,
        re-places it on the engine's mesh, and swaps between decode steps —
        in-flight requests keep their caches and continue on the refreshed
        chip at the next tick.  A swap onto a just-reprogrammed store is
        bit-identical to an engine freshly constructed on that chip
        (programming is deterministic; the store round-trips exact dtypes).
        """
        self._require_programmed("hot_swap()")
        from repro.checkpoint import restore_programmed
        from repro.device.programmed import expected_artifact_names

        prog = restore_programmed(directory, mesh=self.mesh, slot=slot)
        expected = expected_artifact_names(
            self.params,
            tie_lm_head=(self.cfg.tie_embeddings and self.cfg.frontend == "token"),
        )
        bad = sorted(
            name for name, shape in expected.items()
            if prog.lookup(name, shape) is None
        )
        if bad:
            raise ValueError(
                f"hot_swap store at {directory!r} does not match this model: "
                f"{len(bad)}/{len(expected)} projections missing or "
                f"shape-mismatched ({', '.join(bad[:5])}"
                + (", ..." if len(bad) > 5 else "") + ")"
            )
        self._rebind(self._shard_artifacts(prog))

    def refresh(self, directory: Optional[str] = None) -> Optional[str]:
        """Reprogram fresh chips and swap them in — the lifecycle reset.

        Reprograms every projection from the engine's params under the
        construction-time device config (deterministic: the same chip the
        engine started with, at service time zero).  With ``directory``,
        the fresh chips are written into the *inactive* store slot while
        the old ones keep serving, the ``ACTIVE`` pointer is atomically
        swapped, and the engine hot-swaps from the store (serving exactly
        what a restart would restore); returns the committed slot.  Without
        a directory the fresh chips are rebound directly.
        """
        self._require_programmed("refresh()")
        from repro.device.programmed import program_model

        prog = program_model(
            self.params,
            device=self.crossbar.device,
            fast=self.crossbar.fast,
            tie_lm_head=(self.cfg.tie_embeddings and self.cfg.frontend == "token"),
            expert_chips=self.expert_chips,
            plan=self.plan,
        )
        if directory is None:
            self._rebind(self._shard_artifacts(prog))
            return None
        from repro.checkpoint import active_slot, save_programmed, swap_active

        target = "B" if active_slot(directory) == "A" else "A"
        save_programmed(directory, prog, slot=target)
        swap_active(directory, target)
        self.hot_swap(directory)
        return target

    def _with_crossbar(self, fn):
        """Run ``fn`` under the engine's mesh and crossbar mode, with the
        programmed model's name-keyed artifact table bound for the dynamic
        scope (works at jit trace time — lookups resolve by name, not by
        leaf identity, so any congruent params tree serves).  With a mesh,
        the model's shard_map EP/TP paths engage and their bodies rebind
        rank-local artifact slices."""
        with contextlib.ExitStack() as stack:
            if self.mesh is not None:
                from repro.models.layers import layout_overrides, use_mesh

                stack.enter_context(use_mesh(self.mesh, layout_overrides(self.cfg)))
                stack.enter_context(self.mesh)
            if self.crossbar is not None:
                stack.enter_context(crossbar_mode(self.crossbar))
                if self.crossbar.programmed is not None:
                    stack.enter_context(self.crossbar.programmed.bind())
            return fn()

    # ------------------------------------------------------------------
    def submit(self, prompt, max_new_tokens: int = 16, eos_id: Optional[int] = None) -> int:
        req = Request(next(self._rid), np.asarray(prompt), max_new_tokens, eos_id)
        self.pending.append(req)
        return req.rid

    def _prefill_fn(self, bucket: int):
        if bucket not in self._prefills:
            def fn(params, tokens, cache):
                return self._with_crossbar(
                    lambda: model_lib.prefill(params, self.cfg, tokens, cache)
                )
            self._prefills[bucket] = jax.jit(fn)
        return self._prefills[bucket]

    def _admit(self):
        for slot in range(self.max_batch):
            if self.slots[slot] is not None or not self.pending:
                continue
            req = self.pending.pop(0)
            S = len(req.prompt)
            # Recurrent archs (ssm/hybrid) must not process padding tokens —
            # their state would absorb them — so they prefill exact lengths;
            # attention caches tolerate padding (masked by position), so they
            # use buckets + an idempotent catch-up re-issue of token S-1.
            recurrent = self.cfg.family in ("ssm", "hybrid")
            bucket = S if recurrent else min(_bucket(S), self.max_seq)
            prompt = np.zeros((1, bucket), np.int32)
            prompt[0, :S] = req.prompt[:bucket]
            small_cache = model_lib.init_cache(self.cfg, 1, self.max_seq, dtype=jnp.float32)
            logits, filled = self._prefill_fn(bucket)(self.params, jnp.asarray(prompt), small_cache)
            self.cache = jax.tree.map(
                lambda big, one: big.at[:, slot].set(one[:, 0]), self.cache, filled
            )
            if recurrent:
                tok = int(self._sample(np.asarray(logits, np.float32))[0])
                self.pos[slot] = S
                self.last_tok[slot] = tok
                req.generated.append(tok)
            else:
                self.pos[slot] = S - 1
                self.last_tok[slot] = int(req.prompt[S - 1])
            self.slots[slot] = req

    # ------------------------------------------------------------------
    def _sample(self, logits: np.ndarray) -> np.ndarray:
        if self.temperature <= 0.0:
            return np.argmax(logits, axis=-1).astype(np.int32)
        self.key, sub = jax.random.split(self.key)
        g = jax.random.gumbel(sub, logits.shape)
        return np.asarray(
            jnp.argmax(logits / self.temperature + g, axis=-1), np.int32
        )

    def step(self) -> int:
        """Admit pending requests and advance every occupied slot one token.

        Returns the number of active slots advanced."""
        self._admit()
        active = [i for i in range(self.max_batch) if self.slots[i] is not None]
        if not active:
            return 0
        toks = jnp.asarray(self.last_tok[:, None])
        pos = jnp.asarray(self.pos)
        logits, self.cache = self._decode(self.params, toks, pos, self.cache)
        nxt = self._sample(np.asarray(logits, np.float32))
        for i in active:
            req = self.slots[i]
            self.pos[i] += 1
            tok = int(nxt[i])
            req.generated.append(tok)
            self.last_tok[i] = tok
            if (
                len(req.generated) >= req.max_new_tokens
                or (req.eos_id is not None and tok == req.eos_id)
                or self.pos[i] >= self.max_seq - 1
            ):
                req.done = True
                self.slots[i] = None
        return len(active)

    def run_until_done(self, max_ticks: int = 10_000) -> List[Request]:
        finished: List[Request] = []
        seen: Dict[int, Request] = {}
        for _ in range(max_ticks):
            for s in self.slots:
                if s is not None:
                    seen[s.rid] = s
            if not self.pending and all(s is None for s in self.slots):
                break
            self.step()
        for s in self.slots:
            if s is not None:
                seen[s.rid] = s
        return sorted(seen.values(), key=lambda r: r.rid)
