"""Mixture-of-Experts FFN with expert parallelism.

Design (DESIGN.md §4): experts are sharded over the ``model`` mesh axis.  At
the MoE boundary activations are model-replicated (as after any Megatron
row-parallel matmul), so each model rank routes *locally*, computes its own
experts on a capacity-bounded buffer, and the partial outputs are combined
with one all-reduce over ``model`` — the same collective a dense Megatron
FFN needs, and no all-to-all.  (The all-to-all dispatch alternative is
evaluated in EXPERIMENTS.md §Perf.)

Dispatch is sort-based (argsort over N*k expert assignments) rather than the
GShard one-hot-cumsum, keeping transient memory O(N*k) instead of O(N*E) —
at kimi-k2 scale (384 experts) that is the difference between 2 MB and 50 MB
per layer per device.

Shared experts (deepseek-v2) are dense MLPs applied to every token and use
ordinary tensor parallelism outside this module.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.configs.base import ModelConfig
from repro.models.layers import (
    Init,
    crossbar_linear,
    current_crossbar,
    current_mesh,
    lookup_crossbar_artifact,
    note_crossbar_gap,
    shard,
)


def init_moe(ini: Init, cfg: ModelConfig):
    d, e, f = cfg.d_model, cfg.moe_experts, cfg.moe_d_ff
    ini.param("router", (d, e), ("moe_dm", None), scale=0.02)
    glu = cfg.mlp_kind in ("swiglu", "geglu")
    # up ("wi") and gate ("wg") projections are separate parameters so the
    # F dim can be TP-sharded (expert_tp layout) without the fused-GLU
    # split-vs-shard hazard.
    # expert_tp shards wi/wg on the D contraction ("moe_dm") and wo on the F
    # contraction ("moe_ff") — distinct names so no tensor maps one mesh
    # axis twice.
    ini.param("wi", (e, d, f), ("experts", "moe_dm", None))
    if glu:
        ini.param("wg", (e, d, f), ("experts", "moe_dm", None))
    ini.param("wo", (e, f, d), ("experts", "moe_ff", "embed"))
    if cfg.moe_shared_experts:
        # Under alltoall dispatch the shared expert runs on the
        # sequence-sharded stream with *replicated* weights (they are small),
        # so the MoE layer needs no activation gather at all; under
        # allreduce dispatch it is a standard TP ("mlp"-sharded) MLP.
        shard_ax = None if cfg.moe_dispatch == "alltoall" else "mlp"
        fs = cfg.moe_d_ff * cfg.moe_shared_experts
        ini.param("shared_wi", (d, fs), ("embed", shard_ax))
        if glu:
            ini.param("shared_wg", (d, fs), ("embed", shard_ax))
        ini.param("shared_wo", (fs, d), (shard_ax, "embed"))


def _act(u, g, kind: str):
    if kind == "swiglu":
        return u * jax.nn.silu(g)
    if kind == "geglu":
        return u * jax.nn.gelu(g)
    if kind == "gelu":
        return jax.nn.gelu(u)
    return jnp.square(jax.nn.relu(u))


def _expert_ffn(h: jnp.ndarray, wi, wg, wo, kind: str) -> jnp.ndarray:
    """h: (E, C, D); wi/wg: (E, D, F); wo: (E, F, D).

    Inside ``shard_map`` bodies the weights are rank-local expert shards;
    per-rank artifact sharding rebinds the matching rank-local artifact
    slices by name before this runs, so the crossbar path below serves
    expert-parallel ranks exactly like the single-device path (each
    expert's (D, F) slab is intact on its owner rank — bit-identical).
    """
    if not current_crossbar().enabled:
        u = jnp.einsum("ecd,edf->ecf", h, wi)
        g = jnp.einsum("ecd,edf->ecf", h, wg) if wg is not None else None
        a = _act(u, g, kind)
        return jnp.einsum("ecf,efd->ecd", a, wo)
    return _expert_ffn_crossbar(h, wi, wg, wo, kind)


def _expert_ffn_crossbar(h: jnp.ndarray, wi, wg, wo, kind: str) -> jnp.ndarray:
    """The expert FFN on the crossbar datapath: one scan over experts.

    Each expert's (D, F) / (F, D) projection is an independent weight slab
    and maps onto its own crossbars, so the batched einsum decomposes into
    per-expert ``crossbar_linear`` calls — HLO size stays E-independent via
    ``lax.scan``.  When expert-stacked programmed artifacts are bound for
    this layer (the ``(E, K, N)`` banks ``program_layer`` compiles from 4-D
    ``(L, E, K, N)`` leaves, layer-sliced by the stage scan), the scan
    slices them per expert and rebinds, so every expert serves steady-state
    from its own programmed chip; otherwise the per-call pipeline programs
    each expert slice on the fly, exactly like any other unprogrammed
    projection.
    """
    from repro.device.programmed import bind_artifacts

    arts = {}
    for n, w in (("wi", wi), ("wg", wg), ("wo", wo)):
        if w is None:
            continue
        art = lookup_crossbar_artifact(n, w.shape)  # expert-stacked (E, K, N)
        if art is not None:
            arts[n] = art

    def body(carry, xs):
        he, wie, wge, woe, arte = xs
        with bind_artifacts(arte):
            u = crossbar_linear(he, wie, name="wi")
            g = crossbar_linear(he, wge, name="wg") if wge is not None else None
            a = _act(u, g, kind)
            ye = crossbar_linear(a, woe, name="wo")
        return carry, ye

    _, y = jax.lax.scan(body, 0, (h, wi, wg, wo, arts))
    return y


# ---------------------------------------------------------------------------
# Per-rank artifact plumbing for shard_map bodies
# ---------------------------------------------------------------------------

def _artifact_shard_inputs(entries):
    """Stage this layer's programmed artifacts for ``shard_map`` passing.

    ``entries``: ``(name, weight, weight_pspec)`` per projection the body
    serves.  For every name that resolves a bound artifact (the stage scan
    binds the layer-sliced banks just outside this call), returns parallel
    dicts: ``arrays`` (the artifact's array leaves — a shard_map input
    pytree), ``specs`` (matching in_specs, derived from the *weight's*
    PartitionSpec so artifact shards track weight shards axis-for-axis) and
    ``templates`` (the global artifacts, closed over for their static aux).
    Names with no artifact are simply absent — the body notes the gap
    loudly if a ProgrammedModel is active.
    """
    from repro.device import programmed as prog

    arrays, specs, templates = {}, {}, {}
    for name, w, wspec in entries:
        if w is None:
            continue
        art = lookup_crossbar_artifact(name, w.shape)
        if art is None:
            continue
        arrays[name] = prog.artifact_arrays(art)
        specs[name] = prog.artifact_shard_specs(art, wspec)
        templates[name] = art
    return arrays, specs, templates


def _rebind_rank_artifacts(templates, arrays):
    """Rebuild rank-local artifacts from shard_map-sliced arrays (inside the
    body) keyed by the same call-site names the global binding used."""
    from repro.device import programmed as prog

    return {n: prog.with_arrays(templates[n], arrays[n]) for n in arrays}


def _dispatch_compute(
    xf: jnp.ndarray,  # (N, D) tokens
    top_idx: jnp.ndarray,  # (N, k) global expert ids
    gates: jnp.ndarray,  # (N, k)
    wi: jnp.ndarray,  # (E_loc, D, F)
    wg,  # (E_loc, D, F) or None
    wo: jnp.ndarray,  # (E_loc, F, D)
    lo: jnp.ndarray,  # first global expert id owned locally
    capacity: int,
    mlp_kind: str,
) -> jnp.ndarray:
    """Capacity-bounded dispatch -> expert FFN -> weighted combine.

    All (token, D)-sized gathers/scatters happen in *slot space* (E_loc * C
    rows), never in assignment space (N * k rows) — at kimi-k2 scale that is
    1.2 GB vs 14 GB of transients per layer.
    """
    N, k = top_idx.shape
    E_loc = wi.shape[0]
    n_slots = E_loc * capacity
    flat_e_glob = top_idx.reshape(-1)
    flat_gate = gates.reshape(-1)
    e_loc = flat_e_glob - lo
    is_local = (e_loc >= 0) & (e_loc < E_loc)
    e_key = jnp.where(is_local, e_loc, E_loc)  # non-local -> overflow bucket
    order = jnp.argsort(e_key, stable=True)
    sorted_e = e_key[order]
    counts = jnp.bincount(e_key, length=E_loc + 1)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(N * k, dtype=jnp.int32) - starts[sorted_e].astype(jnp.int32)
    keep = (sorted_e < E_loc) & (pos < capacity)
    slot = jnp.where(keep, sorted_e.astype(jnp.int32) * capacity + pos, n_slots)
    token_of = (order // k).astype(jnp.int32)

    # slot -> source token / gate (index arrays only; O(E*C + N*k) ints)
    tok_slot = jnp.zeros((n_slots + 1,), jnp.int32).at[slot].set(token_of)
    gate_slot = (
        jnp.zeros((n_slots + 1,), flat_gate.dtype)
        .at[slot]
        .set(flat_gate[order] * keep.astype(flat_gate.dtype))
    )
    buf = xf[tok_slot[:n_slots]].reshape(E_loc, capacity, -1)
    out = _expert_ffn(buf, wi, wg, wo, mlp_kind)
    contrib = out.reshape(n_slots, -1) * gate_slot[:n_slots, None].astype(out.dtype)
    y = jnp.zeros_like(xf).at[tok_slot[:n_slots]].add(contrib.astype(xf.dtype))
    return y


def _route(x: jnp.ndarray, router_w: jnp.ndarray, cfg: ModelConfig):
    # the router is a weight-bearing projection like any other: under an
    # enabled CrossbarMode it runs on the crossbar datapath (programmed or
    # per-call), so routing decisions are made from the analog logits the
    # deployed chip would actually produce.  Inside shard_map EP bodies the
    # router weight is replicated and its (rebound) artifact serves whole.
    logits = crossbar_linear(x, router_w.astype(x.dtype), name="router").astype(
        jnp.float32
    )
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.moe_top_k)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    return idx, gates.astype(x.dtype), probs


def _capacity(n_tokens: int, cfg: ModelConfig, n_local_experts: int) -> int:
    c = n_tokens * cfg.moe_top_k / max(1, cfg.moe_experts) * cfg.moe_capacity_factor
    return max(8, int(math.ceil(c / 8) * 8))


def _dispatch_indices(top_idx, gates, n_experts: int, capacity: int):
    """Slot assignment shared by both EP dispatches.

    Returns (tok_slot, gate_slot) with ``n_experts * capacity`` slots;
    overflow assignments drop (capacity semantics, GShard)."""
    N, k = top_idx.shape
    n_slots = n_experts * capacity
    flat_e = top_idx.reshape(-1)
    flat_gate = gates.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=n_experts)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(N * k, dtype=jnp.int32) - starts[sorted_e].astype(jnp.int32)
    keep = pos < capacity
    slot = jnp.where(keep, sorted_e.astype(jnp.int32) * capacity + pos, n_slots)
    token_of = (order // k).astype(jnp.int32)
    tok_slot = jnp.zeros((n_slots + 1,), jnp.int32).at[slot].set(token_of)
    gate_slot = (
        jnp.zeros((n_slots + 1,), flat_gate.dtype)
        .at[slot]
        .set(flat_gate[order] * keep.astype(flat_gate.dtype))
    )
    return tok_slot[:n_slots], gate_slot[:n_slots]


def _moe_alltoall(params, x, cfg: ModelConfig, mesh, batch_axes):
    """GShard-style EP: tokens stay sequence-sharded over ``model``; the
    dispatch all-to-all moves only routed token copies (N_loc * k * D),
    not the full activation — ~8x less traffic than replicated-token EP at
    kimi-k2 scale (EXPERIMENTS.md §Perf)."""
    B, S, D = x.shape
    E = cfg.moe_experts
    n_ranks = int(mesh.shape["model"])
    E_loc = E // n_ranks
    dp = int(np.prod([mesh.shape[a] for a in batch_axes])) if batch_axes else 1
    n_loc = (B // dp if B % dp == 0 else B) * (S // n_ranks)
    cap = _capacity(n_loc, cfg, E_loc)
    x_spec = (
        P(batch_axes, "model", None) if B % dp == 0 else P(None, "model", None)
    )

    wg = params.get("wg")
    e_spec = P("model", None, None)
    # per-rank artifact sharding: the expert banks' artifacts slice along E
    # with the weights (router stays replicated, its artifact whole), so the
    # body serves programmed from rank-local chips instead of going digital
    from repro.device.programmed import bind_artifacts

    arts, aspecs, tmpl = _artifact_shard_inputs((
        ("router", params["router"], P(None, None)),
        ("wi", params["wi"], e_spec),
        ("wg", wg, e_spec),
        ("wo", params["wo"], e_spec),
    ))

    def body(xl, rw, wi_l, wg_l, wo_l, arts_l):
        Bl, Sl, _ = xl.shape
        xf = xl.reshape(-1, D)
        with bind_artifacts(_rebind_rank_artifacts(tmpl, arts_l)):
            idx, gates, _ = _route(xl, rw, cfg)
            tok_slot, gate_slot = _dispatch_indices(
                idx.reshape(-1, cfg.moe_top_k), gates.reshape(-1, cfg.moe_top_k), E, cap
            )
            buf = xf[tok_slot]  # (E * cap, D): rows for every (expert, slot)
            # dispatch: slice per destination rank, exchange
            buf = buf.reshape(n_ranks, E_loc * cap, D)
            buf = jax.lax.all_to_all(buf, "model", split_axis=0, concat_axis=0, tiled=True)
            # now (n_ranks * E_loc * cap, D) = this rank's experts, all sources
            h = buf.reshape(n_ranks, E_loc, cap, D).transpose(1, 0, 2, 3)
            h = h.reshape(E_loc, n_ranks * cap, D)
            out = _expert_ffn(h, wi_l, wg_l, wo_l, cfg.mlp_kind)
        out = out.reshape(E_loc, n_ranks, cap, D).transpose(1, 0, 2, 3)
        out = out.reshape(n_ranks, E_loc * cap, D)
        out = jax.lax.all_to_all(out, "model", split_axis=0, concat_axis=0, tiled=True)
        contrib = out.reshape(E * cap, D) * gate_slot[:, None].astype(out.dtype)
        y = jnp.zeros_like(xf).at[tok_slot].add(contrib.astype(xf.dtype))
        return y.reshape(Bl, Sl, D)

    return shard_map(
        body,
        mesh=mesh,
        in_specs=(
            x_spec, P(None, None), e_spec, None if wg is None else e_spec, e_spec,
            aspecs,
        ),
        out_specs=x_spec,
        check_rep=False,
    )(x, params["router"], params["wi"], wg, params["wo"], arts)


def _moe_expert_tp(params, x, cfg: ModelConfig, mesh, batch_axes):
    """Weights-stationary serving EP (layout="expert_tp"): experts sharded
    over "data", expert FFN contraction dims TP-sharded over "model" — the
    paper's in-situ principle at cluster scale: no weight ever moves; only
    the (tiny, at decode) routed activations cross links, via one all-to-all
    over "data" and psum-scatters over "model".  See EXPERIMENTS.md §Perf
    (deepseek-v2 decode hillclimb)."""
    B, S, D = x.shape
    E = cfg.moe_experts
    n_dr = int(mesh.shape["data"])
    n_mr = int(mesh.shape["model"])
    E_dp = E // n_dr
    dp = int(np.prod([mesh.shape[a] for a in batch_axes])) if batch_axes else 1
    n_loc = (B // dp if B % dp == 0 else B) * S
    cap = _capacity(n_loc, cfg, E_dp)
    # tokens: batch over data, D sharded over model (activations tiny)
    x_spec = P(batch_axes, None, "model") if B % dp == 0 else P(None, None, "model")

    wg = params.get("wg")
    wspec_i = P("data", "model", None)
    wspec_o = P("data", "model", None)
    # per-rank artifact sharding, TP flavor: every projection here contracts
    # over a mesh-sharded dim, so each rank holds *rows of the global chip*
    # (experts additionally sharded over "data").  Rank-local artifacts
    # serve partial sums — physically, row-split crossbar tiles whose
    # results the existing psum/psum_scatter collectives accumulate
    # digitally, exactly the paper's inter-tile reduction at cluster scale.
    from repro.device.programmed import programmed_linear as _plin

    arts, aspecs, tmpl = _artifact_shard_inputs((
        ("router", params["router"], P("model", None)),
        ("wi", params["wi"], wspec_i),
        ("wg", wg, wspec_i),
        ("wo", params["wo"], wspec_o),
    ))

    def body(xl, rw_l, wi_l, wg_l, wo_l, arts_l):
        # xl: (B_loc, S, D/mr); rw_l: (D/mr, E); wi_l/wg_l: (E_dp, D/mr, F);
        # wo_l: (E_dp, F/mr, D)
        from repro.device import programmed as _prog

        local = _rebind_rank_artifacts(tmpl, arts_l)
        for n in local:
            # the TP partial path serves below via programmed_linear directly
            # (crossbar_linear cannot express the colsum override), so record
            # consumption here for the structural name-set check
            _prog.record_artifact_consumed(_prog.scoped_name(n))

        def _partial(xe, we, art):
            # K-sharded programmed partial: the artifact's sliced rows are
            # the rows the global chip programmed (quantization is
            # elementwise in w); the offset correction must use the *local*
            # rows' column sums — sum_r(shift_r * colsum_r) reconstitutes
            # the full correction exactly under the caller's all-reduce
            return _plin(xe, art, colsum=jnp.sum(we.astype(jnp.float32), axis=0))

        def _bank(h, w_l, name):
            # (E_dp, C, K_loc) @ (E_dp, K_loc, N) partial sums, per-expert
            # scan so HLO size stays E-independent; collectives hoisted out
            art = local.get(name)
            if art is None:
                note_crossbar_gap(name)
                return jnp.einsum("ecd,edf->ecf", h, w_l)

            def f(c, xs_):
                he, we, ae = xs_
                return c, _partial(he, we, ae).astype(he.dtype)

            _, u = jax.lax.scan(f, 0, (h, w_l, art))
            return u

        Bl, Sl, Dl = xl.shape
        xf = xl.reshape(-1, Dl)
        if "router" in local:
            part = _partial(xf, rw_l.astype(xf.dtype), local["router"])
        else:
            note_crossbar_gap("router")
            part = (xf @ rw_l.astype(xf.dtype)).astype(jnp.float32)
        logits = jax.lax.psum(part.astype(jnp.float32), "model")
        probs = jax.nn.softmax(logits, axis=-1)
        gates, idx = jax.lax.top_k(probs, cfg.moe_top_k)
        gates = (gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)).astype(xf.dtype)
        tok_slot, gate_slot = _dispatch_indices(idx, gates, E, cap)
        buf = xf[tok_slot]  # (E * cap, D/mr)
        buf = buf.reshape(n_dr, E_dp * cap, Dl)
        buf = jax.lax.all_to_all(buf, "data", split_axis=0, concat_axis=0, tiled=True)
        h = buf.reshape(n_dr, E_dp, cap, Dl).transpose(1, 0, 2, 3).reshape(E_dp, n_dr * cap, Dl)
        # expert matmuls: contraction over the model-sharded D, then psum-
        # scatter onto the model-sharded F — weights never move
        u = _bank(h, wi_l, "wi")
        u = jax.lax.psum_scatter(u, "model", scatter_dimension=2, tiled=True)
        if wg_l is not None:
            g = _bank(h, wg_l, "wg")
            g = jax.lax.psum_scatter(g, "model", scatter_dimension=2, tiled=True)
        else:
            g = None
        a = _act(u, g, cfg.mlp_kind)  # (E_dp, slots, F/mr)
        out = _bank(a, wo_l, "wo")  # partial over F -> full D
        out = jax.lax.psum_scatter(out, "model", scatter_dimension=2, tiled=True)
        # back to sources
        out = out.reshape(E_dp, n_dr, cap, Dl).transpose(1, 0, 2, 3).reshape(n_dr, E_dp * cap, Dl)
        out = jax.lax.all_to_all(out, "data", split_axis=0, concat_axis=0, tiled=True)
        contrib = out.reshape(E * cap, Dl) * gate_slot[:, None].astype(out.dtype)
        y = jnp.zeros_like(xf).at[tok_slot].add(contrib.astype(xf.dtype))
        return y.reshape(Bl, Sl, Dl)

    y = shard_map(
        body,
        mesh=mesh,
        in_specs=(
            x_spec,
            P("model", None),
            wspec_i,
            None if wg is None else wspec_i,
            wspec_o,
            aspecs,
        ),
        out_specs=x_spec,
        check_rep=False,
    )(x, params["router"], params["wi"], wg, params["wo"], arts)
    return y


def moe_ffn(params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """x: (B, S, D) -> (B, S, D).  Routed experts + optional shared expert."""
    B, S, D = x.shape
    mesh = current_mesh()
    E = cfg.moe_experts
    model_size = int(mesh.shape.get("model", 1)) if mesh is not None else 1
    if mesh is not None:
        from repro.models.layers import _resolve_axis

        if _resolve_axis("experts", mesh) is None and cfg.layout != "expert_tp":
            model_size = 1  # layout override: no EP

    if (
        cfg.layout == "expert_tp"
        and mesh is not None
        and "data" in mesh.axis_names
        and model_size > 1
        and E % int(mesh.shape["data"]) == 0
        and D % model_size == 0
        and cfg.moe_d_ff % model_size == 0
    ):
        batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        y = _moe_expert_tp(
            params, shard(x, "batch", None, "moe_dm"), cfg, mesh, batch_axes
        )
        y = shard(y, "batch", None, "moe_dm")
    elif (
        cfg.moe_dispatch == "alltoall"
        and mesh is not None
        and model_size > 1
        and E % model_size == 0
        and S % model_size == 0
    ):
        batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        y = _moe_alltoall(params, shard(x, "batch", "act_seq", None), cfg, mesh, batch_axes)
    elif mesh is None or model_size == 1 or E % model_size != 0:
        idx, gates, _ = _route(x, params["router"], cfg)
        cap = _capacity(B * S, cfg, E)
        y = _dispatch_compute(
            x.reshape(-1, D),
            idx.reshape(-1, cfg.moe_top_k),
            gates.reshape(-1, cfg.moe_top_k),
            params["wi"],
            params.get("wg"),
            params["wo"],
            jnp.int32(0),
            cap,
            cfg.mlp_kind,
        ).reshape(B, S, D)
    else:
        batch_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        dp = int(np.prod([mesh.shape[a] for a in batch_axes]))
        n_local = (B * S) // dp if B % dp == 0 else B * S
        cap = _capacity(n_local, cfg, E // model_size)
        x_spec = P(batch_axes, None, None) if B % dp == 0 else P(None, None, None)

        wg = params.get("wg")
        e_spec = P("model", None, None)
        # per-rank artifact sharding: each expert bank's artifact slices
        # along E exactly like its weight, so every rank serves its local
        # experts from the programmed chip — bit-identical to single-device
        # (each expert's (D, F) slab is intact on its owner rank)
        from repro.device.programmed import bind_artifacts

        arts, aspecs, tmpl = _artifact_shard_inputs((
            ("router", params["router"], P(None, None)),
            ("wi", params["wi"], e_spec),
            ("wg", wg, e_spec),
            ("wo", params["wo"], e_spec),
        ))

        def body(xl, rw, wi_l, wg_l, wo_l, arts_l):
            Bl, Sl, _ = xl.shape
            with bind_artifacts(_rebind_rank_artifacts(tmpl, arts_l)):
                idx, gates, _ = _route(xl, rw, cfg)
                rank = jax.lax.axis_index("model")
                lo = rank.astype(jnp.int32) * (E // model_size)
                y = _dispatch_compute(
                    xl.reshape(-1, D),
                    idx.reshape(-1, cfg.moe_top_k),
                    gates.reshape(-1, cfg.moe_top_k),
                    wi_l,
                    wg_l,
                    wo_l,
                    lo,
                    cap,
                    cfg.mlp_kind,
                ).reshape(Bl, Sl, D)
            return jax.lax.psum(y, "model")

        y = shard_map(
            body,
            mesh=mesh,
            in_specs=(
                x_spec, P(None, None), e_spec, None if wg is None else e_spec,
                e_spec, aspecs,
            ),
            out_specs=x_spec,
            check_rep=False,
        )(x, params["router"], params["wi"], wg, params["wo"], arts)

    if cfg.moe_shared_experts:
        if cfg.moe_dispatch == "alltoall":
            # replicated weights, sequence-sharded tokens: zero comm
            xs = shard(x, "batch", "act_seq", None)
        else:
            xs = x
        u = crossbar_linear(xs, params["shared_wi"], name="shared_wi")
        g = (
            crossbar_linear(xs, params["shared_wg"], name="shared_wg")
            if "shared_wg" in params
            else None
        )
        if cfg.moe_dispatch != "alltoall":
            u = shard(u, "batch", None, "mlp")
            g = shard(g, "batch", None, "mlp") if g is not None else None
        h = _act(u, g, cfg.mlp_kind)
        y = y + crossbar_linear(h, params["shared_wo"], name="shared_wo")
    if cfg.moe_dispatch == "alltoall":
        return shard(y, "batch", "act_seq", None)
    return shard(y, "batch", None, None)
