"""Attention variants: GQA (dense + q-chunked), sliding-window local,
softcapped (gemma2), and absorbed multi-head latent attention (MLA,
deepseek-v2) — with KV caches for prefill/decode serving.

Memory strategy: training/prefill attention scans over query chunks
(``Q_CHUNK``), bounding the live score tensor to (B, qc, H, S) regardless of
sequence length; GQA grouping is kept inside the einsum so KV heads are
never materialized repeated.  MLA uses the *absorbed* form — scores are
computed directly against the latent cache, so per-head K/V are never
materialized (this is what makes deepseek-v2 prefill_32k fit).

Cache sharding: ``shard_cache`` shards the batch dim over ("pod","data")
when it divides, otherwise (long_500k, batch=1) shards the cache *sequence*
dim — decode attention then reduces over a sharded axis and XLA inserts the
softmax-stable all-reduces.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import (
    Init,
    apply_rope,
    crossbar_linear,
    current_mesh,
    pspec,
    shard,
    softcap,
)

Q_CHUNK = 256  # bounds live scores at (B, 256, H, S); see EXPERIMENTS.md §Perf
NEG_INF = -2.3819763e38  # most-negative bf16-representable-ish


def shard_cache(x: jnp.ndarray) -> jnp.ndarray:
    """Shard (B, S, ...) caches: batch over ("pod","data") when divisible,
    else sequence (long-context SP)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    from repro.models.layers import _resolve_axis, dividing_entry

    resolved = _resolve_axis("batch", mesh)
    dp_axes = () if resolved is None else (
        resolved if isinstance(resolved, tuple) else (resolved,)
    )
    dp = int(np.prod([mesh.shape[a] for a in dp_axes])) if dp_axes else 1
    rest = [None] * (x.ndim - 2)
    b_entry = dividing_entry(x.shape[0], dp_axes, mesh) if dp > 1 and x.shape[0] > 1 else None
    if b_entry is not None:
        spec = P(b_entry, None, *rest)
    elif dp > 1 and x.shape[1] % dp == 0:
        spec = P(None, dp_axes, *rest)
    else:
        spec = P(None, None, *rest)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def init_attention(ini: Init, cfg: ModelConfig):
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    if cfg.kv_lora_rank:
        ini.param("wq", (d, h * (dh + cfg.qk_rope_dim)), ("embed", "heads"))
        ini.param("w_kv_down", (d, cfg.kv_lora_rank + cfg.qk_rope_dim), ("embed", None))
        ini.param("w_uk", (cfg.kv_lora_rank, h, dh), (None, "heads", None))
        ini.param("w_uv", (cfg.kv_lora_rank, h, dh), (None, "heads", None))
        ini.param("wo", (h * dh, d), ("heads", "embed"))
    else:
        ini.param("wq", (d, h * dh), ("embed", "heads"))
        ini.param("wk", (d, kv * dh), ("embed", "kv_heads"))
        ini.param("wv", (d, kv * dh), ("embed", "kv_heads"))
        ini.param("wo", (h * dh, d), ("heads", "embed"))


# ---------------------------------------------------------------------------
# GQA core
# ---------------------------------------------------------------------------

def _gqa_scores(q, k):
    """q: (B, qc, G, R, dh); k: (B, S, G, dh) -> (B, qc, G, R, S)."""
    return jnp.einsum("bqgrd,bsgd->bqgrs", q, k, preferred_element_type=jnp.float32)


def _gqa_out(p, v):
    """p: (B, qc, G, R, S); v: (B, S, G, dh) -> (B, qc, G, R, dh)."""
    return jnp.einsum("bqgrs,bsgd->bqgrd", p, v.astype(p.dtype))


def _mask(pos_q, pos_k, window: int):
    m = pos_k[None, :] <= pos_q[:, None]
    if window:
        m &= pos_k[None, :] > (pos_q[:, None] - window)
    return m


def gqa_attention(
    q: jnp.ndarray,  # (B, S, H, dh)
    k: jnp.ndarray,  # (B, Sk, KV, dh)
    v: jnp.ndarray,
    *,
    scale: float,
    window: int = 0,
    attn_cap: float = 0.0,
    q_offset: int = 0,
    chunk: int = Q_CHUNK,
) -> jnp.ndarray:
    B, S, H, dh = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G, R = KV, H // KV
    qg = q.reshape(B, S, G, R, dh)
    pos_k = jnp.arange(Sk)

    def block(q_blk, start):
        pos_q = q_offset + start + jnp.arange(q_blk.shape[1])
        s = _gqa_scores(q_blk, k) * scale
        if attn_cap:
            s = softcap(s, attn_cap)
        m = _mask(pos_q, pos_k, window)
        s = jnp.where(m[None, :, None, None, :], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        return _gqa_out(p, v)

    if S <= chunk:
        out = block(qg, 0)
    else:
        nc = S // chunk
        assert S % chunk == 0, (S, chunk)
        qc = qg.reshape(B, nc, chunk, G, R, dh).transpose(1, 0, 2, 3, 4, 5)

        # checkpoint each chunk: without this the scan's backward saves every
        # chunk's (B, qc, H, S) score tensor simultaneously (flash-attention
        # memory discipline, rematerialized per chunk)
        def body(_, inp):
            q_blk, idx = inp
            return None, jax.checkpoint(block)(q_blk, idx * chunk)

        _, outs = jax.lax.scan(body, None, (qc, jnp.arange(nc)))
        out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, G, R, dh)
    return out.reshape(B, S, H, dh).astype(q.dtype)


def decode_attention(q, k, v, pos, *, scale, window=0, attn_cap=0.0):
    """Single-position decode: q (B, 1, H, dh) against full cache (B, S, KV, dh).

    ``pos`` is the index of the newest token — scalar, or (B,) for
    continuous batching (each slot at its own position); cache entries
    beyond a slot's position are masked.
    """
    B, _, H, dh = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G, R = KV, H // KV
    qg = q.reshape(B, 1, G, R, dh)
    s = _gqa_scores(qg, k) * scale  # (B,1,G,R,S)
    if attn_cap:
        s = softcap(s, attn_cap)
    pos_k = jnp.arange(Sk)
    pos_b = jnp.broadcast_to(jnp.asarray(pos), (B,))  # scalar or per-slot
    m = pos_k[None, :] <= pos_b[:, None]
    if window:
        m &= pos_k[None, :] > (pos_b[:, None] - window)
    s = jnp.where(m[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = _gqa_out(p, v)
    return out.reshape(B, 1, H, dh).astype(q.dtype)


def _cache_write(cache: jnp.ndarray, new: jnp.ndarray, pos) -> jnp.ndarray:
    """Write one decode step into the cache at ``pos`` (scalar, or (B,) for
    per-slot positions in continuous batching)."""
    new = new.astype(cache.dtype)
    pos = jnp.asarray(pos)
    if pos.ndim == 0:
        start = (0, pos) + (0,) * (cache.ndim - 2)
        return jax.lax.dynamic_update_slice(cache, new, start)
    b = jnp.arange(cache.shape[0])
    return cache.at[b, pos].set(new[:, 0])


# ---------------------------------------------------------------------------
# Attention block (pre-norm handled by caller); returns (y, new_cache)
# ---------------------------------------------------------------------------

def attention_block(
    params,
    x: jnp.ndarray,  # (B, S, D)
    cfg: ModelConfig,
    kind: str,  # attn | attn_local | attn_global
    positions: jnp.ndarray,  # (S,) absolute positions
    cache: Optional[Dict[str, jnp.ndarray]] = None,
    decode_pos: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    if cfg.kv_lora_rank:
        return _mla_block(params, x, cfg, positions, cache, decode_pos)
    B, S, D = x.shape
    H, KV, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    window = cfg.sliding_window if kind == "attn_local" else 0
    scale = cfg.attn_scale if cfg.attn_scale else dh**-0.5

    q = crossbar_linear(x, params["wq"], name="wq").reshape(B, S, H, dh)
    k = crossbar_linear(x, params["wk"], name="wk").reshape(B, S, KV, dh)
    v = crossbar_linear(x, params["wv"], name="wv").reshape(B, S, KV, dh)
    q = shard(q, "batch", None, "heads", None)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    new_cache = None
    if cache is None:
        out = gqa_attention(q, k, v, scale=scale, window=window, attn_cap=cfg.attn_softcap)
    elif decode_pos is None:
        # prefill: attend within the prompt and return the filled cache
        out = gqa_attention(q, k, v, scale=scale, window=window, attn_cap=cfg.attn_softcap)
        kc = shard_cache(jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0)))
        vc = shard_cache(jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0)))
        new_cache = {"k": kc, "v": vc}
    else:
        kc = shard_cache(_cache_write(cache["k"], k, decode_pos))
        vc = shard_cache(_cache_write(cache["v"], v, decode_pos))
        out = decode_attention(
            q, kc, vc, decode_pos, scale=scale, window=window, attn_cap=cfg.attn_softcap
        )
        new_cache = {"k": kc, "v": vc}

    y = crossbar_linear(out.reshape(B, S, H * dh), params["wo"], name="wo")
    return shard(y, "batch", None, None), new_cache


def init_attention_cache(cfg: ModelConfig, batch: int, seq: int, dtype=jnp.bfloat16):
    if cfg.kv_lora_rank:
        return {
            "latent": jnp.zeros((batch, seq, cfg.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, seq, cfg.qk_rope_dim), dtype),
        }
    return {
        "k": jnp.zeros((batch, seq, cfg.n_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, seq, cfg.n_kv_heads, cfg.head_dim), dtype),
    }


# ---------------------------------------------------------------------------
# MLA (deepseek-v2) — absorbed form
# ---------------------------------------------------------------------------

def _mla_block(params, x, cfg: ModelConfig, positions, cache, decode_pos):
    B, S, D = x.shape
    H, dh, rope_d, lora = cfg.n_heads, cfg.head_dim, cfg.qk_rope_dim, cfg.kv_lora_rank
    scale = (dh + rope_d) ** -0.5

    q = crossbar_linear(x, params["wq"], name="wq").reshape(B, S, H, dh + rope_d)
    q = shard(q, "batch", None, "heads", None)
    q_nope, q_rope = q[..., :dh], q[..., dh:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kvd = crossbar_linear(x, params["w_kv_down"], name="w_kv_down")  # (B, S, lora + rope)
    latent, k_rope = kvd[..., :lora], kvd[..., lora:]
    k_rope = apply_rope(k_rope[..., None, :], positions, cfg.rope_theta)[..., 0, :]

    if cache is not None:
        lc_dtype = cache["latent"].dtype
        if decode_pos is None:
            latent_c = jax.lax.dynamic_update_slice(
                cache["latent"], latent.astype(lc_dtype), (0, 0, 0)
            )
            rope_c = jax.lax.dynamic_update_slice(
                cache["k_rope"], k_rope.astype(lc_dtype), (0, 0, 0)
            )
        else:
            latent_c = _cache_write(cache["latent"], latent, decode_pos)
            rope_c = _cache_write(cache["k_rope"], k_rope, decode_pos)
        latent_c = shard_cache(latent_c)
        rope_c = shard_cache(rope_c)
        new_cache = {"latent": latent_c, "k_rope": rope_c}
        latent_k, rope_k = latent_c, rope_c
        Sk = latent_c.shape[1]
    else:
        new_cache = None
        latent_k, rope_k = latent, k_rope
        Sk = S

    # Absorb W_uk into the query: q_abs (B, S, H, lora)
    q_abs = jnp.einsum("bshd,lhd->bshl", q_nope, params["w_uk"])

    pos_k = jnp.arange(Sk)

    def block(q_abs_blk, q_rope_blk, start, single_pos=None):
        # bf16 operands + f32 accumulation: no f32 copy of the latent cache
        # (halves decode cache-read bytes; MXU-native on TPU)
        s = jnp.einsum(
            "bqhl,bsl->bqhs", q_abs_blk.astype(latent_k.dtype), latent_k,
            preferred_element_type=jnp.float32,
        )
        s = s + jnp.einsum(
            "bqhr,bsr->bqhs", q_rope_blk.astype(rope_k.dtype), rope_k,
            preferred_element_type=jnp.float32,
        )
        s = s * scale
        if single_pos is None:
            pos_q = start + jnp.arange(q_abs_blk.shape[1])
            m = pos_k[None, :] <= pos_q[:, None]
            s = jnp.where(m[None, :, None, :], s, NEG_INF)
        else:
            pos_b = jnp.broadcast_to(jnp.asarray(single_pos), (B,))
            m = pos_k[None, :] <= pos_b[:, None]
            s = jnp.where(m[:, None, None, :], s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1)
        # attend over the latent, then up-project per head
        ctx = jnp.einsum(
            "bqhs,bsl->bqhl", p.astype(latent_k.dtype), latent_k,
            preferred_element_type=jnp.float32,
        )
        return jnp.einsum("bqhl,lhd->bqhd", ctx, params["w_uv"].astype(jnp.float32))

    if decode_pos is not None:
        out = block(q_abs, q_rope, 0, single_pos=decode_pos)
    elif S <= Q_CHUNK:
        out = block(q_abs, q_rope, 0)
    else:
        nc = S // Q_CHUNK
        assert S % Q_CHUNK == 0
        qa = q_abs.reshape(B, nc, Q_CHUNK, H, lora).transpose(1, 0, 2, 3, 4)
        qr = q_rope.reshape(B, nc, Q_CHUNK, H, rope_d).transpose(1, 0, 2, 3, 4)

        def body(_, inp):
            qa_b, qr_b, idx = inp
            return None, jax.checkpoint(block)(qa_b, qr_b, idx * Q_CHUNK)

        _, outs = jax.lax.scan(body, None, (qa, qr, jnp.arange(nc)))
        out = outs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, dh)

    y = crossbar_linear(out.reshape(B, S, H * dh).astype(x.dtype), params["wo"], name="wo")
    return shard(y, "batch", None, None), new_cache
