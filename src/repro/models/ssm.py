"""Mamba (S6) block for the jamba hybrid — chunked selective scan.

Training runs a chunked scan: an outer ``lax.scan`` over sequence chunks
carries the (B, d_inner, d_state) state; within a chunk the linear
recurrence h_t = a_t * h_{t-1} + b_t is evaluated with
``lax.associative_scan``.  The (B, c, d_inner, d_state) intra-chunk tensor is
the live buffer — d_inner is sharded over the ``model`` axis so it stays
per-device small (DESIGN.md §4).  Decode is the O(1) recurrent step.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Init, shard

CHUNK = 256


def d_inner_of(cfg: ModelConfig) -> int:
    return cfg.mamba_d_inner or 2 * cfg.d_model


def dt_rank_of(cfg: ModelConfig) -> int:
    return cfg.mamba_dt_rank or max(1, math.ceil(cfg.d_model / 16))


def init_mamba(ini: Init, cfg: ModelConfig):
    d = cfg.d_model
    din, n, dtr = d_inner_of(cfg), cfg.mamba_d_state, dt_rank_of(cfg)
    ini.param("in_proj", (d, 2 * din), ("embed", "d_inner"))
    ini.param("conv_w", (cfg.mamba_d_conv, din), (None, "d_inner"), scale=0.5)
    ini.param("conv_b", (din,), ("d_inner",), init="zeros")
    ini.param("x_proj", (din, dtr + 2 * n), ("d_inner", None))
    ini.param("dt_proj", (dtr, din), (None, "d_inner"))
    ini.param("dt_bias", (din,), ("d_inner",), init="zeros")
    ini.param("A_log", (din, n), ("d_inner", None), init="zeros")
    ini.param("D_skip", (din,), ("d_inner",), init="ones")
    ini.param("out_proj", (din, d), ("d_inner", "embed"))


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv via shifted adds. x: (B, S, din); w: (K, din)."""
    K = w.shape[0]
    y = x * w[K - 1]
    for j in range(1, K):
        shifted = jnp.pad(x, ((0, 0), (j, 0), (0, 0)))[:, : x.shape[1]]
        y = y + shifted * w[K - 1 - j]
    return y + b


def _ssm_chunked(a: jnp.ndarray, bx: jnp.ndarray, h0: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Linear recurrence over S via chunked associative scan.

    a, bx: (B, S, din, n); h0: (B, din, n).  Returns (h_all, h_last).
    """
    B, S, din, n = a.shape
    c = min(CHUNK, S)
    nc = S // c
    assert S % c == 0, (S, c)
    a_c = a.reshape(B, nc, c, din, n).transpose(1, 0, 2, 3, 4)
    b_c = bx.reshape(B, nc, c, din, n).transpose(1, 0, 2, 3, 4)

    def chunk_step(h, inp):
        ac, bc = inp  # (B, c, din, n)

        def combine(x, y):
            a1, b1 = x
            a2, b2 = y
            return a1 * a2, a2 * b1 + b2

        a_cum, b_cum = jax.lax.associative_scan(combine, (ac, bc), axis=1)
        h_all = b_cum + a_cum * h[:, None]  # (B, c, din, n)
        return h_all[:, -1], h_all

    # checkpoint per chunk: otherwise the scan backward keeps every chunk's
    # (B, c, din, n) cumulative tensors live at once
    h_last, h_chunks = jax.lax.scan(jax.checkpoint(chunk_step), h0, (a_c, b_c))
    h_all = h_chunks.transpose(1, 0, 2, 3, 4).reshape(B, S, din, n)
    return h_all, h_last


def mamba_block(
    params,
    x: jnp.ndarray,  # (B, S, D)
    cfg: ModelConfig,
    cache: Optional[Dict[str, jnp.ndarray]] = None,
    decode: bool = False,
) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    B, S, D = x.shape
    din, n, dtr = d_inner_of(cfg), cfg.mamba_d_state, dt_rank_of(cfg)
    K = cfg.mamba_d_conv

    xz = x @ params["in_proj"]
    xz = shard(xz, "batch", None, "d_inner")
    x_in, z = jnp.split(xz, 2, axis=-1)

    new_cache = None
    if decode:
        assert cache is not None and S == 1
        conv_state = cache["conv"]  # (B, K-1, din)
        window = jnp.concatenate([conv_state, x_in], axis=1)  # (B, K, din)
        xc = jnp.einsum("bkd,kd->bd", window, params["conv_w"])[:, None] + params["conv_b"]
        new_conv = window[:, 1:]
    else:
        xc = _causal_conv(x_in, params["conv_w"], params["conv_b"])
        new_conv = None
        if cache is not None:
            pad = jnp.zeros((B, max(0, K - 1 - S), din), x_in.dtype)
            new_conv = jnp.concatenate([pad, x_in[:, -(K - 1):]], axis=1)
    xc = jax.nn.silu(xc)

    x_db = xc @ params["x_proj"]
    dt, B_ssm, C_ssm = jnp.split(x_db, [dtr, dtr + n], axis=-1)
    dt = jax.nn.softplus(dt @ params["dt_proj"] + params["dt_bias"])  # (B,S,din)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))  # (din, n)
    a = jnp.exp(dt.astype(jnp.float32)[..., None] * A)  # (B,S,din,n)
    bx = (
        dt.astype(jnp.float32)[..., None]
        * B_ssm.astype(jnp.float32)[:, :, None, :]
        * xc.astype(jnp.float32)[..., None]
    )

    if decode:
        h = a[:, 0] * cache["h"] + bx[:, 0]  # (B, din, n)
        y = jnp.einsum("bdn,bn->bd", h, C_ssm.astype(jnp.float32)[:, 0])[:, None]
        new_cache = {"h": h, "conv": new_conv}
    else:
        h0 = cache["h"] if cache is not None else jnp.zeros((B, din, n), jnp.float32)
        h_all, h_last = _ssm_chunked(a, bx, h0)
        y = jnp.einsum("bsdn,bsn->bsd", h_all, C_ssm.astype(jnp.float32))
        if cache is not None:
            new_cache = {"h": h_last, "conv": new_conv}

    y = (y + params["D_skip"].astype(jnp.float32) * xc.astype(jnp.float32)).astype(x.dtype)
    y = y * jax.nn.silu(z)
    out = y @ params["out_proj"]
    return shard(out, "batch", None, None), new_cache


def init_mamba_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    din, n, K = d_inner_of(cfg), cfg.mamba_d_state, cfg.mamba_d_conv
    return {
        "h": jnp.zeros((batch, din, n), jnp.float32),
        "conv": jnp.zeros((batch, K - 1, din), dtype),
    }
