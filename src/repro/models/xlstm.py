"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, chunkwise-parallel)
and sLSTM (scalar memory, strictly recurrent — that non-parallelizability is
the architecture's documented trade-off and shows up honestly as a sequential
scan in the HLO).

mLSTM recurrence (per head):
    C_t = f_t C_{t-1} + i_t k_t v_t^T      (dk x dv matrix memory)
    n_t = f_t n_{t-1} + i_t k_t
    h_t = (C_t^T q_t) / max(|n_t . q_t|, 1)

Training uses the chunkwise form: within a chunk, contributions are computed
attention-style with a causal decay matrix D_ts = exp(L_t - L_s + log i_s)
(L = cumulative log f); across chunks the (B, H, dk, dv) state is carried by
a ``lax.scan``.  Gates are computed in float32 with the input gate clipped
for stability (the paper's m_t stabilizer is folded into the clip; see
DESIGN.md).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import Init, shard

CHUNK = 256
IGATE_CLIP = 5.0


def d_inner_of(cfg: ModelConfig) -> int:
    return cfg.xlstm_d_inner or 2 * cfg.d_model


def init_mlstm(ini: Init, cfg: ModelConfig):
    d, din, H = cfg.d_model, d_inner_of(cfg), cfg.n_heads
    ini.param("wqkv", (d, 3 * din), ("embed", "d_inner"))
    ini.param("w_gates", (d, 2 * H), ("embed", None), scale=0.02)
    ini.param("w_ogate", (d, din), ("embed", "d_inner"))
    ini.param("out_proj", (din, d), ("d_inner", "embed"))


def init_slstm(ini: Init, cfg: ModelConfig):
    d, din, H = cfg.d_model, d_inner_of(cfg), cfg.n_heads
    dh = din // H
    ini.param("w_in", (d, 4 * din), ("embed", "d_inner"))  # z, i, f, o
    ini.param("r_z", (H, dh, dh), (None, None, None), scale=dh**-0.5)
    ini.param("r_i", (H, dh, dh), (None, None, None), scale=dh**-0.5)
    ini.param("r_f", (H, dh, dh), (None, None, None), scale=dh**-0.5)
    ini.param("r_o", (H, dh, dh), (None, None, None), scale=dh**-0.5)
    ini.param("out_proj", (din, d), ("d_inner", "embed"))


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def _mlstm_chunk(q, k, v, log_f, log_i, C0, n0):
    """One chunk. q,k,v: (B, c, H, dh); log_f, log_i: (B, c, H) f32.

    Returns (y, C1, n1).
    """
    B, c, H, dh = q.shape
    L = jnp.cumsum(log_f, axis=1)  # (B, c, H) cumulative log forget from chunk start
    # inter-chunk: state contribution decayed by exp(L_t)
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32) * (dh**-0.5)
    vf = v.astype(jnp.float32)
    decay_t = jnp.exp(L)  # (B, c, H)
    y_inter = jnp.einsum("bchd,bhde->bche", qf, C0) * decay_t[..., None]
    n_inter = jnp.einsum("bchd,bhd->bch", qf, n0) * decay_t

    # intra-chunk causal decay matrix: D_ts = exp(L_t - L_s + log_i_s), s <= t
    diff = L[:, :, None, :] - L[:, None, :, :] + log_i[:, None, :, :]  # (B,t,s,H)
    mask = jnp.tril(jnp.ones((c, c), bool))
    D = jnp.where(mask[None, :, :, None], jnp.exp(diff), 0.0)
    scores = jnp.einsum("bthd,bshd->btsh", qf, kf) * D
    y_intra = jnp.einsum("btsh,bshe->bthe", scores, vf)
    # normalizer accumulates decay-weighted keys (no q): n_t = sum_s D_ts k_s
    n_intra = jnp.einsum("btsh,bshd->bthd", D, kf)

    # denominator: max(|n_t . q_t|, 1)
    n_tot = n_intra + jnp.einsum("bhd,bth->bthd", n0, decay_t)
    denom = jnp.maximum(jnp.abs(jnp.einsum("bthd,bthd->bth", n_tot, qf)), 1.0)
    y = (y_inter + y_intra) / denom[..., None]

    # state update to end of chunk
    total_decay = jnp.exp(L[:, -1])  # (B, H)
    w_s = jnp.exp(L[:, -1:, :] - L + log_i)  # (B, c, H): decay from s to end
    C1 = total_decay[..., None, None] * C0 + jnp.einsum(
        "bch,bchd,bche->bhde", w_s, kf, vf
    )
    n1 = total_decay[..., None] * n0 + jnp.einsum("bch,bchd->bhd", w_s, kf)
    return y, C1, n1


def mlstm_block(
    params,
    x: jnp.ndarray,
    cfg: ModelConfig,
    cache: Optional[Dict[str, jnp.ndarray]] = None,
    decode: bool = False,
) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    B, S, D = x.shape
    din, H = d_inner_of(cfg), cfg.n_heads
    dh = din // H

    qkv = x @ params["wqkv"]
    qkv = shard(qkv, "batch", None, "d_inner")
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(B, S, H, dh)
    k = k.reshape(B, S, H, dh)
    v = v.reshape(B, S, H, dh)
    gates = (x @ params["w_gates"]).astype(jnp.float32).reshape(B, S, 2, H)
    log_i = jnp.minimum(gates[:, :, 0], IGATE_CLIP)  # log input gate
    log_f = jax.nn.log_sigmoid(gates[:, :, 1])  # log forget gate
    o = jax.nn.sigmoid(x @ params["w_ogate"])

    if decode:
        assert cache is not None and S == 1
        C0, n0 = cache["C"], cache["n"]
        f_t = jnp.exp(log_f[:, 0])[..., None, None]  # (B,H,1,1)
        i_t = jnp.exp(log_i[:, 0])[..., None, None]
        kf = k.astype(jnp.float32)[:, 0] * (dh**-0.5)
        vf = v.astype(jnp.float32)[:, 0]
        C1 = f_t * C0 + i_t * jnp.einsum("bhd,bhe->bhde", kf, vf)
        n1 = f_t[..., 0] * n0 + i_t[..., 0] * kf
        qf = q.astype(jnp.float32)[:, 0]
        num = jnp.einsum("bhde,bhd->bhe", C1, qf)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n1, qf)), 1.0)
        y = (num / den[..., None])[:, None]  # (B,1,H,dh)
        new_cache = {"C": C1, "n": n1}
    else:
        c = min(CHUNK, S)
        nc = S // c
        assert S % c == 0
        C0 = cache["C"] if cache is not None else jnp.zeros((B, H, dh, dh), jnp.float32)
        n0 = cache["n"] if cache is not None else jnp.zeros((B, H, dh), jnp.float32)

        def step(carry, inp):
            C_, n_ = carry
            qc, kc, vc, lfc, lic = inp
            # checkpoint: the (B, c, c, H) decay/score tensors are recomputed
            # in backward instead of saved for every chunk at once
            y, C1, n1 = jax.checkpoint(_mlstm_chunk)(qc, kc, vc, lfc, lic, C_, n_)
            return (C1, n1), y

        xs = (
            q.reshape(B, nc, c, H, dh).transpose(1, 0, 2, 3, 4),
            k.reshape(B, nc, c, H, dh).transpose(1, 0, 2, 3, 4),
            v.reshape(B, nc, c, H, dh).transpose(1, 0, 2, 3, 4),
            log_f.reshape(B, nc, c, H).transpose(1, 0, 2, 3),
            log_i.reshape(B, nc, c, H).transpose(1, 0, 2, 3),
        )
        (C1, n1), ys = jax.lax.scan(step, (C0, n0), xs)
        y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, dh)
        new_cache = {"C": C1, "n": n1} if cache is not None else None

    y = (y.reshape(B, S, din).astype(x.dtype)) * o
    out = y @ params["out_proj"]
    return shard(out, "batch", None, None), new_cache


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_block(
    params,
    x: jnp.ndarray,
    cfg: ModelConfig,
    cache: Optional[Dict[str, jnp.ndarray]] = None,
    decode: bool = False,
) -> Tuple[jnp.ndarray, Optional[Dict[str, jnp.ndarray]]]:
    B, S, D = x.shape
    din, H = d_inner_of(cfg), cfg.n_heads
    dh = din // H

    pre = (x @ params["w_in"]).reshape(B, S, 4, H, dh)  # z, i, f, o pre-activations

    if cache is not None:
        c0, n0, h0 = cache["c"], cache["n"], cache["h"]
    else:
        c0 = jnp.zeros((B, H, dh), jnp.float32)
        n0 = jnp.ones((B, H, dh), jnp.float32)
        h0 = jnp.zeros((B, H, dh), jnp.float32)

    rz, ri, rf, ro = params["r_z"], params["r_i"], params["r_f"], params["r_o"]

    def step(carry, pre_t):
        c_, n_, h_ = carry  # (B, H, dh) f32
        hz = jnp.einsum("bhd,hde->bhe", h_, rz.astype(jnp.float32))
        hi = jnp.einsum("bhd,hde->bhe", h_, ri.astype(jnp.float32))
        hf = jnp.einsum("bhd,hde->bhe", h_, rf.astype(jnp.float32))
        ho = jnp.einsum("bhd,hde->bhe", h_, ro.astype(jnp.float32))
        pf = pre_t.astype(jnp.float32)
        z = jnp.tanh(pf[:, 0] + hz)
        i = jnp.exp(jnp.minimum(pf[:, 1] + hi, IGATE_CLIP))
        f = jax.nn.sigmoid(pf[:, 2] + hf)
        o = jax.nn.sigmoid(pf[:, 3] + ho)
        c1 = f * c_ + i * z
        n1 = f * n_ + i
        h1 = o * c1 / jnp.maximum(n1, 1.0)
        return (c1, n1, h1), h1

    if decode:
        assert S == 1
        (c1, n1, h1), h_out = step((c0, n0, h0), pre[:, 0])
        y = h_out[:, None].reshape(B, 1, din)
        new_cache = {"c": c1, "n": n1, "h": h1}
    else:
        (c1, n1, h1), hs = jax.lax.scan(step, (c0, n0, h0), pre.transpose(1, 0, 2, 3, 4))
        y = hs.transpose(1, 0, 2, 3).reshape(B, S, din)
        new_cache = {"c": c1, "n": n1, "h": h1} if cache is not None else None

    out = y.astype(x.dtype) @ params["out_proj"]
    return shard(out, "batch", None, None), new_cache


def init_xlstm_cache(cfg: ModelConfig, kind: str, batch: int):
    din, H = d_inner_of(cfg), cfg.n_heads
    dh = din // H
    if kind == "mlstm":
        return {
            "C": jnp.zeros((batch, H, dh, dh), jnp.float32),
            "n": jnp.zeros((batch, H, dh), jnp.float32),
        }
    return {
        "c": jnp.zeros((batch, H, dh), jnp.float32),
        "n": jnp.ones((batch, H, dh), jnp.float32),
        "h": jnp.zeros((batch, H, dh), jnp.float32),
    }
