"""Model assembly: embed -> stages (scan over super-blocks) -> norm -> logits.

Entry points:
  * ``init_model(key, cfg)``      -> (params, axes)   [axes: logical shardings]
  * ``forward(params, cfg, inp)`` -> logits            [training]
  * ``loss_fn(params, cfg, batch)``-> scalar CE loss
  * ``init_cache(cfg, B, S)``     -> cache pytree
  * ``prefill(params, cfg, inp, cache)``  -> (last_logits, cache)
  * ``decode_step(params, cfg, tok, pos, cache)`` -> (logits, cache)

Layers are stacked per stage on a leading axis and run under ``lax.scan``
(with optional rematerialization), so HLO size is depth-independent — the
multi-pod dry-run and the 1/2-layer roofline extrapolation rely on this.
``inp`` is int tokens (B, S) for ``frontend == "token"`` archs, or
precomputed frame/patch embeddings (B, S, D) for the audio/vlm stubs.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, StageSpec
from repro.device.programmed import bind_artifacts, name_scope
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.layers import (
    Init,
    current_crossbar,
    embed,
    init_embed,
    init_mlp,
    lm_head,
    mlp,
    rms_norm,
    shard,
    softcap,
)


def _stage_artifacts(si: int):
    """Programmed-crossbar artifact subtree for stage ``si``.

    Non-None only when serving under ``crossbar_mode(CrossbarMode(...,
    programmed=...))`` — the program-once steady-state path.  The subtree
    mirrors the stage's stacked params; ``_run_stage`` zips it into the
    layer scan so each iteration binds its parameter slices to the matching
    pre-programmed artifact slices.
    """
    mode = current_crossbar()
    if not mode.enabled or mode.programmed is None:
        return None
    sub = mode.programmed.subtree(f"stage{si}")
    if sub is None:
        return None
    # stage params are layer-stacked; only stacked artifacts can ride the
    # scan (a stray 2-D artifact would crash the per-layer slicing)
    from repro.device.programmed import stacked_only

    return stacked_only(sub)


# ---------------------------------------------------------------------------
# Block init / apply
# ---------------------------------------------------------------------------

def _init_block(ini: Init, cfg: ModelConfig, kind: str, use_moe: bool):
    d = cfg.d_model
    ini.param("norm1", (d,), (None,), init="zeros")
    mixer = ini.sub("mixer")
    if kind.startswith("attn"):
        attn_mod.init_attention(mixer, cfg)
    elif kind == "mamba":
        ssm_mod.init_mamba(mixer, cfg)
    elif kind == "mlstm":
        xlstm_mod.init_mlstm(mixer, cfg)
    elif kind == "slstm":
        xlstm_mod.init_slstm(mixer, cfg)
    else:
        raise ValueError(kind)
    if cfg.post_norm:
        ini.param("norm1_post", (d,), (None,), init="zeros")
    has_ffn = (cfg.d_ff or use_moe) and kind not in ("mlstm", "slstm")
    if has_ffn:
        ini.param("norm2", (d,), (None,), init="zeros")
        ffn = ini.sub("ffn")
        if use_moe:
            moe_mod.init_moe(ffn, cfg)
        else:
            init_mlp(ffn, d, cfg.d_ff, cfg.mlp_kind)
        if cfg.post_norm:
            ini.param("norm2_post", (d,), (None,), init="zeros")


def _apply_block(
    params,
    x: jnp.ndarray,
    cfg: ModelConfig,
    kind: str,
    use_moe: bool,
    positions: jnp.ndarray,
    cache_entry=None,
    decode_pos=None,
):
    h = rms_norm(x, params["norm1"], cfg.norm_eps)
    new_entry = None
    # name_scope pushes the param path components ("mixer"/"ffn", with
    # "stage{i}"/"b{i}" pushed by the callers) so crossbar_linear call sites
    # can address their programmed artifacts by canonical joined name
    with name_scope("mixer"):
        if kind.startswith("attn"):
            h, new_entry = attn_mod.attention_block(
                params["mixer"], h, cfg, kind, positions, cache_entry, decode_pos
            )
        elif kind == "mamba":
            h, new_entry = ssm_mod.mamba_block(
                params["mixer"], h, cfg, cache_entry, decode=decode_pos is not None
            )
        elif kind == "mlstm":
            h, new_entry = xlstm_mod.mlstm_block(
                params["mixer"], h, cfg, cache_entry, decode=decode_pos is not None
            )
        elif kind == "slstm":
            h, new_entry = xlstm_mod.slstm_block(
                params["mixer"], h, cfg, cache_entry, decode=decode_pos is not None
            )
    if cfg.post_norm:
        h = rms_norm(h, params["norm1_post"], cfg.norm_eps)
    x = x + h

    if "norm2" in params:
        h = rms_norm(x, params["norm2"], cfg.norm_eps)
        with name_scope("ffn"):
            if use_moe:
                h = moe_mod.moe_ffn(params["ffn"], h, cfg)
            else:
                h = mlp(params["ffn"], h, cfg.mlp_kind)
        if cfg.post_norm:
            h = rms_norm(h, params["norm2_post"], cfg.norm_eps)
        x = x + h
    return x, new_entry


# ---------------------------------------------------------------------------
# Cache
# ---------------------------------------------------------------------------

def _block_cache(cfg: ModelConfig, kind: str, batch: int, seq: int, dtype):
    if kind.startswith("attn"):
        return attn_mod.init_attention_cache(cfg, batch, seq, dtype)
    if kind == "mamba":
        return ssm_mod.init_mamba_cache(cfg, batch, dtype)
    return xlstm_mod.init_xlstm_cache(cfg, kind, batch)


def init_cache(cfg: ModelConfig, batch: int, seq: int, dtype=jnp.bfloat16):
    """Cache pytree: list per stage of {b<i>: stacked (repeats, ...)}."""
    stages = []
    for spec in cfg.stages:
        entry = {}
        for i, kind in enumerate(spec.kinds):
            one = _block_cache(cfg, kind, batch, seq, dtype)
            entry[f"b{i}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (spec.repeats,) + a.shape), one
            )
        stages.append(entry)
    return stages


def cache_axes(cfg: ModelConfig):
    """Logical-axis tree parallel to ``init_cache`` (for input shardings).

    Names: cache_batch (DP when divisible), cache_seq (DP when the batch is
    not shardable — the long_500k sequence-parallel layout), kv_heads /
    heads / d_inner (model axis).
    """

    def block_axes(kind: str):
        if kind.startswith("attn"):
            if cfg.kv_lora_rank:
                return {
                    "latent": ("layers", "cache_batch", "cache_seq", None),
                    "k_rope": ("layers", "cache_batch", "cache_seq", None),
                }
            return {
                "k": ("layers", "cache_batch", "cache_seq", "kv_heads", None),
                "v": ("layers", "cache_batch", "cache_seq", "kv_heads", None),
            }
        if kind == "mamba":
            return {
                "h": ("layers", "cache_batch", "d_inner", None),
                "conv": ("layers", "cache_batch", None, "d_inner"),
            }
        if kind == "mlstm":
            return {
                "C": ("layers", "cache_batch", "heads", None, None),
                "n": ("layers", "cache_batch", "heads", None),
            }
        return {
            "c": ("layers", "cache_batch", "heads", None),
            "n": ("layers", "cache_batch", "heads", None),
            "h": ("layers", "cache_batch", "heads", None),
        }

    return [
        {f"b{i}": block_axes(kind) for i, kind in enumerate(spec.kinds)}
        for spec in cfg.stages
    ]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init_model(key, cfg: ModelConfig, dtype=None, shape_only: bool = False) -> Tuple[Dict, Dict]:
    """Returns (params, axes).  ``shape_only=True`` materializes nothing —
    params are ShapeDtypeStructs (used by the dry-run for 1T-param configs)."""
    dtype = dtype or jnp.dtype(cfg.param_dtype)
    root = Init(key=key, dtype=dtype, shape_only=shape_only)
    if cfg.frontend == "token":
        init_embed(root.sub("embed"), cfg.vocab_size, cfg.d_model)
    for si, spec in enumerate(cfg.stages):
        # Build one layer's params, then stack `repeats` copies with vmap'd init
        def one(k, so=shape_only):
            ini = Init(key=k, dtype=dtype, shape_only=so)
            for i, kind in enumerate(spec.kinds):
                _init_block(ini.sub(f"b{i}"), cfg, kind, spec.moe[i] and cfg.moe_experts > 0)
            return ini.params, ini.axes

        if shape_only:
            shapes, axes = one(key)
            stacked = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((spec.repeats,) + s.shape, s.dtype),
                shapes,
            )
        else:
            keys = jax.random.split(root._next_key(), spec.repeats)
            stacked = jax.vmap(lambda k: one(k, False)[0])(keys)
            axes = one(key, True)[1]
        axes = jax.tree.map(
            lambda a: ("layers",) + a, axes, is_leaf=lambda x: isinstance(x, tuple)
        )
        root.params[f"stage{si}"] = stacked
        root.axes[f"stage{si}"] = axes
    root.param("final_norm", (cfg.d_model,), (None,), init="zeros")
    if not cfg.tie_embeddings or cfg.frontend != "token":
        root.param("head", (cfg.d_model, cfg.vocab_size), ("embed", "vocab"), scale=cfg.d_model**-0.5)
    return root.params, root.axes


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def _run_stage(
    params_stage,
    x,
    cfg: ModelConfig,
    spec: StageSpec,
    positions,
    cache_stage=None,
    decode_pos=None,
    remat: bool = False,
    artifacts_stage=None,
):
    def body(carry, xs):
        h = carry
        lp, cache_layer, ap = xs
        # bind this layer's programmed-crossbar artifacts (scan-sliced in
        # lockstep with the params) so crossbar_linear serves steady-state;
        # keys are joined under the caller's "stage{i}" name scope
        with bind_artifacts(ap):
            new_entries = {}
            for i, kind in enumerate(spec.kinds):
                entry = cache_layer[f"b{i}"] if cache_layer is not None else None
                with name_scope(f"b{i}"):
                    h, ne = _apply_block(
                        lp[f"b{i}"], h, cfg, kind,
                        bool(spec.moe[i]) and cfg.moe_experts > 0,
                        positions, entry, decode_pos,
                    )
                if cache_layer is not None:
                    new_entries[f"b{i}"] = ne
            if decode_pos is None and h.shape[1] > 1:
                # sequence-parallel residual stream: the layer-boundary carries the
                # scan backward must save shrink by the model-axis extent
                h = shard(h, "batch", "act_seq", None)
        return h, (new_entries if cache_layer is not None else None)

    if remat:
        body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    if not cfg.scan_layers:
        # unrolled path (roofline depth variants): every layer appears in the
        # HLO so cost_analysis counts true totals
        entries = []
        for r in range(spec.repeats):
            lp = jax.tree.map(lambda a: a[r], params_stage)
            cl = jax.tree.map(lambda a: a[r], cache_stage) if cache_stage is not None else None
            ap = (
                jax.tree.map(lambda a: a[r], artifacts_stage)
                if artifacts_stage is not None
                else None
            )
            x, ne = body(x, (lp, cl, ap))
            entries.append(ne)
        if cache_stage is None:
            return x, None
        stacked = jax.tree.map(lambda *ys: jnp.stack(ys), *entries)
        return x, stacked
    x, new_cache = jax.lax.scan(body, x, (params_stage, cache_stage, artifacts_stage))
    return x, new_cache


def _embed_input(params, cfg: ModelConfig, inp) -> jnp.ndarray:
    if cfg.frontend == "token":
        return embed(params["embed"], inp, cfg.embed_scale, cfg.d_model)
    # audio/vlm stub: precomputed frame/patch embeddings
    x = inp.astype(jnp.dtype(cfg.param_dtype))
    return shard(x, "batch", None, None)


def _logits(params, cfg: ModelConfig, x) -> jnp.ndarray:
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings and cfg.frontend == "token":
        # the tied head serves from the transposed artifact that
        # program_model(tie_lm_head=True) binds under the embedding's name
        return lm_head(
            params["embed"]["tokens"], x, tied=True, cap=cfg.logit_softcap,
            name="embed/tokens",
        )
    return lm_head(params["head"], x, tied=False, cap=cfg.logit_softcap, name="head")


def forward(params, cfg: ModelConfig, inp, positions=None) -> jnp.ndarray:
    """Full-sequence forward (training). Returns logits (B, S, V)."""
    x = _embed_input(params, cfg, inp)
    S = x.shape[1]
    if positions is None:
        positions = jnp.arange(S)
    for si, spec in enumerate(cfg.stages):
        with name_scope(f"stage{si}"):
            x, _ = _run_stage(
                params[f"stage{si}"], x, cfg, spec, positions, remat=cfg.remat,
                artifacts_stage=_stage_artifacts(si),
            )
    return _logits(params, cfg, x)


LOSS_CHUNK = 512  # sequence chunking bounds the live (B, c, V) logits buffer


def loss_fn(params, cfg: ModelConfig, batch) -> jnp.ndarray:
    """Next-token cross entropy. batch: {"inputs": ..., "targets": (B, S)}.

    The LM head + softmax run chunked over the sequence: materializing full
    (B, S, V) logits for a 256k vocab at 4k x 256 tokens would be ~0.5 TB
    even in bf16; chunking keeps the live buffer at (B, c, V).
    """
    x = _embed_input(params, cfg, batch["inputs"])
    S = x.shape[1]
    positions = jnp.arange(S)
    for si, spec in enumerate(cfg.stages):
        with name_scope(f"stage{si}"):
            x, _ = _run_stage(
                params[f"stage{si}"], x, cfg, spec, positions, remat=cfg.remat,
                artifacts_stage=_stage_artifacts(si),
            )
    targets = batch["targets"]
    mask = batch.get("mask", jnp.ones(targets.shape, jnp.float32))

    c = min(LOSS_CHUNK, S)
    if S % c != 0:
        c = S
    nc = S // c
    B = x.shape[0]

    def _one_chunk(xc, tc, mc):
        logits = _logits(params, cfg, xc).astype(jnp.float32)
        # one-hot contraction keeps the vocab dim sharded (take_along_axis
        # would gather the full (B, c, V) logp onto every model shard)
        lse = jax.nn.logsumexp(logits, axis=-1)
        lab = jnp.sum(logits * jax.nn.one_hot(tc, logits.shape[-1], dtype=logits.dtype), -1)
        return jnp.sum((lse - lab) * mc)

    def chunk_nll(carry, inp):
        xc, tc, mc = inp  # (B, c, D), (B, c), (B, c)
        # checkpoint: otherwise every chunk's (B, c, V) logp is saved at once
        return carry + jax.checkpoint(_one_chunk)(xc, tc, mc), None

    xs = (
        x.reshape(B, nc, c, -1).transpose(1, 0, 2, 3),
        targets.reshape(B, nc, c).transpose(1, 0, 2),
        mask.reshape(B, nc, c).transpose(1, 0, 2),
    )
    total, _ = jax.lax.scan(chunk_nll, jnp.zeros((), jnp.float32), xs)
    return total / jnp.maximum(jnp.sum(mask), 1.0)


def prefill(params, cfg: ModelConfig, inp, cache):
    """Process the prompt, fill the cache; returns (last_logits, cache)."""
    x = _embed_input(params, cfg, inp)
    S = x.shape[1]
    positions = jnp.arange(S)
    new_cache = []
    for si, spec in enumerate(cfg.stages):
        with name_scope(f"stage{si}"):
            x, nc = _run_stage(
                params[f"stage{si}"], x, cfg, spec, positions, cache_stage=cache[si],
                remat=False, artifacts_stage=_stage_artifacts(si),
            )
        new_cache.append(nc)
    logits = _logits(params, cfg, x[:, -1:])
    return logits[:, 0], new_cache


def decode_step(params, cfg: ModelConfig, inp, pos, cache):
    """One decode step at position ``pos`` — scalar, or (B,) per-slot
    positions for continuous batching.  Returns (logits, cache)."""
    x = _embed_input(params, cfg, inp)  # (B, 1) tokens or (B, 1, D) embeds
    pos = jnp.asarray(pos)
    positions = pos[:, None] if pos.ndim == 1 else jnp.asarray([0]) + pos
    new_cache = []
    for si, spec in enumerate(cfg.stages):
        with name_scope(f"stage{si}"):
            x, nc = _run_stage(
                params[f"stage{si}"], x, cfg, spec, positions, cache_stage=cache[si],
                decode_pos=pos, remat=False, artifacts_stage=_stage_artifacts(si),
            )
        new_cache.append(nc)
    logits = _logits(params, cfg, x)
    return logits[:, 0], new_cache
