"""Core layers: parameter system, sharding helpers, norms, MLPs, RoPE,
embeddings — pure functional JAX (no flax), pytree params.

Parameter/sharding system
-------------------------
``Init`` collects parameters and their *logical axes* simultaneously; logical
axes map to mesh axes via ``LOGICAL_RULES`` ("vocab"/"heads"/"mlp"/"experts"
-> "model"; "batch" -> ("pod","data"); everything else replicated).  The
active mesh is held in a context (``use_mesh``) so the same model code runs
on a single CPU device (tests), the 16x16 production mesh, and the 2x16x16
multi-pod mesh without modification.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# Mesh context + logical axis rules
# ---------------------------------------------------------------------------

_CTX = threading.local()

LOGICAL_RULES: Dict[str, Any] = {
    "batch": ("pod", "data"),
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "experts": "model",
    "d_inner": "model",
    "seq_shard": ("pod", "data"),  # long-context cache sequence sharding
    "act_seq": "model",  # sequence-parallel residual stream between blocks
    # expert-TP decode layout (weights-stationary serving; see moe.py):
    "moe_dm": None,  # wi contraction dim; "model" under expert_tp
    "moe_ff": None,  # wo contraction dim; "model" under expert_tp
}


def current_mesh() -> Optional[Mesh]:
    return getattr(_CTX, "mesh", None)


def current_overrides() -> Dict[str, Any]:
    return getattr(_CTX, "overrides", {})


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], overrides: Optional[Dict[str, Any]] = None):
    """Install the active mesh and optional per-config logical-rule overrides.

    Overrides support per-architecture layouts, e.g. a 350M model on a fixed
    (data, model) mesh is fastest as pure DP: {"batch": ("pod", "data",
    "model"), "vocab": None, "d_inner": None, ...} treats the model axis as
    extra data parallelism (EXPERIMENTS.md §Perf, xlstm hillclimb).
    """
    prev = getattr(_CTX, "mesh", None)
    prev_ov = getattr(_CTX, "overrides", {})
    _CTX.mesh = mesh
    _CTX.overrides = dict(overrides or {})
    try:
        yield
    finally:
        _CTX.mesh = prev
        _CTX.overrides = prev_ov


def layout_overrides(cfg) -> Dict[str, Any]:
    """Per-config logical-rule overrides (see ModelConfig.layout)."""
    if getattr(cfg, "layout", "") == "pure_dp":
        return {
            "batch": ("pod", "data", "model"),
            "seq_shard": ("pod", "data", "model"),
            "vocab": None,
            "heads": None,
            "kv_heads": None,
            "mlp": None,
            "d_inner": None,
            "experts": None,
            "act_seq": None,
        }
    if getattr(cfg, "layout", "") == "ep_only":
        # Expert-parallel-only serving: the MoE expert banks shard over
        # "model"; every other tensor (and every activation constraint)
        # stays replicated.  The digital parts of the graph then compile
        # identically to single-device, which makes programmed crossbar
        # serving on a mesh *bit-identical* to the single-device chip —
        # the distributed test tier pins exactly this
        # (tests/test_sharded_artifacts.py).
        return {
            "batch": None,
            "seq_shard": None,
            "vocab": None,
            "heads": None,
            "kv_heads": None,
            "mlp": None,
            "d_inner": None,
            "act_seq": None,
        }
    if getattr(cfg, "layout", "") == "expert_tp":
        # Weights-stationary MoE serving: experts sharded over "data",
        # expert FFN contraction dims TP-sharded over "model" — no FSDP
        # weight gathers at decode (the paper's in-situ principle at
        # cluster scale; EXPERIMENTS.md §Perf, deepseek decode).
        return {"experts": "data", "moe_dm": "model", "moe_ff": "model"}
    return {}


def _resolve_axis(logical: Optional[str], mesh: Mesh):
    if logical is None:
        return None
    ov = current_overrides()
    rule = ov[logical] if logical in ov else LOGICAL_RULES.get(logical)
    if rule is None:
        return None
    if isinstance(rule, tuple):
        present = tuple(a for a in rule if a in mesh.axis_names)
        return present if present else None
    return rule if rule in mesh.axis_names else None


def pspec(axes: Sequence[Optional[str]], mesh: Optional[Mesh] = None) -> P:
    mesh = mesh or current_mesh()
    if mesh is None:
        return P()
    return P(*[_resolve_axis(a, mesh) for a in axes])


def dividing_entry(dim: int, ax, mesh: Mesh):
    """Largest usable sharding for one dim: the full entry when it divides,
    else the longest *prefix* of a tuple entry that divides (e.g. batch 32
    on ("pod","data","model") -> ("pod","data")), else None."""
    if ax is None:
        return None
    axes = ax if isinstance(ax, tuple) else (ax,)
    for end in range(len(axes), 0, -1):
        size = int(np.prod([mesh.shape[a] for a in axes[:end]]))
        if size > 1 and dim % size == 0:
            prefix = axes[:end]
            return prefix if isinstance(ax, tuple) else prefix[0]
    return None


def shard(x: jnp.ndarray, *axes: Optional[str]) -> jnp.ndarray:
    """Apply a sharding constraint by logical axes (no-op without a mesh;
    non-dividing dims fall back to the largest dividing prefix)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = pspec(axes, mesh)
    fixed = [dividing_entry(dim, ax, mesh) for dim, ax in zip(x.shape, spec)]
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*fixed)))


# ---------------------------------------------------------------------------
# Parameter initialization with collected PartitionSpecs
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Init:
    """Collects params and their logical-axis tuples in parallel trees.

    With ``shape_only=True`` no arrays are materialized — params are
    ShapeDtypeStructs.  The dry-run uses this to derive shardings for
    trillion-parameter configs without allocating anything.
    """

    key: jax.Array
    dtype: Any = jnp.float32
    shape_only: bool = False
    params: Dict[str, Any] = dataclasses.field(default_factory=dict)
    axes: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def _next_key(self):
        if self.shape_only:
            return self.key
        self.key, sub = jax.random.split(self.key)
        return sub

    def param(
        self,
        name: str,
        shape: Tuple[int, ...],
        axes: Tuple[Optional[str], ...],
        init: str = "normal",
        scale: Optional[float] = None,
    ):
        assert len(shape) == len(axes), (name, shape, axes)
        if self.shape_only:
            v = jax.ShapeDtypeStruct(shape, self.dtype)
        else:
            k = self._next_key()
            if init == "normal":
                s = scale if scale is not None else (shape[0] ** -0.5 if shape else 1.0)
                v = jax.random.normal(k, shape, self.dtype) * jnp.asarray(s, self.dtype)
            elif init == "zeros":
                v = jnp.zeros(shape, self.dtype)
            elif init == "ones":
                v = jnp.ones(shape, self.dtype)
            else:
                raise ValueError(init)
        self.params[name] = v
        self.axes[name] = axes
        return v

    def sub(self, name: str) -> "Init":
        child = Init(key=self._next_key(), dtype=self.dtype, shape_only=self.shape_only)
        self.params[name] = child.params
        self.axes[name] = child.axes
        return child


def axes_to_pspecs(axes_tree, mesh: Mesh):
    """Map a tree of logical-axis tuples to a tree of PartitionSpecs.

    Dims that do not divide their mesh axes are replicated (e.g. smollm's 15
    heads on a 16-way model axis).  Shapes are unknown here, so divisibility
    is checked later against the actual arrays via ``named_sharding_tree``.
    """
    return jax.tree.map(
        lambda a: pspec(a, mesh), axes_tree, is_leaf=lambda x: isinstance(x, tuple)
    )


def named_sharding_tree(params_shape_tree, axes_tree, mesh: Mesh):
    """NamedShardings for every param, dropping non-dividing axis entries."""

    def one(shape_struct, axes):
        spec = pspec(axes, mesh)
        shape = shape_struct.shape
        fixed = []
        for dim, ax in zip(shape, spec):
            if ax is None:
                fixed.append(None)
                continue
            size = int(
                np.prod([mesh.shape[a] for a in (ax if isinstance(ax, tuple) else (ax,))])
            )
            fixed.append(ax if dim % size == 0 else None)
        return NamedSharding(mesh, P(*fixed))

    return jax.tree.map(
        one, params_shape_tree, axes_tree, is_leaf=lambda x: isinstance(x, tuple)
    )


# ---------------------------------------------------------------------------
# Norms / activations / MLPs
# ---------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    return cap * jnp.tanh(x / cap)


def init_mlp(ini: Init, d_model: int, d_ff: int, kind: str):
    if kind in ("swiglu", "geglu"):
        ini.param("wi", (d_model, 2 * d_ff), ("embed", "mlp"))
    else:
        ini.param("wi", (d_model, d_ff), ("embed", "mlp"))
    ini.param("wo", (d_ff, d_model), ("mlp", "embed"))


def mlp(params, x: jnp.ndarray, kind: str) -> jnp.ndarray:
    # wi/wo route through crossbar_linear so an enabled CrossbarMode (and
    # the programmed/repaired artifact path) covers the FFN, not just the
    # attention projections; with the mode disabled this is a plain matmul
    h = crossbar_linear(x, params["wi"], name="wi")
    h = shard(h, "batch", None, "mlp")
    if kind in ("swiglu", "geglu"):
        u, g = jnp.split(h, 2, axis=-1)
        act = jax.nn.silu(g) if kind == "swiglu" else jax.nn.gelu(g)
        h = u * act
    elif kind == "gelu":
        h = jax.nn.gelu(h)
    elif kind == "relu2":
        h = jnp.square(jax.nn.relu(h))
    else:
        raise ValueError(kind)
    y = crossbar_linear(h, params["wo"], name="wo")
    return shard(y, "batch", None, None)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, D) with positions (..., S) or (S,)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (D/2,)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, D/2)
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, D/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------

def init_embed(ini: Init, vocab: int, d_model: int):
    ini.param("tokens", (vocab, d_model), ("vocab", "embed"), scale=0.02)


def embed(params, tokens: jnp.ndarray, scale: bool, d_model: int) -> jnp.ndarray:
    x = params["tokens"][tokens]
    x = shard(x, "batch", None, None)
    if scale:
        x = x * jnp.asarray(d_model**0.5, x.dtype)
    return x


def lm_head(
    table_or_w,
    x: jnp.ndarray,
    tied: bool,
    cap: float = 0.0,
    name: Optional[str] = None,
) -> jnp.ndarray:
    # the LM head is the model's largest single projection; routing it
    # through crossbar_linear completes full-model crossbar coverage.  A
    # *tied* head multiplies a transpose of the embedding table — the
    # transpose view has no stable object identity, but it has a stable
    # *name*, so ``program_model(tie_lm_head=True)`` compiles the transpose
    # once at deploy time and name-keyed lookup serves it here; without an
    # artifact the per-call crossbar path programs the transpose like any
    # other unprogrammed projection.
    w = table_or_w.T if tied else table_or_w
    logits = crossbar_linear(x, w, name=name)
    logits = shard(logits, "batch", None, "vocab")
    if cap:
        logits = softcap(logits.astype(jnp.float32), cap)
    return logits


# ---------------------------------------------------------------------------
# CrossbarLinear — the paper's technique as a first-class serving feature
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CrossbarMode:
    """When enabled, every weight-bearing matmul — attention projections,
    dense-MLP wi/wo, the MoE router/experts/shared experts, and the LM head
    (tied or untied; a tied head runs the embedding transpose, see
    ``lm_head``) — runs through the Newton bit-sliced crossbar datapath
    (Pallas kernel; interpret-mode on CPU) instead of XLA matmul; only
    activation-activation products (attention scores/values) stay digital
    (tests/test_models_smoke.py pins the coverage on dense and MoE
    configs).  ``shard_map`` expert-/tensor-parallel bodies serve too:
    artifacts shard with the weights they shadow
    (``device.programmed.shard_artifacts``), the bodies rebind rank-local
    slices by name, and expert-parallel serving stays bit-identical to
    single-device (tests/test_sharded_artifacts.py).

    ``device`` (a ``repro.device.DeviceConfig``) additionally routes the
    matmul through the memristor non-ideality pipeline — stuck cells,
    programming variation, drift, IR drop — so end-to-end model accuracy
    under realistic devices is one context manager away.

    ``programmed`` (a ``repro.device.programmed.ProgrammedModel``) is the
    program-once steady-state path: projections whose *name* resolves a
    compiled artifact skip quantization-scale reductions, fault redraw and
    write-verify entirely and serve from the fixed programmed chip; names
    without an artifact fall back to the program-every-call path above —
    and, because a silent fallback misreports crossbar coverage and skips
    the device model, every such miss is counted
    (``crossbar_misses()``) and ``strict=True`` turns it into an error."""

    enabled: bool = False
    fast: bool = True  # fused exact kernel (full-resolution ADC)
    device: Optional[Any] = None  # repro.device.DeviceConfig
    programmed: Optional[Any] = None  # repro.device.programmed.ProgrammedModel
    strict: bool = False  # raise on artifact miss when ``programmed`` is set


_CROSSBAR = CrossbarMode()

# Artifact-miss accounting: every crossbar_linear call that falls back to
# per-call programming *while a ProgrammedModel is active* records the name
# it failed to resolve.  Misses are recorded at trace time (a cached jit
# executable traces once), so "zero misses over a traced forward" is the
# invariant tests assert.  Stored as {name: count} — bounded by the number
# of distinct projection names, never by call volume, so a long-running
# eager loop with a persistent miss cannot grow memory.
_MISSES = threading.local()  # .counts: dict[str, int], insertion-ordered


def _record_crossbar_miss(name: str) -> None:
    counts = getattr(_MISSES, "counts", None)
    if counts is None:
        counts = _MISSES.counts = {}
    counts[name] = counts.get(name, 0) + 1


def crossbar_misses() -> Tuple[str, ...]:
    """Distinct names that resolved no artifact under an active
    ProgrammedModel, in first-miss order (``crossbar_miss_counts`` for
    per-name totals)."""
    return tuple(getattr(_MISSES, "counts", {}))


def crossbar_miss_counts() -> Dict[str, int]:
    """{name: times missed} under an active ProgrammedModel."""
    return dict(getattr(_MISSES, "counts", {}))


def reset_crossbar_misses() -> None:
    _MISSES.counts = {}


def restore_crossbar_misses(counts: Dict[str, int]) -> None:
    """Overwrite the miss record with a snapshot from
    ``crossbar_miss_counts`` — for internal traces (e.g. the engine's
    construction-time coverage check) that must not leave their own
    trace-time misses behind for an operator to misread."""
    _MISSES.counts = dict(counts)


def note_crossbar_gap(name: str) -> None:
    """Record that a weight-bearing computation stayed digital under an
    active ProgrammedModel.

    Since per-rank artifact sharding, the ``shard_map`` EP/TP bodies serve
    from rank-local artifact slices, so this fires only when a body finds
    *no* artifact to rebind (a partially-programmed model, a stale store):
    the coverage gap must still be loud — it counts as a miss and raises
    under strict mode, never silently misreporting crossbar coverage.
    No-op when no ProgrammedModel is active (digital/per-call runs are not
    gaps).
    """
    if not _CROSSBAR.enabled or _CROSSBAR.programmed is None:
        return
    from repro.device import programmed as prog

    key = prog.scoped_name(name)
    _record_crossbar_miss(key)
    if _CROSSBAR.strict:
        raise LookupError(
            f"crossbar coverage gap: {key!r} runs digitally inside a mesh-"
            "sharded path — no programmed artifact was bound for it to "
            "rebind per rank (a partially-programmed model or a stale "
            "artifact store); program the missing leaf (program_model "
            "leaf_filter), refresh the store, or drop strict mode."
        )


def current_crossbar() -> CrossbarMode:
    """The active CrossbarMode (the all-default disabled mode when unset)."""
    return _CROSSBAR


@contextlib.contextmanager
def crossbar_mode(mode: CrossbarMode):
    global _CROSSBAR
    prev = _CROSSBAR
    _CROSSBAR = mode
    try:
        yield
    finally:
        _CROSSBAR = prev


def _resolve_crossbar_artifact(name: str, shape) -> Tuple[Optional[str], Optional[Any]]:
    """(canonical key, artifact-or-None) for a scoped name + exact shape —
    the single derivation site for the key, shared by the hit and miss
    paths of ``crossbar_linear``.

    Resolution order: the dynamic ``bind_artifacts`` stack (innermost wins
    — this is where scan-sliced per-layer and per-expert bindings live),
    then the active ``CrossbarMode.programmed`` model's canonical
    ``by_name`` table.
    """
    from repro.device import programmed as prog

    key = prog.scoped_name(name)
    art = prog.active_artifact_for(key, tuple(shape))
    if art is None and _CROSSBAR.programmed is not None:
        art = _CROSSBAR.programmed.lookup(key, tuple(shape))
    return key, art


def lookup_crossbar_artifact(name: str, shape) -> Optional[Any]:
    """Resolve a programmed artifact by scoped name + exact shape (see
    ``_resolve_crossbar_artifact``).  Returns None when the mode is
    disabled or nothing matches.  ``shape`` may be a still-stacked shape
    (the MoE expert path fetches its ``(E, K, N)`` bank this way before
    slicing it)."""
    if not _CROSSBAR.enabled:
        return None
    return _resolve_crossbar_artifact(name, shape)[1]


def crossbar_linear(
    x: jnp.ndarray,
    w: jnp.ndarray,
    name: Optional[str] = None,
    *,
    strict: Optional[bool] = None,
) -> jnp.ndarray:
    """y = x @ w, optionally through the crossbar datapath (W16A16).

    Activations are offset-encoded (crossbar inputs are unsigned; the offset
    is corrected digitally — see ``core.crossbar.signed_vmm_limbs``).

    ``name`` is the call site's local parameter name (e.g. "wq"); joined
    with the ambient ``device.programmed.name_scope`` stack it forms the
    canonical artifact key.  If a programmed artifact resolves for that key
    (via an enclosing ``bind_artifacts`` scope or
    ``CrossbarMode.programmed``), the steady-state program-once path serves
    the call: quantize input -> Pallas kernel -> dequantize, with scales /
    effective cells / correction column sums all precomputed at programming
    time.  Otherwise the weight is programmed on the fly (the per-call
    pipeline) — and if a ProgrammedModel *is* active, that fallback is a
    **miss**: it is counted (``crossbar_misses()``), and ``strict=True``
    (per call, or via ``CrossbarMode.strict``) raises instead of silently
    serving digital-grade results the operator believes are programmed."""
    if not _CROSSBAR.enabled:
        return x @ w
    from repro.kernels import ops as kops

    key = art = None
    if name is not None:
        key, art = _resolve_crossbar_artifact(name, w.shape)
    if art is not None:
        from repro.device import programmed as prog

        # consumption record for the structural name-set check: after a
        # traced forward, ProgrammedModel.verify_consumed compares the
        # emitted name set against exactly these hits
        prog.record_artifact_consumed(key)
        # x passed as-is: programmed_linear offset-encodes in x.dtype before
        # casting, mirroring the fallback below op-for-op (pre-casting bf16
        # activations here would break bit-identity between the two paths)
        return prog.programmed_linear(x, art).astype(x.dtype)

    if _CROSSBAR.programmed is not None:
        if key is None:
            key = f"<unnamed {tuple(int(d) for d in w.shape)}>"
        _record_crossbar_miss(key)
        strict_now = _CROSSBAR.strict if strict is None else strict
        if strict_now:
            raise LookupError(
                f"crossbar artifact miss: {key!r} (shape "
                f"{tuple(int(d) for d in w.shape)}) resolves no programmed "
                "artifact — the call would silently fall back to per-call "
                "programming.  Program the leaf (program_model leaf_filter / "
                "tie_lm_head), fix the call-site name, or drop strict mode."
            )

    shift = jnp.min(x)
    xs = (x - shift).astype(jnp.float32)  # non-negative
    y = kops.crossbar_matmul(
        xs, w.astype(jnp.float32), device=_CROSSBAR.device, fast=_CROSSBAR.fast
    )
    corr = shift.astype(jnp.float32) * jnp.sum(w.astype(jnp.float32), axis=0)
    return (y + corr).astype(x.dtype)
