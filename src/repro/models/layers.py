"""Core layers: parameter system, sharding helpers, norms, MLPs, RoPE,
embeddings — pure functional JAX (no flax), pytree params.

Parameter/sharding system
-------------------------
``Init`` collects parameters and their *logical axes* simultaneously; logical
axes map to mesh axes via ``LOGICAL_RULES`` ("vocab"/"heads"/"mlp"/"experts"
-> "model"; "batch" -> ("pod","data"); everything else replicated).  The
active mesh is held in a context (``use_mesh``) so the same model code runs
on a single CPU device (tests), the 16x16 production mesh, and the 2x16x16
multi-pod mesh without modification.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# Mesh context + logical axis rules
# ---------------------------------------------------------------------------

_CTX = threading.local()

LOGICAL_RULES: Dict[str, Any] = {
    "batch": ("pod", "data"),
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "experts": "model",
    "d_inner": "model",
    "seq_shard": ("pod", "data"),  # long-context cache sequence sharding
    "act_seq": "model",  # sequence-parallel residual stream between blocks
    # expert-TP decode layout (weights-stationary serving; see moe.py):
    "moe_dm": None,  # wi contraction dim; "model" under expert_tp
    "moe_ff": None,  # wo contraction dim; "model" under expert_tp
}


def current_mesh() -> Optional[Mesh]:
    return getattr(_CTX, "mesh", None)


def current_overrides() -> Dict[str, Any]:
    return getattr(_CTX, "overrides", {})


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], overrides: Optional[Dict[str, Any]] = None):
    """Install the active mesh and optional per-config logical-rule overrides.

    Overrides support per-architecture layouts, e.g. a 350M model on a fixed
    (data, model) mesh is fastest as pure DP: {"batch": ("pod", "data",
    "model"), "vocab": None, "d_inner": None, ...} treats the model axis as
    extra data parallelism (EXPERIMENTS.md §Perf, xlstm hillclimb).
    """
    prev = getattr(_CTX, "mesh", None)
    prev_ov = getattr(_CTX, "overrides", {})
    _CTX.mesh = mesh
    _CTX.overrides = dict(overrides or {})
    try:
        yield
    finally:
        _CTX.mesh = prev
        _CTX.overrides = prev_ov


def layout_overrides(cfg) -> Dict[str, Any]:
    """Per-config logical-rule overrides (see ModelConfig.layout)."""
    if getattr(cfg, "layout", "") == "pure_dp":
        return {
            "batch": ("pod", "data", "model"),
            "seq_shard": ("pod", "data", "model"),
            "vocab": None,
            "heads": None,
            "kv_heads": None,
            "mlp": None,
            "d_inner": None,
            "experts": None,
            "act_seq": None,
        }
    if getattr(cfg, "layout", "") == "expert_tp":
        # Weights-stationary MoE serving: experts sharded over "data",
        # expert FFN contraction dims TP-sharded over "model" — no FSDP
        # weight gathers at decode (the paper's in-situ principle at
        # cluster scale; EXPERIMENTS.md §Perf, deepseek decode).
        return {"experts": "data", "moe_dm": "model", "moe_ff": "model"}
    return {}


def _resolve_axis(logical: Optional[str], mesh: Mesh):
    if logical is None:
        return None
    ov = current_overrides()
    rule = ov[logical] if logical in ov else LOGICAL_RULES.get(logical)
    if rule is None:
        return None
    if isinstance(rule, tuple):
        present = tuple(a for a in rule if a in mesh.axis_names)
        return present if present else None
    return rule if rule in mesh.axis_names else None


def pspec(axes: Sequence[Optional[str]], mesh: Optional[Mesh] = None) -> P:
    mesh = mesh or current_mesh()
    if mesh is None:
        return P()
    return P(*[_resolve_axis(a, mesh) for a in axes])


def dividing_entry(dim: int, ax, mesh: Mesh):
    """Largest usable sharding for one dim: the full entry when it divides,
    else the longest *prefix* of a tuple entry that divides (e.g. batch 32
    on ("pod","data","model") -> ("pod","data")), else None."""
    if ax is None:
        return None
    axes = ax if isinstance(ax, tuple) else (ax,)
    for end in range(len(axes), 0, -1):
        size = int(np.prod([mesh.shape[a] for a in axes[:end]]))
        if size > 1 and dim % size == 0:
            prefix = axes[:end]
            return prefix if isinstance(ax, tuple) else prefix[0]
    return None


def shard(x: jnp.ndarray, *axes: Optional[str]) -> jnp.ndarray:
    """Apply a sharding constraint by logical axes (no-op without a mesh;
    non-dividing dims fall back to the largest dividing prefix)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = pspec(axes, mesh)
    fixed = [dividing_entry(dim, ax, mesh) for dim, ax in zip(x.shape, spec)]
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*fixed)))


# ---------------------------------------------------------------------------
# Parameter initialization with collected PartitionSpecs
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Init:
    """Collects params and their logical-axis tuples in parallel trees.

    With ``shape_only=True`` no arrays are materialized — params are
    ShapeDtypeStructs.  The dry-run uses this to derive shardings for
    trillion-parameter configs without allocating anything.
    """

    key: jax.Array
    dtype: Any = jnp.float32
    shape_only: bool = False
    params: Dict[str, Any] = dataclasses.field(default_factory=dict)
    axes: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def _next_key(self):
        if self.shape_only:
            return self.key
        self.key, sub = jax.random.split(self.key)
        return sub

    def param(
        self,
        name: str,
        shape: Tuple[int, ...],
        axes: Tuple[Optional[str], ...],
        init: str = "normal",
        scale: Optional[float] = None,
    ):
        assert len(shape) == len(axes), (name, shape, axes)
        if self.shape_only:
            v = jax.ShapeDtypeStruct(shape, self.dtype)
        else:
            k = self._next_key()
            if init == "normal":
                s = scale if scale is not None else (shape[0] ** -0.5 if shape else 1.0)
                v = jax.random.normal(k, shape, self.dtype) * jnp.asarray(s, self.dtype)
            elif init == "zeros":
                v = jnp.zeros(shape, self.dtype)
            elif init == "ones":
                v = jnp.ones(shape, self.dtype)
            else:
                raise ValueError(init)
        self.params[name] = v
        self.axes[name] = axes
        return v

    def sub(self, name: str) -> "Init":
        child = Init(key=self._next_key(), dtype=self.dtype, shape_only=self.shape_only)
        self.params[name] = child.params
        self.axes[name] = child.axes
        return child


def axes_to_pspecs(axes_tree, mesh: Mesh):
    """Map a tree of logical-axis tuples to a tree of PartitionSpecs.

    Dims that do not divide their mesh axes are replicated (e.g. smollm's 15
    heads on a 16-way model axis).  Shapes are unknown here, so divisibility
    is checked later against the actual arrays via ``named_sharding_tree``.
    """
    return jax.tree.map(
        lambda a: pspec(a, mesh), axes_tree, is_leaf=lambda x: isinstance(x, tuple)
    )


def named_sharding_tree(params_shape_tree, axes_tree, mesh: Mesh):
    """NamedShardings for every param, dropping non-dividing axis entries."""

    def one(shape_struct, axes):
        spec = pspec(axes, mesh)
        shape = shape_struct.shape
        fixed = []
        for dim, ax in zip(shape, spec):
            if ax is None:
                fixed.append(None)
                continue
            size = int(
                np.prod([mesh.shape[a] for a in (ax if isinstance(ax, tuple) else (ax,))])
            )
            fixed.append(ax if dim % size == 0 else None)
        return NamedSharding(mesh, P(*fixed))

    return jax.tree.map(
        one, params_shape_tree, axes_tree, is_leaf=lambda x: isinstance(x, tuple)
    )


# ---------------------------------------------------------------------------
# Norms / activations / MLPs
# ---------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    return cap * jnp.tanh(x / cap)


def init_mlp(ini: Init, d_model: int, d_ff: int, kind: str):
    if kind in ("swiglu", "geglu"):
        ini.param("wi", (d_model, 2 * d_ff), ("embed", "mlp"))
    else:
        ini.param("wi", (d_model, d_ff), ("embed", "mlp"))
    ini.param("wo", (d_ff, d_model), ("mlp", "embed"))


def mlp(params, x: jnp.ndarray, kind: str) -> jnp.ndarray:
    # wi/wo route through crossbar_linear so an enabled CrossbarMode (and
    # the programmed/repaired artifact path) covers the FFN, not just the
    # attention projections; with the mode disabled this is a plain matmul
    h = crossbar_linear(x, params["wi"])
    h = shard(h, "batch", None, "mlp")
    if kind in ("swiglu", "geglu"):
        u, g = jnp.split(h, 2, axis=-1)
        act = jax.nn.silu(g) if kind == "swiglu" else jax.nn.gelu(g)
        h = u * act
    elif kind == "gelu":
        h = jax.nn.gelu(h)
    elif kind == "relu2":
        h = jnp.square(jax.nn.relu(h))
    else:
        raise ValueError(kind)
    y = crossbar_linear(h, params["wo"])
    return shard(y, "batch", None, None)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, D) with positions (..., S) or (S,)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (D/2,)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, D/2)
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, D/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / LM head
# ---------------------------------------------------------------------------

def init_embed(ini: Init, vocab: int, d_model: int):
    ini.param("tokens", (vocab, d_model), ("vocab", "embed"), scale=0.02)


def embed(params, tokens: jnp.ndarray, scale: bool, d_model: int) -> jnp.ndarray:
    x = params["tokens"][tokens]
    x = shard(x, "batch", None, None)
    if scale:
        x = x * jnp.asarray(d_model**0.5, x.dtype)
    return x


def lm_head(table_or_w, x: jnp.ndarray, tied: bool, cap: float = 0.0) -> jnp.ndarray:
    # the LM head is the model's largest single projection; routing it
    # through crossbar_linear completes full-model crossbar coverage.  A
    # *tied* head multiplies a per-call transpose of the embedding table —
    # no stable leaf identity to bind a programmed artifact to — so putting
    # it on the crossbar would rerun the whole programming pipeline (fault
    # draw, write-verify, repair planning) inside every decode step,
    # breaking the engine's program-once guarantee; tied heads therefore
    # stay digital (ROADMAP: name-keyed artifact binding would lift this)
    if tied:
        logits = x @ table_or_w.T
    else:
        logits = crossbar_linear(x, table_or_w)
    logits = shard(logits, "batch", None, "vocab")
    if cap:
        logits = softcap(logits.astype(jnp.float32), cap)
    return logits


# ---------------------------------------------------------------------------
# CrossbarLinear — the paper's technique as a first-class serving feature
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CrossbarMode:
    """When enabled, every weight-bearing matmul — attention projections,
    dense-MLP wi/wo and the (untied) LM head — runs through the Newton
    bit-sliced crossbar datapath (Pallas kernel; interpret-mode on CPU)
    instead of XLA matmul; activation-activation products (attention
    scores/values) and tied LM heads (a per-call transpose, see ``lm_head``)
    stay digital (tests/test_models_smoke.py pins the coverage).

    ``device`` (a ``repro.device.DeviceConfig``) additionally routes the
    matmul through the memristor non-ideality pipeline — stuck cells,
    programming variation, drift, IR drop — so end-to-end model accuracy
    under realistic devices is one context manager away.

    ``programmed`` (a ``repro.device.programmed.ProgrammedModel``) is the
    program-once steady-state path: projections whose weight matches a
    compiled artifact skip quantization-scale reductions, fault redraw and
    write-verify entirely and serve from the fixed programmed chip; weights
    without an artifact fall back to the program-every-call path above."""

    enabled: bool = False
    fast: bool = True  # fused exact kernel (full-resolution ADC)
    device: Optional[Any] = None  # repro.device.DeviceConfig
    programmed: Optional[Any] = None  # repro.device.programmed.ProgrammedModel


_CROSSBAR = CrossbarMode()


def current_crossbar() -> CrossbarMode:
    """The active CrossbarMode (the all-default disabled mode when unset)."""
    return _CROSSBAR


@contextlib.contextmanager
def crossbar_mode(mode: CrossbarMode):
    global _CROSSBAR
    prev = _CROSSBAR
    _CROSSBAR = mode
    try:
        yield
    finally:
        _CROSSBAR = prev


def crossbar_linear(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """y = x @ w, optionally through the crossbar datapath (W16A16).

    Activations are offset-encoded (crossbar inputs are unsigned; the offset
    is corrected digitally — see ``core.crossbar.signed_vmm_limbs``).

    If a programmed artifact is bound for ``w`` (via
    ``CrossbarMode.programmed`` or an enclosing ``ProgrammedModel.bind``),
    the steady-state program-once path serves the call: quantize input ->
    Pallas kernel -> dequantize, with scales / effective cells / correction
    column sums all precomputed at programming time.  Otherwise the weight
    is programmed on the fly (the original per-call pipeline)."""
    if not _CROSSBAR.enabled:
        return x @ w
    from repro.device import programmed as prog
    from repro.kernels import ops as kops

    if _CROSSBAR.programmed is not None:
        art = _CROSSBAR.programmed.lookup(w)  # bind-stack first, then build map
    else:
        art = prog.active_artifact_for(w)
    if art is not None:
        # x passed as-is: programmed_linear offset-encodes in x.dtype before
        # casting, mirroring the fallback below op-for-op (pre-casting bf16
        # activations here would break bit-identity between the two paths)
        return prog.programmed_linear(x, art).astype(x.dtype)

    shift = jnp.min(x)
    xs = (x - shift).astype(jnp.float32)  # non-negative
    y = kops.crossbar_matmul(
        xs, w.astype(jnp.float32), device=_CROSSBAR.device, fast=_CROSSBAR.fast
    )
    corr = shift.astype(jnp.float32) * jnp.sum(w.astype(jnp.float32), axis=0)
    return (y + corr).astype(x.dtype)
