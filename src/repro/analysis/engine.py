"""AST lint engine for the crossbar stack's static contracts.

The runtime already defends the "zero-miss, bit-identical" contract with
miss counters, ``strict=`` and the eval_shape coverage sweep — but only
after a model runs.  This engine checks the same contracts from source
alone: every rule in ``rules_*`` is a function ``(relpath, tree, source)
-> findings`` over one parsed module, and ``run_lint`` maps them across
the repo's Python files.  Findings carry a severity: ``error`` findings
fail the ``python -m repro.analysis --check`` CI gate; ``info`` findings
(e.g. audited known-digital projections) are printed but do not fail —
they are the visible, auditable form of what used to be folklore.

Rules are registered in ``ALL_RULES`` (populated by ``repro.analysis``
importing the rule modules); each rule decides from ``relpath`` which
files it applies to, so the engine itself stays policy-free.
"""
from __future__ import annotations

import ast
import dataclasses
import os
from typing import Callable, Dict, Iterator, List, Optional, Sequence, Tuple

ERROR = "error"
INFO = "info"

# roots scanned by default, relative to the repo root
DEFAULT_ROOTS = ("src/repro", "benchmarks")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One lint finding: a rule violation (or audited ``info`` note) at a
    source location.  ``path`` is repo-root-relative with forward slashes,
    so findings are stable across machines and usable as fixture keys."""

    rule: str
    path: str
    line: int
    message: str
    level: str = ERROR

    def format(self) -> str:
        return f"{self.path}:{self.line}: {self.level}[{self.rule}] {self.message}"


Rule = Callable[[str, ast.Module, str], List[Finding]]

# populated by repro.analysis.__init__ importing the rule modules; kept as
# a mutable registry so tests can run single rules against fixture snippets
ALL_RULES: List[Rule] = []


def repo_root() -> str:
    """The directory containing ``src/repro`` (walk up from this file)."""
    d = os.path.dirname(os.path.abspath(__file__))
    while True:
        if os.path.isdir(os.path.join(d, "src", "repro")):
            return d
        parent = os.path.dirname(d)
        if parent == d:  # filesystem root: fall back to cwd
            return os.getcwd()
        d = parent


def iter_python_files(
    root: str, roots: Sequence[str] = DEFAULT_ROOTS
) -> Iterator[str]:
    """Repo-relative paths of every ``.py`` under the scanned roots."""
    for sub in roots:
        base = os.path.join(root, sub)
        if not os.path.isdir(base):
            continue
        for dirpath, dirnames, filenames in os.walk(base):
            dirnames[:] = [d for d in dirnames if d != "__pycache__"]
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    full = os.path.join(dirpath, fn)
                    yield os.path.relpath(full, root).replace(os.sep, "/")


def lint_source(
    relpath: str, source: str, rules: Optional[Sequence[Rule]] = None
) -> List[Finding]:
    """Run rules over one module's source (the fixture-test entry point)."""
    try:
        tree = ast.parse(source)
    except SyntaxError as e:
        return [
            Finding("syntax", relpath, e.lineno or 0, f"unparseable module: {e.msg}")
        ]
    out: List[Finding] = []
    for rule in rules if rules is not None else ALL_RULES:
        out.extend(rule(relpath, tree, source))
    return out


def run_lint(
    root: Optional[str] = None,
    roots: Sequence[str] = DEFAULT_ROOTS,
    rules: Optional[Sequence[Rule]] = None,
) -> List[Finding]:
    """Lint every Python file under ``roots``; findings sorted by location."""
    root = root or repo_root()
    findings: List[Finding] = []
    for relpath in iter_python_files(root, roots):
        with open(os.path.join(root, relpath), "r", encoding="utf-8") as f:
            source = f.read()
        findings.extend(lint_source(relpath, source, rules))
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings


# ---------------------------------------------------------------------------
# Shared AST helpers
# ---------------------------------------------------------------------------

def dotted_name(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def parent_map(tree: ast.AST) -> Dict[ast.AST, ast.AST]:
    parents: Dict[ast.AST, ast.AST] = {}
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            parents[child] = node
    return parents


def enclosing_functions(tree: ast.Module) -> Dict[ast.AST, str]:
    """Map every node to the name of its nearest enclosing function
    (module-level nodes are absent)."""
    owner: Dict[ast.AST, str] = {}

    def visit(node: ast.AST, fn: Optional[str]):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            fn = node.name
        for child in ast.iter_child_nodes(node):
            if fn is not None:
                owner[child] = fn
            visit(child, fn)

    visit(tree, None)
    return owner


def terminal_names(node: ast.AST) -> List[str]:
    """Terminal identifiers of an expression: Name ids plus Attribute attrs
    (``art.w_scale`` contributes ``w_scale``)."""
    out = []
    for n in ast.walk(node):
        if isinstance(n, ast.Name):
            out.append(n.id)
        elif isinstance(n, ast.Attribute):
            out.append(n.attr)
    return out
