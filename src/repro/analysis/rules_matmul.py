"""Digital-fallback detector: every matmul in ``src/repro/models/`` is
classified, or it is a finding.

Newton's premise only holds if every weight-bearing contraction reaches the
crossbar path (``models.layers.crossbar_linear`` -> programmed artifacts).
This rule inventories every ``jnp.dot`` / ``jnp.matmul`` / ``jnp.einsum`` /
``@`` site under ``src/repro/models/`` and checks it against an explicit
audit table keyed by ``(relpath, ast.unparse(site))``:

* ``allow`` — legitimately digital forever: weightless attention dots,
  recurrent scan state math, crossbar-disabled fallback branches that the
  runtime already guards (``current_crossbar().enabled`` /
  ``note_crossbar_gap``), and the one sanctioned dense fallback inside
  ``crossbar_linear`` itself.
* ``known`` — a *known-digital projection*: a weight contraction that has
  not been lifted onto the programmed path yet (ROADMAP #5's ssm/xlstm
  recurrent projections, MLA's absorbed W_uk/W_uv).  Reported as an
  ``info`` finding so the gap stays visible in every lint run instead of
  being folklore, but does not fail ``--check``.

Any site absent from the table is an ``error``: new matmuls in models/
must be deliberately classified before CI passes.  Keys are unparsed
source, not line numbers, so the table survives code motion and goes
stale loudly (an orphaned entry is itself a finding).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Tuple

from repro.analysis.engine import ERROR, INFO, Finding, dotted_name

RULE = "digital-fallback"

MATMUL_FUNCS = {"dot", "matmul", "einsum", "tensordot", "dot_general"}

# (relpath prefix the rule applies to)
SCOPE = "src/repro/models/"

# status: "allow" (legitimately digital) | "known" (known-digital projection,
# reported as info).  Keyed by exact ast.unparse of the site.
AUDIT: Dict[str, Dict[str, Tuple[str, str]]] = {
    "src/repro/models/ssm.py": {
        "x @ params['in_proj']": (
            "known", "mamba in_proj runs digital (ROADMAP #5 ssm lift)"),
        "xc @ params['x_proj']": (
            "known", "mamba x_proj runs digital (ROADMAP #5 ssm lift)"),
        "dt @ params['dt_proj']": (
            "known", "mamba dt_proj runs digital (ROADMAP #5 ssm lift)"),
        "y @ params['out_proj']": (
            "known", "mamba out_proj runs digital (ROADMAP #5 ssm lift)"),
        "jnp.einsum('bkd,kd->bd', window, params['conv_w'])": (
            "allow", "depthwise causal conv taps (K=d_conv) — not a dense slab"),
        "jnp.einsum('bdn,bn->bd', h, C_ssm.astype(jnp.float32)[:, 0])": (
            "allow", "weightless selective-scan state readout"),
        "jnp.einsum('bsdn,bsn->bsd', h_all, C_ssm.astype(jnp.float32))": (
            "allow", "weightless selective-scan state readout"),
    },
    "src/repro/models/xlstm.py": {
        # mLSTM chunk math: weightless q/k/v + per-chunk state tensors
        "jnp.einsum('bchd,bhde->bche', qf, C0)": (
            "allow", "weightless mLSTM inter-chunk state readout"),
        "jnp.einsum('bchd,bhd->bch', qf, n0)": (
            "allow", "weightless mLSTM normalizer readout"),
        "jnp.einsum('bthd,bshd->btsh', qf, kf)": (
            "allow", "weightless intra-chunk attention-form scores"),
        "jnp.einsum('btsh,bshe->bthe', scores, vf)": (
            "allow", "weightless intra-chunk value mix"),
        "jnp.einsum('btsh,bshd->bthd', D, kf)": (
            "allow", "weightless decay-weighted key sum"),
        "jnp.einsum('bhd,bth->bthd', n0, decay_t)": (
            "allow", "weightless normalizer decay"),
        "jnp.einsum('bthd,bthd->bth', n_tot, qf)": (
            "allow", "weightless normalizer dot"),
        "jnp.einsum('bch,bchd,bche->bhde', w_s, kf, vf)": (
            "allow", "weightless chunk state update (k (x) v outer)"),
        "jnp.einsum('bch,bchd->bhd', w_s, kf)": (
            "allow", "weightless chunk normalizer update"),
        # mLSTM decode recurrent state math (O(1) step)
        "jnp.einsum('bhd,bhe->bhde', kf, vf)": (
            "allow", "weightless decode state outer product"),
        "jnp.einsum('bhde,bhd->bhe', C1, qf)": (
            "allow", "weightless decode state readout"),
        "jnp.einsum('bhd,bhd->bh', n1, qf)": (
            "allow", "weightless decode normalizer dot"),
        # sLSTM recurrence: per-step hidden-to-hidden inside the scan body
        # (the slstm_scan Pallas kernel's domain — sequential step math, not
        # a programmable weight slab)
        "jnp.einsum('bhd,hde->bhe', h_, rz.astype(jnp.float32))": (
            "allow", "sequential sLSTM recurrence inside the scan step"),
        "jnp.einsum('bhd,hde->bhe', h_, ri.astype(jnp.float32))": (
            "allow", "sequential sLSTM recurrence inside the scan step"),
        "jnp.einsum('bhd,hde->bhe', h_, rf.astype(jnp.float32))": (
            "allow", "sequential sLSTM recurrence inside the scan step"),
        "jnp.einsum('bhd,hde->bhe', h_, ro.astype(jnp.float32))": (
            "allow", "sequential sLSTM recurrence inside the scan step"),
        # input/output projections: dense slabs still off the crossbar path
        "x @ params['wqkv']": (
            "known", "xLSTM qkv projection runs digital (ROADMAP #5 lift)"),
        "x @ params['w_gates']": (
            "known", "xLSTM gate projection runs digital (ROADMAP #5 lift)"),
        "x @ params['w_ogate']": (
            "known", "xLSTM output-gate projection runs digital (ROADMAP #5 lift)"),
        "y @ params['out_proj']": (
            "known", "mLSTM out_proj runs digital (ROADMAP #5 lift)"),
        "x @ params['w_in']": (
            "known", "sLSTM input projection runs digital (ROADMAP #5 lift)"),
        "y.astype(x.dtype) @ params['out_proj']": (
            "known", "sLSTM out_proj runs digital (ROADMAP #5 lift)"),
    },
    "src/repro/models/attention.py": {
        "jnp.einsum('bqgrd,bsgd->bqgrs', q, k, preferred_element_type=jnp.float32)": (
            "allow", "weightless GQA attention scores"),
        "jnp.einsum('bqgrs,bsgd->bqgrd', p, v.astype(p.dtype))": (
            "allow", "weightless GQA value mix"),
        "jnp.einsum('bqhl,bsl->bqhs', q_abs_blk.astype(latent_k.dtype), latent_k, preferred_element_type=jnp.float32)": (
            "allow", "weightless MLA scores vs cached latents"),
        "jnp.einsum('bqhr,bsr->bqhs', q_rope_blk.astype(rope_k.dtype), rope_k, preferred_element_type=jnp.float32)": (
            "allow", "weightless MLA rope scores vs cached keys"),
        "jnp.einsum('bqhs,bsl->bqhl', p.astype(latent_k.dtype), latent_k, preferred_element_type=jnp.float32)": (
            "allow", "weightless MLA latent value mix"),
        "jnp.einsum('bshd,lhd->bshl', q_nope, params['w_uk'])": (
            "known", "MLA absorbed W_uk projection runs digital "
                     "(per-head low-rank absorb — ROADMAP #5 lift)"),
        "jnp.einsum('bqhl,lhd->bqhd', ctx, params['w_uv'].astype(jnp.float32))": (
            "known", "MLA absorbed W_uv projection runs digital "
                     "(per-head low-rank absorb — ROADMAP #5 lift)"),
    },
    "src/repro/models/moe.py": {
        # digital fallback branch: runs only when current_crossbar().enabled
        # is False (the crossbar-off serving mode)
        "jnp.einsum('ecd,edf->ecf', h, wi)": (
            "allow", "crossbar-disabled digital branch (guarded by "
                     "current_crossbar().enabled)"),
        "jnp.einsum('ecd,edf->ecf', h, wg)": (
            "allow", "crossbar-disabled digital branch (guarded by "
                     "current_crossbar().enabled)"),
        "jnp.einsum('ecf,efd->ecd', a, wo)": (
            "allow", "crossbar-disabled digital branch (guarded by "
                     "current_crossbar().enabled)"),
        # runtime-audited gaps: note_crossbar_gap records these misses
        "jnp.einsum('ecd,edf->ecf', h, w_l)": (
            "known", "grouped expert fallback runs digital — runtime-audited "
                     "via note_crossbar_gap"),
        "xf @ rw_l.astype(xf.dtype)": (
            "known", "router projection runs digital — runtime-audited via "
                     "note_crossbar_gap('router')"),
    },
    "src/repro/models/layers.py": {
        "x @ w": (
            "allow", "the sanctioned dense fallback inside crossbar_linear "
                     "itself — guarded by the miss counter and strict="),
    },
    "src/repro/models/model.py": {},
}


def _matmul_sites(tree: ast.Module) -> List[ast.AST]:
    sites = []
    for node in ast.walk(tree):
        if isinstance(node, ast.BinOp) and isinstance(node.op, ast.MatMult):
            sites.append(node)
        elif isinstance(node, ast.Call):
            dn = dotted_name(node.func)
            if dn is not None and dn.split(".")[-1] in MATMUL_FUNCS:
                sites.append(node)
    return sites


def rule_digital_fallback(relpath: str, tree: ast.Module, source: str) -> List[Finding]:
    if not relpath.startswith(SCOPE):
        return []
    table = AUDIT.get(relpath, {})
    findings: List[Finding] = []
    seen = set()
    for node in _matmul_sites(tree):
        key = ast.unparse(node)
        seen.add(key)
        entry = table.get(key)
        if entry is None:
            findings.append(Finding(
                RULE, relpath, node.lineno,
                f"unclassified matmul site: `{key}` — route it through "
                "crossbar_linear or add an 'allow'/'known' entry to "
                "repro.analysis.rules_matmul.AUDIT",
            ))
        elif entry[0] == "known":
            findings.append(Finding(
                RULE, relpath, node.lineno,
                f"known-digital projection: `{key}` ({entry[1]})",
                level=INFO,
            ))
        elif entry[0] != "allow":
            findings.append(Finding(
                RULE, relpath, node.lineno,
                f"bad AUDIT status {entry[0]!r} for `{key}` "
                "(must be 'allow' or 'known')",
            ))
    # stale entries: audited sites that no longer exist go loudly, so the
    # table can never accrete dead reassurances
    for key in table:
        if key not in seen:
            findings.append(Finding(
                RULE, relpath, 0,
                f"stale AUDIT entry (site no longer in file): `{key}`",
            ))
    return findings
