"""Pallas kernel contract rule.

Kernel bodies (functions taking ``*_ref`` parameters) execute inside the
Pallas tracer: Python-level side effects don't run per grid step the way
they read, data-dependent Python branches on ``pl.program_id`` silently
specialize to one trace, and a BlockSpec index map whose lambda arity
disagrees with the grid raises only at call time on the machine that
first exercises the kernel.  Statically enforced here:

* no Python side effects in a kernel body (``print``/``open``/
  ``breakpoint``, wall clock, numpy global RNG);
* no ``global``/``nonlocal`` state;
* no Python ``if`` on ``pl.program_id`` — grid-position guards must be
  ``@pl.when`` so they stay inside the traced computation;
* every ``pl.BlockSpec`` index-map lambda has exactly as many arguments
  as the ``pallas_call`` grid has dimensions (grid resolved from a tuple
  literal, an int literal, or a same-function tuple assignment).
"""
from __future__ import annotations

import ast
from typing import List, Optional

from repro.analysis.engine import ERROR, Finding, dotted_name

RULE = "pallas-contract"

SCOPE = "src/repro/kernels/"

_SIDE_EFFECT_CALLS = {"print", "open", "input", "breakpoint", "exec", "eval"}


def _is_kernel(fn: ast.FunctionDef) -> bool:
    names = [a.arg for a in fn.args.args + fn.args.kwonlyargs]
    return sum(1 for n in names if n.endswith("_ref")) >= 2


def _kernel_body_findings(relpath: str, fn: ast.FunctionDef) -> List[Finding]:
    findings: List[Finding] = []
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            dn = dotted_name(node.func) or ""
            leaf = dn.split(".")[-1]
            if dn in _SIDE_EFFECT_CALLS:
                findings.append(Finding(
                    RULE, relpath, node.lineno,
                    f"Python side effect `{dn}(...)` inside kernel "
                    f"{fn.name}() — kernel bodies must be pure traced code",
                ))
            elif dn.endswith("time.time") or (
                ".random." in dn and dn.startswith(("np.", "numpy."))
            ):
                findings.append(Finding(
                    RULE, relpath, node.lineno,
                    f"host-state call `{dn}` inside kernel {fn.name}()",
                ))
        elif isinstance(node, (ast.Global, ast.Nonlocal)):
            findings.append(Finding(
                RULE, relpath, node.lineno,
                f"`{'global' if isinstance(node, ast.Global) else 'nonlocal'}` "
                f"state in kernel {fn.name}() — kernels cannot carry Python "
                "state across grid steps",
            ))
        elif isinstance(node, ast.If):
            if any(
                isinstance(n, ast.Attribute) and n.attr == "program_id"
                for n in ast.walk(node.test)
            ):
                findings.append(Finding(
                    RULE, relpath, node.lineno,
                    f"Python `if` on pl.program_id in kernel {fn.name}() — "
                    "the branch is resolved once at trace time; guard with "
                    "@pl.when so it executes per grid step",
                ))
    return findings


def _grid_len(call: ast.Call, enclosing: Optional[ast.FunctionDef]) -> Optional[int]:
    grid = None
    for kw in call.keywords:
        if kw.arg == "grid":
            grid = kw.value
    if grid is None:
        return None
    if isinstance(grid, ast.Tuple):
        return len(grid.elts)
    if isinstance(grid, ast.Constant) and isinstance(grid.value, int):
        return 1
    if isinstance(grid, ast.Name) and enclosing is not None:
        # resolve a local ``grid = (gm, gn)`` tuple assignment
        for node in ast.walk(enclosing):
            if isinstance(node, ast.Assign):
                for t in node.targets:
                    if isinstance(t, ast.Name) and t.id == grid.id:
                        if isinstance(node.value, ast.Tuple):
                            return len(node.value.elts)
    return None


def _blockspec_arity_findings(
    relpath: str, call: ast.Call, enclosing: Optional[ast.FunctionDef]
) -> List[Finding]:
    g = _grid_len(call, enclosing)
    if g is None:
        return []
    findings: List[Finding] = []
    for node in ast.walk(call):
        if not isinstance(node, ast.Call):
            continue
        dn = dotted_name(node.func) or ""
        if dn.split(".")[-1] != "BlockSpec":
            continue
        for arg in list(node.args) + [kw.value for kw in node.keywords]:
            if isinstance(arg, ast.Lambda):
                arity = len(arg.args.args)
                if arity != g:
                    findings.append(Finding(
                        RULE, relpath, arg.lineno,
                        f"BlockSpec index map takes {arity} arg(s) but the "
                        f"pallas_call grid has {g} dimension(s)",
                    ))
    return findings


def rule_pallas(relpath: str, tree: ast.Module, source: str) -> List[Finding]:
    if not relpath.startswith(SCOPE):
        return []
    findings: List[Finding] = []
    for fn in ast.walk(tree):
        if isinstance(fn, ast.FunctionDef) and _is_kernel(fn):
            findings.extend(_kernel_body_findings(relpath, fn))
    # pallas_call grid/BlockSpec arity, resolved per enclosing function
    for fn in [None] + [
        n for n in ast.walk(tree) if isinstance(n, ast.FunctionDef)
    ]:
        scope = tree if fn is None else fn
        for node in (scope.body if fn is None else [fn]):
            for call in ast.walk(node):
                if not isinstance(call, ast.Call):
                    continue
                dn = dotted_name(call.func) or ""
                if dn.split(".")[-1] == "pallas_call":
                    findings.extend(
                        _blockspec_arity_findings(relpath, call, fn)
                    )
    # dedupe: module-level pass sees function bodies too
    uniq = {}
    for f in findings:
        uniq[(f.line, f.message)] = f
    return sorted(uniq.values(), key=lambda f: f.line)
