"""Offline artifact-store & plan verifier.

``verify_store(path)`` validates a ``checkpoint.save_programmed`` store
from its manifest and npz *headers* alone — no arrays are loaded, no model
runs, nothing is device_put — so it is cheap enough to run fail-fast at
every ``ServingEngine(restore_artifacts=)`` construction and offline in CI
against fleet stores.  Checked:

* **store resolution** — slot A/B layout, ``programmed.ACTIVE`` pointer
  (a corrupt or dangling pointer is a finding, not a crash), crash-recovery
  candidates (``.tmp``/``.old``) in the same completeness order
  ``restore_programmed`` uses;
* **manifest schema** — known schema version, required per-artifact keys,
  decodable ``CrossbarSpec`` / ``ADCConfig`` / ``DeviceConfig`` / reports
  (tolerant of pre-planner and pre-lifecycle manifests, which carry no
  ``plan`` / ``device`` / ``t_service_s``);
* **array leaves** — every npz member is a known ``ProgrammedLinear``
  array field, the mandatory fields are present, and (via npz headers)
  ``g_eff`` is (n_slices, K, N)-consistent with ``w_codes`` and the spec;
  ``g_spare``/``out_gather`` travel as a pair;
* **sharding specs** — recorded PartitionSpecs name only known fields and
  fit the array ranks;
* **plan admissibility** — each ``LayerPlan`` decodes (unknown datapath /
  ADC mode fails in ``LayerPlan.__post_init__``), its ADC config matches
  the recorded one, its datapath crossbar factor fits an optional
  ``max_crossbar_factor`` area budget, and its ADC mode satisfies an
  optional ``exactness`` contract;
* **name-set vs a model** — pass ``expected`` (from
  ``device.programmed.expected_artifact_names``) to cross-check the store
  against what a given params tree would program: missing / extra names
  and per-name ``w_codes`` shape mismatches are findings.
"""
from __future__ import annotations

import dataclasses
import json
import os
import zipfile
from typing import Dict, List, Optional, Tuple

REQUIRED_INFO_KEYS = ("file", "spec", "adc_cfg", "fast", "report", "repair")
MANDATORY_ARRAYS = ("w_codes", "w_colsum", "w_scale")
KNOWN_SCHEMAS = (1,)


@dataclasses.dataclass(frozen=True)
class StoreFinding:
    rule: str
    message: str
    name: Optional[str] = None  # artifact name, when the finding is per-leaf

    def format(self) -> str:
        where = f" [{self.name}]" if self.name else ""
        return f"[{self.rule}]{where} {self.message}"


@dataclasses.dataclass
class StoreReport:
    directory: str
    resolved: Optional[str]  # directory actually holding the manifest
    slot: Optional[str]
    findings: List[StoreFinding]
    n_artifacts: int = 0

    @property
    def ok(self) -> bool:
        return not self.findings

    def summary(self) -> str:
        head = (
            f"store {self.directory!r}"
            + (f" (slot {self.slot})" if self.slot else "")
            + f": {self.n_artifacts} artifact(s), "
            + ("OK" if self.ok else f"{len(self.findings)} finding(s)")
        )
        return "\n".join([head] + ["  " + f.format() for f in self.findings])


def _npz_headers(path: str) -> Dict[str, Tuple[Tuple[int, ...], str]]:
    """{member: (shape, dtype)} from npz headers — no array data is read."""
    from numpy.lib import format as npformat

    out: Dict[str, Tuple[Tuple[int, ...], str]] = {}
    with zipfile.ZipFile(path) as z:
        for member in z.namelist():
            if not member.endswith(".npy"):
                continue
            with z.open(member) as f:
                version = npformat.read_magic(f)
                try:
                    shape, _, dtype = npformat._read_array_header(f, version)
                except AttributeError:  # very old numpy: public per-version API
                    reader = {
                        (1, 0): npformat.read_array_header_1_0,
                        (2, 0): npformat.read_array_header_2_0,
                    }[version]
                    shape, _, dtype = reader(f)
            out[member[: -len(".npy")]] = (tuple(shape), str(dtype))
    return out


def _resolve(directory: str, slot: Optional[str], findings: List[StoreFinding]):
    """Mirror ``restore_programmed``'s store resolution, turning pointer
    corruption into findings.  Returns (resolved_dir_or_None, slot)."""
    from repro.checkpoint.checkpoint import PROGRAMMED_SLOTS, _active_pointer

    if slot is None:
        ptr = _active_pointer(directory)
        if os.path.isfile(ptr):
            with open(ptr) as f:
                content = f.read().strip()
            if content not in PROGRAMMED_SLOTS:
                findings.append(StoreFinding(
                    "active-pointer",
                    f"corrupt programmed.ACTIVE pointer: {content!r} is not "
                    f"one of {PROGRAMMED_SLOTS}",
                ))
                return None, None
            slot = content
    if slot is not None:
        base = os.path.join(directory, f"programmed.slot{slot}")
        candidates = [base, base + ".tmp", base + ".old"]
    else:
        base = os.path.join(directory, "programmed")
        candidates = [base, base + ".tmp", base + ".old", directory]
    for c in candidates:
        if os.path.isfile(os.path.join(c, "manifest.json")):
            return c, slot
    if slot is not None:
        findings.append(StoreFinding(
            "active-pointer",
            f"dangling ACTIVE pointer: slot {slot} has no manifest.json "
            f"under {directory!r} (swap_active would have refused this)",
        ))
    else:
        findings.append(StoreFinding(
            "store", f"no programmed-artifact store under {directory!r}"
        ))
    return None, slot


def verify_store(
    directory: str,
    expected: Optional[Dict[str, Tuple[int, ...]]] = None,
    slot: Optional[str] = None,
    max_crossbar_factor: Optional[float] = None,
    exactness: Optional[str] = None,
) -> StoreReport:
    from repro.core.adc import ADCConfig
    from repro.core.crossbar import CrossbarSpec
    from repro.core.planner import adc_config_for, datapath_crossbar_factor
    from repro.checkpoint.checkpoint import _decode_aux, _decode_plan
    from repro.device.models import DeviceConfig
    from repro.device.programmed import ARTIFACT_ARRAY_FIELDS

    findings: List[StoreFinding] = []
    resolved, slot = _resolve(directory, slot, findings)
    if resolved is None:
        return StoreReport(directory, None, slot, findings)

    try:
        with open(os.path.join(resolved, "manifest.json")) as f:
            manifest = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        findings.append(StoreFinding("manifest", f"unreadable manifest: {e}"))
        return StoreReport(directory, resolved, slot, findings)

    schema = manifest.get("schema")
    if schema not in KNOWN_SCHEMAS:
        findings.append(StoreFinding(
            "manifest",
            f"unknown store schema {schema!r} (this checker knows "
            f"{KNOWN_SCHEMAS}) — refusing to certify",
        ))
    artifacts = manifest.get("artifacts")
    if not isinstance(artifacts, dict) or not artifacts:
        findings.append(StoreFinding(
            "manifest", "manifest has no artifacts — nothing to serve from"
        ))
        return StoreReport(directory, resolved, slot, findings)

    for name, info in artifacts.items():
        missing_keys = [k for k in REQUIRED_INFO_KEYS if k not in info]
        if missing_keys:
            findings.append(StoreFinding(
                "manifest", f"missing manifest key(s) {missing_keys}", name
            ))
            continue

        # -- spec / configs decode ------------------------------------------
        spec = None
        try:
            spec = CrossbarSpec(**info["spec"])
        except TypeError as e:
            findings.append(StoreFinding("spec", f"undecodable CrossbarSpec: {e}", name))
        adc_cfg = None
        if info["adc_cfg"] is not None:
            try:
                adc_cfg = ADCConfig(**info["adc_cfg"])
            except TypeError as e:
                findings.append(StoreFinding("spec", f"undecodable ADCConfig: {e}", name))
        if info.get("device") is not None:
            try:
                DeviceConfig(**info["device"])
            except TypeError as e:
                findings.append(StoreFinding(
                    "spec", f"undecodable DeviceConfig: {e}", name
                ))
        t = info.get("t_service_s", 0.0)
        if not isinstance(t, (int, float)) or t < 0.0:
            findings.append(StoreFinding(
                "spec", f"invalid t_service_s {t!r} (service clock)", name
            ))
        for aux_key in ("report", "repair"):
            try:
                _decode_aux(info[aux_key])
            except (KeyError, TypeError, ValueError) as e:
                findings.append(StoreFinding(
                    "manifest", f"undecodable {aux_key} aux: {e}", name
                ))

        # -- array leaves via npz headers -----------------------------------
        npz_path = os.path.join(resolved, info["file"])
        headers = None
        if not os.path.isfile(npz_path):
            findings.append(StoreFinding(
                "arrays", f"missing array file {info['file']!r}", name
            ))
        else:
            try:
                headers = _npz_headers(npz_path)
            except (zipfile.BadZipFile, KeyError, ValueError, OSError) as e:
                findings.append(StoreFinding(
                    "arrays", f"unreadable npz {info['file']!r}: {e}", name
                ))
        if headers is not None:
            unknown = sorted(set(headers) - set(ARTIFACT_ARRAY_FIELDS))
            if unknown:
                findings.append(StoreFinding(
                    "arrays",
                    f"unknown array field(s) {unknown} — not ProgrammedLinear "
                    "leaves",
                    name,
                ))
            absent = [k for k in MANDATORY_ARRAYS if k not in headers]
            if absent:
                findings.append(StoreFinding(
                    "arrays", f"mandatory array field(s) {absent} missing", name
                ))
            if ("g_spare" in headers) != ("out_gather" in headers):
                findings.append(StoreFinding(
                    "arrays",
                    "g_spare/out_gather must travel as a pair (spare block "
                    "without its gather table is unservable)",
                    name,
                ))
            if spec is not None and "w_codes" in headers and "g_eff" in headers:
                wshape = headers["w_codes"][0]
                gshape = headers["g_eff"][0]
                if len(wshape) == 2:
                    want = (spec.n_slices,) + wshape
                    if gshape != want:
                        findings.append(StoreFinding(
                            "arrays",
                            f"g_eff shape {gshape} inconsistent with w_codes "
                            f"{wshape} under spec (expected {want}: one "
                            f"{spec.cell_bits}-bit slice plane per of "
                            f"{spec.n_slices})",
                            name,
                        ))

        # -- sharding specs --------------------------------------------------
        sharding = info.get("sharding")
        if sharding is not None:
            if not isinstance(sharding, dict):
                findings.append(StoreFinding(
                    "sharding", f"sharding must be a dict, got {type(sharding).__name__}", name
                ))
            else:
                bad_fields = sorted(set(sharding) - set(ARTIFACT_ARRAY_FIELDS))
                if bad_fields:
                    findings.append(StoreFinding(
                        "sharding", f"sharding names unknown field(s) {bad_fields}", name
                    ))
                for field, entries in sharding.items():
                    if not isinstance(entries, list) or not all(
                        e is None or isinstance(e, (str, list)) for e in entries
                    ):
                        findings.append(StoreFinding(
                            "sharding",
                            f"malformed PartitionSpec for {field}: {entries!r}",
                            name,
                        ))
                    elif headers is not None and field in headers:
                        rank = len(headers[field][0])
                        if len(entries) > rank:
                            findings.append(StoreFinding(
                                "sharding",
                                f"PartitionSpec for {field} has "
                                f"{len(entries)} entries but the array is "
                                f"rank {rank}",
                                name,
                            ))

        # -- plan admissibility ----------------------------------------------
        if info.get("plan") is not None:
            plan = None
            try:
                plan = _decode_plan(info["plan"])
            except (TypeError, ValueError) as e:
                findings.append(StoreFinding("plan", f"inadmissible plan: {e}", name))
            if plan is not None and spec is not None:
                if adc_cfg is not None:
                    try:
                        want_adc = adc_config_for(plan.adc_mode, spec)
                    except (KeyError, ValueError):
                        want_adc = None
                    if want_adc is not None and dataclasses.asdict(
                        want_adc
                    ) != dataclasses.asdict(adc_cfg):
                        findings.append(StoreFinding(
                            "plan",
                            f"recorded ADCConfig disagrees with plan's "
                            f"adc_mode={plan.adc_mode!r} under the recorded "
                            "spec — the chip is not the chip the plan admitted",
                            name,
                        ))
                if max_crossbar_factor is not None:
                    factor = datapath_crossbar_factor(plan.datapath, spec)
                    if factor > max_crossbar_factor:
                        findings.append(StoreFinding(
                            "plan",
                            f"plan over budget: datapath {plan.datapath!r} "
                            f"needs {factor:.2f}x crossbars > "
                            f"max_crossbar_factor={max_crossbar_factor}",
                            name,
                        ))
                if exactness is not None and headers is not None and "w_codes" in headers:
                    from repro.core.planner import _admissible_adc_modes

                    rows = headers["w_codes"][0][0] if headers["w_codes"][0] else 0
                    admissible = _admissible_adc_modes(spec, rows, exactness)
                    if plan.adc_mode not in admissible:
                        findings.append(StoreFinding(
                            "plan",
                            f"adc_mode {plan.adc_mode!r} violates the "
                            f"{exactness!r} exactness contract "
                            f"(admissible: {sorted(admissible)})",
                            name,
                        ))

        # -- name-set / shape vs the model -----------------------------------
        if expected is not None and name in expected and headers is not None:
            want = tuple(expected[name])
            got = headers.get("w_codes", ((), ""))[0]
            if len(got) == len(want) and got != want:
                findings.append(StoreFinding(
                    "name-set",
                    f"w_codes shape {got} != model's expected {want}",
                    name,
                ))

    if expected is not None:
        store_names = set(artifacts)
        want_names = set(expected)
        for n in sorted(want_names - store_names):
            findings.append(StoreFinding(
                "name-set",
                "model expects an artifact the store lacks — restore would "
                "silently fall back to per-call reprogramming",
                n,
            ))
        for n in sorted(store_names - want_names):
            findings.append(StoreFinding(
                "name-set",
                "store carries an artifact the model never consumes "
                "(orphaned leaf — saved from a different model/config?)",
                n,
            ))

    return StoreReport(directory, resolved, slot, findings, n_artifacts=len(artifacts))
