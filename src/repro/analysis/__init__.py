"""Static contract checker for the crossbar stack.

``repro.analysis`` enforces, from source and manifests alone, the
invariants the runtime can only observe after the fact:

* ``run_lint`` — AST rules over ``src/repro`` + ``benchmarks``:
  digital-fallback audit (every models/ matmul classified), determinism
  (seeded RNG, ``optimization_barrier``-pinned scale products), stage-key
  registry collisions, aux-slot shadowing, Pallas kernel contracts.
* ``verify_store`` — offline validation of a ``save_programmed`` artifact
  store (manifest schema, npz-header shapes, slot/ACTIVE consistency,
  plan admissibility) without loading arrays or running a model;
  ``ServingEngine(restore_artifacts=)`` runs it fail-fast before binding.

CLI: ``python -m repro.analysis [--check] [--store DIR]`` — ``--check``
exits nonzero on any error-level finding (the CI gate wired into
``scripts/run_tests.sh``).
"""
from repro.analysis.engine import (  # noqa: F401
    ALL_RULES,
    ERROR,
    INFO,
    Finding,
    lint_source,
    repo_root,
    run_lint,
)
from repro.analysis.rules_determinism import rule_barrier, rule_rng
from repro.analysis.rules_device import rule_shadowing, rule_stage_keys
from repro.analysis.rules_matmul import rule_digital_fallback
from repro.analysis.rules_pallas import rule_pallas
from repro.analysis.store import StoreFinding, StoreReport, verify_store  # noqa: F401

# rule registry: order is display order for same-line findings
for _rule in (
    rule_digital_fallback,
    rule_rng,
    rule_barrier,
    rule_stage_keys,
    rule_shadowing,
    rule_pallas,
):
    if _rule not in ALL_RULES:
        ALL_RULES.append(_rule)
