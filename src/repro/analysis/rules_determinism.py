"""Determinism lint: seeded randomness and pinned scale arithmetic.

Serving is contractually deterministic in (seed, admission order), and the
programmed-crossbar steady state is contractually *bit-identical* across
restarts/retraces.  Three statically checkable hazards:

* **unseeded RNG** — a ``PRNGKey``/``default_rng`` whose seed is neither a
  literal nor derived from an identifier containing "seed" breaks replay;
  module-level ``np.random.*`` samplers use hidden global state; and
  ``time.time`` anywhere in ``src/`` injects wall clock (allowlisted for
  the two telemetry sites that only *report* time).
* **unpinned scale products** — PR 5 pinned FMA-contraction ULP flips by
  wrapping every product of two quantization scales in
  ``jax.lax.optimization_barrier`` (XLA may otherwise fuse
  ``(x * a) * b`` into ``x * (a * b)`` differently across retraces).  Any
  ``*_scale * *_scale`` arithmetic in the device family outside a barrier
  is a finding.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Set, Tuple

from repro.analysis.engine import (
    ERROR,
    Finding,
    dotted_name,
    parent_map,
    terminal_names,
)

RULE_RNG = "determinism-rng"
RULE_BARRIER = "determinism-barrier"

# whole-file allowlist for wall-clock reads: these report time, they never
# feed it into computation
TIME_ALLOW: Dict[str, str] = {
    "src/repro/train/loop.py": "step-time telemetry in training metrics",
    "src/repro/launch/dryrun.py": "compile-walltime reporting",
}

# np.random attributes that touch the hidden global generator
_GLOBAL_SAMPLERS = {
    "seed", "rand", "randn", "randint", "random", "random_sample", "normal",
    "uniform", "choice", "permutation", "shuffle", "poisson", "exponential",
    "standard_normal", "binomial",
}

# files the barrier rule applies to: the programmed steady-state path and
# its lifecycle compensation
BARRIER_SCOPE = ("src/repro/device/",)


def _seed_ok(args: List[ast.AST]) -> bool:
    """A seed argument is acceptable if any part of it is an int literal or
    an identifier mentioning seed/key/rng/tag/chip (derived randomness)."""
    for arg in args:
        for n in ast.walk(arg):
            if isinstance(n, ast.Constant) and isinstance(n.value, int):
                return True
            name = None
            if isinstance(n, ast.Name):
                name = n.id
            elif isinstance(n, ast.Attribute):
                name = n.attr
            if name is not None and any(
                s in name.lower() for s in ("seed", "key", "rng", "tag", "chip")
            ):
                return True
    return False


def rule_rng(relpath: str, tree: ast.Module, source: str) -> List[Finding]:
    findings: List[Finding] = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            dn = dotted_name(node.func) or ""
            leaf = dn.split(".")[-1]
            if leaf == "PRNGKey":
                if not node.args or not _seed_ok(list(node.args)):
                    findings.append(Finding(
                        RULE_RNG, relpath, node.lineno,
                        f"PRNGKey seed `{ast.unparse(node)}` is neither a "
                        "literal nor derived from a seed — replay breaks",
                    ))
            elif leaf == "default_rng" and not node.args and not node.keywords:
                findings.append(Finding(
                    RULE_RNG, relpath, node.lineno,
                    "unseeded np.random.default_rng() — pass an explicit seed",
                ))
        elif isinstance(node, ast.Attribute):
            dn = dotted_name(node)
            if dn is None:
                continue
            parts = dn.split(".")
            if (
                len(parts) >= 3
                and parts[-3] in ("np", "numpy")
                and parts[-2] == "random"
                and parts[-1] in _GLOBAL_SAMPLERS
            ):
                findings.append(Finding(
                    RULE_RNG, relpath, node.lineno,
                    f"`{dn}` uses numpy's hidden global RNG state — use a "
                    "seeded np.random.default_rng(seed) generator",
                ))
            elif dn.endswith("time.time") and relpath.startswith("src/"):
                if relpath not in TIME_ALLOW:
                    findings.append(Finding(
                        RULE_RNG, relpath, node.lineno,
                        "wall-clock `time.time` in src/ — outputs must be a "
                        "function of (config, seed); allowlist telemetry-only "
                        "sites in rules_determinism.TIME_ALLOW",
                    ))
    # dedupe attribute findings that also appear inside a flagged Call, and
    # repeated Name/Attribute walks of the same node chain
    uniq = {}
    for f in findings:
        uniq[(f.rule, f.line, f.message)] = f
    return list(uniq.values())


def rule_barrier(relpath: str, tree: ast.Module, source: str) -> List[Finding]:
    if not relpath.startswith(BARRIER_SCOPE):
        return []
    parents = parent_map(tree)
    findings: List[Finding] = []
    for node in ast.walk(tree):
        if not (isinstance(node, ast.BinOp) and isinstance(node.op, ast.Mult)):
            continue
        left = {n for n in terminal_names(node.left)
                if n == "scale" or n.endswith("_scale")}
        right = {n for n in terminal_names(node.right)
                 if n == "scale" or n.endswith("_scale")}
        # the hazard is a product of two *different* scale values (the FMA
        # contraction XLA may reassociate across retraces); x/scale*scale
        # grid snaps and single-scale dequantizes are not it
        if not left or not right or left == right:
            continue
        # climb through arithmetic to the expression's owning call: the
        # product is pinned if any ancestor on the pure-expression chain is
        # an optimization_barrier call
        cur: ast.AST = node
        pinned = False
        while True:
            parent = parents.get(cur)
            if parent is None:
                break
            if isinstance(parent, ast.Call):
                dn = dotted_name(parent.func) or ""
                if dn.split(".")[-1] == "optimization_barrier":
                    pinned = True
                break
            if isinstance(parent, (ast.BinOp, ast.Tuple, ast.UnaryOp)):
                cur = parent
                continue
            break
        if not pinned:
            findings.append(Finding(
                RULE_BARRIER, relpath, node.lineno,
                f"scale product `{ast.unparse(node)}` is not pinned with "
                "jax.lax.optimization_barrier — XLA fusion may reassociate "
                "the FMA contraction and flip ULPs across retraces",
            ))
    return findings
