"""Device-family contract rules: stage-key registry and aux-slot shadowing.

**Stage-key collision.**  All programming randomness flows through
``device.models._stage_key``; independence of the fault / program /
spare_faults / spare_program draws rests entirely on each stage folding a
*distinct* index into the key.  The registry in ``device/models.py``
(``STAGE_*`` constants + ``_STAGES``) is the single source of truth:

* the registry itself must be collision-free (distinct stage names AND
  distinct fold_in indices — a duplicate index correlates two supposedly
  independent fields), and built from constants, not ad-hoc literals;
* call sites must pass the constants — a string literal ``stage="..."``
  (or a literal second arg to ``_stage_key``) dodges the registry and is
  flagged wherever it appears;
* duplicate integer-literal ``fold_in(key, <n>)`` indices within one file
  are flagged: two different streams folding the same literal collide.

**Aux-slot shadowing.**  ``ProgrammedLinear`` carries hashable aux slots
(``spec``/``adc_cfg``/``report``/``repair``/``device``/``plan``) whose
names are also natural local-variable names.  PR 7 shipped exactly this
bug: ``plan = plan_repair(...)`` rebound the layer's ``LayerPlan`` local
to a ``RepairPlan`` and the wrong object rode into the artifact.  Inside
the device family, any local binding of an aux-slot name must be in the
audited allowlist (file, function, name) or it is a finding.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.engine import ERROR, Finding, dotted_name

RULE_STAGE = "stage-key-collision"
RULE_SHADOW = "aux-slot-shadowing"

REGISTRY_FILE = "src/repro/device/models.py"

# files where aux-slot locals are load-bearing (the ProgrammedLinear family)
SHADOW_SCOPE = (
    "src/repro/device/models.py",
    "src/repro/device/programmed.py",
    "src/repro/device/repair.py",
    "src/repro/device/health.py",
)

AUX_SLOTS = {"plan", "repair", "device", "report", "spec", "adc_cfg"}

# audited legitimate rebinds: (relpath, function, name) -> reason
SHADOW_ALLOW: Dict[Tuple[str, str, str], str] = {
    ("src/repro/device/programmed.py", "program_layer", "spec"):
        "layer-scaled spec replaces the base spec for the whole layer",
    ("src/repro/device/programmed.py", "program_layer", "adc_cfg"):
        "planned ADC config derived from the (rebound) layer spec",
    ("src/repro/device/programmed.py", "program_layer", "device"):
        "plan's spare budget folded into the device config",
    ("src/repro/device/programmed.py", "program_layer", "report"):
        "ProgramReport destined for the report aux slot (correct type)",
    ("src/repro/device/programmed.py", "programmed_matmul", "spec"):
        "read-alias of art.spec (same object, same type)",
    ("src/repro/device/programmed.py", "tree_unflatten", "spec"):
        "canonical aux-tuple unpack in slot order",
    ("src/repro/device/programmed.py", "tree_unflatten", "adc_cfg"):
        "canonical aux-tuple unpack in slot order",
    ("src/repro/device/programmed.py", "tree_unflatten", "report"):
        "canonical aux-tuple unpack in slot order",
    ("src/repro/device/programmed.py", "tree_unflatten", "repair"):
        "canonical aux-tuple unpack in slot order",
    ("src/repro/device/programmed.py", "tree_unflatten", "device"):
        "canonical aux-tuple unpack in slot order",
    ("src/repro/device/programmed.py", "tree_unflatten", "plan"):
        "canonical aux-tuple unpack in slot order",
    ("src/repro/device/repair.py", "repaired_effective_cells", "report"):
        "ProgramReport destined for the report aux slot (correct type)",
}


def _registry_findings(relpath: str, tree: ast.Module) -> List[Finding]:
    """Validate the STAGE_* registry inside device/models.py."""
    findings: List[Finding] = []
    const_strings: Dict[str, str] = {}
    stages_dict: Optional[ast.Dict] = None
    stages_line = 0
    for node in tree.body:
        if isinstance(node, ast.Assign) and len(node.targets) == 1:
            tgt = node.targets[0]
            if isinstance(tgt, ast.Name):
                if tgt.id.startswith("STAGE_") and isinstance(node.value, ast.Constant):
                    const_strings[tgt.id] = node.value.value
                elif tgt.id == "_STAGES" and isinstance(node.value, ast.Dict):
                    stages_dict = node.value
                    stages_line = node.lineno
    if stages_dict is None:
        return [Finding(RULE_STAGE, relpath, 0,
                        "no _STAGES registry dict found in device/models.py")]
    names: List[str] = []
    indices: List[int] = []
    for k, v in zip(stages_dict.keys, stages_dict.values):
        if isinstance(k, ast.Constant):
            findings.append(Finding(
                RULE_STAGE, relpath, k.lineno,
                f"_STAGES key {k.value!r} is an ad-hoc literal — define a "
                "STAGE_* constant so call sites can share it",
            ))
            names.append(k.value)
        elif isinstance(k, ast.Name):
            if k.id not in const_strings:
                findings.append(Finding(
                    RULE_STAGE, relpath, k.lineno,
                    f"_STAGES key {k.id} is not a module-level STAGE_* "
                    "string constant",
                ))
            else:
                names.append(const_strings[k.id])
        if isinstance(v, ast.Constant) and isinstance(v.value, int):
            indices.append(v.value)
        else:
            findings.append(Finding(
                RULE_STAGE, relpath, v.lineno,
                f"_STAGES index `{ast.unparse(v)}` is not an int literal",
            ))
    if len(set(names)) != len(names):
        dup = sorted({n for n in names if names.count(n) > 1})
        findings.append(Finding(
            RULE_STAGE, relpath, stages_line,
            f"duplicate stage name(s) in registry: {dup} — two stages with "
            "one name silently share draws",
        ))
    if len(set(indices)) != len(indices):
        dup = sorted({i for i in indices if indices.count(i) > 1})
        findings.append(Finding(
            RULE_STAGE, relpath, stages_line,
            f"stage fold_in index collision: {dup} — supposedly independent "
            "stages would draw identical randomness",
        ))
    return findings


def rule_stage_keys(relpath: str, tree: ast.Module, source: str) -> List[Finding]:
    if not relpath.startswith("src/"):
        return []
    findings: List[Finding] = []
    if relpath == REGISTRY_FILE or relpath.endswith("device/models.py"):
        findings.extend(_registry_findings(relpath, tree))
    fold_in_literals: Dict[int, List[int]] = {}
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        dn = dotted_name(node.func) or ""
        leaf = dn.split(".")[-1]
        for kw in node.keywords:
            if (
                kw.arg == "stage"
                and isinstance(kw.value, ast.Constant)
                and isinstance(kw.value.value, str)
            ):
                findings.append(Finding(
                    RULE_STAGE, relpath, node.lineno,
                    f"ad-hoc stage literal stage={kw.value.value!r} — use the "
                    "device.models.STAGE_* registry constant",
                ))
        if leaf == "_stage_key" and len(node.args) >= 2:
            arg = node.args[1]
            if isinstance(arg, ast.Constant) and isinstance(arg.value, str):
                findings.append(Finding(
                    RULE_STAGE, relpath, node.lineno,
                    f"ad-hoc stage literal _stage_key(..., {arg.value!r}) — "
                    "use the device.models.STAGE_* registry constant",
                ))
        if leaf == "fold_in" and len(node.args) >= 2:
            arg = node.args[1]
            if isinstance(arg, ast.Constant) and isinstance(arg.value, int):
                fold_in_literals.setdefault(arg.value, []).append(node.lineno)
    for val, lines in fold_in_literals.items():
        if len(lines) > 1:
            findings.append(Finding(
                RULE_STAGE, relpath, lines[1],
                f"fold_in index literal {val} used at lines {lines} — "
                "distinct streams folding the same literal draw identical "
                "randomness",
            ))
    return findings


def _bound_names(target: ast.AST) -> List[ast.Name]:
    if isinstance(target, ast.Name):
        return [target]
    if isinstance(target, (ast.Tuple, ast.List)):
        out = []
        for elt in target.elts:
            out.extend(_bound_names(elt))
        return out
    return []


def rule_shadowing(relpath: str, tree: ast.Module, source: str) -> List[Finding]:
    if relpath not in SHADOW_SCOPE and not relpath.endswith(
        ("device/models.py", "device/programmed.py", "device/repair.py", "device/health.py")
    ):
        return []
    findings: List[Finding] = []

    def _walk_own(fn: ast.AST):
        """Nodes of a function body, NOT descending into nested functions
        (those are visited under their own name)."""
        stack = list(fn.body)
        while stack:
            node = stack.pop()
            yield node
            if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
                stack.extend(ast.iter_child_nodes(node))

    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        for node in _walk_own(fn):
            targets: List[ast.AST] = []
            if isinstance(node, ast.Assign):
                targets = node.targets
            elif isinstance(node, (ast.AnnAssign, ast.AugAssign, ast.NamedExpr)):
                targets = [node.target]
            elif isinstance(node, ast.For):
                targets = [node.target]
            for t in targets:
                for name in _bound_names(t):
                    if name.id not in AUX_SLOTS:
                        continue
                    key = (relpath, fn.name, name.id)
                    if key in SHADOW_ALLOW:
                        continue
                    findings.append(Finding(
                        RULE_SHADOW, relpath, node.lineno,
                        f"local `{name.id} = ...` in {fn.name}() rebinds a "
                        "frozen-artifact aux slot name (the PR 7 "
                        "plan/RepairPlan bug class) — rename the local or "
                        "audit it in rules_device.SHADOW_ALLOW",
                    ))
    return findings
