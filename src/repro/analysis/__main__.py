"""CLI: ``python -m repro.analysis [--check] [--store DIR] ...``.

Default run lints the repo and prints every finding (``info`` findings —
the audited known-digital projections — included).  ``--check`` is the CI
gate: exit 1 if any *error*-level finding survives.  ``--store DIR`` runs
the offline artifact-store verifier instead of (or in addition to) the
lint pass; a failing store always exits nonzero.
"""
from __future__ import annotations

import argparse
import sys

from repro.analysis import ERROR, repo_root, run_lint, verify_store


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static contract checker for the crossbar stack",
    )
    p.add_argument("--check", action="store_true",
                   help="CI gate: exit 1 on any error-level lint finding")
    p.add_argument("--root", default=None,
                   help="repo root to lint (default: autodetected)")
    p.add_argument("--store", default=None, metavar="DIR",
                   help="verify a save_programmed artifact store offline")
    p.add_argument("--slot", default=None, choices=("A", "B"),
                   help="verify a specific store slot (default: follow ACTIVE)")
    p.add_argument("--max-crossbar-factor", type=float, default=None,
                   help="area budget for plan admissibility checks")
    p.add_argument("--exactness", default=None,
                   help="ADC exactness contract for plan checks (e.g. 'provable')")
    p.add_argument("--no-lint", action="store_true",
                   help="skip the lint pass (with --store: verify only)")
    p.add_argument("-q", "--quiet", action="store_true",
                   help="print only error-level findings and the summary")
    args = p.parse_args(argv)

    status = 0

    if not args.no_lint:
        findings = run_lint(root=args.root or repo_root())
        errors = [f for f in findings if f.level == ERROR]
        for f in (errors if args.quiet else findings):
            print(f.format())
        print(
            f"lint: {len(findings)} finding(s), {len(errors)} error(s) "
            f"across rules"
        )
        if args.check and errors:
            status = 1

    if args.store is not None:
        report = verify_store(
            args.store,
            slot=args.slot,
            max_crossbar_factor=args.max_crossbar_factor,
            exactness=args.exactness,
        )
        print(report.summary())
        if not report.ok:
            status = 1

    return status


if __name__ == "__main__":
    sys.exit(main())
