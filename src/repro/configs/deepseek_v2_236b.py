"""deepseek-v2-236b — MLA + fine-grained MoE [arXiv:2405.04434].

60L, d_model=5120, 128 heads with Multi-head Latent Attention (kv_lora=512,
decoupled RoPE dim 64, head_dim 128), vocab 102400.  MoE: 2 shared + 160
routed experts, top-6, expert d_ff=1536; the first layer uses a dense FFN
(d_ff=12288).  Adafactor states (1T-scale MoE training memory).
"""
from repro.configs.base import ModelConfig, StageSpec, register

CONFIG = register(
    ModelConfig(
        name="deepseek-v2-236b",
        family="moe",
        n_layers=60,
        d_model=5120,
        n_heads=128,
        n_kv_heads=128,
        head_dim=128,
        d_ff=12288,  # the single dense layer
        vocab_size=102400,
        stages=(
            StageSpec(kinds=("attn",), repeats=1, moe=(False,)),
            StageSpec(kinds=("attn",), repeats=59, moe=(True,)),
        ),
        kv_lora_rank=512,
        qk_rope_dim=64,
        moe_experts=160,
        moe_top_k=6,
        moe_shared_experts=2,
        moe_d_ff=1536,
        mlp_kind="swiglu",
        tie_embeddings=False,
        optimizer="adafactor",
        fsdp=True,
        layout_decode="expert_tp",
        source="arXiv:2405.04434 (hf)",
    )
)
