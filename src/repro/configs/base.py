"""Model configuration system.

A ``ModelConfig`` fully describes one architecture: dimensions, the per-layer
block pattern (attention variants / Mamba / xLSTM), MoE routing, and
parallelism/training preferences.  Configs are registered by id and selected
with ``--arch <id>`` throughout the launchers.

The layer stack is organized into **stages**: a stage is a repeating
super-block (e.g. gemma2's [local, global] pair; jamba's 8-layer period) whose
parameters are stacked on a leading axis and executed under ``lax.scan`` —
this keeps compiled HLO size independent of depth, which the multi-pod
dry-run relies on.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

REGISTRY: Dict[str, "ModelConfig"] = {}


@dataclasses.dataclass(frozen=True)
class StageSpec:
    """A repeated super-block: ``kinds`` executed in order, ``repeats`` times.

    ``moe`` marks which positions within the super-block use the MoE FFN
    (True) vs the dense FFN / no FFN.
    """

    kinds: Tuple[str, ...]
    repeats: int
    moe: Tuple[bool, ...] = ()

    def __post_init__(self):
        if not self.moe:
            object.__setattr__(self, "moe", tuple(False for _ in self.kinds))

    @property
    def n_layers(self) -> int:
        return len(self.kinds) * self.repeats


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    stages: Tuple[StageSpec, ...] = ()
    head_dim: int = 0  # 0 -> d_model // n_heads

    # attention
    rope_theta: float = 10000.0
    sliding_window: int = 0  # window for attn_local layers
    attn_softcap: float = 0.0  # gemma2 attention logit soft-capping
    logit_softcap: float = 0.0  # gemma2 final logit soft-capping
    attn_scale: Optional[float] = None  # override 1/sqrt(head_dim)

    # MLA (deepseek-v2)
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    qk_rope_dim: int = 0

    # MoE
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_shared_experts: int = 0
    moe_d_ff: int = 0
    moe_capacity_factor: float = 1.25
    # EP dispatch: "allreduce" (model-replicated tokens, local experts, psum
    # combine — no a2a) or "alltoall" (sequence-sharded tokens, GShard-style
    # all-to-all dispatch/combine — moves only routed tokens).  §Perf
    # hillclimb measures both; alltoall wins for large-E MoE.
    moe_dispatch: str = "allreduce"

    # Mamba (jamba)
    mamba_d_state: int = 16
    mamba_d_conv: int = 4
    mamba_d_inner: int = 0
    mamba_dt_rank: int = 0

    # xLSTM
    xlstm_d_inner: int = 0

    mlp_kind: str = "swiglu"  # swiglu | geglu | gelu | relu2
    norm_eps: float = 1e-6
    post_norm: bool = False  # gemma2: extra norm after each sub-block
    tie_embeddings: bool = True
    embed_scale: bool = False  # gemma: scale embeddings by sqrt(d_model)
    frontend: str = "token"  # token | embed (audio/vlm stubs feed embeddings)

    # substrate preferences
    optimizer: str = "adamw"  # adamw | adafactor
    remat: bool = True
    param_dtype: str = "bfloat16"
    # FSDP (ZeRO-3): additionally shard large params + optimizer state over
    # the "data" axis (per-pod; replicated across pods — inter-pod per-layer
    # all-gathers would swamp the pod links).  Needed when params do not fit
    # under tensor parallelism alone.
    fsdp: bool = False
    # lax.scan over layer stacks (HLO size independent of depth).  The
    # roofline depth variants unroll instead, because XLA's HloCostAnalysis
    # counts a while body once regardless of trip count.
    scan_layers: bool = True
    # Parallel layout: "tp" (default: TP/SP/EP over the model axis),
    # "pure_dp" (model axis as extra data parallelism — fastest for small
    # models on the fixed production mesh), "expert_tp" (weights-
    # stationary MoE serving), or "ep_only" (experts sharded over the model
    # axis, everything else replicated — programmed crossbar serving on a
    # mesh is bit-identical to the single-device chip).  See §Perf.
    layout: str = "tp"
    # Layout override for decode/serving cells (e.g. "expert_tp": training
    # moves weights (FSDP) because tokens >> weights; decode moves
    # activations because weights >> tokens).
    layout_decode: str = ""

    # provenance
    source: str = ""

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if not self.stages:
            object.__setattr__(
                self, "stages", (StageSpec(kinds=("attn",), repeats=self.n_layers),)
            )
        total = sum(s.n_layers for s in self.stages)
        assert total == self.n_layers, f"{self.name}: stages cover {total} != {self.n_layers}"

    # --- helpers used across the framework --------------------------------
    def block_pattern_summary(self) -> List[str]:
        out: List[str] = []
        for s in self.stages:
            out.extend(list(s.kinds) * s.repeats)
        return out

    def moe_layer(self, i: int) -> bool:
        flat: List[bool] = []
        for s in self.stages:
            flat.extend(list(s.moe) * s.repeats)
        return flat[i] if self.moe_experts else False

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def param_count(self) -> int:
        """Exact parameter count from the block pattern (used for 6ND)."""
        d = self.d_model
        total = 0
        if self.frontend == "token":
            total += self.vocab_size * d
        if not self.tie_embeddings:
            total += self.vocab_size * d
        for i, kind in enumerate(self.block_pattern_summary()):
            total += 2 * d  # norms (approx: pre-norm per sub-block)
            if kind.startswith("attn"):
                if self.kv_lora_rank:
                    total += d * self.q_dim  # q proj
                    total += d * (self.kv_lora_rank + self.qk_rope_dim)
                    total += self.kv_lora_rank * 2 * self.q_dim
                    total += self.q_dim * d
                else:
                    total += d * (self.q_dim + 2 * self.kv_dim) + self.q_dim * d
            elif kind == "mamba":
                din = self.mamba_d_inner or 2 * d
                dt = self.mamba_dt_rank or max(1, math.ceil(d / 16))
                total += d * 2 * din  # in_proj
                total += din * (dt + 2 * self.mamba_d_state)  # x_proj
                total += dt * din + din * d  # dt_proj + out_proj
                total += din * self.mamba_d_conv + din * self.mamba_d_state  # conv + A
            elif kind in ("mlstm", "slstm"):
                din = self.xlstm_d_inner or 2 * d
                total += d * 3 * din + d * 2 * din + din * d
            if self.moe_layer(i):
                e_params = 3 * self.moe_d_ff * d if self.mlp_kind in ("swiglu", "geglu") else 2 * self.moe_d_ff * d
                total += (self.moe_experts + self.moe_shared_experts) * e_params
                total += d * self.moe_experts  # router
            elif self.d_ff and not kind in ("mlstm", "slstm"):
                if self.mlp_kind in ("swiglu", "geglu"):
                    total += 3 * self.d_ff * d
                else:
                    total += 2 * self.d_ff * d
        total += d  # final norm
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only routed top-k + shared)."""
        if not self.moe_experts:
            return self.param_count()
        d = self.d_model
        e_params = (
            3 * self.moe_d_ff * d
            if self.mlp_kind in ("swiglu", "geglu")
            else 2 * self.moe_d_ff * d
        )
        inactive = 0
        for i, _ in enumerate(self.block_pattern_summary()):
            if self.moe_layer(i):
                inactive += (self.moe_experts - self.moe_top_k) * e_params
        return self.param_count() - inactive


def register(cfg: ModelConfig) -> ModelConfig:
    REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    from repro import configs as _  # ensure registry population

    if name not in REGISTRY:
        raise KeyError(f"unknown arch '{name}'; known: {sorted(REGISTRY)}")
    return REGISTRY[name]


def reduced(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Build a smoke-test-sized config of the same family.

    Shrinks width/depth/experts/vocab while preserving the block pattern
    structure (every stage keeps its kinds, with 1-2 repeats).
    """
    d_model = overrides.pop("d_model", 64)
    n_heads = max(2, min(cfg.n_heads, 4))
    n_kv = max(1, min(cfg.n_kv_heads, 2))
    head_dim = d_model // n_heads
    stages = tuple(
        StageSpec(kinds=s.kinds, repeats=min(s.repeats, 1 if len(s.kinds) > 1 else 2), moe=s.moe)
        for s in cfg.stages
    )
    n_layers = sum(s.n_layers for s in stages)
    kw = dict(
        name=cfg.name + "-smoke",
        family=cfg.family,
        n_layers=n_layers,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=head_dim,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        stages=stages,
        rope_theta=cfg.rope_theta,
        sliding_window=min(cfg.sliding_window, 16) if cfg.sliding_window else 0,
        attn_softcap=cfg.attn_softcap,
        logit_softcap=cfg.logit_softcap,
        kv_lora_rank=32 if cfg.kv_lora_rank else 0,
        qk_rope_dim=16 if cfg.qk_rope_dim else 0,
        moe_experts=min(cfg.moe_experts, 8) if cfg.moe_experts else 0,
        moe_top_k=min(cfg.moe_top_k, 2) if cfg.moe_experts else 0,
        moe_shared_experts=min(cfg.moe_shared_experts, 1),
        moe_d_ff=64 if cfg.moe_experts else 0,
        mamba_d_state=min(cfg.mamba_d_state, 8),
        mamba_d_conv=cfg.mamba_d_conv,
        mamba_d_inner=2 * d_model if cfg.mamba_d_inner else 0,
        mamba_dt_rank=8 if cfg.mamba_dt_rank else 0,
        xlstm_d_inner=2 * d_model if cfg.xlstm_d_inner else 0,
        mlp_kind=cfg.mlp_kind,
        post_norm=cfg.post_norm,
        tie_embeddings=cfg.tie_embeddings,
        embed_scale=cfg.embed_scale,
        frontend=cfg.frontend,
        optimizer="adamw",
        remat=False,
        param_dtype="float32",
    )
    kw.update(overrides)
    return ModelConfig(**kw)


# ---------------------------------------------------------------------------
# Input shapes assigned to every architecture (the 4-shape set)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# long_500k only runs for sub-quadratic (SSM / hybrid) archs — see DESIGN.md
LONG_CONTEXT_FAMILIES = ("ssm", "hybrid")


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> bool:
    if shape.name == "long_500k":
        return cfg.family in LONG_CONTEXT_FAMILIES
    return True
