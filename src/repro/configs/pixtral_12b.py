"""pixtral-12b — Pixtral-ViT frontend + Mistral-Nemo backbone
[hf:mistralai/Pixtral-12B-2409, unverified].

Backbone only (the ViT frontend is a stub; ``input_specs`` feeds precomputed
patch embeddings): 40L, d_model=5120, 32 heads (GQA kv=8, head_dim=128),
d_ff=14336 (SwiGLU), vocab 131072.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="pixtral-12b",
        family="vlm",
        n_layers=40,
        d_model=5120,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=131072,
        rope_theta=1e6,
        mlp_kind="swiglu",
        frontend="embed",
        tie_embeddings=False,
        optimizer="adamw",
        source="hf:mistralai/Pixtral-12B-2409 (unverified)",
    )
)
