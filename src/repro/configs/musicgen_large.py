"""musicgen-large — decoder-only transformer over EnCodec tokens
[arXiv:2306.05284].  Backbone only: 48L, d_model=2048, 32 heads (MHA),
d_ff=8192, vocab=2048 (one EnCodec codebook head).  The EnCodec frontend is a
stub per the assignment: ``input_specs`` feeds precomputed frame embeddings.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="musicgen-large",
        family="audio",
        n_layers=48,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_ff=8192,
        vocab_size=2048,
        mlp_kind="gelu",
        frontend="embed",
        tie_embeddings=False,
        optimizer="adamw",
        source="arXiv:2306.05284 (hf)",
    )
)
