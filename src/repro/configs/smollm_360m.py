"""smollm-360m — llama-architecture small model [hf:HuggingFaceTB/SmolLM].

32L, d_model=960, 15 heads with GQA kv=5, d_ff=2560 (SwiGLU), vocab 49152,
tied embeddings, RoPE.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="smollm-360m",
        family="dense",
        n_layers=32,
        d_model=960,
        n_heads=15,
        n_kv_heads=5,
        d_ff=2560,
        vocab_size=49152,
        mlp_kind="swiglu",
        tie_embeddings=True,
        optimizer="adamw",
        source="hf:HuggingFaceTB/SmolLM-360M",
    )
)
