"""minitron-4b — pruned Nemotron [arXiv:2407.14679].

32L, d_model=3072, 24 heads (GQA kv=8), d_ff=9216 with squared-ReLU MLP
(Nemotron family), vocab 256000, untied embeddings, RoPE.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="minitron-4b",
        family="dense",
        n_layers=32,
        d_model=3072,
        n_heads=24,
        n_kv_heads=8,
        d_ff=9216,
        vocab_size=256000,
        mlp_kind="relu2",
        tie_embeddings=False,
        optimizer="adamw",
        source="arXiv:2407.14679 (hf)",
    )
)
