"""jamba-v0.1-52b — Mamba + attention 1:7 interleave with MoE
[arXiv:2403.19887].

32L in four 8-layer periods: one attention layer (position 4) per 7 Mamba
layers; MoE (16 experts, top-2) on every other layer, dense d_ff=14336 on
the rest.  d_model=4096, 32 heads (GQA kv=8), Mamba d_inner=8192, d_state=16,
conv=4, dt_rank=256, vocab 65536.
"""
from repro.configs.base import ModelConfig, StageSpec, register

_PERIOD = ("mamba", "mamba", "mamba", "attn", "mamba", "mamba", "mamba", "mamba")
_MOE = (False, True, False, True, False, True, False, True)

CONFIG = register(
    ModelConfig(
        name="jamba-v0.1-52b",
        family="hybrid",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        d_ff=14336,
        vocab_size=65536,
        stages=(StageSpec(kinds=_PERIOD, repeats=4, moe=_MOE),),
        moe_experts=16,
        moe_top_k=2,
        moe_shared_experts=0,
        moe_d_ff=14336,
        mamba_d_state=16,
        mamba_d_conv=4,
        mamba_d_inner=8192,
        mamba_dt_rank=256,
        mlp_kind="swiglu",
        tie_embeddings=False,
        optimizer="adamw",
        fsdp=True,
        source="arXiv:2403.19887 (hf)",
    )
)
