"""xlstm-350m — sLSTM + mLSTM blocks [arXiv:2405.04517].

24L, d_model=1024, 4 heads (kv=4), d_ff=0 (xLSTM blocks carry their own
up/down projections and gates; no separate FFN), vocab 50304.  Blocks
alternate mLSTM (matrix memory, parallelizable) and sLSTM (scalar memory,
strictly recurrent) in 1:1 ratio.
"""
from repro.configs.base import ModelConfig, StageSpec, register

CONFIG = register(
    ModelConfig(
        name="xlstm-350m",
        family="ssm",
        n_layers=24,
        d_model=1024,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab_size=50304,
        stages=(StageSpec(kinds=("mlstm", "slstm"), repeats=12),),
        xlstm_d_inner=2048,
        tie_embeddings=True,
        optimizer="adamw",
        layout="pure_dp",
        source="arXiv:2405.04517 (unverified)",
    )
)
