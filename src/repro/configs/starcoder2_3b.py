"""starcoder2-3b — GQA + RoPE code model [arXiv:2402.19173].

30L, d_model=3072, 24 heads (GQA kv=2), d_ff=12288 (GELU MLP), vocab 49152,
tied embeddings.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        name="starcoder2-3b",
        family="dense",
        n_layers=30,
        d_model=3072,
        n_heads=24,
        n_kv_heads=2,
        d_ff=12288,
        vocab_size=49152,
        mlp_kind="gelu",
        tie_embeddings=True,
        optimizer="adamw",
        source="arXiv:2402.19173 (hf)",
    )
)
