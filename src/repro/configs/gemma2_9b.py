"""gemma2-9b — local+global alternating attention with logit softcaps
[arXiv:2408.00118].

42L, d_model=3584, 16 heads (GQA kv=8, head_dim=256), d_ff=14336 (GeGLU),
vocab 256000.  Odd layers use sliding-window (4096) attention, even layers
global; attention logits soft-capped at 50, final logits at 30; pre+post
RMSNorm around each sub-block; embeddings scaled by sqrt(d_model) and tied.
"""
from repro.configs.base import ModelConfig, StageSpec, register

CONFIG = register(
    ModelConfig(
        name="gemma2-9b",
        family="dense",
        n_layers=42,
        d_model=3584,
        n_heads=16,
        n_kv_heads=8,
        head_dim=256,
        d_ff=14336,
        vocab_size=256000,
        stages=(StageSpec(kinds=("attn_local", "attn_global"), repeats=21),),
        sliding_window=4096,
        attn_softcap=50.0,
        logit_softcap=30.0,
        mlp_kind="geglu",
        post_norm=True,
        embed_scale=True,
        tie_embeddings=True,
        optimizer="adamw",
        source="arXiv:2408.00118 (hf)",
    )
)
