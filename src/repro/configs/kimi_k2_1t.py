"""kimi-k2-1t-a32b — trillion-parameter MoE (paper-table config)
[arXiv:2501.kimi2, unverified].

61L, d_model=7168, 64 heads (GQA kv=8 per the assignment table), vocab
163840.  MoE: 384 routed experts top-8 + 1 shared, expert d_ff=2048; first
layer dense (d_ff=18432).  Adafactor is mandatory at this scale.
"""
from repro.configs.base import ModelConfig, StageSpec, register

CONFIG = register(
    ModelConfig(
        name="kimi-k2-1t-a32b",
        family="moe",
        n_layers=61,
        d_model=7168,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=18432,  # the single dense layer
        vocab_size=163840,
        stages=(
            StageSpec(kinds=("attn",), repeats=1, moe=(False,)),
            StageSpec(kinds=("attn",), repeats=60, moe=(True,)),
        ),
        moe_experts=384,
        moe_top_k=8,
        moe_shared_experts=1,
        moe_d_ff=2048,
        moe_dispatch="alltoall",
        mlp_kind="swiglu",
        tie_embeddings=False,
        optimizer="adafactor",
        fsdp=True,
        layout_decode="expert_tp",
        source="arXiv:2501.kimi2 (paper-table, unverified)",
    )
)
