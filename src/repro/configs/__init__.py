"""Architecture registry: one module per assigned architecture.

``get_config(name)`` returns the full config; ``reduced(cfg)`` builds the
smoke-test variant.  ``ALL_ARCHS`` lists the ten assigned architectures.
"""
from repro.configs.base import (  # noqa: F401
    ModelConfig,
    REGISTRY,
    SHAPES,
    ShapeSpec,
    StageSpec,
    get_config,
    reduced,
    shape_applicable,
)

# Register every architecture (import order = presentation order).
from repro.configs import xlstm_350m  # noqa: F401
from repro.configs import musicgen_large  # noqa: F401
from repro.configs import smollm_360m  # noqa: F401
from repro.configs import gemma2_9b  # noqa: F401
from repro.configs import minitron_4b  # noqa: F401
from repro.configs import starcoder2_3b  # noqa: F401
from repro.configs import deepseek_v2_236b  # noqa: F401
from repro.configs import kimi_k2_1t  # noqa: F401
from repro.configs import pixtral_12b  # noqa: F401
from repro.configs import jamba_52b  # noqa: F401

ALL_ARCHS = [
    "xlstm-350m",
    "musicgen-large",
    "smollm-360m",
    "gemma2-9b",
    "minitron-4b",
    "starcoder2-3b",
    "deepseek-v2-236b",
    "kimi-k2-1t-a32b",
    "pixtral-12b",
    "jamba-v0.1-52b",
]
