"""Public jit'd wrappers for the Pallas kernels.

On CPU (this container / unit tests) the kernels run in interpret mode; on a
real TPU they compile to Mosaic.  ``interpret`` is resolved automatically
from the backend unless forced.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.adc import ADCConfig, SAFE_ADAPTIVE
from repro.core.crossbar import (
    CrossbarSpec,
    DEFAULT_SPEC,
    QuantParams,
    layer_scaled_spec,
    quantize_input,
    quantize_weight,
)
from repro.kernels.crossbar_vmm import crossbar_vmm_pallas
from repro.kernels.noisy_vmm import noisy_vmm_pallas


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


def crossbar_vmm_op(
    x_codes: jnp.ndarray,
    w_codes: jnp.ndarray,
    spec: CrossbarSpec = DEFAULT_SPEC,
    adc_cfg: Optional[ADCConfig] = None,
    fast: bool = False,
    interpret: Optional[bool] = None,
    skip_zero_planes: bool = True,
) -> jnp.ndarray:
    """Bit-exact crossbar VMM on integer codes (Pallas)."""
    if interpret is None:
        interpret = _auto_interpret()
    return crossbar_vmm_pallas(
        x_codes, w_codes, spec=spec, adc_cfg=adc_cfg, fast=fast, interpret=interpret,
        skip_zero_planes=skip_zero_planes,
    )


def noisy_vmm_op(
    x_codes: jnp.ndarray,
    g_eff: jnp.ndarray,
    spec: CrossbarSpec = DEFAULT_SPEC,
    adc_cfg: Optional[ADCConfig] = None,
    interpret: Optional[bool] = None,
    skip_zero_planes: bool = True,
) -> jnp.ndarray:
    """Device-perturbed crossbar VMM on integer codes + effective cells."""
    if interpret is None:
        interpret = _auto_interpret()
    return noisy_vmm_pallas(
        x_codes, g_eff, spec=spec, adc_cfg=adc_cfg, interpret=interpret,
        skip_zero_planes=skip_zero_planes,
    )


def crossbar_matmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    spec: CrossbarSpec = DEFAULT_SPEC,
    qp: Optional[QuantParams] = None,
    adc_cfg: ADCConfig = SAFE_ADAPTIVE,
    interpret: Optional[bool] = None,
    device=None,
    fast: bool = False,
) -> jnp.ndarray:
    """Float-in / float-out crossbar matmul with ISAAC W16A16 semantics.

    Quantizes operands, runs the Pallas datapath (adaptive SAR schedule with
    the provably-safe guard by default), dequantizes.  ``x`` must be
    non-negative; ``qp`` scales must be provided for jit-stable use.

    ``device``: optional ``repro.device.DeviceConfig``; when set (and not
    ideal), the quantized weights are programmed through the non-ideality
    pipeline and the VMM runs on the noisy Pallas kernel instead (``fast``
    does not apply there — the noisy kernel has a single path).

    ``fast``: use the fused exact kernel, which models full-resolution ADCs
    (``adc_cfg`` is ignored).
    """
    # Per-layer output scaling so the K-row accumulator fits the out window
    spec = layer_scaled_spec(spec, x.shape[-1])
    if qp is None:
        # traced (jit-safe) dynamic quantization scales
        x_scale = jnp.maximum(jnp.max(x), 1e-9) / ((1 << spec.input_bits) - 1)
        w_scale = jnp.maximum(jnp.max(jnp.abs(w)), 1e-9) / (
            (1 << (spec.weight_bits - 1)) - 1
        )
    else:
        x_scale, w_scale = qp.x_scale, qp.w_scale
    xq = quantize_input(x, spec, x_scale)
    wq = quantize_weight(w, spec, w_scale)
    if device is not None and not device.is_ideal:
        from repro.device import models as dev_models

        g_eff = dev_models.effective_cell_codes(
            wq + spec.weight_bias, spec, device
        )
        yq = noisy_vmm_op(xq, g_eff, spec, adc_cfg=adc_cfg, interpret=interpret)
    elif fast:
        yq = crossbar_vmm_op(xq, wq, spec, adc_cfg=None, fast=True, interpret=interpret)
    else:
        yq = crossbar_vmm_op(xq, wq, spec, adc_cfg=adc_cfg, interpret=interpret)
    return yq.astype(jnp.float32) * (x_scale * w_scale * (2.0 ** spec.drop_lsb))
