"""Public jit'd wrappers for the Pallas kernels.

On CPU (this container / unit tests) the kernels run in interpret mode; on a
real TPU they compile to Mosaic.  ``interpret`` is resolved automatically
from the backend unless forced.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.adc import ADCConfig, SAFE_ADAPTIVE
from repro.core.crossbar import (
    CrossbarSpec,
    DEFAULT_SPEC,
    QuantParams,
    layer_scaled_spec,
    quantize_input,
    quantize_weight,
)
from repro.kernels.crossbar_vmm import crossbar_vmm_pallas


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


def crossbar_vmm_op(
    x_codes: jnp.ndarray,
    w_codes: jnp.ndarray,
    spec: CrossbarSpec = DEFAULT_SPEC,
    adc_cfg: Optional[ADCConfig] = None,
    fast: bool = False,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Bit-exact crossbar VMM on integer codes (Pallas)."""
    if interpret is None:
        interpret = _auto_interpret()
    return crossbar_vmm_pallas(
        x_codes, w_codes, spec=spec, adc_cfg=adc_cfg, fast=fast, interpret=interpret
    )


def crossbar_matmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    spec: CrossbarSpec = DEFAULT_SPEC,
    qp: Optional[QuantParams] = None,
    adc_cfg: ADCConfig = SAFE_ADAPTIVE,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Float-in / float-out crossbar matmul with ISAAC W16A16 semantics.

    Quantizes operands, runs the Pallas datapath (adaptive SAR schedule with
    the provably-safe guard by default), dequantizes.  ``x`` must be
    non-negative; ``qp`` scales must be provided for jit-stable use.
    """
    # Per-layer output scaling so the K-row accumulator fits the out window
    spec = layer_scaled_spec(spec, x.shape[-1])
    if qp is None:
        # traced (jit-safe) dynamic quantization scales
        x_scale = jnp.maximum(jnp.max(x), 1e-9) / ((1 << spec.input_bits) - 1)
        w_scale = jnp.maximum(jnp.max(jnp.abs(w)), 1e-9) / (
            (1 << (spec.weight_bits - 1)) - 1
        )
    else:
        x_scale, w_scale = qp.x_scale, qp.w_scale
    xq = quantize_input(x, spec, x_scale)
    wq = quantize_weight(w, spec, w_scale)
    yq = crossbar_vmm_op(xq, wq, spec, adc_cfg=adc_cfg, interpret=interpret)
    return yq.astype(jnp.float32) * (x_scale * w_scale * (2.0 ** spec.drop_lsb))
