"""Pure-jnp oracles for the Pallas kernels (the bit-exact functional model
from ``repro.core`` — itself validated against an int64 numpy reference)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import adc as adc_mod
from repro.core.crossbar import (
    CrossbarSpec,
    DEFAULT_SPEC,
    crossbar_vmm,
    noisy_crossbar_vmm,
)


def _adc_transform(spec: CrossbarSpec, adc_cfg: Optional[adc_mod.ADCConfig]):
    if adc_cfg is not None and adc_cfg.mode != "full":
        return adc_mod.make_partial_transform(spec, adc_cfg)
    return None


def crossbar_vmm_ref(
    x_codes: jnp.ndarray,
    w_codes: jnp.ndarray,
    spec: CrossbarSpec = DEFAULT_SPEC,
    adc_cfg: Optional[adc_mod.ADCConfig] = None,
) -> jnp.ndarray:
    """Oracle for ``kernels.crossbar_vmm.crossbar_vmm_pallas``."""
    return crossbar_vmm(
        x_codes, w_codes, spec, partial_transform=_adc_transform(spec, adc_cfg)
    )


def noisy_vmm_ref(
    x_codes: jnp.ndarray,
    g_eff: jnp.ndarray,
    spec: CrossbarSpec = DEFAULT_SPEC,
    adc_cfg: Optional[adc_mod.ADCConfig] = None,
) -> jnp.ndarray:
    """Oracle for ``kernels.noisy_vmm.noisy_vmm_pallas``: the dense perturbed
    reference — same ADC rounding/saturation, pure-jnp shift-add."""
    return noisy_crossbar_vmm(
        x_codes, g_eff, spec, partial_transform=_adc_transform(spec, adc_cfg)
    )


def chunked_attention_ref(q, k, v, scale=None, causal=True):
    """Oracle for the chunked/flash attention path: plain softmax attention.

    q: (B, H, S, D); k, v: (B, Hkv, S, D) with H a multiple of Hkv.
    """
    B, H, S, D = q.shape
    Hkv = k.shape[1]
    if Hkv != H:
        rep = H // Hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    if scale is None:
        scale = D ** -0.5
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * scale
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        logits = jnp.where(mask, logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)
