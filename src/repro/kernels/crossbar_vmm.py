"""Pallas TPU kernel for the Newton crossbar VMM datapath.

This is the compute hot spot of the paper adapted to the TPU memory
hierarchy: a 128x128 memristor crossbar tile maps exactly onto an MXU-aligned
128x128 block held in VMEM.  Per (row-group k) block the kernel streams the
16 input bit-planes (generated in-register from the int32 activation block —
the "1-bit DAC"), multiplies them against the 8 weight bit-slices (extracted
in-register from the int32 weight block — the "2-bit cells"), applies the
per-(t, s) adaptive-ADC transform (static shift/clamp tables baked in at
trace time), and shift-adds everything into a two-limb (radix 2**20) int32
accumulator pair held in VMEM scratch — the same exact-arithmetic strategy as
``core.crossbar``.

Two kernels:

* ``crossbar_vmm`` — the paper-faithful datapath: T x S = 128 MXU dots of
  (bm, 128) x (128, bn) per block, each a {0,1} x {0..3} product (exact in
  f32 by a large margin), with the ADC transform applied per conversion.
* ``crossbar_vmm_fast`` — exact fused path when no ADC transform is needed
  (full-resolution ADCs): splits activations into two 8-bit halves and does
  2 x S = 16 dots per block; each dot's accumulator is bounded by
  255 * 3 * 128 < 2**24, so f32 stays exact.

Grid is (M/bm, N/bn, K/bk) with bk = rows = 128 (the ADC row-group); the
k axis is the innermost reduction ("arbitrary" semantics).  Both kernels are
validated in interpret mode against ``ref.crossbar_vmm_ref`` across shape /
guard sweeps (tests/test_kernels.py) — bit-identical outputs.

Both kernels are column-separable (bitline j reads only weight column j),
which is what lets ``device.repair`` bake spare-column repairs into the
weight layout at programming time instead of gathering kernel outputs —
tests/test_repair.py pins gather-commutation down bit-for-bit.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.adc import ADCConfig, window
from repro.core.crossbar import CrossbarSpec, DEFAULT_SPEC, RADIX_BITS, RADIX_MASK

DEFAULT_BM = 128
DEFAULT_BN = 128

# jax renamed TPUCompilerParams -> CompilerParams around 0.5; support both.
COMPILER_PARAMS = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def _schedule_tables(spec: CrossbarSpec, cfg: Optional[ADCConfig]):
    """Static per-(t, s) LSB shift and MSB detect tables (python ints)."""
    T, S = spec.n_iters, spec.n_slices
    if cfg is None or cfg.mode == "full":
        return [[0] * S for _ in range(T)], [[None] * S for _ in range(T)]
    lo, hi = window(spec, cfg)
    shifts, detects = [], []
    for t in range(T):
        srow, drow = [], []
        for s in range(S):
            base = spec.base_shift(t, s)
            srow.append(int(np.clip(lo - base, 0, spec.adc_bits)))
            hi_rel = hi - base
            # MSB detect is only sound on the unsigned datapath (see adc.py)
            if cfg.msb_clamp and hi_rel < spec.adc_bits and not spec.signed_weights:
                drow.append(int(hi_rel))
            else:
                drow.append(None)
        shifts.append(srow)
        detects.append(drow)
    return shifts, detects


def _vmm_kernel(
    x_ref, w_ref, xsum_ref, o_ref, acc_hi, acc_lo, flag_ref, *,
    spec: CrossbarSpec, shifts, detects, n_k: int, skip_zero_planes: bool,
):
    """One (bm, bn) output block; k-axis accumulates row groups.

    With ``skip_zero_planes`` the T x S dot loop is predicated per iteration
    ``t`` on the plane popcount: an all-zero input bit-plane produces only
    zero partials (and zero ADC/flag effects — a rounded/clamped 0 is 0), so
    a real adaptive ADC never samples it (Ibrayev et al.) and the kernel
    skips all S dots for that plane.  Bit-identical to the dense loop; on
    post-ReLU activations most high planes are dead, so the win is large.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_hi[...] = jnp.zeros_like(acc_hi)
        acc_lo[...] = jnp.zeros_like(acc_lo)
        flag_ref[...] = jnp.zeros_like(flag_ref)

    x = x_ref[...]  # (bm, bk) int32 unsigned codes
    w = w_ref[...]  # (bk, bn) int32 biased cell codes
    T, S = spec.n_iters, spec.n_slices
    cell_mask = (1 << spec.cell_bits) - 1
    dac_mask = (1 << spec.dac_bits) - 1

    for t in range(T):
        plane_i = (x >> (t * spec.dac_bits)) & dac_mask

        def _accum(plane_i=plane_i, t=t):
            plane = plane_i.astype(jnp.float32)
            hi_acc = acc_hi[...]
            lo_acc = acc_lo[...]
            flags = flag_ref[...]
            for s in range(S):
                sl = ((w >> (s * spec.cell_bits)) & cell_mask).astype(jnp.float32)
                # {0..dac_max} x {0..3} over 128 rows: exact in f32 (<= 2**9)
                p = jax.lax.dot_general(
                    plane, sl, (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32,
                ).astype(jnp.int32)
                g = shifts[t][s]
                if g > 0:  # SAR skips LSBs below the window: round-half-up
                    p = ((p + (1 << (g - 1))) >> g) << g
                d = detects[t][s]
                if d is not None:  # overflow-detect comparison -> clamp signal
                    flags = jnp.maximum(flags, ((p >> d) > 0).astype(jnp.int32))
                base = spec.base_shift(t, s)
                if base < RADIX_BITS:
                    sh = p << base  # <= 2**(19 + adc_bits) — safe
                    lo_acc = lo_acc + (sh & RADIX_MASK)
                    hi_acc = hi_acc + (sh >> RADIX_BITS)
                else:
                    hi_acc = hi_acc + (p << (base - RADIX_BITS))
            # normalize per plane so limbs stay far from overflow
            carry = lo_acc >> RADIX_BITS
            acc_hi[...] = hi_acc + carry
            acc_lo[...] = lo_acc - (carry << RADIX_BITS)
            flag_ref[...] = flags

        if skip_zero_planes:
            pl.when(jnp.any(plane_i != 0))(_accum)
        else:
            _accum()

    @pl.when(k == n_k - 1)
    def _finalize():
        _requantize_block(o_ref, acc_hi, acc_lo, flag_ref, xsum_ref, spec)


def _requantize_block(o_ref, acc_hi, acc_lo, flag_ref, xsum_ref, spec: CrossbarSpec):
    hi = acc_hi[...]
    lo = acc_lo[...]
    if spec.signed_weights:
        xs = xsum_ref[...]  # (bm, 1) int32 sum of input codes
        wb = spec.weight_bits - 1
        if wb >= RADIX_BITS:
            b_hi = xs << (wb - RADIX_BITS)
            b_lo = jnp.zeros_like(xs)
        else:
            b_hi = xs >> (RADIX_BITS - wb)
            b_lo = (xs << wb) & RADIX_MASK
        hi = hi - b_hi
        lo = lo - b_lo
        out_max = (1 << (spec.out_bits - 1)) - 1
        out_min = -(1 << (spec.out_bits - 1))
    else:
        out_max = (1 << spec.out_bits) - 1
        out_min = 0
    carry = lo >> RADIX_BITS
    hi = hi + carry
    lo = lo - (carry << RADIX_BITS)
    d = spec.drop_lsb
    if d < RADIX_BITS:
        hi_cap = (1 << max(spec.out_bits + d - RADIX_BITS, 1)) + 1
        hi_c = jnp.clip(hi, -hi_cap, hi_cap)
        y = (hi_c << (RADIX_BITS - d)) + ((lo + (1 << (d - 1))) >> d)
        y = jnp.where(hi > hi_cap, out_max, jnp.where(hi < -hi_cap, out_min, y))
    else:
        # exact for d >= 20: see core.crossbar._scale_round_clip
        if d - 1 >= 31:
            tmp = lo
            hi = hi + (1 << (d - 1 - RADIX_BITS))
        else:
            tmp = lo + (1 << (d - 1))
        y = (hi + (tmp >> RADIX_BITS)) >> (d - RADIX_BITS)
    y = jnp.clip(y, out_min, out_max)
    y = jnp.where(flag_ref[...] > 0, out_max, y)
    o_ref[...] = y.astype(jnp.int32)


def _fast_kernel(x_ref, w_ref, xsum_ref, o_ref, acc_hi, acc_lo, flag_ref, *,
                 spec: CrossbarSpec, n_k: int, skip_zero_planes: bool):
    """Fused exact path: 2 activation halves x S slices = 16 dots/block.

    ``skip_zero_planes`` predicates each activation half on its popcount —
    small post-ReLU codes leave the high half all-zero, halving the dots.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_hi[...] = jnp.zeros_like(acc_hi)
        acc_lo[...] = jnp.zeros_like(acc_lo)
        flag_ref[...] = jnp.zeros_like(flag_ref)

    x = x_ref[...]
    w = w_ref[...]
    S = spec.n_slices
    cell_mask = (1 << spec.cell_bits) - 1
    half = spec.input_bits // 2
    hmask = (1 << half) - 1
    for hx, xbits in ((0, (x & hmask)), (half, (x >> half) & hmask)):

        def _accum(xbits=xbits, hx=hx):
            xf = xbits.astype(jnp.float32)
            hi_acc = acc_hi[...]
            lo_acc = acc_lo[...]
            for s in range(S):
                sl = ((w >> (s * spec.cell_bits)) & cell_mask).astype(jnp.float32)
                # 255 * 3 * 128 < 2**24: exact in f32
                p = jax.lax.dot_general(
                    xf, sl, (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32,
                ).astype(jnp.int32)
                base = hx + s * spec.cell_bits
                if base < RADIX_BITS:
                    # p < 2**17, so split before shifting to stay in int32:
                    # p * 2**base = (p >> k) * 2**20 + (p & (2**k - 1)) * 2**base
                    k_bits = RADIX_BITS - base
                    hi_acc = hi_acc + (p >> k_bits)
                    lo_acc = lo_acc + ((p & ((1 << k_bits) - 1)) << base)
                else:
                    hi_acc = hi_acc + (p << (base - RADIX_BITS))
            carry = lo_acc >> RADIX_BITS
            acc_hi[...] = hi_acc + carry
            acc_lo[...] = lo_acc - (carry << RADIX_BITS)

        if skip_zero_planes:
            pl.when(jnp.any(xbits != 0))(_accum)
        else:
            _accum()

    @pl.when(k == n_k - 1)
    def _finalize():
        _requantize_block(o_ref, acc_hi, acc_lo, flag_ref, xsum_ref, spec)


def _pad_to(a: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    size = a.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


@functools.partial(
    jax.jit,
    static_argnames=(
        "spec", "adc_cfg", "block_m", "block_n", "fast", "interpret",
        "skip_zero_planes",
    ),
)
def crossbar_vmm_pallas(
    x_codes: jnp.ndarray,
    w_codes: jnp.ndarray,
    spec: CrossbarSpec = DEFAULT_SPEC,
    adc_cfg: Optional[ADCConfig] = None,
    block_m: int = DEFAULT_BM,
    block_n: int = DEFAULT_BN,
    fast: bool = False,
    interpret: bool = False,
    skip_zero_planes: bool = True,
) -> jnp.ndarray:
    """Crossbar VMM on integer codes via the Pallas kernel.

    x_codes: (..., K) unsigned input codes; w_codes: (K, N) signed codes when
    ``spec.signed_weights``.  Returns (..., N) int32 output codes identical
    to ``repro.core.crossbar.crossbar_vmm``.

    ``skip_zero_planes``: predicate each input bit-plane's dots on its
    popcount (``@pl.when``); bit-identical either way, faster on sparse
    inputs.  ``core.crossbar.plane_activity`` counts the skipped
    conversions for the energy model.
    """
    batch_shape = x_codes.shape[:-1]
    K = x_codes.shape[-1]
    N = w_codes.shape[-1]
    x = x_codes.reshape(-1, K).astype(jnp.int32)
    M = x.shape[0]
    w = w_codes.astype(jnp.int32) + spec.weight_bias

    bm = min(block_m, max(8, M))
    bn = min(block_n, N)
    bk = spec.rows

    xs = jnp.sum(x, axis=-1, keepdims=True)  # (M, 1) before padding
    x = _pad_to(_pad_to(x, 0, bm), 1, bk)
    xs = _pad_to(xs, 0, bm)
    w = _pad_to(_pad_to(w, 0, bk), 1, bn)
    # Padded K rows hold cell code 0 and x code 0: zero contribution.
    Mp, Kp = x.shape
    Np = w.shape[1]
    grid = (Mp // bm, Np // bn, Kp // bk)

    shifts, detects = _schedule_tables(spec, adc_cfg)
    if fast:
        if adc_cfg is not None and adc_cfg.mode != "full":
            raise ValueError("fast path models full-resolution ADCs only")
        kernel = functools.partial(
            _fast_kernel, spec=spec, n_k=grid[2], skip_zero_planes=skip_zero_planes
        )
    else:
        kernel = functools.partial(
            _vmm_kernel, spec=spec, shifts=shifts, detects=detects, n_k=grid[2],
            skip_zero_planes=skip_zero_planes,
        )

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.int32),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.int32),  # accumulator hi limb
            pltpu.VMEM((bm, bn), jnp.int32),  # accumulator lo limb
            pltpu.VMEM((bm, bn), jnp.int32),  # ADC overflow clamp flags
        ],
        compiler_params=COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, w, xs)
    return out[:M, :N].reshape(batch_shape + (N,))
