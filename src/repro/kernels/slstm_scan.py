"""Fused sLSTM recurrence as a Pallas TPU kernel (EXPERIMENTS.md §Perf, cell 1).

The sLSTM scan is strictly sequential; under XLA each of the S steps re-reads
the four (H, dh, dh) recurrent matrices from HBM — ~33 MB x 4096 steps
~ 137 GB per device per training step, the dominant memory-roofline term of
xlstm-350m after the pure-DP layout fix.

This kernel pins the recurrent matrices (8 MB bf16) and the (c, n, h) state
in VMEM and streams only the per-step pre-activations: grid (B, S) with the
sequence axis innermost ("arbitrary" semantics), Pallas pipelining keeps the
constant-index R blocks resident, and per-step HBM traffic drops to the
x-projection stream (4*H*dh values in, H*dh out).

Validated in interpret mode against the pure-jnp scan (ref:
``models.xlstm.slstm_block``) — see tests/test_kernels.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.crossbar_vmm import COMPILER_PARAMS

IGATE_CLIP = 5.0


def _kernel(pre_ref, rz_ref, ri_ref, rf_ref, ro_ref, c0_ref, n0_ref, h0_ref,
            h_out_ref, c_out_ref, n_out_ref, hn_out_ref, state, *, seq_len: int):
    s = pl.program_id(1)

    @pl.when(s == 0)
    def _init():
        state[0] = c0_ref[0].astype(jnp.float32)
        state[1] = n0_ref[0].astype(jnp.float32)
        state[2] = h0_ref[0].astype(jnp.float32)

    c_, n_, h_ = state[0], state[1], state[2]  # (H, dh) f32
    pre = pre_ref[0, 0].astype(jnp.float32)  # (4, H, dh)

    def rec(r_ref):
        # (H, dh) x (H, dh, dh) -> (H, dh), batched over heads
        return jax.lax.dot_general(
            h_.astype(jnp.float32), r_ref[...].astype(jnp.float32),
            (((1,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32,
        )

    z = jnp.tanh(pre[0] + rec(rz_ref))
    i = jnp.exp(jnp.minimum(pre[1] + rec(ri_ref), IGATE_CLIP))
    f = jax.nn.sigmoid(pre[2] + rec(rf_ref))
    o = jax.nn.sigmoid(pre[3] + rec(ro_ref))
    c1 = f * c_ + i * z
    n1 = f * n_ + i
    h1 = o * c1 / jnp.maximum(n1, 1.0)
    state[0], state[1], state[2] = c1, n1, h1
    h_out_ref[0, 0] = h1.astype(h_out_ref.dtype)

    @pl.when(s == seq_len - 1)
    def _final():
        c_out_ref[0] = c1.astype(c_out_ref.dtype)
        n_out_ref[0] = n1.astype(n_out_ref.dtype)
        hn_out_ref[0] = h1.astype(hn_out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def slstm_scan_pallas(pre, r_z, r_i, r_f, r_o, c0, n0, h0, interpret: bool = False):
    """pre: (B, S, 4, H, dh); r_*: (H, dh, dh); c0/n0/h0: (B, H, dh).

    Returns (h_all (B, S, H, dh), c1, n1, h1)."""
    B, S, _, H, dh = pre.shape
    kernel = functools.partial(_kernel, seq_len=S)
    grid = (B, S)
    r_spec = pl.BlockSpec((H, dh, dh), lambda b, s: (0, 0, 0))
    st_spec = pl.BlockSpec((1, H, dh), lambda b, s: (b, 0, 0))
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 4, H, dh), lambda b, s: (b, s, 0, 0, 0)),
            r_spec, r_spec, r_spec, r_spec,
            st_spec, st_spec, st_spec,
        ],
        out_specs=[
            pl.BlockSpec((1, 1, H, dh), lambda b, s: (b, s, 0, 0)),
            st_spec, st_spec, st_spec,
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, dh), pre.dtype),
            jax.ShapeDtypeStruct((B, H, dh), jnp.float32),
            jax.ShapeDtypeStruct((B, H, dh), jnp.float32),
            jax.ShapeDtypeStruct((B, H, dh), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((3, H, dh), jnp.float32)],
        compiler_params=COMPILER_PARAMS(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(pre, r_z, r_i, r_f, r_o, c0, n0, h0)
    return out[0], out[1], out[2], out[3]
