"""Batched Pallas kernel for the device-perturbed crossbar VMM.

Same structure as ``crossbar_vmm``'s paper-faithful kernel — grid (M/bm,
N/bn, K/bk) with bk = rows, T x S MXU dots per block, two-limb (radix 2**20)
int32 accumulator in VMEM scratch — but the weight operand is the *effective
cell code* array from ``repro.device``: (S, K, N) float32, one perturbed
value per (slice, wordline, bitline) instead of S bit-slices re-derived from
an int32 block in-register.  Each dot is a {0..dac_max} x [0, cell_max]
product; the ADC stage rounds the analog column sum half-up to an integer
code and saturates at ``partial_max``, after which the shift-add tree is the
exact integer arithmetic shared with the ideal kernel.

Exactness argument (why the kernel is validated bit-identical, not
allclose, against ``core.crossbar.noisy_crossbar_vmm``): effective codes are
quantized to a ``2**-GEFF_FRAC_BITS`` grid, so every partial product and
every partial sum is a multiple of the grid step bounded by ``partial_max``
— all exactly representable in float32 (``partial_max << GEFF_FRAC_BITS <
2**24``), making f32 accumulation order-independent.  The adaptive-ADC
shift/clamp tables from ``crossbar_vmm`` apply unchanged, so noise sweeps
can compare full vs adaptive ADC configs on identical perturbed cells.

Spare-column repair (``device.repair``) needs no kernel support: the
datapath is column-separable (bitline j only reads ``g_eff[:, :, j]``), so
the repaired layout — spare cells scattered into victim columns at
programming time — is just another ``g_eff`` and the kernel serves it with
zero steady-state overhead.  tests/test_repair.py pins the equivalence to
an explicit physical-layout + output-gather formulation bit-for-bit.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.adc import ADCConfig
from repro.core.crossbar import CrossbarSpec, DEFAULT_SPEC, RADIX_BITS, RADIX_MASK
from repro.device.models import GEFF_FRAC_BITS
from repro.kernels.crossbar_vmm import (
    COMPILER_PARAMS,
    DEFAULT_BM,
    DEFAULT_BN,
    _pad_to,
    _requantize_block,
    _schedule_tables,
)


def _noisy_kernel(
    x_ref, g_ref, xsum_ref, o_ref, acc_hi, acc_lo, flag_ref, *,
    spec: CrossbarSpec, shifts, detects, n_k: int, skip_zero_planes: bool,
):
    """One (bm, bn) output block against perturbed cells; k accumulates groups.

    ``skip_zero_planes``: as in ``crossbar_vmm._vmm_kernel`` — an all-zero
    input bit-plane drives zero current into every bitline regardless of the
    perturbed cell values (0 * g == 0, and the ADC's round/saturate of 0 is
    0), so its S dots are skipped under a ``@pl.when`` popcount predicate.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_hi[...] = jnp.zeros_like(acc_hi)
        acc_lo[...] = jnp.zeros_like(acc_lo)
        flag_ref[...] = jnp.zeros_like(flag_ref)

    x = x_ref[...]  # (bm, bk) int32 unsigned codes
    g = g_ref[...]  # (S, bk, bn) f32 effective cell codes
    T, S = spec.n_iters, spec.n_slices
    dac_mask = (1 << spec.dac_bits) - 1

    for t in range(T):
        plane_i = (x >> (t * spec.dac_bits)) & dac_mask

        def _accum(plane_i=plane_i, t=t):
            plane = plane_i.astype(jnp.float32)
            hi_acc = acc_hi[...]
            lo_acc = acc_lo[...]
            flags = flag_ref[...]
            for s in range(S):
                # grid-quantized cells keep this dot exact in f32 (module doc)
                raw = jax.lax.dot_general(
                    plane, g[s], (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32,
                )
                # ADC sampling: round-half-up to an integer code, saturating
                p = jnp.floor(raw + 0.5).astype(jnp.int32)
                p = jnp.clip(p, 0, spec.partial_max)
                gsh = shifts[t][s]
                if gsh > 0:  # SAR skips LSBs below the window: round-half-up
                    p = ((p + (1 << (gsh - 1))) >> gsh) << gsh
                d = detects[t][s]
                if d is not None:  # overflow-detect comparison -> clamp signal
                    flags = jnp.maximum(flags, ((p >> d) > 0).astype(jnp.int32))
                base = spec.base_shift(t, s)
                if base < RADIX_BITS:
                    sh = p << base  # <= 2**(19 + adc_bits) — safe
                    lo_acc = lo_acc + (sh & RADIX_MASK)
                    hi_acc = hi_acc + (sh >> RADIX_BITS)
                else:
                    hi_acc = hi_acc + (p << (base - RADIX_BITS))
            carry = lo_acc >> RADIX_BITS
            acc_hi[...] = hi_acc + carry
            acc_lo[...] = lo_acc - (carry << RADIX_BITS)
            flag_ref[...] = flags

        if skip_zero_planes:
            pl.when(jnp.any(plane_i != 0))(_accum)
        else:
            _accum()

    @pl.when(k == n_k - 1)
    def _finalize():
        _requantize_block(o_ref, acc_hi, acc_lo, flag_ref, xsum_ref, spec)


@functools.partial(
    jax.jit,
    static_argnames=(
        "spec", "adc_cfg", "block_m", "block_n", "interpret", "skip_zero_planes",
    ),
)
def noisy_vmm_pallas(
    x_codes: jnp.ndarray,
    g_eff: jnp.ndarray,
    spec: CrossbarSpec = DEFAULT_SPEC,
    adc_cfg: Optional[ADCConfig] = None,
    block_m: int = DEFAULT_BM,
    block_n: int = DEFAULT_BN,
    interpret: bool = False,
    skip_zero_planes: bool = True,
) -> jnp.ndarray:
    """Device-perturbed crossbar VMM via the Pallas kernel.

    x_codes: (..., K) unsigned input codes; g_eff: (S, K, N) float32
    effective cell codes (``repro.device.models.effective_cell_codes``).
    Returns (..., N) int32 output codes identical to
    ``repro.core.crossbar.noisy_crossbar_vmm``; ``skip_zero_planes`` is the
    bit-identical plane-popcount early-out (see ``crossbar_vmm``).
    """
    if spec.partial_max << GEFF_FRAC_BITS >= 1 << 24:
        raise ValueError(
            f"partial_max {spec.partial_max} too wide for exact f32 sums at "
            f"{GEFF_FRAC_BITS} fractional bits"
        )
    batch_shape = x_codes.shape[:-1]
    K = x_codes.shape[-1]
    S, Kg, N = g_eff.shape
    if Kg != K or S != spec.n_slices:
        raise ValueError(f"g_eff shape {g_eff.shape} != ({spec.n_slices}, {K}, N)")
    x = x_codes.reshape(-1, K).astype(jnp.int32)
    M = x.shape[0]
    g = g_eff.astype(jnp.float32)

    bm = min(block_m, max(8, M))
    bn = min(block_n, N)
    bk = spec.rows

    xs = jnp.sum(x, axis=-1, keepdims=True)  # (M, 1) before padding
    x = _pad_to(_pad_to(x, 0, bm), 1, bk)
    xs = _pad_to(xs, 0, bm)
    g = _pad_to(_pad_to(g, 1, bk), 2, bn)
    # Padded K rows hold x code 0: zero planes, zero contribution.
    Mp, Kp = x.shape
    Np = g.shape[2]
    grid = (Mp // bm, Np // bn, Kp // bk)

    shifts, detects = _schedule_tables(spec, adc_cfg)
    kernel = functools.partial(
        _noisy_kernel, spec=spec, shifts=shifts, detects=detects, n_k=grid[2],
        skip_zero_planes=skip_zero_planes,
    )

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((S, bk, bn), lambda i, j, k: (0, k, j)),
            pl.BlockSpec((bm, 1), lambda i, j, k: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.int32),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.int32),  # accumulator hi limb
            pltpu.VMEM((bm, bn), jnp.int32),  # accumulator lo limb
            pltpu.VMEM((bm, bn), jnp.int32),  # ADC overflow clamp flags
        ],
        compiler_params=COMPILER_PARAMS(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, g, xs)
    return out[:M, :N].reshape(batch_shape + (N,))
