"""Drift health monitoring and free digital compensation.

A programmed chip decays in service (power-law retention drift —
``models.drift_time_factor``), but the *digital* record it was programmed
from is immortal: ``w_codes`` / ``w_colsum`` / the quantization scales never
age.  That asymmetry is the whole lifecycle story:

* **Monitor** (``probe_artifact`` / ``health_check``): push a small batch of
  seeded non-negative probe vectors through the served (possibly aged)
  datapath and through the artifact's *digital twin* — the same artifact
  with every analog leaf stripped, so ``programmed_matmul`` serves the
  ideal ``w_codes`` path.  The relative probe error is the chip's drift
  health; a per-layer budget turns it into a flag the serving engine can
  schedule refreshes from.

* **Compensate** (``fit_compensation``): retention drift is almost exactly
  a common conductance scale — in code space an aged cell reads
  ``f*c + (f-1)*g_off/step`` with the additive term well under one write
  grid step — so a *digital* per-column output rescale recovers most of
  the error, for free: ``ProgrammedLinear.comp_scale`` lives outside the
  chip and updating it costs no reprogramming.  The scale is the
  closed-form power-law factor ``1/f`` refined by a per-column least
  squares fit of the probe responses (the residual picks up clipping,
  grid re-quantization and the additive offset term).

* **Refresh** (``checkpoint.swap_active`` + ``ServingEngine.hot_swap``):
  when compensation can no longer hold a layer under budget, reprogram
  into the inactive store slot and swap — the only step that touches the
  analog array.

Everything here runs on the digital side at inference time; none of it
perturbs the programmed cells.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.device import models as dm
from repro.device.programmed import (
    ProgrammedLinear,
    ProgrammedModel,
    programmed_matmul,
)

DEFAULT_PROBES = 16
DEFAULT_BUDGET = 0.05  # relative RMS probe error a healthy layer stays under


def digital_twin(art: ProgrammedLinear) -> ProgrammedLinear:
    """The artifact's frozen digital reference.

    Strips every analog leaf (``g_eff`` / ``g_spare`` / ``out_gather``) and
    the compensation scales, so ``programmed_matmul`` serves the ideal
    ``w_codes`` datapath — exactly what the chip was programmed to realize,
    at any service time.  Quantization scales and spec are shared with the
    real chip, so probe responses are comparable column by column.
    """
    return dataclasses.replace(
        art,
        g_eff=None,
        g_spare=None,
        out_gather=None,
        comp_scale=None,
        report=None,
        repair=None,
    )


def probe_vectors(k: int, n_probes: int = DEFAULT_PROBES, seed: int = 0) -> jnp.ndarray:
    """Seeded non-negative probe batch (n_probes, k).

    Uniform on (0, 1]: ``programmed_matmul`` requires non-negative inputs
    (the offset-encoded signed path is a wrapper), and a strictly positive
    batch exercises every row of the chip.  Deterministic in (k, seed) so
    monitor readings are comparable across checks and across hosts.
    """
    key = jax.random.fold_in(jax.random.PRNGKey(seed), k)
    return jax.random.uniform(
        key, (n_probes, k), jnp.float32, minval=1.0 / (1 << 10), maxval=1.0
    )


def _leading_slices(art: ProgrammedLinear):
    """Yield every servable (K, N) slice of a (possibly stacked) artifact."""
    if not art.stacked:
        yield art
        return
    for i in range(art.shape[0]):
        yield from _leading_slices(art.layer(i))


def probe_artifact(
    art: ProgrammedLinear,
    n_probes: int = DEFAULT_PROBES,
    seed: int = 0,
    interpret: Optional[bool] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(served, reference) probe responses, stacked over servable slices.

    ``served`` runs the artifact as bound — aged cells, repair layout,
    compensation scales, everything the inference path sees; ``reference``
    runs the digital twin.  Shapes are (n_slices, n_probes, N).
    """
    xs = probe_vectors(int(art.shape[-2]), n_probes, seed)
    served, ref = [], []
    for sl in _leading_slices(art):
        served.append(programmed_matmul(xs, sl, interpret=interpret))
        ref.append(programmed_matmul(xs, digital_twin(sl), interpret=interpret))
    return jnp.stack(served), jnp.stack(ref)


@dataclasses.dataclass(frozen=True)
class LayerHealth:
    """One bound artifact's drift reading."""

    name: str
    rel_err: float  # ||served - reference|| / ||reference|| over the probes
    mse: float
    t_service_s: float
    budget: float

    @property
    def over_budget(self) -> bool:
        return self.rel_err > self.budget


@dataclasses.dataclass(frozen=True)
class HealthReport:
    """Per-layer drift health for a whole programmed model."""

    layers: Tuple[LayerHealth, ...]
    budget: float

    @property
    def flagged(self) -> Tuple[str, ...]:
        """Names whose probe error crossed the budget — refresh candidates."""
        return tuple(l.name for l in self.layers if l.over_budget)

    @property
    def worst(self) -> float:
        return max((l.rel_err for l in self.layers), default=0.0)

    @property
    def healthy(self) -> bool:
        return not self.flagged

    def __repr__(self) -> str:  # compact operator view
        return (
            f"HealthReport(worst={self.worst:.4g}, budget={self.budget:g}, "
            f"flagged={len(self.flagged)}/{len(self.layers)})"
        )


def layer_health(
    name: str,
    art: ProgrammedLinear,
    n_probes: int = DEFAULT_PROBES,
    seed: int = 0,
    budget: float = DEFAULT_BUDGET,
    interpret: Optional[bool] = None,
) -> LayerHealth:
    """Probe one artifact against its digital twin."""
    served, ref = probe_artifact(art, n_probes, seed, interpret=interpret)
    diff = served - ref
    mse = float(jnp.mean(diff**2))
    rel = float(
        jnp.sqrt(jnp.sum(diff**2)) / jnp.maximum(jnp.sqrt(jnp.sum(ref**2)), 1e-12)
    )
    return LayerHealth(
        name=name, rel_err=rel, mse=mse, t_service_s=art.t_service_s, budget=budget
    )


def health_check(
    prog: ProgrammedModel,
    n_probes: int = DEFAULT_PROBES,
    seed: int = 0,
    budget: float = DEFAULT_BUDGET,
    interpret: Optional[bool] = None,
) -> HealthReport:
    """Probe every bound artifact; the serving engine's monitor entry point."""
    layers = tuple(
        layer_health(name, art, n_probes, seed, budget, interpret=interpret)
        for name, art in sorted(prog.by_name.items())
    )
    return HealthReport(layers=layers, budget=budget)


def closed_form_scale(art: ProgrammedLinear) -> float:
    """The zero-probe compensation: inverse of the accrued power-law decay.

    Conductance decays by ``f = drift_time_factor(device, 0, t_service_s)``
    since programming, so multiplying the analog output by ``1/f`` undoes
    the common-mode drift exactly (up to the additive ``(f-1)*g_off/step``
    code offset and grid re-quantization, which the probe fit mops up).
    """
    if art.device is None or art.g_eff is None or art.t_service_s == 0.0:
        return 1.0
    return 1.0 / dm.drift_time_factor(art.device, 0.0, art.t_service_s)


def fit_compensation(
    art: ProgrammedLinear,
    n_probes: int = DEFAULT_PROBES,
    seed: int = 0,
    interpret: Optional[bool] = None,
) -> ProgrammedLinear:
    """Refit the artifact's digital compensation scales — zero reprogramming.

    Per output column, the least-squares scale aligning the served probe
    response with the digital reference::

        s_j = sum_i ref[i,j] * served[i,j] / sum_i served[i,j]^2

    seeded by the closed-form power-law factor: the fit runs on the
    ``1/f``-rescaled response, so the probe batch only has to resolve the
    *residual* (clipping, re-quantization, the additive offset term) around
    1.0 rather than the full decay.  Stacked artifacts get per-slice scale
    rows — ``comp_scale`` carries the same leading axes as every other
    leaf, so the layer/expert scans slice it like the cells.

    The fit measures the chip *without* its current compensation (a refit
    replaces, never compounds).  Degenerate columns (zero probe response)
    keep the closed-form scale.
    """
    base = closed_form_scale(art)
    xs = probe_vectors(int(art.shape[-2]), n_probes, seed)
    lead = art.shape[:-2]

    def _fit(sl: ProgrammedLinear) -> jnp.ndarray:
        raw = dataclasses.replace(sl, comp_scale=None)
        served = programmed_matmul(xs, raw, interpret=interpret) * base
        ref = programmed_matmul(xs, digital_twin(sl), interpret=interpret)
        num = jnp.sum(ref * served, axis=0)
        den = jnp.sum(served * served, axis=0)
        resid = jnp.where(den > 0.0, num / jnp.maximum(den, 1e-30), 1.0)
        return jnp.asarray(base, jnp.float32) * resid

    scales = jnp.stack([_fit(sl) for sl in _leading_slices(art)])
    comp = scales.reshape(lead + (int(art.shape[-1]),))
    return dataclasses.replace(art, comp_scale=comp)


def compensate_model(
    prog: ProgrammedModel,
    n_probes: int = DEFAULT_PROBES,
    seed: int = 0,
    interpret: Optional[bool] = None,
) -> ProgrammedModel:
    """``fit_compensation`` over every noisy artifact (ideal chips have no
    drift to compensate and keep ``comp_scale=None`` — bit-identical)."""
    return prog.map_artifacts(
        lambda a: (
            fit_compensation(a, n_probes, seed, interpret=interpret)
            if a.g_eff is not None
            else a
        )
    )
