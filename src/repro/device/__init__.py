"""Device non-ideality subsystem: memristor fault/variation models, the
write-verify programmer, and the read-time pipeline that turns ideal cell
codes into the perturbed values the noisy datapath multiplies against.

Entry points:
  * ``DeviceConfig`` / ``IDEAL_DEVICE`` — the knobs (all-default == ideal).
  * ``effective_cell_codes`` — (K, N) biased codes -> (S, K, N) effective.
  * ``program.write_verify`` — calibration loop with convergence report.
  * ``core.crossbar.crossbar_vmm(..., device=cfg)`` and
    ``kernels.ops.noisy_vmm_op`` — functional / Pallas inference paths.
  * ``programmed.program_layer`` / ``program_model`` — program-once
    compilation into frozen ``ProgrammedLinear`` artifacts (2-D, scan-
    stacked 3-D, or 4-D MoE expert banks; ``tie_lm_head=True`` programs
    the embedding transpose for tied heads); steady-state serving via
    ``programmed_matmul`` / ``programmed_linear``.  Artifacts bind by
    canonical parameter *name* (``name_scope`` / ``bind_artifacts`` /
    ``ProgrammedModel.by_name``), so binding survives pytree copies, jit
    retraces and transposes; ``checkpoint.save_programmed`` persists the
    chip bit-for-bit.
  * ``repair.plan_repair`` / ``apply_repair`` — fault-aware spare-column
    repair: rank columns by fault-weighted salience, remap the worst into a
    ``DeviceConfig.spare_cols`` budget of programmed spares (zero
    steady-state overhead; ``RepairReport`` records what moved).
  * the **chip lifecycle**: ``age_artifact`` / ``artifact_at_time`` evolve a
    programmed chip through the retention-drift power law without
    reprogramming; ``health.health_check`` probes every bound artifact
    against its frozen digital twin; ``health.fit_compensation`` refits the
    free digital ``comp_scale`` correction; ``checkpoint`` slot A/B +
    ``ServingEngine.hot_swap`` close the loop with a zero-downtime refresh.
"""
from repro.device.models import (  # noqa: F401
    DeviceConfig,
    GEFF_FRAC_BITS,
    IDEAL_DEVICE,
    drift_time_factor,
    effective_cell_codes,
    effective_drift_nu,
    fault_masks,
    programmed_conductance,
    read_effective_codes,
    target_cell_codes,
    wants_repair,
)
from repro.device.health import (  # noqa: F401
    HealthReport,
    LayerHealth,
    compensate_model,
    digital_twin,
    fit_compensation,
    health_check,
    layer_health,
    probe_artifact,
)
from repro.device.program import ProgramReport, write_verify  # noqa: F401
from repro.device.repair import (  # noqa: F401
    RepairPlan,
    RepairReport,
    apply_repair,
    column_salience,
    plan_repair,
    repair_report,
    repaired_effective_cells,
    spare_budget,
)
from repro.device.programmed import (  # noqa: F401
    ProgrammedLinear,
    ProgrammedModel,
    age_artifact,
    artifact_at_time,
    artifact_arrays,
    artifact_shard_specs,
    bind_artifacts,
    consumed_artifact_names,
    local_artifact,
    name_scope,
    program_layer,
    program_model,
    programmed_linear,
    programmed_matmul,
    reset_consumed_artifact_names,
    scoped_name,
    shard_artifacts,
    with_arrays,
)
