"""Memristor device non-ideality models for the Newton crossbar datapath.

Composable, seeded models of everything between "the mapper assigns cell code
``c``" and "the column ADC samples a current":

* **conductance quantization** — a cell stores one of ``2**cell_bits`` levels
  spread linearly over the device rails ``[g_off_s, g_on_s]`` (the AG2048
  metal-oxide device range, 3.16 uS .. 316 uS),
* **programming variation** — each write lands lognormally distributed around
  the target conductance (``sigma`` on ``ln G``),
* **drift** — programmed conductance decays as the power law
  ``G(t) = G0 * (1 + t/t0)**-nu`` (PCM/ReRAM retention),
* **stuck-at faults** — a seeded per-cell map pins faulty cells to the
  ``g_on_s`` / ``g_off_s`` rails regardless of writes,
* **IR drop** — wordline/bitline wire resistance attenuates each cell's
  contribution.  This follows the AG2048 ``LineResistanceCrossbar`` model
  reduced to its first-order series-resistance form (``g_eff = g / (1 + g *
  R_series)`` with ``R_series`` the wire path through column ``j`` and row
  ``i`` of the 128-row group) so it stays a closed-form jnp expression
  instead of a nodal solve.

All randomness flows from ``DeviceConfig.seed`` through stage-tagged
``jax.random.fold_in`` keys, so fault maps and programming noise are
reproducible functions of (config, weight-slab shape).  The all-default
``DeviceConfig()`` is the identity: effective cell codes equal the ideal
slices bit-for-bit (tests/test_device.py pins this down).

Effective cell values are returned in *code units* on a ``2**-GEFF_FRAC_BITS``
grid.  The grid is what makes the noisy Pallas kernel verifiable: every
column partial is a multiple of the grid step and bounded by
``spec.partial_max``, so float32 summation is exact in any order and the
kernel matches the jnp reference bit-for-bit, not just allclose
(see ``kernels/noisy_vmm.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import fixedpoint as fxp
from repro.core.crossbar import CrossbarSpec

# Fractional bits of the effective-cell-code grid.  Exactness of f32 column
# sums needs partial_max * 2**GEFF_FRAC_BITS < 2**24 (float32 integer range):
# 384 * 256 = 98304 for the default spec, with ample headroom for variants.
GEFF_FRAC_BITS = 8

# Stage-key registry: every independent randomness stream in the programming
# pipeline is named here, once, with a distinct fold_in index.  Call sites
# MUST use these constants (never string literals) — `repro.analysis`'s
# stage-key collision rule enforces both halves statically: duplicate indices
# here would correlate supposedly independent draws, and an ad-hoc literal at
# a call site would dodge the registry.
STAGE_FAULTS = "faults"
STAGE_PROGRAM = "program"
STAGE_SPARE_FAULTS = "spare_faults"
STAGE_SPARE_PROGRAM = "spare_program"

_STAGES = {
    STAGE_FAULTS: 0,
    STAGE_PROGRAM: 1,
    STAGE_SPARE_FAULTS: 2,
    STAGE_SPARE_PROGRAM: 3,
}


@dataclasses.dataclass(frozen=True)
class DeviceConfig:
    """Programmed-conductance non-ideality knobs (all default to ideal).

    ``spare_cols`` provisions redundant spare columns — per 128-column
    crossbar column group — for the fault-aware repair planner
    (``device.repair``): at programming time the worst fault-afflicted
    columns of a weight slab are remapped into spares drawn from their own
    seeded fault/variation fields.  Zero (the default) disables repair.

    ``temp_k`` / ``drift_ea_ev`` make retention drift temperature-dependent:
    the power-law exponent is scaled Arrhenius-style,
    ``nu(T) = drift_nu * exp((Ea/kB) * (1/T_ref - 1/T))`` with
    ``T_ref = 300 K`` — a hotter chip ages faster.  ``drift_ea_ev = 0`` (the
    default) keeps drift temperature-independent bit-for-bit, so every
    pre-existing config is unchanged.  (The AG2048 calibration folds
    temperature into ``sigma``; this knob unfolds the retention component.)

    ``chip`` is a physical chip identity mixed into every seeded draw
    (faults, programming variation): two crossbars holding *identical*
    weight slabs on the same ``seed`` draw identical non-idealities — fine
    for one die, wrong for a fleet.  Giving each rank of a sharded
    deployment its own ``chip`` index models chip-to-chip spread; ``chip=0``
    (the default) reproduces the single-die draws bit-for-bit.
    """

    sigma: float = 0.0  # lognormal programming variation of ln(G)
    p_stuck_on: float = 0.0  # fraction of cells pinned at g_on_s
    p_stuck_off: float = 0.0  # fraction of cells pinned at g_off_s
    drift_nu: float = 0.0  # power-law drift exponent
    t_drift_s: float = 0.0  # time since programming (seconds)
    t0_s: float = 1.0  # drift reference time
    r_line_ohm: float = 0.0  # wire resistance per cell segment
    g_on_s: float = 316e-6  # device rails (siemens); AG2048 static memristor
    g_off_s: float = 3.16e-6
    write_verify_iters: int = 1  # programming pulses (1 = open-loop write)
    write_verify_tol: float = 0.25  # verify tolerance, cell-code units
    spare_cols: int = 0  # spare columns per crossbar column group (repair)
    temp_k: float = 300.0  # operating temperature (drift Arrhenius scaling)
    drift_ea_ev: float = 0.0  # drift activation energy (eV); 0 = T-independent
    chip: int = 0  # physical chip identity (decorrelates fleet draws)
    seed: int = 0

    def replace(self, **kw) -> "DeviceConfig":
        return dataclasses.replace(self, **kw)

    @property
    def is_ideal(self) -> bool:
        return (
            self.sigma == 0.0
            and self.p_stuck_on == 0.0
            and self.p_stuck_off == 0.0
            and (self.drift_nu == 0.0 or self.t_drift_s == 0.0)
            and self.r_line_ohm == 0.0
        )


IDEAL_DEVICE = DeviceConfig()


def _stage_key(cfg: DeviceConfig, stage: str, tag: Optional[jnp.ndarray] = None) -> jax.Array:
    key = jax.random.PRNGKey(cfg.seed)
    if cfg.chip:
        # fold only a nonzero chip identity so chip=0 draws stay
        # bit-identical to every pre-fleet config (tests pin this)
        key = jax.random.fold_in(key, cfg.chip)
    key = jax.random.fold_in(key, _STAGES[stage])
    if tag is not None:
        key = jax.random.fold_in(key, tag)
    return key


def _slab_tag(w_codes_biased: jnp.ndarray) -> jnp.ndarray:
    """Content-derived uint32 tag mixed into the stage keys per weight slab.

    Without it, every same-shape slab in a model (e.g. all q/k/v/o
    projections) would draw identical fault maps and noise fields from the
    shared ``DeviceConfig``, making layer errors add coherently instead of
    independently.  A position-weighted wrapping sum keeps the pipeline a
    deterministic function of (config, weights) while decorrelating slabs.
    """
    w = w_codes_biased.astype(jnp.uint32).ravel()
    mix = jnp.arange(w.size, dtype=jnp.uint32) * jnp.uint32(2654435761) + jnp.uint32(1)
    return jnp.sum(w * mix, dtype=jnp.uint32)


# ---------------------------------------------------------------------------
# Conductance <-> cell-code mapping (level quantization)
# ---------------------------------------------------------------------------

def code_step_siemens(spec: CrossbarSpec, cfg: DeviceConfig) -> float:
    """Conductance per cell-code LSB: rails split into 2**cell_bits levels."""
    return (cfg.g_on_s - cfg.g_off_s) / ((1 << spec.cell_bits) - 1)


def conductance_of_codes(codes: jnp.ndarray, spec: CrossbarSpec, cfg: DeviceConfig) -> jnp.ndarray:
    return cfg.g_off_s + codes.astype(jnp.float32) * code_step_siemens(spec, cfg)


def codes_of_conductance(g: jnp.ndarray, spec: CrossbarSpec, cfg: DeviceConfig) -> jnp.ndarray:
    return (g - cfg.g_off_s) / code_step_siemens(spec, cfg)


def quantize_code_grid(codes: jnp.ndarray) -> jnp.ndarray:
    """Snap effective codes to the 2**-GEFF_FRAC_BITS grid (see module doc)."""
    scale = float(1 << GEFF_FRAC_BITS)
    return jnp.round(codes * scale) / scale


# ---------------------------------------------------------------------------
# Stochastic / deterministic perturbation stages
# ---------------------------------------------------------------------------

def fault_masks(
    cfg: DeviceConfig,
    shape: Tuple[int, ...],
    tag: Optional[jnp.ndarray] = None,
    stage: str = STAGE_FAULTS,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Disjoint (stuck_on, stuck_off) bool maps — a pure function of
    (cfg, shape, tag): repeated calls (eager or under ``jax.jit``) return the
    identical draw.  ``tag`` decorrelates same-shape slabs (see
    ``_slab_tag``); ``stage`` selects an independent fault field — the
    repair planner draws its spare-column block from ``"spare_faults"`` so
    provisioning spares never perturbs the primary columns' faults."""
    u = jax.random.uniform(_stage_key(cfg, stage, tag), shape)
    stuck_off = u < cfg.p_stuck_off
    stuck_on = (u >= cfg.p_stuck_off) & (u < cfg.p_stuck_off + cfg.p_stuck_on)
    return stuck_on, stuck_off


def apply_faults(
    g: jnp.ndarray, masks: Tuple[jnp.ndarray, jnp.ndarray], cfg: DeviceConfig
) -> jnp.ndarray:
    stuck_on, stuck_off = masks
    return jnp.where(stuck_on, cfg.g_on_s, jnp.where(stuck_off, cfg.g_off_s, g))


def program_variation(g: jnp.ndarray, cfg: DeviceConfig, key: jax.Array) -> jnp.ndarray:
    """One write pulse: lands lognormally around the target (median-preserving)."""
    if cfg.sigma == 0.0:
        return g
    z = jax.random.normal(key, g.shape, jnp.float32)
    return g * jnp.exp(cfg.sigma * z)


# Boltzmann constant in eV/K and the reference temperature the AG2048
# drift exponent was calibrated at — ``effective_drift_nu`` is exactly
# ``drift_nu`` at 300 K (exp(0) == 1.0, bit-for-bit).
BOLTZMANN_EV_K = 8.617333262e-5
DRIFT_T_REF_K = 300.0


def effective_drift_nu(cfg: DeviceConfig) -> float:
    """Temperature-scaled drift exponent (Arrhenius in 1/T).

    ``nu(T) = drift_nu * exp((Ea/kB) * (1/T_ref - 1/T))``: retention loss is
    thermally activated, so a chip above the 300 K reference drifts faster
    and a cold one slower.  ``drift_ea_ev = 0`` or ``temp_k = 300`` return
    ``drift_nu`` unchanged (exactly — the scale factor is 1.0).
    """
    if cfg.drift_ea_ev == 0.0 or cfg.temp_k == DRIFT_T_REF_K:
        return cfg.drift_nu
    scale = float(
        jnp.exp(
            (cfg.drift_ea_ev / BOLTZMANN_EV_K)
            * (1.0 / DRIFT_T_REF_K - 1.0 / cfg.temp_k)
        )
    )
    return cfg.drift_nu * scale


def apply_drift(g: jnp.ndarray, cfg: DeviceConfig) -> jnp.ndarray:
    """Power-law retention loss; identity at t=0 or nu=0."""
    nu = effective_drift_nu(cfg)
    if nu == 0.0 or cfg.t_drift_s == 0.0:
        return g
    factor = (1.0 + cfg.t_drift_s / cfg.t0_s) ** (-nu)
    return g * factor


def drift_time_factor(cfg: DeviceConfig, t_from_s: float, t_to_s: float) -> float:
    """Incremental conductance decay between two *service* times.

    The power law is anchored at programming time: a chip programmed with
    baked-in drift ``t_drift_s`` and now ``t`` seconds into service sits at
    total elapsed time ``t_drift_s + t``, so the decay accrued between
    service times ``t1 < t2`` is the ratio

        ``((1 + (t_drift_s + t2)/t0) / (1 + (t_drift_s + t1)/t0)) ** -nu``

    — exactly 1.0 when nothing drifts (``nu == 0`` or ``t1 == t2``), which
    is what makes ``device.programmed`` aging a bit-identical no-op for
    drift-free configs.  Composable: ``f(t1,t2) * f(t2,t3) == f(t1,t3)`` up
    to float rounding, so repeated ``age()`` steps track ``at_time``.
    """
    nu = effective_drift_nu(cfg)
    if nu == 0.0 or t_to_s == t_from_s:
        return 1.0
    if t_to_s < t_from_s:
        raise ValueError(
            f"cannot run service time backwards: {t_to_s} < {t_from_s} "
            "(the fresh chip is gone; reprogram to rejuvenate)"
        )
    base = cfg.t_drift_s
    return float(
        ((1.0 + (base + t_to_s) / cfg.t0_s) / (1.0 + (base + t_from_s) / cfg.t0_s))
        ** (-nu)
    )


def age_effective_codes(
    codes: jnp.ndarray, spec: CrossbarSpec, cfg: DeviceConfig, factor: float
) -> jnp.ndarray:
    """Drift-evolve stored effective cell codes by a conductance decay factor.

    The stored codes are the grid-quantized read-time view of the cell
    conductances; aging maps them back through the level map
    (``g = g_off + c * step``), decays the conductance by ``factor`` — the
    power law acts on G, not on codes, so the code-space transform is the
    affine ``f*c + (f-1)*g_off/step``, not a pure scale — and re-reads
    through clip + grid quantization.  Exact (up to one re-quantization on
    the 2**-GEFF_FRAC_BITS grid) for the closed-form IR-drop-free read
    path; with line resistance it is the same first-order view the read
    pipeline already commits to.  ``factor == 1.0`` must be short-circuited
    by the caller — re-quantization is not a bit-exact identity.
    """
    step = code_step_siemens(spec, cfg)
    g = cfg.g_off_s + codes.astype(jnp.float32) * step
    aged = (g * factor - cfg.g_off_s) / step
    aged = jnp.clip(aged, 0.0, float((1 << spec.cell_bits) - 1))
    return quantize_code_grid(aged)


def ir_drop_conductance(
    g: jnp.ndarray, spec: CrossbarSpec, cfg: DeviceConfig, col_offset: int = 0
) -> jnp.ndarray:
    """First-order line-resistance attenuation (AG2048 model, closed form).

    A cell at (row ``i`` of its 128-row group, column ``j``) sees series wire
    resistance ``(j + 1) * r`` along the wordline from the driver plus
    ``(rows - i) * r`` along the bitline down to the ADC; its effective
    conductance is the series combination ``g / (1 + g * R_series)``.  Cells
    far from driver and ADC attenuate most — the classic IR-drop corner.

    ``g``: (S, K, N) conductances; K is the contraction dim (wordlines, row
    ``i = k mod rows`` within its group), N the bitlines.  ``col_offset``
    shifts the wordline position of column 0 — ``device.repair`` reads each
    spare block at the position just past its own column group's data
    columns, not at the near-driver corner.
    """
    if cfg.r_line_ohm == 0.0:
        return g
    S, K, N = g.shape
    i = (jnp.arange(K, dtype=jnp.int32) % spec.rows).astype(jnp.float32)
    j = jnp.arange(N, dtype=jnp.float32) + float(col_offset)
    r_series = ((j[None, :] + 1.0) + (spec.rows - i[:, None])) * cfg.r_line_ohm
    return g / (1.0 + g * r_series[None, :, :])


# ---------------------------------------------------------------------------
# Programming + read pipeline
# ---------------------------------------------------------------------------

def target_cell_codes(w_codes_biased: jnp.ndarray, spec: CrossbarSpec) -> jnp.ndarray:
    """(K, N) biased weight codes -> (S, K, N) ideal per-slice cell codes."""
    return fxp.cell_slices(w_codes_biased, spec.weight_bits, spec.cell_bits)


def program_attempt(
    target_g: jnp.ndarray,
    masks: Tuple[jnp.ndarray, jnp.ndarray],
    cfg: DeviceConfig,
    key: jax.Array,
    i: int,
) -> jnp.ndarray:
    """Write pulse ``i`` of a verify sequence: one noisy open-loop write with
    stuck cells pinned.  Per-pulse randomness is ``fold_in(key, i)`` — the
    shared currency between ``programmed_conductance`` (trace-safe inference
    path), ``program.write_verify`` (host-side reporting path) and the spare
    block programmer in ``device.repair``, which must all land bit-identical
    conductances for the same pulse index."""
    return apply_faults(
        program_variation(target_g, cfg, jax.random.fold_in(key, i)), masks, cfg
    )


def write_verify_fixed(
    target: jnp.ndarray,
    masks: Tuple[jnp.ndarray, jnp.ndarray],
    key: jax.Array,
    spec: CrossbarSpec,
    cfg: DeviceConfig,
) -> jnp.ndarray:
    """Fixed-iteration (trace-safe) write-verify of target cell codes.

    With ``write_verify_iters <= 1`` this is an open-loop write (one noisy
    pulse); otherwise cells whose read-back code is more than
    ``write_verify_tol`` from target are re-pulsed.  Stuck cells ignore
    every pulse.
    """
    target_g = conductance_of_codes(target, spec, cfg)
    iters = max(1, cfg.write_verify_iters)
    g = program_attempt(target_g, masks, cfg, key, 0)
    if iters > 1:
        done = (
            jnp.abs(codes_of_conductance(g, spec, cfg) - target) <= cfg.write_verify_tol
        )
        for i in range(1, iters):
            attempt = program_attempt(target_g, masks, cfg, key, i)
            g = jnp.where(done, g, attempt)
            done = (
                jnp.abs(codes_of_conductance(g, spec, cfg) - target) <= cfg.write_verify_tol
            )
    return g


def programmed_conductance(
    w_codes_biased: jnp.ndarray, spec: CrossbarSpec, cfg: DeviceConfig
) -> jnp.ndarray:
    """Program a weight slab into cell conductances (trace-safe).

    Draws the slab's fault map and pulse keys, then runs the fixed-iteration
    ``write_verify_fixed`` loop.  ``program.write_verify`` wraps the same
    keys with host-side convergence reporting.
    """
    target = target_cell_codes(w_codes_biased, spec)
    tag = _slab_tag(w_codes_biased)
    masks = fault_masks(cfg, target.shape, tag)
    key = _stage_key(cfg, STAGE_PROGRAM, tag)
    return write_verify_fixed(target, masks, key, spec, cfg)


def read_effective_codes(
    g: jnp.ndarray, spec: CrossbarSpec, cfg: DeviceConfig, col_offset: int = 0
) -> jnp.ndarray:
    """Read-time view of programmed conductances, in grid-quantized code units.

    Applies drift and IR drop, converts back through the level map, clips to
    the physical rails [0, 2**cell_bits - 1] and snaps to the verification
    grid.  (S, K, N) in, (S, K, N) float32 out.  ``col_offset`` positions
    the block on the wordline for IR drop (see ``ir_drop_conductance``).
    """
    g = apply_drift(g, cfg)
    g = ir_drop_conductance(g, spec, cfg, col_offset=col_offset)
    codes = codes_of_conductance(g, spec, cfg)
    codes = jnp.clip(codes, 0.0, float((1 << spec.cell_bits) - 1))
    return quantize_code_grid(codes)


def wants_repair(cfg: DeviceConfig) -> bool:
    """Spare-column repair is active: a budget is provisioned and stuck-at
    faults exist to repair (variation/drift are not column-clustered, so
    repair without faults would be pure provisioning waste)."""
    return cfg.spare_cols > 0 and (cfg.p_stuck_on > 0.0 or cfg.p_stuck_off > 0.0)


def effective_cell_codes(
    w_codes_biased: jnp.ndarray,
    spec: CrossbarSpec,
    cfg: DeviceConfig,
    repair: bool = True,
) -> jnp.ndarray:
    """Full program+read pipeline: (K, N) biased codes -> (S, K, N) effective.

    The one call sites need: what the analog datapath actually multiplies
    against, given this device config.  Deterministic in (cfg, shape); the
    ideal config returns the exact integer slices.

    When the config provisions spare columns (``cfg.spare_cols > 0``) and
    stuck-at faults are enabled, the returned layout is the *repaired* one:
    ``device.repair`` remaps the worst fault-afflicted columns into
    programmed spares and scatters the spare cells back into the victim
    positions, so every downstream consumer (functional model, Pallas
    kernels, programmed artifacts) reads the repaired chip with zero
    steady-state overhead.  ``repair=False`` returns the primary columns
    only (``device.programmed`` uses this to record the spare block and
    gather map explicitly).
    """
    if cfg.is_ideal:
        return target_cell_codes(w_codes_biased, spec).astype(jnp.float32)
    g_eff, target, tag, masks = _programmed_effective(w_codes_biased, spec, cfg)
    if repair and wants_repair(cfg):
        from repro.device import repair as repair_mod  # deferred: repair imports models

        rplan = repair_mod.plan_repair(
            w_codes_biased, spec, cfg, target=target, tag=tag, primary_masks=masks
        )
        g_eff = repair_mod.apply_repair(g_eff, rplan)
    return g_eff


def _programmed_effective(
    w_codes_biased: jnp.ndarray, spec: CrossbarSpec, cfg: DeviceConfig
):
    """Programming pipeline with its intermediates exposed: (g_eff, target,
    tag, masks).  The repair planner needs the same target slices, slab tag
    and primary fault draw — handing them over avoids paying the cell-slice
    expansion / content hash / fault draw twice per slab."""
    target = target_cell_codes(w_codes_biased, spec)
    tag = _slab_tag(w_codes_biased)
    masks = fault_masks(cfg, target.shape, tag)
    key = _stage_key(cfg, STAGE_PROGRAM, tag)
    g = write_verify_fixed(target, masks, key, spec, cfg)
    return read_effective_codes(g, spec, cfg), target, tag, masks
