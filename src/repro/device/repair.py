"""Fault-aware spare-column repair of programmed crossbar slabs.

Newton's mapping (§III.B) provisions tiles as if every memristor cell works;
real arrays ship with stuck-at cells, and Xiao et al. ("On the Accuracy of
Analog Neural Network Inference Accelerators") show those hard faults — not
programming variation — dominate accuracy loss at realistic rates.  Because
the datapath is column-separable (one bitline = one output), the classic
memory-repair remedy applies: provision a budget of **redundant spare
columns** per crossbar and, at programming time, remap the worst
fault-afflicted columns into them, rerouting the column outputs through a
gather table.

The pipeline here:

* ``column_salience`` — rank columns by fault-weighted salience: the total
  |installed - target| cell-code error a column's stuck cells would cause,
  weighted by bit-slice significance ``2**(s * cell_bits)`` (a stuck MSB
  slice cell is 16384x a stuck LSB one for the default 16b/2b layout).
* ``plan_repair`` — greedy budget assignment at **physical-crossbar
  granularity**: each (bit-slice, row group) of a slab is its own 128x128
  array with its own ADC, and both the slice shift-and-add and the
  row-group accumulation happen digitally *after* conversion — so the
  output mux can pick primary-or-spare independently per (slice, row
  group, column), not just per whole logical column.  That granularity is
  load-bearing: at p = 1e-2 a 512-row x 8-slice logical column is faulty
  with near certainty (and so is any whole-column spare), while a single
  128-cell physical column is clean with probability ~0.28 — per-unit
  matching is what keeps deep slabs repairable.  Within each unit the
  greedy repeatedly moves the (victim, spare) pair with the largest
  salience *gain*.  Spares draw their own seeded stuck-at field (stage
  ``"spare_faults"``), so a faulty spare is never blindly trusted — a
  victim moves only where it strictly improves.  Trace-safe: the loop has
  a static trip count (the budget) and all choices are jnp argmax/where,
  vmapped over the slice x row-group units.
* spare programming — the chosen victims' target codes are written into the
  spare block through the same write-verify pulse pipeline as primary cells
  (stage ``"spare_program"`` keys), then read back through drift/IR-drop.
* ``apply_repair`` — scatter the programmed spare cells into the victim
  positions.  The datapath is column-separable, so pre-gathering the
  repaired layout at programming time is bit-identical to gathering kernel
  outputs at read time — and costs nothing per call: all three Pallas
  kernels consume the repaired ``(S, K, N)`` layout unchanged.

Primary columns are programmed exactly as without repair (their fault and
variation draws never see the spare block), so repair on/off comparisons are
apples-to-apples and a zero-fault config with a nonzero budget stays
bit-identical to the unrepaired path.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.crossbar import CrossbarSpec
from repro.device import models as dm


def spare_budget(n_cols: int, spec: CrossbarSpec, cfg: dm.DeviceConfig) -> int:
    """Spare columns available to one (K, N) weight slab.

    ``cfg.spare_cols`` is provisioned per physical crossbar column group; a
    slab spanning ``ceil(N / spec.cols)`` column groups owns that many
    budgets, and each budget is group-local — a spare's output muxes can
    only stand in for columns of their own group (``plan_repair``).  (A
    spare is one redundant column position in every bit-slice x row-group
    crossbar of the group; each of those S x R physical spare columns is
    assigned its own victim independently, since the cross-array merge is
    digital.)
    """
    return int(cfg.spare_cols) * max(1, -(-n_cols // spec.cols))


def _slice_weights(spec: CrossbarSpec) -> jnp.ndarray:
    """(S,) bit-slice significance: slice s carries 2**(s * cell_bits)."""
    return (2.0 ** (spec.cell_bits * jnp.arange(spec.n_slices))).astype(jnp.float32)


def column_salience(
    target: jnp.ndarray,
    masks: Tuple[jnp.ndarray, jnp.ndarray],
    spec: CrossbarSpec,
) -> jnp.ndarray:
    """Fault-weighted salience of each column of a target-code slab.

    ``target``: (S, K, N) ideal cell codes; ``masks``: (stuck_on, stuck_off)
    bool maps of the same shape.  Returns (N,) float32: the significance-
    weighted total |stuck value - target| each column's hard faults inflict.
    A stuck-on cell installs the top code ``cell_max``; stuck-off installs 0.
    """
    stuck_on, stuck_off = masks
    cell_max = float((1 << spec.cell_bits) - 1)
    w = _slice_weights(spec)[:, None, None]
    err = jnp.where(stuck_on, (cell_max - target) * w, 0.0)
    err = err + jnp.where(stuck_off, target * w, 0.0)
    return jnp.sum(err, axis=(0, 1)).astype(jnp.float32)


def _unit_view(a: jnp.ndarray, rows: int) -> jnp.ndarray:
    """(S, K, X) -> (S, R, rows, X) physical-crossbar units, zero-padding a
    partial last row group (padded cells carry target 0 and no faults, so
    they never contribute salience or spare error)."""
    S, K, X = a.shape
    R = -(-K // rows)
    pad = R * rows - K
    if pad:
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
    return a.reshape(S, R, rows, X)


def _unit_fault_error(
    target_u: jnp.ndarray,
    masks_u: Tuple[jnp.ndarray, jnp.ndarray],
    spec: CrossbarSpec,
) -> jnp.ndarray:
    """(S, R, N) unweighted per-unit fault error of a unit view: the total
    |stuck - target| cell error each physical column's hard faults inflict
    (slice significance is a *cross*-unit weight and does not reorder
    choices within one slice's crossbar)."""
    cell_max = float((1 << spec.cell_bits) - 1)
    err = jnp.where(masks_u[0], cell_max - target_u, 0.0)
    err = err + jnp.where(masks_u[1], target_u, 0.0)
    return jnp.sum(err, axis=2).astype(jnp.float32)


@dataclasses.dataclass
class RepairPlan:
    """Trace-safe record of one slab's spare-column repair.

    Repair is resolved per physical crossbar: with ``R = ceil(K / rows)``
    row groups and ``S`` bit slices, every (s, r) pair is its own array and
    gets its own victim/gather tables.  ``victim``: (S, R, B) int32 — the
    logical column whose (s, r) unit is programmed into each spare column's
    (s, r) unit, -1 for unused slots.  ``out_gather``: (S, R, N) int32 —
    physical column serving each logical output within that crossbar
    (j itself, or N + b for repaired units); the routing tables a real chip
    would burn into its per-array column muxes (the merge across slices and
    row groups is digital, so per-array muxing costs nothing extra).
    ``g_spare``: (S, K, B) float32 effective cell codes of the programmed
    spare block; slots not serving a victim are programmed toward target 0
    but still read back their own faults/variation, so detect them via
    ``victim == -1``, not zero cells.  ``rows`` is the unit height (the
    physical crossbar row count the plan was built for).  Saliences are
    pre/post-repair (N,) vectors of ``column_salience`` units.
    """

    victim: jnp.ndarray
    out_gather: jnp.ndarray
    g_spare: jnp.ndarray
    salience_before: jnp.ndarray
    salience_after: jnp.ndarray
    rows: int = 128


@dataclasses.dataclass(frozen=True)
class RepairReport:
    """Host-side summary of a ``RepairPlan`` (hashable: rides pytree aux).

    ``budget`` and ``n_repaired`` count (slice, row group, spare) *unit
    slots* — the per-physical-crossbar repair resolution; ``repaired_cols``
    is the sorted set of logical columns with at least one repaired unit.
    """

    budget: int
    n_repaired: int
    repaired_cols: Tuple[int, ...]  # logical columns with >= 1 repaired unit
    salience_before: float
    salience_after: float

    @property
    def recovered_frac(self) -> float:
        """Fraction of planner-model salience removed by the repair."""
        if self.salience_before <= 0.0:
            return 0.0
        return 1.0 - self.salience_after / self.salience_before


def _greedy_assign(sal0: jnp.ndarray, err_sp: jnp.ndarray):
    """Greedy (victim, spare) assignment within one column group.

    Each of the ``B`` steps moves the pair with the largest remaining
    salience gain, if any strict improvement exists.  A repaired column is
    never displaced to a second spare: re-stealing column j from spare b1
    by b2 would need ``err_sp[b2, j] < err_sp[b1, j]``, but b2 was already
    available when (b1, j) won the argmax (the available set only shrinks),
    so ``err_sp[b1, j] <= err_sp[b2, j]`` — every spare therefore serves at
    most one column and no victim slot is ever orphaned.  Returns local
    (salience_after (n,), victim (B,), gather (n,)) with gather entries
    ``>= n`` meaning "spare gather - n".
    """
    B, n = err_sp.shape

    def _step(_, carry):
        sal, victim, gather, avail = carry
        gain = jnp.where(avail[:, None], sal[None, :] - err_sp, -jnp.inf)
        flat = jnp.argmax(gain)
        b, j = flat // n, flat % n
        do = gain.reshape(-1)[flat] > 0.0
        victim = victim.at[b].set(jnp.where(do, j.astype(jnp.int32), victim[b]))
        gather = jnp.where(do, gather.at[j].set(n + b.astype(jnp.int32)), gather)
        sal = sal.at[j].set(jnp.where(do, err_sp[b, j], sal[j]))
        avail = avail.at[b].set(jnp.where(do, False, avail[b]))
        return sal, victim, gather, avail

    sal, victim, gather, _ = jax.lax.fori_loop(
        0,
        B,
        _step,
        (
            sal0,
            jnp.full((B,), -1, jnp.int32),
            jnp.arange(n, dtype=jnp.int32),
            jnp.ones((B,), bool),
        ),
    )
    return sal, victim, gather


def plan_repair(
    w_codes_biased: jnp.ndarray,
    spec: CrossbarSpec,
    cfg: dm.DeviceConfig,
    *,
    target: Optional[jnp.ndarray] = None,
    tag: Optional[jnp.ndarray] = None,
    primary_masks: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
) -> Optional[RepairPlan]:
    """Plan and program one slab's spare-column repair (trace-safe).

    Planning is *per column group and per physical crossbar*: a spare
    column lives in one 128-column crossbar group and its per-array output
    muxes can only stand in for columns of that group, so each group's
    ``cfg.spare_cols`` spares are assigned greedily among its own
    <= ``spec.cols`` columns — independently for every (bit-slice, row
    group) unit, since each is its own array and the cross-array merge is
    digital.  (This also bounds the planner: every gain matrix is at most
    ``spare_cols x cols``, vmapped over the S x R units, so wide slabs —
    e.g. a vocab-sized LM head — cost one small greedy pass per group
    instead of one quadratic pass over all columns.)  Spares carry their
    own seeded stuck-at faults, write-verify pulse noise, drift and IR
    drop, so the plan never pretends a spare is perfect.  Returns None when
    the config provisions no repair.

    ``target`` / ``tag`` / ``primary_masks`` let a caller that has already
    run the programming pipeline for this slab (``effective_cell_codes``)
    hand its intermediates over instead of paying the cell-slice expansion,
    content-hash and fault draw a second time; when provided they MUST be
    the values the standard pipeline derives from ``w_codes_biased``.
    """
    if not dm.wants_repair(cfg):
        return None
    if target is None:
        target = dm.target_cell_codes(w_codes_biased, spec)
    target = target.astype(jnp.float32)
    S, K, N = target.shape
    R = -(-K // spec.rows)
    B_per = int(cfg.spare_cols)
    B = spare_budget(N, spec, cfg)
    n_groups = B // B_per
    if tag is None:
        tag = dm._slab_tag(w_codes_biased)
    if primary_masks is None:
        primary_masks = dm.fault_masks(cfg, (S, K, N), tag)
    spare_masks = dm.fault_masks(cfg, (S, K, B), tag, stage=dm.STAGE_SPARE_FAULTS)

    cell_max = float((1 << spec.cell_bits) - 1)
    t_u = _unit_view(target, spec.rows)  # (S, R, rows, N)
    units0 = _unit_fault_error(
        t_u,
        (_unit_view(primary_masks[0], spec.rows), _unit_view(primary_masks[1], spec.rows)),
        spec,
    )  # (S, R, N)
    on_sp = _unit_view(spare_masks[0].astype(jnp.float32), spec.rows)  # (S,R,rows,B)
    off_sp = _unit_view(spare_masks[1].astype(jnp.float32), spec.rows)

    sal0 = column_salience(target, primary_masks, spec)  # (N,)
    units = units0
    victim = jnp.full((S, R, B), -1, jnp.int32)
    gather = jnp.broadcast_to(jnp.arange(N, dtype=jnp.int32), (S, R, N))
    for g in range(n_groups):
        n0, n1 = g * spec.cols, min((g + 1) * spec.cols, N)
        n_g = n1 - n0
        b0 = g * B_per
        t_g = t_u[:, :, :, n0:n1]
        # err_sp[s, r, b, v]: fault error of spare b's (s, r) unit holding
        # logical column v's targets for that unit
        err_sp = jnp.einsum(
            "srkb,srkv->srbv", on_sp[:, :, :, b0 : b0 + B_per], cell_max - t_g
        ) + jnp.einsum("srkb,srkv->srbv", off_sp[:, :, :, b0 : b0 + B_per], t_g)
        sal_u, victim_u, gather_u = jax.vmap(_greedy_assign)(
            units0[:, :, n0:n1].reshape(S * R, n_g),
            err_sp.reshape(S * R, B_per, n_g),
        )
        victim_u = victim_u.reshape(S, R, B_per)
        gather_u = gather_u.reshape(S, R, n_g)
        victim = victim.at[:, :, b0 : b0 + B_per].set(
            jnp.where(victim_u >= 0, victim_u + n0, -1)
        )
        gather = gather.at[:, :, n0:n1].set(
            jnp.where(gather_u >= n_g, gather_u - n_g + N + b0, gather_u + n0)
        )
        units = units.at[:, :, n0:n1].set(sal_u.reshape(S, R, n_g))

    # Program the chosen targets into the spare block through the standard
    # write-verify pipeline (independent "spare_program" pulse keys), then
    # read back through drift/IR drop at each group's true wordline
    # position: a spare physically sits right past its own group's data
    # columns (group-local mux), never at the near-driver corner — so
    # repair is not optimistically biased under r_line_ohm.  Each spare
    # column's (s, r) unit holds its own victim's targets — per-array
    # muxing means one physical spare column serves up to S x R victims.
    vt = jnp.take_along_axis(
        t_u, jnp.clip(victim, 0, N - 1)[:, :, None, :], axis=3
    )  # (S, R, rows, B)
    vt = jnp.where((victim >= 0)[:, :, None, :], vt, 0.0)
    spare_target = vt.reshape(S, R * spec.rows, B)[:, :K, :]
    key = dm._stage_key(cfg, dm.STAGE_SPARE_PROGRAM, tag)
    g = dm.write_verify_fixed(spare_target, spare_masks, key, spec, cfg)
    parts = []
    for gi in range(n_groups):
        b0 = gi * B_per
        n_end = min((gi + 1) * spec.cols, N)
        parts.append(
            dm.read_effective_codes(
                g[:, :, b0 : b0 + B_per], spec, cfg, col_offset=n_end
            )
        )
    g_spare = jnp.concatenate(parts, axis=2) if n_groups > 1 else parts[0]

    w = _slice_weights(spec)
    return RepairPlan(
        victim=victim,
        out_gather=gather,
        g_spare=g_spare,
        salience_before=sal0,
        salience_after=jnp.sum(units * w[:, None, None], axis=(0, 1)),
        rows=int(spec.rows),
    )


def apply_repair(g_eff_primary: jnp.ndarray, plan: Optional[RepairPlan]) -> jnp.ndarray:
    """Scatter programmed spare cells into victim positions: the repaired
    (S, K, N) layout every kernel consumes with zero steady-state overhead.

    Column-separability *per physical crossbar* makes this exactly
    equivalent to running the physical (S, K, N + B) layout and gathering
    each (slice, row group) unit's partial outputs through its
    ``plan.out_gather`` table before the digital shift-and-add / row-group
    merge — see tests/test_repair.py, which pins the equivalence down
    bit-for-bit.
    """
    if plan is None:
        return g_eff_primary
    S, K, N = g_eff_primary.shape
    R = plan.out_gather.shape[1]
    g_full = jnp.concatenate([g_eff_primary, plan.g_spare], axis=2)
    rg = jnp.minimum(jnp.arange(K) // plan.rows, R - 1)
    idx = plan.out_gather[:, rg, :]  # (S, K, N): per-row-of-cells gather
    return jnp.take_along_axis(g_full, idx, axis=2)


def repaired_effective_cells(
    w_codes_biased: jnp.ndarray,
    spec: CrossbarSpec,
    cfg: dm.DeviceConfig,
    *,
    with_report: bool = False,
) -> Tuple[jnp.ndarray, Optional[RepairPlan], Optional[Any]]:
    """Program + repair in one pass: (repaired g_eff, plan, report).

    Equivalent to ``effective_cell_codes(wb, spec, cfg)`` but also returns
    the plan (spare block, gather table, saliences) for callers — notably
    ``programmed.program_layer`` — that record the repair; the programming
    intermediates are shared with the planner, never recomputed.

    This is the **single derivation site** for the programming
    intermediates.  ``with_report=True`` swaps the trace-safe fixed-
    iteration pulse loop for ``program.write_verify`` — identical stage
    keys, so the cells are bit-identical (pinned by
    ``test_programming_is_deterministic``) — and returns its convergence
    ``ProgramReport`` as the third element (None otherwise).
    """
    if with_report:
        from repro.device.program import write_verify

        target = dm.target_cell_codes(w_codes_biased, spec)
        tag = dm._slab_tag(w_codes_biased)
        masks = dm.fault_masks(cfg, target.shape, tag)
        g, report = write_verify(
            w_codes_biased, spec, cfg, target=target, tag=tag, masks=masks
        )
        g_eff = dm.read_effective_codes(g, spec, cfg)
    else:
        g_eff, target, tag, masks = dm._programmed_effective(
            w_codes_biased, spec, cfg
        )
        report = None
    rplan = plan_repair(
        w_codes_biased, spec, cfg, target=target, tag=tag, primary_masks=masks
    )
    return apply_repair(g_eff, rplan), rplan, report


def repair_report(plan: Optional[RepairPlan]) -> Optional[RepairReport]:
    """Materialize the host-side summary (programming time only, not under
    trace — the plan's arrays are concretized)."""
    if plan is None:
        return None
    victim = np.asarray(plan.victim)
    return RepairReport(
        budget=int(victim.size),
        n_repaired=int((victim >= 0).sum()),
        repaired_cols=tuple(sorted({int(v) for v in victim.ravel() if v >= 0})),
        salience_before=float(np.asarray(plan.salience_before).sum()),
        salience_after=float(np.asarray(plan.salience_after).sum()),
    )
