"""Fault-aware spare-column repair of programmed crossbar slabs.

Newton's mapping (§III.B) provisions tiles as if every memristor cell works;
real arrays ship with stuck-at cells, and Xiao et al. ("On the Accuracy of
Analog Neural Network Inference Accelerators") show those hard faults — not
programming variation — dominate accuracy loss at realistic rates.  Because
the datapath is column-separable (one bitline = one output), the classic
memory-repair remedy applies: provision a budget of **redundant spare
columns** per crossbar and, at programming time, remap the worst
fault-afflicted columns into them, rerouting the column outputs through a
gather table.

The pipeline here:

* ``column_salience`` — rank columns by fault-weighted salience: the total
  |installed - target| cell-code error a column's stuck cells would cause,
  weighted by bit-slice significance ``2**(s * cell_bits)`` (a stuck MSB
  slice cell is 16384x a stuck LSB one for the default 16b/2b layout).
* ``plan_repair`` — greedy budget assignment: repeatedly move the
  (victim column, spare) pair with the largest salience *gain*.  Spares
  draw their own seeded stuck-at field (stage ``"spare_faults"``), so a
  faulty spare is never blindly trusted — a victim moves only where it
  strictly improves.  Trace-safe: the loop has a static trip count (the
  budget) and all choices are jnp argmax/where.
* spare programming — the chosen victims' target codes are written into the
  spare block through the same write-verify pulse pipeline as primary cells
  (stage ``"spare_program"`` keys), then read back through drift/IR-drop.
* ``apply_repair`` — scatter the programmed spare cells into the victim
  positions.  The datapath is column-separable, so pre-gathering the
  repaired layout at programming time is bit-identical to gathering kernel
  outputs at read time — and costs nothing per call: all three Pallas
  kernels consume the repaired ``(S, K, N)`` layout unchanged.

Primary columns are programmed exactly as without repair (their fault and
variation draws never see the spare block), so repair on/off comparisons are
apples-to-apples and a zero-fault config with a nonzero budget stays
bit-identical to the unrepaired path.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.crossbar import CrossbarSpec
from repro.device import models as dm


def spare_budget(n_cols: int, spec: CrossbarSpec, cfg: dm.DeviceConfig) -> int:
    """Spare columns available to one (K, N) weight slab.

    ``cfg.spare_cols`` is provisioned per physical crossbar column group; a
    slab spanning ``ceil(N / spec.cols)`` column groups owns that many
    budgets, and each budget is group-local — a spare's output mux can only
    stand in for columns of its own group (``plan_repair``).  (Each row
    group reuses the same spare columns — a spare is a full-height column of
    every bit-slice crossbar in the group.)
    """
    return int(cfg.spare_cols) * max(1, -(-n_cols // spec.cols))


def _slice_weights(spec: CrossbarSpec) -> jnp.ndarray:
    """(S,) bit-slice significance: slice s carries 2**(s * cell_bits)."""
    return (2.0 ** (spec.cell_bits * jnp.arange(spec.n_slices))).astype(jnp.float32)


def column_salience(
    target: jnp.ndarray,
    masks: Tuple[jnp.ndarray, jnp.ndarray],
    spec: CrossbarSpec,
) -> jnp.ndarray:
    """Fault-weighted salience of each column of a target-code slab.

    ``target``: (S, K, N) ideal cell codes; ``masks``: (stuck_on, stuck_off)
    bool maps of the same shape.  Returns (N,) float32: the significance-
    weighted total |stuck value - target| each column's hard faults inflict.
    A stuck-on cell installs the top code ``cell_max``; stuck-off installs 0.
    """
    stuck_on, stuck_off = masks
    cell_max = float((1 << spec.cell_bits) - 1)
    w = _slice_weights(spec)[:, None, None]
    err = jnp.where(stuck_on, (cell_max - target) * w, 0.0)
    err = err + jnp.where(stuck_off, target * w, 0.0)
    return jnp.sum(err, axis=(0, 1)).astype(jnp.float32)


def _salience_in_spares(
    target: jnp.ndarray,
    spare_masks: Tuple[jnp.ndarray, jnp.ndarray],
    spec: CrossbarSpec,
) -> jnp.ndarray:
    """(B, N) salience of placing column n's targets into spare b."""
    stuck_on, stuck_off = spare_masks
    cell_max = float((1 << spec.cell_bits) - 1)
    w = _slice_weights(spec)[:, None, None]
    on = stuck_on.astype(jnp.float32)  # (S, K, B)
    off = stuck_off.astype(jnp.float32)
    t = target.astype(jnp.float32)  # (S, K, N)
    return jnp.einsum("skb,skn->bn", on, (cell_max - t) * w) + jnp.einsum(
        "skb,skn->bn", off, t * w
    )


@dataclasses.dataclass
class RepairPlan:
    """Trace-safe record of one slab's spare-column repair.

    ``victim``: (B,) int32 — logical column programmed into each spare, -1
    for unused spares.  ``out_gather``: (N,) int32 — physical column serving
    each logical output (j itself, or N + b for repaired columns); the
    hardware routing table a real chip would burn into its column mux.
    ``g_spare``: (S, K, B) float32 effective cell codes of the programmed
    spare block; unused spares are programmed toward target 0 but still
    read back their own faults/variation, so detect them via
    ``victim == -1``, not zero cells.  Saliences are pre/post-repair (N,)
    vectors of ``column_salience`` units.
    """

    victim: jnp.ndarray
    out_gather: jnp.ndarray
    g_spare: jnp.ndarray
    salience_before: jnp.ndarray
    salience_after: jnp.ndarray


@dataclasses.dataclass(frozen=True)
class RepairReport:
    """Host-side summary of a ``RepairPlan`` (hashable: rides pytree aux)."""

    budget: int
    n_repaired: int
    repaired_cols: Tuple[int, ...]  # logical columns, in spare order
    salience_before: float
    salience_after: float

    @property
    def recovered_frac(self) -> float:
        """Fraction of planner-model salience removed by the repair."""
        if self.salience_before <= 0.0:
            return 0.0
        return 1.0 - self.salience_after / self.salience_before


def _greedy_assign(sal0: jnp.ndarray, err_sp: jnp.ndarray):
    """Greedy (victim, spare) assignment within one column group.

    Each of the ``B`` steps moves the pair with the largest remaining
    salience gain, if any strict improvement exists.  A repaired column is
    never displaced to a second spare: re-stealing column j from spare b1
    by b2 would need ``err_sp[b2, j] < err_sp[b1, j]``, but b2 was already
    available when (b1, j) won the argmax (the available set only shrinks),
    so ``err_sp[b1, j] <= err_sp[b2, j]`` — every spare therefore serves at
    most one column and no victim slot is ever orphaned.  Returns local
    (salience_after (n,), victim (B,), gather (n,)) with gather entries
    ``>= n`` meaning "spare gather - n".
    """
    B, n = err_sp.shape

    def _step(_, carry):
        sal, victim, gather, avail = carry
        gain = jnp.where(avail[:, None], sal[None, :] - err_sp, -jnp.inf)
        flat = jnp.argmax(gain)
        b, j = flat // n, flat % n
        do = gain.reshape(-1)[flat] > 0.0
        victim = victim.at[b].set(jnp.where(do, j.astype(jnp.int32), victim[b]))
        gather = jnp.where(do, gather.at[j].set(n + b.astype(jnp.int32)), gather)
        sal = sal.at[j].set(jnp.where(do, err_sp[b, j], sal[j]))
        avail = avail.at[b].set(jnp.where(do, False, avail[b]))
        return sal, victim, gather, avail

    sal, victim, gather, _ = jax.lax.fori_loop(
        0,
        B,
        _step,
        (
            sal0,
            jnp.full((B,), -1, jnp.int32),
            jnp.arange(n, dtype=jnp.int32),
            jnp.ones((B,), bool),
        ),
    )
    return sal, victim, gather


def plan_repair(
    w_codes_biased: jnp.ndarray,
    spec: CrossbarSpec,
    cfg: dm.DeviceConfig,
    *,
    target: Optional[jnp.ndarray] = None,
    tag: Optional[jnp.ndarray] = None,
    primary_masks: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
) -> Optional[RepairPlan]:
    """Plan and program one slab's spare-column repair (trace-safe).

    Planning is *per column group*: a spare column physically lives in one
    128-column crossbar group and its output mux can only stand in for
    columns of that group, so each group's ``cfg.spare_cols`` spares are
    assigned greedily among its own <= ``spec.cols`` columns.  (This also
    bounds the planner: every gain matrix is at most ``spare_cols x cols``,
    so wide slabs — e.g. a vocab-sized LM head — cost one small greedy pass
    per group instead of one quadratic pass over all columns.)  Spares carry
    their own seeded stuck-at faults, write-verify pulse noise, drift and IR
    drop, so the plan never pretends a spare is perfect.  Returns None when
    the config provisions no repair.

    ``target`` / ``tag`` / ``primary_masks`` let a caller that has already
    run the programming pipeline for this slab (``effective_cell_codes``)
    hand its intermediates over instead of paying the cell-slice expansion,
    content-hash and fault draw a second time; when provided they MUST be
    the values the standard pipeline derives from ``w_codes_biased``.
    """
    if not dm.wants_repair(cfg):
        return None
    if target is None:
        target = dm.target_cell_codes(w_codes_biased, spec)
    target = target.astype(jnp.float32)
    S, K, N = target.shape
    B_per = int(cfg.spare_cols)
    B = spare_budget(N, spec, cfg)
    n_groups = B // B_per
    if tag is None:
        tag = dm._slab_tag(w_codes_biased)
    if primary_masks is None:
        primary_masks = dm.fault_masks(cfg, (S, K, N), tag)
    spare_masks = dm.fault_masks(cfg, (S, K, B), tag, stage="spare_faults")

    sal0 = column_salience(target, primary_masks, spec)  # (N,)
    sal = sal0
    victim = jnp.full((B,), -1, jnp.int32)
    gather = jnp.arange(N, dtype=jnp.int32)
    for g in range(n_groups):
        n0, n1 = g * spec.cols, min((g + 1) * spec.cols, N)
        b0 = g * B_per
        err_sp = _salience_in_spares(
            target[:, :, n0:n1],
            (
                spare_masks[0][:, :, b0 : b0 + B_per],
                spare_masks[1][:, :, b0 : b0 + B_per],
            ),
            spec,
        )  # (B_per, n1 - n0)
        sal_g, victim_g, gather_g = _greedy_assign(sal0[n0:n1], err_sp)
        n_g = n1 - n0
        victim = victim.at[b0 : b0 + B_per].set(
            jnp.where(victim_g >= 0, victim_g + n0, -1)
        )
        gather = gather.at[n0:n1].set(
            jnp.where(gather_g >= n_g, gather_g - n_g + N + b0, gather_g + n0)
        )
        sal = sal.at[n0:n1].set(sal_g)

    # Program the chosen targets into the spare block through the standard
    # write-verify pipeline (independent "spare_program" pulse keys), then
    # read back through drift/IR drop at each group's true wordline
    # position: a spare physically sits right past its own group's data
    # columns (group-local mux), never at the near-driver corner — so
    # repair is not optimistically biased under r_line_ohm.
    used = victim >= 0
    spare_target = jnp.where(
        used[None, None, :], target[:, :, jnp.clip(victim, 0, N - 1)], 0.0
    )
    key = dm._stage_key(cfg, "spare_program", tag)
    g = dm.write_verify_fixed(spare_target, spare_masks, key, spec, cfg)
    parts = []
    for gi in range(n_groups):
        b0 = gi * B_per
        n_end = min((gi + 1) * spec.cols, N)
        parts.append(
            dm.read_effective_codes(
                g[:, :, b0 : b0 + B_per], spec, cfg, col_offset=n_end
            )
        )
    g_spare = jnp.concatenate(parts, axis=2) if n_groups > 1 else parts[0]

    return RepairPlan(
        victim=victim,
        out_gather=gather,
        g_spare=g_spare,
        salience_before=sal0,
        salience_after=sal,
    )


def apply_repair(g_eff_primary: jnp.ndarray, plan: Optional[RepairPlan]) -> jnp.ndarray:
    """Scatter programmed spare cells into victim positions: the repaired
    (S, K, N) layout every kernel consumes with zero steady-state overhead.

    Column-separability makes this exactly equivalent to running the
    physical (S, K, N + B) layout and gathering kernel outputs through
    ``plan.out_gather`` — see tests/test_repair.py, which pins the
    equivalence down bit-for-bit.
    """
    if plan is None:
        return g_eff_primary
    g_full = jnp.concatenate([g_eff_primary, plan.g_spare], axis=2)
    return jnp.take(g_full, plan.out_gather, axis=2)


def repaired_effective_cells(
    w_codes_biased: jnp.ndarray,
    spec: CrossbarSpec,
    cfg: dm.DeviceConfig,
    *,
    with_report: bool = False,
) -> Tuple[jnp.ndarray, Optional[RepairPlan], Optional[Any]]:
    """Program + repair in one pass: (repaired g_eff, plan, report).

    Equivalent to ``effective_cell_codes(wb, spec, cfg)`` but also returns
    the plan (spare block, gather table, saliences) for callers — notably
    ``programmed.program_layer`` — that record the repair; the programming
    intermediates are shared with the planner, never recomputed.

    This is the **single derivation site** for the programming
    intermediates.  ``with_report=True`` swaps the trace-safe fixed-
    iteration pulse loop for ``program.write_verify`` — identical stage
    keys, so the cells are bit-identical (pinned by
    ``test_programming_is_deterministic``) — and returns its convergence
    ``ProgramReport`` as the third element (None otherwise).
    """
    if with_report:
        from repro.device.program import write_verify

        target = dm.target_cell_codes(w_codes_biased, spec)
        tag = dm._slab_tag(w_codes_biased)
        masks = dm.fault_masks(cfg, target.shape, tag)
        g, report = write_verify(
            w_codes_biased, spec, cfg, target=target, tag=tag, masks=masks
        )
        g_eff = dm.read_effective_codes(g, spec, cfg)
    else:
        g_eff, target, tag, masks = dm._programmed_effective(
            w_codes_biased, spec, cfg
        )
        report = None
    plan = plan_repair(
        w_codes_biased, spec, cfg, target=target, tag=tag, primary_masks=masks
    )
    return apply_repair(g_eff, plan), plan, report


def repair_report(plan: Optional[RepairPlan]) -> Optional[RepairReport]:
    """Materialize the host-side summary (programming time only, not under
    trace — the plan's arrays are concretized)."""
    if plan is None:
        return None
    victim = np.asarray(plan.victim)
    return RepairReport(
        budget=int(victim.shape[0]),
        n_repaired=int((victim >= 0).sum()),
        repaired_cols=tuple(int(v) for v in victim if v >= 0),
        salience_before=float(np.asarray(plan.salience_before).sum()),
        salience_after=float(np.asarray(plan.salience_after).sum()),
    )
