"""Program-once crossbar compilation: frozen programmed-weight artifacts.

Newton's core premise is that weights are programmed into crossbars *once*
and then serve in-situ traffic indefinitely — programming (fault draw,
write-verify pulses, IR-drop solve, quantization-scale reductions) is a
deployment-time cost, not a per-call one.  The pre-existing hot path
re-ran that whole pipeline inside every ``crossbar_matmul(device=...)``
call; this module splits the stack into an explicit **programming time**
vs **inference time**:

* ``program_layer(w, spec, device, adc_cfg) -> ProgrammedLinear`` — compile
  one float weight matrix into a frozen pytree artifact: quantized cell
  codes, the device-perturbed effective cells (``g_eff``), the static
  ``QuantParams``, the ``layer_scaled_spec``, the digital correction column
  sums, and the write-verify ``ProgramReport`` metadata.
* ``programmed_matmul(x, art)`` / ``programmed_linear(x, art)`` — the
  steady-state forward: input quantization -> Pallas kernel -> dequantize.
  No ``jnp.max(w)`` reductions, no ``effective_cell_codes``, no per-call
  fault redraw.  Noisy runs become self-consistent: one fixed programmed
  chip serves the whole inference run instead of a fresh noise draw per
  layer call.
* ``program_model(params, ...) -> ProgrammedModel`` — walk a parameter
  pytree and compile every matmul-shaped leaf.  Artifacts are **keyed by
  the joined parameter path** ("stage0/b0/mixer/wq"), not by leaf object
  identity: a pytree copy (``jax.device_put``, donation, optimizer step,
  checkpoint restore), a fresh jit trace, or a transpose view all resolve
  to the same artifact, because the *name* is stable where the array
  object is not.  ``models.layers.crossbar_linear(x, w, name=...)`` joins
  the call-site name with the active ``name_scope`` stack (pushed by
  ``models.model`` as it descends stages/blocks/submodules) and looks the
  key up in the dynamic ``bind_artifacts`` stack first (scan-sliced
  per-layer bindings) and the model's ``by_name`` table second.

Everything static (spec, scales, ADC config, report) rides in the pytree
*aux* so a ``ProgrammedLinear`` can be passed through ``jax.jit`` or closed
over as a constant; the arrays (``w_codes``, ``g_eff``, ``w_colsum``) are
ordinary leaves.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.adc import ADCConfig, SAFE_ADAPTIVE
from repro.core.crossbar import (
    CrossbarSpec,
    DEFAULT_SPEC,
    QuantParams,
    layer_scaled_spec,
    quantize_input,
    quantize_weight,
)
from repro.device import models as dm
from repro.device.program import ProgramReport


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class ProgrammedLinear:
    """One weight matrix compiled onto (possibly noisy) crossbars.

    Array leaves (all become scan/vmap-sliceable pytree children):
      * ``w_codes``: (K, N) int32 signed quantized weight codes — the ideal
        cells, consumed directly by the bit-slicing Pallas kernel.
      * ``g_eff``: (S, K, N) float32 device-perturbed effective cell codes,
        or None for ideal devices (then ``w_codes`` is the ground truth).
      * ``w_colsum``: (N,) float32 column sums of the *float* weights — the
        digital offset-correction term ``crossbar_linear`` needs (computed
        at write time on real hardware, alongside the biased column sums
        inside the kernels' requantize stage).
      * ``w_scale``: 0-d float32 — the frozen weight quantization scale (the
        ``max |w|`` reduction, paid once at programming time).
      * ``x_scale``: 0-d float32 or None — frozen input scale; None keeps
        input quantization dynamic (per-call ``max(x)``), exactly matching
        the unprogrammed path.
      * ``g_spare``: (S, K, B) float32 programmed spare-column cells, or
        None when the device provisions no repair (``device.repair``).
        ``g_eff`` already holds the *repaired* layout (spares scattered into
        victim positions at programming time — zero steady-state overhead);
        the spare block plus ``out_gather`` are the explicit hardware
        record: the redundant columns as programmed and the column-mux
        routing table.
      * ``out_gather``: (S, R, N) int32 or None — per-physical-crossbar
        routing tables (R = row groups): the physical column serving each
        logical output within that (slice, row group) array (j, or N + b
        for repaired units).
      * ``comp_scale``: (N,) float32 or None — drift-compensating *digital*
        per-column output scales (``device.health.fit_compensation``).
        They live outside the chip — updating them costs no reprogramming —
        and are applied after the dequantize, before the offset-correction
        colsum.  None (fresh chips) is a bit-exact no-op.

    **Service time**: a programmed chip decays in service (power-law
    retention drift).  ``age(dt_s)`` / ``at_time(t_s)`` return a
    drift-evolved view of the same chip — ``g_eff``/``g_spare`` decayed
    through the device's level map, ``t_service_s`` advanced — without
    reprogramming; the digital record (``w_codes``, ``w_colsum``) is
    immortal and stays the frozen reference the health monitor probes
    against.  Aging a drift-free chip only advances the clock
    (bit-identical arrays).

    A *stacked* artifact (from a ``(L, K, N)`` scan-stacked parameter leaf)
    carries a leading layer axis on every array; ``jax.lax.scan`` /
    ``tree.map(lambda a: a[i])`` slice it back to a servable per-layer
    artifact (``models.model._run_stage`` does exactly this).

    Static aux (hashable; part of the jit cache key): ``spec`` — the
    layer-scaled ``CrossbarSpec`` (``drop_lsb`` already chosen for this K);
    ``adc_cfg`` / ``fast`` — which kernel path serves this artifact;
    ``report`` — optional write-verify ``ProgramReport``; ``repair`` —
    optional ``repair.RepairReport`` (tuples of them for stacked artifacts);
    ``device`` — the ``DeviceConfig`` the chip was programmed with (the
    lifecycle layer needs its drift law and level map to age the chip);
    ``t_service_s`` — seconds of service since programming; ``plan`` — the
    optional ``core.planner.LayerPlan`` this chip was compiled under: which
    datapath serves it (direct / Karatsuba levels / Strassen — executed by
    ``programmed_matmul`` on ideal chips, bit-identical by exact limb
    arithmetic), which ADC schedule, and the spare/replication budgets the
    programming pass materialized.
    """

    w_codes: jnp.ndarray
    g_eff: Optional[jnp.ndarray]
    w_colsum: jnp.ndarray
    w_scale: jnp.ndarray
    x_scale: Optional[jnp.ndarray]
    spec: CrossbarSpec
    adc_cfg: Optional[ADCConfig] = None
    fast: bool = True
    report: Optional[Any] = None
    g_spare: Optional[jnp.ndarray] = None
    out_gather: Optional[jnp.ndarray] = None
    repair: Optional[Any] = None
    comp_scale: Optional[jnp.ndarray] = None
    device: Optional[dm.DeviceConfig] = None
    t_service_s: float = 0.0
    plan: Optional[Any] = None  # core.planner.LayerPlan (static, hashable)

    @property
    def noisy(self) -> bool:
        return self.g_eff is not None

    def age(self, dt_s: float) -> "ProgrammedLinear":
        """Advance the chip ``dt_s`` seconds of service (drift-evolved view)."""
        return age_artifact(self, dt_s)

    def at_time(self, t_s: float) -> "ProgrammedLinear":
        """The chip at absolute service time ``t_s >= t_service_s``."""
        return artifact_at_time(self, t_s)

    @property
    def stacked(self) -> bool:
        """Carries leading stacking axes beyond the servable (K, N) matrix:
        (L, K, N) scan-stacked layers, (E, K, N) expert stacks, or the
        (L, E, K, N) combination.  ``layer(i)`` peels one leading axis."""
        return self.w_codes.ndim >= 3

    @property
    def shape(self) -> Tuple[int, ...]:
        return tuple(self.w_codes.shape)

    @property
    def qp(self) -> QuantParams:
        """Static view of the frozen quantization scales (introspection)."""
        if self.stacked:
            raise ValueError(
                "stacked artifact holds per-layer scales: use art.layer(i).qp"
            )
        return QuantParams(
            x_scale=(float(self.x_scale) if self.x_scale is not None else 0.0),
            w_scale=float(self.w_scale),
        )

    def layer(self, i: int) -> "ProgrammedLinear":
        """Slice one layer out of a stacked artifact."""
        assert self.stacked, "layer() only applies to stacked artifacts"
        return jax.tree.map(lambda a: a[i], self)

    def tree_flatten(self):
        children = (
            self.w_codes, self.g_eff, self.w_colsum, self.w_scale, self.x_scale,
            self.g_spare, self.out_gather, self.comp_scale,
        )
        aux = (
            self.spec, self.adc_cfg, self.fast, self.report, self.repair,
            self.device, self.t_service_s, self.plan,
        )
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        (w_codes, g_eff, w_colsum, w_scale, x_scale, g_spare, out_gather,
         comp_scale) = children
        spec, adc_cfg, fast, report, repair, device, t_service_s, plan = aux
        return cls(
            w_codes, g_eff, w_colsum, w_scale, x_scale, spec, adc_cfg, fast,
            report, g_spare=g_spare, out_gather=out_gather, repair=repair,
            comp_scale=comp_scale, device=device, t_service_s=t_service_s,
            plan=plan,
        )


# Every array leaf a ProgrammedLinear carries — the single source of truth
# for serialization (checkpoint.save_programmed) and equality checks.
ARTIFACT_ARRAY_FIELDS = (
    "w_codes", "g_eff", "w_colsum", "w_scale", "x_scale", "g_spare", "out_gather",
    "comp_scale",
)


def artifacts_equal(a: "ProgrammedLinear", b: "ProgrammedLinear") -> bool:
    """Bit-exact artifact equality: every array field (None-ness included)
    plus the static datapath aux (spec / adc_cfg / fast) and the lifecycle
    state (device / t_service_s — two chips at different service times are
    different chips).  Reports are observability metadata and deliberately
    not part of chip equality."""
    for f in ARTIFACT_ARRAY_FIELDS:
        va, vb = getattr(a, f), getattr(b, f)
        if (va is None) != (vb is None):
            return False
        if va is not None and not bool(jnp.array_equal(va, vb)):
            return False
    return (
        a.spec == b.spec
        and a.adc_cfg == b.adc_cfg
        and a.fast == b.fast
        and a.device == b.device
        and a.t_service_s == b.t_service_s
        and a.plan == b.plan
    )


def program_layer(
    w: jnp.ndarray,
    spec: CrossbarSpec = DEFAULT_SPEC,
    device: Optional[dm.DeviceConfig] = None,
    adc_cfg: Optional[ADCConfig] = SAFE_ADAPTIVE,
    *,
    x_scale: Optional[float] = None,
    w_scale: Optional[float] = None,
    fast: bool = True,
    with_report: bool = False,
    chips: Optional[Tuple[int, ...]] = None,
    plan: Optional[Any] = None,
) -> ProgrammedLinear:
    """Compile one (K, N) — or stacked (L, K, N) / (L, E, K, N) — weight.

    This is the *programming-time* entry point — it runs every expensive,
    weight-only stage exactly once: the ``max |w|`` scale reduction, weight
    quantization, the device fault draw + write-verify pulse loop + read
    path (``effective_cell_codes``), and the correction column sums.  It is
    deterministic in (w, spec, device): programming twice yields the same
    chip, bit for bit, as the old program-every-call path drew per call.

    ``x_scale=None`` keeps input quantization dynamic (per-call ``max(x)``),
    matching the unprogrammed path exactly; pass a calibrated scale for
    fully static serving.  ``with_report=True`` routes programming through
    ``program.write_verify`` for convergence metadata (bit-identical cells).

    Stacked leaves recurse over every leading axis: a scan-stacked MoE
    expert bank ``(L, E, d_model, d_ff)`` compiles to an artifact whose
    arrays carry ``(L, E, ...)`` — the layer scan slices ``L``, the
    per-expert scan inside ``models.moe`` slices ``E``.

    ``chips`` models chip-to-chip fleet spread: one ``DeviceConfig.chip``
    identity per slice of the *innermost* stacking axis (the expert axis
    for a 4-D bank, the layer axis for 3-D), so the slabs an EP mesh places
    on different ranks draw decorrelated device perturbations — the same
    expert weights on chip 3 and chip 5 are different physical dies.  The
    stacked artifact's ``device`` aux keeps the base config (chip as
    passed): aging depends only on the drift law, which the spread does not
    touch.  ``chips=None`` (default) is bit-compatible with every
    pre-lifecycle artifact.

    ``plan`` (a ``core.planner.LayerPlan``) compiles the chip under the
    plan compiler's per-layer choices: the ADC config is materialized from
    the plan's mode against the layer-scaled spec, a positive planned
    spare-column budget overrides the device's (only when the device has
    stuck faults to repair — a plan cannot conjure a fault model), and the
    plan rides the artifact's static aux so ``programmed_matmul`` executes
    the chosen datapath.  ``plan=None`` is the homogeneous compile,
    bit-compatible with every pre-planner artifact.
    """
    w = jnp.asarray(w, jnp.float32)
    if w.ndim >= 3:  # stacked (L/E leading axes): compile per slice, stack
        if chips is not None and w.ndim == 3:
            if device is None:
                raise ValueError("chips= requires a DeviceConfig")
            if len(chips) != w.shape[0]:
                raise ValueError(
                    f"chips has {len(chips)} entries for stacking axis "
                    f"of {w.shape[0]}"
                )
            devices = [
                dataclasses.replace(device, chip=int(c)) for c in chips
            ]
        else:  # 4-D: forward chips to the inner (expert) axis
            devices = [device] * w.shape[0]
        parts = [
            program_layer(
                w[i], spec, devices[i], adc_cfg, x_scale=x_scale,
                w_scale=w_scale, fast=fast, with_report=with_report,
                chips=(chips if w.ndim > 3 else None), plan=plan,
            )
            for i in range(w.shape[0])
        ]
        reports = tuple(p.report for p in parts)
        repairs = tuple(p.repair for p in parts)
        # per-layer reports differ, which would make the tree structures
        # unequal — strip them before stacking, reattach as tuples; the
        # per-slice device aux (chip spread) is likewise normalized to the
        # base config so every part flattens to the same treedef
        parts = [
            dataclasses.replace(p, report=None, repair=None, device=device)
            for p in parts
        ]
        out = jax.tree.map(lambda *xs: jnp.stack(xs), *parts)
        return dataclasses.replace(
            out,
            report=(reports if any(r is not None for r in reports) else None),
            repair=(repairs if any(r is not None for r in repairs) else None),
        )
    spec = layer_scaled_spec(spec, w.shape[0])
    if plan is not None:
        from repro.core.planner import adc_config_for

        # materialize the plan's choices: ADC schedule against *this*
        # layer's scaled spec, spare budget onto the fault model (a plan
        # with spares but no faulty device to repair is a no-op, not an
        # error — the plan may have been compiled for a noisier deployment)
        adc_cfg = adc_config_for(plan.adc_mode, spec)
        if (
            plan.spare_cols > 0
            and device is not None
            and not device.is_ideal
            and (device.p_stuck_on > 0 or device.p_stuck_off > 0)
        ):
            device = dataclasses.replace(device, spare_cols=plan.spare_cols)
    if w_scale is None:
        # kept as a 0-d array so the steady-state dequantize is op-for-op
        # identical to the per-call path's traced scale
        w_scale_a = jnp.maximum(jnp.max(jnp.abs(w)), 1e-9) / (
            (1 << (spec.weight_bits - 1)) - 1
        )
    else:
        w_scale_a = jnp.asarray(w_scale, jnp.float32)
    wq = quantize_weight(w, spec, w_scale_a)
    w_colsum = jnp.sum(w, axis=0)
    g_eff = None
    g_spare = None
    out_gather = None
    report = None
    repair_rep = None
    if device is not None and not device.is_ideal:
        wb = wq + spec.weight_bias
        # fault-aware spare-column repair (device.repair): remap the worst
        # fault-afflicted columns into programmed spares and bake the
        # repaired layout into g_eff — steady-state calls pay nothing.
        # repaired_effective_cells is the single derivation site for the
        # programming intermediates; with_report only adds observability
        # (bit-identical cells, pinned by test_programming_is_deterministic)
        from repro.device import repair as repair_mod

        g_eff, rplan, report = repair_mod.repaired_effective_cells(
            wb, spec, device, with_report=with_report
        )
        if rplan is not None:
            g_spare = rplan.g_spare
            out_gather = rplan.out_gather
            repair_rep = repair_mod.repair_report(rplan)
    return ProgrammedLinear(
        w_codes=wq, g_eff=g_eff, w_colsum=w_colsum,
        w_scale=w_scale_a,
        x_scale=(jnp.asarray(x_scale, jnp.float32) if x_scale is not None else None),
        g_spare=g_spare, out_gather=out_gather,
        spec=spec, adc_cfg=adc_cfg, fast=fast, report=report, repair=repair_rep,
        device=device, t_service_s=0.0, plan=plan,
    )


# ---------------------------------------------------------------------------
# Service-time aging (the chip lifecycle's clock)
# ---------------------------------------------------------------------------


def artifact_at_time(art: ProgrammedLinear, t_s: float) -> ProgrammedLinear:
    """The chip as it reads at absolute service time ``t_s``.

    Drift is monotone conductance loss — a programmed chip can only move
    forward in time (``t_s >= art.t_service_s``; rejuvenation means
    reprogramming, see ``ServingEngine.refresh``).  The decay between the
    two service times is a single scalar factor from the device's power law
    (``models.drift_time_factor``), pushed through the level map onto the
    stored effective cells (``models.age_effective_codes``) — works
    unchanged on stacked ``(L, …, S, K, N)`` arrays because the transform
    is elementwise.  The digital record (``w_codes``, ``w_colsum``,
    scales) never ages: it is the frozen reference the health monitor
    compares against.

    A drift-free chip (no device, ideal device, ``drift_nu == 0``) only
    advances the clock — the arrays are the same objects, bit-identical by
    construction.  The factor-1.0 short-circuit also matters for exactness:
    the code -> conductance -> code round trip re-snaps to the write grid
    and is not a float identity.
    """
    t_s = float(t_s)
    if t_s < art.t_service_s:
        raise ValueError(
            f"cannot rejuvenate a chip: at_time({t_s}) < current service "
            f"time {art.t_service_s} (reprogram instead)"
        )
    if art.g_eff is None or art.device is None:
        return dataclasses.replace(art, t_service_s=t_s)
    factor = dm.drift_time_factor(art.device, art.t_service_s, t_s)
    if factor == 1.0:
        return dataclasses.replace(art, t_service_s=t_s)
    g_eff = dm.age_effective_codes(art.g_eff, art.spec, art.device, factor)
    g_spare = (
        dm.age_effective_codes(art.g_spare, art.spec, art.device, factor)
        if art.g_spare is not None
        else None
    )
    return dataclasses.replace(
        art, g_eff=g_eff, g_spare=g_spare, t_service_s=t_s
    )


def age_artifact(art: ProgrammedLinear, dt_s: float) -> ProgrammedLinear:
    """Advance a chip ``dt_s >= 0`` seconds of service (see ``artifact_at_time``)."""
    if dt_s < 0:
        raise ValueError(f"dt_s must be non-negative, got {dt_s}")
    return artifact_at_time(art, art.t_service_s + float(dt_s))


def programmed_matmul(
    x: jnp.ndarray,
    art: ProgrammedLinear,
    interpret: Optional[bool] = None,
    skip_zero_planes: bool = True,
) -> jnp.ndarray:
    """Steady-state float crossbar matmul against a programmed artifact.

    The entire inference-time path: input quantization -> Pallas kernel ->
    dequantize — no weight reductions, no fault redraw.  Bit-identical to
    ``kernels.ops.crossbar_matmul(x, w, device=...)`` with the same
    quantization scales, but the programming pipeline has been amortized
    away, and repeated calls reuse the *same* programmed chip
    (self-consistent noise) instead of redrawing it.  ``x`` must be
    non-negative (see ``programmed_linear`` for the offset-encoded form).

    Deliberately *not* wrapped in an extra jit: the elementwise stages
    mirror ``crossbar_matmul`` op-for-op (XLA's scalar-chain reassociation
    inside a fused jit can perturb the dequantize product by 1 ULP,
    breaking the bit-identity guarantee vs the program-every-call path);
    the heavy kernel call is jitted already, and under an outer jit
    everything fuses anyway.
    """
    from repro.kernels.crossbar_vmm import crossbar_vmm_pallas
    from repro.kernels.noisy_vmm import noisy_vmm_pallas

    if art.stacked:
        raise ValueError(
            "stacked artifact: slice one layer first (art.layer(i), or let "
            "models.model._run_stage scan over it)"
        )
    if interpret is None:
        from repro.kernels.ops import _auto_interpret

        interpret = _auto_interpret()
    spec = art.spec
    if art.x_scale is not None:
        x_scale = art.x_scale
    else:
        # barrier: one canonical x_scale value feeds both the quantize and
        # the dequantize — without it XLA duplicates this cheap computation
        # into both consumer fusions, where it may lower differently (e.g.
        # divide vs reciprocal-multiply) and perturb the dequantize by an
        # output ULP; bit-identity across eager/jit/shard_map is a contract
        # here (tests/test_sharded_artifacts.py pins it on an 8-rank mesh)
        x_scale = jax.lax.optimization_barrier(
            jnp.maximum(jnp.max(x), 1e-9) / ((1 << spec.input_bits) - 1)
        )
    xq = quantize_input(x, spec, x_scale)
    datapath = art.plan.datapath if art.plan is not None else "direct"
    if art.g_eff is not None:
        # noisy chips always serve through the device kernel: the
        # effective-cell read models physical arrays, which the
        # divide-and-conquer datapaths re-tile rather than re-read — the
        # plan still governs the ADC schedule (adc_cfg below) and the spare
        # budget (baked into g_eff at programming time)
        yq = noisy_vmm_pallas(
            xq, art.g_eff, spec, adc_cfg=art.adc_cfg, interpret=interpret,
            skip_zero_planes=skip_zero_planes,
        )
    elif datapath != "direct":
        # planned heterogeneous datapath: exact limb arithmetic, so the
        # output codes are bit-identical to the direct kernel's (the
        # kernel_planned bench and tests/test_planner.py gate this)
        if datapath == "strassen":
            from repro.core.strassen import strassen_matmul

            lead = xq.shape[:-1]
            yq = strassen_matmul(
                xq.reshape(-1, xq.shape[-1]), art.w_codes, spec, levels=1
            ).reshape(lead + (art.w_codes.shape[-1],))
        else:
            from repro.core.karatsuba import karatsuba_vmm

            yq = karatsuba_vmm(
                xq, art.w_codes, spec, levels=art.plan.karatsuba_levels
            )
    elif art.fast:
        yq = crossbar_vmm_pallas(
            xq, art.w_codes, spec, adc_cfg=None, fast=True, interpret=interpret,
            skip_zero_planes=skip_zero_planes,
        )
    else:
        yq = crossbar_vmm_pallas(
            xq, art.w_codes, spec, adc_cfg=art.adc_cfg, interpret=interpret,
            skip_zero_planes=skip_zero_planes,
        )
    # dequantize with a pinned association order: the barrier keeps XLA's
    # algebraic simplifier from reassociating the scalar chain (folding
    # w_scale into the 2^drop constant under jit, which rounds differently
    # than the eager left-to-right product) — eager, jit and shard_map
    # executions of one artifact must dequantize bit-identically
    scale = jax.lax.optimization_barrier(x_scale * art.w_scale)
    y = yq.astype(jnp.float32) * (scale * (2.0 ** spec.drop_lsb))
    if art.comp_scale is not None:
        # drift compensation is a separate digital per-column multiply,
        # after the dequantize and before the offset-correction colsum (the
        # correction uses the time-invariant digital w_colsum, so only the
        # analog product gets rescaled).  The barrier pins it as its own
        # rounding step so eager/jit/shard_map stay bit-identical.
        y = jax.lax.optimization_barrier(y) * art.comp_scale
    return y


def programmed_linear(
    x: jnp.ndarray,
    art: ProgrammedLinear,
    interpret: Optional[bool] = None,
    colsum: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Signed-activation ``x @ w`` against a programmed artifact.

    The offset-encoding dance of ``models.layers.crossbar_linear`` — shift
    activations non-negative, run the unsigned datapath, correct digitally
    with the weight column sums — except the column sums come precomputed
    from the artifact (written once at programming time, as real hardware
    does) instead of a per-call ``sum(w, axis=0)`` reduction.

    ``colsum`` overrides ``art.w_colsum`` — the per-rank partial-sum path
    needs it: a contraction-sharded (K-sharded) artifact holds only this
    rank's rows, so the offset correction must use the *local* rows' column
    sums (the all-reduce of ``shift_r * colsum_r`` across ranks then
    reconstitutes the full correction exactly — offset encoding decomposes
    over row blocks).
    """
    shift = jnp.min(x)
    # barriers pin the rounding points of the offset-encode chain: without
    # them XLA is free to fuse the subtraction into the downstream quantize
    # divide (or the dequantize multiply and the correction into an FMA),
    # and those contractions round differently depending on how the
    # *surrounding* graph fuses — eager, jit and shard_map executions of the
    # same artifact must agree bit-for-bit (the distributed test tier pins
    # this across an 8-device mesh)
    xs = jax.lax.optimization_barrier((x - shift).astype(jnp.float32))
    y = programmed_matmul(xs, art, interpret=interpret)
    cs = art.w_colsum if colsum is None else colsum
    y, corr = jax.lax.optimization_barrier((y, shift.astype(jnp.float32) * cs))
    return y + corr


# ---------------------------------------------------------------------------
# Per-rank artifact sharding (mesh serving)
# ---------------------------------------------------------------------------
#
# A multi-chip deployment is a mapping constraint in the paper's sense: the
# weight's PartitionSpec says which crossbars live on which rank.  Artifacts
# must shard *with* the weights they shadow — same specs, sliced consistently
# across every array leaf — so a ``shard_map`` body can rebuild a rank-local
# ``ProgrammedLinear`` from rank-local array shards and serve programmed.
#
# Axis semantics per artifact field (w_codes is the weight, (…stack, K, N)):
#   * stacking axes (L layers / E experts) — slice every leaf; each (K, N)
#     slab stays intact, so expert-parallel serving is bit-identical;
#   * N (output columns) — column-separable: cells, colsums and gather
#     tables slice cleanly (``local_artifact`` re-indexes repair tables to
#     local column coordinates);
#   * K (contraction rows) — rank-local *rows of the global chip*: servable
#     as partial sums (quantization is elementwise in w, so sliced rows of
#     ``w_codes``/``g_eff`` ARE the rows the global chip programmed), but
#     ``w_colsum`` is a full-K reduction and cannot be sliced — the caller
#     must supply local column sums (``programmed_linear(colsum=...)``).


def _pspec_entries(wspec, ndim: int) -> Tuple[Any, ...]:
    """Normalize a PartitionSpec (possibly shorter than ndim) to entries."""
    entries = tuple(wspec) if wspec is not None else ()
    if len(entries) > ndim:
        raise ValueError(f"spec {wspec} longer than weight rank {ndim}")
    return entries + (None,) * (ndim - len(entries))


def artifact_shard_specs(art: ProgrammedLinear, wspec) -> Dict[str, Any]:
    """{array field: PartitionSpec} matching the shadowed weight's spec.

    ``wspec`` is the weight's PartitionSpec ((…stack, K, N) axes).  Every
    array leaf of the artifact gets the spec that slices it consistently
    with the weight: stacking axes map one-to-one, ``g_eff``/``g_spare``
    keep their bit-plane axis replicated, column-shaped leaves follow N.
    The returned dict is exactly what ``shard_map`` ``in_specs`` (via
    ``artifact_arrays``) or ``NamedSharding`` placement needs.
    """
    from jax.sharding import PartitionSpec as P

    nd = art.w_codes.ndim
    entries = _pspec_entries(wspec, nd)
    stack, kspec, nspec = entries[:-2], entries[-2], entries[-1]
    specs = {
        "w_codes": P(*stack, kspec, nspec),
        "g_eff": P(*stack, None, kspec, nspec),
        # w_colsum has no K axis — under K-sharding it stays the *global*
        # correction term (a K-sharded chip's per-rank partial colsums
        # cannot live in the artifact; the partial-sum serving path
        # overrides it via ``programmed_linear(colsum=)``)
        "w_colsum": P(*stack, nspec),
        "w_scale": P(*stack),
        "x_scale": P(*stack),
        # the spare block is a per-group column *budget*, not logical output
        # columns — keep it whole on every rank that holds the group's rows
        "g_spare": P(*stack, None, kspec, None),
        # (S, R, N) per-crossbar routing tables: slice/row-group axes stay
        # whole (they are physical-array coordinates), columns follow N
        "out_gather": P(*stack, None, None, nspec),
        # digital per-column compensation scales follow the output columns,
        # exactly like w_colsum
        "comp_scale": P(*stack, nspec),
    }
    return {f: specs[f] for f in ARTIFACT_ARRAY_FIELDS if getattr(art, f) is not None}


def dividing_pspec(spec, shape, axis_sizes) -> Any:
    """Degrade non-dividing PartitionSpec entries to replicated.

    The one shared rule for "can this dim actually shard here": an entry
    is kept only if every named axis exists in ``axis_sizes`` (a mesh's
    ``.shape`` mapping) and the axes' total size divides the dim; anything
    else becomes None.  ``shard_artifacts`` placement, checkpoint
    ``restore_programmed`` re-placement and ``local_artifact`` slicing all
    route through this, so a chip is re-placed on restore exactly where
    the deployment put it — the three sites can never drift apart.
    """
    import numpy as np
    from jax.sharding import PartitionSpec as P

    fixed = []
    for dim, ax in zip(shape, _pspec_entries(spec, len(shape))):
        if ax is None:
            fixed.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        if any(a not in axis_sizes for a in axes):
            fixed.append(None)
            continue
        size = int(np.prod([axis_sizes[a] for a in axes]))
        fixed.append(ax if dim % size == 0 else None)
    return P(*fixed)


def artifact_arrays(art: ProgrammedLinear) -> Dict[str, jnp.ndarray]:
    """{field: array} for every non-None array leaf (shard_map input tree)."""
    return {
        f: getattr(art, f)
        for f in ARTIFACT_ARRAY_FIELDS
        if getattr(art, f) is not None
    }


def with_arrays(template: ProgrammedLinear, arrays: Dict[str, jnp.ndarray]) -> ProgrammedLinear:
    """Rebuild an artifact from (rank-local) arrays + a template's static aux.

    The inverse of ``artifact_arrays``: the ``shard_map`` body receives the
    sliced arrays as inputs, closes over the global artifact as the aux
    template, and rebinds.  Reports describe the *global* chip and are
    dropped — a rank-local view must not masquerade as the full record.
    """
    missing = {
        f: None for f in ARTIFACT_ARRAY_FIELDS if f not in arrays
    }
    return dataclasses.replace(
        template, report=None, repair=None, **arrays, **missing
    )


def shard_artifacts(prog: "ProgrammedModel", mesh, specs: Dict[str, Any]) -> "ProgrammedModel":
    """Place every artifact's arrays on ``mesh`` with its weight's spec.

    ``specs`` maps canonical artifact names to the shadowed weight's
    PartitionSpec (missing names stay replicated).  Non-dividing dims fall
    back to replicated per entry — mirroring ``layers.named_sharding_tree``
    — so a spec tuned for the production mesh degrades gracefully on a
    smaller test mesh.  Returns a new ProgrammedModel (same tree layout,
    same aux); under jit/GSPMD the placed arrays serve distributed instead
    of replicating the 8x ``g_eff`` planes onto every device, and a
    ``shard_map`` body receiving them with matching in_specs pays no
    resharding.
    """
    from jax.sharding import NamedSharding

    def _place(name: str, art: ProgrammedLinear) -> ProgrammedLinear:
        wspec = specs.get(name)
        if wspec is None:
            return art
        child_specs = artifact_shard_specs(art, wspec)
        placed = {
            f: jax.device_put(
                getattr(art, f),
                NamedSharding(
                    mesh,
                    dividing_pspec(
                        child_specs[f], getattr(art, f).shape, mesh.shape
                    ),
                ),
            )
            for f in child_specs
        }
        return dataclasses.replace(art, **placed)

    flat, treedef = jax.tree_util.tree_flatten_with_path(
        prog.artifacts, is_leaf=lambda x: isinstance(x, ProgrammedLinear)
    )
    leaves = [
        _place(join_path(path), leaf) if isinstance(leaf, ProgrammedLinear) else leaf
        for path, leaf in flat
    ]
    return ProgrammedModel(jax.tree_util.tree_unflatten(treedef, leaves))


def local_artifact(
    art: ProgrammedLinear,
    wspec,
    axis_sizes: Dict[str, int],
    coords: Dict[str, int],
) -> ProgrammedLinear:
    """Materialize one rank's slice of an artifact (host-side, numpy).

    ``axis_sizes`` gives the mesh extent of every named axis in ``wspec``;
    ``coords`` is this rank's coordinate per axis.  Every array leaf is
    sliced along the weight's sharded axes; when N (output columns) is
    sharded and the artifact carries repair tables, ``out_gather`` is
    re-indexed to *local* column coordinates and ``g_spare`` is compacted to
    the spares local columns actually use — the per-rank hardware record a
    physically partitioned deployment would hold.  This is the validation /
    persistence counterpart of ``shard_artifacts`` (which places global
    arrays); serving correctness never depends on it because ``g_eff``
    already holds the repaired layout.
    """
    import numpy as np

    child_specs = artifact_shard_specs(art, wspec)

    def _block(entry, dim: int):
        # entry comes pre-normalized through dividing_pspec: non-dividing
        # or unknown-axis entries are already None (replicated)
        if entry is None:
            return slice(None)
        axes = entry if isinstance(entry, tuple) else (entry,)
        size = int(np.prod([axis_sizes[a] for a in axes]))
        idx = 0
        for a in axes:  # row-major linearization, like mesh device order
            idx = idx * axis_sizes[a] + coords[a]
        step = dim // size
        return slice(idx * step, (idx + 1) * step)

    def _slice(a, spec):
        a = np.asarray(jax.device_get(a))
        fixed = dividing_pspec(spec, a.shape, axis_sizes)
        sl = tuple(_block(e, d) for e, d in zip(fixed, a.shape))
        return a[sl]

    arrays = {f: _slice(getattr(art, f), child_specs[f]) for f in child_specs}
    # repair re-indexing keys off the *normalized* N entry: if the column
    # dim could not shard (axis unknown / non-dividing), out_gather was not
    # sliced above and must keep its global coordinates
    nspec = tuple(dividing_pspec(wspec, art.w_codes.shape, axis_sizes))[-1]
    if nspec is not None and art.out_gather is not None:
        n_cols = int(art.w_codes.shape[-1])
        size = int(np.prod([axis_sizes[a] for a in (nspec if isinstance(nspec, tuple) else (nspec,))]))
        n_loc = n_cols // size
        gather = arrays["out_gather"]  # stack + (S, R, n_loc)
        lead = gather.shape[:-3]
        gather = gather.reshape((-1,) + gather.shape[-3:]).copy()
        spare = arrays["g_spare"]  # stack + (S, K, B)
        spare2 = spare.reshape((-1,) + spare.shape[-3:])
        new_spares = []
        for i in range(gather.shape[0]):
            # one chip: compact its spare block to the columns any of the
            # per-(slice, row group) routing tables actually reference,
            # sharing one local numbering across all of them (a spare is one
            # physical column position in every array of the group)
            flat = gather[i].reshape(-1, gather.shape[-1])
            used: list = []
            for u in range(flat.shape[0]):
                for j in range(n_loc):
                    g = int(flat[u, j])
                    if g < n_cols:
                        # data column: repair only ever redirects a column to
                        # a spare, so the global value is this column's own
                        # physical position — locally that is just j
                        flat[u, j] = j
                    else:
                        b = g - n_cols
                        if b not in used:
                            used.append(b)
                        flat[u, j] = n_loc + used.index(b)
            new_spares.append(spare2[i][..., used] if used else spare2[i][..., :0])
        width = max((s.shape[-1] for s in new_spares), default=0)
        padded = [
            np.pad(s, [(0, 0)] * (s.ndim - 1) + [(0, width - s.shape[-1])])
            for s in new_spares
        ]
        spare_out = np.stack(padded).reshape(lead + padded[0].shape) if lead else padded[0]
        arrays["out_gather"] = gather.reshape(lead + gather.shape[-3:])
        arrays["g_spare"] = spare_out
    arrays = {f: jnp.asarray(v) for f, v in arrays.items()}
    return with_arrays(art, arrays)


# ---------------------------------------------------------------------------
# Name-keyed artifact binding (eager and under jit)
# ---------------------------------------------------------------------------
#
# Artifacts are addressed by the *joined parameter path* — "stage0/b0/mixer/
# wq" — never by array object identity.  Identity keying silently orphans
# every artifact the moment the params tree is copied (jax.device_put, buffer
# donation, an optimizer step, a checkpoint restore all produce fresh leaf
# objects), downgrading the whole model to plain XLA matmul with no error.
# Names survive all of those, survive jit retraces, and give transposed
# views (the tied LM head) something stable to bind to.

_SCOPE = threading.local()  # .stack: list[str] — the active module path


@contextlib.contextmanager
def name_scope(name: str):
    """Push one path component onto the ambient parameter-name scope.

    ``models.model`` pushes "stage{i}" / "b{i}" / "mixer" / "ffn" as it
    descends, so a call site only states its local leaf name —
    ``crossbar_linear(x, w, name="wq")`` — and ``scoped_name`` joins the
    full key.  Purely a Python-level dynamic scope: it is active during
    tracing, costs nothing inside the compiled computation, and nests
    across ``jit`` / ``scan`` / ``checkpoint`` bodies.
    """
    stack = getattr(_SCOPE, "stack", None)
    if stack is None:
        stack = _SCOPE.stack = []
    stack.append(str(name))
    try:
        yield
    finally:
        stack.pop()


def scoped_name(name: str) -> str:
    """Join ``name`` onto the active scope: the canonical artifact key."""
    return "/".join(getattr(_SCOPE, "stack", []) + [str(name)])


def _path_component(entry: Any) -> str:
    for attr in ("key", "idx", "name"):
        if hasattr(entry, attr):
            return str(getattr(entry, attr))
    return str(entry)


def join_path(path: Tuple[Any, ...]) -> str:
    """Canonical "a/b/c" key for a jax tree path (Dict/Sequence/Attr keys)."""
    return "/".join(_path_component(p) for p in path)


def artifact_names(artifacts: Any, prefix: str = "") -> Dict[str, "ProgrammedLinear"]:
    """Flatten an artifact (sub)tree into {joined path: artifact}.

    ``prefix`` (usually the ambient scope at bind time) is prepended to
    every key, so a subtree bound deep inside a model maps to the same
    canonical names ``program_model`` derived from the full params tree.
    """
    flat, _ = jax.tree_util.tree_flatten_with_path(
        artifacts, is_leaf=lambda x: isinstance(x, ProgrammedLinear)
    )
    out: Dict[str, ProgrammedLinear] = {}
    for path, art in flat:
        if not isinstance(art, ProgrammedLinear):
            continue
        rel = join_path(path)
        key = "/".join(p for p in (prefix, rel) if p)
        out[key] = art
    return out


# Consumption accounting: every crossbar_linear call that *serves* from an
# artifact records the canonical name it resolved.  Together with the miss
# counter (models.layers) this gives the structural name-set check: after a
# traced forward, the names a ProgrammedModel emitted must equal the names
# the model consumed — a renamed layer or an artifact nothing serves is
# caught as a set mismatch even when no lookup ever *misses* (an orphaned
# artifact produces zero misses; only the consumption side exposes it).
# Recorded at trace time, bounded by distinct names, thread-local like the
# miss counter.
_CONSUMED = threading.local()  # .names: dict[str, None] (insertion-ordered set)


def record_artifact_consumed(name: str) -> None:
    names = getattr(_CONSUMED, "names", None)
    if names is None:
        names = _CONSUMED.names = {}
    names[name] = None


def consumed_artifact_names() -> Tuple[str, ...]:
    """Canonical names served from artifacts since the last reset, in
    first-consumption order."""
    return tuple(getattr(_CONSUMED, "names", {}))


def reset_consumed_artifact_names() -> None:
    _CONSUMED.names = {}


_BIND = threading.local()  # .maps: list of {name -> ProgrammedLinear}


@contextlib.contextmanager
def _push_bind_map(m: Dict[str, "ProgrammedLinear"]):
    stack = getattr(_BIND, "maps", None)
    if stack is None:
        stack = _BIND.maps = []
    stack.append(m)
    try:
        yield
    finally:
        stack.pop()


@contextlib.contextmanager
def bind_artifacts(artifacts: Any):
    """Bind a (sub)tree of artifacts by name for the dynamic scope.

    Keys are the subtree's own paths joined under the *current*
    ``name_scope`` — so ``model._run_stage``'s layer scan, which executes
    its body under ``name_scope("stage{i}")``, binds each per-iteration
    artifact slice to exactly the key the call sites inside the layer will
    ask for.  Later binds shadow earlier ones (innermost wins), which is
    how a per-expert slice bound inside the MoE expert scan takes
    precedence over the still-stacked per-layer binding outside it.
    """
    if artifacts is None:
        yield
        return
    m = artifact_names(artifacts, prefix="/".join(getattr(_SCOPE, "stack", [])))
    with _push_bind_map(m):
        yield


def active_artifact_for(
    name: str, shape: Optional[Tuple[int, ...]] = None
) -> Optional[ProgrammedLinear]:
    """Artifact bound to this canonical name in the dynamic scope, if any.

    Consulted by ``crossbar_linear`` (which passes the weight's shape).
    The shape guard does double duty: it rejects a still-stacked artifact
    when a 2-D weight asks (the enclosing scan hasn't sliced it yet — keep
    looking at outer binds), and it rejects stale bindings when two
    different tensors legitimately share a name (e.g. the embedding table
    vs its transposed LM-head artifact under the tied-head scheme).
    """
    for m in reversed(getattr(_BIND, "maps", [])):
        art = m.get(name)
        if art is not None and (shape is None or art.shape == tuple(shape)):
            return art
    return None


# The projection leaves routed through models.layers.crossbar_linear — the
# call sites that can consume an artifact: attention q/k/v/o and the MLA kv
# down-projection, the dense-MLP wi/wo, the MoE expert bank wi/wg/wo plus
# router and shared-expert projections, and the untied LM head.  (A tied LM
# head serves from the transposed embedding artifact that
# ``program_model(tie_lm_head=True)`` compiles under the embedding's name.)
_CROSSBAR_CONSUMERS = (
    "wq", "wk", "wv", "wo", "w_kv_down", "wi", "head",
    "wg", "router", "shared_wi", "shared_wg", "shared_wo",
)


def _path_names(path: Tuple[Any, ...]) -> Tuple[str, ...]:
    return tuple(str(getattr(p, "key", getattr(p, "name", ""))) for p in path)


def _matmul_leaf(path: Tuple[Any, ...], leaf: Any) -> bool:
    """Default predicate: which param leaves go onto crossbars.

    Allowlist of the projection names ``crossbar_linear`` actually serves
    (attention q/k/v/o, the MLA kv down-projection, dense-MLP wi/wo, MoE
    router/experts/shared experts, the untied LM head), as 2-D matrices,
    3-D scan-stacked ``(L, K, N)``, or 4-D expert banks ``(L, E, K, N)``.
    An allowlist — rather than excluding known non-matmuls — keeps stacked
    per-layer *vectors* (ssm ``conv_b``, ``D_skip``: ``(L, din)`` after
    stacking, indistinguishable from a small weight matrix by shape alone)
    from being miscompiled into unusable artifacts, and avoids paying
    write-verify programming + 8x ``g_eff`` memory for leaves no crossbar
    call site consumes.  Override with ``leaf_filter`` for exotic layouts.
    """
    if not isinstance(leaf, jnp.ndarray) or leaf.ndim not in (2, 3, 4):
        return False
    if not jnp.issubdtype(leaf.dtype, jnp.floating):
        return False
    names = _path_names(path)
    return bool(names) and names[-1] in _CROSSBAR_CONSUMERS


def stacked_only(artifacts: Any) -> Any:
    """Prune non-stacked artifacts from a stage subtree.

    A stage's layer scan slices every artifact array on a leading layer
    axis; a 2-D artifact (scalar ``w_scale``) inside a stacked-stage
    subtree can never be sliced that way and would crash the scan — drop
    it (the weight simply falls back to the per-call path).
    """
    return jax.tree_util.tree_map(
        lambda a: a if isinstance(a, ProgrammedLinear) and a.stacked else None,
        artifacts,
        is_leaf=lambda x: isinstance(x, ProgrammedLinear),
    )


class ProgrammedModel:
    """A pytree of ProgrammedLinear artifacts mirroring a params pytree.

    The tree shape mirrors the params so stage subtrees can ride the layer
    scan; ``by_name`` is the canonical path-keyed table every lookup
    resolves through.  Nothing here references parameter *objects* — a
    ProgrammedModel built once serves any congruent params tree (copies,
    donated buffers, restored checkpoints) and survives every jit retrace.
    """

    def __init__(self, artifacts: Any):
        self.artifacts = artifacts
        self.by_name: Dict[str, ProgrammedLinear] = artifact_names(artifacts)

    def bind(self):
        """Bind every artifact by name for the dynamic scope (must be
        entered at top-level model scope, e.g. around a jitted forward).
        Pushes the precomputed ``by_name`` table directly — no per-call
        tree reflatten in the serving hot loop."""
        return _push_bind_map(self.by_name)

    def subtree(self, key: str) -> Any:
        """Artifact subtree for one top-level params key (e.g. "stage0")."""
        try:
            return self.artifacts[key]
        except (KeyError, TypeError, IndexError):
            return None

    def lookup(
        self, name: str, shape: Optional[Tuple[int, ...]] = None
    ) -> Optional[ProgrammedLinear]:
        """Artifact for a canonical name, optionally shape-checked."""
        art = self.by_name.get(name)
        if art is not None and (shape is None or art.shape == tuple(shape)):
            return art
        return None

    @property
    def n_compiled(self) -> int:
        return len(self.by_name)

    @property
    def emitted_names(self) -> frozenset:
        """The canonical name set ``program_model`` emitted — the contract a
        forward pass must consume exactly (``verify_consumed``)."""
        return frozenset(self.by_name)

    def verify_consumed(self, consumed: Optional[Any] = None) -> None:
        """Assert a traced forward consumed exactly the emitted name set.

        ``consumed`` defaults to the ambient consumption record
        (``consumed_artifact_names()`` since the last reset).  Raises
        ``LookupError`` on any emitted artifact no call site served —
        the drift mode the miss counter can *never* catch: a renamed layer
        (or a leaf_filter that compiles a dead leaf) produces an orphaned
        artifact and zero misses, because nothing ever looks its name up.
        Names consumed but not emitted are reported alongside (they come
        from ad-hoc ``bind_artifacts`` scopes and usually accompany a
        rename).
        """
        got = frozenset(consumed_artifact_names() if consumed is None else consumed)
        unconsumed = self.emitted_names - got
        unexpected = got - self.emitted_names
        if unconsumed:
            raise LookupError(
                "programmed-artifact name-set drift: "
                f"{len(unconsumed)}/{len(self.by_name)} emitted artifacts were "
                f"never consumed by the forward ({', '.join(sorted(unconsumed)[:5])}"
                + (", ..." if len(unconsumed) > 5 else "")
                + ")"
                + (
                    f"; consumed-but-not-emitted: {', '.join(sorted(unexpected)[:5])}"
                    if unexpected
                    else ""
                )
                + " — a layer was renamed, or program_model compiled a leaf "
                "no call site serves."
            )

    def reports(self) -> Dict[str, ProgramReport]:
        """Name -> write-verify report for every compiled leaf that has one."""
        return {
            name: art.report
            for name, art in self.by_name.items()
            if art.report is not None
        }

    def repair_reports(self) -> Dict[str, Any]:
        """Name -> spare-column ``RepairReport`` (or per-layer tuple for
        stacked leaves) for every compiled leaf that was repaired."""
        return {
            name: art.repair
            for name, art in self.by_name.items()
            if art.repair is not None
        }

    def map_artifacts(
        self, fn: Callable[[ProgrammedLinear], ProgrammedLinear]
    ) -> "ProgrammedModel":
        """A new ProgrammedModel with ``fn`` applied to every artifact."""
        mapped = jax.tree_util.tree_map(
            lambda a: fn(a) if isinstance(a, ProgrammedLinear) else a,
            self.artifacts,
            is_leaf=lambda x: isinstance(x, ProgrammedLinear),
        )
        return ProgrammedModel(mapped)

    @property
    def t_service_s(self) -> float:
        """Fleet service time: the oldest chip's clock (chips age together
        under ``age``/``at_time``, so normally they all agree)."""
        return max((a.t_service_s for a in self.by_name.values()), default=0.0)

    def age(self, dt_s: float) -> "ProgrammedModel":
        """Every chip advanced ``dt_s`` seconds of service (no reprogramming)."""
        return self.map_artifacts(lambda a: age_artifact(a, dt_s))

    def at_time(self, t_s: float) -> "ProgrammedModel":
        """Every chip at absolute service time ``t_s`` (see ``artifact_at_time``)."""
        return self.map_artifacts(lambda a: artifact_at_time(a, t_s))


def program_model(
    params: Any,
    spec: CrossbarSpec = DEFAULT_SPEC,
    device: Optional[dm.DeviceConfig] = None,
    adc_cfg: Optional[ADCConfig] = SAFE_ADAPTIVE,
    *,
    fast: bool = True,
    with_report: bool = False,
    tie_lm_head: bool = False,
    leaf_filter: Optional[Callable[[Tuple[Any, ...], Any], bool]] = None,
    expert_chips: Optional[Tuple[int, ...]] = None,
    plan: Optional[Any] = None,
) -> ProgrammedModel:
    """Walk a param pytree and compile every matmul-shaped leaf.

    The whole-model programming pass: one ``program_layer`` per selected
    leaf, so an inference run (or a serving engine) works against a single
    fixed programmed chip.  ``leaf_filter(path, leaf) -> bool`` overrides
    the default projection-name predicate.

    ``expert_chips`` gives every 4-D expert bank one chip identity per
    expert (``program_layer(chips=...)``): an EP deployment that places
    expert ``e`` on rank ``e`` then models genuine chip-to-chip spread —
    each rank's slab drew its own device perturbations.  Leaves without an
    expert axis (2-D / 3-D) keep the base device unchanged, so the knob is
    a no-op for dense models and bit-compatible when ``None``.

    ``tie_lm_head=True`` additionally compiles the **transpose** of every
    2-D ``tokens`` embedding leaf and binds it to the embedding's own name
    — the tied LM head (``x @ tokens.T``) then serves from one artifact
    programmed at deploy time instead of reprogramming the transpose in
    every decode step (name-keyed binding is what makes this possible: a
    per-call transpose has no stable object identity, but it does have a
    name).  The (D, V) artifact shares the key with the (V, D) embedding
    leaf; shape-checked lookup keeps the two uses apart.

    ``plan`` (a ``core.planner.ChipPlan``, e.g. from ``planner.plan_model``
    on the same params) compiles each leaf under its per-layer
    ``LayerPlan``, matched by canonical artifact name; leaves the plan does
    not cover compile homogeneous, exactly as with ``plan=None``.
    """
    pred = leaf_filter if leaf_filter is not None else _matmul_leaf

    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    arts = []
    for path, leaf in flat:
        action = _program_action(path, leaf, pred, tie_lm_head)
        chips = (
            expert_chips
            if (
                expert_chips is not None
                and action is not None
                and getattr(leaf, "ndim", 0) == 4
            )
            else None
        )
        layer_plan = (
            plan.layer_for(join_path(path))
            if plan is not None and action is not None
            else None
        )
        arts.append(
            program_layer(
                leaf.T if action == "transpose" else leaf,
                spec, device, adc_cfg, fast=fast, with_report=with_report,
                chips=chips, plan=layer_plan,
            )
            if action is not None
            else None
        )
    artifacts = jax.tree_util.tree_unflatten(treedef, arts)
    return ProgrammedModel(artifacts)


def _program_action(path, leaf, pred, tie_lm_head: bool) -> Optional[str]:
    """What ``program_model`` does with this param leaf: "program" the leaf,
    "transpose" it first (tied-head ``tokens`` embeddings), or None when it
    stays digital.  A pure decision — nothing is materialized, so shape-only
    consumers (``expected_artifact_names``) stay allocation-free."""
    names = _path_names(path)
    if (
        tie_lm_head
        and names
        and names[-1] == "tokens"
        and isinstance(leaf, jnp.ndarray)
        and leaf.ndim == 2
        and jnp.issubdtype(leaf.dtype, jnp.floating)
    ):
        return "transpose"
    if pred(path, leaf):
        return "program"
    return None


def expected_artifact_names(
    params: Any,
    *,
    tie_lm_head: bool = False,
    leaf_filter: Optional[Callable[[Tuple[Any, ...], Any], bool]] = None,
) -> Dict[str, Tuple[int, ...]]:
    """{canonical name: servable shape} ``program_model`` would compile —
    without programming anything.

    The validation counterpart of ``program_model``: a restored artifact
    store can be cross-checked against the model it is about to serve
    (``ServingEngine(restore_artifacts=...)`` does) so a stale or
    mismatched store fails loudly at construction instead of silently
    degrading every lookup to per-call programming.
    """
    pred = leaf_filter if leaf_filter is not None else _matmul_leaf
    out: Dict[str, Tuple[int, ...]] = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        action = _program_action(path, leaf, pred, tie_lm_head)
        if action is not None:
            shape = tuple(leaf.shape)
            out[join_path(path)] = (
                tuple(reversed(shape)) if action == "transpose" else shape
            )
    return out
