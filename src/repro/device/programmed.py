"""Program-once crossbar compilation: frozen programmed-weight artifacts.

Newton's core premise is that weights are programmed into crossbars *once*
and then serve in-situ traffic indefinitely — programming (fault draw,
write-verify pulses, IR-drop solve, quantization-scale reductions) is a
deployment-time cost, not a per-call one.  The pre-existing hot path
re-ran that whole pipeline inside every ``crossbar_matmul(device=...)``
call; this module splits the stack into an explicit **programming time**
vs **inference time**:

* ``program_layer(w, spec, device, adc_cfg) -> ProgrammedLinear`` — compile
  one float weight matrix into a frozen pytree artifact: quantized cell
  codes, the device-perturbed effective cells (``g_eff``), the static
  ``QuantParams``, the ``layer_scaled_spec``, the digital correction column
  sums, and the write-verify ``ProgramReport`` metadata.
* ``programmed_matmul(x, art)`` / ``programmed_linear(x, art)`` — the
  steady-state forward: input quantization -> Pallas kernel -> dequantize.
  No ``jnp.max(w)`` reductions, no ``effective_cell_codes``, no per-call
  fault redraw.  Noisy runs become self-consistent: one fixed programmed
  chip serves the whole inference run instead of a fresh noise draw per
  layer call.
* ``program_model(params, ...) -> ProgrammedModel`` — walk a parameter
  pytree and compile every matmul-shaped leaf; ``ProgrammedModel.bind``
  re-associates artifacts with (possibly traced) parameters inside ``jit``
  so ``models.layers.crossbar_linear`` finds them transparently.

Everything static (spec, scales, ADC config, report) rides in the pytree
*aux* so a ``ProgrammedLinear`` can be passed through ``jax.jit`` or closed
over as a constant; the arrays (``w_codes``, ``g_eff``, ``w_colsum``) are
ordinary leaves.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.adc import ADCConfig, SAFE_ADAPTIVE
from repro.core.crossbar import (
    CrossbarSpec,
    DEFAULT_SPEC,
    QuantParams,
    layer_scaled_spec,
    quantize_input,
    quantize_weight,
)
from repro.device import models as dm
from repro.device.program import ProgramReport, write_verify


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class ProgrammedLinear:
    """One weight matrix compiled onto (possibly noisy) crossbars.

    Array leaves (all become scan/vmap-sliceable pytree children):
      * ``w_codes``: (K, N) int32 signed quantized weight codes — the ideal
        cells, consumed directly by the bit-slicing Pallas kernel.
      * ``g_eff``: (S, K, N) float32 device-perturbed effective cell codes,
        or None for ideal devices (then ``w_codes`` is the ground truth).
      * ``w_colsum``: (N,) float32 column sums of the *float* weights — the
        digital offset-correction term ``crossbar_linear`` needs (computed
        at write time on real hardware, alongside the biased column sums
        inside the kernels' requantize stage).
      * ``w_scale``: 0-d float32 — the frozen weight quantization scale (the
        ``max |w|`` reduction, paid once at programming time).
      * ``x_scale``: 0-d float32 or None — frozen input scale; None keeps
        input quantization dynamic (per-call ``max(x)``), exactly matching
        the unprogrammed path.
      * ``g_spare``: (S, K, B) float32 programmed spare-column cells, or
        None when the device provisions no repair (``device.repair``).
        ``g_eff`` already holds the *repaired* layout (spares scattered into
        victim positions at programming time — zero steady-state overhead);
        the spare block plus ``out_gather`` are the explicit hardware
        record: the redundant columns as programmed and the column-mux
        routing table.
      * ``out_gather``: (N,) int32 or None — physical column serving each
        logical output (j, or N + b for repaired columns).

    A *stacked* artifact (from a ``(L, K, N)`` scan-stacked parameter leaf)
    carries a leading layer axis on every array; ``jax.lax.scan`` /
    ``tree.map(lambda a: a[i])`` slice it back to a servable per-layer
    artifact (``models.model._run_stage`` does exactly this).

    Static aux (hashable; part of the jit cache key): ``spec`` — the
    layer-scaled ``CrossbarSpec`` (``drop_lsb`` already chosen for this K);
    ``adc_cfg`` / ``fast`` — which kernel path serves this artifact;
    ``report`` — optional write-verify ``ProgramReport``; ``repair`` —
    optional ``repair.RepairReport`` (tuples of them for stacked artifacts).
    """

    w_codes: jnp.ndarray
    g_eff: Optional[jnp.ndarray]
    w_colsum: jnp.ndarray
    w_scale: jnp.ndarray
    x_scale: Optional[jnp.ndarray]
    spec: CrossbarSpec
    adc_cfg: Optional[ADCConfig] = None
    fast: bool = True
    report: Optional[Any] = None
    g_spare: Optional[jnp.ndarray] = None
    out_gather: Optional[jnp.ndarray] = None
    repair: Optional[Any] = None

    @property
    def noisy(self) -> bool:
        return self.g_eff is not None

    @property
    def stacked(self) -> bool:
        return self.w_codes.ndim == 3

    @property
    def shape(self) -> Tuple[int, ...]:
        return tuple(self.w_codes.shape)

    @property
    def qp(self) -> QuantParams:
        """Static view of the frozen quantization scales (introspection)."""
        if self.stacked:
            raise ValueError(
                "stacked artifact holds per-layer scales: use art.layer(i).qp"
            )
        return QuantParams(
            x_scale=(float(self.x_scale) if self.x_scale is not None else 0.0),
            w_scale=float(self.w_scale),
        )

    def layer(self, i: int) -> "ProgrammedLinear":
        """Slice one layer out of a stacked artifact."""
        assert self.stacked, "layer() only applies to stacked artifacts"
        return jax.tree.map(lambda a: a[i], self)

    def tree_flatten(self):
        children = (
            self.w_codes, self.g_eff, self.w_colsum, self.w_scale, self.x_scale,
            self.g_spare, self.out_gather,
        )
        aux = (self.spec, self.adc_cfg, self.fast, self.report, self.repair)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        w_codes, g_eff, w_colsum, w_scale, x_scale, g_spare, out_gather = children
        spec, adc_cfg, fast, report, repair = aux
        return cls(
            w_codes, g_eff, w_colsum, w_scale, x_scale, spec, adc_cfg, fast,
            report, g_spare=g_spare, out_gather=out_gather, repair=repair,
        )


def program_layer(
    w: jnp.ndarray,
    spec: CrossbarSpec = DEFAULT_SPEC,
    device: Optional[dm.DeviceConfig] = None,
    adc_cfg: Optional[ADCConfig] = SAFE_ADAPTIVE,
    *,
    x_scale: Optional[float] = None,
    w_scale: Optional[float] = None,
    fast: bool = True,
    with_report: bool = False,
) -> ProgrammedLinear:
    """Compile one (K, N) — or scan-stacked (L, K, N) — float weight matrix.

    This is the *programming-time* entry point — it runs every expensive,
    weight-only stage exactly once: the ``max |w|`` scale reduction, weight
    quantization, the device fault draw + write-verify pulse loop + read
    path (``effective_cell_codes``), and the correction column sums.  It is
    deterministic in (w, spec, device): programming twice yields the same
    chip, bit for bit, as the old program-every-call path drew per call.

    ``x_scale=None`` keeps input quantization dynamic (per-call ``max(x)``),
    matching the unprogrammed path exactly; pass a calibrated scale for
    fully static serving.  ``with_report=True`` routes programming through
    ``program.write_verify`` for convergence metadata (bit-identical cells).
    """
    w = jnp.asarray(w, jnp.float32)
    if w.ndim == 3:  # scan-stacked (L, K, N): compile per layer, stack
        parts = [
            program_layer(
                w[i], spec, device, adc_cfg, x_scale=x_scale, w_scale=w_scale,
                fast=fast, with_report=with_report,
            )
            for i in range(w.shape[0])
        ]
        reports = tuple(p.report for p in parts)
        repairs = tuple(p.repair for p in parts)
        # per-layer reports differ, which would make the tree structures
        # unequal — strip them before stacking, reattach as tuples
        parts = [dataclasses.replace(p, report=None, repair=None) for p in parts]
        out = jax.tree.map(lambda *xs: jnp.stack(xs), *parts)
        return dataclasses.replace(
            out,
            report=(reports if any(r is not None for r in reports) else None),
            repair=(repairs if any(r is not None for r in repairs) else None),
        )
    spec = layer_scaled_spec(spec, w.shape[0])
    if w_scale is None:
        # kept as a 0-d array so the steady-state dequantize is op-for-op
        # identical to the per-call path's traced scale
        w_scale_a = jnp.maximum(jnp.max(jnp.abs(w)), 1e-9) / (
            (1 << (spec.weight_bits - 1)) - 1
        )
    else:
        w_scale_a = jnp.asarray(w_scale, jnp.float32)
    wq = quantize_weight(w, spec, w_scale_a)
    w_colsum = jnp.sum(w, axis=0)
    g_eff = None
    g_spare = None
    out_gather = None
    report = None
    repair_rep = None
    if device is not None and not device.is_ideal:
        wb = wq + spec.weight_bias
        # fault-aware spare-column repair (device.repair): remap the worst
        # fault-afflicted columns into programmed spares and bake the
        # repaired layout into g_eff — steady-state calls pay nothing
        from repro.device import repair as repair_mod

        if with_report:
            target = dm.target_cell_codes(wb, spec)
            tag = dm._slab_tag(wb)
            masks = dm.fault_masks(device, target.shape, tag)
            g, report = write_verify(
                wb, spec, device, target=target, tag=tag, masks=masks
            )
            g_eff = dm.read_effective_codes(g, spec, device)
            plan = repair_mod.plan_repair(
                wb, spec, device, target=target, tag=tag, primary_masks=masks
            )
            g_eff = repair_mod.apply_repair(g_eff, plan)
        else:
            g_eff, plan = repair_mod.repaired_effective_cells(wb, spec, device)
        if plan is not None:
            g_spare = plan.g_spare
            out_gather = plan.out_gather
            repair_rep = repair_mod.repair_report(plan)
    return ProgrammedLinear(
        w_codes=wq, g_eff=g_eff, w_colsum=w_colsum,
        w_scale=w_scale_a,
        x_scale=(jnp.asarray(x_scale, jnp.float32) if x_scale is not None else None),
        g_spare=g_spare, out_gather=out_gather,
        spec=spec, adc_cfg=adc_cfg, fast=fast, report=report, repair=repair_rep,
    )


def programmed_matmul(
    x: jnp.ndarray,
    art: ProgrammedLinear,
    interpret: Optional[bool] = None,
    skip_zero_planes: bool = True,
) -> jnp.ndarray:
    """Steady-state float crossbar matmul against a programmed artifact.

    The entire inference-time path: input quantization -> Pallas kernel ->
    dequantize — no weight reductions, no fault redraw.  Bit-identical to
    ``kernels.ops.crossbar_matmul(x, w, device=...)`` with the same
    quantization scales, but the programming pipeline has been amortized
    away, and repeated calls reuse the *same* programmed chip
    (self-consistent noise) instead of redrawing it.  ``x`` must be
    non-negative (see ``programmed_linear`` for the offset-encoded form).

    Deliberately *not* wrapped in an extra jit: the elementwise stages
    mirror ``crossbar_matmul`` op-for-op (XLA's scalar-chain reassociation
    inside a fused jit can perturb the dequantize product by 1 ULP,
    breaking the bit-identity guarantee vs the program-every-call path);
    the heavy kernel call is jitted already, and under an outer jit
    everything fuses anyway.
    """
    from repro.kernels.crossbar_vmm import crossbar_vmm_pallas
    from repro.kernels.noisy_vmm import noisy_vmm_pallas

    if art.stacked:
        raise ValueError(
            "stacked artifact: slice one layer first (art.layer(i), or let "
            "models.model._run_stage scan over it)"
        )
    if interpret is None:
        from repro.kernels.ops import _auto_interpret

        interpret = _auto_interpret()
    spec = art.spec
    if art.x_scale is not None:
        x_scale = art.x_scale
    else:
        x_scale = jnp.maximum(jnp.max(x), 1e-9) / ((1 << spec.input_bits) - 1)
    xq = quantize_input(x, spec, x_scale)
    if art.g_eff is not None:
        yq = noisy_vmm_pallas(
            xq, art.g_eff, spec, adc_cfg=art.adc_cfg, interpret=interpret,
            skip_zero_planes=skip_zero_planes,
        )
    elif art.fast:
        yq = crossbar_vmm_pallas(
            xq, art.w_codes, spec, adc_cfg=None, fast=True, interpret=interpret,
            skip_zero_planes=skip_zero_planes,
        )
    else:
        yq = crossbar_vmm_pallas(
            xq, art.w_codes, spec, adc_cfg=art.adc_cfg, interpret=interpret,
            skip_zero_planes=skip_zero_planes,
        )
    return yq.astype(jnp.float32) * (x_scale * art.w_scale * (2.0 ** spec.drop_lsb))


def programmed_linear(
    x: jnp.ndarray,
    art: ProgrammedLinear,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Signed-activation ``x @ w`` against a programmed artifact.

    The offset-encoding dance of ``models.layers.crossbar_linear`` — shift
    activations non-negative, run the unsigned datapath, correct digitally
    with the weight column sums — except the column sums come precomputed
    from the artifact (written once at programming time, as real hardware
    does) instead of a per-call ``sum(w, axis=0)`` reduction.
    """
    shift = jnp.min(x)
    xs = (x - shift).astype(jnp.float32)
    y = programmed_matmul(xs, art, interpret=interpret)
    return y + shift.astype(jnp.float32) * art.w_colsum


# ---------------------------------------------------------------------------
# Whole-model compilation + artifact lookup (eager and under jit)
# ---------------------------------------------------------------------------

_BIND = threading.local()  # .maps: list of {id(param leaf) -> ProgrammedLinear}


def _id_map_of(params: Any, artifacts: Any) -> Dict[int, ProgrammedLinear]:
    """Position-exact {id(param leaf) -> artifact}: flatten params, align the
    artifact tree to the same structure (None where not compiled), zip."""
    flat_p, treedef_p = jax.tree_util.tree_flatten(params)
    flat_a = treedef_p.flatten_up_to(artifacts)
    out: Dict[int, ProgrammedLinear] = {}
    for leaf, art in zip(flat_p, flat_a):
        if isinstance(art, ProgrammedLinear):
            out[id(leaf)] = art
    return out


@contextlib.contextmanager
def bind_artifacts(params: Any, artifacts: Any):
    """Associate a (sub)tree of artifacts with congruent parameter leaves
    for the dynamic scope.  Works eagerly and at ``jit``/``scan`` trace
    time: the leaves may be tracers, and the map built here routes each
    traced weight to its (closure-constant or traced) artifact — this is
    how scan-stacked layers bind their per-iteration parameter slices to
    the matching per-iteration artifact slices inside the scan body."""
    if artifacts is None:
        yield
        return
    m = _id_map_of(params, artifacts)
    stack = getattr(_BIND, "maps", None)
    if stack is None:
        stack = _BIND.maps = []
    stack.append(m)
    try:
        yield
    finally:
        stack.pop()


def active_artifact_for(w: jnp.ndarray) -> Optional[ProgrammedLinear]:
    """Artifact bound to this exact parameter object, if any.

    Consulted by ``crossbar_linear``.  Lookup is by object identity — the
    leaf of the params pytree the model was compiled from (eager), or the
    tracer standing for it inside a ``bind_artifacts`` scope (jit/scan).
    A shape guard protects against id reuse after garbage collection; a
    stacked artifact never serves a 2-D weight directly.
    """
    for m in reversed(getattr(_BIND, "maps", [])):
        art = m.get(id(w))
        if art is not None and not art.stacked and art.shape == tuple(w.shape):
            return art
    return None


# The projection leaves routed through models.layers.crossbar_linear — the
# call sites that can consume an artifact: attention q/k/v/o and the MLA kv
# down-projection, the dense-MLP wi/wo, and the untied LM head.  (MoE expert
# stacks are (L, E, dm, ff) after layer stacking — 4-D, rejected by the
# ndim guard below — and a tied LM head multiplies a per-call transpose of
# the embedding table, which has no stable leaf identity to bind.)
_CROSSBAR_CONSUMERS = ("wq", "wk", "wv", "wo", "w_kv_down", "wi", "head")


def _path_names(path: Tuple[Any, ...]) -> Tuple[str, ...]:
    return tuple(str(getattr(p, "key", getattr(p, "name", ""))) for p in path)


def _matmul_leaf(path: Tuple[Any, ...], leaf: Any) -> bool:
    """Default predicate: which param leaves go onto crossbars.

    Allowlist of the projection names ``crossbar_linear`` actually serves
    (attention q/k/v/o, the MLA kv down-projection, dense-MLP wi/wo, the
    untied LM head), as 2-D matrices or 3-D scan-stacked ``(L, K, N)``.  An
    allowlist — rather than excluding known non-matmuls — keeps stacked
    per-layer *vectors* (ssm ``conv_b``, ``D_skip``: ``(L, din)`` after
    stacking, indistinguishable from a small weight matrix by shape alone)
    from being miscompiled into unusable artifacts, and avoids paying
    write-verify programming + 8x ``g_eff`` memory for leaves no crossbar
    call site consumes.  Override with ``leaf_filter`` for exotic layouts.
    """
    if not isinstance(leaf, jnp.ndarray) or leaf.ndim not in (2, 3):
        return False
    if not jnp.issubdtype(leaf.dtype, jnp.floating):
        return False
    names = _path_names(path)
    return bool(names) and names[-1] in _CROSSBAR_CONSUMERS


def stacked_only(artifacts: Any) -> Any:
    """Prune non-stacked artifacts from a stage subtree.

    A stage's layer scan slices every artifact array on a leading layer
    axis; a 2-D artifact (scalar ``w_scale``) inside a stacked-stage
    subtree can never be sliced that way and would crash the scan — drop
    it (the weight simply falls back to the per-call path).
    """
    return jax.tree_util.tree_map(
        lambda a: a if isinstance(a, ProgrammedLinear) and a.stacked else None,
        artifacts,
        is_leaf=lambda x: isinstance(x, ProgrammedLinear),
    )


class ProgrammedModel:
    """A pytree of ProgrammedLinear artifacts mirroring a params pytree.

    Holds the compiled chips plus an identity map from the *build-time*
    parameter leaves, so eager forwards resolve immediately; ``bind(params)``
    pushes a temporary map for a different-but-congruent params tree — in
    particular the tracers seen while ``jax.jit`` traces a forward pass.
    """

    def __init__(self, artifacts: Any, params: Optional[Any] = None):
        self.artifacts = artifacts
        self._build_map: Dict[int, ProgrammedLinear] = (
            _id_map_of(params, artifacts) if params is not None else {}
        )
        self._keepalive = params  # ids stay valid while the model lives

    def bind(self, params: Any):
        """Associate artifacts with ``params``' leaves for the dynamic scope
        (see ``bind_artifacts``); use around jitted forwards so traced
        weights resolve to their artifacts."""
        return bind_artifacts(params, self.artifacts)

    def subtree(self, key: str) -> Any:
        """Artifact subtree for one top-level params key (e.g. "stage0")."""
        try:
            return self.artifacts[key]
        except (KeyError, TypeError, IndexError):
            return None

    def lookup(self, w: jnp.ndarray) -> Optional[ProgrammedLinear]:
        art = active_artifact_for(w)
        if art is not None:
            return art
        art = self._build_map.get(id(w))
        if art is not None and not art.stacked and art.shape == tuple(w.shape):
            return art
        return None

    @property
    def n_compiled(self) -> int:
        return sum(
            1
            for a in jax.tree_util.tree_leaves(
                self.artifacts, is_leaf=lambda x: isinstance(x, ProgrammedLinear)
            )
            if isinstance(a, ProgrammedLinear)
        )

    def reports(self) -> Dict[str, ProgramReport]:
        """Path -> write-verify report for every compiled leaf that has one."""
        out: Dict[str, ProgramReport] = {}
        flat, _ = jax.tree_util.tree_flatten_with_path(
            self.artifacts, is_leaf=lambda x: isinstance(x, ProgrammedLinear)
        )
        for path, art in flat:
            if isinstance(art, ProgrammedLinear) and art.report is not None:
                out[jax.tree_util.keystr(path)] = art.report
        return out

    def repair_reports(self) -> Dict[str, Any]:
        """Path -> spare-column ``RepairReport`` (or per-layer tuple for
        stacked leaves) for every compiled leaf that was repaired."""
        out: Dict[str, Any] = {}
        flat, _ = jax.tree_util.tree_flatten_with_path(
            self.artifacts, is_leaf=lambda x: isinstance(x, ProgrammedLinear)
        )
        for path, art in flat:
            if isinstance(art, ProgrammedLinear) and art.repair is not None:
                out[jax.tree_util.keystr(path)] = art.repair
        return out


def program_model(
    params: Any,
    spec: CrossbarSpec = DEFAULT_SPEC,
    device: Optional[dm.DeviceConfig] = None,
    adc_cfg: Optional[ADCConfig] = SAFE_ADAPTIVE,
    *,
    fast: bool = True,
    with_report: bool = False,
    leaf_filter: Optional[Callable[[Tuple[Any, ...], Any], bool]] = None,
) -> ProgrammedModel:
    """Walk a param pytree and compile every matmul-shaped leaf.

    The whole-model programming pass: one ``program_layer`` per selected
    leaf, so an inference run (or a serving engine) works against a single
    fixed programmed chip.  ``leaf_filter(path, leaf) -> bool`` overrides
    the default 2-D-float-non-embedding predicate.
    """
    pred = leaf_filter if leaf_filter is not None else _matmul_leaf

    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    arts = [
        program_layer(
            leaf, spec, device, adc_cfg, fast=fast, with_report=with_report
        )
        if pred(path, leaf)
        else None
        for path, leaf in flat
    ]
    artifacts = jax.tree_util.tree_unflatten(treedef, arts)
    return ProgrammedModel(artifacts, params=params)
