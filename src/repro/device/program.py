"""Write-verify programming of a weight slab into noisy memristor cells.

Real crossbar deployments do not open-loop write a conductance and hope: the
programmer pulses a cell, reads it back, and re-pulses until the read-back
code is within tolerance of the target (or a pulse budget is exhausted —
stuck cells never converge).  ``models.programmed_conductance`` implements
the trace-safe fixed-iteration loop used inside jitted inference; this
module wraps the same per-pulse keys (``models.program_attempt``) with
host-side diagnostics so calibration quality is observable: per-iteration
error, converged fraction, and the residual programming error the inference
path will see.  The spare-column block of ``device.repair`` is programmed
through the identical pulse pipeline under its own stage keys.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.crossbar import CrossbarSpec, DEFAULT_SPEC
from repro.device import models as dm


@dataclasses.dataclass(frozen=True)
class ProgramReport:
    """Host-side summary of one write-verify calibration run.

    Errors are in cell-code units (1.0 == one conductance level); a mean
    well under ``write_verify_tol`` with high ``converged_frac`` means the
    residual inference error is dominated by read-time effects (drift, IR
    drop) and hard faults rather than programming noise.
    """

    iterations: int
    converged_frac: float
    mean_abs_error: float
    max_abs_error: float
    stuck_frac: float
    per_iter_mean_error: Tuple[float, ...]


def write_verify(
    w_codes_biased: jnp.ndarray,
    spec: CrossbarSpec = DEFAULT_SPEC,
    cfg: dm.DeviceConfig = dm.IDEAL_DEVICE,
    *,
    target=None,
    tag=None,
    masks=None,
) -> Tuple[jnp.ndarray, ProgramReport]:
    """Program ``(K, N)`` biased weight codes; return conductances + report.

    Uses the same stage keys as ``models.programmed_conductance`` (pulse
    ``i`` draws ``fold_in(program_key, i)``), so the returned conductance
    array is bit-identical to what the jitted inference path programs — the
    report is pure added observability.  Early-stops once every non-stuck
    cell verifies, which is why this variant is host-only.

    ``target`` / ``tag`` / ``masks`` accept the standard pipeline's
    intermediates when the caller (``programmed.program_layer``) already
    derived them for the repair planner; they MUST match what this function
    would compute itself.
    """
    if target is None:
        target = dm.target_cell_codes(w_codes_biased, spec)
    target_g = dm.conductance_of_codes(target, spec, cfg)
    if tag is None:
        tag = dm._slab_tag(w_codes_biased)
    if masks is None:
        masks = dm.fault_masks(cfg, target.shape, tag)
    stuck = masks[0] | masks[1]
    key = dm._stage_key(cfg, dm.STAGE_PROGRAM, tag)
    iters = max(1, cfg.write_verify_iters)

    g = dm.program_attempt(target_g, masks, cfg, key, 0)
    per_iter = []
    done = None
    used = iters
    for i in range(iters):
        if i > 0:
            attempt = dm.program_attempt(target_g, masks, cfg, key, i)
            g = jnp.where(done, g, attempt)
        err = jnp.abs(dm.codes_of_conductance(g, spec, cfg) - target)
        done = err <= cfg.write_verify_tol
        per_iter.append(float(jnp.mean(err)))
        if bool(jnp.all(done | stuck)):
            used = i + 1
            break

    err = np.asarray(jnp.abs(dm.codes_of_conductance(g, spec, cfg) - target))
    done_np = np.asarray(done)
    report = ProgramReport(
        iterations=used,
        converged_frac=float(done_np.mean()),
        mean_abs_error=float(err.mean()),
        max_abs_error=float(err.max()),
        stuck_frac=float(np.asarray(stuck).mean()),
        per_iter_mean_error=tuple(per_iter),
    )
    return g, report
