"""Sharded, elastic checkpointing.

Layout: ``<dir>/step_<n>/``
  * ``manifest.json`` — tree structure, shapes, dtypes, step, metadata
  * ``<leaf_path>.npy`` — one file per leaf (host-local shard in multi-host;
    full array in single-process)

Properties needed at scale (DESIGN.md §4):
  * **atomic** — written to ``step_<n>.tmp`` then renamed, so a killed job
    never leaves a half checkpoint that restore would pick up;
  * **elastic** — restore only needs the manifest + arrays; the caller
    ``device_put``s onto *any* mesh/sharding, so a job can resume on a
    different topology (tested in tests/test_checkpoint.py);
  * **async** — ``CheckpointManager.save_async`` snapshots to host memory
    synchronously (cheap) and writes files on a background thread, keeping
    the accelerator busy;
  * **bounded** — keeps the newest ``keep`` checkpoints.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten_with_paths(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        flat[key] = leaf
    return flat


def _unflatten_from_paths(tree_like, flat: Dict[str, Any]):
    paths = jax.tree_util.tree_flatten_with_path(tree_like)[0]
    treedef = jax.tree_util.tree_structure(tree_like)
    leaves = []
    for path, _ in paths:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p))))
            for p in path
        )
        leaves.append(flat[key])
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save_checkpoint(directory: str, step: int, tree, metadata: Optional[dict] = None):
    """Synchronous atomic checkpoint write."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:09d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten_with_paths(tree)
    manifest = {"step": step, "metadata": metadata or {}, "leaves": {}}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        fname = key.replace("/", "__") + ".npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"][key] = {
            "file": fname,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
    ]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: Optional[int], tree_like, shardings=None):
    """Restore onto an arbitrary sharding layout (elastic resume).

    ``tree_like`` provides the pytree structure; ``shardings`` (optional,
    same structure) places each leaf via ``jax.device_put``.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    d = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    flat = {}
    for key, info in manifest["leaves"].items():
        arr = np.load(os.path.join(d, info["file"]))
        flat[key] = arr
    tree = _unflatten_from_paths(tree_like, flat)
    if shardings is not None:
        tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree, shardings)
    return tree, manifest["step"], manifest["metadata"]


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._pending = None
        self._lock = threading.Lock()

    def save_async(self, step: int, tree, metadata: Optional[dict] = None):
        """Snapshot to host memory now; write files in the background."""
        host_tree = jax.tree.map(lambda l: np.asarray(jax.device_get(l)), tree)
        self.wait()
        self._pending = self._pool.submit(self._write, step, host_tree, metadata)

    def _write(self, step, host_tree, metadata):
        save_checkpoint(self.directory, step, host_tree, metadata)
        self._gc()

    def wait(self):
        with self._lock:
            if self._pending is not None:
                self._pending.result()
                self._pending = None

    def _gc(self):
        steps = sorted(
            int(d.split("_")[1])
            for d in os.listdir(self.directory)
            if d.startswith("step_") and not d.endswith(".tmp")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:09d}"), ignore_errors=True)

    def restore_latest(self, tree_like, shardings=None):
        return restore_checkpoint(self.directory, None, tree_like, shardings)
