"""Sharded, elastic checkpointing.

Layout: ``<dir>/step_<n>/``
  * ``manifest.json`` — tree structure, shapes, dtypes, step, metadata
  * ``<leaf_path>.npy`` — one file per leaf (host-local shard in multi-host;
    full array in single-process)

Properties needed at scale (DESIGN.md §4):
  * **atomic** — written to ``step_<n>.tmp`` then renamed, so a killed job
    never leaves a half checkpoint that restore would pick up;
  * **elastic** — restore only needs the manifest + arrays; the caller
    ``device_put``s onto *any* mesh/sharding, so a job can resume on a
    different topology (tested in tests/test_checkpoint.py);
  * **async** — ``CheckpointManager.save_async`` snapshots to host memory
    synchronously (cheap) and writes files on a background thread, keeping
    the accelerator busy;
  * **bounded** — keeps the newest ``keep`` checkpoints.

Programmed-crossbar artifacts: ``save_programmed`` / ``restore_programmed``
persist a ``repro.device.programmed.ProgrammedModel`` — the *chip*, not the
weights: effective cell codes (fault fields and all), frozen quantization
scales, correction column sums, spare blocks + gather tables, and the
write-verify / repair reports.  The store is keyed by the same canonical
parameter names the binding layer uses ("stage0/b0/mixer/wq"), so a
restored model serves any congruent params tree; a serving restart becomes
file I/O instead of a full write-verify reprogramming pass, and restores
the *same* chip bit-for-bit (``ServingEngine(restore_artifacts=...)``).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, Optional

import jax
import numpy as np

# the one canonical tree-path -> "a/b/c" key derivation, shared with the
# artifact-binding layer so weight-checkpoint keys and artifact-store keys
# can never diverge for the same pytree
from repro.device.programmed import join_path as _join_path


def _flatten_with_paths(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        flat[_join_path(path)] = leaf
    return flat


def _unflatten_from_paths(tree_like, flat: Dict[str, Any]):
    paths = jax.tree_util.tree_flatten_with_path(tree_like)[0]
    treedef = jax.tree_util.tree_structure(tree_like)
    leaves = [flat[_join_path(path)] for path, _ in paths]
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save_checkpoint(directory: str, step: int, tree, metadata: Optional[dict] = None):
    """Synchronous atomic checkpoint write."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:09d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten_with_paths(tree)
    manifest = {"step": step, "metadata": metadata or {}, "leaves": {}}
    for key, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        fname = key.replace("/", "__") + ".npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"][key] = {
            "file": fname,
            "shape": list(arr.shape),
            "dtype": str(arr.dtype),
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
    ]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, step: Optional[int], tree_like, shardings=None):
    """Restore onto an arbitrary sharding layout (elastic resume).

    ``tree_like`` provides the pytree structure; ``shardings`` (optional,
    same structure) places each leaf via ``jax.device_put``.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    d = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    flat = {}
    for key, info in manifest["leaves"].items():
        arr = np.load(os.path.join(d, info["file"]))
        flat[key] = arr
    tree = _unflatten_from_paths(tree_like, flat)
    if shardings is not None:
        tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree, shardings)
    return tree, manifest["step"], manifest["metadata"]


# ---------------------------------------------------------------------------
# Programmed-crossbar artifact store (name-keyed chips)
# ---------------------------------------------------------------------------

def _encode_aux(obj):
    """JSON-encode artifact aux metadata: None, report dataclasses, and the
    (possibly nested) per-layer/per-expert tuples stacked artifacts carry."""
    import dataclasses as dc

    if obj is None:
        return None
    if isinstance(obj, tuple):
        return {"__kind__": "tuple", "items": [_encode_aux(o) for o in obj]}
    if dc.is_dataclass(obj):
        return {"__kind__": type(obj).__name__, **dc.asdict(obj)}
    raise TypeError(f"unserializable artifact aux: {type(obj)!r}")


def _decode_aux(obj):
    if obj is None:
        return None
    kind = obj["__kind__"]
    if kind == "tuple":
        return tuple(_decode_aux(o) for o in obj["items"])
    from repro.device.program import ProgramReport
    from repro.device.repair import RepairReport

    fields = {k: v for k, v in obj.items() if k != "__kind__"}
    if kind == "ProgramReport":
        fields["per_iter_mean_error"] = tuple(fields["per_iter_mean_error"])
        return ProgramReport(**fields)
    if kind == "RepairReport":
        fields["repaired_cols"] = tuple(fields["repaired_cols"])
        return RepairReport(**fields)
    raise ValueError(f"unknown artifact aux kind: {kind!r}")


def _decode_plan(obj: dict):
    """Rebuild a ``core.planner.LayerPlan`` from its manifest dict."""
    from repro.core.planner import LayerPlan

    return LayerPlan(**obj)


def _encode_pspec(spec) -> list:
    """JSON-encode a PartitionSpec's entries (None / str / tuple-of-str)."""
    return [list(e) if isinstance(e, tuple) else e for e in spec]


def _decode_pspec(entries):
    from jax.sharding import PartitionSpec as P

    return P(*[tuple(e) if isinstance(e, list) else e for e in entries])


def _artifact_shardings(art) -> Optional[Dict[str, list]]:
    """{field: encoded spec} for every array leaf carrying a non-trivial
    NamedSharding — how the chip was deployed across the mesh.  None when
    the artifact is unplaced/replicated (single-device chips)."""
    from jax.sharding import NamedSharding

    from repro.device.programmed import ARTIFACT_ARRAY_FIELDS

    out = {}
    for f in ARTIFACT_ARRAY_FIELDS:
        v = getattr(art, f)
        sh = getattr(v, "sharding", None) if v is not None else None
        if isinstance(sh, NamedSharding) and any(e is not None for e in sh.spec):
            out[f] = _encode_pspec(sh.spec)
    return out or None


PROGRAMMED_SLOTS = ("A", "B")


def _programmed_dir(directory: str, slot: Optional[str] = None) -> str:
    """Store path for a slot: ``programmed`` (unslotted, the pre-lifecycle
    layout) or ``programmed.slotA`` / ``programmed.slotB``."""
    if slot is None:
        return os.path.join(directory, "programmed")
    if slot not in PROGRAMMED_SLOTS:
        raise ValueError(f"slot must be one of {PROGRAMMED_SLOTS}, got {slot!r}")
    return os.path.join(directory, f"programmed.slot{slot}")


def _active_pointer(directory: str) -> str:
    return os.path.join(directory, "programmed.ACTIVE")


def active_slot(directory: str) -> Optional[str]:
    """The slot the ACTIVE pointer names, or None (unslotted store)."""
    try:
        with open(_active_pointer(directory)) as f:
            slot = f.read().strip()
    except FileNotFoundError:
        return None
    if slot not in PROGRAMMED_SLOTS:
        raise ValueError(f"corrupt ACTIVE pointer: {slot!r}")
    return slot


def swap_active(directory: str, slot: str) -> str:
    """Atomically point the store at ``slot`` (the hot-swap commit point).

    The pointer is one short file, replaced with ``os.replace`` — readers
    see either the old slot or the new one, never a torn state, and the
    inactive slot's files are untouched (the refresh that wrote them can be
    rolled back by pointing the other way).
    """
    if slot not in PROGRAMMED_SLOTS:
        raise ValueError(f"slot must be one of {PROGRAMMED_SLOTS}, got {slot!r}")
    if not os.path.isfile(
        os.path.join(_programmed_dir(directory, slot), "manifest.json")
    ):
        raise FileNotFoundError(
            f"slot {slot} has no programmed store in {directory} — "
            "save_programmed(..., slot=...) first"
        )
    ptr = _active_pointer(directory)
    tmp = ptr + ".tmp"
    with open(tmp, "w") as f:
        f.write(slot)
    os.replace(tmp, ptr)
    return slot


def save_programmed(
    directory: str,
    prog,
    metadata: Optional[dict] = None,
    slot: Optional[str] = None,
) -> str:
    """Atomically persist a ``ProgrammedModel`` under ``<dir>/programmed/``.

    One ``.npz`` per artifact (every non-None array leaf, exact dtypes) plus
    a manifest holding the name-keyed static aux: ``CrossbarSpec``,
    ``ADCConfig``, the kernel-path flag, the write-verify/repair reports,
    the lifecycle state (the programming ``DeviceConfig`` and the
    chip's ``t_service_s`` service clock), and — for planned chips —
    each layer's compile decision (``core.planner.LayerPlan``: datapath,
    ADC schedule, spare budget).  Restoring yields a
    bit-identical chip — same effective cells, same fault realizations,
    same routing tables, same age.

    Mesh-sharded chips (``device.programmed.shard_artifacts``) additionally
    record each array leaf's PartitionSpec, so ``restore_programmed(...,
    mesh=)`` re-places every shard where the serving deployment had it —
    the per-rank store round-trips through one canonical global file set
    (each rank's slice is a view of the saved array under the recorded
    spec; single-host saves stay fully addressable).

    ``slot``: write into the double-buffered ``programmed.slotA`` /
    ``programmed.slotB`` layout instead of the unslotted path.  A refresh
    reprograms into the *inactive* slot while the active one keeps serving,
    then commits with ``swap_active`` — the store is never without a
    complete, servable chip.
    """
    import dataclasses as dc

    from repro.device.programmed import ARTIFACT_ARRAY_FIELDS

    os.makedirs(directory, exist_ok=True)
    final = _programmed_dir(directory, slot)
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    manifest = {"schema": 1, "metadata": metadata or {}, "artifacts": {}}
    for name, art in prog.by_name.items():
        # injective escaping ("_" first, then "/"): distinct names can never
        # collide onto one file — "a/b" -> "a__b" but "a__b" -> "a_u_ub"
        fname = name.replace("_", "_u").replace("/", "__") + ".npz"
        arrays = {
            f: np.asarray(jax.device_get(getattr(art, f)))
            for f in ARTIFACT_ARRAY_FIELDS
            if getattr(art, f) is not None
        }
        np.savez(os.path.join(tmp, fname), **arrays)
        manifest["artifacts"][name] = {
            "file": fname,
            "spec": dc.asdict(art.spec),
            "adc_cfg": dc.asdict(art.adc_cfg) if art.adc_cfg is not None else None,
            "fast": bool(art.fast),
            "report": _encode_aux(art.report),
            "repair": _encode_aux(art.repair),
            "sharding": _artifact_shardings(art),
            "device": (dc.asdict(art.device) if art.device is not None else None),
            "t_service_s": float(art.t_service_s),
            "plan": (dc.asdict(art.plan) if art.plan is not None else None),
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    # swap, don't delete-then-rename: a crash between those two steps would
    # lose the old store too, and the next restart would have to pay the
    # full write-verify reprogramming this store exists to avoid
    old = final + ".old"
    if os.path.exists(old):
        shutil.rmtree(old)
    if os.path.exists(final):
        os.rename(final, old)
    os.rename(tmp, final)
    shutil.rmtree(old, ignore_errors=True)
    return final


def restore_programmed(directory: str, mesh=None, slot: Optional[str] = None):
    """Load a ``save_programmed`` store back into a ``ProgrammedModel``.

    The artifact tree is rebuilt as nested dicts from the canonical names,
    so stage subtrees ride the layer scan exactly as freshly programmed
    ones do; no parameter tree is needed — name-keyed binding resolves
    against whatever congruent params the model is served with.

    ``mesh``: re-place each array leaf with the PartitionSpec recorded at
    save time (specs whose axes the mesh lacks, or whose dims no longer
    divide, degrade to replicated per entry) — a serving restart on the
    deployment mesh restores the *sharded* chip directly, paying file I/O
    plus one device_put per shard instead of write-verify reprogramming.

    ``slot``: read a specific double-buffer slot.  Default (None) follows
    the ``ACTIVE`` pointer when one exists — a restart after a hot-swap
    refresh comes back up on the refreshed chip — and falls back to the
    unslotted pre-lifecycle layout otherwise.
    """
    import jax.numpy as jnp
    from jax.sharding import NamedSharding

    from repro.core.adc import ADCConfig
    from repro.core.crossbar import CrossbarSpec
    from repro.device.models import DeviceConfig
    from repro.device.programmed import (
        ProgrammedLinear,
        ProgrammedModel,
        dividing_pspec,
    )

    def _placed(arr, encoded_spec):
        if mesh is None or encoded_spec is None:
            return jnp.asarray(arr)
        # the same degrade-to-replicated rule placement used at save time
        # (device.programmed.dividing_pspec), so restore re-places shards
        # exactly where the deployment had them
        fixed = dividing_pspec(_decode_pspec(encoded_spec), arr.shape, mesh.shape)
        return jax.device_put(arr, NamedSharding(mesh, fixed))

    if slot is None:
        slot = active_slot(directory)
    if slot is not None:
        base = _programmed_dir(directory, slot)
        candidates = [base, base + ".tmp", base + ".old"]
    else:
        base = os.path.join(directory, "programmed")
        # a crash inside save_programmed's two-rename swap can leave the store
        # under ".tmp" (fully written — the manifest is the last file out — but
        # not yet renamed) or only under ".old" (previous chip renamed aside);
        # fall back in completeness order instead of forcing a reprogram
        candidates = [base, base + ".tmp", base + ".old", directory]
    d = next(
        (c for c in candidates if os.path.isfile(os.path.join(c, "manifest.json"))),
        None,
    )
    if d is None:
        raise FileNotFoundError(f"no programmed-artifact store in {directory}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    tree: Dict[str, Any] = {}
    for name, info in manifest["artifacts"].items():
        shardings = info.get("sharding") or {}
        with np.load(os.path.join(d, info["file"])) as z:
            arrays = {k: _placed(z[k], shardings.get(k)) for k in z.files}
        art = ProgrammedLinear(
            w_codes=arrays["w_codes"],
            g_eff=arrays.get("g_eff"),
            w_colsum=arrays["w_colsum"],
            w_scale=arrays["w_scale"],
            x_scale=arrays.get("x_scale"),
            spec=CrossbarSpec(**info["spec"]),
            adc_cfg=(
                ADCConfig(**info["adc_cfg"]) if info["adc_cfg"] is not None else None
            ),
            fast=bool(info["fast"]),
            report=_decode_aux(info["report"]),
            g_spare=arrays.get("g_spare"),
            out_gather=arrays.get("out_gather"),
            repair=_decode_aux(info["repair"]),
            comp_scale=arrays.get("comp_scale"),
            # tolerant decode: pre-lifecycle manifests carry neither key
            device=(
                DeviceConfig(**info["device"])
                if info.get("device") is not None
                else None
            ),
            t_service_s=float(info.get("t_service_s", 0.0)),
            # tolerant decode: pre-planner manifests carry no plan
            plan=(
                _decode_plan(info["plan"]) if info.get("plan") is not None else None
            ),
        )
        node = tree
        parts = name.split("/")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = art
    return ProgrammedModel(tree)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._pending = None
        self._lock = threading.Lock()

    def save_async(self, step: int, tree, metadata: Optional[dict] = None):
        """Snapshot to host memory now; write files in the background."""
        host_tree = jax.tree.map(lambda l: np.asarray(jax.device_get(l)), tree)
        self.wait()
        self._pending = self._pool.submit(self._write, step, host_tree, metadata)

    def _write(self, step, host_tree, metadata):
        save_checkpoint(self.directory, step, host_tree, metadata)
        self._gc()

    def wait(self):
        with self._lock:
            if self._pending is not None:
                self._pending.result()
                self._pending = None

    def _gc(self):
        steps = sorted(
            int(d.split("_")[1])
            for d in os.listdir(self.directory)
            if d.startswith("step_") and not d.endswith(".tmp")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:09d}"), ignore_errors=True)

    def restore_latest(self, tree_like, shardings=None):
        return restore_checkpoint(self.directory, None, tree_like, shardings)
