from repro.checkpoint.checkpoint import (  # noqa: F401
    CheckpointManager,
    PROGRAMMED_SLOTS,
    active_slot,
    save_checkpoint,
    restore_checkpoint,
    restore_programmed,
    save_programmed,
    swap_active,
    latest_step,
)
