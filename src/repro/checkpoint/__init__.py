from repro.checkpoint.checkpoint import (  # noqa: F401
    CheckpointManager,
    save_checkpoint,
    restore_checkpoint,
    restore_programmed,
    save_programmed,
    latest_step,
)
