"""Serving launcher: batched requests through the continuous-batching engine.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --reduced \
      --requests 8 --max-new 16 [--crossbar]

``--crossbar`` routes every projection through the Newton bit-sliced
crossbar datapath (the paper's technique as a serving feature; Pallas kernel
in interpret mode on CPU) and reports the analytic Newton-vs-ISAAC energy
estimate for the served tokens.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.configs.base import reduced as reduced_cfg
from repro.models import model as model_lib
from repro.models.layers import CrossbarMode, crossbar_mode
from repro.serving import ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--crossbar", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_cfg(cfg)
    params, _ = model_lib.init_model(jax.random.PRNGKey(args.seed), cfg)
    engine = ServingEngine(
        cfg, params, max_batch=args.max_batch, max_seq=args.max_seq,
        temperature=args.temperature, seed=args.seed,
    )
    rng = np.random.default_rng(args.seed)
    for i in range(args.requests):
        n = int(rng.integers(4, 48))
        engine.submit(rng.integers(0, cfg.vocab_size, size=n), max_new_tokens=args.max_new)

    mode = CrossbarMode(enabled=args.crossbar)
    t0 = time.perf_counter()
    with crossbar_mode(mode):
        reqs = engine.run_until_done()
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.generated) for r in reqs)
    print(f"[serve] {len(reqs)} requests, {total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens/dt:.1f} tok/s){' [crossbar datapath]' if args.crossbar else ''}")
    for r in reqs[:4]:
        print(f"  req{r.rid}: {r.generated[:12]}")

    if args.crossbar:
        from repro.core import arch as hw, energy as en, workloads as wl

        net = wl.lm_workload(cfg)
        newton = en.evaluate(net, hw.NEWTON_CHIP, policy="newton", strassen=True)
        isaac = en.evaluate(net, hw.ISAAC_CHIP, policy="isaac")
        print(f"[newton] serving energy estimate: {newton.energy_per_sample_j*1e6:.1f} uJ/token "
              f"(ISAAC baseline {isaac.energy_per_sample_j*1e6:.1f} uJ/token, "
              f"{isaac.energy_per_sample_j/newton.energy_per_sample_j:.2f}x)")


if __name__ == "__main__":
    main()
