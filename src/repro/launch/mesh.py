"""Device meshes.

``make_production_mesh`` builds the deployment topology: a 16x16
("data","model") pod, or 2x16x16 ("pod","data","model") for the two-pod
configuration.  Defined as functions so importing this module never touches
JAX device state (the dry-run sets the host-device-count flag first).
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == need:
        return jax.make_mesh(shape, axes)
    if len(devices) < need:
        raise RuntimeError(
            f"production mesh needs {need} devices, have {len(devices)} — "
            "run under launch/dryrun.py (it forces 512 host devices)"
        )
    return Mesh(np.asarray(devices[:need]).reshape(shape), axes)


def make_local_mesh(data: Optional[int] = None, model: int = 1) -> Mesh:
    """Small mesh over whatever devices exist (tests, examples)."""
    devices = jax.devices()
    n = len(devices)
    if data is None:
        data = n // model
    used = data * model
    return Mesh(np.asarray(devices[:used]).reshape(data, model), ("data", "model"))
