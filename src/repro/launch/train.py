"""Training launcher.

Runs real training (synthetic or memmap data) on whatever devices exist,
with the same sharding machinery the production mesh uses.  Example — the
(b) deliverable's end-to-end driver, ~100M-class model for a few hundred
steps:

  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
      --reduced --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Fault-tolerance in action: re-running the same command resumes from the
latest checkpoint (deterministic data => identical continuation); NaN steps
are skipped; straggler steps are flagged.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import reduced as reduced_cfg
from repro.data import make_dataset
from repro.launch import sharding as shlib
from repro.launch.mesh import make_local_mesh
from repro.models import model as model_lib
from repro.models.layers import use_mesh
from repro.optim import cosine_with_warmup, make_optimizer
from repro.train import TrainLoop, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true", help="smoke-size config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--data", default=None, help="memmap token file (int32)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_cfg(cfg)
    mesh = make_local_mesh(model=args.model_parallel)
    print(f"[train] arch={cfg.name} params={cfg.param_count()/1e6:.1f}M mesh={dict(mesh.shape)}")

    with use_mesh(mesh), mesh:
        params, axes = model_lib.init_model(jax.random.PRNGKey(args.seed), cfg)
        p_shard = shlib.param_shardings(
            jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params),
            axes, mesh, fsdp=cfg.fsdp,
        )
        params = jax.tree.map(jax.device_put, params, p_shard)

        opt = make_optimizer(
            cfg.optimizer, cosine_with_warmup(args.lr, args.steps // 10 + 1, args.steps)
        )
        opt_state = opt.init(params)
        step_fn = jax.jit(make_train_step(cfg, opt, microbatches=args.microbatches),
                          donate_argnums=(0, 1))

        ds = make_dataset(cfg, args.seq, args.batch, seed=args.seed, path=args.data)
        loop = TrainLoop(
            cfg, step_fn, ds,
            ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every, log_every=10,
        )
        params, opt_state, start = loop.maybe_resume(params, opt_state)
        params, opt_state = loop.run(params, opt_state, args.steps, start_step=start)
    print("[train] done")


if __name__ == "__main__":
    main()
