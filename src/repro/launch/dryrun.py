import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run: lower + compile every (arch x shape) on the production
meshes and extract the roofline inputs.

For each cell this script:
  1. builds the production mesh (16x16 "data","model"; or 2x16x16 with "pod"),
  2. constructs the step function for the shape kind:
       train_4k    -> train_step (grads + optimizer update, remat'd scan)
       prefill_32k -> prefill   (fills the KV/state caches)
       decode_*    -> decode_step (one token against a full cache)
  3. derives shardings for params / optimizer state / caches / batch from the
     logical-axis trees (launch/sharding.py) — no arrays are materialized
     (ShapeDtypeStruct end to end),
  4. ``jit(...).lower(...).compile()`` and records
     ``memory_analysis()`` (proves the layout fits),
     ``cost_analysis()``   (FLOPs / bytes for the roofline),
     collective byte counts parsed from the compiled HLO.

``--stage-repeats`` compiles reduced-depth variants (e.g. 1,1 and 2,2) used
by launch/roofline.py to undo XLA's count-while-body-once accounting.

Usage:
  python -m repro.launch.dryrun --arch smollm-360m --shape train_4k --mesh pod
  python -m repro.launch.dryrun --all --mesh pod --out-dir experiments/dryrun
"""
import argparse
import dataclasses
import json
import re
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ALL_ARCHS, SHAPES, get_config, shape_applicable
from repro.configs.base import ModelConfig, ShapeSpec, StageSpec
from repro.launch import sharding as shlib
from repro.launch.mesh import make_production_mesh
from repro.models import model as model_lib
from repro.models.layers import use_mesh
from repro.optim import cosine_with_warmup, make_optimizer
from repro.train.loop import make_train_step

KEY = jax.random.PRNGKey(0)


def with_stage_repeats(cfg: ModelConfig, repeats) -> ModelConfig:
    """Depth-reduced, *unrolled* variant for the cost extrapolation (XLA's
    HloCostAnalysis counts a while body once, so the variants must place
    every layer in the HLO)."""
    stages = tuple(
        StageSpec(kinds=s.kinds, repeats=r, moe=s.moe)
        for s, r in zip(cfg.stages, repeats)
    )
    return dataclasses.replace(
        cfg, stages=stages, n_layers=sum(s.n_layers for s in stages),
        scan_layers=False,
    )


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of this shape."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        if cfg.frontend == "embed":
            inputs = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)
        else:
            inputs = jax.ShapeDtypeStruct((B, S), jnp.int32)
        return {
            "inputs": inputs,
            "targets": jax.ShapeDtypeStruct((B, S), jnp.int32),
        }
    if shape.kind == "prefill":
        if cfg.frontend == "embed":
            return {"inputs": jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)}
        return {"inputs": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    # decode: one new token with a cache of seq_len
    if cfg.frontend == "embed":
        return {"inputs": jax.ShapeDtypeStruct((B, 1, cfg.d_model), jnp.bfloat16)}
    return {"inputs": jax.ShapeDtypeStruct((B, 1), jnp.int32)}


_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Sum result-buffer bytes of every collective op in the compiled HLO.

    ``-start`` variants are counted; their ``-done`` twins are skipped.
    Returns bytes per collective kind plus 'total'.
    """
    out = {k: 0.0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        if "=" not in line:
            continue
        head, _, rest = line.partition("=")
        opm = re.search(r"\b([a-z0-9\-]+)\(", rest)
        if not opm:
            continue
        op = opm.group(1)
        if op.endswith("-start"):
            op = op[: -len("-start")]
        elif op.endswith("-done") or op.endswith("-update"):
            continue
        if op not in _COLLECTIVES:
            continue
        # result shape(s) live between '=' and the op name
        result_part = rest[: opm.start(1)]
        out[op] += _shape_bytes(result_part)
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def build_step(cfg: ModelConfig, shape: ShapeSpec, mesh):
    """Returns (fn, example_args, in_shardings) ready for jit/lower."""
    p_shapes, axes = model_lib.init_model(KEY, cfg, shape_only=True)
    p_shard = shlib.param_shardings(p_shapes, axes, mesh, fsdp=cfg.fsdp)
    specs = input_specs(cfg, shape)
    B, S = shape.global_batch, shape.seq_len

    if shape.kind == "train":
        opt = make_optimizer(cfg.optimizer, cosine_with_warmup(3e-4, 100, 10000))
        step_fn = make_train_step(cfg, opt)
        o_shapes = jax.eval_shape(opt.init, p_shapes)
        o_shard = shlib.opt_state_shardings(cfg.optimizer, o_shapes, p_shard, mesh)
        b_shard = shlib.batch_shardings(specs, mesh)
        step_scalar = jax.ShapeDtypeStruct((), jnp.int32)
        args = (p_shapes, o_shapes, step_scalar, specs)
        in_sh = (p_shard, o_shard, None, b_shard)
        # outputs: (params, opt, step, metrics); donation aliases params/opt
        out_sh = (p_shard, o_shard, None, None)
        return step_fn, args, in_sh, out_sh, (0, 1)

    cache_shapes = jax.eval_shape(
        lambda: model_lib.init_cache(cfg, B, S, dtype=jnp.bfloat16)
    )
    c_axes = model_lib.cache_axes(cfg)
    c_shard = shlib.cache_shardings(cache_shapes, c_axes, mesh)
    b_shard = shlib.batch_shardings(specs, mesh)

    if shape.kind == "prefill":
        def fn(params, inputs, cache):
            return model_lib.prefill(params, cfg, inputs, cache)

        args = (p_shapes, specs["inputs"], cache_shapes)
        in_sh = (p_shard, b_shard["inputs"], c_shard)
        out_sh = (None, c_shard)  # (last_logits, cache)
        return fn, args, in_sh, out_sh, (2,)

    def fn(params, inputs, pos, cache):
        return model_lib.decode_step(params, cfg, inputs, pos, cache)

    pos = jax.ShapeDtypeStruct((), jnp.int32)
    args = (p_shapes, specs["inputs"], pos, cache_shapes)
    in_sh = (p_shard, b_shard["inputs"], None, c_shard)
    out_sh = (None, c_shard)
    return fn, args, in_sh, out_sh, (3,)


def run_cell(
    arch: str,
    shape_name: str,
    mesh_kind: str,
    stage_repeats: Optional[str] = None,
    want_hlo: bool = True,
) -> Dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_kind,
        "stage_repeats": stage_repeats,
        "status": "skipped",
    }
    if not shape_applicable(cfg, shape):
        result["reason"] = (
            "long_500k requires sub-quadratic attention; skipped for pure "
            "full-attention archs (DESIGN.md §5)"
        )
        return result
    if stage_repeats:
        reps = [int(r) for r in stage_repeats.split(",")]
        cfg = with_stage_repeats(cfg, reps)
    if shape.kind == "decode" and cfg.layout_decode:
        # serving layout: weights stationary (no FSDP gathers at decode)
        cfg = dataclasses.replace(cfg, layout=cfg.layout_decode, fsdp=False)

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    from repro.models.layers import layout_overrides

    with use_mesh(mesh, layout_overrides(cfg)), mesh:
        # Donation is omitted: on the host backend it merely re-buckets the
        # output buffers into "temp", obscuring comparisons.  Production jobs
        # donate params/opt/caches, so reported peak = arguments + temp
        # (outputs alias the donated arguments).
        fn, args, in_sh, out_sh, _donate = build_step(cfg, shape, mesh)
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        mem_d = {}
        for attr in (
            "argument_size_in_bytes",
            "output_size_in_bytes",
            "temp_size_in_bytes",
            "alias_size_in_bytes",
            "generated_code_size_in_bytes",
        ):
            mem_d[attr] = int(getattr(mem, attr, 0) or 0)
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        cost_d = {k: float(v) for k, v in cost.items() if np.isscalar(v)}
        coll = collective_bytes(compiled.as_text()) if want_hlo else {}

    result.update(
        status="ok",
        n_devices=int(np.prod(list(mesh.shape.values()))),
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        memory=mem_d,
        flops=cost_d.get("flops", 0.0),
        bytes_accessed=cost_d.get("bytes accessed", 0.0),
        cost=cost_d,
        collectives=coll,
    )
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    ap.add_argument("--stage-repeats", default=None, help="e.g. '1,1' for depth variants")
    ap.add_argument("--all", action="store_true", help="run every (arch x shape)")
    ap.add_argument("--out-dir", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in ALL_ARCHS:
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape
        cells = [(args.arch, args.shape)]

    os.makedirs(args.out_dir, exist_ok=True)
    for arch, shape in cells:
        tag = f"{args.mesh}__{arch}__{shape}"
        if args.stage_repeats:
            tag += f"__reps{args.stage_repeats.replace(',', '-')}"
        path = os.path.join(args.out_dir, tag + ".json")
        if args.skip_existing and os.path.exists(path):
            print(f"[dryrun] {tag}: exists, skipping")
            continue
        print(f"[dryrun] {tag}: start", flush=True)
        try:
            res = run_cell(arch, shape, args.mesh, args.stage_repeats)
        except Exception as e:  # noqa: BLE001 — record and continue
            res = {
                "arch": arch,
                "shape": shape,
                "mesh": args.mesh,
                "stage_repeats": args.stage_repeats,
                "status": "error",
                "error": repr(e),
                "traceback": traceback.format_exc()[-4000:],
            }
        with open(path, "w") as f:
            json.dump(res, f, indent=1)
        print(
            f"[dryrun] {tag}: {res['status']} "
            f"(compile {res.get('compile_s', '-')}s, "
            f"temp {res.get('memory', {}).get('temp_size_in_bytes', 0)/2**30:.2f} GiB)",
            flush=True,
        )


if __name__ == "__main__":
    main()
