"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads experiments/dryrun/*.json and computes, per (arch x shape) on the
single-pod mesh:

  compute term    = HLO_FLOPs_corrected / peak_FLOPs          [s, per device]
  memory term     = HLO_bytes_corrected / HBM_bw
  collective term = collective_bytes_corrected / link_bw

TPU v5e-class constants: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
cost_analysis numbers are per-device (SPMD program); XLA counts a while body
once, so corrected totals are extrapolated from the depth-1/depth-2
compiles: F_total = F(1) + sum_s (R_s - 1) * (F(1 with stage s at 2) - F(1)).

MODEL_FLOPS uses the 6*N*D (train) / 2*N*D (inference) convention with
N = active params; the ratio MODEL_FLOPS / HLO_FLOPs surfaces
remat/attention/routing overheads (>1 is impossible; ~0.3 means 3x the
minimal compute is being executed — see the per-cell notes).

Usage:  PYTHONPATH=src python -m repro.launch.roofline \
            [--dir experiments/dryrun] [--out experiments/roofline.json]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, Optional

from repro.configs import ALL_ARCHS, SHAPES, get_config, shape_applicable

PEAK_FLOPS = 197e12  # bf16 per chip
HBM_BW = 819e9
LINK_BW = 50e9


def _load(dirname: str) -> Dict[str, dict]:
    out = {}
    for path in glob.glob(os.path.join(dirname, "*.json")):
        with open(path) as f:
            out[os.path.basename(path)[: -len(".json")]] = json.load(f)
    return out


def _key(mesh, arch, shape, reps=None):
    k = f"{mesh}__{arch}__{shape}"
    if reps:
        k += f"__reps{reps.replace(',', '-')}"
    return k


def model_flops_per_device(arch: str, shape_name: str, n_devices: int) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        total = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        total = 2.0 * n_active * tokens
    else:  # decode: one token per sequence
        total = 2.0 * n_active * shape.global_batch
    return total / n_devices


def corrected_totals(data: Dict[str, dict], arch: str, shape: str) -> Optional[dict]:
    """Undo while-loop count-once using the depth variants."""
    cfg = get_config(arch)
    full = data.get(_key("pod", arch, shape))
    if not full or full.get("status") != "ok":
        return None
    reps_full = [s.repeats for s in cfg.stages]
    n_stages = len(reps_full)
    if n_stages == 1:
        v1 = data.get(_key("pod", arch, shape, "1"))
        v2 = data.get(_key("pod", arch, shape, "2"))
        variants = [v1, v2]
        if any(v is None or v.get("status") != "ok" for v in variants):
            return dict(flops=full["flops"], bytes=full["bytes_accessed"],
                        coll=full["collectives"]["total"], corrected=False)
        bodies = {
            "flops": [v2["flops"] - v1["flops"]],
            "bytes": [v2["bytes_accessed"] - v1["bytes_accessed"]],
            "coll": [v2["collectives"]["total"] - v1["collectives"]["total"]],
        }
        base = v1
    else:
        v11 = data.get(_key("pod", arch, shape, "1,1"))
        v21 = data.get(_key("pod", arch, shape, "2,1"))
        v12 = data.get(_key("pod", arch, shape, "1,2"))
        variants = [v11, v21, v12]
        if any(v is None or v.get("status") != "ok" for v in variants):
            return dict(flops=full["flops"], bytes=full["bytes_accessed"],
                        coll=full["collectives"]["total"], corrected=False)
        bodies = {
            "flops": [v21["flops"] - v11["flops"], v12["flops"] - v11["flops"]],
            "bytes": [
                v21["bytes_accessed"] - v11["bytes_accessed"],
                v12["bytes_accessed"] - v11["bytes_accessed"],
            ],
            "coll": [
                v21["collectives"]["total"] - v11["collectives"]["total"],
                v12["collectives"]["total"] - v11["collectives"]["total"],
            ],
        }
        base = v11

    out = {}
    for k, src in (("flops", "flops"), ("bytes", "bytes_accessed")):
        total = base[src]
        for body, r in zip(bodies[k], reps_full):
            total += max(0.0, body) * (r - 1)
        out[k] = total
    total = base["collectives"]["total"]
    for body, r in zip(bodies["coll"], reps_full):
        total += max(0.0, body) * (r - 1)
    out["coll"] = total
    out["corrected"] = True
    return out


def analyze(dirname: str) -> dict:
    data = _load(dirname)
    cells = []
    for arch in ALL_ARCHS:
        cfg = get_config(arch)
        for shape_name, shape in SHAPES.items():
            cell = {"arch": arch, "shape": shape_name}
            if not shape_applicable(cfg, shape):
                cell["status"] = "skipped (full attention @500k; DESIGN.md §5)"
                cells.append(cell)
                continue
            full = data.get(_key("pod", arch, shape_name))
            if not full or full.get("status") != "ok":
                cell["status"] = (full or {}).get("status", "missing")
                cell["error"] = (full or {}).get("error", "")[:200]
                cells.append(cell)
                continue
            n_dev = full["n_devices"]
            tot = corrected_totals(data, arch, shape_name)
            t_compute = tot["flops"] / PEAK_FLOPS
            t_memory = tot["bytes"] / HBM_BW
            t_coll = tot["coll"] / LINK_BW
            terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
            dominant = max(terms, key=terms.get)
            mf = model_flops_per_device(arch, shape_name, n_dev)
            mem = full["memory"]
            hbm = (mem["argument_size_in_bytes"] + mem["temp_size_in_bytes"]) / 2**30
            mp = data.get(_key("multipod", arch, shape_name), {})
            cell.update(
                status="ok",
                corrected=tot["corrected"],
                n_devices=n_dev,
                compute_s=t_compute,
                memory_s=t_memory,
                collective_s=t_coll,
                dominant=dominant,
                step_time_bound_s=max(terms.values()),
                roofline_fraction=t_compute / max(terms.values()),
                model_flops_per_dev=mf,
                hlo_flops_per_dev=tot["flops"],
                useful_flops_ratio=min(1.0, mf / max(tot["flops"], 1.0)),
                hbm_gib=hbm,
                multipod_status=mp.get("status", "missing"),
                note=_note(dominant, cfg, shape),
            )
            cells.append(cell)
    return {"cells": cells}


def _note(dominant: str, cfg, shape) -> str:
    if dominant == "compute":
        return "compute-bound: gains need less recompute (remat policy) or fewer wasted flops (causal-chunk skipping, MoE capacity)"
    if dominant == "memory":
        if shape.kind == "decode":
            return "memory-bound (weight/cache streaming — inherent to batch-limited decode); gains need quantization or more batch"
        return "memory-bound: fuse/reuse activations, larger per-step arithmetic intensity"
    return "collective-bound: resharding traffic dominates; gains need sharding-axis changes or comm/compute overlap"


def to_markdown(result: dict) -> str:
    lines = [
        "| arch | shape | dom. | compute s | memory s | collective s | roofline frac | useful/HLO | HBM GiB/dev | multipod |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for c in result["cells"]:
        if c.get("status") != "ok":
            lines.append(f"| {c['arch']} | {c['shape']} | — | — | — | — | — | — | — | {c['status'][:40]} |")
            continue
        lines.append(
            "| {arch} | {shape} | {dominant} | {compute_s:.3e} | {memory_s:.3e} | "
            "{collective_s:.3e} | {roofline_fraction:.2f} | {useful_flops_ratio:.2f} | "
            "{hbm_gib:.1f} | {multipod_status} |".format(**c)
        )
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--out", default="experiments/roofline.json")
    args = ap.parse_args()
    result = analyze(args.dir)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)
    print(to_markdown(result))
    ok = [c for c in result["cells"] if c.get("status") == "ok"]
    print(f"\n{len(ok)} ok cells; dominant terms:",
          {d: sum(1 for c in ok if c['dominant'] == d) for d in ('compute', 'memory', 'collective')})


if __name__ == "__main__":
    main()
