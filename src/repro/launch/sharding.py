"""Sharding layout construction for params, optimizer state, caches, inputs.

Everything is derived from the logical-axis trees collected at init
(``models.layers.Init``) plus shape-aware rules for caches (batch-sharded
when the batch divides the DP extent, sequence-sharded otherwise — the
long_500k path) — so one code path serves the 1-device test mesh, the 16x16
pod and the 2x16x16 multi-pod mesh.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import _resolve_axis, named_sharding_tree


def dp_axes(mesh: Mesh):
    """Batch axes under the active logical overrides (layers.use_mesh)."""
    resolved = _resolve_axis("batch", mesh)
    if resolved is None:
        return ()
    return resolved if isinstance(resolved, tuple) else (resolved,)


def dp_size(mesh: Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in dp_axes(mesh)])) if dp_axes(mesh) else 1


def model_size(mesh: Mesh) -> int:
    return int(mesh.shape.get("model", 1))


def param_shardings(params_shapes, axes_tree, mesh: Mesh, fsdp: bool = False):
    """Base TP shardings from logical axes; with ``fsdp`` additionally shard
    each large leaf's biggest unsharded dim over "data" (ZeRO-3; per-pod —
    cross-pod per-layer all-gathers would swamp the pod links)."""
    base = named_sharding_tree(params_shapes, axes_tree, mesh)
    if not fsdp or "data" not in mesh.axis_names:
        return base
    dsize = int(mesh.shape["data"])

    def add_fsdp(shape_struct, sh: NamedSharding):
        shape = shape_struct.shape
        if int(np.prod(shape)) < (1 << 22):  # < 4M elements: keep replicated
            return sh
        spec = list(sh.spec) + [None] * (len(shape) - len(sh.spec))
        cands = sorted(
            (d for d in range(len(shape)) if spec[d] is None and shape[d] % dsize == 0),
            key=lambda d: -shape[d],
        )
        if cands:
            spec[cands[0]] = "data"
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(
        add_fsdp, params_shapes, base, is_leaf=lambda x: isinstance(x, tuple)
    )


def opt_state_shardings(opt_name: str, state_shapes, param_shardings_tree, mesh: Mesh):
    """Optimizer state mirrors its parameter's sharding.

    adamw: m/v have the param's shape -> same sharding.  adafactor: vr drops
    the last dim, vc the second-to-last -> drop that entry of the spec.
    Scalars/vectors fall back to replicated when shapes do not divide.
    """

    def like(shape_struct, pshard: NamedSharding):
        spec = list(pshard.spec) + [None] * 8
        shape = shape_struct.shape
        if len(shape) == len(pshard.spec):
            take = list(pshard.spec)
        elif len(shape) == len(pshard.spec) - 1:
            take = list(pshard.spec)[:-1]  # vr: dropped last dim
        else:
            take = [None] * len(shape)
        fixed = []
        for dim, ax in zip(shape, take):
            if ax is None:
                fixed.append(None)
                continue
            size = int(np.prod([mesh.shape[a] for a in (ax if isinstance(ax, tuple) else (ax,))]))
            fixed.append(ax if dim % size == 0 else None)
        return NamedSharding(mesh, P(*fixed))

    if opt_name == "adamw":
        return {
            "m": jax.tree.map(like, state_shapes["m"], param_shardings_tree),
            "v": jax.tree.map(like, state_shapes["v"], param_shardings_tree),
        }
    if opt_name == "adafactor":

        def acc_like(acc_shapes, pshard):
            return {k: like(v, pshard) for k, v in acc_shapes.items()}

        return {
            "acc": jax.tree.map(
                acc_like,
                state_shapes["acc"],
                param_shardings_tree,
                is_leaf=lambda x: isinstance(x, dict) and ("v" in x or "vr" in x),
            )
        }
    if opt_name == "sgd":
        return {"mu": jax.tree.map(like, state_shapes["mu"], param_shardings_tree)}
    raise ValueError(opt_name)


def batch_shardings(batch_shapes, mesh: Mesh):
    """Input batches: leading dim over the batch axes (largest dividing
    prefix — e.g. global batch 32 on a 256-way pure-DP layout shards 32
    ways and replicates the rest)."""
    from repro.models.layers import dividing_entry

    axes = dp_axes(mesh)

    def one(s):
        if axes and s.shape:
            entry = dividing_entry(s.shape[0], axes, mesh)
            if entry is not None:
                return NamedSharding(mesh, P(entry, *([None] * (len(s.shape) - 1))))
        return NamedSharding(mesh, P())

    return jax.tree.map(one, batch_shapes)


def cache_shardings(cache_shapes, cache_axes_tree, mesh: Mesh):
    """Resolve the explicit cache logical axes (models.model.cache_axes).

    cache_batch -> DP axes when the batch divides; cache_seq -> DP axes when
    the batch was NOT shardable (long_500k); kv_heads/heads/d_inner ->
    "model" when divisible.
    """
    from repro.models.layers import dividing_entry

    dpx = dp_axes(mesh)
    dp = dp_size(mesh)

    def one(s, axes):
        shape = s.shape
        spec: list = [None] * len(shape)
        batch_sharded = False
        for d, (dim, ax) in enumerate(zip(shape, axes)):
            if ax == "cache_batch" and dp > 1 and dim > 1:
                entry = dividing_entry(dim, dpx, mesh)
                if entry is not None:
                    spec[d] = entry
                    batch_sharded = True
        for d, (dim, ax) in enumerate(zip(shape, axes)):
            if ax == "cache_seq" and not batch_sharded and dp > 1 and dim % dp == 0:
                spec[d] = dpx
            elif ax in ("kv_heads", "heads", "d_inner"):
                resolved = _resolve_axis(ax, mesh)
                if resolved is not None:
                    sz = int(np.prod([mesh.shape[a] for a in
                                      (resolved if isinstance(resolved, tuple) else (resolved,))]))
                    if sz > 1 and dim % sz == 0:
                        spec[d] = resolved
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(
        one, cache_shapes, cache_axes_tree, is_leaf=lambda x: isinstance(x, tuple)
    )
