"""Accuracy-vs-device-noise sweep: the paper's claims under real devices.

Sweeps conductance-variation sigma and stuck-at fault rate through the noisy
Pallas datapath (interpret mode on CPU) for full-resolution and
SAFE_ADAPTIVE ADC configs, measuring output error against the ideal
bit-exact datapath.  The zero-noise point is asserted bit-identical to
``crossbar_vmm`` — the subsystem's end-to-end acceptance check.

Run:  PYTHONPATH=src python -m benchmarks.noise_sweep [--out noise_sweep.json]

Emits JSON:
  {"meta": {...},
   "variation_curve": [{"sigma": s, "adc": "full"|"safe_adaptive",
                        "rmse_ulp": ..., "max_abs_ulp": ..., "rel_err": ...,
                        "bit_exact_vs_ideal": bool}, ...],
   "fault_curve":     [{"fault_rate": p, "adc": ..., ...}, ...],
   "repair_curve":    [{"fault_rate": p, "repair": "off"|"on",
                        "spare_cols": B, ..., "recovered_frac": r}, ...]}

The repair curve reruns the fault sweep with the ``device.repair``
spare-column planner on vs off (same seed, same primary fault draw — the
planner never perturbs primary columns), reporting the fraction of
stuck-at MSE degradation the repair recovers.  ``model_fault_recovery``
runs the same comparison end-to-end on a tiny LM (every projection routed
through the crossbar), which is the repo's acceptance bar: >= 70% of
logit-MSE degradation recovered at a 1% stuck rate (tests/test_repair.py).

Error units: output ULPs of the per-layer-scaled 16-bit output format
(``layer_scaled_spec`` picks drop_lsb so the K-row accumulator fits the
window — the deployment regime, where outputs are not clamp-saturated).
"""
from __future__ import annotations

import argparse
import json
from typing import Dict, List, Optional

import numpy as np
import jax.numpy as jnp

from repro.core import adc
from repro.core import crossbar as cb
from repro.device import DeviceConfig, effective_cell_codes
from repro.kernels import ops

SIGMAS = [0.0, 0.02, 0.05, 0.1, 0.2, 0.4]
FAULT_RATES = [0.0, 1e-3, 3e-3, 1e-2, 3e-2]
ADC_CONFIGS = {"full": None, "safe_adaptive": adc.SAFE_ADAPTIVE}
REPAIR_SPARE_COLS = 64  # per-column-group repair budget for the repair curve


def _error_row(y: np.ndarray, y_ideal: np.ndarray) -> Dict[str, float]:
    d = y.astype(np.int64) - y_ideal.astype(np.int64)
    denom = max(1.0, float(np.abs(y_ideal).mean()))
    return {
        "rmse_ulp": float(np.sqrt(np.mean(d * d.astype(np.float64)))),
        "max_abs_ulp": int(np.abs(d).max()),
        "rel_err": float(np.abs(d).mean() / denom),
        "bit_exact_vs_ideal": bool((d == 0).all()),
    }


def run_sweep(
    batch: int = 8,
    k: int = 256,
    n: int = 64,
    sigmas: Optional[List[float]] = None,
    fault_rates: Optional[List[float]] = None,
    seed: int = 0,
    interpret: bool = True,
    spare_cols: int = REPAIR_SPARE_COLS,
) -> Dict:
    sigmas = SIGMAS if sigmas is None else sigmas
    fault_rates = FAULT_RATES if fault_rates is None else fault_rates
    spec = cb.layer_scaled_spec(cb.DEFAULT_SPEC, k)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(0, 1 << spec.input_bits, size=(batch, k)))
    w = jnp.asarray(
        rng.integers(-(1 << (spec.weight_bits - 1)), 1 << (spec.weight_bits - 1), size=(k, n))
    )
    wb = w.astype(jnp.int32) + spec.weight_bias
    y_ideal = np.asarray(cb.crossbar_vmm(x, w, spec))

    def measure(cfg: DeviceConfig, adc_name: str) -> Dict[str, float]:
        g_eff = effective_cell_codes(wb, spec, cfg)
        y = np.asarray(
            ops.noisy_vmm_op(x, g_eff, spec, adc_cfg=ADC_CONFIGS[adc_name], interpret=interpret)
        )
        return _error_row(y, y_ideal)

    variation_curve = []
    for adc_name in ADC_CONFIGS:
        for s in sigmas:
            row = {"sigma": s, "adc": adc_name}
            row.update(measure(DeviceConfig(sigma=s, seed=seed), adc_name))
            variation_curve.append(row)
            if s == 0.0 and adc_name == "full":
                # acceptance: the zero-noise point through the noisy Pallas
                # kernel must reproduce the ideal datapath bit-for-bit
                assert row["bit_exact_vs_ideal"], "zero-noise point diverged from crossbar_vmm"

    fault_curve = []
    for adc_name in ADC_CONFIGS:
        for p in fault_rates:
            cfg = DeviceConfig(p_stuck_on=p / 2, p_stuck_off=p / 2, seed=seed)
            row = {"fault_rate": p, "adc": adc_name}
            row.update(measure(cfg, adc_name))
            fault_curve.append(row)

    # --- spare-column repair on/off (full-resolution ADC) ------------------
    # the "off" arm is the fault_curve's (p, full-ADC) row — same config,
    # same primary fault draw — so only the repaired chip is re-measured
    fault_full = {r["fault_rate"]: r for r in fault_curve if r["adc"] == "full"}
    repair_curve = []
    for p in fault_rates:
        base = DeviceConfig(p_stuck_on=p / 2, p_stuck_off=p / 2, seed=seed)
        off = {k: v for k, v in fault_full[p].items() if k not in ("fault_rate", "adc")}
        # at p=0 the budget is inert (wants_repair False): provably the off arm
        on = measure(base.replace(spare_cols=spare_cols), "full") if p > 0 else dict(off)
        mse_off, mse_on = off["rmse_ulp"] ** 2, on["rmse_ulp"] ** 2
        recovered = 1.0 - mse_on / mse_off if mse_off > 0 else 0.0
        repair_curve.append(
            {"fault_rate": p, "repair": "off", "spare_cols": 0, **off}
        )
        repair_curve.append(
            {
                "fault_rate": p,
                "repair": "on",
                "spare_cols": spare_cols,
                "recovered_frac": recovered,
                **on,
            }
        )

    return {
        "meta": {
            "batch": batch,
            "k": k,
            "n": n,
            "spec": {"drop_lsb": spec.drop_lsb, "out_bits": spec.out_bits},
            # full reproducibility record: re-running with this seed (and
            # these grids) regenerates the JSON bit-for-bit
            "seed": seed,
            "sigmas": list(sigmas),
            "fault_rates": list(fault_rates),
            "repair_spare_cols": spare_cols,
        },
        "variation_curve": variation_curve,
        "fault_curve": fault_curve,
        "repair_curve": repair_curve,
    }


def tiny_lm_config():
    """A deliberately tiny attention LM whose every projection (q/k/v/o,
    mlp wi/wo, untied head) routes through ``crossbar_linear`` — small
    enough for interpret-mode forwards in the fast test tier."""
    from repro.configs.base import ModelConfig, StageSpec

    return ModelConfig(
        name="tiny-crossbar-lm",
        family="dense",
        n_layers=1,
        d_model=32,
        n_heads=2,
        n_kv_heads=2,
        d_ff=64,
        vocab_size=64,
        stages=(StageSpec(kinds=("attn",), repeats=1),),
        tie_embeddings=False,
        param_dtype="float32",
        remat=False,
    )


def tiny_moe_lm_config():
    """A deliberately tiny *MoE* LM with a tied embedding/LM head: every
    weight-bearing projection — attention q/k/v/o, the router, the
    per-expert wi/wg/wo bank, and the tied head (via its transposed
    artifact) — routes through ``crossbar_linear``.  Small enough for
    interpret-mode forwards in the fast test tier; exercises the name-keyed
    4-D expert stacking and the tied-head transpose binding."""
    from repro.configs.base import ModelConfig, StageSpec

    return ModelConfig(
        name="tiny-crossbar-moe",
        family="moe",
        n_layers=1,
        d_model=16,
        n_heads=2,
        n_kv_heads=2,
        d_ff=0,
        vocab_size=32,
        stages=(StageSpec(kinds=("attn",), repeats=1, moe=(True,)),),
        moe_experts=2,
        moe_top_k=1,
        moe_d_ff=16,
        mlp_kind="swiglu",
        tie_embeddings=True,
        param_dtype="float32",
        remat=False,
    )


def model_fault_recovery(
    fault_rate: float = 1e-2,
    spare_cols: int = REPAIR_SPARE_COLS,
    seed: int = 0,
    batch: int = 2,
    seq: int = 8,
) -> Dict[str, float]:
    """End-to-end logit-MSE degradation under stuck-at faults, repair on/off.

    Runs the tiny LM three times through the per-call crossbar path (ideal
    device, faulty device, faulty device + spare-column repair) and reports
    the fraction of logit-MSE degradation the repair recovers — the repo's
    model-level acceptance metric for the fault-aware mapping subsystem.
    """
    import jax

    from repro.models import model as M
    from repro.models.layers import CrossbarMode, crossbar_mode

    cfg = tiny_lm_config()
    params, _ = M.init_model(jax.random.PRNGKey(seed), cfg, dtype=jnp.float32)
    rng = np.random.default_rng(seed)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(batch, seq)))

    def logits(mode: CrossbarMode) -> np.ndarray:
        with crossbar_mode(mode):
            return np.asarray(M.forward(params, cfg, tokens), np.float32)

    y_ideal = logits(CrossbarMode(enabled=True, fast=False))
    dev = DeviceConfig(p_stuck_on=fault_rate / 2, p_stuck_off=fault_rate / 2, seed=seed)
    y_fault = logits(CrossbarMode(enabled=True, fast=False, device=dev))
    y_repair = logits(
        CrossbarMode(enabled=True, fast=False, device=dev.replace(spare_cols=spare_cols))
    )
    mse_off = float(np.mean((y_fault - y_ideal) ** 2))
    mse_on = float(np.mean((y_repair - y_ideal) ** 2))
    return {
        "fault_rate": fault_rate,
        "spare_cols": spare_cols,
        "logit_mse_norepair": mse_off,
        "logit_mse_repair": mse_on,
        "recovered_frac": (1.0 - mse_on / mse_off) if mse_off > 0 else 0.0,
    }


def _programmed_logits(params, cfg, tokens, prog=None, fast=False) -> np.ndarray:
    """Forward under the crossbar path: per-call ideal when ``prog`` is
    None, else served from the bound programmed artifacts."""
    from repro.models import model as M
    from repro.models.layers import CrossbarMode, crossbar_mode

    with crossbar_mode(CrossbarMode(enabled=True, fast=fast, programmed=prog)):
        return np.asarray(M.forward(params, cfg, tokens), np.float32)


UPTIMES_S = [0.0, 1e3, 1e5, 1e7]
TEMPS_K = [300.0, 330.0, 360.0]


def uptime_sweep(
    times_s: Optional[List[float]] = None,
    temps_k: Optional[List[float]] = None,
    drift_nu: float = 0.05,
    drift_ea_ev: float = 0.3,
    sigma: float = 0.02,
    seed: int = 0,
    batch: int = 2,
    seq: int = 8,
) -> Dict:
    """Accuracy-vs-uptime: the chip lifecycle's headline curve.

    Programs the tiny LM once onto a drifting device, then ages the *same*
    chip (``ProgrammedModel.at_time`` — no reprogramming) across a service
    time grid, measuring logit MSE against the ideal crossbar datapath with
    and without the free digital compensation
    (``device.health.compensate_model``).  Aged error must grow
    monotonically; compensation must recover most of it.

    The temperature arm re-ages the same fresh chip under Arrhenius-scaled
    drift (``DeviceConfig.temp_k`` / ``drift_ea_ev``): hotter chips sit
    higher at every uptime, the 300 K row reproduces the base curve
    (``effective_drift_nu`` is exactly ``drift_nu`` at the reference
    temperature).
    """
    import jax

    from repro.device.health import compensate_model
    from repro.device.programmed import program_model
    from repro.models import model as M

    times_s = UPTIMES_S if times_s is None else times_s
    temps_k = TEMPS_K if temps_k is None else temps_k
    cfg = tiny_lm_config()
    params, _ = M.init_model(jax.random.PRNGKey(seed), cfg, dtype=jnp.float32)
    rng = np.random.default_rng(seed)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(batch, seq)))
    y_ideal = _programmed_logits(params, cfg, tokens)

    def mse(prog) -> float:
        y = _programmed_logits(params, cfg, tokens, prog=prog)
        return float(np.mean((y - y_ideal) ** 2))

    dev = DeviceConfig(sigma=sigma, drift_nu=drift_nu, seed=seed)
    prog0 = program_model(params, device=dev, fast=False)
    mse_fresh = mse(prog0)

    uptime_curve = []
    for t in times_s:
        aged = prog0.at_time(t)
        m_aged = mse(aged)
        m_comp = mse(compensate_model(aged))
        uptime_curve.append(
            {
                "t_service_s": t,
                "logit_mse_aged": m_aged,
                "logit_mse_compensated": m_comp,
                "recovered_frac": (1.0 - m_comp / m_aged) if m_aged > 0 else 0.0,
            }
        )

    # drift-vs-T: one fresh chip per temperature (identical cells — the
    # temperature only scales the drift law, never the programming), aged to
    # the same horizon; Arrhenius acceleration shows as MSE ordering in T
    t_ref = times_s[-1] if times_s else 1e7
    temp_curve = []
    for T in temps_k:
        dev_t = dev.replace(temp_k=T, drift_ea_ev=drift_ea_ev)
        prog_t = program_model(params, device=dev_t, fast=False)
        aged = prog_t.at_time(t_ref)
        temp_curve.append(
            {
                "temp_k": T,
                "drift_ea_ev": drift_ea_ev,
                "t_service_s": t_ref,
                "logit_mse_aged": mse(aged),
            }
        )

    return {
        "meta": {
            "seed": seed,
            "sigma": sigma,
            "drift_nu": drift_nu,
            "drift_ea_ev": drift_ea_ev,
            "times_s": list(times_s),
            "temps_k": list(temps_k),
            "logit_mse_fresh": mse_fresh,
        },
        "uptime_curve": uptime_curve,
        "drift_temp_curve": temp_curve,
    }


def model_drift_recovery(
    t_service_s: float = 1e6,
    drift_nu: float = 0.05,
    sigma: float = 0.02,
    seed: int = 0,
    batch: int = 2,
    seq: int = 8,
) -> Dict[str, float]:
    """End-to-end logit-MSE degradation under retention drift, compensation
    on/off — the lifecycle counterpart of ``model_fault_recovery``.

    Programs the tiny LM once, ages the chip ``t_service_s`` seconds, and
    reports the fraction of aged logit MSE the free digital compensation
    recovers with zero reprogramming — the repo's model-level acceptance
    metric for the drift-compensation subsystem (floor 0.5, gated in
    ``benchmarks.run --check`` via ``kernel_lifecycle``).
    """
    import jax

    from repro.device.health import compensate_model
    from repro.device.programmed import program_model
    from repro.models import model as M

    cfg = tiny_lm_config()
    params, _ = M.init_model(jax.random.PRNGKey(seed), cfg, dtype=jnp.float32)
    rng = np.random.default_rng(seed)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(batch, seq)))
    y_ideal = _programmed_logits(params, cfg, tokens)

    dev = DeviceConfig(sigma=sigma, drift_nu=drift_nu, seed=seed)
    prog = program_model(params, device=dev, fast=False)
    aged = prog.at_time(t_service_s)
    comp = compensate_model(aged)

    def mse(p) -> float:
        y = _programmed_logits(params, cfg, tokens, prog=p)
        return float(np.mean((y - y_ideal) ** 2))

    mse_fresh, mse_aged, mse_comp = mse(prog), mse(aged), mse(comp)
    return {
        "t_service_s": t_service_s,
        "drift_nu": drift_nu,
        "logit_mse_fresh": mse_fresh,
        "logit_mse_aged": mse_aged,
        "logit_mse_compensated": mse_comp,
        "recovered_frac": (1.0 - mse_comp / mse_aged) if mse_aged > 0 else 0.0,
    }


def noise_sweep_bench(seed: int = 0) -> Dict[str, float]:
    """Compact entry for benchmarks.run: headline numbers only."""
    out = run_sweep(
        batch=4, k=128, n=32, sigmas=[0.0, 0.1], fault_rates=[0.0, 1e-2], seed=seed
    )
    by = {(r["adc"], r["sigma"]): r for r in out["variation_curve"]}
    rep = {
        (r["fault_rate"], r["repair"]): r for r in out["repair_curve"]
    }
    return {
        "zero_noise_bit_exact": float(by[("full", 0.0)]["bit_exact_vs_ideal"]),
        "rmse_full_sigma0.1": by[("full", 0.1)]["rmse_ulp"],
        "rmse_adaptive_sigma0.1": by[("safe_adaptive", 0.1)]["rmse_ulp"],
        "repair_recovered_frac_p0.01": rep[(1e-2, "on")]["recovered_frac"],
    }


ALL = [("noise_sweep", noise_sweep_bench)]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="noise_sweep.json")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--k", type=int, default=256)
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--spare-cols", type=int, default=REPAIR_SPARE_COLS)
    ap.add_argument(
        "--no-lifecycle", action="store_true",
        help="skip the accuracy-vs-uptime / drift-vs-T model sweeps",
    )
    args = ap.parse_args()
    out = run_sweep(
        batch=args.batch, k=args.k, n=args.n, seed=args.seed,
        spare_cols=args.spare_cols,
    )
    if not args.no_lifecycle:
        life = uptime_sweep(seed=args.seed)
        out["uptime_curve"] = life["uptime_curve"]
        out["drift_temp_curve"] = life["drift_temp_curve"]
        out["meta"]["lifecycle"] = life["meta"]
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {args.out} (seed={args.seed})")
    for row in out["variation_curve"]:
        print(
            f"  sigma={row['sigma']:<5} adc={row['adc']:<14} "
            f"rmse={row['rmse_ulp']:<10.3f} max={row['max_abs_ulp']:<6} "
            f"bit_exact={row['bit_exact_vs_ideal']}"
        )
    for row in out["fault_curve"]:
        print(
            f"  fault={row['fault_rate']:<6} adc={row['adc']:<14} "
            f"rmse={row['rmse_ulp']:<10.3f} max={row['max_abs_ulp']:<6}"
        )
    for row in out["repair_curve"]:
        rec = row.get("recovered_frac")
        print(
            f"  fault={row['fault_rate']:<6} repair={row['repair']:<3} "
            f"spares={row['spare_cols']:<4} rmse={row['rmse_ulp']:<10.3f}"
            + (f" recovered={rec:.3f}" if rec is not None else "")
        )
    for row in out.get("uptime_curve", []):
        print(
            f"  uptime={row['t_service_s']:<8g} "
            f"mse_aged={row['logit_mse_aged']:<10.4g} "
            f"mse_comp={row['logit_mse_compensated']:<10.4g} "
            f"recovered={row['recovered_frac']:.3f}"
        )
    for row in out.get("drift_temp_curve", []):
        print(
            f"  T={row['temp_k']:<6g} uptime={row['t_service_s']:<8g} "
            f"mse_aged={row['logit_mse_aged']:.4g}"
        )


if __name__ == "__main__":
    main()
