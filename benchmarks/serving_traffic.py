"""Serving-traffic benchmark: the continuous-batching tier under load.

Drives ``serving.scheduler.ContinuousBatchingScheduler`` (and the
``serving.farm.ChipFarm`` router) with a seeded Poisson arrival process
over a short/long prompt mix and reports:

  * ``serving_traffic.bit_exact`` — the tentpole refactor gate: for the
    same (seed, admission order) the scheduler serves token-identical
    outputs to the slot-loop ``ServingEngine`` (1.0 = every request's
    token stream matches bit-for-bit);
  * ``serving_traffic.p50_ticks`` / ``.p99_ticks`` — request latency in
    decode ticks (arrival to final token) under the Poisson mix.  Ticks,
    not wall clock: one tick = one jitted decode step, so the numbers are
    deterministic and gateable (a scheduling regression — lost admission
    slots, spurious preemption — moves them; host speed does not);
  * ``serving_traffic.tokens_per_tick`` — batching efficiency: generated
    tokens per decode tick (max_batch would be perfect packing);
  * ``serving_traffic.farm_speedup_x`` — farm scaling: ticks to drain a
    fixed workload on 1 replica vs 2 (pure fan-out, gated > 1.3x);
  * ``serving_traffic.tokens_per_s`` — wall-clock throughput of the
    scheduler run, reported for the record but NOT gated (host dependent).

Traffic mixes are first-class frozen dataclasses (``PromptClass``,
``TrafficMix``): a mix owns its arrival rate, class weights and seed, so
a workload is one hashable value and every run over it replays the same
arrival schedule.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as M
from repro.serving import ContinuousBatchingScheduler, ChipFarm, ModelRunner, ServingEngine

from benchmarks.noise_sweep import tiny_lm_config


@dataclasses.dataclass(frozen=True)
class PromptClass:
    """One request shape in a traffic mix."""

    name: str
    prompt_len: int
    max_new_tokens: int
    weight: float  # relative admission probability within the mix


@dataclasses.dataclass(frozen=True)
class TrafficMix:
    """A seeded Poisson arrival process over prompt classes.

    ``rate`` is the mean number of arrivals per decode tick; class choice
    and prompt tokens draw from the mix's own seeded generator, so one
    ``TrafficMix`` value IS the workload — every sampling of it replays
    the identical request schedule.
    """

    name: str
    classes: Tuple[PromptClass, ...]
    rate: float
    n_requests: int
    seed: int = 0

    def sample_arrivals(self, vocab: int) -> List[Tuple[int, PromptClass, np.ndarray]]:
        """(arrival_tick, class, prompt) for each request, tick-ordered."""
        rng = np.random.default_rng(self.seed)
        w = np.asarray([c.weight for c in self.classes], np.float64)
        w = w / w.sum()
        out: List[Tuple[int, PromptClass, np.ndarray]] = []
        tick = 0
        while len(out) < self.n_requests:
            for _ in range(int(rng.poisson(self.rate))):
                if len(out) >= self.n_requests:
                    break
                cls = self.classes[int(rng.choice(len(self.classes), p=w))]
                prompt = rng.integers(1, vocab, size=cls.prompt_len).astype(np.int32)
                out.append((tick, cls, prompt))
            tick += 1
        return out


# the headline mix: mostly short interactive prompts with a long-prompt
# tail — the shape that makes continuous batching pay (short requests
# drain and refill slots while long ones keep decoding)
SHORT_LONG = TrafficMix(
    name="short_long",
    classes=(
        PromptClass("short", prompt_len=6, max_new_tokens=4, weight=0.7),
        PromptClass("long", prompt_len=20, max_new_tokens=10, weight=0.3),
    ),
    rate=0.75,
    n_requests=12,
    seed=0,
)


def _tiny_setup():
    cfg = tiny_lm_config()
    params, _ = M.init_model(jax.random.PRNGKey(0), cfg, dtype=jnp.float32)
    return cfg, params


def serving_traffic_bench(mix: TrafficMix = SHORT_LONG) -> Dict[str, float]:
    cfg, params = _tiny_setup()
    arrivals = mix.sample_arrivals(cfg.vocab_size)
    max_batch, max_seq = 4, 48

    # -- bit-exactness gate: scheduler vs slot-loop engine, same (seed,
    # admission order) — every request submitted up front, FIFO
    eng = ServingEngine(cfg, params, max_batch=max_batch, max_seq=max_seq, seed=0)
    for _, cls, prompt in arrivals:
        eng.submit(prompt, max_new_tokens=cls.max_new_tokens)
    eng_out = {r.rid: r.generated for r in eng.run_until_done()}

    runner = ModelRunner(cfg, params, max_seq=max_seq, seed=0)
    sched = ContinuousBatchingScheduler(runner, max_batch=max_batch)
    for _, cls, prompt in arrivals:
        sched.submit(prompt, max_new_tokens=cls.max_new_tokens)
    sched_out = {r.rid: r.generated for r in sched.run()}
    bit_exact = float(sched_out == eng_out and len(sched_out) == len(arrivals))

    # -- latency/throughput under the Poisson arrival schedule
    runner = ModelRunner(cfg, params, max_seq=max_seq, seed=0)
    sched = ContinuousBatchingScheduler(runner, max_batch=max_batch)
    queue = list(arrivals)
    t0 = time.perf_counter()
    while queue or sched.load:
        while queue and queue[0][0] <= sched.tick:
            _, cls, prompt = queue.pop(0)
            sched.submit(prompt, max_new_tokens=cls.max_new_tokens)
        sched.step()
    wall = time.perf_counter() - t0
    done = sorted(sched.completed.values(), key=lambda r: r.rid)
    lat = np.asarray([r.finish - r.arrival for r in done], np.float64)
    n_tokens = sum(len(r.generated) for r in done)
    ticks = max(1, sched.tick)

    # -- farm scaling: ticks to drain the same workload, 1 vs 2 replicas
    def farm_ticks(n_replicas: int) -> int:
        farm = ChipFarm(
            cfg, params, n_replicas=n_replicas, policy="round_robin",
            max_batch=2, max_seq=max_seq, seed=0,
        )
        for _, cls, prompt in arrivals:
            farm.submit(prompt, max_new_tokens=cls.max_new_tokens)
        n = 0
        while not all(farm.is_idle(i) for i in range(n_replicas)):
            farm.step()
            n += 1
        return n

    speedup = farm_ticks(1) / max(1, farm_ticks(2))

    return {
        "bit_exact": bit_exact,
        "n_completed": float(len(done)),
        "p50_ticks": float(np.percentile(lat, 50)),
        "p99_ticks": float(np.percentile(lat, 99)),
        "tokens_per_tick": n_tokens / ticks,
        "farm_speedup_x": speedup,
        "tokens_per_s": n_tokens / max(wall, 1e-9),
    }


ALL = [("serving_traffic", serving_traffic_bench)]


if __name__ == "__main__":
    for name, fn in ALL:
        print(f"== {name}")
        for k, v in fn().items():
            print(f"  {k}: {v:.4f}")
