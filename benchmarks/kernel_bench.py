"""Kernel micro-benchmarks: the Pallas crossbar datapath vs the jnp reference
(interpret mode on CPU — wall times are CPU-emulation numbers; the relevant
derived metrics are conversion counts and exactness, plus the TPU roofline
estimates from the dry-run in EXPERIMENTS.md).

The programmed-vs-unprogrammed benchmark is the exception: both sides run
the same executor, so their *ratio* is meaningful on CPU — it measures how
much of the old per-call latency was the programming pipeline (fault draw,
write-verify pulses, IR-drop solve, quantization-scale reductions) that
``repro.device.programmed`` amortizes into a one-time cost.

``benchmarks.run --json`` persists these results to ``BENCH_kernels.json``
at the repo root; ``scripts/run_tests.sh --bench`` re-runs the tier and
refuses >20% regressions on the headline numbers.
"""
from __future__ import annotations

import time
from typing import Dict

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import crossbar as cb
from repro.device import DeviceConfig, program_layer, programmed_matmul
from repro.kernels import ops, ref


def _time(fn, *args, reps=5) -> float:
    """Median-of-reps wall time (us) — medians resist the multi-second
    scheduler noise of shared CI boxes that a mean-of-3 does not."""
    fn(*args)  # compile/warm
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)) * 1e6  # us


def crossbar_kernel_bench() -> Dict[str, float]:
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(0, 1 << 16, size=(64, 512)))
    w = jnp.asarray(rng.integers(-(1 << 15), 1 << 15, size=(512, 128)))
    t_ref = _time(lambda a, b: ref.crossbar_vmm_ref(a, b), x, w)
    t_pal = _time(lambda a, b: ops.crossbar_vmm_op(a, b, interpret=True), x, w)
    t_fast = _time(lambda a, b: ops.crossbar_vmm_op(a, b, fast=True, interpret=True), x, w)
    y1 = ops.crossbar_vmm_op(x, w, interpret=True)
    y2 = ref.crossbar_vmm_ref(x, w)
    stats = cb.conversion_stats(64, 512, 128, cb.DEFAULT_SPEC)
    return {
        "ref_us": t_ref,
        "pallas_us": t_pal,
        "pallas_fast_us": t_fast,
        "bit_exact": float(bool(jnp.array_equal(y1, y2))),
        "adc_conversions": float(stats.conversions),
    }


def programmed_kernel_bench() -> Dict[str, float]:
    """Program-once vs program-every-call for the device-noisy path.

    Steady-state serving scenario: one weight slab, many inference calls.
    ``unprogrammed_us`` is the old hot path (full programming pipeline per
    ``crossbar_matmul(device=...)`` call); ``steady_state_us`` is the same
    call served from a ``ProgrammedLinear`` artifact; ``program_once_us``
    is the amortized one-time compile.  The acceptance bar for this repo is
    ``speedup_x >= 5`` — and outputs must stay bit-identical.
    """
    rng = np.random.default_rng(0)
    B, K, N = 8, 512, 256
    x = jnp.asarray(np.abs(rng.normal(size=(B, K))).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(K, N)).astype(np.float32))
    dev = DeviceConfig(
        sigma=0.1, p_stuck_on=1e-3, p_stuck_off=1e-3, write_verify_iters=8
    )

    t_unprog = _time(
        lambda a, b: ops.crossbar_matmul(a, b, device=dev, interpret=True), x, w
    )
    t0 = time.perf_counter()
    art = program_layer(w, device=dev)
    jax.block_until_ready(art.g_eff)
    t_program = (time.perf_counter() - t0) * 1e6
    t_prog = _time(lambda a: programmed_matmul(a, art, interpret=True), x)

    y_unprog = ops.crossbar_matmul(x, w, device=dev, interpret=True)
    y_prog = programmed_matmul(x, art, interpret=True)
    return {
        "unprogrammed_us": t_unprog,
        "steady_state_us": t_prog,
        "program_once_us": t_program,
        "speedup_x": t_unprog / t_prog,
        "bit_exact": float(bool(jnp.array_equal(y_unprog, y_prog))),
    }


def zero_plane_kernel_bench() -> Dict[str, float]:
    """Zero-plane skipping: conversion counts + exactness, dense vs sparse.

    Post-ReLU activations quantize to small codes with most high bit-planes
    dead; the kernels' ``skip_zero_planes`` predicate never issues those
    conversions.  Wall time in interpret mode is not meaningful — the
    honest metrics are the activity-aware conversion counts feeding
    ``core.energy`` and the bit-identity of the skipping kernel.
    """
    rng = np.random.default_rng(1)
    B, K, N = 8, 512, 128
    spec = cb.DEFAULT_SPEC
    w = jnp.asarray(rng.integers(-(1 << 15), 1 << 15, size=(K, N)))
    x_dense = jnp.asarray(rng.integers(0, 1 << 16, size=(B, K)))
    # post-ReLU style: ~70% exact zeros, survivors in the low 9 bits
    x_sparse = jnp.asarray(
        rng.integers(0, 1 << 9, size=(B, K)) * (rng.random((B, K)) < 0.3)
    )

    s_dense = cb.conversion_stats(B, K, N, spec, x_codes=x_dense)
    s_sparse = cb.conversion_stats(B, K, N, spec, x_codes=x_sparse)

    exact = True
    for xx in (x_dense, x_sparse):
        y_skip = ops.crossbar_vmm_op(xx, w, spec, interpret=True, skip_zero_planes=True)
        y_dense = ops.crossbar_vmm_op(xx, w, spec, interpret=True, skip_zero_planes=False)
        exact &= bool(jnp.array_equal(y_skip, y_dense))

    total = s_dense.conversions + s_dense.skipped_conversions
    return {
        "conversions_dense": float(s_dense.conversions),
        "conversions_sparse": float(s_sparse.conversions),
        "skipped_sparse": float(s_sparse.skipped_conversions),
        "sparse_activity": s_sparse.conversions / total,
        "bit_exact": float(exact),
    }


def repaired_kernel_bench() -> Dict[str, float]:
    """Spare-column repair on the programmed path (device.repair).

    The repaired layout is pre-gathered at programming time, so the
    steady-state artifact path must keep the program-once speedup (gated by
    the same >= 5x acceptance floor as ``kernel_programmed`` — a spare
    gather accidentally moved into the hot path would show up here) while
    recovering most of the stuck-at output error.  ``bit_exact`` pins the
    programmed-vs-per-call identity with repair active on both sides;
    ``bit_exact_zero_fault`` pins that a provisioned-but-unneeded budget
    (faults disabled) changes nothing.
    """
    rng = np.random.default_rng(2)
    B, K, N = 8, 512, 256
    x = jnp.asarray(np.abs(rng.normal(size=(B, K))).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(K, N)).astype(np.float32))
    dev = DeviceConfig(
        sigma=0.05, p_stuck_on=5e-3, p_stuck_off=5e-3, write_verify_iters=4,
        # Two spares per data column: at p = 1e-2 ~72% of 128-cell physical
        # columns carry a fault — including the spares themselves — so a 1x
        # pool cannot cover the victims; the provisioning rule
        # (mapper.provision_spare_cols) discounts the pool by the spares'
        # own fault rate and lands on the 2x budget at this burden.
        spare_cols=256,
    )

    t_unprog = _time(
        lambda a, b: ops.crossbar_matmul(a, b, device=dev, interpret=True), x, w
    )
    art = program_layer(w, device=dev)
    t_prog = _time(lambda a: programmed_matmul(a, art, interpret=True), x)

    y_unprog = ops.crossbar_matmul(x, w, device=dev, interpret=True)
    y_prog = programmed_matmul(x, art, interpret=True)

    # recovery of the *stuck-at* error component: MSE vs the ideal datapath,
    # with the sigma-variation floor (which no column repair can touch)
    # subtracted out of both sides
    y_ideal = np.asarray(ops.crossbar_matmul(x, w, interpret=True), np.float32)

    def _mse(device):
        y = programmed_matmul(x, program_layer(w, device=device), interpret=True)
        return float(np.mean((np.asarray(y, np.float32) - y_ideal) ** 2))

    mse_rep = float(np.mean((np.asarray(y_prog, np.float32) - y_ideal) ** 2))
    mse_norep = _mse(dev.replace(spare_cols=0))
    dev_zf = dev.replace(p_stuck_on=0.0, p_stuck_off=0.0)
    mse_sigma = _mse(dev_zf.replace(spare_cols=0))
    degradation_norepair = mse_norep - mse_sigma
    degradation_repair = mse_rep - mse_sigma

    y_zf_prog = programmed_matmul(x, program_layer(w, device=dev_zf), interpret=True)
    y_zf_percall = ops.crossbar_matmul(x, w, device=dev_zf, interpret=True)

    return {
        "unprogrammed_us": t_unprog,
        "steady_state_us": t_prog,
        "speedup_x": t_unprog / t_prog,
        "bit_exact": float(bool(jnp.array_equal(y_unprog, y_prog))),
        "recovery_frac": (
            1.0 - degradation_repair / degradation_norepair
            if degradation_norepair > 0
            else 0.0
        ),
        "bit_exact_zero_fault": float(bool(jnp.array_equal(y_zf_prog, y_zf_percall))),
        "repaired_cols": float(art.repair.n_repaired if art.repair else 0),
    }


def artifact_store_bench() -> Dict[str, float]:
    """Restore-vs-reprogram: serving-restart latency (ISSUE 4 tentpole).

    A restart that replays ``program_model`` pays the full write-verify /
    fault-draw / IR-drop pipeline for every projection; one that restores a
    ``save_programmed`` artifact store pays file I/O.  Both must produce
    the *same chip* — ``bit_exact`` compares every array leaf of every
    artifact (effective cells, scales, spare blocks, gather tables).  The
    acceptance floor is ``restore_speedup_x >= 2`` (in practice restore is
    orders of magnitude faster; the floor only guards against restore
    accidentally re-entering the programming pipeline).
    """
    import tempfile

    from repro.checkpoint import restore_programmed, save_programmed
    from repro.device import program_model

    rng = np.random.default_rng(3)
    params = {
        "stage0": {
            "b0": {
                "wq": jnp.asarray(rng.normal(size=(2, 256, 64)).astype(np.float32)),
                "wi": jnp.asarray(rng.normal(size=(2, 256, 128)).astype(np.float32)),
            }
        },
        "head": jnp.asarray(rng.normal(size=(256, 128)).astype(np.float32)),
    }
    dev = DeviceConfig(
        sigma=0.1, p_stuck_on=1e-3, p_stuck_off=1e-3, write_verify_iters=8,
        spare_cols=8,
    )

    def _program():
        prog = program_model(params, device=dev)
        jax.block_until_ready([a.g_eff for a in prog.by_name.values()])
        return prog

    t0 = time.perf_counter()
    prog = _program()
    t_program = (time.perf_counter() - t0) * 1e6

    with tempfile.TemporaryDirectory() as d:
        save_programmed(d, prog)

        def _restore():
            back = restore_programmed(d)
            jax.block_until_ready([a.g_eff for a in back.by_name.values()])
            return back

        t_restore = _time(_restore)
        back = _restore()

    from repro.device.programmed import artifacts_equal

    exact = set(back.by_name) == set(prog.by_name)
    exact = exact and all(
        artifacts_equal(prog.by_name[n], back.by_name[n]) for n in prog.by_name
    )
    return {
        "program_us": t_program,
        "restore_us": t_restore,
        "restore_speedup_x": t_program / t_restore,
        "bit_exact": float(bool(exact)),
    }


def moe_programmed_bench() -> Dict[str, float]:
    """Per-expert stacked artifacts vs per-call expert programming.

    The (E, K, N) expert bank compiles once (name-keyed 4-D stacking);
    steady-state serving slices per-expert artifacts instead of rerunning
    the programming pipeline per expert per call.  Held to the same
    ``speedup_x >= 5`` program-once floor as the dense benches, and each
    expert's steady-state output must stay bit-identical to its per-call
    reference.
    """
    rng = np.random.default_rng(4)
    E, B, K, N = 4, 8, 256, 64
    xs = jnp.asarray(np.abs(rng.normal(size=(E, B, K))).astype(np.float32))
    ws = jnp.asarray(rng.normal(size=(E, K, N)).astype(np.float32))
    dev = DeviceConfig(
        sigma=0.1, p_stuck_on=1e-3, p_stuck_off=1e-3, write_verify_iters=8
    )

    def percall():
        return [
            ops.crossbar_matmul(xs[e], ws[e], device=dev, interpret=True)
            for e in range(E)
        ]

    t_percall = _time(lambda: jax.block_until_ready(percall()))

    t0 = time.perf_counter()
    bank = program_layer(ws, device=dev)  # expert-stacked artifact
    jax.block_until_ready(bank.g_eff)
    t_program = (time.perf_counter() - t0) * 1e6

    def steady():
        return [programmed_matmul(xs[e], bank.layer(e), interpret=True) for e in range(E)]

    t_steady = _time(lambda: jax.block_until_ready(steady()))

    y_percall = percall()
    y_steady = steady()
    exact = all(
        bool(jnp.array_equal(a, b)) for a, b in zip(y_percall, y_steady)
    )
    return {
        "percall_us": t_percall,
        "steady_state_us": t_steady,
        "program_once_us": t_program,
        "speedup_x": t_percall / t_steady,
        "bit_exact": float(exact),
        "experts": float(E),
    }


def sharded_programmed_bench() -> Dict[str, float]:
    """Per-rank artifact sharding (ISSUE 5 tentpole): rank-local serving.

    An (E, K, N) expert bank is programmed once as the global chip, then
    sliced per rank along the expert axis (``local_artifact`` — the same
    slicing the shard_map in_specs perform on the fly).  Two invariants:

    * ``bit_exact`` — every rank's slice serves exactly the outputs the
      global chip produces for its experts (slicing is a pure relabeling of
      which crossbars live where; the EP mesh forward being bit-identical
      to single-device rests on this);
    * ``speedup_x >= 5`` — rank-local *programmed* steady state vs the
      rank-local per-call device pipeline, the same program-once floor as
      every other bench: sharding must not leak programming-time work
      (fault draw, write-verify, scale reductions) back into the serving
      hot path.
    """
    from jax.sharding import PartitionSpec as P

    from repro.device.programmed import local_artifact

    rng = np.random.default_rng(5)
    E, B, K, N, ranks = 8, 8, 256, 64, 4
    xs = jnp.asarray(np.abs(rng.normal(size=(E, B, K))).astype(np.float32))
    ws = jnp.asarray(rng.normal(size=(E, K, N)).astype(np.float32))
    dev = DeviceConfig(
        sigma=0.1, p_stuck_on=1e-3, p_stuck_off=1e-3, write_verify_iters=8
    )
    bank = program_layer(ws, device=dev)  # the global chip
    E_loc = E // ranks
    locs = [
        local_artifact(bank, P("model", None, None), {"model": ranks}, {"model": r})
        for r in range(ranks)
    ]

    # the sharding invariant: rank-local serving == global-chip serving
    exact = True
    for r in range(ranks):
        for i in range(E_loc):
            e = r * E_loc + i
            y_loc = programmed_matmul(xs[e], locs[r].layer(i), interpret=True)
            y_glob = programmed_matmul(xs[e], bank.layer(e), interpret=True)
            exact &= bool(jnp.array_equal(y_loc, y_glob))

    # one rank's serving latency: per-call device pipeline vs programmed
    def percall_rank0():
        return [
            ops.crossbar_matmul(xs[e], ws[e], device=dev, interpret=True)
            for e in range(E_loc)
        ]

    def steady_rank0():
        return [
            programmed_matmul(xs[i], locs[0].layer(i), interpret=True)
            for i in range(E_loc)
        ]

    t_percall = _time(lambda: jax.block_until_ready(percall_rank0()))
    t_steady = _time(lambda: jax.block_until_ready(steady_rank0()))
    return {
        "percall_us": t_percall,
        "steady_state_us": t_steady,
        "speedup_x": t_percall / t_steady,
        "bit_exact": float(exact),
        "ranks": float(ranks),
        "experts_per_rank": float(E_loc),
    }


def lifecycle_kernel_bench() -> Dict[str, float]:
    """Chip lifecycle: aging, free compensation, double-buffered refresh.

    Three gated claims (ISSUE 7 acceptance):
      * ``aged_monotone`` — the same programmed chip, aged through the
        retention power law (``artifact_at_time``, zero reprogramming),
        shows strictly growing output MSE vs its digital reference;
      * ``comp_recovery_frac`` — refitting the digital per-column
        ``comp_scale`` (``health.fit_compensation``) recovers at least half
        of the aged MSE, floor 0.5 (drift is almost pure common-mode scale,
        so in practice recovery is near-total);
      * ``refresh_bit_exact`` — a reprogram into the inactive store slot +
        ``swap_active`` + restore round-trips bit-identically to a freshly
        programmed chip (programming is deterministic; the store preserves
        exact dtypes), so a hot-swapped engine serves the same tokens.

    ``age_us`` / ``refresh_us`` time the two lifecycle transitions — both
    are deploy-time costs, never on the steady-state serving path.
    """
    import tempfile

    from repro.checkpoint import restore_programmed, save_programmed, swap_active
    from repro.device.health import fit_compensation
    from repro.device.programmed import ProgrammedModel, artifacts_equal

    rng = np.random.default_rng(5)
    k, n = 256, 64
    w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32) * 0.1)
    x = jnp.asarray(np.abs(rng.normal(size=(8, k))).astype(np.float32))
    dev = DeviceConfig(sigma=0.02, drift_nu=0.05, seed=5)
    art = program_layer(w, device=dev)
    ideal = program_layer(w)  # the digital reference datapath
    y_ref = programmed_matmul(x, ideal, interpret=True)

    def mse(a) -> float:
        y = programmed_matmul(x, a, interpret=True)
        return float(jnp.mean((y - y_ref) ** 2))

    times_s = [1e3, 1e5, 1e7]
    curve = [mse(art.at_time(t)) for t in times_s]
    monotone = all(a < b for a, b in zip(curve, curve[1:]))

    aged = art.at_time(times_s[-1])
    comp = fit_compensation(aged)
    mse_aged, mse_comp = curve[-1], mse(comp)
    recovery = (1.0 - mse_comp / mse_aged) if mse_aged > 0 else 0.0

    t_age = _time(lambda: jax.block_until_ready(art.at_time(1e5).g_eff))

    # zero-downtime refresh through the double-buffered store: reprogram
    # into the inactive slot, swap the ACTIVE pointer, restore — must be
    # the same chip a fresh construction would program, bit for bit
    with tempfile.TemporaryDirectory() as d:
        save_programmed(d, ProgrammedModel({"w": aged}), slot="A")
        swap_active(d, "A")

        def _refresh():
            fresh = program_layer(w, device=dev)
            save_programmed(d, ProgrammedModel({"w": fresh}), slot="B")
            swap_active(d, "B")
            return restore_programmed(d).by_name["w"]

        t0 = time.perf_counter()
        back = _refresh()
        t_refresh = (time.perf_counter() - t0) * 1e6

    refreshed_exact = artifacts_equal(back, art)
    y_fresh = programmed_matmul(x, art, interpret=True)
    y_back = programmed_matmul(x, back, interpret=True)
    refreshed_exact = refreshed_exact and bool(jnp.array_equal(y_fresh, y_back))

    return {
        "aged_monotone": float(monotone),
        "mse_aged_t1e7": mse_aged,
        "mse_compensated_t1e7": mse_comp,
        "comp_recovery_frac": recovery,
        "refresh_bit_exact": float(refreshed_exact),
        "age_us": t_age,
        "refresh_us": t_refresh,
    }


def planned_kernel_bench() -> Dict[str, float]:
    """Chip-plan compiler: heterogeneous compile vs the homogeneous baseline.

    Gated claims (ISSUE 8 acceptance):
      * ``bit_exact`` — artifacts compiled under a ``LayerPlan`` (Karatsuba
        level 1/2 and Strassen datapaths, adaptive ADC schedule) produce the
        same bits as the homogeneous direct compile, per the exact limb
        arithmetic guarantee the planner's docstring promises;
      * ``conversions_ratio_max`` / ``energy_ratio_max`` — over the tested
        models (an LM from ``configs/`` via ``lm_workload`` plus the Table II
        AlexNet), the *worst* planned/homogeneous predicted-cost ratio must
        stay strictly below 1: the planner never admits a plan that loses.

    ``plan_compile_us`` times the whole-model compile (a deploy-time cost,
    never on the serving path).
    """
    from repro.configs import get_config
    from repro.core.planner import LayerPlan, homogeneous_network, plan_network
    from repro.core.workloads import alexnet, lm_workload

    # --- predicted-cost ratios over real model shapes -------------------
    nets = [lm_workload(get_config("smollm-360m")), alexnet()]
    conv_ratio = energy_ratio = 0.0
    for net in nets:
        planned = plan_network(net)
        homo = homogeneous_network(net)
        conv_ratio = max(conv_ratio, planned.total_conversions / homo.total_conversions)
        energy_ratio = max(energy_ratio, planned.total_energy_pj / homo.total_energy_pj)
    t_plan = _time(lambda: plan_network(nets[0]), reps=3)

    # --- executed bit-identity: every non-direct datapath vs direct -----
    rng = np.random.default_rng(8)
    K, N = 256, 128
    w = jnp.asarray(rng.normal(size=(K, N)).astype(np.float32) * 0.1)
    x = jnp.asarray(np.abs(rng.normal(size=(8, K))).astype(np.float32))
    base = program_layer(w)
    y_base = programmed_matmul(x, base, interpret=True)
    exact = True
    for dp in ("karatsuba1", "karatsuba2", "strassen"):
        plan = LayerPlan(name="w", datapath=dp, adc_mode="safe_adaptive")
        art = program_layer(w, plan=plan)
        y = programmed_matmul(x, art, interpret=True)
        exact = exact and bool(jnp.array_equal(y, y_base))

    return {
        "bit_exact": float(exact),
        "conversions_ratio_max": float(conv_ratio),
        "energy_ratio_max": float(energy_ratio),
        "plan_compile_us": t_plan,
    }


ALL = [
    ("kernel_crossbar", crossbar_kernel_bench),
    ("kernel_programmed", programmed_kernel_bench),
    ("kernel_zero_plane", zero_plane_kernel_bench),
    ("kernel_repaired", repaired_kernel_bench),
    ("kernel_artifact_store", artifact_store_bench),
    ("kernel_moe_programmed", moe_programmed_bench),
    ("kernel_sharded_programmed", sharded_programmed_bench),
    ("kernel_lifecycle", lifecycle_kernel_bench),
    ("kernel_planned", planned_kernel_bench),
]
