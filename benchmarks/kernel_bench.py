"""Kernel micro-benchmarks: the Pallas crossbar datapath vs the jnp reference
(interpret mode on CPU — wall times are CPU-emulation numbers; the relevant
derived metrics are conversion counts and exactness, plus the TPU roofline
estimates from the dry-run in EXPERIMENTS.md)."""
from __future__ import annotations

import time
from typing import Dict

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import crossbar as cb
from repro.kernels import ops, ref


def _time(fn, *args, reps=3) -> float:
    fn(*args)  # compile/warm
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6  # us


def crossbar_kernel_bench() -> Dict[str, float]:
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(0, 1 << 16, size=(64, 512)))
    w = jnp.asarray(rng.integers(-(1 << 15), 1 << 15, size=(512, 128)))
    t_ref = _time(lambda a, b: ref.crossbar_vmm_ref(a, b), x, w)
    t_pal = _time(lambda a, b: ops.crossbar_vmm_op(a, b, interpret=True), x, w)
    t_fast = _time(lambda a, b: ops.crossbar_vmm_op(a, b, fast=True, interpret=True), x, w)
    y1 = ops.crossbar_vmm_op(x, w, interpret=True)
    y2 = ref.crossbar_vmm_ref(x, w)
    stats = cb.conversion_stats(64, 512, 128, cb.DEFAULT_SPEC)
    return {
        "ref_us": t_ref,
        "pallas_us": t_pal,
        "pallas_fast_us": t_fast,
        "bit_exact": float(bool(jnp.array_equal(y1, y2))),
        "adc_conversions": float(stats.conversions),
    }


ALL = [("crossbar_kernel", crossbar_kernel_bench)]
