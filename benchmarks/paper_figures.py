"""One benchmark per paper table/figure.

Each function reproduces one figure's quantity from the mechanism-level
models in ``repro.core`` and returns (derived_dict) used for the CSV and for
EXPERIMENTS.md §Repro-validation.  Paper targets are embedded for
comparison; deviations are expected to be documented, not hidden.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Tuple

import numpy as np
import jax.numpy as jnp

from repro.core import adc, arch, crossbar as cb, energy as en, karatsuba as ka
from repro.core import mapper, strassen as st, workloads as wl


def _suite_results():
    global _CACHE
    try:
        return _CACHE
    except NameError:
        _CACHE = en.evaluate_suite(wl.benchmark_suite())
        return _CACHE


def fig2_vmm_energy_breakdown() -> Dict[str, float]:
    """Fig 2: energy breakdown of a 1x128 x 128x128 16-bit VMM."""
    res = en.evaluate(wl.alexnet(), arch.ISAAC_CHIP, policy="isaac")
    total = sum(res.breakdown.values())
    out = {f"frac_{k}": v / total for k, v in res.breakdown.items()}
    out["adc_dominates"] = float(out["frac_adc"] == max(out.values()))
    return out


def fig5_adaptive_schedule() -> Dict[str, float]:
    """Fig 5: heterogeneous ADC sampling resolution per (column, iteration)."""
    sched = adc.adaptive_schedule(cb.DEFAULT_SPEC.replace(signed_weights=False))
    return {
        "mean_bits": float(sched.mean()),
        "full_bits": 9.0,
        "min_bits": float(sched.min()),
        "bits_saved_frac": 1.0 - float(sched.mean()) / 9.0,
    }


def fig10_underutilization() -> Dict[str, float]:
    """Fig 10: crossbar under-utilization vs IMA size (paper: 9% @128x256)."""
    sizes = [(128, 64), (128, 128), (128, 256), (512, 256), (2048, 1024), (8192, 1024)]
    uu = mapper.underutilization_sweep(wl.benchmark_suite(), sizes, arch.NEWTON_CHIP)
    return {f"waste_{k}": v for k, v in uu.items()}


def fig11_constrained_mapping() -> Dict[str, float]:
    """Fig 11: compact HTree + constrained mapping (paper: +37% CE, +18% PE)."""
    r = _suite_results()
    ce = np.mean([r[n]["+compact-htree"].ce / r[n]["isaac"].ce for n in r])
    pw = np.mean([r[n]["+compact-htree"].peak_power_w / r[n]["isaac"].peak_power_w for n in r])
    return {"area_eff_x": float(ce), "power_x": float(pw),
            "paper_area_eff_x": 1.37, "paper_power_x": 0.82}


def fig12_adaptive_adc() -> Dict[str, float]:
    """Fig 12: adaptive ADC (paper: ~15% power reduction)."""
    r = _suite_results()
    pw = np.mean([r[n]["+adaptive-adc"].peak_power_w / r[n]["+compact-htree"].peak_power_w for n in r])
    e = np.mean([
        r[n]["+adaptive-adc"].energy_per_sample_j / r[n]["+compact-htree"].energy_per_sample_j
        for n in r
    ])
    return {"power_x": float(pw), "energy_x": float(e), "paper_power_x": 0.85}


def fig13_karatsuba_recursive() -> Dict[str, float]:
    """Fig 13: divide & conquer applied recursively (1 level ~ as good as 2)."""
    c1, c2 = ka.karatsuba_cost(1), ka.karatsuba_cost(2)
    return {
        "L1_adc_slots": c1.adc_slots, "L2_adc_slots": c2.adc_slots,
        "L1_reduction": c1.adc_reduction_vs_baseline,
        "L2_reduction": c2.adc_reduction_vs_baseline,
        "L1_iters": c1.iterations, "L2_iters": c2.iterations,
        "L1_crossbars": c1.crossbars, "L2_crossbars": c2.crossbars,
    }


def fig14_karatsuba() -> Dict[str, float]:
    """Fig 14: Karatsuba stage (paper: ~25% energy-eff gain, -6.4% area eff)."""
    r = _suite_results()
    e = np.mean([r[n]["+karatsuba"].energy_per_sample_j / r[n]["+adaptive-adc"].energy_per_sample_j for n in r])
    ce = np.mean([r[n]["+karatsuba"].ce / r[n]["+adaptive-adc"].ce for n in r])
    return {"energy_x": float(e), "area_eff_x": float(ce),
            "paper_energy_x": 0.75, "paper_area_eff_x": 0.936}


def fig15_buffer_requirements() -> Dict[str, float]:
    """Fig 15: per-tile buffer needs under spreading (paper: 16 KB chosen)."""
    out = {}
    for net in wl.benchmark_suite():
        m = mapper.map_network(net, arch.NEWTON_CHIP, policy="newton")
        out[f"kb_{net.name}"] = m.mean_tile_buffer_bytes / 1024
    worst_isaac = max(
        mapper.map_network(n, arch.ISAAC_CHIP, policy="isaac").worst_tile_buffer_bytes
        for n in wl.benchmark_suite()
    )
    out["isaac_worst_kb"] = worst_isaac / 1024
    return out


def fig16_small_buffers() -> Dict[str, float]:
    """Fig 16: smaller eDRAM buffers (paper: +6.5% area efficiency)."""
    r = _suite_results()
    ce = np.mean([r[n]["+small-buffers"].ce / r[n]["+karatsuba"].ce for n in r])
    return {"area_eff_x": float(ce), "paper_area_eff_x": 1.065}


def fig17_fc_tile_power() -> Dict[str, float]:
    """Fig 17: FC tiles with slowed ADCs (paper: ~50% lower peak power)."""
    r = _suite_results()
    pw = np.mean([r[n]["+fc-tiles"].peak_power_w / r[n]["+small-buffers"].peak_power_w for n in r])
    return {"power_x": float(pw), "paper_power_x": 0.5,
            "resnet_power_x": float(
                r["resnet-34"]["+fc-tiles"].peak_power_w
                / r["resnet-34"]["+small-buffers"].peak_power_w
            )}


def fig18_fc_tile_area() -> Dict[str, float]:
    """Fig 18: crossbars sharing an ADC in FC tiles (paper: +38% area eff)."""
    r = _suite_results()
    ce = np.mean([r[n]["+fc-tiles"].ce / r[n]["+small-buffers"].ce for n in r])
    return {"area_eff_x": float(ce), "paper_area_eff_x": 1.38}


def fig19_strassen() -> Dict[str, float]:
    """Fig 19: Strassen (paper: +4.5% energy efficiency; both accountings)."""
    r = _suite_results()
    e = np.mean([
        r[n]["newton (+strassen)"].energy_per_sample_j / r[n]["+fc-tiles"].energy_per_sample_j
        for n in r
    ])
    paper_acc = st.strassen_cost(256, 256, 256, levels=1, widening="paper")
    exact_acc = st.strassen_cost(256, 256, 256, levels=1, widening="exact")
    base = st.strassen_cost(256, 256, 256, levels=0)
    return {
        "energy_x": float(e), "paper_energy_x": 0.955,
        "conv_ratio_paper_mode": paper_acc.adc_conversions / base.adc_conversions,
        "conv_ratio_exact_mode": exact_acc.adc_conversions / base.adc_conversions,
    }


def fig20_peak_ce_pe() -> Dict[str, float]:
    """Fig 20: peak CE / PE of DaDianNao, ISAAC, Newton chips."""
    isaac, newton = arch.ISAAC_CHIP, arch.NEWTON_CHIP
    return {
        "isaac_ce": isaac.ce(), "isaac_pe": isaac.pe(),
        "newton_ce": newton.ce(), "newton_pe": newton.pe(),
        "dadiannao_ce": en.DADIANNAO_REF.ce_gops_mm2,
        "dadiannao_pe": en.DADIANNAO_REF.pe_gops_w,
        "newton_over_isaac_ce": newton.ce() / isaac.ce(),
    }


def fig21_23_headline() -> Dict[str, float]:
    """Figs 21-23 aggregate: the abstract's 77% / 51% / 2.2x claims."""
    h = en.headline(_suite_results())
    r = _suite_results()
    pj_i = float(np.mean([r[n]["isaac"].pj_per_op for n in r]))
    pj_n = float(np.mean([r[n]["newton (+strassen)"].pj_per_op for n in r]))
    return {
        "power_decrease": h["power_decrease"], "paper_power_decrease": 0.77,
        "energy_decrease": h["energy_decrease"], "paper_energy_decrease": 0.51,
        "throughput_per_area_x": h["throughput_per_area_x"], "paper_tpa_x": 2.2,
        "isaac_pj_op": pj_i, "newton_pj_op": pj_n,
        "paper_isaac_pj": 1.8, "paper_newton_pj": 0.85, "ideal_pj": 0.33,
    }


def fig24_tpu_comparison() -> Dict[str, float]:
    """Fig 24: 8-bit Newton vs TPU-1, iso-area (paper: 10.3x thpt avg)."""
    tpu = en.TPUModel()
    chip8 = arch.newton_chip_8bit()
    out = {}
    ratios = []
    for net in wl.benchmark_suite():
        b = tpu.best_batch(net)
        t = tpu.throughput(net, b)
        nt = en.evaluate(net, chip8, policy="newton", strassen=True)
        ratio = nt.throughput_samples_s * tpu.area_mm2 / nt.area_mm2 / t
        ratios.append(ratio)
        out[f"x_{net.name}"] = float(ratio)
    out["mean_x"] = float(np.mean(ratios))
    out["paper_mean_x"] = 10.3
    return out


def table2_suite() -> Dict[str, float]:
    """Table II: the CNN benchmark definitions (weights / MACs sanity)."""
    out = {}
    for net in wl.benchmark_suite():
        out[f"Mw_{net.name}"] = net.total_weights / 1e6
    out["msra_over_alexnet"] = out["Mw_msra-c"] / out["Mw_alexnet"]  # paper: 5.5x
    return out


ALL: List[Tuple[str, Callable[[], Dict[str, float]]]] = [
    ("table2_suite", table2_suite),
    ("fig2_vmm_energy_breakdown", fig2_vmm_energy_breakdown),
    ("fig5_adaptive_schedule", fig5_adaptive_schedule),
    ("fig10_underutilization", fig10_underutilization),
    ("fig11_constrained_mapping", fig11_constrained_mapping),
    ("fig12_adaptive_adc", fig12_adaptive_adc),
    ("fig13_karatsuba_recursive", fig13_karatsuba_recursive),
    ("fig14_karatsuba", fig14_karatsuba),
    ("fig15_buffer_requirements", fig15_buffer_requirements),
    ("fig16_small_buffers", fig16_small_buffers),
    ("fig17_fc_tile_power", fig17_fc_tile_power),
    ("fig18_fc_tile_area", fig18_fc_tile_area),
    ("fig19_strassen", fig19_strassen),
    ("fig20_peak_ce_pe", fig20_peak_ce_pe),
    ("fig21_23_headline", fig21_23_headline),
    ("fig24_tpu_comparison", fig24_tpu_comparison),
]
