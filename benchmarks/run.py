"""Benchmark harness: one function per paper table/figure plus kernel
micro-benchmarks.  Prints ``name,us_per_call,derived`` CSV rows.

Run:  PYTHONPATH=src python -m benchmarks.run [--only fig21]
"""
from __future__ import annotations

import argparse
import json
import sys
import time


def main() -> None:
    sys.path.insert(0, "src")
    from benchmarks import kernel_bench, noise_sweep, paper_figures

    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="substring filter")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    for name, fn in paper_figures.ALL + kernel_bench.ALL + noise_sweep.ALL:
        if args.only and args.only not in name:
            continue
        t0 = time.perf_counter()
        derived = fn()
        dt_us = (time.perf_counter() - t0) * 1e6
        compact = json.dumps({k: (round(v, 4) if isinstance(v, float) else v)
                              for k, v in derived.items()})
        print(f"{name},{dt_us:.0f},{compact}")


if __name__ == "__main__":
    main()
